"""End-to-end behaviour tests for the paper's system.

The headline system property: RidgeWalker's walks feed a real graph-ML
pipeline (DeepWalk skip-gram embedding training), and the zero-bubble
scheduler measurably removes scheduling waste vs the static baseline —
the CPU-scale version of the paper's Fig. 11 claim chain.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EngineConfig
from repro.core.samplers import SamplerSpec
from repro.core.scheduler import analyze_run
from repro.core.walk_engine import _run_walks
from repro.graph import make_dataset
from repro.models import embeddings as emb

pytestmark = pytest.mark.slow  # end-to-end training loops


def test_deepwalk_to_skipgram_end_to_end(rng):
    """Walks -> sliding-window pairs -> SGNS training. Loss must drop and
    embeddings of co-walked vertices must be closer than random pairs."""
    g = make_dataset("WG", scale_override=9, weighted=True, with_alias=True)
    starts = rng.integers(0, g.num_vertices, 400).astype(np.int32)
    res = _run_walks(g, starts, SamplerSpec(kind="alias"),
                     EngineConfig(num_slots=128, max_hops=12))
    paths, lengths = res.as_numpy()

    cfg = emb.SkipGramConfig(num_vertices=g.num_vertices, dim=32,
                             num_negatives=5, window=3)
    centers, contexts = emb.pairs_from_walks(paths, lengths, cfg.window,
                                             rng, max_pairs=20000)
    assert centers.size > 1000
    params = emb.init_params(jax.random.PRNGKey(0), cfg)

    # mean-reduced SGNS + sparse row updates => large nominal lr (the
    # per-row effective step is lr/batch); lr=30 converges in 6 epochs
    @jax.jit
    def step(params, c, x, n):
        loss, g_ = jax.value_and_grad(emb.loss_fn)(params, c, x, n)
        params = jax.tree.map(lambda p, gg: p - 30.0 * gg, params, g_)
        return params, loss

    losses = []
    bs = 2048
    for epoch in range(6):
        for i in range(0, centers.size - bs, bs):
            c = jnp.asarray(centers[i:i + bs])
            x = jnp.asarray(contexts[i:i + bs])
            n = jnp.asarray(rng.integers(0, g.num_vertices, (bs, 5)))
            params, loss = step(params, c, x, n)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.8

    # co-walked pairs closer than random pairs in embedding space
    E = np.asarray(params["in_embed"])
    E = E / (np.linalg.norm(E, axis=1, keepdims=True) + 1e-9)
    pos_sim = np.mean(np.sum(E[centers[:2000]] * E[contexts[:2000]], axis=1))
    rnd = rng.integers(0, g.num_vertices, (2000, 2))
    neg_sim = np.mean(np.sum(E[rnd[:, 0]] * E[rnd[:, 1]], axis=1))
    assert pos_sim > neg_sim + 0.05


def test_zero_bubble_speedup_chain(rng):
    """System-level Fig. 11 analogue: zero-bubble scheduling completes the
    same workload in fewer supersteps at higher occupancy on a skewed,
    early-terminating workload (Graph500 RMAT)."""
    g = make_dataset("CP", scale_override=10)   # skewed, many danglers
    starts = rng.integers(0, g.num_vertices, 2000).astype(np.int32)
    base = EngineConfig(num_slots=256, max_hops=20, record_paths=False)
    spec = SamplerSpec(kind="uniform")
    a_zb = analyze_run(_run_walks(g, starts, spec, base).stats)
    a_st = analyze_run(_run_walks(
        g, starts, spec,
        dataclasses.replace(base, mode="static")).stats)
    assert a_zb.steps == a_st.steps          # identical work (stateless!)
    assert a_zb.supersteps < a_st.supersteps  # done sooner
    assert a_zb.occupancy > a_st.occupancy + 0.15
    speedup = a_st.supersteps / a_zb.supersteps
    assert speedup > 1.3


def test_neighbor_sampler_blocks(rng):
    """GNN minibatch substrate: sampled blocks have valid, real edges."""
    from repro.graph.sampling_service import sample_blocks
    g = make_dataset("WG", scale_override=10)
    seeds = rng.integers(0, g.num_vertices, 64).astype(np.int32)
    blocks, all_nodes = sample_blocks(g, jnp.asarray(seeds), (5, 3), seed=1)
    assert len(blocks) == 2
    assert blocks[0].edge_index.shape == (2, 64 * 5)
    assert blocks[1].edge_index.shape == (2, 64 * 5 * 3)
    rp, col = np.asarray(g.row_ptr), np.asarray(g.col)
    ei = np.asarray(blocks[0].edge_index)
    for s, d in zip(ei[0][:100], ei[1][:100]):
        seg = col[rp[d]:rp[d + 1]]
        assert (s in seg) or (s == d)  # sampled edge or deg-0 self-loop


def test_continuous_batching_zero_bubble():
    """Serving analogue (beyond-paper reuse): continuous batching keeps
    decode lanes busy."""
    import repro.launch.serve as serve
    from repro.configs import get_arch
    from repro.models import transformer as tfm
    cfg = dataclasses.replace(get_arch("deepseek_7b").SMOKE,
                              dtype=jnp.float32)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    r = np.random.default_rng(0)
    reqs = [jnp.asarray(r.integers(0, cfg.vocab, 8), jnp.int32)
            for _ in range(12)]
    results, stats = serve.continuous_batching_loop(
        params, cfg, reqs, num_slots=4, max_new=8, cache_cap=20)
    assert stats.completed == 12
    assert stats.bubble_ratio < 0.05
