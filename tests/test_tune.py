"""Autotuning subsystem: signatures, cache, model, tuner, serve adaptation.

Everything here is deterministic — measurement runs use the injected
measurer (`tune.measure.InjectedMeasurer`), never a clock — so the full
tuning pipeline (enumerate -> anchor -> fit -> prune -> pick -> cache)
is exercised as a pure function of its inputs.
"""
import dataclasses
import json

import numpy as np
import pytest

from conftest import run_in_subprocess
from repro import tune, walker
from repro.serve import HopsController
from repro.tune.cache import WEIGHTED_QS, GraphSignature
from repro.walker import ExecutionConfig, WalkProgram


# ------------------------------------------------------------- signatures


def test_signature_stable_and_distinguishes_skew(small_graph):
    sig1 = tune.graph_signature(small_graph)
    sig2 = tune.graph_signature(small_graph)
    assert sig1 == sig2
    assert sig1.token() == sig2.token()
    assert sig1.num_vertices == small_graph.num_vertices
    assert sig1.num_edges == small_graph.num_edges
    # the ladders are sorted ascending and end at max_degree
    assert list(sig1.deg_q) == sorted(sig1.deg_q)
    assert sig1.deg_q[-1] == sig1.max_degree
    assert sig1.deg_wq[-1] == sig1.max_degree


def test_signature_weighted_flag(small_graph, weighted_graph):
    assert not tune.graph_signature(small_graph).weighted
    assert tune.graph_signature(weighted_graph).weighted
    assert (tune.graph_signature(small_graph).token()
            != tune.graph_signature(weighted_graph).token())


def test_workload_bucket():
    assert tune.workload_bucket(None) == 0
    assert tune.workload_bucket(0) == 0
    assert tune.workload_bucket(1) == 64
    assert tune.workload_bucket(64) == 64
    assert tune.workload_bucket(65) == 128
    assert tune.workload_bucket(1000) == 1024


# ------------------------------------------------------------------ cache


def test_cache_round_trip(tmp_path, small_graph):
    path = str(tmp_path / "cache.json")
    sig = tune.graph_signature(small_graph)
    key = tune.cache_key(sig, "uniform", "single", "jnp", "cpu", True, 256)
    cache = tune.TuningCache(path)
    cache.put(key, {"num_slots": 128}, meta={"source": "measured"})
    assert cache.save() == path

    reloaded = tune.TuningCache(path)
    rec = reloaded.get(key)
    assert rec["knobs"] == {"num_slots": 128}
    assert rec["meta"]["source"] == "measured"
    # key stability: recomputing from the same graph hits the same entry
    key2 = tune.cache_key(tune.graph_signature(small_graph), "uniform",
                          "single", "jnp", "cpu", True, 256)
    assert key2 == key
    # workload bucketing: 200 and 256 queries share a bucket, 257 does not
    assert tune.cache_key(sig, "uniform", "single", "jnp", "cpu", True,
                          200) == key
    assert tune.cache_key(sig, "uniform", "single", "jnp", "cpu", True,
                          257) != key


def test_cache_tolerates_corrupt_file(tmp_path):
    path = tmp_path / "broken.json"
    path.write_text("{not json")
    cache = tune.TuningCache(str(path))
    assert len(cache) == 0
    path.write_text(json.dumps({"version": 999, "entries": {"k": {}}}))
    assert len(tune.TuningCache(str(path))) == 0


# ------------------------------------------------------------------ space


def test_candidate_apply_and_validity():
    prog = WalkProgram.urw(8)
    ex = ExecutionConfig(record_paths=False)
    cand = tune.Candidate.of(num_slots=64, queue_depth_factor=2.0)
    prog2, ex2 = cand.apply(prog, ex)
    assert ex2.num_slots == 64 and ex2.queue_depth_factor == 2.0
    assert prog2 is prog
    with pytest.raises(ValueError):
        tune.Candidate.of(num_slots=-1).apply(prog, ex)
    with pytest.raises(ValueError):
        tune.Candidate.of(bogus_knob=1).apply(prog, ex)


def test_enumeration_excludes_resampling_knobs_by_default():
    prog = WalkProgram.node2vec(2.0, 0.5, 8, weighted=True)
    ex = ExecutionConfig(record_paths=False)
    cands = tune.enumerate_candidates(prog, ex)
    chunks = {c.get("reservoir_chunk") for c in cands}
    assert chunks == {prog.spec.reservoir_chunk}  # pinned, never enumerated
    assert {c.get("adaptive_chunks") for c in cands} == {True, False}
    with_rs = tune.enumerate_candidates(prog, ex, include_resampling=True)
    assert len({c.get("reservoir_chunk") for c in with_rs}) > 1


def test_hops_per_launch_only_on_fused():
    prog = WalkProgram.urw(8)
    jnp_knobs = {k.name for k in tune.knobs_for(
        prog, ExecutionConfig(record_paths=False))}
    fused_knobs = {k.name for k in tune.knobs_for(
        prog, ExecutionConfig(record_paths=False, step_impl="fused"))}
    assert "hops_per_launch" not in jnp_knobs
    assert "hops_per_launch" in fused_knobs


# ------------------------------------------------------------------ model


def test_adaptive_gate_on_skewed_off_balanced():
    # Power-law tail: most edge mass sits at modest degrees, the max is an
    # outlier -> live lanes stay far below max_degree -> gate opens.
    assert len(WEIGHTED_QS) == 8
    skewed = GraphSignature(
        num_vertices=4096, num_edges=32768, max_degree=600,
        weighted=True, typed=False,
        deg_q=(1, 2, 4, 8, 16, 300, 600),
        deg_wq=(20, 40, 80, 120, 160, 400, 600, 600))
    assert tune.adaptive_chunk_gate(skewed, num_slots=32, chunk=16)
    # Balanced: live max ~= max degree -> adaptive cannot win -> gate off.
    balanced = GraphSignature(
        num_vertices=4096, num_edges=32768, max_degree=20,
        weighted=True, typed=False,
        deg_q=(12, 14, 15, 16, 17, 19, 20),
        deg_wq=(16, 17, 18, 18, 19, 19, 20, 20))
    assert not tune.adaptive_chunk_gate(balanced, num_slots=32, chunk=64)


def test_bytes_per_hop_orders_sampler_kinds(weighted_graph):
    sig = tune.graph_signature(weighted_graph)
    uni = tune.bytes_per_hop(WalkProgram.urw(8).spec, sig)
    rej = tune.bytes_per_hop(WalkProgram.node2vec(2.0, 0.5, 8).spec, sig)
    res = tune.bytes_per_hop(
        WalkProgram.node2vec(2.0, 0.5, 8, weighted=True).spec, sig)
    assert 0 < uni < rej < res


def test_fit_recovers_scale():
    rows = [np.array([10.0, 100.0, 1000.0, 1.0]),
            np.array([20.0, 400.0, 2000.0, 1.0]),
            np.array([5.0, 50.0, 5000.0, 2.0]),
            np.array([40.0, 200.0, 1500.0, 4.0]),
            np.array([15.0, 300.0, 2500.0, 1.0])]
    true = tune.CostCoeffs(10.0, 0.5, 0.01, 100.0)
    ys = [float(r @ true.as_array()) for r in rows]
    fitted = tune.fit(rows, ys)
    for r, y in zip(rows, ys):
        assert float(r @ fitted.as_array()) == pytest.approx(y, rel=1e-6)


def test_fit_underdetermined_rescales():
    rows = [np.array([10.0, 100.0, 1000.0, 1.0])]
    ys = [float(rows[0] @ tune.DEFAULT_COEFFS.as_array()) * 3.0]
    fitted = tune.fit(rows, ys)
    assert (float(rows[0] @ fitted.as_array())
            == pytest.approx(ys[0], rel=1e-6))


def test_prune_keeps_model_best_and_default(small_graph):
    prog = WalkProgram.urw(8)
    ex = ExecutionConfig(record_paths=False)
    sig = tune.graph_signature(small_graph)
    cands = tune.enumerate_candidates(prog, ex)
    preds = {c: tune.predict_us(*c.apply(prog, ex), sig, 256)
             for c in cands}
    best = min(preds, key=preds.get)
    knobs = tune.knobs_for(prog, ex)
    default = tune.default_candidate(prog, ex, knobs)
    kept = tune.prune(prog, ex, sig, 256, cands, keep=3,
                      always_keep=(default,))
    assert best in kept
    assert default in kept
    assert len(kept) <= 3 + 1


# ------------------------------------------------------------------ tuner


def test_autotune_injected_measurer_is_deterministic(small_graph):
    prog = WalkProgram.urw(8)
    ex = ExecutionConfig(record_paths=False)

    def cost(c):  # prefer small lane pools, mildly penalize deep queues
        return float(c.get("num_slots")) + 10.0 * float(
            c.get("queue_depth_factor"))

    results = []
    for _ in range(2):
        meas = tune.InjectedMeasurer(cost)
        res = tune.autotune(small_graph, prog, ex, num_queries=128,
                            measurer=meas, cache=tune.TuningCache(None),
                            keep=4)
        assert res.source == "measured"
        assert meas.calls >= 1            # runners were never timed
        results.append(res.candidate)
    assert results[0] == results[1]
    # the injected cost is minimized at the smallest grid point
    assert results[0].get("num_slots") == 32
    assert results[0].get("queue_depth_factor") == 0.5


def test_autotune_min_gain_keeps_default(small_graph):
    """A sub-threshold win must not displace the default (hysteresis)."""
    prog = WalkProgram.urw(8)
    ex = ExecutionConfig(record_paths=False)
    knobs = tune.knobs_for(prog, ex)
    default = tune.default_candidate(prog, ex, knobs)

    def cost(c):  # everyone ties except a 1% win somewhere else
        return 0.99 if c != default else 1.0

    res = tune.autotune(small_graph, prog, ex, num_queries=128,
                        measurer=tune.InjectedMeasurer(cost),
                        cache=tune.TuningCache(None), min_gain=0.02)
    assert res.candidate == default


def test_autotune_writes_and_reuses_cache(small_graph):
    prog = WalkProgram.urw(8)
    ex = ExecutionConfig(record_paths=False)
    cache = tune.TuningCache(None)
    res = tune.autotune(small_graph, prog, ex, num_queries=128,
                        measurer=tune.InjectedMeasurer(
                            lambda c: float(c.get("num_slots"))),
                        cache=cache, keep=3)
    assert len(cache) == 1
    again = tune.autotune(small_graph, prog, ex, num_queries=128,
                          measurer=tune.InjectedMeasurer(lambda c: 0.0),
                          cache=cache, keep=3)
    assert again.source == "cache"
    assert again.candidate == res.candidate


def test_model_only_autotune_no_measure(small_graph):
    res = tune.autotune(small_graph, WalkProgram.urw(8),
                        ExecutionConfig(record_paths=False),
                        num_queries=128, measurer=None,
                        cache=tune.TuningCache(None))
    assert res.source == "model"
    assert not res.measured
    assert not res.execution.has_auto


# --------------------------------------------------------- auto sentinels


def test_execution_config_auto_validation():
    ex = ExecutionConfig(num_slots="auto", hops_per_launch="auto")
    assert ex.has_auto
    assert ex.auto_knobs == ("num_slots", "hops_per_launch")
    with pytest.raises(ValueError):
        ExecutionConfig(num_slots="turbo")
    with pytest.raises(ValueError):
        ex.engine_config(WalkProgram.urw(8))
    r = ex.resolved(num_slots=64)
    assert r.num_slots == 64
    assert r.hops_per_launch == 16   # sentinel fell back to field default
    with pytest.raises(ValueError):
        ex.resolved(record_paths=False)   # not a tunable knob


def test_sampler_spec_adaptive_auto_validation():
    spec = WalkProgram.node2vec(2.0, 0.5, 8, weighted=True).spec
    assert spec.adaptive_chunks == "auto"
    with pytest.raises(ValueError):
        dataclasses.replace(spec, adaptive_chunks="sometimes")


def test_auto_resolution_preserves_paths(small_graph):
    prog = WalkProgram.urw(8)
    starts = np.arange(64, dtype=np.int32) % small_graph.num_vertices
    out_auto = walker.compile(
        prog, execution=ExecutionConfig(num_slots="auto")).run(
        small_graph, starts, seed=3)
    out_def = walker.compile(
        prog, execution=ExecutionConfig()).run(small_graph, starts, seed=3)
    assert (np.asarray(out_auto.paths) == np.asarray(out_def.paths)).all()
    assert (np.asarray(out_auto.lengths)
            == np.asarray(out_def.lengths)).all()


def test_auto_resolution_uses_cached_entry(small_graph, tmp_path):
    path = str(tmp_path / "cache.json")
    prog = WalkProgram.urw(8)
    ex = ExecutionConfig(num_slots="auto", tune_cache=path)
    sig = tune.graph_signature(small_graph)
    from repro.tune.tuner import _device_kind, _interpret_mode
    key = tune.cache_key(sig, "uniform", "single", "jnp", _device_kind(),
                         _interpret_mode(), 64)
    cache = tune.TuningCache(path)
    cache.put(key, {"num_slots": 96}, meta={"source": "test"})
    cache.save()
    prog2, ex2 = tune.resolve(prog, ex, small_graph, num_queries=64)
    assert ex2.num_slots == 96


def test_reservoir_auto_gate_resolution(weighted_graph):
    prog = WalkProgram.node2vec(2.0, 0.5, 8, weighted=True)
    ex = ExecutionConfig(num_slots=32, record_paths=False)
    assert tune.needs_resolution(prog, ex)    # adaptive_chunks == "auto"
    prog2, _ = tune.resolve(prog, ex, weighted_graph,
                            cache=tune.TuningCache(None))
    assert prog2.spec.adaptive_chunks in (True, False)
    sig = tune.graph_signature(weighted_graph)
    assert prog2.spec.adaptive_chunks == tune.adaptive_chunk_gate(
        sig, 32, prog.spec.reservoir_chunk)


SHARDED_AUTO = r"""
import numpy as np
from repro import walker
from repro.graph import make_dataset, partition_graph
from repro.walker import ExecutionConfig, WalkProgram

g = make_dataset("WG", scale_override=9)
pg = partition_graph(g, 2)
prog = WalkProgram.urw(6)
starts = np.arange(32, dtype=np.int32) % g.num_vertices
out_auto = walker.compile(prog, backend="sharded",
                          execution=ExecutionConfig(num_slots="auto")).run(
    pg, starts, seed=1)
out_def = walker.compile(prog, backend="sharded",
                         execution=ExecutionConfig()).run(pg, starts, seed=1)
assert (np.asarray(out_auto.paths) == np.asarray(out_def.paths)).all()
print("SHARDED_AUTO_OK")
"""


def test_auto_resolution_sharded_backend():
    out = run_in_subprocess(SHARDED_AUTO, devices=2)
    assert "SHARDED_AUTO_OK" in out


# -------------------------------------------------------- serve adaptation


def test_controller_bounds_and_validation():
    c = HopsController(min_chunk=2, max_chunk=32)
    assert c.clamp(1) == 2 and c.clamp(1000) == 32 and c.clamp(8) == 8
    with pytest.raises(ValueError):
        HopsController(min_chunk=0)
    with pytest.raises(ValueError):
        HopsController(low_water=0.5, high_water=0.1)
    with pytest.raises(ValueError):
        HopsController(patience=0)


def test_controller_shrinks_on_starvation():
    c = HopsController(min_chunk=1, max_chunk=64, high_water=0.15)
    chunk, ev = c.propose(32, starved_ratio=0.5, bubble_ratio=0.6)
    assert chunk == 16 and ev.reason == "shrink"
    # at the floor the event degrades to "hold", never below min_chunk
    chunk, ev = c.propose(1, starved_ratio=0.9, bubble_ratio=0.9)
    assert chunk == 1 and ev.reason == "hold"


def test_controller_grows_only_after_patience():
    c = HopsController(min_chunk=1, max_chunk=64, patience=3)
    for _ in range(2):
        chunk, ev = c.propose(8, starved_ratio=0.0, bubble_ratio=0.1)
        assert chunk == 8 and ev is None
    chunk, ev = c.propose(8, starved_ratio=0.0, bubble_ratio=0.1)
    assert chunk == 16 and ev.reason == "grow"
    # a bad window resets the streak
    c.propose(16, starved_ratio=0.5, bubble_ratio=0.5)
    chunk, ev = c.propose(8, starved_ratio=0.0, bubble_ratio=0.0)
    assert chunk == 8 and ev is None


def test_controller_holds_between_watermarks():
    c = HopsController(low_water=0.02, high_water=0.15, patience=1)
    chunk, ev = c.propose(8, starved_ratio=0.08, bubble_ratio=0.3)
    assert chunk == 8 and ev is None


def test_controller_converges_under_synthetic_load():
    """Feedback loop against a synthetic plant: starvation grows with the
    chunk (big launches strand arrivals).  The controller must settle
    inside its bounds without oscillating forever."""
    c = HopsController(min_chunk=1, max_chunk=256, patience=2)
    chunk = 256
    history = []
    for _ in range(64):
        starved = min(0.9, chunk / 64.0 * 0.2)   # plant: starved ~ chunk
        chunk, _ = c.propose(chunk, starved, bubble_ratio=starved)
        history.append(chunk)
    tail = history[-16:]
    assert all(1 <= h <= 256 for h in history)
    assert max(tail) - min(tail) <= max(tail) // 2 + 1  # bounded cycle
    assert max(tail) <= 64    # settled well below the starved regime


def test_service_adaptation_trace(small_graph):
    """Overloaded service grows its chunk; the trace lands in analyze()."""
    w = walker.compile(WalkProgram.urw(12),
                       execution=ExecutionConfig(num_slots=64))
    svc = w.serve(small_graph, seed=0, chunk=2, adapt=True,
                  controller=HopsController(min_chunk=1, max_chunk=32,
                                            patience=2))
    rng = np.random.default_rng(0)
    for _ in range(30):
        svc.submit(rng.integers(0, small_graph.num_vertices,
                                size=64).astype(np.int32))
        svc.step()
    svc.drain()
    events = svc.analyze().adaptation
    assert events, "overload produced no adaptation events"
    assert any(e.reason == "grow" for e in events)
    assert all(1 <= e.chunk_after <= 32 for e in events)
    assert svc.chunk <= 32
    # the trace survives into ServiceAnalysis verbatim
    assert events == svc.adaptation


def test_service_fixed_without_adapt(small_graph):
    w = walker.compile(WalkProgram.urw(8),
                       execution=ExecutionConfig(num_slots=64))
    svc = w.serve(small_graph, seed=0, chunk=4)
    svc.submit(np.arange(16, dtype=np.int32))
    svc.drain()
    assert svc.chunk == 4
    assert svc.analyze().adaptation == ()
