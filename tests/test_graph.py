"""Graph substrate tests: CSR, generators, alias tables, partitioning."""
import jax.numpy as jnp
import numpy as np

from repro.graph import (BALANCED, GRAPH500, build_alias_tables, build_csr,
                         make_dataset, partition_graph, rmat_edges,
                         validate_csr)
from repro.graph.csr import column_access, degrees, row_access
from repro.graph.generators import dangling_fraction


def test_build_csr_basic():
    edges = np.array([[0, 1], [0, 2], [1, 2], [2, 0], [3, 3]])
    g = build_csr(edges, 5)
    validate_csr(g)
    assert g.num_vertices == 5 and g.num_edges == 5
    assert list(np.asarray(degrees(g))) == [2, 1, 1, 1, 0]
    addr, deg = row_access(g, jnp.asarray([0, 4]))
    assert list(np.asarray(deg)) == [2, 0]
    v = column_access(g, addr[:1], jnp.asarray([1]))
    assert int(v[0]) == 2


def test_csr_neighbor_lists_sorted():
    g = make_dataset("WG", scale_override=9)
    rp, col = np.asarray(g.row_ptr), np.asarray(g.col)
    for v in range(0, g.num_vertices, 37):
        seg = col[rp[v]:rp[v + 1]]
        assert (np.diff(seg) > 0).all()  # sorted + dedup


def test_rmat_deterministic():
    e1, n1 = rmat_edges(10, 4, GRAPH500, seed=3)
    e2, n2 = rmat_edges(10, 4, GRAPH500, seed=3)
    assert n1 == n2 == 1024
    assert np.array_equal(e1, e2)
    e3, _ = rmat_edges(10, 4, GRAPH500, seed=4)
    assert not np.array_equal(e1, e3)


def test_rmat_graph500_skew():
    """Graph500 initiator produces a much more skewed degree distribution
    than balanced (the imbalance driver of paper §VIII-C2)."""
    eb, n = rmat_edges(12, 8, BALANCED, seed=0)
    es, _ = rmat_edges(12, 8, GRAPH500, seed=0)
    db = np.bincount(eb[:, 0], minlength=n)
    ds = np.bincount(es[:, 0], minlength=n)
    assert ds.max() > 4 * db.max()
    assert dangling_fraction(es, n) > dangling_fraction(eb, n)


def test_alias_tables_preserve_distribution(rng):
    """Alias sampling must reproduce the edge-weight distribution."""
    w = rng.random(8).astype(np.float32) + 0.05
    edges = np.array([[0, i + 1] for i in range(8)])
    g = build_csr(edges, 9, weights=w)
    g = build_alias_tables(g)
    prob = np.asarray(g.alias_prob)[:8]
    alias = np.asarray(g.alias_idx)[:8]
    # exact check: total mass per column equals d*w_i/sum(w)
    mass = prob.copy()
    for k in range(8):
        mass[alias[k]] += 1.0 - prob[k]
    expect = 8 * w / w.sum()
    np.testing.assert_allclose(mass, expect, rtol=1e-4)


def test_partition_preserves_neighbor_segments():
    g = make_dataset("WG", scale_override=9)
    pg = partition_graph(g, 4)
    rp, col = np.asarray(g.row_ptr), np.asarray(g.col)
    lrp, lcol = np.asarray(pg.row_ptr), np.asarray(pg.col)
    for v in range(0, g.num_vertices, 13):
        r, k = v % 4, v // 4
        seg_global = col[rp[v]:rp[v + 1]]
        seg_local = lcol[r, lrp[r, k]:lrp[r, k + 1]]
        assert np.array_equal(seg_global, seg_local)


def test_typed_graph_offsets():
    g = make_dataset("WG", scale_override=9, num_edge_types=3)
    validate_csr(g)
    rp = np.asarray(g.row_ptr)
    et = np.asarray(g.edge_type)
    to = np.asarray(g.type_offsets)
    for v in range(0, g.num_vertices, 29):
        seg = et[rp[v]:rp[v + 1]]
        for t in range(3):
            assert (seg[to[v, t]:to[v, t + 1]] == t).all()


def test_dataset_registry():
    from repro.graph.datasets import DATASET_SPECS
    assert set(DATASET_SPECS) == {"WG", "CP", "AS", "LJ", "AB", "UK"}
    for spec in DATASET_SPECS.values():
        assert spec.num_edges > spec.num_vertices
