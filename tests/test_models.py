"""Model correctness: transformer decode/prefill consistency, chunked
attention oracle, MoE dispatch, MACE equivariance, DCN shapes."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tfm
from repro.models.attention_chunked import (chunked_attention,
                                            full_attention_ref)
from repro.models.moe import MoEConfig, moe_apply, moe_init


KEY = jax.random.PRNGKey(0)


def _tiny(moe=None, **kw):
    return tfm.TransformerConfig(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=101,
        moe=moe, dtype=jnp.float32, **kw)


def test_decode_matches_forward():
    """Greedy decode via KV cache must produce the same logits as rerunning
    the full forward pass — the KV-cache correctness invariant."""
    cfg = _tiny()
    p = tfm.init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 12), 0, cfg.vocab)
    # full forward logits at the last position
    x, _ = tfm.forward(p, toks, cfg)
    full_logits = (x @ p["lm_head"]).astype(jnp.float32)

    # prefill on the first 11 tokens, decode token 12
    logits_p, kv = tfm.prefill(p, toks[:, :11], cfg)
    np.testing.assert_allclose(np.asarray(logits_p[:, 0]),
                               np.asarray(full_logits[:, 10]), atol=2e-4)
    cache = tfm.make_kv_cache(cfg, 2, 16, jnp.float32)
    cache = cache.at[:, :, :, :11].set(kv)
    logits_d, _ = tfm.decode_step(p, toks[:, 11:12], cache,
                                  jnp.asarray(11), cfg)
    np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                               np.asarray(full_logits[:, 11]), atol=2e-4)


def test_chunked_attention_matches_full():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 32, 8, 16))
    k = jax.random.normal(ks[1], (2, 32, 2, 16))
    v = jax.random.normal(ks[2], (2, 32, 2, 16))
    for qb, kb in [(8, 8), (16, 32), (32, 8)]:
        o = chunked_attention(q, k, v, causal=True, q_block=qb, kv_block=kb)
        r = full_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=1e-5)


def test_chunked_attention_used_above_threshold():
    cfg = _tiny(chunk_threshold=16, q_block=8, kv_block=8)
    cfg_full = _tiny(chunk_threshold=1 << 30)
    p = tfm.init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 32), 0, cfg.vocab)
    l1 = tfm.train_loss(p, toks, toks, cfg)
    l2 = tfm.train_loss(p, toks, toks, cfg_full)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_moe_dispatch_matches_dense():
    """With capacity >= T·top_k the bucketed dispatch must equal the dense
    top-k mixture computed explicitly."""
    cfg = MoEConfig(num_experts=4, top_k=2, d_ff=32, capacity_factor=8.0)
    d = 16
    p = moe_init(KEY, d, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (24, d))
    y, aux = moe_apply(p, x, cfg)

    logits = x @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gv, ge = jax.lax.top_k(probs, 2)
    gv = gv / gv.sum(-1, keepdims=True)
    expect = jnp.zeros_like(x)
    for t in range(24):
        acc = jnp.zeros((d,))
        for j in range(2):
            e = int(ge[t, j])
            g = jax.nn.silu(x[t] @ p["w_gate"][e]) * (x[t] @ p["w_up"][e])
            acc += gv[t, j] * (g @ p["w_down"][e])
        expect = expect.at[t].set(acc)
    np.testing.assert_allclose(np.asarray(y), np.asarray(expect), atol=1e-4)


def test_moe_capacity_drops_are_passthrough():
    """Over-capacity tokens contribute 0 from the MoE (residual passthrough
    at the block level) — never garbage."""
    cfg = MoEConfig(num_experts=2, top_k=1, d_ff=8, capacity_factor=0.1)
    p = moe_init(KEY, 8, cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (64, 8))
    y, _ = moe_apply(p, x, cfg)
    assert bool(jnp.isfinite(y).all())
    # some rows must be exactly zero (dropped)
    zero_rows = (jnp.abs(y).sum(-1) == 0).sum()
    assert int(zero_rows) > 0


def _rotation(seed=3):
    a, b, c = np.random.default_rng(seed).random(3) * 2 * np.pi
    Rz = np.array([[np.cos(a), -np.sin(a), 0], [np.sin(a), np.cos(a), 0],
                   [0, 0, 1]])
    Ry = np.array([[np.cos(b), 0, np.sin(b)], [0, 1, 0],
                   [-np.sin(b), 0, np.cos(b)]])
    Rx = np.array([[1, 0, 0], [0, np.cos(c), -np.sin(c)],
                   [0, np.sin(c), np.cos(c)]])
    return (Rz @ Ry @ Rx).astype(np.float32)


def test_mace_rotation_invariance(rng):
    """E(3)-equivariance: rotating + translating all positions must leave
    per-molecule energies unchanged."""
    from repro.models.gnn import mace
    cfg = mace.MACEConfig(n_layers=2, d_hidden=8, n_rbf=4)
    p = mace.init_params(KEY, cfg)
    N, E = 20, 60
    species = jnp.asarray(rng.integers(0, 5, N))
    pos = jnp.asarray(rng.random((N, 3), np.float32) * 3)
    ei = jnp.asarray(np.stack([rng.integers(0, N, E), rng.integers(0, N, E)]))
    e1 = mace.apply(p, species, pos, ei, cfg)
    R = jnp.asarray(_rotation())
    e2 = mace.apply(p, species, pos @ R.T + 1.5, ei, cfg)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), rtol=2e-4,
                               atol=2e-4)


def test_schnet_rotation_invariance(rng):
    from repro.models.gnn import schnet
    cfg = schnet.SchNetConfig(n_interactions=2, d_hidden=16, n_rbf=16)
    p = schnet.init_params(KEY, cfg)
    N, E = 20, 60
    species = jnp.asarray(rng.integers(0, 5, N))
    pos = jnp.asarray(rng.random((N, 3), np.float32) * 3)
    ei = jnp.asarray(np.stack([rng.integers(0, N, E), rng.integers(0, N, E)]))
    e1 = schnet.apply(p, species, pos, ei, cfg)
    R = jnp.asarray(_rotation())
    e2 = schnet.apply(p, species, pos @ R.T - 0.3, ei, cfg)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), rtol=2e-4,
                               atol=2e-4)


def test_dcn_cross_layer_identity():
    """With zero cross weights, cross output must equal x0 (residual)."""
    from repro.models.recsys import dcn
    cfg = dcn.DCNConfig(vocab_sizes=tuple([50] * 26), mlp_dims=(32, 16))
    p = dcn.init_params(KEY, cfg)
    p = jax.tree.map(lambda x: x, p)
    for c in p["cross"]:
        c["w"] = jnp.zeros_like(c["w"])
        c["b"] = jnp.zeros_like(c["b"])
    B = 4
    r = np.random.default_rng(0)
    dense = jnp.asarray(r.random((B, 13), np.float32))
    sparse = jnp.asarray(r.integers(0, 50, (B, 26)).astype(np.int32))
    z = dcn._backbone(p, dense, sparse, cfg)
    # first d0 dims of the backbone output are the cross tower == x0
    from repro.models.recsys.embedding import EmbeddingConfig, lookup
    x0 = jnp.concatenate(
        [dense, lookup(p["tables"], sparse, EmbeddingConfig(cfg.vocabs(), 16))],
        axis=-1)
    np.testing.assert_allclose(np.asarray(z[:, :cfg.d0]), np.asarray(x0),
                               atol=1e-6)


def test_skipgram_loss_decreases(rng):
    from repro.models import embeddings as emb
    cfg = emb.SkipGramConfig(num_vertices=50, dim=16, num_negatives=4)
    p = emb.init_params(KEY, cfg)
    c = jnp.asarray(rng.integers(0, 50, 256))
    x = jnp.asarray((np.asarray(c) + 1) % 50)
    n = jnp.asarray(rng.integers(0, 50, (256, 4)))
    loss0 = emb.loss_fn(p, c, x, n)
    g = jax.grad(emb.loss_fn)(p, c, x, n)
    p2 = jax.tree.map(lambda a, b: a - 0.5 * b, p, g)
    loss1 = emb.loss_fn(p2, c, x, n)
    assert float(loss1) < float(loss0)
