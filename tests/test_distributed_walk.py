"""Distributed engine tests (forced host devices via subprocess —
device count locks at first jax init, so these run out-of-process)."""
import pytest

from conftest import run_in_subprocess

pytestmark = pytest.mark.slow  # out-of-process multi-device runs


DIST_EQUIV = r"""
import numpy as np
from repro.graph import make_dataset, partition_graph
from repro.core import EngineConfig
from repro.core.samplers import SamplerSpec
from repro.core.distributed import DistConfig, _run_distributed, assemble_paths
from repro.core.walk_engine import _run_walks

for kind, kwargs in [("uniform", {}), ("alias", dict(weighted=True, with_alias=True))]:
    g = make_dataset("WG", scale_override=9, **kwargs)
    pg = partition_graph(g, {N})
    starts = np.random.default_rng(0).integers(0, g.num_vertices, 240).astype(np.int32)
    spec = SamplerSpec(kind=kind)
    ref = _run_walks(g, starts, spec, EngineConfig(num_slots=64, max_hops=10), seed=3)
    rp, rl = ref.as_numpy()
    logs, stats = _run_distributed(pg, starts, spec,
        DistConfig(slots_per_device=16, max_hops=10, log_capacity=1<<14), seed=3)
    dp, dl = assemble_paths(logs, starts, 10)
    assert (dp == rp).all() and (dl == rl).all(), kind
    assert int(np.asarray(stats.drops).sum()) == 0, kind
print("EQUIV_OK")
"""


@pytest.mark.parametrize("n_devices", [2, 8])
def test_distributed_bit_identical(n_devices):
    """The strongest §V-A check: re-routing tasks across N devices yields
    bit-identical walks to the single-device engine.

    The 8-device case used to xfail: the heuristically-sized router
    retention overflowed under hub skew and silently dropped live tasks,
    truncating their walks.  The flow-controlled refill (global live-task
    bound N·W_loc, retention provisioned to it) makes drops structurally
    impossible — see core/distributed.py module docs."""
    out = run_in_subprocess(DIST_EQUIV.replace("{N}", str(n_devices)),
                            devices=max(n_devices, 2))
    assert "EQUIV_OK" in out


PPR_DIST = r"""
import numpy as np
from repro.graph import make_dataset, partition_graph
from repro.core import EngineConfig
from repro.core.samplers import SamplerSpec
from repro.core.distributed import DistConfig, _run_distributed, assemble_paths
from repro.core.walk_engine import _run_walks

g = make_dataset("CP", scale_override=9)
pg = partition_graph(g, 8)
starts = np.random.default_rng(1).integers(0, g.num_vertices, 200).astype(np.int32)
spec = SamplerSpec(kind="uniform", stop_prob=0.2)
ref = _run_walks(g, starts, spec, EngineConfig(num_slots=64, max_hops=20), seed=11)
logs, stats = _run_distributed(pg, starts, spec,
    DistConfig(slots_per_device=16, max_hops=20, log_capacity=1<<14), seed=11)
dp, dl = assemble_paths(logs, starts, 20)
rp, rl = ref.as_numpy()
assert (dp == rp).all() and (dl == rl).all()
waits = int(np.asarray(stats.route_waits).sum())
drops = int(np.asarray(stats.drops).sum())
assert drops == 0
print("PPR_OK waits=", waits)
"""


def test_distributed_ppr_and_no_drops():
    out = run_in_subprocess(PPR_DIST, devices=8)
    assert "PPR_OK" in out


ROUTER_UNIT = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import router
from repro.core.tasks import WalkerSlots

# pack_buckets: every live task either lands in its destination bucket or
# retention; nothing is lost below capacity.
S, N, K, R = 64, 4, 8, 32
rng = np.random.default_rng(0)
slots = WalkerSlots(
    v_curr=jnp.asarray(rng.integers(0, 100, S), jnp.int32),
    v_prev=jnp.full((S,), -1, jnp.int32),
    query_id=jnp.asarray(np.arange(S), jnp.int32),
    hop=jnp.zeros((S,), jnp.int32),
    active=jnp.asarray(rng.random(S) < 0.8),
    epoch=jnp.zeros((S,), jnp.int32))
dest = jnp.asarray(rng.integers(0, N, S), jnp.int32)
prio = jnp.ones((S,), jnp.int32)
rr = router.pack_buckets(slots, dest, prio, N, K, R)
sent = np.asarray(rr.send.query_id)
ret = np.asarray(rr.retention.query_id)
live = set(np.asarray(slots.query_id)[np.asarray(slots.active)].tolist())
placed = set(sent[sent >= 0].tolist()) | set(ret[ret >= 0].tolist())
assert placed == live, (placed ^ live)
assert int(rr.drops) == 0
# destination correctness
d = np.asarray(dest); q = np.asarray(slots.query_id)
for b in range(N):
    ids = sent[b*K:(b+1)*K]
    for qid in ids[ids >= 0]:
        assert d[list(q).index(qid)] == b
print("ROUTER_OK")
"""


def test_router_pack_buckets_lossless():
    out = run_in_subprocess(ROUTER_UNIT, devices=2)
    assert "ROUTER_OK" in out


N2V_DIST = r"""
import numpy as np
from repro.graph import make_dataset, partition_graph
from repro.core import EngineConfig
from repro.core.samplers import SamplerSpec
from repro.core.distributed import DistConfig, _run_distributed, assemble_paths
from repro.core.walk_engine import _run_walks

g = make_dataset("WG", scale_override=9)
pg = partition_graph(g, 8)
starts = np.random.default_rng(0).integers(0, g.num_vertices, 200).astype(np.int32)
spec = SamplerSpec(kind="rejection_n2v", p=2.0, q=0.5, rejection_rounds=8)
ref = _run_walks(g, starts, spec, EngineConfig(num_slots=64, max_hops=10), seed=5)
rp, rl = ref.as_numpy()
logs, stats = _run_distributed(pg, starts, spec,
    DistConfig(slots_per_device=16, max_hops=10, log_capacity=1<<14), seed=5)
dp, dl = assemble_paths(logs, starts, 10)
assert (dp == rp).all() and (dl == rl).all()
assert int(np.asarray(stats.drops).sum()) == 0
print("N2V_DIST_OK")
"""


def test_distributed_node2vec_two_phase():
    """Second-order walks route through the *generic* distributed engine
    (phase-program dispatch: propose at owner(v_curr), verify at
    owner(v_prev)) and are bit-identical to the single-device rejection
    sampler."""
    out = run_in_subprocess(N2V_DIST, devices=8)
    assert "N2V_DIST_OK" in out


W_N2V_DIST = r"""
import numpy as np
from repro import walker
from repro.graph import make_dataset, partition_graph

g = make_dataset("WG", scale_override=9, weighted=True)
pg = partition_graph(g, 2)
starts = np.random.default_rng(1).integers(0, g.num_vertices, 120).astype(np.int32)
program = walker.WalkProgram.node2vec(2.0, 0.5, 10, weighted=True)
ref = walker.compile(
    program, execution=walker.ExecutionConfig(num_slots=64)).run(
        g, starts, seed=7)
rp, rl = ref.as_numpy()
res = walker.compile(
    program, backend="sharded",
    execution=walker.ExecutionConfig(slots_per_device=16,
                                     log_capacity=1 << 14)).run(
        pg, starts, seed=7)
dp, dl = res.as_numpy()
assert (dp == rp).all() and (dl == rl).all()
assert int(np.asarray(res.stats.drops)) == 0
# Hop-0 prescan: the one-time batched local scan replaces the per-query
# hop-0 superstep (was 141 at PR 2, 91 after per-lane early finalize).
assert int(res.stats.supersteps) < 91, int(res.stats.supersteps)
print("W_N2V_OK")
"""


def test_distributed_weighted_node2vec_reservoir():
    """Weighted Node2Vec (Efraimidis–Spirakis reservoir) on 2 devices,
    through compile(program, backend="sharded"): the chunked scan
    ping-pongs between owner(v_curr) and owner(v_prev) and the sampled
    walks are bit-identical to the single-device reference.  The hop-0
    local scan is batched out of the superstep loop (supersteps < 91)."""
    out = run_in_subprocess(W_N2V_DIST, devices=2)
    assert "W_N2V_OK" in out
