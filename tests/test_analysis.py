"""Static-verifier tests: clean runs over the real declarations, CLI
exit-code semantics, property tests mutating valid declarations into
each hazard class, and the BENCH schema validation."""
import dataclasses
import json
import os
import subprocess
import sys

import pytest

from conftest import REPO, SRC, hypothesis_or_stubs
from repro.analysis import dma_hazards, residency, rng_collisions, run_all
from repro.analysis.fixtures import FIXTURES, run_fixture
from repro.core.phase_program import DrawStream, _default_spec, lower
from repro.core.rng import SALTS, SaltRegistry
from repro.core.samplers import KINDS
from repro.kernels.common import DmaOp, schedule_buffers

given, settings, st = hypothesis_or_stubs()


# ------------------------------------------------------------- clean runs


def test_repo_is_clean():
    assert run_all() == []


@pytest.mark.parametrize("kind", KINDS)
def test_every_kind_streams_disjoint(kind):
    streams = rng_collisions.spec_streams(_default_spec(kind))
    assert len(streams) >= 2  # sampler draw + engine stop draw
    assert rng_collisions.check_streams(streams) == []


@pytest.mark.parametrize("kind", KINDS)
def test_every_kind_residency_legal(kind):
    assert residency.check_program(lower(_default_spec(kind))) == []


def test_every_kernel_schedule_hazard_free():
    schedules = dma_hazards.kernel_schedules()
    # every kernel in the tree is declared
    assert {"walk_step.uniform", "walk_step.alias", "embedding_bag",
            "segment_sum"} <= set(schedules)
    assert {f"fused_superstep.{k}" for k in KINDS} <= set(schedules)
    for name, ops in schedules.items():
        assert dma_hazards.check_schedule(ops, name) == []
        assert len(schedule_buffers(ops)) >= 1


def test_builder_patterns_hazard_free():
    """The ScheduleBuilder emitters are safe by construction at any
    unroll count ≥ 1 (they mirror the kernels' loop shapes)."""
    from repro.kernels.common import ScheduleBuilder
    for n in (1, 2, 3, 5):
        b = ScheduleBuilder()
        b.gather_loop("g", n)
        b.pingpong_loop(["c", "w"], n, reads_per_chunk=2)
        b.writeback_loop("wb", n)
        assert dma_hazards.check_schedule(b.ops, f"patterns[{n}]") == []


def test_fixtures_all_trip():
    for name in FIXTURES:
        findings = run_fixture(name)
        assert findings, f"fixture {name} produced no findings"
        for f in findings:
            assert f.site and f.message  # diagnostics are actionable


# ---------------------------------------------------------- salt registry


def test_registry_rejects_duplicate_scalar():
    reg = SaltRegistry()
    reg.register("A", 0)
    with pytest.raises(ValueError):
        reg.register("B", 0)


def test_registry_rejects_scalar_inside_family():
    reg = SaltRegistry()
    reg.register("FAM", 8, family=True)
    with pytest.raises(ValueError):
        reg.register("S", 12)
    reg.register("OK", 3)  # below the family base is fine


def test_registry_rejects_second_family():
    reg = SaltRegistry()
    reg.register("FAM", 8, family=True)
    with pytest.raises(ValueError):
        reg.register("FAM2", 100, family=True)


def test_global_registry_channels():
    names = SALTS.names()
    assert {"SALT_COLUMN", "SALT_ACCEPT", "SALT_STOP",
            "SALT_CHUNK0"} <= set(names)
    assert SALTS["SALT_CHUNK0"].family


# ----------------------------------------------- property tests: mutation


@given(salt=st.integers(min_value=0, max_value=7),
       w1=st.integers(min_value=1, max_value=64),
       w2=st.integers(min_value=1, max_value=64))
@settings(max_examples=30, deadline=None)
def test_any_duplicate_salt_collides(salt, w1, w2):
    streams = (DrawStream("a", salt, w1), DrawStream("b", salt, w2))
    findings = rng_collisions.check_streams(streams)
    assert findings and findings[0].pass_name == "rng"
    assert f"[0, {min(w1, w2)})" in findings[0].message


@given(offset=st.integers(min_value=0, max_value=100))
@settings(max_examples=30, deadline=None)
def test_any_scalar_inside_chunk_family_collides(offset):
    fam = DrawStream("fam", 8, 64, family=True)
    scalar = DrawStream("scalar", 8 + offset, 1)
    assert rng_collisions.check_streams((fam, scalar))


@given(drop=st.integers(min_value=0, max_value=5))
@settings(max_examples=30, deadline=None)
def test_dropping_any_wait_is_caught(drop):
    from repro.kernels.walk_step.walk_step import dma_schedule
    ops = dma_schedule("uniform")
    waits = [i for i, op in enumerate(ops) if op.kind == "wait"]
    i = waits[drop % len(waits)]
    mutated = ops[:i] + ops[i + 1:]
    findings = dma_hazards.check_schedule(mutated, "mutated")
    assert findings
    assert any("read-before-arrival" in f.message
               or "never waited" in f.message for f in findings)


@given(kind=st.sampled_from(["uniform", "alias", "metapath",
                             "rejection_n2v", "reservoir_n2v"]),
       seed=st.integers(min_value=0, max_value=10**6))
@settings(max_examples=25, deadline=None)
def test_pinning_pingpong_to_one_slot_is_caught(kind, seed):
    from repro.kernels.fused_superstep.fused_superstep import dma_schedule
    ops = dma_schedule(kind)
    bufs = [b for b in schedule_buffers(ops) if b != "wbuf"]
    buf = bufs[seed % len(bufs)]
    mutated = [op._replace(slot=0) if op.buffer == buf else op
               for op in ops]
    findings = dma_hazards.check_schedule(mutated, "mutated")
    assert any("overwrite-while-in-flight" in f.message
               or "not in flight" in f.message for f in findings)


@given(kind=st.sampled_from(["uniform", "alias", "metapath"]))
@settings(max_examples=10, deadline=None)
def test_moving_phase_to_vprev_is_caught(kind):
    prog = lower(_default_spec(kind))
    idx = next(i for i, p in enumerate(prog.phases)
               if p.op in ("draw", "gather"))
    phases = list(prog.phases)
    phases[idx] = dataclasses.replace(phases[idx], residency="v_prev")
    mutated = dataclasses.replace(prog, phases=tuple(phases))
    findings = residency.check_program(mutated)
    assert any("v_prev" in f.message for f in findings)


def test_single_phase_with_carry_is_caught():
    prog = dataclasses.replace(lower(_default_spec("uniform")),
                               carry="candidates")
    assert residency.check_program(prog)


def test_dead_accumulate_without_init_is_caught():
    ops = [DmaOp("visit", "out", 0, first=False, live=True)]
    findings = dma_hazards.check_schedule(ops, "x")
    assert any("uninitialized" in f.message for f in findings)


# -------------------------------------------------------------------- CLI


def _run_cli(*args):
    env = dict(os.environ, PYTHONPATH=SRC)
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=300)


def test_cli_check_passes_on_repo():
    r = _run_cli("--check")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "all invariants hold" in r.stdout


def test_cli_fixture_exits_nonzero_with_diagnostics():
    for name in ("rng-duplicate-salt", "dma-missing-wait",
                 "residency-vprev-draw", "determinism-jax-random"):
        r = _run_cli("--fixture", name)
        assert r.returncode == 1, (name, r.stdout)
        assert "finding" in r.stdout  # per-finding diagnostics printed


def test_cli_table_embedded_in_docs():
    r = _run_cli("--table")
    assert r.returncode == 0
    doc = open(os.path.join(REPO, "docs", "architecture.md")).read()
    for line in r.stdout.splitlines():
        if line.strip():
            assert line in doc, f"docs drift: {line!r}"


# ----------------------------------------------------------- BENCH schema


def test_bench_schema_accepts_valid():
    sys.path.insert(0, REPO)
    try:
        from benchmarks.run import validate_payload
    finally:
        sys.path.pop(0)
    payload = {"fig8": {"urw": {"us_per_call": 1.5, "derived": "x"}},
               "walks_per_sec": {"urw": {"jnp": 1e6, "fused": 2e6}}}
    assert validate_payload(payload) == []
    assert json.dumps(payload)  # serializable


@pytest.mark.parametrize("mutate,expect", [
    (lambda p: p["fig8"]["urw"].update(us_per_per_call=1.0), "unknown"),
    (lambda p: p["fig8"]["urw"].pop("derived"), "missing"),
    (lambda p: p["fig8"]["urw"].update(us_per_call="fast"), "number"),
    (lambda p: p.update(fig9=[1, 2]), "expected dict"),
    (lambda p: p["walks_per_sec"]["urw"].update(jnp="NaN?"), "number"),
])
def test_bench_schema_rejects_malformed(mutate, expect):
    sys.path.insert(0, REPO)
    try:
        from benchmarks.run import validate_payload
    finally:
        sys.path.pop(0)
    payload = {"fig8": {"urw": {"us_per_call": 1.5, "derived": "x"}},
               "walks_per_sec": {"urw": {"jnp": 1e6}}}
    mutate(payload)
    problems = validate_payload(payload)
    assert problems and any(expect in p for p in problems)
