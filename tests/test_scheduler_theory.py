"""Queuing-theory layer (paper §VI): Theorem VI.1 depth formula, butterfly
delay bounds, and a hypothesis property test that the zero-bubble property
holds across random workloads whenever the buffer is provisioned at the
theorem depth."""
import numpy as np
import pytest

from conftest import hypothesis_or_stubs
from repro.core import EngineConfig
from repro.core.samplers import SamplerSpec
from repro.core.scheduler import (analyze_run, butterfly_feedback_delay,
                                  min_queue_depth, per_pipeline_fifo_depth,
                                  routing_capacity)
from repro.core.walk_engine import _run_walks
from repro.graph import build_csr
from repro.graph.generators import GRAPH500, rmat_edges

given, settings, st = hypothesis_or_stubs()


def test_paper_constants():
    """§VI-D: 16 pipelines -> C = 4·log2(16) = 16; per-pipeline FIFO depth
    1 + 4·log2(16) = 17; paper Table/§VIII uses 65-entry scheduler FIFOs
    (> the bound, as expected for an implementation)."""
    assert butterfly_feedback_delay(16) == 16
    assert per_pipeline_fifo_depth(16) == 17
    assert min_queue_depth(16, 1.0, butterfly_feedback_delay(16)) == \
        16 + 16 * 16


def test_routing_capacity_margin():
    assert routing_capacity(256, 8, margin=2.0) == 64
    assert routing_capacity(7, 8, margin=2.0) == 2  # ceil on tiny loads


@pytest.mark.slow
@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), delay=st.integers(0, 4),
       slots_pow=st.integers(4, 7))
def test_zero_starvation_at_theorem_depth(seed, delay, slots_pow):
    """Property: ∀ graph/seed/delay — queue depth D = N(1+C) ⇒ no lane
    starves while upstream queries exist (Theorem VI.1)."""
    slots = 1 << slots_pow
    edges, n = rmat_edges(9, 4, GRAPH500, seed=seed)
    g = build_csr(edges, n)
    starts = np.random.default_rng(seed).integers(0, n, 4 * slots)
    cfg = EngineConfig(num_slots=slots, max_hops=8, injection_delay=delay,
                       record_paths=False)
    a = analyze_run(_run_walks(g, starts, SamplerSpec(kind="uniform"),
                               cfg).stats)
    assert a.starved == 0
    assert a.terminations == len(starts)
