"""MoE dispatch variants: row vs global, expert padding, aux loss."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.moe import MoEConfig, moe_apply, moe_apply_batched, moe_init

KEY = jax.random.PRNGKey(0)


def test_row_dispatch_matches_global_at_high_capacity():
    """With capacity ≥ all tokens, per-row and global dispatch compute the
    identical mixture (dispatch granularity only changes *drop* behavior)."""
    cfg_g = MoEConfig(num_experts=4, top_k=2, d_ff=16, capacity_factor=16.0,
                      dispatch="global")
    cfg_r = MoEConfig(num_experts=4, top_k=2, d_ff=16, capacity_factor=16.0,
                      dispatch="row")
    p = moe_init(KEY, 8, cfg_g)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 10, 8))
    yg, _ = moe_apply_batched(p, x, cfg_g)
    yr, _ = moe_apply_batched(p, x, cfg_r)
    np.testing.assert_allclose(np.asarray(yg), np.asarray(yr), atol=1e-5)


def test_padded_experts_never_routed():
    """Padding experts to a mesh-divisible count must not change outputs
    (dummy experts receive -inf router logits)."""
    cfg = MoEConfig(num_experts=5, top_k=2, d_ff=16, capacity_factor=8.0)
    cfg_pad = MoEConfig(num_experts=5, top_k=2, d_ff=16, capacity_factor=8.0,
                        pad_experts_to=8)
    assert cfg_pad.padded_experts == 8
    p = moe_init(KEY, 8, cfg_pad)
    # un-padded params = slice of padded params
    p5 = dict(p, w_gate=p["w_gate"][:5], w_up=p["w_up"][:5],
              w_down=p["w_down"][:5])
    x = jax.random.normal(jax.random.PRNGKey(2), (24, 8))
    y8, _ = moe_apply(p, x, cfg_pad)
    y5, _ = moe_apply(p5, x, cfg)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y5), atol=1e-5)


def test_aux_loss_encourages_balance():
    cfg = MoEConfig(num_experts=4, top_k=1, d_ff=8, router_aux_weight=1.0)
    p = moe_init(KEY, 8, cfg)
    # force all tokens to expert 0 -> aux near its max; random router -> ~1
    p_skew = dict(p, router=jnp.zeros_like(p["router"])
                  .at[:, 0].set(100.0))
    x = jax.random.normal(jax.random.PRNGKey(3), (64, 8))
    _, aux_skew = moe_apply(p_skew, x, cfg)
    _, aux_rand = moe_apply(p, x, cfg)
    assert float(aux_skew) > float(aux_rand)


def test_moe_grads_flow():
    cfg = MoEConfig(num_experts=4, top_k=2, d_ff=16, dispatch="row")
    p = moe_init(KEY, 8, cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 12, 8))

    def loss(p):
        y, aux = moe_apply_batched(p, x, cfg)
        return jnp.sum(jnp.square(y)) + aux

    g = jax.grad(loss)(p)
    total = sum(float(jnp.sum(jnp.abs(l))) for l in jax.tree.leaves(g))
    assert np.isfinite(total) and total > 0
