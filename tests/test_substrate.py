"""Substrate tests: optimizer, checkpoint/restore, train loop + fault
tolerance, gradient compression, data pipeline determinism."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpointer
from repro.data import pipeline as datapipe
from repro.optim import adamw
from repro.optim.grad_compression import (compress_with_feedback,
                                          dequantize_int8, quantize_int8)
from repro.runtime import train_loop


def test_adamw_minimizes_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                            total_steps=200)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw.init_state(params)
    loss = lambda p: jnp.sum(jnp.square(p["w"]))
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, state, _ = adamw.apply_updates(params, g, state, cfg)
    assert float(loss(params)) < 1e-2


def test_cosine_schedule_shape():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_ratio=0.1)
    lrs = [float(adamw.cosine_lr(cfg, s)) for s in [0, 5, 10, 55, 100]]
    assert lrs[0] < lrs[1] < lrs[2]          # warmup
    assert abs(lrs[2] - 1.0) < 1e-6          # peak
    assert lrs[2] > lrs[3] > lrs[4]          # decay
    assert abs(lrs[4] - 0.1) < 1e-6          # floor


def test_int8_quantization_roundtrip(rng):
    x = jnp.asarray(rng.standard_normal(1000), jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) / 2 + 1e-6


def test_error_feedback_accumulates(rng):
    """EF invariant: quantized-with-feedback averages converge to the true
    gradient average (residual never lost)."""
    g = jnp.asarray(rng.standard_normal(512) * 1e-3, jnp.float32)
    e = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    for _ in range(50):
        (_, _), deq, e = compress_with_feedback(g, e)
        total = total + deq
    np.testing.assert_allclose(np.asarray(total / 50), np.asarray(g),
                               atol=float(jnp.abs(g).max()) * 0.05)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 4), jnp.bfloat16)},
            "s": jnp.asarray(7, jnp.int32)}
    checkpointer.save(str(tmp_path), 42, tree)
    assert checkpointer.latest_step(str(tmp_path)) == 42
    like = jax.tree.map(jnp.zeros_like, tree)
    back = checkpointer.restore(str(tmp_path), 42, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_atomicity(tmp_path):
    """A .tmp dir must never be visible as a valid checkpoint."""
    tree = {"a": jnp.ones(4)}
    checkpointer.save(str(tmp_path), 1, tree)
    os.makedirs(os.path.join(str(tmp_path), "step_00000002.tmp"))
    assert checkpointer.latest_step(str(tmp_path)) == 1


def test_train_loop_runs_and_resumes(tmp_path):
    cfg = adamw.AdamWConfig(lr=0.25, weight_decay=0.0, warmup_steps=1,
                            total_steps=40)
    params = {"w": jnp.asarray([4.0])}
    state = (params, adamw.init_state(params))

    @jax.jit
    def step_fn(state, batch):
        params, opt = state
        loss, g = jax.value_and_grad(
            lambda p: jnp.sum(jnp.square(p["w"] - batch)))(params)
        params, opt, _ = adamw.apply_updates(params, g, opt, cfg)
        return (params, opt), {"loss": loss}

    loop_cfg = train_loop.TrainLoopConfig(
        total_steps=20, ckpt_dir=str(tmp_path), ckpt_every=10, log_every=5,
        async_checkpoint=False)
    batch_fn = lambda s: jnp.asarray(1.0)
    state, step, hist, wd = train_loop.run(step_fn, state, batch_fn, loop_cfg)
    assert step == 20
    assert checkpointer.latest_step(str(tmp_path)) == 20
    # resume continues from 20
    state2, start = train_loop.resume_or_init(str(tmp_path), state)
    assert start == 20
    loop_cfg2 = dataclasses.replace(loop_cfg, total_steps=30)
    state2, step2, _, _ = train_loop.run(step_fn, state2, batch_fn,
                                         loop_cfg2, start_step=start)
    assert step2 == 30
    assert abs(float(state2[0]["w"][0]) - 1.0) < 0.5


def test_straggler_watchdog():
    wd = train_loop.StragglerWatchdog(factor=3.0)
    for _ in range(10):
        wd.observe(0.01)
    assert wd.observe(0.2) is True
    assert wd.straggler_steps == 1


def test_elastic_remesh_plan():
    from repro.runtime.elastic import plan_remesh, ElasticController
    assert plan_remesh(512)[0] == (2, 16, 16)
    assert plan_remesh(511)[0] == (1, 16, 16)
    assert plan_remesh(256)[0] == (1, 16, 16)
    assert plan_remesh(8)[0] == (8,)
    ctl = ElasticController(min_devices=4)
    assert ctl.decide(2, 100, 0) == "abort"
    assert ctl.decide(256, 100, 50) == "remesh"
    assert ctl.decide(256, 100, 0) is None


def test_data_pipeline_deterministic():
    cfg = datapipe.TokenPipelineConfig(vocab=100, seq_len=16, global_batch=4)
    t1, l1 = datapipe.lm_batch(cfg, 7)
    t2, l2 = datapipe.lm_batch(cfg, 7)
    t3, _ = datapipe.lm_batch(cfg, 8)
    assert np.array_equal(t1, t2) and np.array_equal(l1, l2)
    assert not np.array_equal(t1, t3)
    assert (l1 == np.roll(np.concatenate([t1, l1[:, -1:]], 1), -1, 1)[:, :-1]).all()


def test_checkpoint_elastic_reshard(tmp_path):
    """Restore with an explicit (single-device) sharding — the elastic path."""
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    checkpointer.save(str(tmp_path), 5, tree)
    sh = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    back = checkpointer.restore(str(tmp_path), 5, tree, {"w": sh})
    assert back["w"].sharding == sh
    np.testing.assert_array_equal(np.asarray(back["w"]),
                                  np.asarray(tree["w"]))
