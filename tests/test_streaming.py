"""Streaming (open-system) engine + WalkService: chunked/one-shot parity,
mid-stream injection, multi-tenant harvesting, and the ring-buffer slot
economy (continuous reclamation, epoch-salted RNG, no drain barrier)."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_in_subprocess
from repro import walker
from repro.core import EngineConfig, rng as task_rng
from repro.core.samplers import SamplerSpec
from repro.core.walk_engine import (init_stream_state, inject_queries,
                                    make_superstep_runner, run_walks)
from repro.serve import OpenLoad, WalkService, run_open_load

CFG = EngineConfig(num_slots=64, max_hops=12)
SPECS = {
    "uniform": SamplerSpec(kind="uniform"),
    "node2vec": SamplerSpec(kind="rejection_n2v", p=2.0, q=0.5),
}


def _drain_stream(runner, graph, state, seed, chunk):
    for _ in range(10_000):
        if bool(np.asarray(state.done).all()):
            return state
        state = runner(graph, state, seed, chunk)
    raise AssertionError("stream did not drain")


def _inject_fresh(state, starts, qid0=0):
    """Engine-level injection of fresh (epoch 0) queries into sequential
    slots — the closed-batch special case of the ring economy."""
    n = len(starts)
    qids = jnp.arange(qid0, qid0 + n, dtype=jnp.int32)
    return inject_queries(state, qids, jnp.asarray(starts, jnp.int32),
                          jnp.zeros((n,), jnp.int32), n)


@pytest.mark.parametrize("algo", sorted(SPECS))
def test_chunked_matches_oneshot(algo, small_graph, rng):
    """Parity: chunked run_supersteps == one-shot engine, bit-identical."""
    spec = SPECS[algo]
    starts = rng.integers(0, small_graph.num_vertices, 300).astype(np.int32)
    one = run_walks(small_graph, starts, spec, CFG, seed=3)
    p1, l1 = one.as_numpy()

    runner = make_superstep_runner(spec, CFG)
    state = init_stream_state(CFG, capacity=300)
    state = _inject_fresh(state, starts)
    state = _drain_stream(runner, small_graph, state, seed=3, chunk=7)
    assert np.array_equal(p1, np.asarray(state.paths))
    assert np.array_equal(l1, np.asarray(state.lengths))
    assert int(state.stats.terminations) == 300


def test_midstream_injection_preserves_paths(small_graph, rng):
    """Queries injected while the engine is mid-flight sample the same
    paths as a single up-front batch (stateless tasks, §V-A)."""
    spec = SPECS["uniform"]
    starts = rng.integers(0, small_graph.num_vertices, 200).astype(np.int32)
    p1, l1 = run_walks(small_graph, starts, spec, CFG, seed=5).as_numpy()

    runner = make_superstep_runner(spec, CFG)
    state = init_stream_state(CFG, capacity=200)
    state = _inject_fresh(state, starts[:80])
    state = runner(small_graph, state, 5, 4)
    assert not bool(np.asarray(state.done).all())
    state = _inject_fresh(state, starts[80:], qid0=80)
    state = _drain_stream(runner, small_graph, state, seed=5, chunk=6)
    assert np.array_equal(p1, np.asarray(state.paths))
    assert np.array_equal(l1, np.asarray(state.lengths))


def test_inject_padding_is_inert(small_graph, rng):
    """Padded injection (fixed block shapes) must not create phantom
    queries: tail advances by n_valid only, pad entries are dropped."""
    spec = SPECS["uniform"]
    starts = rng.integers(0, small_graph.num_vertices, 48).astype(np.int32)
    p1, l1 = run_walks(small_graph, starts, spec, CFG, seed=2).as_numpy()

    runner = make_superstep_runner(spec, CFG)
    state = init_stream_state(CFG, capacity=48)
    pad_q = np.full((32,), 48, np.int32)       # 48 = capacity = inert pad
    pad_s = np.zeros((32,), np.int32)
    pad_q[:20] = np.arange(20)
    pad_s[:20] = starts[:20]
    state = inject_queries(state, jnp.asarray(pad_q), jnp.asarray(pad_s),
                           jnp.zeros((32,), jnp.int32), 20)
    assert int(state.queue.tail) == 20
    state = _inject_fresh(state, starts[20:], qid0=20)
    assert int(state.queue.tail) == 48
    state = _drain_stream(runner, small_graph, state, seed=2, chunk=5)
    assert np.array_equal(p1, np.asarray(state.paths))
    assert np.array_equal(l1, np.asarray(state.lengths))


def test_legacy_inject_shim_warns_and_matches(small_graph, rng):
    """The pre-ring inject_queries(state, starts, n_valid) form survives
    as a deprecated shim with identical append-at-tail semantics."""
    spec = SPECS["uniform"]
    starts = rng.integers(0, small_graph.num_vertices, 40).astype(np.int32)
    p1, l1 = run_walks(small_graph, starts, spec, CFG, seed=6).as_numpy()
    runner = make_superstep_runner(spec, CFG)
    state = init_stream_state(CFG, capacity=40)
    with pytest.deprecated_call():
        state = inject_queries(state, jnp.asarray(starts), 40)
    assert int(state.queue.tail) == 40
    state = _drain_stream(runner, small_graph, state, seed=6, chunk=5)
    assert np.array_equal(p1, np.asarray(state.paths))
    assert np.array_equal(l1, np.asarray(state.lengths))


def test_staged_watermark_tracks_arrivals(small_graph):
    """Open system: the controller may stage only queries that actually
    arrived (staged <= tail), not the whole buffer capacity."""
    spec = SPECS["uniform"]
    runner = make_superstep_runner(spec, CFG)
    state = init_stream_state(CFG, capacity=512)
    state = _inject_fresh(state, np.zeros((16,), np.int32))
    state = runner(small_graph, state, 0, 3)
    assert int(state.queue.staged) <= int(state.queue.tail) == 16
    assert int(state.queue.head) <= int(state.queue.staged)


def test_service_two_waves(small_graph, rng):
    """Two request waves; every walk completes and each tenant harvests
    exactly its own queries."""
    cfg = dataclasses.replace(CFG, max_hops=8)
    svc = WalkService(small_graph, SPECS["uniform"], cfg,
                      capacity=512, chunk=4, seed=1)
    waves = []
    rids = []
    for _ in range(3):
        waves.append(rng.integers(0, small_graph.num_vertices, 16)
                     .astype(np.int32))
        rids.append(svc.submit(waves[-1]))
    svc.step()
    assert svc.num_inflight == 3
    for _ in range(2):
        waves.append(rng.integers(0, small_graph.num_vertices, 24)
                     .astype(np.int32))
        rids.append(svc.submit(waves[-1]))
    done = svc.drain()
    assert len(done) == 5 and svc.num_pending == svc.num_inflight == 0

    seen = set()
    for rid, starts in zip(rids, waves):
        r = svc.poll(rid)
        assert r is not None and r.done
        assert r.paths.shape == (len(starts), cfg.max_hops + 1)
        assert np.array_equal(r.paths[:, 0], starts)
        assert (r.lengths >= 1).all() and (r.lengths <= cfg.max_hops + 1).all()
        assert r.sojourn >= 1
        assert r.admission_wait >= 0
        assert r.sojourn >= r.admission_wait
        # (epoch, qid) identities are disjoint across tenants
        ids = {(int(e), int(q)) for e, q in zip(r.epochs, r.qids)}
        assert len(ids) == r.num_walks
        assert not (ids & seen)
        seen |= ids

    # harvested paths are real walks on the graph
    rp, col = np.asarray(small_graph.row_ptr), np.asarray(small_graph.col)
    r = svc.poll(rids[0])
    for q in range(r.num_walks):
        for t in range(r.lengths[q] - 1):
            u, v = r.paths[q, t], r.paths[q, t + 1]
            assert v in col[rp[u]:rp[u + 1]]


def test_service_ring_reclamation_bounded_buffer(small_graph, rng):
    """An unbounded request stream is served with a bounded device buffer
    via ring-buffer slot reclamation (no rotation, no drain barrier): all
    requests complete and recycled slots carry bumped epochs."""
    svc = WalkService(small_graph, SPECS["uniform"],
                      dataclasses.replace(CFG, max_hops=6),
                      capacity=64, chunk=4, seed=2)
    rids = [svc.submit(rng.integers(0, small_graph.num_vertices, 32))
            for _ in range(6)]
    done = svc.drain()
    assert len(done) == 6
    assert all(svc.poll(rid).done for rid in rids)
    assert int(svc.walk_stats().terminations) == 6 * 32
    # 6 x 32 = 192 walks through 64 slots: slots recycled at least twice
    assert max(int(r.epochs.max()) for r in done) >= 2
    # a recycled slot's occupants have strictly increasing epochs
    by_slot = {}
    for r in done:
        for e, q in zip(r.epochs, r.qids):
            by_slot.setdefault(int(q), []).append(int(e))
    assert any(len(v) > 1 for v in by_slot.values())
    for q, epochs in by_slot.items():
        assert len(set(epochs)) == len(epochs), f"slot {q} epoch reused"


def test_open_load_below_saturation_completes(small_graph):
    """Poisson arrivals at moderate utilization: everything completes and
    sojourn percentiles are finite."""
    svc = WalkService(small_graph, SPECS["uniform"],
                      dataclasses.replace(CFG, max_hops=8),
                      capacity=1024, chunk=4, seed=3)
    a = run_open_load(svc, OpenLoad(num_requests=20, request_size=8,
                                    utilization=0.5), seed=0)
    assert a.requests == 20
    assert a.walks == 20 * 8
    assert a.p50_sojourn <= a.p99_sojourn < float("inf")
    assert a.p50_admission_wait <= a.p99_admission_wait < float("inf")
    assert 0.0 <= a.bubble_ratio <= 1.0


# ------------------------------------------------------- streaming soak


def _soak_stream(stream, make_reference, graph, capacity, total, rng,
                 inject_wave=8, chunk=5):
    """Push ``total`` (> capacity) queries through a small slot ring,
    asserting the ring-economy invariants:

      * every (epoch, qid) identity is harvested exactly once,
      * each slot's occupant epochs strictly increase,
      * per epoch, harvested paths are bit-identical to a closed-batch
        ``Walker.run`` under ``rng.stream_key(seed, epoch)``.
    """
    pending = [rng.integers(0, graph.num_vertices, 1).astype(np.int32)[0]
               for _ in range(total)]
    harvested = {}          # (epoch, qid) -> (start, path, length)
    live = {}               # qid -> (epoch, start)
    max_epoch_seen = np.full((capacity,), -1, np.int64)
    while pending or live:
        n = min(inject_wave, stream.num_free, len(pending))
        if n:
            wave = np.asarray(pending[:n], np.int32)
            del pending[:n]
            qids, epochs = stream.inject(wave)
            for q, e, s in zip(qids, epochs, wave):
                assert int(e) > max_epoch_seen[q], "epoch must increase"
                max_epoch_seen[q] = int(e)
                live[int(q)] = (int(e), int(s))
        stream.advance(chunk)
        done = stream.done_live_mask()
        ready = [q for q in live if done[q]]
        if ready:
            paths, lengths = stream.harvest_ids(ready)
            for i, q in enumerate(ready):
                e, s = live.pop(q)
                key = (e, q)
                assert key not in harvested, f"{key} harvested twice"
                harvested[key] = (s, paths[i].copy(), int(lengths[i]))
            stream.release(ready)

    assert len(harvested) == total
    assert int(stream.walk_stats().drops) == 0

    # per-epoch closed-batch reference under the epoch-salted key
    by_epoch = {}
    for (e, q), rec in harvested.items():
        by_epoch.setdefault(e, {})[q] = rec
    assert len(by_epoch) >= 2, "soak must actually recycle slots"
    for e, rows in by_epoch.items():
        starts_e = np.zeros((capacity,), np.int32)
        for q, (s, _, _) in rows.items():
            starts_e[q] = s
        ref = make_reference(starts_e, task_rng.stream_key(stream.seed, e))
        ep, el = ref.as_numpy()
        for q, (_, path, length) in rows.items():
            assert np.array_equal(ep[q], path), (e, q)
            assert el[q] == length, (e, q)


@pytest.mark.parametrize("algo", sorted(SPECS))
def test_soak_single_device_ring(algo, small_graph, rng):
    """≥3× capacity queries through a 32-slot single-device ring."""
    program = walker.WalkProgram(spec=SPECS[algo], max_hops=8)
    w = walker.compile(program,
                       execution=walker.ExecutionConfig(num_slots=16))
    stream = w.stream(small_graph, capacity=32, seed=11)
    _soak_stream(stream, lambda s, k: w.run(small_graph, s, seed=k),
                 small_graph, capacity=32, total=100, rng=rng)


SHARDED_SOAK = r"""
import numpy as np
from repro import walker
from repro.graph import make_dataset, partition_graph
from tests_soak import soak

g = make_dataset("WG", scale_override=9)
pg = partition_graph(g, 2)
for algo in ("urw", "node2vec"):
    if algo == "urw":
        program = walker.WalkProgram.urw(8)
    else:
        program = walker.WalkProgram.node2vec(2.0, 0.5, 8)
    sharded = walker.compile(
        program, backend="sharded",
        execution=walker.ExecutionConfig(slots_per_device=8))
    single = walker.compile(
        program, execution=walker.ExecutionConfig(num_slots=16))
    stream = sharded.stream(pg, capacity=32, seed=11)
    soak(stream, lambda s, k: single.run(g, s, seed=k), g,
         capacity=32, total=100)
print("SHARDED_SOAK_OK")
"""


@pytest.mark.slow
def test_soak_sharded_ring_two_devices(tmp_path):
    """≥3× capacity queries through a 2-device sharded ring; per-epoch
    paths bit-identical to the single-device closed batch."""
    import inspect
    import os
    import textwrap

    # Ship the soak harness to the subprocess as a module so both soak
    # tests share one implementation.
    src = (
        "import numpy as np\n"
        "from repro.core import rng as task_rng\n"
        + textwrap.dedent(inspect.getsource(_soak_stream)).replace(
            "_soak_stream", "_soak_impl")
        + "\ndef soak(stream, ref, graph, capacity, total):\n"
        "    _soak_impl(stream, ref, graph, capacity, total,\n"
        "               np.random.default_rng(0))\n")
    (tmp_path / "tests_soak.py").write_text(src)
    out = run_in_subprocess(SHARDED_SOAK, devices=2,
                            extra_path=os.fspath(tmp_path))
    assert "SHARDED_SOAK_OK" in out
