"""Streaming (open-system) engine + WalkService: chunked/one-shot parity,
mid-stream injection, multi-tenant harvesting, generation rotation."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EngineConfig
from repro.core.samplers import SamplerSpec
from repro.core.walk_engine import (init_stream_state, inject_queries,
                                    make_superstep_runner, run_walks)
from repro.serve import OpenLoad, WalkService, run_open_load

CFG = EngineConfig(num_slots=64, max_hops=12)
SPECS = {
    "uniform": SamplerSpec(kind="uniform"),
    "node2vec": SamplerSpec(kind="rejection_n2v", p=2.0, q=0.5),
}


def _drain_stream(runner, graph, state, seed, chunk):
    for _ in range(10_000):
        if bool(np.asarray(state.done).all()):
            return state
        state = runner(graph, state, seed, chunk)
    raise AssertionError("stream did not drain")


@pytest.mark.parametrize("algo", sorted(SPECS))
def test_chunked_matches_oneshot(algo, small_graph, rng):
    """Parity: chunked run_supersteps == one-shot engine, bit-identical."""
    spec = SPECS[algo]
    starts = rng.integers(0, small_graph.num_vertices, 300).astype(np.int32)
    one = run_walks(small_graph, starts, spec, CFG, seed=3)
    p1, l1 = one.as_numpy()

    runner = make_superstep_runner(spec, CFG)
    state = init_stream_state(CFG, capacity=300)
    state = inject_queries(state, jnp.asarray(starts), 300)
    state = _drain_stream(runner, small_graph, state, seed=3, chunk=7)
    assert np.array_equal(p1, np.asarray(state.paths))
    assert np.array_equal(l1, np.asarray(state.lengths))
    assert int(state.stats.terminations) == 300


def test_midstream_injection_preserves_paths(small_graph, rng):
    """Queries injected while the engine is mid-flight sample the same
    paths as a single up-front batch (stateless tasks, §V-A)."""
    spec = SPECS["uniform"]
    starts = rng.integers(0, small_graph.num_vertices, 200).astype(np.int32)
    p1, l1 = run_walks(small_graph, starts, spec, CFG, seed=5).as_numpy()

    runner = make_superstep_runner(spec, CFG)
    state = init_stream_state(CFG, capacity=200)
    state = inject_queries(state, jnp.asarray(starts[:80]), 80)
    state = runner(small_graph, state, 5, 4)
    assert not bool(np.asarray(state.done).all())
    state = inject_queries(state, jnp.asarray(starts[80:]), 120)
    state = _drain_stream(runner, small_graph, state, seed=5, chunk=6)
    assert np.array_equal(p1, np.asarray(state.paths))
    assert np.array_equal(l1, np.asarray(state.lengths))


def test_inject_padding_is_inert(small_graph, rng):
    """Padded injection (fixed block shapes) must not create phantom
    queries: tail advances by n_valid only and padding is overwritten."""
    spec = SPECS["uniform"]
    starts = rng.integers(0, small_graph.num_vertices, 48).astype(np.int32)
    p1, l1 = run_walks(small_graph, starts, spec, CFG, seed=2).as_numpy()

    runner = make_superstep_runner(spec, CFG)
    state = init_stream_state(CFG, capacity=48)
    pad1 = np.zeros((32,), np.int32)
    pad1[:20] = starts[:20]
    state = inject_queries(state, jnp.asarray(pad1), 20)
    assert int(state.queue.tail) == 20
    pad2 = np.zeros((28,), np.int32)
    pad2[:28] = starts[20:]
    state = inject_queries(state, jnp.asarray(pad2), 28)
    assert int(state.queue.tail) == 48
    state = _drain_stream(runner, small_graph, state, seed=2, chunk=5)
    assert np.array_equal(p1, np.asarray(state.paths))
    assert np.array_equal(l1, np.asarray(state.lengths))


def test_staged_watermark_tracks_arrivals(small_graph):
    """Open system: the controller may stage only queries that actually
    arrived (staged <= tail), not the whole buffer capacity."""
    spec = SPECS["uniform"]
    runner = make_superstep_runner(spec, CFG)
    state = init_stream_state(CFG, capacity=512)
    state = inject_queries(state, jnp.zeros((16,), jnp.int32), 16)
    state = runner(small_graph, state, 0, 3)
    assert int(state.queue.staged) <= int(state.queue.tail) == 16
    assert int(state.queue.head) <= int(state.queue.staged)


def test_service_two_waves(small_graph, rng):
    """Two request waves; every walk completes and each tenant harvests
    exactly its own queries."""
    cfg = dataclasses.replace(CFG, max_hops=8)
    svc = WalkService(small_graph, SPECS["uniform"], cfg,
                      capacity=512, chunk=4, seed=1)
    waves = []
    rids = []
    for _ in range(3):
        waves.append(rng.integers(0, small_graph.num_vertices, 16)
                     .astype(np.int32))
        rids.append(svc.submit(waves[-1]))
    svc.step()
    assert svc.num_inflight == 3
    for _ in range(2):
        waves.append(rng.integers(0, small_graph.num_vertices, 24)
                     .astype(np.int32))
        rids.append(svc.submit(waves[-1]))
    done = svc.drain()
    assert len(done) == 5 and svc.num_pending == svc.num_inflight == 0

    ranges = []
    for rid, starts in zip(rids, waves):
        r = svc.poll(rid)
        assert r is not None and r.done
        assert r.paths.shape == (len(starts), cfg.max_hops + 1)
        assert np.array_equal(r.paths[:, 0], starts)
        assert (r.lengths >= 1).all() and (r.lengths <= cfg.max_hops + 1).all()
        assert r.sojourn >= 1
        ranges.append((r.generation, r.qid_lo, r.qid_hi))
    # per-generation qid ranges are disjoint (multi-tenant isolation)
    for i, (g1, lo1, hi1) in enumerate(ranges):
        for g2, lo2, hi2 in ranges[i + 1:]:
            assert g1 != g2 or hi1 <= lo2 or hi2 <= lo1

    # harvested paths are real walks on the graph
    rp, col = np.asarray(small_graph.row_ptr), np.asarray(small_graph.col)
    r = svc.poll(rids[0])
    for q in range(r.num_walks):
        for t in range(r.lengths[q] - 1):
            u, v = r.paths[q, t], r.paths[q, t + 1]
            assert v in col[rp[u]:rp[u + 1]]


def test_service_rotation_bounded_buffer(small_graph, rng):
    """An unbounded request stream is served with a bounded device buffer
    via generation rotation; all requests still complete."""
    svc = WalkService(small_graph, SPECS["uniform"],
                      dataclasses.replace(CFG, max_hops=6),
                      capacity=64, chunk=4, seed=2)
    rids = [svc.submit(rng.integers(0, small_graph.num_vertices, 32))
            for _ in range(6)]
    done = svc.drain()
    assert len(done) == 6
    assert svc.generation >= 2
    assert all(svc.poll(rid).done for rid in rids)
    assert int(svc.walk_stats().terminations) == 6 * 32


def test_open_load_below_saturation_completes(small_graph):
    """Poisson arrivals at moderate utilization: everything completes and
    sojourn percentiles are finite."""
    svc = WalkService(small_graph, SPECS["uniform"],
                      dataclasses.replace(CFG, max_hops=8),
                      capacity=1024, chunk=4, seed=3)
    a = run_open_load(svc, OpenLoad(num_requests=20, request_size=8,
                                    utilization=0.5), seed=0)
    assert a.requests == 20
    assert a.walks == 20 * 8
    assert a.p50_sojourn <= a.p99_sojourn < float("inf")
    assert 0.0 <= a.bubble_ratio <= 1.0
