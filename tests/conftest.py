import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_in_subprocess(code: str, devices: int = 8, timeout: int = 600):
    """Run a python snippet with N forced host devices (device count is
    locked at first jax init, so multi-device tests need a fresh process)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=timeout)
    if r.returncode != 0:
        raise AssertionError(f"subprocess failed:\n{r.stdout}\n{r.stderr}")
    return r.stdout


@pytest.fixture(scope="session")
def small_graph():
    from repro.graph import make_dataset
    return make_dataset("WG", scale_override=9)


@pytest.fixture(scope="session")
def weighted_graph():
    from repro.graph import make_dataset
    return make_dataset("WG", scale_override=9, weighted=True,
                        with_alias=True)


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
