import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def hypothesis_or_stubs():
    """``(given, settings, st)`` from hypothesis, or stand-ins that skip the
    property tests when hypothesis is missing — so bare (runtime-only)
    environments still collect and run every deterministic test."""
    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st
    except ImportError:
        def given(*_a, **_k):
            return lambda f: pytest.mark.skip(
                reason="hypothesis not installed")(f)

        def settings(*_a, **_k):
            return lambda f: f

        class _NoStrategies:
            def __getattr__(self, _name):
                return lambda *a, **k: None

        st = _NoStrategies()
    return given, settings, st


def run_in_subprocess(code: str, devices: int = 8, timeout: int = 600,
                      extra_path: str | None = None):
    """Run a python snippet with N forced host devices (device count is
    locked at first jax init, so multi-device tests need a fresh process).
    ``extra_path`` adds a directory to the subprocess PYTHONPATH (e.g. a
    tmp dir holding a generated helper module)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC if extra_path is None else os.pathsep.join(
        [SRC, extra_path])
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=timeout)
    if r.returncode != 0:
        raise AssertionError(f"subprocess failed:\n{r.stdout}\n{r.stderr}")
    return r.stdout


@pytest.fixture(scope="session")
def small_graph():
    from repro.graph import make_dataset
    return make_dataset("WG", scale_override=9)


@pytest.fixture(scope="session")
def weighted_graph():
    from repro.graph import make_dataset
    return make_dataset("WG", scale_override=9, weighted=True,
                        with_alias=True)


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
