"""Fused device-resident superstep (`step_impl="fused"`) + shared Threefry
RNG: bit-equality of the rng refactor against the jax.random derivation,
and bit-identity of the fused kernel against the jnp superstep over
{uniform, ppr, alias, rejection_n2v, metapath, reservoir_n2v} ×
{zero_bubble, static} × {closed batch, chunked stream} — every phase
program lowers to the kernel (the chunked E-S reservoir runs the in-kernel
chunk loop; there is no jnp fallback path).
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EngineConfig, rng as task_rng
from repro.core.samplers import SamplerSpec
from repro.core.walk_engine import (_run_walks, init_stream_state,
                                    inject_queries, make_superstep_runner)

CFG = EngineConfig(num_slots=32, max_hops=10)
SPECS = {
    "uniform": SamplerSpec(kind="uniform"),
    "ppr": SamplerSpec(kind="uniform", stop_prob=0.15),
    "alias": SamplerSpec(kind="alias"),
    "rejection_n2v": SamplerSpec(kind="rejection_n2v", p=2.0, q=0.5,
                                 rejection_rounds=6),
    "metapath": SamplerSpec(kind="metapath", metapath=(0, 1, 2)),
    # Small chunk so the in-kernel loop runs multiple (and partial) chunks
    # on the scale-9 fixture's degree range.
    "reservoir_n2v": SamplerSpec(kind="reservoir_n2v", p=2.0, q=0.5,
                                 reservoir_chunk=8),
}


@pytest.fixture(scope="module")
def fused_graph():
    """One graph with every payload the fused matrix samples from
    (weights + alias tables + edge types)."""
    from repro.graph import make_dataset
    return make_dataset("WG", scale_override=9, weighted=True,
                        with_alias=True, num_edge_types=3)


def _fused(cfg, hops_per_launch=4, **kw):
    return dataclasses.replace(cfg, step_impl="fused",
                               hops_per_launch=hops_per_launch, **kw)


def _assert_same_run(r1, r2):
    p1, l1 = r1.as_numpy()
    p2, l2 = r2.as_numpy()
    assert np.array_equal(p1, p2)
    assert np.array_equal(l1, l2)
    # launches is the one knob that differs by design (fusion factor).
    for f in r1.stats._fields:
        if f == "launches":
            continue
        assert int(getattr(r1.stats, f)) == int(getattr(r2.stats, f)), f


# ------------------------------------------------------------ shared RNG


def _jaxrandom_task_uniforms(base_key, qid, hop, num, salt=0, epoch=None):
    """The historical jax.random-based derivation, kept here verbatim as
    the reference the refactored `rng` module must match bit-for-bit."""
    salt_b = jnp.broadcast_to(jnp.asarray(salt, jnp.uint32),
                              qid.shape).astype(jnp.uint32)
    if epoch is None:
        def one(q, h, s):
            k = jax.random.fold_in(base_key, q)
            k = jax.random.fold_in(k, h)
            return jax.random.fold_in(k, s)

        keys = jax.vmap(one)(qid.astype(jnp.uint32), hop.astype(jnp.uint32),
                             salt_b)
    else:
        ep = jnp.broadcast_to(jnp.asarray(epoch, jnp.int32), qid.shape)

        def one(q, h, s, e):
            salted = jax.random.fold_in(base_key, e.astype(jnp.uint32))
            kb = jnp.where(e > 0, salted, base_key)
            k = jax.random.fold_in(kb, q)
            k = jax.random.fold_in(k, h)
            return jax.random.fold_in(k, s)

        keys = jax.vmap(one)(qid.astype(jnp.uint32), hop.astype(jnp.uint32),
                             salt_b, ep)
    return jax.vmap(lambda k: jax.random.uniform(k, (num,)))(keys)


@pytest.mark.parametrize("epoch_kind", ["none", "zero", "mixed"])
@pytest.mark.parametrize("salt", [0, 2, 8, 17])
def test_rng_bit_equal_to_jax_random(epoch_kind, salt, rng):
    """`rng.threefry2x32`-based task_uniforms == the jax.random fold chain,
    across epochs, salts, and odd/even draw counts."""
    key = jax.random.PRNGKey(123)
    qid = jnp.asarray(rng.integers(0, 5000, 64), jnp.int32)
    hop = jnp.asarray(rng.integers(0, 80, 64), jnp.int32)
    epoch = {"none": None, "zero": 0,
             "mixed": jnp.asarray(rng.integers(0, 9, 64), jnp.int32)}[
        epoch_kind]
    for num in (1, 2, 5, 24):
        ref = _jaxrandom_task_uniforms(key, qid, hop, num, salt, epoch)
        got = task_rng.task_uniforms(key, qid, hop, num, salt, epoch)
        assert np.array_equal(np.asarray(ref), np.asarray(got)), num


def test_threefry_primitive_bit_equal():
    """The shared block cipher itself matches jax.random.bits."""
    key = jax.random.PRNGKey(7)
    folded = jax.random.fold_in(key, 42)
    y0, y1 = task_rng.threefry2x32(key[0], key[1], jnp.uint32(0),
                                   jnp.uint32(42))
    assert np.array_equal(np.asarray(folded), np.asarray([y0, y1]))
    for num in (1, 2, 3, 8, 9):
        ref = jax.random.bits(folded, (num,), jnp.uint32)
        got = task_rng.key_bits(folded[0], folded[1], num)
        assert np.array_equal(np.asarray(ref), np.asarray(got).reshape(-1))


def test_epoch_zero_matches_legacy_tuple(rng):
    """Epoch 0 must keep deriving exactly like the 3-tuple (the contract
    that makes a closed batch epoch 0 of a stream)."""
    key = jax.random.PRNGKey(5)
    qid = jnp.asarray(rng.integers(0, 999, 32), jnp.int32)
    hop = jnp.asarray(rng.integers(0, 30, 32), jnp.int32)
    a = task_rng.task_uniforms(key, qid, hop, 3, 1, epoch=None)
    b = task_rng.task_uniforms(key, qid, hop, 3, 1,
                               epoch=jnp.zeros((32,), jnp.int32))
    assert np.array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------- fused vs jnp, closed


@pytest.mark.parametrize("algo", sorted(SPECS))
@pytest.mark.parametrize("mode", ["zero_bubble", "static"])
def test_fused_closed_batch_bit_identical(algo, mode, fused_graph, rng):
    """Closed batch: fused kernel == jnp superstep — paths, lengths, and
    every stat except the launch count — for every covered sampler
    (rejection Node2Vec's in-kernel verify and MetaPath's typed gather
    included)."""
    spec = SPECS[algo]
    cfg = dataclasses.replace(CFG, mode=mode)
    starts = rng.integers(0, fused_graph.num_vertices, 80).astype(np.int32)
    r_jnp = _run_walks(fused_graph, starts, spec, cfg, seed=9)
    r_fused = _run_walks(fused_graph, starts, spec, _fused(cfg), seed=9)
    _assert_same_run(r_jnp, r_fused)
    assert int(r_fused.stats.launches) < int(r_fused.stats.supersteps)
    assert int(r_jnp.stats.launches) == int(r_jnp.stats.supersteps)


def test_fused_hops_per_launch_invariance(small_graph, rng):
    """The launch cadence is a pure machine knob: any hops_per_launch
    samples identical paths, and the launch count shrinks as k grows."""
    starts = rng.integers(0, small_graph.num_vertices, 60).astype(np.int32)
    spec = SPECS["ppr"]
    ref = _run_walks(small_graph, starts, spec, CFG, seed=4)
    launches = []
    for k in (1, 3, 16):
        r = _run_walks(small_graph, starts, spec, _fused(CFG, k), seed=4)
        _assert_same_run(ref, r)
        launches.append(int(r.stats.launches))
    assert launches[0] > launches[1] > launches[2] >= 1
    # supersteps-per-launch is surfaced in the stats
    assert float(ref.stats.supersteps_per_launch()) == pytest.approx(1.0)
    assert float(r.stats.supersteps_per_launch()) > 1.0


def test_fused_injection_delay_and_depth(small_graph, rng):
    """The Theorem VI.1 staging controller runs in-kernel: delayed head
    observations behave identically to the jnp superstep."""
    starts = rng.integers(0, small_graph.num_vertices, 100).astype(np.int32)
    for C in (1, 3):
        cfg = dataclasses.replace(CFG, injection_delay=C)
        r1 = _run_walks(small_graph, starts, SPECS["uniform"], cfg, seed=2)
        r2 = _run_walks(small_graph, starts, SPECS["uniform"], _fused(cfg),
                        seed=2)
        _assert_same_run(r1, r2)


def test_fused_no_record_paths(small_graph, rng):
    """record_paths=False (throughput mode): stats still match."""
    starts = rng.integers(0, small_graph.num_vertices, 64).astype(np.int32)
    cfg = dataclasses.replace(CFG, record_paths=False)
    r1 = _run_walks(small_graph, starts, SPECS["ppr"], cfg, seed=6)
    r2 = _run_walks(small_graph, starts, SPECS["ppr"], _fused(cfg), seed=6)
    for f in r1.stats._fields:
        if f != "launches":
            assert int(getattr(r1.stats, f)) == int(getattr(r2.stats, f)), f


def test_fused_reservoir_in_kernel_no_fallback(weighted_graph, rng):
    """Weighted Node2Vec (the chunked E-S reservoir) runs in-kernel: the
    full Walker path emits no fallback warning and matches the jnp
    superstep bit-for-bit — the matrix row the kernel closed last."""
    from repro import walker

    program = walker.WalkProgram.node2vec(2.0, 0.5, 6, weighted=True)
    assert program.spec.kind == "reservoir_n2v"
    starts = rng.integers(0, weighted_graph.num_vertices, 24).astype(np.int32)
    ref = walker.compile(program, execution=walker.ExecutionConfig(
        num_slots=16)).run(weighted_graph, starts, seed=0)
    ex = walker.ExecutionConfig(num_slots=16, step_impl="fused",
                                hops_per_launch=4)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        got = walker.compile(program, execution=ex).run(weighted_graph,
                                                        starts, seed=0)
    assert not [c for c in caught if issubclass(c.category, RuntimeWarning)
                and "falling back" in str(c.message)]
    _assert_same_run(ref, got)


def test_fused_reservoir_adaptive_vs_fixed_chunks(weighted_graph, rng):
    """Degree-adaptive trip bounding is a pure machine knob: adaptive and
    fixed chunk counts sample identical paths, on both engines (chunks
    past a lane's degree contribute only -inf reservoir keys)."""
    starts = rng.integers(0, weighted_graph.num_vertices, 40).astype(np.int32)
    runs = []
    for adaptive in (True, False):
        spec = SamplerSpec(kind="reservoir_n2v", p=2.0, q=0.5,
                           reservoir_chunk=8, adaptive_chunks=adaptive)
        runs.append(_run_walks(weighted_graph, starts, spec, CFG, seed=3))
        runs.append(_run_walks(weighted_graph, starts, spec, _fused(CFG),
                               seed=3))
    for r in runs[1:]:
        _assert_same_run(runs[0], r)


def test_fused_reservoir_partial_final_chunks(rng):
    """Skewed degrees exercise the chunk loop's ragged tail: a hub whose
    degree is not a multiple of reservoir_chunk (partial final chunk,
    clamped fixed-length DMA) next to degree-2 ring vertices (single
    partial chunk), with p/q biases live via the ring back-edges."""
    from repro.graph import build_csr

    n = 48
    edges = []
    for v in range(1, n):          # star: hub 0 <-> every spoke
        edges += [(0, v), (v, 0)]
    for v in range(1, n):          # ring over the spokes
        w = v % (n - 1) + 1
        edges += [(v, w), (w, v)]
    g = build_csr(np.asarray(edges, np.int64), n,
                  weights=rng.random(len(edges)).astype(np.float32) + 1e-3)
    spec = SamplerSpec(kind="reservoir_n2v", p=4.0, q=0.25,
                       reservoir_chunk=16)
    deg0 = int(g.row_ptr[1] - g.row_ptr[0])
    assert deg0 % spec.reservoir_chunk != 0 and deg0 > spec.reservoir_chunk
    starts = rng.integers(0, n, 40).astype(np.int32)
    cfg = dataclasses.replace(CFG, num_slots=16, max_hops=6)
    ref = _run_walks(g, starts, spec, cfg, seed=12)
    got = _run_walks(g, starts, spec, _fused(cfg), seed=12)
    _assert_same_run(ref, got)


# ------------------------------------------------- fused vs jnp, stream


def _stream_drain(runner, graph, state, seed, chunk):
    for _ in range(10_000):
        if bool(np.asarray(state.done).all()):
            return state
        state = runner(graph, state, seed, chunk)
    raise AssertionError("stream did not drain")


@pytest.mark.parametrize("algo", sorted(SPECS))
def test_fused_chunked_stream_bit_identical(algo, fused_graph, rng):
    """Open system: mid-stream injection + odd chunk sizes, fused vs jnp —
    identical paths/lengths/done and identical stream stats, for every
    covered sampler."""
    spec = SPECS[algo]
    starts = rng.integers(0, fused_graph.num_vertices, 90).astype(np.int32)
    cfg = dataclasses.replace(CFG, num_slots=16)

    def run(c):
        runner = make_superstep_runner(spec, c)
        st = init_stream_state(c, capacity=90)
        st = inject_queries(st, jnp.arange(50, dtype=jnp.int32),
                            jnp.asarray(starts[:50]),
                            jnp.zeros((50,), jnp.int32), 50)
        st = runner(fused_graph, st, 8, 5)   # mid-flight...
        st = inject_queries(st, jnp.arange(50, 90, dtype=jnp.int32),
                            jnp.asarray(starts[50:]),
                            jnp.zeros((40,), jnp.int32), 40)
        return _stream_drain(runner, fused_graph, st, 8, 7)

    s1 = run(cfg)
    s2 = run(_fused(cfg, hops_per_launch=3))
    assert np.array_equal(np.asarray(s1.paths), np.asarray(s2.paths))
    assert np.array_equal(np.asarray(s1.lengths), np.asarray(s2.lengths))
    assert np.array_equal(np.asarray(s1.done), np.asarray(s2.done))
    for f in s1.stats._fields:
        if f != "launches":
            assert int(getattr(s1.stats, f)) == int(getattr(s2.stats, f)), f


def test_fused_ring_reclamation_stream(small_graph, rng):
    """The ring economy (epoch-salted slot reuse) runs unchanged over the
    fused runner: Walker.stream with step_impl='fused' harvests the same
    walks as the jnp stream under identical inject/release schedules."""
    from repro import walker

    program = walker.WalkProgram(spec=SPECS["ppr"], max_hops=8)
    arrivals = rng.integers(0, small_graph.num_vertices, 60).astype(np.int32)

    def soak(execution):
        w = walker.compile(program, execution=execution)
        stream = w.stream(small_graph, capacity=24, seed=11)
        pending = arrivals.tolist()
        out = {}
        live = {}
        while pending or live:
            n = min(8, stream.num_free, len(pending))
            if n:
                wave = np.asarray(pending[:n], np.int32)
                del pending[:n]
                qids, epochs = stream.inject(wave)
                for q, e in zip(qids, epochs):
                    live[int(q)] = int(e)
            stream.advance(5)
            done = stream.done_live_mask()
            ready = [q for q in live if done[q]]
            if ready:
                paths, lengths = stream.harvest_ids(ready)
                for i, q in enumerate(ready):
                    out[(live.pop(q), q)] = (paths[i].copy(), int(lengths[i]))
                stream.release(ready)
        return out

    ex = walker.ExecutionConfig(num_slots=8)
    a = soak(ex)
    b = soak(dataclasses.replace(ex, step_impl="fused", hops_per_launch=4))
    assert a.keys() == b.keys()
    for k in a:
        assert np.array_equal(a[k][0], b[k][0]), k
        assert a[k][1] == b[k][1], k


# ------------------------------------------- gather hierarchy (hot cache)

# Stats that legitimately differ cache-on vs cache-off: the launch
# cadence (as everywhere) and the cache's own counters.  Everything the
# engine counted before the hierarchy existed must stay bit-identical.
_CACHE_ONLY = ("launches", "cache_hits", "cache_misses", "cache_coalesced")
_CACHE_BUDGET = 1 << 13


def _assert_same_walks_mod_cache(r_off, r_on):
    p1, l1 = r_off.as_numpy()
    p2, l2 = r_on.as_numpy()
    assert np.array_equal(p1, p2)
    assert np.array_equal(l1, l2)
    for f in r_off.stats._fields:
        if f in _CACHE_ONLY:
            continue
        assert int(getattr(r_off.stats, f)) == int(getattr(r_on.stats, f)), f


@pytest.mark.parametrize("algo", sorted(SPECS))
@pytest.mark.parametrize("mode", ["zero_bubble", "static"])
def test_cached_fused_closed_bit_identical(algo, mode, fused_graph, rng):
    """Closed batch: the VMEM hot-vertex cache is invisible in every
    sampled walk and every pre-existing stat — hits read the same bytes
    from a different tier — while the new counters show it actually
    served traffic (nonzero hits on the skewed fixture, all-zero when
    the cache is off)."""
    spec = SPECS[algo]
    cfg = dataclasses.replace(CFG, mode=mode)
    starts = rng.integers(0, fused_graph.num_vertices, 80).astype(np.int32)
    r_off = _run_walks(fused_graph, starts, spec, _fused(cfg), seed=9)
    r_on = _run_walks(fused_graph, starts, spec,
                      _fused(cfg, cache_budget=_CACHE_BUDGET), seed=9)
    _assert_same_walks_mod_cache(r_off, r_on)
    assert int(r_on.stats.cache_hits) > 0
    assert 0.0 < float(r_on.stats.cache_hit_rate()) <= 1.0
    for f in ("cache_hits", "cache_misses", "cache_coalesced"):
        assert int(getattr(r_off.stats, f)) == 0, f


@pytest.mark.parametrize("algo", sorted(SPECS))
def test_cached_fused_stream_bit_identical(algo, fused_graph, rng):
    """Open system: mid-stream injection over the cached runner drains to
    the same paths/lengths/done as the uncached one."""
    from repro.core.walk_engine import maybe_build_cache

    spec = SPECS[algo]
    starts = rng.integers(0, fused_graph.num_vertices, 90).astype(np.int32)
    cfg = _fused(dataclasses.replace(CFG, num_slots=16), hops_per_launch=3)

    def run(budget):
        c = dataclasses.replace(cfg, cache_budget=budget)
        runner = make_superstep_runner(
            spec, c, cache=maybe_build_cache(spec, c, fused_graph))
        st = init_stream_state(c, capacity=90)
        st = inject_queries(st, jnp.arange(50, dtype=jnp.int32),
                            jnp.asarray(starts[:50]),
                            jnp.zeros((50,), jnp.int32), 50)
        st = runner(fused_graph, st, 8, 5)   # mid-flight...
        st = inject_queries(st, jnp.arange(50, 90, dtype=jnp.int32),
                            jnp.asarray(starts[50:]),
                            jnp.zeros((40,), jnp.int32), 40)
        return _stream_drain(runner, fused_graph, st, 8, 7)

    s_off = run(0)
    s_on = run(_CACHE_BUDGET)
    assert np.array_equal(np.asarray(s_off.paths), np.asarray(s_on.paths))
    assert np.array_equal(np.asarray(s_off.lengths),
                          np.asarray(s_on.lengths))
    assert np.array_equal(np.asarray(s_off.done), np.asarray(s_on.done))
    for f in s_off.stats._fields:
        if f not in _CACHE_ONLY:
            assert int(getattr(s_off.stats, f)) == int(
                getattr(s_on.stats, f)), f
    assert int(s_on.stats.cache_hits) > 0


def test_cached_fused_static_stream_spot_check(fused_graph, rng):
    """The stream × static-mode corner of the matrix (one kind)."""
    spec = SPECS["uniform"]
    from repro.core.walk_engine import maybe_build_cache

    cfg = _fused(dataclasses.replace(CFG, num_slots=16, mode="static"))
    starts = rng.integers(0, fused_graph.num_vertices, 48).astype(np.int32)

    def run(budget):
        c = dataclasses.replace(cfg, cache_budget=budget)
        runner = make_superstep_runner(
            spec, c, cache=maybe_build_cache(spec, c, fused_graph))
        st = init_stream_state(c, capacity=48)
        st = inject_queries(st, jnp.arange(48, dtype=jnp.int32),
                            jnp.asarray(starts), jnp.zeros((48,), jnp.int32),
                            48)
        return _stream_drain(runner, fused_graph, st, 8, 5)

    s_off, s_on = run(0), run(_CACHE_BUDGET)
    assert np.array_equal(np.asarray(s_off.paths), np.asarray(s_on.paths))
    assert int(s_on.stats.cache_hits) > 0


def test_cache_budget_knob_threads_through_walker(fused_graph, rng):
    """The public Walker path builds and memoizes the cache: same walks
    as cache-off, nonzero hit rate in the returned stats."""
    from repro import walker

    program = walker.WalkProgram(spec=SPECS["uniform"], max_hops=10)
    starts = rng.integers(0, fused_graph.num_vertices, 64).astype(np.int32)
    ref = walker.compile(program, execution=walker.ExecutionConfig(
        num_slots=32, step_impl="fused", hops_per_launch=4)).run(
            fused_graph, starts, seed=5)
    w = walker.compile(program, execution=walker.ExecutionConfig(
        num_slots=32, step_impl="fused", hops_per_launch=4,
        cache_budget=_CACHE_BUDGET))
    got = w.run(fused_graph, starts, seed=5)
    _assert_same_walks_mod_cache(ref, got)
    assert float(got.stats.cache_hit_rate()) > 0.0
    # Same graph object: the engine (and its cache) is memoized.
    assert len(w._engines) == 1
    w.run(fused_graph, starts, seed=5)
    assert len(w._engines) == 1
