"""Cross-cutting property tests (hypothesis) on system invariants."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import EngineConfig
from repro.core.samplers import SamplerSpec
from repro.core.walk_engine import run_walks
from repro.graph import build_alias_tables, build_csr
from repro.graph.generators import GRAPH500, rmat_edges
from repro.models.attention_chunked import chunked_attention, full_attention_ref

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
given, settings = hypothesis.given, hypothesis.settings

pytestmark = pytest.mark.slow  # each property runs many engine compiles


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), ef=st.integers(2, 8),
       max_hops=st.integers(1, 12))
def test_walks_always_follow_edges(seed, ef, max_hops):
    """∀ RMAT graph, seed, length: every recorded transition is a real
    edge; every query terminates exactly once; lengths ≤ max_hops+1."""
    edges, n = rmat_edges(8, ef, GRAPH500, seed=seed)
    g = build_csr(edges, n)
    starts = np.random.default_rng(seed).integers(0, n, 100)
    res = run_walks(g, starts, SamplerSpec(kind="uniform"),
                    EngineConfig(num_slots=32, max_hops=max_hops), seed=seed)
    p, l = res.as_numpy()
    assert int(res.stats.terminations) == 100
    assert (l >= 1).all() and (l <= max_hops + 1).all()
    rp, col = np.asarray(g.row_ptr), np.asarray(g.col)
    for q in range(100):
        for t in range(l[q] - 1):
            u, v = p[q, t], p[q, t + 1]
            assert v in col[rp[u]:rp[u + 1]]


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_alias_tables_mass_conservation(seed):
    """∀ weights: alias table column masses equal d·w_i/Σw (exact Vose
    invariant)."""
    r = np.random.default_rng(seed)
    d = int(r.integers(2, 20))
    w = r.random(d).astype(np.float32) + 1e-3
    edges = np.array([[0, i + 1] for i in range(d)])
    g = build_alias_tables(build_csr(edges, d + 1, weights=w))
    prob = np.asarray(g.alias_prob)[:d]
    alias = np.asarray(g.alias_idx)[:d]
    mass = prob.copy()
    for k in range(d):
        mass[alias[k]] += 1.0 - prob[k]
    np.testing.assert_allclose(mass, d * w / w.sum(), rtol=2e-3)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       s_pow=st.integers(3, 5), qb_pow=st.integers(2, 4),
       hq=st.sampled_from([2, 4, 8]), causal=st.booleans())
def test_chunked_attention_equals_full(seed, s_pow, qb_pow, hq, causal):
    """∀ shapes/blocks: online-softmax chunked attention ≡ materialized
    softmax attention."""
    S, qb = 1 << s_pow, 1 << qb_pow
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    hkv = hq // 2 if hq > 2 else hq
    q = jax.random.normal(ks[0], (2, S, hq, 8))
    k = jax.random.normal(ks[1], (2, S, hkv, 8))
    v = jax.random.normal(ks[2], (2, S, hkv, 8))
    o = chunked_attention(q, k, v, causal=causal, q_block=min(qb, S),
                          kv_block=min(qb, S))
    r = full_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000), slots=st.sampled_from([16, 64, 256]))
def test_paths_independent_of_engine_configuration(seed, slots):
    """∀ lane count / scheduling mode / step impl: identical walks (the
    Markov stateless-decomposition invariant, §V-A)."""
    edges, n = rmat_edges(8, 4, GRAPH500, seed=seed)
    g = build_csr(edges, n)
    starts = np.random.default_rng(seed).integers(0, n, 80)
    spec = SamplerSpec(kind="uniform")
    base = EngineConfig(num_slots=slots, max_hops=8)
    ref = run_walks(g, starts, spec, EngineConfig(num_slots=128, max_hops=8),
                    seed=seed).as_numpy()
    for cfg in (base, dataclasses.replace(base, mode="static"),
                dataclasses.replace(base, step_impl="pallas")):
        got = run_walks(g, starts, spec, cfg, seed=seed).as_numpy()
        assert np.array_equal(got[0], ref[0])
        assert np.array_equal(got[1], ref[1])
