"""Hot-vertex cache builder: coverage, determinism, and the verbatim-
payload contract the cached fused superstep's bit-identity rests on."""
import numpy as np
import pytest

from conftest import hypothesis_or_stubs

from repro.graph import build_alias_tables, build_csr
from repro.graph.generators import GRAPH500, rmat_edges
from repro.graph.hot_cache import (build_hot_cache, edge_payload_bytes,
                                   vertex_overhead_bytes)

given, settings, st = hypothesis_or_stubs()


def _graph(seed, scale=7, ef=4, weighted=False):
    edges, n = rmat_edges(scale, ef, GRAPH500, seed=seed)
    r = np.random.default_rng(seed)
    w = (r.random(edges.shape[0]).astype(np.float32) + 1e-3
         if weighted else None)
    g = build_csr(edges, n, weights=w)
    return build_alias_tables(g) if weighted else g


def _expected_top(graph, payloads, budget):
    """Reference admission: descending degree, smaller id wins ties,
    greedy prefix under the byte budget."""
    deg = np.diff(np.asarray(graph.row_ptr)).astype(np.int64)
    order = sorted(range(deg.size), key=lambda v: (-deg[v], v))
    per_edge = edge_payload_bytes(payloads)
    per_vert = vertex_overhead_bytes(payloads, graph.num_edge_types or 0)
    chosen, spent = [], 0
    for v in order:
        c = per_vert + per_edge * int(deg[v])
        if spent + c > budget:
            break
        chosen.append(v)
        spent += c
    return sorted(chosen)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), budget=st.integers(64, 1 << 14))
def test_cache_covers_top_h_by_degree(seed, budget):
    """∀ graph, budget: the cache holds exactly the greedy degree-
    descending prefix (deterministic smaller-id tie-break) that fits."""
    g = _graph(seed)
    cache = build_hot_cache(g, ("col",), budget)
    expect = _expected_top(g, ("col",), budget)
    if cache is None:
        assert expect == []
        return
    assert cache.hot_ids.tolist() == expect
    deg = np.diff(np.asarray(g.row_ptr))
    np.testing.assert_array_equal(cache.hot_deg, deg[cache.hot_ids])
    # Determinism: same inputs, same block.
    again = build_hot_cache(g, ("col",), budget)
    np.testing.assert_array_equal(cache.hot_ids, again.hot_ids)
    np.testing.assert_array_equal(cache.col, again.col)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), budget=st.integers(256, 1 << 13))
def test_cache_hits_are_byte_identical(seed, budget):
    """∀ hot vertex: every packed payload row equals the graph's own CSR
    slice — the whole bit-identity argument of the cached kernel."""
    g = _graph(seed, weighted=True)
    payloads = ("col", "weights", "alias_prob", "alias_idx")
    cache = build_hot_cache(g, payloads, budget)
    if cache is None:
        pytest.skip("budget admits no vertex")
    rp = np.asarray(g.row_ptr)
    for s, v in enumerate(cache.hot_ids):
        lo, hi = int(cache.hot_off[s]), int(cache.hot_off[s + 1])
        glo, ghi = int(rp[v]), int(rp[v + 1])
        np.testing.assert_array_equal(cache.col[lo:hi],
                                      np.asarray(g.col)[glo:ghi])
        np.testing.assert_array_equal(cache.weights[lo:hi],
                                      np.asarray(g.weights)[glo:ghi])
        np.testing.assert_array_equal(cache.alias_prob[lo:hi],
                                      np.asarray(g.alias_prob)[glo:ghi])
        np.testing.assert_array_equal(cache.alias_idx[lo:hi],
                                      np.asarray(g.alias_idx)[glo:ghi])
        assert cache.slot_of(int(v)) == s


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), budget=st.integers(64, 1 << 12))
def test_cache_misses_fall_through(seed, budget):
    """∀ vertex outside the hot set: the lookup misses (slot -1), so the
    kernel's miss path — the unmodified HBM gather — serves it."""
    g = _graph(seed)
    cache = build_hot_cache(g, ("col",), budget)
    if cache is None:
        pytest.skip("budget admits no vertex")
    hot = set(int(v) for v in cache.hot_ids)
    outside = [v for v in range(int(g.num_vertices)) if v not in hot]
    for v in outside[:64]:
        assert cache.slot_of(v) == -1
    # Probe beyond the id range misses too (clamped binary search).
    assert cache.slot_of(int(g.num_vertices) + 7) == -1


def test_zero_or_negative_budget_disables():
    g = _graph(3)
    assert build_hot_cache(g, ("col",), 0) is None
    assert build_hot_cache(g, ("col",), -100) is None


def test_probe_trips_covers_directory():
    g = _graph(5)
    cache = build_hot_cache(g, ("col",), 1 << 13)
    assert cache is not None
    assert 2 ** cache.probe_trips >= cache.num_hot + 1
