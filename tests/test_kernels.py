"""Per-kernel validation: shape/dtype sweeps + hypothesis property tests
against the pure-jnp oracles (interpret=True executes kernel bodies on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import hypothesis_or_stubs
from repro.graph import make_dataset
from repro.kernels.embedding_bag import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.segment_sum import segment_sum
from repro.kernels.segment_sum.ref import segment_sum_ref
from repro.kernels.walk_step import ops as ws_ops, ref as ws_ref

given, settings, st = hypothesis_or_stubs()


@pytest.fixture(scope="module")
def graph():
    return make_dataset("WG", scale_override=9, weighted=True,
                        with_alias=True)


@pytest.mark.parametrize("W,tile", [(5, 64), (64, 16), (200, 64), (256, 256)])
def test_walk_step_uniform_sweep(graph, W, tile, rng):
    v = jnp.asarray(rng.integers(0, graph.num_vertices, W), jnp.int32)
    u = jnp.asarray(rng.random(W), jnp.float32)
    vn, dg = ws_ops.walk_step_uniform(v, u, graph.row_ptr, graph.col,
                                      tile=tile)
    vr, dr = ws_ref.walk_step_uniform_ref(v, u, graph.row_ptr, graph.col)
    np.testing.assert_array_equal(np.asarray(vn), np.asarray(vr))
    np.testing.assert_array_equal(np.asarray(dg), np.asarray(dr))


@pytest.mark.parametrize("W,tile", [(7, 32), (128, 64)])
def test_walk_step_alias_sweep(graph, W, tile, rng):
    v = jnp.asarray(rng.integers(0, graph.num_vertices, W), jnp.int32)
    u1 = jnp.asarray(rng.random(W), jnp.float32)
    u2 = jnp.asarray(rng.random(W), jnp.float32)
    args = (v, u1, u2, graph.row_ptr, graph.col, graph.alias_prob,
            graph.alias_idx)
    vn, dg = ws_ops.walk_step_alias(*args, tile=tile)
    vr, dr = ws_ref.walk_step_alias_ref(*args)
    np.testing.assert_array_equal(np.asarray(vn), np.asarray(vr))
    np.testing.assert_array_equal(np.asarray(dg), np.asarray(dr))


def test_walk_step_dangling_vertices(graph):
    """deg==0 lanes must report v_next = -1 (termination sentinel)."""
    deg = np.diff(np.asarray(graph.row_ptr))
    dang = np.where(deg == 0)[0]
    assert dang.size > 0
    v = jnp.asarray(dang[:32], jnp.int32)
    u = jnp.zeros((v.shape[0],), jnp.float32)
    vn, dg = ws_ops.walk_step_uniform(v, u, graph.row_ptr, graph.col, tile=32)
    assert (np.asarray(vn) == -1).all()
    assert (np.asarray(dg) == 0).all()


@pytest.mark.parametrize("E,V,D,te,rb,dtype", [
    (64, 16, 8, 16, 8, jnp.float32),
    (1000, 177, 16, 128, 64, jnp.float32),
    (333, 64, 4, 32, 16, jnp.float32),
    (256, 32, 8, 64, 32, jnp.bfloat16),
])
def test_segment_sum_sweep(E, V, D, te, rb, dtype, rng):
    seg = np.sort(rng.integers(0, V, E)).astype(np.int32)
    dat = jnp.asarray(rng.random((E, D)), dtype)
    out = segment_sum(dat, seg, V, tile_e=te, row_block=rb)
    ref = segment_sum_ref(dat, jnp.asarray(seg), V)
    atol = 1e-4 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=atol)


def test_segment_sum_empty_segments(rng):
    """Rows with no incident edges must be exactly zero."""
    seg = np.sort(rng.choice(np.arange(0, 50, 5), 40)).astype(np.int32)
    dat = jnp.asarray(rng.random((40, 4)), jnp.float32)
    out = np.asarray(segment_sum(dat, seg, 50, tile_e=16, row_block=8))
    empty = np.setdiff1d(np.arange(50), seg)
    assert (out[empty] == 0).all()


@settings(max_examples=25, deadline=None)
@given(E=st.integers(1, 300), V=st.integers(1, 80), D=st.integers(1, 9),
       seed=st.integers(0, 2**31 - 1))
def test_segment_sum_property(E, V, D, seed):
    r = np.random.default_rng(seed)
    seg = np.sort(r.integers(0, V, E)).astype(np.int32)
    dat = jnp.asarray(r.standard_normal((E, D)), jnp.float32)
    out = segment_sum(dat, seg, V, tile_e=32, row_block=16)
    ref = segment_sum_ref(dat, jnp.asarray(seg), V)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4)


@pytest.mark.parametrize("B,H,R,D,tb", [
    (8, 3, 40, 8, 8), (100, 1, 500, 16, 32), (33, 6, 64, 4, 16),
])
def test_embedding_bag_sweep(B, H, R, D, tb, rng):
    idx = jnp.asarray(rng.integers(-1, R, (B, H)), jnp.int32)
    w = jnp.asarray(rng.random((B, H)), jnp.float32)
    tbl = jnp.asarray(rng.random((R, D)), jnp.float32)
    out = embedding_bag(idx, tbl, w, tile_b=tb)
    ref = embedding_bag_ref(idx, w, tbl)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(B=st.integers(1, 60), H=st.integers(1, 8), R=st.integers(2, 100),
       D=st.integers(1, 16), seed=st.integers(0, 2**31 - 1))
def test_embedding_bag_property(B, H, R, D, seed):
    r = np.random.default_rng(seed)
    idx = jnp.asarray(r.integers(-1, R, (B, H)), jnp.int32)
    w = jnp.asarray(r.random((B, H)), jnp.float32)
    tbl = jnp.asarray(r.standard_normal((R, D)), jnp.float32)
    out = embedding_bag(idx, tbl, w, tile_b=16)
    ref = embedding_bag_ref(idx, w, tbl)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_embedding_bag_all_padding():
    idx = jnp.full((4, 3), -1, jnp.int32)
    tbl = jnp.ones((10, 8), jnp.float32)
    out = embedding_bag(idx, tbl, tile_b=4)
    assert (np.asarray(out) == 0).all()
