"""Sampler correctness: distributions, adjacency tests, 2nd-order bias."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.phase_program import make_sampler
from repro.core.samplers import SamplerSpec, edge_exists
from repro.core.tasks import WalkerSlots
from repro.graph import build_alias_tables, build_csr


def _slots(v_curr, v_prev=None, n=None):
    n = n or len(v_curr)
    return WalkerSlots(
        v_curr=jnp.asarray(v_curr, jnp.int32),
        v_prev=jnp.asarray(v_prev if v_prev is not None else [-1] * n,
                           jnp.int32),
        query_id=jnp.arange(n, dtype=jnp.int32),
        hop=jnp.zeros((n,), jnp.int32),
        active=jnp.ones((n,), bool))


def _star_graph(weights=None):
    """Vertex 0 with 4 neighbors 1..4."""
    edges = np.array([[0, 1], [0, 2], [0, 3], [0, 4]])
    return build_csr(edges, 5, weights=weights)


def _empirical(g, spec, n=20000, v_prev=None):
    slots = _slots([0] * n, v_prev=[v_prev] * n if v_prev is not None
                   else None)
    # vary query ids -> independent streams
    from repro.graph.csr import row_access
    addr, deg = row_access(g, slots.v_curr)
    sampler = make_sampler(spec)
    idx, ok = sampler(g, addr, deg, slots, jax.random.PRNGKey(0))
    e = np.asarray(jnp.clip(addr + idx, 0, g.num_edges - 1))
    chosen = np.asarray(g.col)[e]
    return np.bincount(chosen, minlength=5)[1:5] / n


def test_uniform_distribution():
    g = _star_graph()
    freq = _empirical(g, SamplerSpec(kind="uniform"))
    np.testing.assert_allclose(freq, 0.25, atol=0.02)


def test_alias_weighted_distribution():
    w = np.array([0.1, 0.2, 0.3, 0.4], np.float32)
    g = build_alias_tables(_star_graph(weights=w))
    freq = _empirical(g, SamplerSpec(kind="alias"))
    np.testing.assert_allclose(freq, w / w.sum(), atol=0.02)


def test_edge_exists():
    edges = np.array([[0, 1], [0, 3], [1, 2], [2, 0], [2, 3]])
    g = build_csr(edges, 4)
    src = jnp.asarray([0, 0, 0, 1, 2, 2, 3])
    dst = jnp.asarray([1, 2, 3, 2, 0, 1, 0])
    got = np.asarray(edge_exists(g, src, dst))
    assert list(got) == [True, False, True, True, True, False, False]
    # batched candidate matrix
    got2 = np.asarray(edge_exists(g, jnp.asarray([0, 2]),
                                  jnp.asarray([[1, 2, 3], [0, 3, 1]])))
    assert got2.tolist() == [[True, False, True], [True, True, False]]


def _n2v_exact(g, v_prev, v_curr, p, q, weights=None):
    """Exact Node2Vec transition distribution."""
    rp, col = np.asarray(g.row_ptr), np.asarray(g.col)
    nbrs = col[rp[v_curr]:rp[v_curr + 1]]
    w = np.ones(len(nbrs)) if weights is None else \
        np.asarray(weights)[rp[v_curr]:rp[v_curr + 1]]
    prev_nbrs = set(col[rp[v_prev]:rp[v_prev + 1]])
    bias = np.array([1 / p if y == v_prev else
                     (1.0 if y in prev_nbrs else 1 / q) for y in nbrs])
    probs = w * bias
    return nbrs, probs / probs.sum()


@pytest.mark.parametrize("weighted", [False, True])
def test_node2vec_distribution(weighted, rng):
    # ring + chords graph, walk from 2 with prev=1
    edges = [(i, (i + 1) % 8) for i in range(8)]
    edges += [((i + 1) % 8, i) for i in range(8)]
    edges += [(2, 5), (2, 6), (1, 3)]
    edges = np.array(sorted(set(edges)))
    w = (rng.random(len(edges)).astype(np.float32) + 0.1) if weighted else None
    g = build_csr(edges, 8, weights=w)
    p_, q_ = 2.0, 0.5
    kind = "reservoir_n2v" if weighted else "rejection_n2v"
    spec = SamplerSpec(kind=kind, p=p_, q=q_, rejection_rounds=16)
    n = 30000
    slots = _slots([2] * n, v_prev=[1] * n)
    from repro.graph.csr import row_access
    addr, deg = row_access(g, slots.v_curr)
    idx, ok = make_sampler(spec)(g, addr, deg, slots, jax.random.PRNGKey(1))
    e = np.asarray(jnp.clip(addr + idx, 0, g.num_edges - 1))
    chosen = np.asarray(g.col)[e]
    nbrs, probs = _n2v_exact(g, 1, 2, p_, q_,
                             None if not weighted else g.weights)
    emp = np.bincount(chosen, minlength=8)[nbrs] / n
    np.testing.assert_allclose(emp, probs, atol=0.025)


def test_metapath_respects_types(rng):
    from repro.graph import make_dataset
    g = make_dataset("WG", scale_override=9, num_edge_types=3)
    spec = SamplerSpec(kind="metapath", metapath=(1,))
    n = 500
    starts = rng.integers(0, g.num_vertices, n)
    slots = _slots(starts)
    from repro.graph.csr import row_access
    addr, deg = row_access(g, slots.v_curr)
    idx, ok = make_sampler(spec)(g, addr, deg, slots, jax.random.PRNGKey(2))
    e = np.asarray(jnp.clip(addr + idx, 0, g.num_edges - 1))
    et = np.asarray(g.edge_type)
    ok = np.asarray(ok)
    assert ok.sum() > 0
    assert (et[e[ok]] == 1).all()


def test_stateless_rng_reproducible():
    """The draw is a pure function of (seed, qid, hop) — the stateless-task
    invariant that makes out-of-order execution sound (paper §V-A)."""
    from repro.core import rng as task_rng
    k = jax.random.PRNGKey(0)
    qid = jnp.asarray([5, 5, 9], jnp.uint32)
    hop = jnp.asarray([1, 1, 2], jnp.uint32)
    u1 = task_rng.task_uniforms(k, qid, hop, 3)
    u2 = task_rng.task_uniforms(k, qid[::-1], hop[::-1], 3)[::-1]
    np.testing.assert_array_equal(np.asarray(u1), np.asarray(u2))
    assert np.array_equal(np.asarray(u1[0]), np.asarray(u1[1]))
    assert not np.array_equal(np.asarray(u1[0]), np.asarray(u1[2]))


def test_reservoir_adaptive_chunks_bit_identical(rng):
    """Degree-adaptive E-S scan (dynamic chunk bound at the live lanes'
    max degree) samples exactly the same walks as the full
    ceil(max_degree/chunk) scan — the skipped chunks only ever held -inf
    reservoir keys."""
    import dataclasses

    from repro.core import EngineConfig
    from repro.core.walk_engine import _run_walks
    from repro.graph import make_dataset

    g = make_dataset("WG", scale_override=9, weighted=True)
    starts = rng.integers(0, g.num_vertices, 150).astype(np.int32)
    spec = SamplerSpec(kind="reservoir_n2v", p=2.0, q=0.5,
                       reservoir_chunk=16)
    assert spec.adaptive_chunks  # the default
    cfg = EngineConfig(num_slots=32, max_hops=8)
    fixed = dataclasses.replace(spec, adaptive_chunks=False)
    r_ad = _run_walks(g, starts, spec, cfg, seed=3)
    r_fx = _run_walks(g, starts, fixed, cfg, seed=3)
    pa, la = r_ad.as_numpy()
    pf, lf = r_fx.as_numpy()
    assert np.array_equal(pa, pf)
    assert np.array_equal(la, lf)
