"""Per-architecture smoke tests (deliverable f): instantiate the REDUCED
config of each assigned arch, run one forward/train step on CPU, assert
output shapes and no NaNs.  The FULL configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.data import pipeline as datapipe

KEY = jax.random.PRNGKey(0)


LM_ARCHS = [a for a in ARCHS if get_arch(a).FAMILY == "lm"]
GNN_ARCHS = [a for a in ARCHS if get_arch(a).FAMILY == "gnn"]
REC_ARCHS = [a for a in ARCHS if get_arch(a).FAMILY == "recsys"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke(arch):
    from repro.models import transformer as tfm
    mod = get_arch(arch)
    cfg = dataclasses.replace(mod.SMOKE, dtype=jnp.float32)
    params = tfm.init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 24), 0, cfg.vocab)
    # train step
    loss, grads = jax.value_and_grad(tfm.train_loss)(params, toks, toks, cfg)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0
    # serve: prefill + one decode step
    logits, kv = tfm.prefill(params, toks, cfg)
    assert logits.shape == (2, 1, cfg.vocab)
    cache = tfm.make_kv_cache(cfg, 2, 32, jnp.float32)
    cache = cache.at[:, :, :, :24].set(kv)
    lg, cache2 = tfm.decode_step(params, toks[:, :1], cache,
                                 jnp.asarray(24), cfg)
    assert lg.shape == (2, 1, cfg.vocab)
    assert bool(jnp.isfinite(lg).all())
    assert cache2.shape == cache.shape


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_smoke(arch):
    import repro.models.gnn as gnnmod
    mod = get_arch(arch)
    cfg = mod.SMOKE
    m = getattr(gnnmod, arch)
    params = m.init_params(KEY, cfg)
    if arch in ("schnet", "mace"):
        b = jax.tree.map(jnp.asarray, datapipe.molecule_batch(12, 40, 4))
        e = m.apply(params, b["species"], b["positions"], b["edge_index"],
                    cfg, b["mol_id"], 4)
        assert e.shape == (4,)
        assert bool(jnp.isfinite(e).all())
    else:
        b = jax.tree.map(jnp.asarray, datapipe.gnn_batch(
            100, 400, cfg.node_in, d_edge=4, n_classes=5))
        if arch == "meshgraphnet":
            out = m.apply(params, b["node_feats"], b["edge_feats"],
                          b["edge_index"], cfg)
            assert out.shape == (100, cfg.out_dim)
        else:
            out = m.apply(params, b["node_feats"], b["edge_index"], cfg)
            assert out.shape == (100, cfg.out_dim)
        assert bool(jnp.isfinite(out).all())
    loss, grads = jax.value_and_grad(m.train_loss)(params, b, cfg)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", REC_ARCHS)
def test_recsys_smoke(arch):
    from repro.models.recsys import dcn
    mod = get_arch(arch)
    cfg = mod.SMOKE
    params = dcn.init_params(KEY, cfg)
    b = jax.tree.map(jnp.asarray, datapipe.recsys_batch(
        16, cfg.n_dense, cfg.n_sparse, cfg.vocabs()))
    logits = dcn.predict(params, b["dense"], b["sparse"], cfg)
    assert logits.shape == (16,)
    assert bool(jnp.isfinite(logits).all())
    loss, grads = jax.value_and_grad(dcn.train_loss)(params, b, cfg)
    assert np.isfinite(float(loss))
    # retrieval head
    cands = jax.random.normal(KEY, (100, cfg.retrieval_dim))
    s = dcn.retrieval_scores(params, b["dense"][:1], b["sparse"][:1],
                             cands, cfg)
    assert s.shape == (1, 100)


def test_all_archs_registered():
    assert len(ARCHS) == 10
    fams = [get_arch(a).FAMILY for a in ARCHS]
    assert fams.count("lm") == 5 and fams.count("gnn") == 4
    assert fams.count("recsys") == 1
    for a in ARCHS:
        mod = get_arch(a)
        assert len(mod.SHAPES) == 4


def test_full_configs_match_assignment():
    """Exact published dims (the assignment block)."""
    p = get_arch("phi35_moe").FULL
    assert (p.n_layers, p.d_model, p.n_heads, p.n_kv_heads, p.vocab) == \
        (32, 4096, 32, 8, 32064)
    assert p.moe.num_experts == 16 and p.moe.top_k == 2
    g = get_arch("granite_moe").FULL
    assert (g.d_model, g.n_heads, g.vocab) == (1536, 24, 49155)
    assert g.moe.num_experts == 40 and g.moe.top_k == 8
    d = get_arch("deepseek_7b").FULL
    assert (d.n_layers, d.d_ff, d.n_kv_heads, d.vocab) == \
        (30, 11008, 32, 102400)
    m = get_arch("minitron_8b").FULL
    assert (m.d_ff, m.vocab) == (16384, 256000)
    s = get_arch("stablelm_12b").FULL
    assert (s.n_layers, s.d_model, s.d_ff, s.vocab) == \
        (40, 5120, 13824, 100352)
    mg = get_arch("meshgraphnet").FULL
    assert (mg.n_layers, mg.d_hidden) == (15, 128)
    sc = get_arch("schnet").FULL
    assert (sc.n_interactions, sc.d_hidden, sc.n_rbf) == (3, 64, 300)
    pn = get_arch("pna").FULL
    assert (pn.n_layers, pn.d_hidden) == (4, 75)
    mc = get_arch("mace").FULL
    assert (mc.n_layers, mc.d_hidden, mc.l_max, mc.correlation, mc.n_rbf) == \
        (2, 128, 2, 3, 8)
    dc = get_arch("dcn_v2").FULL
    assert (dc.n_dense, dc.n_sparse, dc.embed_dim, dc.n_cross_layers) == \
        (13, 26, 16, 3)
    assert dc.mlp_dims == (1024, 1024, 512)
