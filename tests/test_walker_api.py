"""Unified walker API (`repro.walker`): program/config validation, the
algorithm × backend parity matrix (batch / streaming / sharded all
bit-identical to the seed `run_walks` reference), the public-API
snapshot, and the deprecation shims."""
import dataclasses
import warnings

import numpy as np
import pytest

from conftest import run_in_subprocess
from repro import walker
from repro.core import EngineConfig
from repro.core.tasks import make_queue
from repro.core.walk_engine import _run_walks

H = 10  # hop budget for the parity matrix


def _programs():
    return {
        "urw": walker.WalkProgram.urw(H),
        "ppr": walker.WalkProgram.ppr(0.15, H),
        "deepwalk": walker.WalkProgram.deepwalk(H),
        "node2vec": walker.WalkProgram.node2vec(2.0, 0.5, H),
        "node2vec_w": walker.WalkProgram.node2vec(2.0, 0.5, H, weighted=True),
        "metapath": walker.WalkProgram.metapath([0, 1, 2], H),
    }


@pytest.fixture(scope="module")
def rich_graph():
    """One graph carrying every payload (weights, alias tables, edge
    types) so a single fixture serves the whole algorithm matrix."""
    from repro.graph import make_dataset
    return make_dataset("WG", scale_override=9, weighted=True,
                        with_alias=True, num_edge_types=3)


def _reference(g, program, starts, seed):
    cfg = EngineConfig(num_slots=64, max_hops=program.max_hops)
    return _run_walks(g, starts, program.spec, cfg, seed=seed)


# ---------------------------------------------------------------- parity


@pytest.mark.parametrize("algo", sorted(_programs()))
def test_batch_parity(algo, rich_graph, rng):
    """compile(program).run == the seed run_walks reference, bit-identical."""
    program = _programs()[algo]
    starts = rng.integers(0, rich_graph.num_vertices, 150).astype(np.int32)
    rp, rl = _reference(rich_graph, program, starts, seed=4).as_numpy()
    res = walker.compile(
        program, execution=walker.ExecutionConfig(num_slots=64)).run(
            rich_graph, starts, seed=4)
    bp, bl = res.as_numpy()
    assert np.array_equal(rp, bp) and np.array_equal(rl, bl)
    assert int(res.stats.terminations) == len(starts)


@pytest.mark.parametrize("algo", sorted(_programs()))
def test_stream_parity(algo, rich_graph, rng):
    """Walker.stream (open system, chunked) == the closed batch."""
    program = _programs()[algo]
    starts = rng.integers(0, rich_graph.num_vertices, 150).astype(np.int32)
    rp, rl = _reference(rich_graph, program, starts, seed=4).as_numpy()
    stream = walker.compile(
        program, execution=walker.ExecutionConfig(num_slots=64)).stream(
            rich_graph, capacity=150, seed=4)
    stream.inject(starts[:70])
    stream.advance(3)                  # arrivals land mid-flight
    stream.inject(starts[70:])
    stream.drain(chunk=7)
    sp, sl = stream.harvest()
    assert np.array_equal(rp, sp) and np.array_equal(rl, sl)


SHARDED_PARITY = r"""
import numpy as np
from repro import walker
from repro.graph import make_dataset, partition_graph
from repro.core import EngineConfig
from repro.core.walk_engine import _run_walks

H = 10
cases = [
    ("urw", walker.WalkProgram.urw(H), {}),
    ("ppr", walker.WalkProgram.ppr(0.15, H), {}),
    ("deepwalk", walker.WalkProgram.deepwalk(H),
     dict(weighted=True, with_alias=True)),
    ("node2vec", walker.WalkProgram.node2vec(2.0, 0.5, H), {}),
    ("node2vec_w", walker.WalkProgram.node2vec(2.0, 0.5, H, weighted=True),
     dict(weighted=True)),
    ("metapath", walker.WalkProgram.metapath([0, 1, 2], H),
     dict(num_edge_types=3)),
]
for name, program, kwargs in cases:
    g = make_dataset("WG", scale_override=9, **kwargs)
    pg = partition_graph(g, 2)
    starts = np.random.default_rng(0).integers(
        0, g.num_vertices, 160).astype(np.int32)
    ref = _run_walks(g, starts, program.spec,
                     EngineConfig(num_slots=64, max_hops=H), seed=4)
    rp, rl = ref.as_numpy()
    sharded = walker.compile(
        program, backend="sharded",
        execution=walker.ExecutionConfig(slots_per_device=16,
                                         log_capacity=1 << 14))
    res = sharded.run(pg, starts, seed=4)
    dp, dl = res.as_numpy()
    assert (dp == rp).all() and (dl == rl).all(), name
    assert int(np.asarray(res.stats.drops)) == 0, name

    # sharded *streaming* (ring substrate): same walks, mid-flight inject
    stream = sharded.stream(pg, capacity=160, seed=4)
    stream.inject(starts[:70])
    stream.advance(3)
    stream.inject(starts[70:])
    stream.drain(chunk=7)
    sp, sl = stream.harvest()
    assert (sp == rp).all() and (sl == rl).all(), name
    assert int(stream.walk_stats().drops) == 0, name
print("SHARDED_PARITY_OK")
"""


@pytest.mark.slow
def test_sharded_parity_two_devices():
    """Every algorithm — metapath included, now that type_offsets shard
    with the CSR — on the 2-device sharded backend == single-device
    reference, through compile(program, backend='sharded'): closed batch
    AND open stream over the same ring substrate."""
    out = run_in_subprocess(SHARDED_PARITY, devices=2)
    assert "SHARDED_PARITY_OK" in out


def test_sharded_metapath_needs_typed_partition(small_graph, rng):
    """A metapath program on an *untyped* partitioned graph fails with an
    actionable error (type_offsets were never built)."""
    from repro.graph import partition_graph
    pg = partition_graph(small_graph, 1)
    starts = rng.integers(0, small_graph.num_vertices, 16).astype(np.int32)
    w = walker.compile(_programs()["metapath"], backend="sharded",
                       execution=walker.ExecutionConfig(num_devices=1))
    with pytest.raises(ValueError, match="type_offsets"):
        w.run(pg, starts)


# ------------------------------------------------------------ validation


def test_program_validation():
    with pytest.raises(ValueError, match="max_hops"):
        walker.WalkProgram.urw(0)
    with pytest.raises(ValueError, match="stop_prob"):
        walker.WalkProgram.ppr(alpha=1.5)
    with pytest.raises(ValueError, match="schedule"):
        walker.WalkProgram.metapath([])
    with pytest.raises(ValueError, match="positive"):
        walker.WalkProgram.node2vec(p=0.0)
    with pytest.raises(TypeError, match="WalkProgram"):
        walker.compile("urw")
    with pytest.raises(ValueError, match="backend"):
        walker.compile(walker.WalkProgram.urw(), backend="tpu_pod")


def test_sampler_spec_validation():
    """Malformed specs fail at construction (not deep inside tracing):
    the kind registry, the MetaPath schedule, and the Node2Vec params are
    all checked by SamplerSpec.__post_init__ itself."""
    from repro.core.samplers import SamplerSpec
    with pytest.raises(ValueError, match="schedule"):
        SamplerSpec(kind="metapath", metapath=())
    with pytest.raises(ValueError, match="schedule"):
        SamplerSpec(kind="metapath")
    with pytest.raises(ValueError, match="non-negative"):
        SamplerSpec(kind="metapath", metapath=(0, -1))
    with pytest.raises(ValueError, match="unknown sampler kind"):
        SamplerSpec(kind="levy_flight")
    with pytest.raises(ValueError, match="positive"):
        SamplerSpec(kind="rejection_n2v", q=-1.0)
    with pytest.raises(ValueError, match="rejection_rounds"):
        SamplerSpec(kind="rejection_n2v", rejection_rounds=0)
    with pytest.raises(ValueError, match="reservoir_chunk"):
        SamplerSpec(kind="reservoir_n2v", reservoir_chunk=0)


def test_support_matrix_generated_from_programs():
    """The docs support matrix is generated from the phase-program
    declarations — docs/api.md must embed render_support_matrix()'s
    output verbatim (regenerate with
    ``python -m repro.core.phase_program``)."""
    import os

    from repro.core.phase_program import (fused_kinds, lower,
                                          render_support_matrix,
                                          support_rows)
    rows = {r["kind"]: r for r in support_rows()}
    # the acceptance surface: fused covers every sampler kind (the
    # chunked reservoir loop runs in-kernel), and every kind (metapath
    # included) is sharded
    from repro.core.samplers import KINDS
    assert fused_kinds() == KINDS
    assert all(r["capability"] is not None for r in rows.values())
    assert rows["metapath"]["capability"] == "first_order"
    assert lower(walker.WalkProgram.node2vec(
        2.0, 0.5, weighted=True).spec).schedule == "chunked_loop"
    docs = open(os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "docs", "api.md")).read()
    for line in render_support_matrix().splitlines():
        assert line in docs, f"docs/api.md out of date, missing: {line}"


def test_schedule_table_generated_from_programs():
    """docs/architecture.md must embed both generated tables verbatim
    (regenerate with ``python -m repro.core.phase_program`` /
    ``--schedule``; CI runs ``--check``)."""
    import os

    from repro.core.phase_program import (render_schedule_table,
                                          render_support_matrix)
    arch = open(os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "docs", "architecture.md")).read()
    for table in (render_schedule_table(), render_support_matrix()):
        for line in table.splitlines():
            assert line in arch, \
                f"docs/architecture.md out of date, missing: {line}"


def test_execution_config_validation():
    with pytest.raises(ValueError, match="num_slots"):
        walker.ExecutionConfig(num_slots=0)
    with pytest.raises(ValueError, match="mode"):
        walker.ExecutionConfig(mode="eager")
    with pytest.raises(ValueError, match="injection_delay"):
        walker.ExecutionConfig(injection_delay=-1)
    with pytest.raises(ValueError, match="queue_depth_factor"):
        walker.ExecutionConfig(queue_depth_factor=0.0)
    with pytest.raises(ValueError, match="num_devices"):
        walker.ExecutionConfig(num_devices=0)


def test_engine_config_validation():
    with pytest.raises(ValueError, match="num_slots"):
        EngineConfig(num_slots=0)
    with pytest.raises(ValueError, match="max_hops"):
        EngineConfig(max_hops=-1)
    with pytest.raises(ValueError, match="step_impl"):
        EngineConfig(step_impl="cuda")
    # valid configs still replace cleanly
    cfg = dataclasses.replace(EngineConfig(), num_slots=4)
    assert cfg.num_slots == 4


def test_dist_config_validation():
    from repro.core.distributed import DistConfig
    with pytest.raises(ValueError, match="slots_per_device"):
        DistConfig(slots_per_device=0)
    with pytest.raises(ValueError, match="max_hops"):
        DistConfig(max_hops=0)


def test_make_queue_watermark_validation():
    with pytest.raises(ValueError, match="staged"):
        make_queue(np.zeros(8, np.int32), staged=9)
    with pytest.raises(ValueError, match="capacity"):
        make_queue(np.zeros(8, np.int32), tail=12)
    q = make_queue(np.zeros(8, np.int32), staged=4)
    assert int(q.staged) == 4 and int(q.tail) == 8


def test_stream_admission_overflow(rich_graph, rng):
    stream = walker.compile(walker.WalkProgram.urw(4)).stream(
        rich_graph, capacity=8)
    stream.inject(rng.integers(0, rich_graph.num_vertices, 8))
    with pytest.raises(ValueError, match="overflows"):
        stream.inject(rng.integers(0, rich_graph.num_vertices, 1))


def test_stream_release_recycles_slots(rich_graph, rng):
    """Ring economy: released slots are re-issued FIFO with epoch + 1;
    releasing an unfinished or non-live slot is rejected."""
    stream = walker.compile(walker.WalkProgram.urw(4)).stream(
        rich_graph, capacity=8)
    starts = rng.integers(0, rich_graph.num_vertices, 8).astype(np.int32)
    qids, epochs = stream.inject(starts)
    assert np.array_equal(qids, np.arange(8)) and (epochs == 0).all()
    assert stream.num_free == 0
    with pytest.raises(ValueError, match="unfinished"):
        stream.release(qids[:2])
    stream.drain(chunk=4)
    with pytest.raises(ValueError, match="duplicate"):
        stream.release([qids[0], qids[0]])
    stream.release(qids[:3])
    assert stream.num_free == 3
    with pytest.raises(ValueError, match="not live"):
        stream.release(qids[:1])           # double release
    q2, e2 = stream.inject(starts[:3])
    assert np.array_equal(q2, qids[:3]) and (e2 == 1).all()
    assert stream.num_injected == 11


# ---------------------------------------------------- API snapshot + shims


def test_public_api_snapshot():
    """The public surface of repro.walker is intentional: additions and
    removals must update this snapshot (and docs/api.md)."""
    assert list(walker.__all__) == [
        "WalkProgram",
        "ExecutionConfig",
        "compile",
        "Walker",
        "WalkStream",
        "ShardedWalkStream",
        "BACKENDS",
    ]
    assert walker.BACKENDS == ("single", "sharded")
    for name in walker.__all__:
        assert getattr(walker, name) is not None
    # the two stream backends expose one interface (WalkService contract)
    for method in ("inject", "advance", "done_mask", "harvest_ids",
                   "release", "walk_stats", "reset", "drain"):
        assert callable(getattr(walker.WalkStream, method))
        assert callable(getattr(walker.ShardedWalkStream, method))


def test_deprecated_names_importable():
    """Surviving legacy entry points remain importable shims; the
    ``core.walks`` / ``core.distributed_n2v`` modules (two PRs past
    deprecation) are gone for good."""
    from repro.core.distributed import run_distributed        # noqa: F401
    from repro.core.walk_engine import (make_engine,          # noqa: F401
                                        make_superstep_runner, run_walks)
    with pytest.raises(ImportError):
        from repro.core import walks                          # noqa: F401
    with pytest.raises(ImportError):
        from repro.core import distributed_n2v                # noqa: F401


def test_legacy_run_walks_shim_warns(rich_graph, rng):
    from repro.core.samplers import SamplerSpec
    from repro.core.walk_engine import run_walks
    starts = rng.integers(0, rich_graph.num_vertices, 32).astype(np.int32)
    with pytest.deprecated_call():
        res = run_walks(rich_graph, starts, SamplerSpec(kind="uniform"),
                        EngineConfig(num_slots=32, max_hops=4), seed=1)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        new = walker.compile(
            walker.WalkProgram.urw(4),
            execution=walker.ExecutionConfig(num_slots=32)).run(
                rich_graph, starts, seed=1)
    # the new surface must NOT route through a deprecated shim
    assert not [w for w in caught
                if issubclass(w.category, DeprecationWarning)
                and "repro.walker" in str(w.message)]
    assert np.array_equal(*(r.as_numpy()[0] for r in (res, new)))
