"""GPipe pipeline parallelism over a `pipe` mesh axis (subprocess: needs
multiple host devices)."""
import pytest

from conftest import run_in_subprocess

pytestmark = pytest.mark.slow  # out-of-process multi-device runs

PIPE = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.distributed.pipeline import pipeline_apply, gpipe_bubble_fraction

P_STAGES, M, MB, D = 4, 8, 4, 16
mesh = Mesh(np.array(jax.devices()[:P_STAGES]), ("pipe",))
key = jax.random.PRNGKey(0)
Ws = jax.random.normal(key, (P_STAGES, D, D)) * 0.3

def stage_fn(w, x):
    return jnp.tanh(x @ w)

xs = jax.random.normal(jax.random.PRNGKey(1), (M, MB, D))
out = pipeline_apply(stage_fn, Ws, xs, mesh)

# reference: sequential application of all stages
ref = xs
for i in range(P_STAGES):
    ref = stage_fn(Ws[i], ref)
assert np.allclose(np.asarray(out), np.asarray(ref), atol=1e-5), (
    np.abs(np.asarray(out) - np.asarray(ref)).max())
assert abs(gpipe_bubble_fraction(4, 8) - 3/11) < 1e-9
print("PIPE_OK")
"""


def test_gpipe_matches_sequential():
    out = run_in_subprocess(PIPE, devices=4)
    assert "PIPE_OK" in out
