"""Device-resident walks→embeddings pipeline (`repro.core.corpus_ring` +
`Walker.train_embeddings`): ring economy unit tests, batch-sampler
determinism, kernel-gather parity, the zero-host-copy guard, overlap vs
serial bit-identity, checkpoint/resume bit-identity, and the sharded
backend parity smoke."""
import glob
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_in_subprocess
from repro import walker
from repro.core import corpus_ring
from repro.core import rng as task_rng
from repro.models import embeddings as emb

H = 10  # hop budget for the pipeline tests


def _walker():
    return walker.compile(walker.WalkProgram.urw(H))


def _train_kw(**over):
    kw = dict(seed=3, rounds=2, walks_per_round=16, steps_per_round=8,
              batch_size=32, dim=8, window=3, num_negatives=4,
              use_kernel=False)
    kw.update(over)
    return kw


# --------------------------------------------------------------- ring unit

def test_ring_init_and_validation():
    ring = corpus_ring.init_ring(8, H + 1)
    assert ring.capacity == 8 and ring.path_width == H + 1
    assert int(ring.tail) == 0 and int(corpus_ring.filled(ring)) == 0
    assert bool(jnp.all(ring.paths == -1))
    with pytest.raises(ValueError):
        corpus_ring.init_ring(0, H + 1)
    with pytest.raises(ValueError):
        corpus_ring.init_ring(8, 0)


def test_ring_append_wraps_and_pads():
    ring = corpus_ring.init_ring(4, 6)
    p0 = jnp.arange(12, dtype=jnp.int32).reshape(3, 4)  # narrower rows
    ring = corpus_ring.append(ring, p0, jnp.full((3,), 4, jnp.int32))
    assert int(ring.tail) == 3 and int(corpus_ring.filled(ring)) == 3
    # Narrow paths are right-padded with -1.
    np.testing.assert_array_equal(np.asarray(ring.paths[0]),
                                  [0, 1, 2, 3, -1, -1])
    # Next append wraps: slots 3, 0 are overwritten, 1..2 survive.
    p1 = jnp.full((2, 6), 7, jnp.int32)
    ring = corpus_ring.append(ring, p1, jnp.full((2,), 6, jnp.int32))
    assert int(ring.tail) == 5 and int(corpus_ring.filled(ring)) == 4
    np.testing.assert_array_equal(np.asarray(ring.paths[3]), [7] * 6)
    np.testing.assert_array_equal(np.asarray(ring.paths[0]), [7] * 6)
    np.testing.assert_array_equal(np.asarray(ring.paths[1]),
                                  [4, 5, 6, 7, -1, -1])


def test_ring_append_rejects_oversize():
    ring = corpus_ring.init_ring(4, 6)
    with pytest.raises(ValueError, match="would overwrite"):
        corpus_ring.append(ring, jnp.zeros((5, 6), jnp.int32),
                           jnp.zeros((5,), jnp.int32))
    with pytest.raises(ValueError, match="wide"):
        corpus_ring.append(ring, jnp.zeros((2, 7), jnp.int32),
                           jnp.zeros((2,), jnp.int32))


# ----------------------------------------------------------- batch sampler

def _filled_ring(nv=64, rows=16, width=H + 1, seed=0):
    r = np.random.default_rng(seed)
    paths = r.integers(0, nv, (rows, width), dtype=np.int32)
    lengths = r.integers(2, width + 1, (rows,), dtype=np.int32)
    for i in range(rows):
        paths[i, lengths[i]:] = -1
    ring = corpus_ring.init_ring(rows, width)
    return corpus_ring.append(ring, jnp.asarray(paths),
                              jnp.asarray(lengths))


def test_batch_sampler_deterministic_and_bounded():
    nv = 64
    ring = _filled_ring(nv)
    sample = corpus_ring.make_batch_sampler(nv, 48, window=3,
                                            num_negatives=5)
    key = task_rng.stream_key(9)
    a = sample(ring, key, 4)
    b = sample(ring, key, 4)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    c = sample(ring, key, 5)
    assert any(not np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(a, c)), "step must salt the draws"
    centers, contexts, negatives, mask = (np.asarray(x) for x in a)
    assert mask.any(), "a filled ring must yield some valid pairs"
    for arr in (centers, contexts, negatives):
        assert arr.min() >= 0 and arr.max() < nv


def test_batch_sampler_empty_ring_masks_everything():
    ring = corpus_ring.init_ring(8, H + 1)
    sample = corpus_ring.make_batch_sampler(64, 16, window=2,
                                            num_negatives=3)
    *_, mask = sample(ring, task_rng.stream_key(0), 0)
    assert not bool(np.asarray(mask).any())


def test_sampler_validation():
    with pytest.raises(ValueError):
        corpus_ring.make_batch_sampler(64, 16, window=0, num_negatives=3)
    with pytest.raises(ValueError):
        corpus_ring.make_batch_sampler(64, 16, window=2, num_negatives=0)


# ------------------------------------------------------- kernel gather path

def test_gather_rows_kernel_parity():
    key = jax.random.PRNGKey(0)
    table = jax.random.normal(key, (128, 16), jnp.float32)
    ids = jax.random.randint(jax.random.PRNGKey(1), (32,), 0, 128)
    ref = emb.gather_rows(table, ids, use_kernel=False)
    ker = emb.gather_rows(table, ids, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(ker))

    def loss(t, use_kernel):
        return jnp.sum(emb.gather_rows(t, ids, use_kernel=use_kernel) ** 2)

    g_ref = jax.grad(lambda t: loss(t, False))(table)
    g_ker = jax.grad(lambda t: loss(t, True))(table)
    np.testing.assert_allclose(np.asarray(g_ref), np.asarray(g_ker),
                               rtol=1e-6, atol=1e-6)


def test_sgns_kernel_step_matches_jnp(small_graph):
    w = _walker()
    kw = _train_kw(rounds=1, steps_per_round=2)
    ref = w.train_embeddings(small_graph, **kw)
    kw["use_kernel"] = True
    ker = w.train_embeddings(small_graph, **kw)
    np.testing.assert_allclose(np.asarray(ref["params"]["in_embed"]),
                               np.asarray(ker["params"]["in_embed"]),
                               rtol=1e-5, atol=1e-6)


# ------------------------------------------------ host-copy accounting

def test_overlap_mode_makes_zero_host_copies(small_graph):
    w = _walker()
    w.train_embeddings(small_graph, **_train_kw())  # warm the jit caches
    before = corpus_ring.host_copies()
    with corpus_ring.no_host_copies():
        out = w.train_embeddings(small_graph, **_train_kw())
    assert corpus_ring.host_copies() == before
    assert out["step"] == 16


def test_serial_mode_trips_the_guard(small_graph):
    w = _walker()
    with pytest.raises(RuntimeError, match="no_host_copies"):
        with corpus_ring.no_host_copies():
            w.train_embeddings(small_graph, **_train_kw(overlap=False))


def test_serial_mode_counts_round_trips(small_graph):
    w = _walker()
    before = corpus_ring.host_copies()
    w.train_embeddings(small_graph, **_train_kw(overlap=False))
    # One path round-trip per round plus one batch staging per step.
    assert corpus_ring.host_copies() - before == 2 + 2 * 8


def test_harvest_ids_is_a_recorded_host_copy(small_graph):
    w = _walker()
    stream = w.stream(small_graph, capacity=8, seed=0)
    qids, _ = stream.inject(np.arange(8))
    stream.drain()
    d_paths, d_lengths = stream.harvest_device(qids)
    before = corpus_ring.host_copies()
    h_paths, h_lengths = stream.harvest_ids(qids)
    assert corpus_ring.host_copies() == before + 1
    np.testing.assert_array_equal(h_paths, np.asarray(d_paths))
    np.testing.assert_array_equal(h_lengths, np.asarray(d_lengths))
    stream.release(qids)


# ------------------------------------------------------------ bit-identity

def test_overlap_and_serial_are_bit_identical(small_graph):
    w = _walker()
    over = w.train_embeddings(small_graph, **_train_kw(overlap=True))
    ser = w.train_embeddings(small_graph, **_train_kw(overlap=False))
    for k in ("in_embed", "out_embed"):
        np.testing.assert_array_equal(np.asarray(over["params"][k]),
                                      np.asarray(ser["params"][k]))
    np.testing.assert_array_equal(np.asarray(over["ring"].paths),
                                  np.asarray(ser["ring"].paths))


def test_checkpoint_resume_is_bit_identical(small_graph, tmp_path):
    w = _walker()
    kw = _train_kw()

    def record_into(log):
        def hook(step, batch):
            log.append((step, tuple(np.asarray(x) for x in batch)))
        return hook

    ref_log = []
    ref = w.train_embeddings(small_graph, **kw,
                             batch_hook=record_into(ref_log))

    ckpt = str(tmp_path / "ckpt")
    w.train_embeddings(small_graph, **kw, ckpt_dir=ckpt, ckpt_every=4)
    # Simulate preemption after step 8: drop every later checkpoint.
    kept = 0
    for p in glob.glob(ckpt + "/step_*"):
        if int(p.rsplit("_", 1)[1]) > 8:
            shutil.rmtree(p)
        else:
            kept += 1
    assert kept >= 1
    res_log = []
    res = w.train_embeddings(small_graph, **kw, ckpt_dir=ckpt,
                             ckpt_every=4, batch_hook=record_into(res_log))

    assert res["step"] == ref["step"] == 16
    # The resumed run replays exactly steps 8..15 with the reference's
    # batch stream, and lands on bit-identical tables.
    tail = {s: b for s, b in ref_log if s >= 8}
    assert [s for s, _ in res_log] == sorted(tail)
    for s, batch in res_log:
        for x, y in zip(batch, tail[s]):
            np.testing.assert_array_equal(x, y)
    for k in ("in_embed", "out_embed"):
        np.testing.assert_array_equal(np.asarray(res["params"][k]),
                                      np.asarray(ref["params"][k]))


def test_seek_epochs_validation(small_graph):
    w = _walker()
    stream = w.stream(small_graph, capacity=8, seed=0)
    stream.seek_epochs(3)
    with pytest.raises(ValueError):
        stream.seek_epochs(1)  # epochs are monotone
    qids, _ = stream.inject(np.arange(4))
    with pytest.raises(RuntimeError, match="live"):
        stream.seek_epochs(5)
    stream.drain()
    stream.harvest_device(qids)
    stream.release(qids)


# -------------------------------------------------------- sharded backend

@pytest.mark.slow
def test_sharded_training_matches_single():
    run_in_subprocess("""
import numpy as np
from repro import walker
from repro.graph import make_dataset

g = make_dataset("WG", scale_override=9)
kw = dict(seed=3, rounds=2, walks_per_round=16, steps_per_round=6,
          batch_size=32, dim=8, window=3, num_negatives=4,
          use_kernel=False)
single = walker.compile(walker.WalkProgram.urw(10))
sharded = walker.compile(walker.WalkProgram.urw(10),
                         backend="sharded")
a = single.train_embeddings(g, **kw)
b = sharded.train_embeddings(g, **kw)
np.testing.assert_array_equal(np.asarray(a["params"]["in_embed"]),
                              np.asarray(b["params"]["in_embed"]))
np.testing.assert_array_equal(np.asarray(a["ring"].paths),
                              np.asarray(b["ring"].paths))
print("OK")
""", devices=2, timeout=600)
