"""Walk-engine behaviour: path validity, zero-bubble theorem, scheduling
modes, Pallas/jnp step equivalence, determinism."""
import dataclasses

import numpy as np
import pytest

from repro.core import EngineConfig
from repro.core.samplers import SamplerSpec
from repro.core.scheduler import analyze_run, min_queue_depth
from repro.core.walk_engine import _run_walks


CFG = EngineConfig(num_slots=128, max_hops=16)


def _walk(g, starts, spec, hops, cfg=None, seed=0):
    cfg = dataclasses.replace(cfg or CFG, max_hops=hops)
    return _run_walks(g, starts, spec, cfg, seed=seed)


def urw(g, starts, hops, cfg=None, seed=0):
    return _walk(g, starts, SamplerSpec(kind="uniform"), hops, cfg, seed)


def ppr(g, starts, alpha, hops, cfg=None, seed=0):
    return _walk(g, starts, SamplerSpec(kind="uniform", stop_prob=alpha),
                 hops, cfg, seed)


def deepwalk(g, starts, hops, cfg=None, seed=0):
    return _walk(g, starts, SamplerSpec(kind="alias"), hops, cfg, seed)


def node2vec(g, starts, p, q, hops, cfg=None, seed=0):
    return _walk(g, starts, SamplerSpec(kind="rejection_n2v", p=p, q=q),
                 hops, cfg, seed)


def metapath(g, starts, schedule, hops, cfg=None, seed=0):
    return _walk(g, starts,
                 SamplerSpec(kind="metapath", metapath=tuple(schedule)),
                 hops, cfg, seed)


def _valid_paths(g, paths, lengths):
    rp, col = np.asarray(g.row_ptr), np.asarray(g.col)
    for q in range(paths.shape[0]):
        for t in range(lengths[q] - 1):
            u, v = paths[q, t], paths[q, t + 1]
            seg = col[rp[u]:rp[u + 1]]
            if v not in seg:
                return False, (q, t, u, v)
    return True, None


@pytest.mark.parametrize("algo", ["urw", "ppr", "deepwalk", "node2vec"])
def test_paths_are_real_walks(algo, small_graph, weighted_graph, rng):
    g = weighted_graph if algo in ("deepwalk",) else small_graph
    starts = rng.integers(0, g.num_vertices, 200)
    runners = {
        "urw": lambda: urw(g, starts, 16, cfg=CFG),
        "ppr": lambda: ppr(g, starts, 0.15, 16, cfg=CFG),
        "deepwalk": lambda: deepwalk(g, starts, 16, cfg=CFG),
        "node2vec": lambda: node2vec(g, starts, 2.0, 0.5, 16, cfg=CFG),
    }
    res = runners[algo]()
    p, l = res.as_numpy()
    ok, info = _valid_paths(g, p, l)
    assert ok, f"invalid transition {info}"
    assert (p[np.arange(len(starts)), 0] == starts).all()
    assert int(res.stats.terminations) == len(starts)
    assert (l <= 17).all() and (l >= 1).all()


def test_every_query_completes(small_graph, rng):
    starts = rng.integers(0, small_graph.num_vertices, 500)
    res = urw(small_graph, starts, 8, cfg=CFG)
    _, l = res.as_numpy()
    assert (l >= 1).all()


def test_zero_bubble_theorem(small_graph, rng):
    """Theorem VI.1: with queue depth D = N + μCN the scheduler never
    starves a lane while work exists; under-provisioning starves."""
    starts = rng.integers(0, small_graph.num_vertices, 600)
    for C in (0, 2, 5):
        cfg = dataclasses.replace(CFG, injection_delay=C)
        a = analyze_run(urw(small_graph, starts, 12, cfg=cfg).stats)
        assert a.starved == 0, f"C={C}: starved={a.starved}"
        assert a.zero_bubble
    cfg = dataclasses.replace(CFG, injection_delay=5, queue_depth_factor=0.05)
    a = analyze_run(urw(small_graph, starts, 12, cfg=cfg).stats)
    assert a.starved > 0


def test_min_queue_depth_formula():
    assert min_queue_depth(16, 1.0, 0) == 16
    assert min_queue_depth(16, 1.0, 4) == 16 + 64
    assert min_queue_depth(128, 0.5, 2) == 128 + 128


def test_static_mode_has_more_bubbles(small_graph, rng):
    """Fig. 11 qualitative: static (bulk-synchronous) scheduling wastes
    lanes on early-terminating walks; zero-bubble does not."""
    starts = rng.integers(0, small_graph.num_vertices, 600)
    a_zb = analyze_run(urw(small_graph, starts, 16, cfg=CFG).stats)
    cfg_s = dataclasses.replace(CFG, mode="static")
    a_st = analyze_run(urw(small_graph, starts, 16, cfg=cfg_s).stats)
    assert a_st.bubble_ratio > a_zb.bubble_ratio + 0.1
    assert a_st.supersteps > a_zb.supersteps


def test_deterministic_across_slot_counts(small_graph, rng):
    """Stateless decomposition: paths depend only on (seed, qid) — NOT on
    lane count, scheduling order, or batch boundaries (paper §V-A)."""
    starts = rng.integers(0, small_graph.num_vertices, 150)
    res_a = urw(small_graph, starts, 12,
                      cfg=dataclasses.replace(CFG, num_slots=32))
    res_b = urw(small_graph, starts, 12,
                      cfg=dataclasses.replace(CFG, num_slots=256))
    res_c = urw(small_graph, starts, 12,
                      cfg=dataclasses.replace(CFG, mode="static"))
    pa, la = res_a.as_numpy()
    pb, lb = res_b.as_numpy()
    pc, lc = res_c.as_numpy()
    assert np.array_equal(pa, pb) and np.array_equal(la, lb)
    assert np.array_equal(pa, pc) and np.array_equal(la, lc)


def test_pallas_step_equivalence(small_graph, weighted_graph, rng):
    starts = rng.integers(0, small_graph.num_vertices, 100)
    cfgp = dataclasses.replace(CFG, step_impl="pallas")
    for g, algo in ((small_graph, urw), (weighted_graph, deepwalk)):
        r1, r2 = algo(g, starts, 8, cfg=CFG), algo(g, starts, 8, cfg=cfgp)
        assert np.array_equal(*(r.as_numpy()[0] for r in (r1, r2)))


def test_ppr_geometric_lengths(small_graph, rng):
    starts = rng.integers(0, small_graph.num_vertices, 800)
    res = ppr(small_graph, starts, 0.3, 64, cfg=CFG)
    _, l = res.as_numpy()
    # hops ~ Geometric(0.3) truncated by dead ends: mean well below 1/0.3+1
    assert 1.0 < l.mean() < 1 + 1 / 0.3 + 1


def test_metapath_early_termination(rng):
    from repro.graph import make_dataset
    g = make_dataset("WG", scale_override=9, num_edge_types=4)
    starts = rng.integers(0, g.num_vertices, 300)
    res = metapath(g, starts, [0, 1, 2, 3], 16, cfg=CFG)
    p, l = res.as_numpy()
    # with 4 types, most walks terminate early -> stressing the scheduler
    assert l.mean() < 16
    a = analyze_run(res.stats)
    assert a.starved == 0
