"""Distributed walks across 8 emulated devices via the unified walker API.

`walker.compile(program, backend="sharded")` runs the full §IV dataflow:
vertex-partitioned graph, per-phase butterfly routing (all_to_all),
flow-controlled zero-bubble refill, streaming path write-back — and the
result is bit-identical to the single-device backend (paper §V-A).
Second-order walks (Node2Vec) route through the same path: the sampler's
declared capability picks the task word and the phase schedule.

  PYTHONPATH=src python examples/distributed_walks.py
  (sets XLA_FLAGS itself; run in a fresh process)
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import time

import numpy as np
import jax

from repro import walker
from repro.graph import make_dataset, partition_graph

N_DEV = 8
MAXH = 40
g = make_dataset("CP", scale_override=12)
pg = partition_graph(g, N_DEV)
print(f"graph |V|={g.num_vertices} |E|={g.num_edges}, "
      f"partitioned over {N_DEV} channels")

starts = np.random.default_rng(0).integers(0, g.num_vertices, 2000)\
    .astype(np.int32)
program = walker.WalkProgram.urw(MAXH)

sharded = walker.compile(
    program, backend="sharded",
    execution=walker.ExecutionConfig(slots_per_device=128,
                                     log_capacity=1 << 17))
t0 = time.time()
res = sharded.run(pg, starts, seed=0)
jax.block_until_ready(res.stats.steps)
dt = time.time() - t0
print(f"distributed: {int(res.stats.steps)} steps in {dt:.1f}s")
print(f"route waits={int(res.stats.route_waits)} "
      f"drops={int(res.stats.drops)} (must be 0)")

ref = walker.compile(
    program, execution=walker.ExecutionConfig(num_slots=512)).run(
        g, starts, seed=0)
dp, dl = res.as_numpy()
rp, rl = ref.as_numpy()
print("bit-identical to single-device engine:",
      bool((dp == rp).all() and (dl == rl).all()))
