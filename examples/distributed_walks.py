"""Distributed walk service across 8 emulated devices (channels).

Shows the full §IV dataflow: vertex-partitioned graph, per-hop butterfly
routing (all_to_all), zero-bubble local refill, streaming path write-back
— and verifies the result is bit-identical to the single-device engine.

  PYTHONPATH=src python examples/distributed_walks.py
  (sets XLA_FLAGS itself; run in a fresh process)
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import time

import numpy as np
import jax

from repro.core import EngineConfig
from repro.core.distributed import (DistConfig, assemble_paths,
                                    run_distributed)
from repro.core.samplers import SamplerSpec
from repro.core.walk_engine import run_walks
from repro.graph import make_dataset, partition_graph

N_DEV = 8
g = make_dataset("CP", scale_override=12)
pg = partition_graph(g, N_DEV)
print(f"graph |V|={g.num_vertices} |E|={g.num_edges}, "
      f"partitioned over {N_DEV} channels")

starts = np.random.default_rng(0).integers(0, g.num_vertices, 2000)\
    .astype(np.int32)
spec = SamplerSpec(kind="uniform")
MAXH = 40

t0 = time.time()
logs, stats = run_distributed(
    pg, starts, spec,
    DistConfig(slots_per_device=128, max_hops=MAXH, log_capacity=1 << 17))
jax.block_until_ready(logs.cursor)
dt = time.time() - t0
steps = int(np.asarray(stats.steps).sum())
print(f"distributed: {steps} steps in {dt:.1f}s; per-device steps = "
      f"{np.asarray(stats.steps).ravel().tolist()}")
print(f"route waits={int(np.asarray(stats.route_waits).sum())} "
      f"drops={int(np.asarray(stats.drops).sum())} (must be 0)")

dp, dl = assemble_paths(logs, starts, MAXH)
ref = run_walks(g, starts, spec, EngineConfig(num_slots=512, max_hops=MAXH),
                seed=0)
rp, rl = ref.as_numpy()
print("bit-identical to single-device engine:",
      bool((dp == rp).all() and (dl == rl).all()))
