"""Serve walk requests as an open system: submit, poll, harvest.

Two tenants submit request waves at different times; the service keeps the
lane pool busy across both, and each tenant harvests exactly its own walks
(request id → ``(epoch, qid)`` slot bookkeeping: each walk occupies a
slot of the device ring, and completed slots are recycled to later
arrivals with a bumped epoch — continuous operation, no drain barrier).

  PYTHONPATH=src python examples/serve_walk_requests.py
"""
import numpy as np

from repro import walker
from repro.graph import make_dataset

g = make_dataset("WG", scale_override=11)
print(f"graph: |V|={g.num_vertices} |E|={g.num_edges}")

svc = walker.compile(
    walker.WalkProgram.urw(20),
    execution=walker.ExecutionConfig(num_slots=256)).serve(
        g, capacity=4096, chunk=4, seed=0)
rng = np.random.default_rng(0)

# Tenant A submits three requests; the service starts working immediately.
a_rids = [svc.submit(rng.integers(0, g.num_vertices, 32)) for _ in range(3)]
svc.step()
print(f"after 1 chunk: inflight={svc.num_inflight} clock={svc.clock}")

# Tenant B arrives mid-stream — no recompilation, no drain barrier.
b_rids = [svc.submit(rng.integers(0, g.num_vertices, 64)) for _ in range(2)]
svc.drain()

for tenant, rids in (("A", a_rids), ("B", b_rids)):
    for rid in rids:
        r = svc.poll(rid)
        print(f"tenant {tenant} request {rid}: {r.num_walks} walks, "
              f"slots [{r.qids.min()},{r.qids.max()}] epoch "
              f"{r.epochs.min()}..{r.epochs.max()}, "
              f"wait={r.admission_wait} sojourn={r.sojourn} supersteps, "
              f"mean_len={r.lengths.mean():.1f}")

r = svc.poll(b_rids[0])
print("\nfirst walk of tenant B's first request:",
      r.paths[0][: r.lengths[0]])

a = svc.analyze()
print(f"\nservice: {a.walks} walks in {a.supersteps} supersteps, "
      f"bubble_ratio={a.bubble_ratio:.2f}, "
      f"p99_sojourn={a.p99_sojourn:.0f} supersteps, "
      f"p99_admission_wait={a.p99_admission_wait:.0f}")
