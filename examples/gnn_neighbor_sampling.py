"""GNN minibatch training on RidgeWalker-sampled blocks.

The fanout neighbor sampler (graph/sampling_service.py — one-hop bounded
random walks on the stateless-sampling substrate) feeds PNA minibatch
training, the ``minibatch_lg`` regime at CPU scale.

  PYTHONPATH=src python examples/gnn_neighbor_sampling.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.graph import make_dataset
from repro.graph.sampling_service import block_union_graph, sample_blocks
from repro.models.gnn import pna
from repro.optim import adamw

g = make_dataset("WG", scale_override=12)
print(f"graph |V|={g.num_vertices} |E|={g.num_edges}")

D_FEAT, N_CLASSES = 32, 7
rng = np.random.default_rng(0)
feats = jnp.asarray(rng.random((g.num_vertices, D_FEAT), np.float32))
labels = jnp.asarray(rng.integers(0, N_CLASSES, g.num_vertices)
                     .astype(np.int32))

cfg = pna.PNAConfig(n_layers=2, d_hidden=32, node_in=D_FEAT,
                    out_dim=N_CLASSES)
params = pna.init_params(jax.random.PRNGKey(0), cfg)
opt_cfg = adamw.AdamWConfig(lr=1e-3, total_steps=60, warmup_steps=5)
opt = adamw.init_state(params)

BATCH, FANOUTS = 256, (10, 5)


@jax.jit
def step(params, opt, node_ids, edge_index):
    def loss_fn(p):
        batch = {"node_feats": feats[node_ids], "edge_index": edge_index,
                 "labels": labels[node_ids]}
        return pna.train_loss(p, batch, cfg)
    loss, grads = jax.value_and_grad(loss_fn)(params)
    params, opt, _ = adamw.apply_updates(params, grads, opt, opt_cfg)
    return params, opt, loss


for it in range(60):
    seeds = rng.integers(0, g.num_vertices, BATCH).astype(np.int32)
    blocks, all_nodes = sample_blocks(g, jnp.asarray(seeds), FANOUTS,
                                      seed=it)
    # remap global ids -> local block ids for the union graph
    uniq, inv = np.unique(np.asarray(all_nodes), return_inverse=True)
    gid2lid = {int(v): i for i, v in enumerate(uniq)}
    ei = np.asarray(block_union_graph(blocks))
    ei_local = np.vectorize(gid2lid.__getitem__)(ei)
    params, opt, loss = step(params, opt, jnp.asarray(uniq),
                             jnp.asarray(ei_local, dtype=jnp.int32))
    if it % 10 == 0:
        print(f"iter {it:3d} sampled_nodes={uniq.size:5d} "
              f"edges={ei.shape[1]:6d} loss={float(loss):.4f}")
print("done")
