"""End-to-end driver: device-resident walks → DeepWalk embeddings.

One call — ``Walker.train_embeddings`` — runs the whole pipeline: walk
rounds land in the HBM corpus ring, the jitted consumer samples
(center, context, negatives) windows straight out of it, and SGNS grad
steps train donated embedding tables, with round ``r+1``'s walk launch
overlapped with round ``r``'s grad steps.  The paths never visit the
host (pinned by ``repro.core.corpus_ring.no_host_copies``); pass
``--serial`` to time the naive host round-trip wiring instead — the
result is bit-identical either way.

Walker API: docs/api.md · perf methodology: docs/benchmarks.md.

  PYTHONPATH=src python examples/train_deepwalk_embeddings.py \
      --scale 12 --dim 64 --rounds 8 --steps-per-round 48
"""
import argparse
import time

import jax

from repro import walker
from repro.core import corpus_ring
from repro.graph import make_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--steps-per-round", type=int, default=48)
    ap.add_argument("--walks-per-round", type=int, default=4096)
    ap.add_argument("--walk-len", type=int, default=40)
    ap.add_argument("--batch", type=int, default=4096)
    ap.add_argument("--serial", action="store_true",
                    help="naive baseline: host round-trip, no overlap")
    ap.add_argument("--backend", choices=["single", "sharded"],
                    default="single")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_deepwalk")
    args = ap.parse_args()

    g = make_dataset("WG", scale_override=args.scale, weighted=True,
                     with_alias=True)
    print(f"graph |V|={g.num_vertices} |E|={g.num_edges}")

    w = walker.compile(walker.WalkProgram.deepwalk(args.walk_len),
                       backend=args.backend)
    t0 = time.time()
    out = w.train_embeddings(
        g, seed=0, rounds=args.rounds, walks_per_round=args.walks_per_round,
        steps_per_round=args.steps_per_round, batch_size=args.batch,
        dim=args.dim, window=5, num_negatives=5, use_kernel=False,
        overlap=not args.serial, ckpt_dir=args.ckpt_dir,
        ckpt_every=max(16, args.steps_per_round), log_every=16)
    jax.block_until_ready(out["params"]["in_embed"])
    dt = time.time() - t0

    walks = args.rounds * args.walks_per_round
    samples = out["step"] * args.batch
    mode = "serial" if args.serial else "overlapped"
    print(f"{mode}: {walks} walks → {out['step']} grad steps "
          f"({samples / dt:.0f} samples/sec) in {dt:.1f}s; "
          f"path host round-trips so far: {corpus_ring.host_copies()}")
    if out["history"]:
        print("loss trajectory:",
              [f"{h['step']}:{h['loss']:.3f}" for h in out["history"][::3]])
    print(f"tables: in_embed{tuple(out['params']['in_embed'].shape)}; "
          f"checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
