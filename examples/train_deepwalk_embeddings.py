"""End-to-end driver: walk corpus → skip-gram DeepWalk embeddings.

Walker API: docs/api.md · perf methodology: docs/benchmarks.md.

  PYTHONPATH=src python examples/train_deepwalk_embeddings.py \
      --scale 12 --dim 64 --steps 200
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import walker
from repro.graph import make_dataset
from repro.models import embeddings as emb
from repro.optim import adamw
from repro.runtime import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--walks", type=int, default=4000)
    ap.add_argument("--walk-len", type=int, default=40)
    ap.add_argument("--batch", type=int, default=4096)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_deepwalk")
    args = ap.parse_args()

    g = make_dataset("WG", scale_override=args.scale, weighted=True,
                     with_alias=True)
    print(f"graph |V|={g.num_vertices} |E|={g.num_edges}")
    rng = np.random.default_rng(0)
    starts = rng.integers(0, g.num_vertices, args.walks).astype(np.int32)

    t0 = time.time()
    res = walker.compile(
        walker.WalkProgram.deepwalk(args.walk_len),
        execution=walker.ExecutionConfig(num_slots=2048)).run(g, starts)
    paths, lengths = res.as_numpy()
    print(f"walk corpus: {int(res.stats.steps)} steps "
          f"in {time.time()-t0:.1f}s")

    cfg = emb.SkipGramConfig(num_vertices=g.num_vertices, dim=args.dim,
                             num_negatives=5, window=5)
    centers, contexts = emb.pairs_from_walks(paths, lengths, cfg.window, rng,
                                             max_pairs=args.steps * args.batch)
    n_params = 2 * g.num_vertices * args.dim
    print(f"pairs: {centers.size}; model params: {n_params/1e6:.1f}M")

    params = emb.init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = adamw.AdamWConfig(lr=2e-2, weight_decay=0.0,
                                warmup_steps=20, total_steps=args.steps)
    opt_state = adamw.init_state(params)

    @jax.jit
    def step_fn(state, batch):
        params, opt = state
        c, x, n = batch
        loss, grads = jax.value_and_grad(emb.loss_fn)(params, c, x, n)
        params, opt, stats = adamw.apply_updates(params, grads, opt, opt_cfg)
        return (params, opt), {"loss": loss, **stats}

    def batch_fn(step):
        r = np.random.default_rng((1, step))
        i = r.integers(0, centers.size, args.batch)
        negs = r.integers(0, g.num_vertices, (args.batch, 5))
        return (jnp.asarray(centers[i]), jnp.asarray(contexts[i]),
                jnp.asarray(negs))

    loop_cfg = train_loop.TrainLoopConfig(
        total_steps=args.steps, ckpt_dir=args.ckpt_dir,
        ckpt_every=max(50, args.steps // 4), log_every=20)
    state, start = train_loop.resume_or_init(args.ckpt_dir,
                                             (params, opt_state))
    state, step, hist, wd = train_loop.run(step_fn, state, batch_fn,
                                           loop_cfg, start_step=start)
    if hist:
        print("loss trajectory:",
              [f"{h['step']}:{h['loss']:.3f}" for h in hist[::3]])
    print(f"finished at step {step}; stragglers={wd.straggler_steps}; "
          f"checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
