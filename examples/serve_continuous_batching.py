"""LM serving with zero-bubble continuous batching (beyond-paper reuse of
the scheduler: decode lanes = walker lanes, requests = queries).

  PYTHONPATH=src python examples/serve_continuous_batching.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.launch.serve import continuous_batching_loop
from repro.models import transformer as tfm

cfg = dataclasses.replace(get_arch("deepseek_7b").SMOKE, dtype=jnp.float32)
params = tfm.init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)

# variable-priority request stream; all lanes stay busy until drain
reqs = [jnp.asarray(rng.integers(0, cfg.vocab, 8), jnp.int32)
        for _ in range(24)]
t0 = time.time()
results, stats = continuous_batching_loop(params, cfg, reqs, num_slots=6,
                                          max_new=12, cache_cap=24)
print(f"served {stats.completed} requests in {time.time()-t0:.1f}s, "
      f"{stats.decode_steps} batched decode steps, "
      f"bubble_ratio={stats.bubble_ratio:.3f}")
print("sample generation:", results[0])
