"""Quickstart: build a graph, run every GRW algorithm, inspect paths.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import EngineConfig, walks
from repro.core.scheduler import analyze_run
from repro.graph import make_dataset

# Graph500-skewed RMAT stand-in for web-Google (paper Table II).
g = make_dataset("WG", scale_override=12, weighted=True, with_alias=True,
                 num_edge_types=3)
print(f"graph: |V|={g.num_vertices} |E|={g.num_edges} "
      f"max_deg={g.max_degree}")

starts = np.random.default_rng(0).integers(0, g.num_vertices, 2000)
cfg = EngineConfig(num_slots=512, max_hops=80)

for name, run in [
    ("URW", lambda: walks.urw(g, starts, 80, cfg)),
    ("PPR(α=.15)", lambda: walks.ppr(g, starts, 0.15, 80, cfg)),
    ("DeepWalk", lambda: walks.deepwalk(g, starts, 80, cfg)),
    ("Node2Vec(2,.5)", lambda: walks.node2vec(g, starts, 2.0, 0.5, 80,
                                              cfg=cfg)),
    ("MetaPath[0,1,2]", lambda: walks.metapath(g, starts, [0, 1, 2], 80,
                                               cfg)),
]:
    res = run()
    a = analyze_run(res.stats)
    paths, lengths = res.as_numpy()
    print(f"{name:16s} steps={a.steps:7d} supersteps={a.supersteps:5d} "
          f"occupancy={a.occupancy:.2f} mean_len={lengths.mean():.1f}")

paths, lengths = res.as_numpy()
print("\nfirst MetaPath walk:", paths[0][: lengths[0]])
