"""Quickstart: compile one `WalkProgram` per GRW algorithm and run it.

API reference: docs/api.md · execution pipeline: docs/architecture.md.

  PYTHONPATH=src python examples/quickstart.py            # full demo
  PYTHONPATH=src python examples/quickstart.py --scale 10 --queries 300 \
      --max-hops 16                                       # CI-sized smoke
"""
import argparse

import numpy as np

from repro import walker
from repro.core.scheduler import analyze_run
from repro.graph import make_dataset

ap = argparse.ArgumentParser()
ap.add_argument("--scale", type=int, default=12, help="RMAT scale")
ap.add_argument("--queries", type=int, default=2000)
ap.add_argument("--max-hops", type=int, default=80)
ap.add_argument("--slots", type=int, default=512)
ap.add_argument("--step-impl", default="jnp",
                choices=["jnp", "pallas", "fused"],
                help="superstep implementation (fused = device-resident "
                     "multi-hop kernel; off-TPU it runs interpreted)")
ap.add_argument("--hops-per-launch", type=int, default=16,
                help="fused only: supersteps per kernel launch")
args = ap.parse_args()

# Graph500-skewed RMAT stand-in for web-Google (paper Table II).
g = make_dataset("WG", scale_override=args.scale, weighted=True,
                 with_alias=True, num_edge_types=3)
print(f"graph: |V|={g.num_vertices} |E|={g.num_edges} "
      f"max_deg={g.max_degree}")

starts = np.random.default_rng(0).integers(0, g.num_vertices, args.queries)
H = args.max_hops
execution = walker.ExecutionConfig(num_slots=args.slots,
                                   step_impl=args.step_impl,
                                   hops_per_launch=args.hops_per_launch)

programs = [
    ("URW", walker.WalkProgram.urw(H)),
    ("PPR(α=.15)", walker.WalkProgram.ppr(0.15, H)),
    ("DeepWalk", walker.WalkProgram.deepwalk(H)),
    ("Node2Vec(2,.5)", walker.WalkProgram.node2vec(2.0, 0.5, H)),
    ("MetaPath[0,1,2]", walker.WalkProgram.metapath([0, 1, 2], H)),
]

for name, program in programs:
    res = walker.compile(program, execution=execution).run(g, starts)
    a = analyze_run(res.stats)
    paths, lengths = res.as_numpy()
    print(f"{name:16s} steps={a.steps:7d} supersteps={a.supersteps:5d} "
          f"occupancy={a.occupancy:.2f} mean_len={lengths.mean():.1f} "
          f"supersteps/launch={a.supersteps_per_launch:.1f}")

paths, lengths = res.as_numpy()
print("\nfirst MetaPath walk:", paths[0][: lengths[0]])
