"""Cached fused-superstep smoke: the gather hierarchy on a skewed graph.

Runs one fused closed batch twice on a small Graph500-skewed RMAT —
hot-vertex cache off, then on — and asserts the hierarchy's contract:

  * bit-identical paths, lengths, and every pre-existing stat
    (a hit reads the same bytes from VMEM instead of HBM);
  * a nonzero hit rate (the skewed degree distribution concentrates
    gather traffic on hubs the budget admits);
  * zero cache counters when the cache is off.

  PYTHONPATH=src python examples/cached_superstep_smoke.py \
      --scale 8 --queries 96 --max-hops 10 --budget 4096
"""
import argparse

import numpy as np

from repro import walker
from repro.graph import build_csr
from repro.graph.generators import GRAPH500, rmat_edges

ap = argparse.ArgumentParser()
ap.add_argument("--scale", type=int, default=8, help="RMAT scale")
ap.add_argument("--queries", type=int, default=96)
ap.add_argument("--max-hops", type=int, default=10)
ap.add_argument("--slots", type=int, default=64)
ap.add_argument("--hops-per-launch", type=int, default=8)
ap.add_argument("--budget", type=int, default=1 << 12,
                help="hot-vertex cache byte budget (the default covers "
                     "the hubs of the scale-8 fixture but not its tail, "
                     "so both the hit and the miss path run)")
args = ap.parse_args()

edges, n = rmat_edges(args.scale, 8, GRAPH500, seed=2)
g = build_csr(edges, n)
print(f"graph: |V|={g.num_vertices} |E|={g.num_edges} "
      f"max_deg={g.max_degree}")

starts = np.random.default_rng(7).integers(0, n, args.queries)
program = walker.WalkProgram.urw(args.max_hops)


def run(cache_budget):
    ex = walker.ExecutionConfig(num_slots=args.slots, step_impl="fused",
                                hops_per_launch=args.hops_per_launch,
                                cache_budget=cache_budget)
    return walker.compile(program, execution=ex).run(g, starts, seed=0)


off = run(0)
on = run(args.budget)

p_off, l_off = off.as_numpy()
p_on, l_on = on.as_numpy()
assert np.array_equal(p_off, p_on), "cached paths diverged from uncached"
assert np.array_equal(l_off, l_on), "cached lengths diverged from uncached"
for f in off.stats._fields:
    if f in ("launches", "cache_hits", "cache_misses", "cache_coalesced"):
        continue
    assert int(getattr(off.stats, f)) == int(getattr(on.stats, f)), f

hits = int(on.stats.cache_hits)
misses = int(on.stats.cache_misses)
coal = int(on.stats.cache_coalesced)
rate = float(on.stats.cache_hit_rate())
assert hits > 0, "cache served no gathers on the skewed fixture"
assert rate > 0.0
for f in ("cache_hits", "cache_misses", "cache_coalesced"):
    assert int(getattr(off.stats, f)) == 0, f

print(f"cache-off == cache-on: paths/lengths/stats bit-identical over "
      f"{args.queries} walks")
print(f"cache: hits={hits} misses={misses} coalesced={coal} "
      f"hit_rate={rate:.3f} budget={args.budget}B")
print("OK")
