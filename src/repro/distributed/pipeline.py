"""Pipeline parallelism (GPipe schedule) via shard_map + ppermute.

For models beyond single-pod HBM, an optional `pipe` mesh axis splits the
layer stack into stages; microbatches stream through with collective
permutes between stages.  Bubble fraction = (P-1)/(M+P-1) — the classic
GPipe result; the launcher picks M >= 4·P so the bubble stays under 20%.

This is demonstrated/tested at small scale (8 host devices) and available
as a config knob; the 16×16 production mesh fits all assigned archs
without PP (see EXPERIMENTS.md §Dry-run memory numbers).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.compat import shard_map


def pipeline_apply(stage_fn: Callable, params_stacked, x_microbatches,
                   mesh, axis: str = "pipe"):
    """Run x through P stages living on the `pipe` axis.

    stage_fn(stage_params, x) -> x  (one stage's compute)
    params_stacked: pytree with leading stage axis (P, ...)
    x_microbatches: (M, mb, ...) microbatched input.
    Returns (M, mb, ...) outputs (after all P stages).
    """
    n_stages = mesh.shape[axis]

    def body(stage_params, xs):
        stage_params = jax.tree.map(lambda a: a[0], stage_params)
        rank = jax.lax.axis_index(axis)
        M = xs.shape[0]
        T = M + n_stages - 1
        buf = jnp.zeros_like(xs[0])
        outs = jnp.zeros_like(xs)

        def step(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (if any); others use the
            # permuted activation from the previous stage.
            mb_idx = jnp.clip(t, 0, M - 1)
            x_in = jnp.where(rank == 0,
                             xs[mb_idx],
                             buf)
            y = stage_fn(stage_params, x_in)
            # forward to the next stage (ring shift by +1)
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf = jax.lax.ppermute(y, axis, perm)
            # last stage emits microbatch t - (P-1)
            out_idx = jnp.clip(t - (n_stages - 1), 0, M - 1)
            emit = (t >= n_stages - 1) & (rank == n_stages - 1)
            outs = jnp.where(emit,
                             outs.at[out_idx].set(y),
                             outs)
            return buf, outs

        buf, outs = jax.lax.fori_loop(0, T, step, (buf, outs))
        return outs[None]

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(axis), P(None)),
                   out_specs=P(axis), check_vma=False)
    outs = fn(params_stacked, x_microbatches)
    # every stage returns a buffer; only the last stage's is valid
    return outs[-1]


def gpipe_bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
