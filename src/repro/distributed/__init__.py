from repro.distributed import pipeline
