"""JAX version compatibility shims for the distributed engines."""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
    """`jax.shard_map` across jax versions.

    jax >= 0.6 exposes ``jax.shard_map(..., check_vma=...)``; on the 0.4.x
    line pinned in requirements.txt the API lives at
    ``jax.experimental.shard_map.shard_map`` and the replication-check
    kwarg is spelled ``check_rep``.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
