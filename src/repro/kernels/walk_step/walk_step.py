"""Fused walk-step Pallas TPU kernel — the paper's asynchronous pipeline
(§V-B) as one kernel: Row Access → Sampling → Column Access.

TPU adaptation of the asynchronous memory-access engine:
  * ``row_ptr`` / ``col`` (and alias tables) live in HBM (`pl.ANY`); the
    kernel issues **double-buffered async DMAs** per task — the copy for
    task *i+1* is in flight while task *i* is processed, which is exactly
    the paper's non-blocking outstanding-request scheme (scaled to the
    DMA depth Pallas exposes; the FPGA engine keeps 128 in flight, a TPU
    core hides latency with the same overlap via its DMA queues).
  * Row access loads ``row_ptr[v]`` and ``row_ptr[v+1]`` in ONE 2-element
    DMA (the paper's RP_entry packs address+degree in one word).
  * Sampling arithmetic (uniform or alias) runs on scalars in SMEM between
    the two gather stages, so intermediates never round-trip to HBM.
  * Task words (v_curr, uniforms) are staged in SMEM — the analogue of the
    single-pipeline-word task tuple of §V-A.

Grid: one program per tile of ``TILE`` walker lanes; lanes are fully
independent (stateless tasks), so tiles can execute in any order.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import ScheduleBuilder


def dma_schedule(kind: str = "uniform", n: int = 3):
    """Declarative DMA schedule of the one-hop walk-step kernel, for the
    static hazard analyzer (`repro.analysis.dma_hazards`).

    Mirrors the kernel bodies below op-for-op: the uniform kernel runs
    the row-access pair gather then the column gather; the alias kernel
    adds the prob/alias probe loops between them.  ``n`` lanes of unroll
    (≥ 3 covers both slot parities of the double buffer plus prologue
    and drain — the pipelines are period-2 in the slot cycle).  Keep in
    sync with `walk_step_uniform_kernel` / `walk_step_alias_kernel`.
    """
    b = ScheduleBuilder()
    b.gather_loop("rpbuf", n)            # row_access_loop
    if kind == "alias":
        b.gather_loop("probbuf", n)      # accept-probability probes
        b.gather_loop("aliasbuf", n)     # alias-index probes
    b.gather_loop("colbuf", n)           # column access
    return b.ops


def row_access_loop(n, v_fn, rp_ref, rpbuf, rpsem, num_vertices, on_result):
    """Double-buffered 2-element DMA loop over lanes: rpbuf[slot] gets
    (row_ptr[v], row_ptr[v+1]) for v = v_fn(i) — the paper's packed
    RP_entry, with lane i+1's fetch in flight while lane i is consumed.
    Calls on_result(i, addr, deg).  Shared with the fused superstep
    kernel (`kernels/fused_superstep`)."""

    def copy(i, slot):
        vv = jnp.clip(v_fn(i), 0, num_vertices - 1)
        return pltpu.make_async_copy(rp_ref.at[pl.ds(vv, 2)],
                                     rpbuf.at[slot], rpsem.at[slot])

    def body(i, _):
        slot = jax.lax.rem(i, 2)

        @pl.when(i + 1 < n)
        def _():
            copy(i + 1, jax.lax.rem(i + 1, 2)).start()

        copy(i, slot).wait()
        addr = rpbuf[slot, 0]
        deg = rpbuf[slot, 1] - rpbuf[slot, 0]
        on_result(i, addr, deg)
        return 0

    copy(0, 0).start()
    jax.lax.fori_loop(0, n, body, 0, unroll=False)


def gather2_loop(n, src_fn, buf, sem, on_result):
    """Double-buffered 2-element DMA gather: buf[slot] gets the packed
    word pair at ``src_fn(i)`` (a 2-element ref slice — an RP_entry or a
    ``type_offsets[v, t:t+2]`` sub-segment bound), with item i+1's fetch
    in flight while item i is consumed.  Calls on_result(i, first,
    second).  Shared with the fused superstep kernel
    (`kernels/fused_superstep`)."""

    def copy(i, slot):
        return pltpu.make_async_copy(src_fn(i), buf.at[slot], sem.at[slot])

    def body(i, _):
        slot = jax.lax.rem(i, 2)

        @pl.when(i + 1 < n)
        def _():
            copy(i + 1, jax.lax.rem(i + 1, 2)).start()

        copy(i, slot).wait()
        on_result(i, buf[slot, 0], buf[slot, 1])
        return 0

    copy(0, 0).start()
    jax.lax.fori_loop(0, n, body, 0, unroll=False)


def gather1_loop(n, e_fn, src_ref, buf, sem, num_entries, on_result):
    """Double-buffered 1-element DMA gather: buf[slot] = src[e_fn(i)].
    Shared with the fused superstep kernel."""

    def copy(i, slot):
        e = jnp.clip(e_fn(i), 0, num_entries - 1)
        return pltpu.make_async_copy(src_ref.at[pl.ds(e, 1)],
                                     buf.at[slot], sem.at[slot])

    def body(i, _):
        slot = jax.lax.rem(i, 2)

        @pl.when(i + 1 < n)
        def _():
            copy(i + 1, jax.lax.rem(i + 1, 2)).start()

        copy(i, slot).wait()
        on_result(i, buf[slot, 0])
        return 0

    copy(0, 0).start()
    jax.lax.fori_loop(0, n, body, 0, unroll=False)


def cached_gather1_loop(n, e_fn, src_ref, buf, sem, num_entries, on_result,
                        *, cache_ref=None, cache_len=1, cache_e_fn=None,
                        hit_fn=None):
    """Skew-aware variant of :func:`gather1_loop`: items whose lane holds
    a hot vertex (``hit_fn(i)``) are served straight from the VMEM cache
    block ``cache_ref`` (no copy, no wait — the same bytes at
    ``cache_e_fn(i)``), while misses run the standard double-buffered HBM
    loop.  Both the prefetch for item i+1 and the wait for item i are
    predicated on that item actually missing, so a fully-hit pass issues
    zero DMAs; results are bit-identical either way because the cache
    packs verbatim CSR slices.  With ``hit_fn=None`` (cache off) this IS
    `gather1_loop` — the uncached kernel trace is unchanged."""
    if hit_fn is None or cache_ref is None:
        return gather1_loop(n, e_fn, src_ref, buf, sem, num_entries,
                            on_result)
    cache_e_fn = cache_e_fn or e_fn

    def hit(i):
        # Lookahead may probe index n; clamp — the predicate it feeds is
        # already false there.
        return hit_fn(jnp.minimum(i, n - 1))

    def copy(i, slot):
        e = jnp.clip(e_fn(i), 0, num_entries - 1)
        return pltpu.make_async_copy(src_ref.at[pl.ds(e, 1)],
                                     buf.at[slot], sem.at[slot])

    def body(i, _):
        slot = jax.lax.rem(i, 2)
        h = hit_fn(i)

        @pl.when((i + 1 < n) & jnp.logical_not(hit(i + 1)))
        def _():
            copy(i + 1, jax.lax.rem(i + 1, 2)).start()

        @pl.when(jnp.logical_not(h))
        def _():
            copy(i, slot).wait()

        ce = jnp.clip(cache_e_fn(i), 0, cache_len - 1)
        # Hit lanes never started a copy: buf holds a stale value the
        # where() discards.
        on_result(i, jnp.where(h, cache_ref[ce], buf[slot, 0]))
        return 0

    @pl.when(jnp.logical_not(hit_fn(0)))
    def _():
        copy(0, 0).start()

    jax.lax.fori_loop(0, n, body, 0, unroll=False)


def cached_gather2_loop(n, src_fn, buf, sem, on_result, *, hit_fn=None,
                        hit_pair_fn=None):
    """Skew-aware variant of :func:`gather2_loop`: hit items take their
    word pair from ``hit_pair_fn(i)`` (a VMEM cache read) instead of the
    DMA staging buffer, with the same miss-predicated prefetch/wait
    structure as :func:`cached_gather1_loop`.  ``hit_fn=None`` falls back
    to the plain loop."""
    if hit_fn is None or hit_pair_fn is None:
        return gather2_loop(n, src_fn, buf, sem, on_result)

    def copy(i, slot):
        return pltpu.make_async_copy(src_fn(i), buf.at[slot], sem.at[slot])

    def body(i, _):
        slot = jax.lax.rem(i, 2)
        h = hit_fn(i)

        @pl.when((i + 1 < n) & jnp.logical_not(
            hit_fn(jnp.minimum(i + 1, n - 1))))
        def _():
            copy(i + 1, jax.lax.rem(i + 1, 2)).start()

        @pl.when(jnp.logical_not(h))
        def _():
            copy(i, slot).wait()

        ca, cb = hit_pair_fn(i)
        on_result(i, jnp.where(h, ca, buf[slot, 0]),
                  jnp.where(h, cb, buf[slot, 1]))
        return 0

    @pl.when(jnp.logical_not(hit_fn(0)))
    def _():
        copy(0, 0).start()

    jax.lax.fori_loop(0, n, body, 0, unroll=False)


def _uniform_index(deg, u):
    idx = jnp.floor(u * deg.astype(jnp.float32)).astype(jnp.int32)
    return jnp.clip(idx, 0, jnp.maximum(deg - 1, 0))


def walk_step_uniform_kernel(num_vertices, num_edges,
                             v_ref, ucol_ref,          # SMEM tiles
                             rp_ref, col_ref,          # ANY (HBM)
                             vnext_ref, deg_ref,       # SMEM outputs
                             addr_scr, idx_scr, rpbuf, colbuf,
                             rpsem, colsem):
    n = v_ref.shape[0]

    def on_row(i, addr, deg):
        addr_scr[i] = addr
        deg_ref[i] = deg
        idx_scr[i] = addr + _uniform_index(deg, ucol_ref[i])

    row_access_loop(n, lambda i: v_ref[i], rp_ref, rpbuf, rpsem,
                    num_vertices, on_row)

    def on_col(i, v):
        vnext_ref[i] = jnp.where(deg_ref[i] > 0, v, -1)

    gather1_loop(n, lambda i: idx_scr[i], col_ref, colbuf, colsem, num_edges, on_col)


def walk_step_alias_kernel(num_vertices, num_edges,
                           v_ref, ucol_ref, uacc_ref,
                           rp_ref, col_ref, prob_ref, alias_ref,
                           vnext_ref, deg_ref,
                           addr_scr, k_scr, idx_scr,
                           rpbuf, probbuf, aliasbuf, colbuf,
                           rpsem, probsem, aliassem, colsem):
    """Alias-table variant (DeepWalk): column draw k, accept test against
    prob[addr+k], fall back to alias[addr+k]. Two extra gathers."""
    n = v_ref.shape[0]

    def on_row(i, addr, deg):
        addr_scr[i] = addr
        deg_ref[i] = deg
        k_scr[i] = addr + _uniform_index(deg, ucol_ref[i])

    row_access_loop(n, lambda i: v_ref[i], rp_ref, rpbuf, rpsem,
                    num_vertices, on_row)

    def on_prob(i, p):
        # accept -> keep k; reject -> need alias[addr+k] (resolved below)
        idx_scr[i] = jnp.where(uacc_ref[i] < p, k_scr[i], -1)

    gather1_loop(n, lambda i: k_scr[i], prob_ref, probbuf, probsem, num_edges, on_prob)

    def on_alias(i, a):
        addr = addr_scr[i]
        take_alias = idx_scr[i] < 0
        idx_scr[i] = jnp.where(take_alias, addr + a, idx_scr[i])

    gather1_loop(n, lambda i: k_scr[i], alias_ref, aliasbuf, aliassem, num_edges, on_alias)

    def on_col(i, v):
        vnext_ref[i] = jnp.where(deg_ref[i] > 0, v, -1)

    gather1_loop(n, lambda i: idx_scr[i], col_ref, colbuf, colsem, num_edges, on_col)


def _smem_tile(tile):
    return pl.BlockSpec((tile,), lambda t: (t,), memory_space=pltpu.SMEM)


def walk_step_uniform(v_curr, u_col, row_ptr, col, *, tile: int = 256,
                      interpret: bool = True):
    """pallas_call wrapper: (v_next, deg) for a batch of walker lanes."""
    W = v_curr.shape[0]
    tile = min(tile, W)
    assert W % tile == 0, (W, tile)
    nv = row_ptr.shape[0] - 1
    ne = col.shape[0]
    kernel = functools.partial(walk_step_uniform_kernel, nv, ne)
    return pl.pallas_call(
        kernel,
        grid=(W // tile,),
        in_specs=[_smem_tile(tile), _smem_tile(tile),
                  pl.BlockSpec(memory_space=pl.ANY),
                  pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=[_smem_tile(tile), _smem_tile(tile)],
        out_shape=[jax.ShapeDtypeStruct((W,), jnp.int32),
                   jax.ShapeDtypeStruct((W,), jnp.int32)],
        scratch_shapes=[pltpu.SMEM((tile,), jnp.int32),
                        pltpu.SMEM((tile,), jnp.int32),
                        pltpu.SMEM((2, 2), jnp.int32),
                        pltpu.SMEM((2, 1), jnp.int32),
                        pltpu.SemaphoreType.DMA((2,)),
                        pltpu.SemaphoreType.DMA((2,))],
        interpret=interpret,
    )(v_curr, u_col, row_ptr, col)


def walk_step_alias(v_curr, u_col, u_acc, row_ptr, col, alias_prob, alias_idx,
                    *, tile: int = 256, interpret: bool = True):
    W = v_curr.shape[0]
    tile = min(tile, W)
    assert W % tile == 0, (W, tile)
    nv = row_ptr.shape[0] - 1
    ne = col.shape[0]
    kernel = functools.partial(walk_step_alias_kernel, nv, ne)
    return pl.pallas_call(
        kernel,
        grid=(W // tile,),
        in_specs=[_smem_tile(tile), _smem_tile(tile), _smem_tile(tile),
                  pl.BlockSpec(memory_space=pl.ANY),
                  pl.BlockSpec(memory_space=pl.ANY),
                  pl.BlockSpec(memory_space=pl.ANY),
                  pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=[_smem_tile(tile), _smem_tile(tile)],
        out_shape=[jax.ShapeDtypeStruct((W,), jnp.int32),
                   jax.ShapeDtypeStruct((W,), jnp.int32)],
        scratch_shapes=[pltpu.SMEM((tile,), jnp.int32),
                        pltpu.SMEM((tile,), jnp.int32),
                        pltpu.SMEM((tile,), jnp.int32),
                        pltpu.SMEM((2, 2), jnp.int32),
                        pltpu.SMEM((2, 1), jnp.float32),
                        pltpu.SMEM((2, 1), jnp.int32),
                        pltpu.SMEM((2, 1), jnp.int32),
                        pltpu.SemaphoreType.DMA((2,)),
                        pltpu.SemaphoreType.DMA((2,)),
                        pltpu.SemaphoreType.DMA((2,)),
                        pltpu.SemaphoreType.DMA((2,))],
        interpret=interpret,
    )(v_curr, u_col, u_acc, row_ptr, col, alias_prob, alias_idx)
