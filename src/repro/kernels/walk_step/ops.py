"""Jitted public wrappers for the fused walk-step kernel.

Pads the lane count to a tile multiple, dispatches to the Pallas kernel,
and exposes a jnp fallback for platforms without Pallas.  ``interpret``
defaults to ``jax.default_backend() != "tpu"``: the kernel compiles on a
real TPU and interprets its body elsewhere (CPU CI) — override per call
to force either.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.walk_step import ref as _ref, walk_step as _k


def _pad_to(x, n, fill):
    w = x.shape[0]
    if w == n:
        return x
    return jnp.concatenate([x, jnp.full((n - w,), fill, x.dtype)])


@partial(jax.jit, static_argnames=("tile", "interpret", "use_kernel"))
def walk_step_uniform(v_curr, u_col, row_ptr, col, tile: int = 256,
                      interpret: bool | None = None, use_kernel: bool = True):
    interpret = default_interpret(interpret)
    if not use_kernel:
        return _ref.walk_step_uniform_ref(v_curr, u_col, row_ptr, col)
    W = v_curr.shape[0]
    t = min(tile, W)
    Wp = -(-W // t) * t
    vn, dg = _k.walk_step_uniform(
        _pad_to(v_curr, Wp, 0), _pad_to(u_col, Wp, 0.0), row_ptr, col,
        tile=t, interpret=interpret)
    return vn[:W], dg[:W]


@partial(jax.jit, static_argnames=("tile", "interpret", "use_kernel"))
def walk_step_alias(v_curr, u_col, u_acc, row_ptr, col, alias_prob, alias_idx,
                    tile: int = 256, interpret: bool | None = None,
                    use_kernel: bool = True):
    interpret = default_interpret(interpret)
    if not use_kernel:
        return _ref.walk_step_alias_ref(v_curr, u_col, u_acc, row_ptr, col,
                                        alias_prob, alias_idx)
    W = v_curr.shape[0]
    t = min(tile, W)
    Wp = -(-W // t) * t
    vn, dg = _k.walk_step_alias(
        _pad_to(v_curr, Wp, 0), _pad_to(u_col, Wp, 0.0),
        _pad_to(u_acc, Wp, 0.0), row_ptr, col, alias_prob, alias_idx,
        tile=t, interpret=interpret)
    return vn[:W], dg[:W]
