from repro.kernels.walk_step.ops import walk_step_alias, walk_step_uniform
