from repro.kernels.walk_step.ops import walk_step_uniform, walk_step_alias
