"""Pure-jnp oracle for the fused walk-step kernel."""
from __future__ import annotations

import jax.numpy as jnp


def _uniform_index(deg, u):
    idx = jnp.floor(u * deg.astype(jnp.float32)).astype(jnp.int32)
    return jnp.clip(idx, 0, jnp.maximum(deg - 1, 0))


def walk_step_uniform_ref(v_curr, u_col, row_ptr, col):
    nv = row_ptr.shape[0] - 1
    v = jnp.clip(v_curr, 0, nv - 1)
    addr = row_ptr[v]
    deg = row_ptr[v + 1] - addr
    idx = _uniform_index(deg, u_col)
    e = jnp.clip(addr + idx, 0, col.shape[0] - 1)
    v_next = jnp.where(deg > 0, col[e], -1)
    return v_next, deg


def walk_step_alias_ref(v_curr, u_col, u_acc, row_ptr, col, alias_prob,
                        alias_idx):
    nv = row_ptr.shape[0] - 1
    v = jnp.clip(v_curr, 0, nv - 1)
    addr = row_ptr[v]
    deg = row_ptr[v + 1] - addr
    k = _uniform_index(deg, u_col)
    ek = jnp.clip(addr + k, 0, col.shape[0] - 1)
    accept = u_acc < alias_prob[ek]
    idx = jnp.where(accept, k, alias_idx[ek])
    e = jnp.clip(addr + idx, 0, col.shape[0] - 1)
    v_next = jnp.where(deg > 0, col[e], -1)
    return v_next, deg
