"""Device-resident fused superstep — the paper's *perfectly pipelined*
walker as ONE Pallas kernel (§V–VI), ``step_impl="fused"``.

The per-hop impls (``jnp`` / ``pallas``) fuse at most one pipeline pass
(Row Access → Sampling → Column Access) and bounce the entire lane pool,
RNG key folds, stop draws, termination, path scatter, and refill through
an XLA superstep on every hop — the launch-and-drain pattern
statically-scheduled designs (FastRW/LightRW) suffer from.  This kernel
instead keeps the whole machine resident on the device across ``k``
supersteps per launch:

  * **WalkerSlots + queue counters + stats stay in SMEM** for the entire
    launch (the paper's single-pipeline-word task tuples, §V-A); the
    staged query ring (order / start / epoch by slot id) is SMEM-resident
    too, so zero-bubble refill is pure scalar work.
  * **In-kernel ThundeRiNG analogue**: per-task uniforms are derived on
    SMEM scalars via the shared :func:`repro.core.rng.threefry2x32` —
    the same fold chain as the jnp path, so draws are bit-identical and
    no random bits ever touch HBM (§VII).
  * **Graph gathers stay asynchronous**: row access / column access /
    alias-table probes issue the same double-buffered one-and-two-element
    DMAs as `kernels/walk_step`, overlapping lane *i+1*'s fetch with lane
    *i*'s sampling arithmetic (§V-B).
  * **Async write-back**: only the per-hop path records stream out to the
    HBM-resident path buffer (one-element DMA per advanced lane — the
    paper's §IV-B streaming-window write-back); ``done``/``lengths`` ride
    home once per launch with the SMEM state.
  * **In-kernel termination + zero-bubble refill**: the PPR stop draw,
    hop budget, dead-end detection, prefix-sum lane compaction, and the
    Theorem VI.1 staging controller all run between hops without leaving
    the kernel.

Host↔device traffic per launch therefore drops from O(k · state) (per-hop
superstep bouncing) to one state round-trip, and ``stats.launches`` counts
1 per ``k`` supersteps instead of 1 per superstep — the fusion factor
``supersteps / launches`` that `WalkStats.supersteps_per_launch` reports.

Semantics are pinned bit-identical to the jnp superstep
(`core/walk_engine.py`) for uniform and alias samplers, including PPR
stop draws, both scheduling modes, and the open-system ring economy —
``tests/test_fused_step.py``.  Layout note: slot state is (W,) and the
query ring (Q,) in SMEM, which assumes the modest W/Q of a single core's
lane pool; the HBM-resident buffers (graph CSR, alias tables, paths) are
unbounded.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import rng
from repro.core.samplers import SALT_COLUMN, SALT_STOP, _uniform_index
from repro.core.tasks import WalkStats
from repro.kernels.walk_step.walk_step import gather1_loop, row_access_loop

# WalkStats slot indices inside the SMEM stats vector.
STAT = {f: i for i, f in enumerate(WalkStats._fields)}
NUM_STATS = len(WalkStats._fields)


def fused_superstep_kernel(
        # ---- static configuration (bound via functools.partial) ----
        num_vertices, num_edges, W, Q, max_hops, depth, delay,
        stop_prob, alias, static_mode, record_paths,
        # ---- inputs ----
        key_ref, ctl_ref,
        vcur_in, vprev_in, qid_in, hop_in, act_in, ep_in,
        qctr_in, hist_in, stats_in, done_in, len_in,
        qstart_ref, qorder_ref, qepoch_ref,
        rp_ref, col_ref, prob_ref, alias_ref, paths_in,
        # ---- outputs ----
        vcur, vprev, qid_o, hop_o, act, ep_o,
        qctr, hist, stats, done, len_o, paths,
        # ---- scratch ----
        stop_scr, u0_scr, u1_scr, addr_scr, deg_scr, idx_scr, vnext_scr,
        term_scr,
        rpbuf, rpsem, colbuf, colsem, probbuf, probsem, aliasbuf, aliassem,
        wbuf, wsem, wmeta, wcnt):
    del paths_in  # aliased with `paths` (input_output_aliases)
    k0 = key_ref[0]
    k1 = key_ref[1]
    wcnt[0] = 0

    def path_write(q, h, v):
        """Async double-buffered single-record path write-back: start the
        HBM store for this record and only wait when its staging slot is
        needed again two writes later — lane i+1's sampling overlaps lane
        i's store, like the row/column gathers."""
        c = wcnt[0]
        slot = jax.lax.rem(c, 2)

        @pl.when(c >= 2)
        def _():  # reclaim the slot: drain its in-flight store
            pltpu.make_async_copy(
                wbuf.at[slot],
                paths.at[wmeta[slot, 0], pl.ds(wmeta[slot, 1], 1)],
                wsem.at[slot]).wait()

        wbuf[slot, 0] = v
        wmeta[slot, 0] = q
        wmeta[slot, 1] = h
        pltpu.make_async_copy(wbuf.at[slot], paths.at[q, pl.ds(h, 1)],
                              wsem.at[slot]).start()
        wcnt[0] = c + 1

    # ---- bring the launch-resident state into the output refs ----------
    def cp_w(i, _):
        vcur[i] = vcur_in[i]
        vprev[i] = vprev_in[i]
        qid_o[i] = qid_in[i]
        hop_o[i] = hop_in[i]
        act[i] = act_in[i]
        ep_o[i] = ep_in[i]
        return 0

    jax.lax.fori_loop(0, W, cp_w, 0)

    def cp_q(i, _):
        done[i] = done_in[i]
        if record_paths:
            len_o[i] = len_in[i]
        return 0

    jax.lax.fori_loop(0, Q, cp_q, 0)
    if not record_paths:
        len_o[0] = len_in[0]
    for i in range(3):
        qctr[i] = qctr_in[i]
    for i in range(delay + 1):
        hist[i] = hist_in[i]
    for i in range(NUM_STATS):
        stats[i] = stats_in[i]
    stats[STAT["launches"]] = stats[STAT["launches"]] + 1

    # ---- one superstep (bit-identical to walk_engine._superstep) -------
    def superstep(_s, carry):
        head = qctr[0]
        tail = qctr[2]
        n_active = jax.lax.fori_loop(0, W, lambda i, a: a + act[i],
                                     jnp.int32(0))
        work = (head < tail) | (n_active > 0)

        @pl.when(work)
        def _():
            # -- per-lane stop draw + sampling uniforms (in-kernel RNG) --
            def lane_rng(i, _):
                q = qid_o[i]
                h = hop_o[i]
                e = ep_o[i]
                if stop_prob > 0.0:
                    s0, s1 = rng.task_key_pair(k0, k1, q, h, SALT_STOP, e)
                    b0, _b1 = rng.threefry2x32(s0, s1, jnp.uint32(0),
                                               jnp.uint32(0))
                    u = rng.bits_to_uniform(b0)
                    stop_scr[i] = ((act[i] == 1)
                                   & (u < stop_prob)).astype(jnp.int32)
                else:
                    stop_scr[i] = 0
                c0, c1 = rng.task_key_pair(k0, k1, q, h, SALT_COLUMN, e)
                if alias:
                    y0, y1 = rng.threefry2x32(c0, c1, jnp.uint32(0),
                                              jnp.uint32(1))
                    u0_scr[i] = rng.bits_to_uniform(y0)
                    u1_scr[i] = rng.bits_to_uniform(y1)
                else:
                    y0, _y1 = rng.threefry2x32(c0, c1, jnp.uint32(0),
                                               jnp.uint32(0))
                    u0_scr[i] = rng.bits_to_uniform(y0)
                return 0

            jax.lax.fori_loop(0, W, lane_rng, 0)

            # -- Row Access: packed (addr, deg) DMA per lane -------------
            def on_row(i, addr, deg):
                v = vcur[i]
                addr_scr[i] = addr
                deg_scr[i] = jnp.where((v >= 0) & (v < num_vertices), deg, 0)

            row_access_loop(W, lambda i: vcur[i], rp_ref, rpbuf, rpsem,
                            num_vertices, on_row)

            # -- Sampling: column draw (+ alias accept probes) -----------
            def pick(i):
                return jnp.clip(
                    addr_scr[i] + _uniform_index(deg_scr[i], u0_scr[i]),
                    0, num_edges - 1)

            if alias:
                def on_prob(i, p):
                    # accept -> keep draw; reject -> resolved by alias probe
                    idx_scr[i] = jnp.where(u1_scr[i] < p, 0, -1)

                gather1_loop(W, pick, prob_ref, probbuf, probsem,
                             num_edges, on_prob)

                def on_alias(i, a):
                    deg = deg_scr[i]
                    kdraw = _uniform_index(deg, u0_scr[i])
                    j = jnp.where(idx_scr[i] < 0, a, kdraw)
                    j = jnp.clip(j, 0, jnp.maximum(deg - 1, 0))
                    idx_scr[i] = jnp.clip(addr_scr[i] + j, 0, num_edges - 1)

                gather1_loop(W, pick, alias_ref, aliasbuf, aliassem,
                             num_edges, on_alias)
            else:
                def set_idx(i, _):
                    idx_scr[i] = pick(i)
                    return 0

                jax.lax.fori_loop(0, W, set_idx, 0)

            # -- Column Access -------------------------------------------
            def on_col(i, v):
                vnext_scr[i] = v

            gather1_loop(W, lambda i: idx_scr[i], col_ref, colbuf, colsem,
                         num_edges, on_col)

            # -- terminate + advance + async path/done write-back --------
            def lane_update(i, acc):
                steps_acc, term_acc = acc
                A = act[i] == 1
                stop = stop_scr[i] == 1
                ok = deg_scr[i] > 0
                adv = A & (~stop) & ok
                dead = A & (~stop) & (~ok)
                nh = jnp.where(adv, hop_o[i] + 1, hop_o[i])
                term = stop | dead | (adv & (nh >= max_hops))
                term_scr[i] = term.astype(jnp.int32)
                q = qid_o[i]
                vprev[i] = jnp.where(adv, vcur[i], vprev[i])
                vcur[i] = jnp.where(adv, vnext_scr[i], vcur[i])
                hop_o[i] = nh

                if record_paths:
                    @pl.when(adv)
                    def _():
                        len_o[q] = nh + 1
                        path_write(q, nh, vnext_scr[i])

                @pl.when(term & A)
                def _():
                    done[q] = 1

                return (steps_acc + adv.astype(jnp.int32),
                        term_acc + (term & A).astype(jnp.int32))

            n_steps, n_term = jax.lax.fori_loop(
                0, W, lane_update, (jnp.int32(0), jnp.int32(0)))

            # -- stats (same accounting as the jnp superstep) ------------
            idle = W - n_active
            upstream = (head < tail).astype(jnp.int32)
            stats[STAT["steps"]] = stats[STAT["steps"]] + n_steps
            stats[STAT["slot_steps"]] = stats[STAT["slot_steps"]] + W
            stats[STAT["bubbles"]] = stats[STAT["bubbles"]] + idle
            stats[STAT["starved"]] = stats[STAT["starved"]] + idle * upstream
            stats[STAT["terminations"]] = (stats[STAT["terminations"]]
                                           + n_term)
            stats[STAT["supersteps"]] = stats[STAT["supersteps"]] + 1

            # -- staging controller (Theorem VI.1, delayed observation) --
            for j in range(delay):
                hist[j] = hist[j + 1]
            hist[delay] = head
            staged = jnp.maximum(qctr[1],
                                 jnp.minimum(hist[0] + depth, tail))
            qctr[1] = staged

            # -- zero-bubble prefix-sum refill from the order ring -------
            if static_mode:
                all_free = jax.lax.fori_loop(
                    0, W,
                    lambda i, a: a & ((act[i] == 0) | (term_scr[i] == 1)),
                    True)
            avail = jnp.maximum(staged - head, 0)

            def lane_refill(i, acc):
                rank, taken = acc
                free = (act[i] == 0) | (term_scr[i] == 1)
                if static_mode:
                    free = free & all_free
                take = free & (rank < avail)

                @pl.when(take)
                def _():
                    pos = jax.lax.rem(head + rank, Q)
                    nq = qorder_ref[pos]
                    start = qstart_ref[nq]
                    vcur[i] = start
                    vprev[i] = -1
                    qid_o[i] = nq
                    hop_o[i] = 0
                    act[i] = 1
                    ep_o[i] = qepoch_ref[nq]
                    if record_paths:
                        len_o[nq] = 1
                        path_write(nq, 0, start)

                @pl.when((~take) & (term_scr[i] == 1))
                def _():
                    qid_o[i] = -1
                    act[i] = 0

                return (rank + free.astype(jnp.int32),
                        taken + take.astype(jnp.int32))

            _, n_taken = jax.lax.fori_loop(
                0, W, lane_refill, (jnp.int32(0), jnp.int32(0)))
            qctr[0] = head + n_taken

        return carry

    jax.lax.fori_loop(0, ctl_ref[0], superstep, 0)

    if record_paths:
        # Drain the (at most two) in-flight path stores before the launch
        # returns its state.
        c = wcnt[0]
        for back in (2, 1):
            @pl.when(c >= back)
            def _(back=back):
                slot = jax.lax.rem(c - back, 2)
                pltpu.make_async_copy(
                    wbuf.at[slot],
                    paths.at[wmeta[slot, 0], pl.ds(wmeta[slot, 1], 1)],
                    wsem.at[slot]).wait()
