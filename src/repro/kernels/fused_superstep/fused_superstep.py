"""Device-resident fused superstep — the paper's *perfectly pipelined*
walker as ONE Pallas kernel (§V–VI), ``step_impl="fused"``.

The per-hop impls (``jnp`` / ``pallas``) fuse at most one pipeline pass
(Row Access → Sampling → Column Access) and bounce the entire lane pool,
RNG key folds, stop draws, termination, path scatter, and refill through
an XLA superstep on every hop — the launch-and-drain pattern
statically-scheduled designs (FastRW/LightRW) suffer from.  This kernel
instead keeps the whole machine resident on the device across ``k``
supersteps per launch:

  * **WalkerSlots + queue counters + stats stay in SMEM** for the entire
    launch (the paper's single-pipeline-word task tuples, §V-A); the
    staged query ring (order / start / epoch by slot id) is SMEM-resident
    too, so zero-bubble refill is pure scalar work.
  * **In-kernel ThundeRiNG analogue**: per-task uniforms are derived on
    SMEM scalars via the shared :func:`repro.core.rng.threefry2x32` —
    the same fold chain as the jnp path, so draws are bit-identical and
    no random bits ever touch HBM (§VII).
  * **Graph gathers stay asynchronous**: row access / column access /
    alias-table probes issue the same double-buffered one-and-two-element
    DMAs as `kernels/walk_step`, overlapping lane *i+1*'s fetch with lane
    *i*'s sampling arithmetic (§V-B).
  * **Async write-back**: only the per-hop path records stream out to the
    HBM-resident path buffer through a two-slot staging buffer whose
    outbound copies stay in flight across records — a slot is reclaimed
    by waiting its two-records-old store, and both slots drain at the end
    of the launch (the paper's §IV-B streaming-window write-back);
    ``done``/``lengths`` ride home once per launch with the SMEM state.
  * **In-kernel termination + zero-bubble refill**: the PPR stop draw,
    hop budget, dead-end detection, prefix-sum lane compaction, and the
    Theorem VI.1 staging controller all run between hops without leaving
    the kernel.

Host↔device traffic per launch therefore drops from O(k · state) (per-hop
superstep bouncing) to one state round-trip, and ``stats.launches`` counts
1 per ``k`` supersteps instead of 1 per superstep — the fusion factor
``supersteps / launches`` that `WalkStats.supersteps_per_launch` reports.

The kernel is a lowering of the sampler **phase-program IR**
(`repro.core.phase_program`): every loop-free program (``prog.fused``)
stages its gather/score phases through the DMA machinery here —

  * ``uniform`` / ``alias`` (and PPR via the stop draw): the original
    double-buffered row/column/alias-probe pipeline;
  * ``metapath``: the typed-segment gather is one extra double-buffered
    2-element DMA loop over the lane pool (``type_offsets[v, t:t+2]``
    packs the sub-segment bounds, like the RP_entry pair, with lane
    i+1's pair in flight while lane i picks), then the same uniform
    pick;
  * ``rejection_n2v``: the csr-gather(K) / first-accept score pair runs
    breadth-wise across the lane pool with in-kernel per-round uniforms
    (same Threefry counters as ``rng.task_uniforms(..., 2K, ...)``) and
    an O(log d) adjacency bisection over N(v_prev) whose proposal /
    probe column fetches are the same double-buffered one-element DMA
    loops as the uniform pipeline — the verify phase's operands never
    leave SMEM;
  * ``reservoir_n2v`` (weighted Node2Vec): the ``chunked_loop`` schedule
    runs in-kernel — a degree-adaptive chunk loop (trip count
    ``ceil(deg/chunk)`` per lane, the in-kernel form of the jnp path's
    ``adaptive_chunks`` trip bounding) streams each lane's CSR segment
    through ping-pong (2, chunk) column/weight DMA buffers (chunk c+1's
    fetch in flight while chunk c is scored), and the Efraimidis–
    Spirakis reservoir carry (running E-S key + winning offset per
    lane) lives in SMEM alongside the lane pool, folded with the same
    float ops as `samplers.es_chunk_score`/`es_merge`.

Every sampler kind therefore runs device-resident with overlapped
memory traffic — there is no jnp fallback path left in the engine.

Semantics are pinned bit-identical to the jnp superstep
(`core/walk_engine.py`) for every sampler, including PPR stop draws,
both scheduling modes, and the open-system ring economy —
``tests/test_fused_step.py``.  Layout note: slot state is (W,) and the
query ring (Q,) in SMEM, which assumes the modest W/Q of a single core's
lane pool; the HBM-resident buffers (graph CSR, edge weights, alias
tables, type_offsets, paths) are unbounded.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import rng
from repro.core.rng import SALT_CHUNK0, SALT_COLUMN, SALT_STOP
from repro.core.samplers import _uniform_index
from repro.core.tasks import WalkStats
from repro.kernels.common import ScheduleBuilder
from repro.kernels.walk_step.walk_step import (cached_gather1_loop,
                                               cached_gather2_loop,
                                               gather1_loop, gather2_loop,
                                               row_access_loop)

# WalkStats slot indices inside the SMEM stats vector.
STAT = {f: i for i, f in enumerate(WalkStats._fields)}
NUM_STATS = len(WalkStats._fields)


def dma_schedule(kind: str = "uniform", lanes: int = 3, rounds: int = 2,
                 bisect_iters: int = 2, chunks: int = 3, records: int = 4,
                 record_paths: bool = True, cached: bool = False,
                 probe_trips: int = 2):
    """Declarative DMA schedule of one fused-superstep launch, for the
    static hazard analyzer (`repro.analysis.dma_hazards`).

    Mirrors `fused_superstep_kernel`'s per-kind pipeline op-for-op with
    small unroll counts (double-buffered loops are period-2 in the slot
    cycle, so ``lanes``/``chunks`` ≥ 3 covers prologue, both steady-state
    parities, and drain):

      * every kind: `row_access_loop` on ``rpbuf``;
      * ``uniform``: column gather on ``colbuf``;
      * ``alias``: prob/alias probe loops then the column gather;
      * ``metapath``: the typed sub-segment bounds ride the
        `gather2_loop` on ``pairbuf``, then the column gather;
      * ``rejection_n2v``: v_prev RP_entry pairs on ``pairbuf``, then per
        round a proposal gather, ``bisect_iters`` probe gathers, and the
        membership gather — all on ``colbuf``;
      * ``reservoir_n2v``: v_prev pairs on ``pairbuf``, then per lane the
        ping-pong (``ckcol``, ``ckwgt``) chunk loop with chunk c+1 in
        flight while chunk c's bisection probes (``colbuf``) and E-S fold
        consume the staged chunk, then the final column access;
      * the async path write-back (``wbuf``) with its delayed two-deep
        slot reclamation and end-of-launch drain.

    ``cached=True`` emits the *fully-hit* representative superstep of the
    gather hierarchy (``cache_budget > 0`` and every lane's v_curr hot):
    every v_curr-keyed gather becomes vmem-tier ``cache.*`` reads — the
    directory probe (``probe_trips`` binary-search reads plus the
    (addr, deg) payload on ``cache.idx``) replaces the RP_entry loop, and
    the column / alias / typed / chunk payloads read ``cache.col`` /
    ``cache.prob`` / ``cache.alias`` / ``cache.toff`` / ``cache.wgt`` —
    while the v_prev-keyed loops (the second-order samplers' pair fetch,
    bisection probes, and membership checks) and the path write-back keep
    their HBM copies.  The DMA pass proves hit paths issue **no** copies
    (a ``start`` on a vmem buffer is a phantom copy) and the surviving
    miss-side loops stay wait-dominated.  Partially-hit supersteps
    interleave this schedule with the uncached one per lane, so the two
    declarations jointly cover every execution.

    Keep in sync with the kernel — the analyzer checks this declaration,
    and the declaration is only as good as its fidelity to the loops
    above.
    """
    b = ScheduleBuilder()

    def probe():
        # Directory probe: the binary search over the sorted hot-id list,
        # then the (addr, deg) directory payload — all launch-resident.
        for _ in range(probe_trips):
            b.cache_read("cache.idx")
        b.cache_read("cache.idx")

    def col_gather():
        if cached:
            for _ in range(lanes):
                b.cache_read("cache.col")
        else:
            b.gather_loop("colbuf", lanes)

    if cached:
        for _ in range(lanes):                      # row access via probe
            probe()
    else:
        b.gather_loop("rpbuf", lanes)               # row access (RP_entry)
    if kind == "alias":
        if cached:
            for _ in range(lanes):
                b.cache_read("cache.prob")
            for _ in range(lanes):
                b.cache_read("cache.alias")
        else:
            b.gather_loop("probbuf", lanes)
            b.gather_loop("aliasbuf", lanes)
        col_gather()
    elif kind == "metapath":
        if cached:
            for _ in range(lanes):                  # typed bounds
                b.cache_read("cache.toff")
        else:
            b.gather_loop("pairbuf", lanes)         # type_offsets[v, t:t+2]
        col_gather()
    elif kind == "rejection_n2v":
        b.gather_loop("pairbuf", lanes)             # RP_entry of v_prev
        for _ in range(rounds):
            col_gather()                            # proposal columns
            for _ in range(bisect_iters):
                b.gather_loop("colbuf", lanes)      # bisection probes
            b.gather_loop("colbuf", lanes)          # membership check
    elif kind == "reservoir_n2v":
        b.gather_loop("pairbuf", lanes)             # RP_entry of v_prev
        for _lane in range(lanes):
            if cached:
                # Hit lane: the chunk loop scores the cached row
                # elementwise — no ping-pong copies; only the
                # N(v_prev)-side bisection/membership DMAs remain.
                for _c in range(chunks):
                    b.cache_read("cache.col")       # candidate columns
                    for _ in range(bisect_iters):
                        b.gather_loop("colbuf", 2)  # probes over CH posns
                    b.gather_loop("colbuf", 2)      # membership check
                    b.cache_read("cache.col")       # E-S fold operands
                    b.cache_read("cache.wgt")
                continue
            # Per-lane degree-adaptive chunk loop: ping-pong (ckcol,
            # ckwgt) with chunk c+1 in flight while chunk c is scored.
            pend = {0: [(buf, b.start(buf, 0))
                        for buf in ("ckcol", "ckwgt")]}
            for c in range(chunks):
                if c + 1 < chunks:
                    pend[c + 1] = [(buf, b.start(buf, (c + 1) % 2))
                                   for buf in ("ckcol", "ckwgt")]
                for buf, cid in pend.pop(c):
                    b.wait(buf, c % 2, cid)
                # Candidate reads feed the breadth-wise bisection...
                b.read("ckcol", c % 2)
                for _ in range(bisect_iters):
                    b.gather_loop("colbuf", 2)      # probes over CH posns
                b.gather_loop("colbuf", 2)          # membership check
                # ...and the E-S fold consumes columns and weights.
                b.read("ckcol", c % 2)
                b.read("ckwgt", c % 2)
        col_gather()                                # final column access
    else:  # uniform / ppr
        col_gather()
    if record_paths:
        b.writeback_loop("wbuf", records)           # async path write-back
    return b.ops


def _bisect_iters(max_degree: int) -> int:
    """Static adjacency-bisection trip count — MUST match
    `samplers.edge_exists` so the fused verify phase takes the same
    number of halvings as the jnp score executor."""
    return max(1, int(math.ceil(math.log2(max(int(max_degree), 2) + 1))))


class _CacheCtx:
    """Hot-vertex cache refs + static geometry, threaded through the
    sampling helpers (``None`` everywhere when ``cache_budget == 0`` —
    the cached code paths are then never traced, so the cache-off kernel
    is the exact pre-cache kernel).

    ``cslot`` is the per-lane probe result scratch: the lane's v_curr
    cache slot, or -1 on a miss — the single hit predicate every
    downstream gather keys on.  After the row-access phase, a hit lane's
    ``addr_scr`` holds the *cache-space* base ``hot_off[slot]`` instead
    of the HBM ``row_ptr[v]``, so ``addr + offset`` arithmetic is
    uniform across tiers and only the indexed array changes.
    """

    def __init__(self, num_hot, probe_trips, length,
                 chot_ref, cdeg_ref, coff_ref, ccol_ref, cwgt_ref,
                 cprob_ref, cali_ref, ctoff_ref, cslot_scr):
        self.num_hot = num_hot          # H (static)
        self.probe_trips = probe_trips  # binary-search trips (static)
        self.length = length            # packed payload length P (static)
        self.chot = chot_ref            # (H,) sorted hot vertex ids
        self.cdeg = cdeg_ref            # (H,) degrees
        self.coff = coff_ref            # (H+1,) exclusive prefix offsets
        self.col = ccol_ref             # (P,) packed columns
        self.wgt = cwgt_ref             # (P,) weights or None
        self.prob = cprob_ref           # (P,) alias accept probs or None
        self.alias = cali_ref           # (P,) alias indices or None
        self.toff = ctoff_ref           # (H, T+1) typed bounds or None
        self.cslot = cslot_scr          # (W,) per-lane probe result

    def hit_fn(self):
        return lambda i: self.cslot[i] >= 0


def _g1(n, e_fn, src_ref, buf, sem, num_entries, on_result, cache,
        cache_ref):
    """Column-style gather that serves hit lanes from ``cache_ref`` (the
    e_fn index is tier-uniform: cache-space for hits, HBM-space for
    misses, because row access already swapped the hit lanes' base
    address)."""
    cached_gather1_loop(
        n, e_fn, src_ref, buf, sem, num_entries, on_result,
        cache_ref=cache_ref,
        cache_len=cache.length if cache is not None else 1,
        hit_fn=cache.hit_fn() if cache is not None else None)


def _cache_probe(vv, cache):
    """Binary search (lower bound) for ``vv`` in the sorted hot-id
    directory — ``probe_trips`` statically-unrolled halvings of scalar
    SMEM reads; returns the cache slot or -1 on a miss."""
    lo = jnp.int32(0)
    hi = jnp.int32(cache.num_hot)
    for _ in range(cache.probe_trips):
        active = lo < hi
        mid = (lo + hi) // 2
        go = cache.chot[jnp.clip(mid, 0, cache.num_hot - 1)] < vv
        lo = jnp.where(active & go, mid + 1, lo)
        hi = jnp.where(active & jnp.logical_not(go), mid, hi)
    found = (lo < cache.num_hot) & (
        cache.chot[jnp.clip(lo, 0, cache.num_hot - 1)] == vv)
    return jnp.where(found, lo, jnp.int32(-1))


def _cached_row_access(W, num_vertices, cache, rp_ref, rpbuf, rpsem,
                       vcur, act, addr_scr, deg_scr, lead_scr,
                       tagv_scr, tagl_scr, stats):
    """Row access through the gather hierarchy: same-vertex coalescing →
    VMEM directory probe → HBM RP_entry DMAs for miss leaders only.

    Pass 1 fills a direct-mapped tag table (vertex → writing lane) in
    *reverse* lane order, so the surviving writer of each tag slot is
    the smallest lane index — every follower's leader precedes it and an
    ascending pass can forward the leader's result.  Staleness is
    impossible: every lane writes its own tag slot each superstep, so a
    surviving tag always belongs to a current v_curr.  Pass 2 resolves
    each lane's leader (a tag match is a full vertex-id match — distinct
    vertices sharing a tag slot fall back to self-leadership) and probes
    the directory (followers share their leader's vertex and therefore
    its probe result).  Pass 3 serves hit leaders from the directory —
    cache-space base + degree, no DMA — and runs the usual
    double-buffered RP_entry loop with start *and* wait predicated on
    "miss leader".  Pass 4 forwards leader results to followers, applies
    the same per-lane validity guard as the uncached ``on_row``, and
    accumulates the live-lane hit/miss/coalesced counters.

    Bit-identity: ``cdeg[slot]`` equals ``rp[v+1] - rp[v]`` by
    construction and followers share the leader's vertex, so every
    lane's (effective address, degree) resolves to the same bytes as the
    uncached loop.
    """
    def vv_of(i):
        return jnp.clip(vcur[i], 0, num_vertices - 1)

    def tag_fill(t, _):
        i = W - 1 - t
        vv = vv_of(i)
        s = jax.lax.rem(vv, W)
        tagv_scr[s] = vv
        tagl_scr[s] = i
        return 0

    jax.lax.fori_loop(0, W, tag_fill, 0)

    def lead_probe(i, _):
        vv = vv_of(i)
        s = jax.lax.rem(vv, W)
        lead_scr[i] = jnp.where(tagv_scr[s] == vv, tagl_scr[s], i)
        cache.cslot[i] = _cache_probe(vv, cache)
        return 0

    jax.lax.fori_loop(0, W, lead_probe, 0)

    def need(i):
        ii = jnp.minimum(i, W - 1)  # lookahead may probe index W
        return (lead_scr[ii] == ii) & (cache.cslot[ii] < 0)

    def copy(i, slot):
        return pltpu.make_async_copy(rp_ref.at[pl.ds(vv_of(i), 2)],
                                     rpbuf.at[slot], rpsem.at[slot])

    def body(i, _):
        slot = jax.lax.rem(i, 2)

        @pl.when((i + 1 < W) & need(i + 1))
        def _():
            copy(i + 1, jax.lax.rem(i + 1, 2)).start()

        @pl.when(need(i))
        def _():
            copy(i, slot).wait()
            addr_scr[i] = rpbuf[slot, 0]
            deg_scr[i] = rpbuf[slot, 1] - rpbuf[slot, 0]

        @pl.when((lead_scr[i] == i) & (cache.cslot[i] >= 0))
        def _():
            s = jnp.clip(cache.cslot[i], 0, cache.num_hot - 1)
            addr_scr[i] = cache.coff[s]
            deg_scr[i] = cache.cdeg[s]

        return 0

    @pl.when(need(0))
    def _():
        copy(0, 0).start()

    jax.lax.fori_loop(0, W, body, 0, unroll=False)

    def fin(i, acc):
        hits, misses, coal = acc
        led = lead_scr[i]
        follower = led != i

        @pl.when(follower)
        def _():
            addr_scr[i] = addr_scr[led]
            deg_scr[i] = deg_scr[led]

        v = vcur[i]
        deg_scr[i] = jnp.where((v >= 0) & (v < num_vertices),
                               deg_scr[i], 0)
        live = act[i] == 1
        hit = cache.cslot[i] >= 0
        return (hits + (live & ~follower & hit).astype(jnp.int32),
                misses + (live & ~follower & ~hit).astype(jnp.int32),
                coal + (live & follower).astype(jnp.int32))

    hits, misses, coal = jax.lax.fori_loop(
        0, W, fin, (jnp.int32(0), jnp.int32(0), jnp.int32(0)))
    stats[STAT["cache_hits"]] = stats[STAT["cache_hits"]] + hits
    stats[STAT["cache_misses"]] = stats[STAT["cache_misses"]] + misses
    stats[STAT["cache_coalesced"]] = stats[STAT["cache_coalesced"]] + coal


def _rejection_sample(W, num_vertices, num_edges, K, inv_p, inv_q,
                      max_degree, k0, k1, rp_ref, col_ref,
                      colbuf, colsem, pairbuf, pairsem,
                      vcur, vprev, qid_o, hop_o, ep_o,
                      addr_scr, deg_scr, idx_scr, vnext_scr, u1_scr,
                      plo_scr, phi_scr, blo_scr, bhi_scr,
                      kq0_scr, kq1_scr, cand_scr, got_scr, cache=None):
    """In-kernel lowering of the rejection program's gather(csr, K) +
    score(first_accept) phases, breadth-wise across the lane pool: per
    round, derive (u_col, u_acc) from the same Threefry counters as
    ``rng.task_uniforms(..., 2K, SALT_COLUMN)`` (draw j and draw K+j
    share one block), propose a column, bisect the candidate in
    N(v_prev) (identical trip count and compares to
    `samplers.edge_exists`), apply the (p, q) bias, and keep the first
    accepted proposal — the last round is forced, like the jnp executor.
    Every column fetch (proposal, bisection probe, membership check)
    runs through the double-buffered one-element DMA loop, so lane i+1's
    fetch is in flight while lane i's arithmetic runs.  With ``cache``,
    the v_curr-keyed *proposal* fetch serves hit lanes from the packed
    cache columns; the v_prev-keyed bisection/membership fetches always
    go to HBM (the cache is keyed on the current vertex only).
    """
    iters = _bisect_iters(max_degree)
    w_max = max(inv_p, 1.0, inv_q)

    # RP_entry pair of v_prev per lane: the verify phase's bisection
    # bounds, plus the lane's folded key pair and accept state.
    def vp_src(i):
        vp = jnp.clip(vprev[i], 0, num_vertices - 1)
        return rp_ref.at[pl.ds(vp, 2)]

    def on_vp(i, lo, hi):
        plo_scr[i] = lo
        phi_scr[i] = hi
        c0, c1 = rng.task_key_pair(k0, k1, qid_o[i], hop_o[i], SALT_COLUMN,
                                   ep_o[i])
        kq0_scr[i] = c0
        kq1_scr[i] = c1
        got_scr[i] = 0
        vnext_scr[i] = 0

    gather2_loop(W, vp_src, pairbuf, pairsem, on_vp)

    def round_body(j, _):
        ju = j.astype(jnp.uint32)

        def lane_draw(i, _i):
            y0, y1 = rng.threefry2x32(kq0_scr[i], kq1_scr[i], ju,
                                      ju + jnp.uint32(K))
            prop = _uniform_index(deg_scr[i], rng.bits_to_uniform(y0))
            u1_scr[i] = rng.bits_to_uniform(y1)
            idx_scr[i] = addr_scr[i] + prop
            blo_scr[i] = plo_scr[i]
            bhi_scr[i] = phi_scr[i]
            return 0

        jax.lax.fori_loop(0, W, lane_draw, 0)

        def on_cand(i, v):
            cand_scr[i] = v

        _g1(W, lambda i: idx_scr[i], col_ref, colbuf, colsem,
            num_edges, on_cand, cache,
            cache.col if cache is not None else None)

        for _ in range(iters):
            def on_probe(i, cv):
                lo = blo_scr[i]
                hi = bhi_scr[i]
                active = lo < hi
                mid = (lo + hi) // 2
                go_right = cv < cand_scr[i]
                blo_scr[i] = jnp.where(active & go_right, mid + 1, lo)
                bhi_scr[i] = jnp.where(active & ~go_right, mid, hi)

            gather1_loop(W, lambda i: (blo_scr[i] + bhi_scr[i]) // 2,
                         col_ref, colbuf, colsem, num_edges, on_probe)

        def on_member(i, cv):
            y = cand_scr[i]
            vp = vprev[i]
            common = (blo_scr[i] < phi_scr[i]) & (cv == y) & (vp >= 0)
            w = jnp.where(vp < 0, 1.0,
                          jnp.where(y == vp, inv_p,
                                    jnp.where(common, 1.0, inv_q)))
            accept = (u1_scr[i] * w_max <= w) | (j == K - 1)
            got = got_scr[i] == 1
            take = accept & ~got
            vnext_scr[i] = jnp.where(take, y, vnext_scr[i])
            got_scr[i] = (got | accept).astype(jnp.int32)

        gather1_loop(W, lambda i: blo_scr[i], col_ref, colbuf, colsem,
                     num_edges, on_member)
        return 0

    jax.lax.fori_loop(0, K, round_body, 0)


def _metapath_sample(W, num_vertices, num_edges, mp_sched, to_ref, col_ref,
                     colbuf, colsem, pairbuf, pairsem,
                     vcur, hop_o, u0_scr, addr_scr, deg_scr, idx_scr,
                     vnext_scr, cache=None):
    """In-kernel lowering of the metapath program's gather(typed) +
    score(pick_uniform) phases: the scheduled type's packed sub-segment
    bounds (``type_offsets[v, t:t+2]``) ride the double-buffered
    2-element DMA loop (lane i+1's bounds in flight while lane i picks),
    the staged uniform picks within the sub-segment, and a no-match
    sub-segment zeroes the lane's effective degree (early termination,
    same as the jnp executor).  With ``cache``, hit lanes take their
    bounds from the packed ``toff`` rows (type offsets are row-relative,
    so the cached row reads identically to the HBM row) and their column
    from the packed cache columns."""
    L = len(mp_sched)

    def seg_t(i):
        r = jax.lax.rem(hop_o[i], L)
        t = jnp.int32(mp_sched[0])
        for s in range(1, L):
            t = jnp.where(r == s, jnp.int32(mp_sched[s]), t)
        return t

    def seg_src(i):
        v_safe = jnp.clip(vcur[i], 0, num_vertices - 1)
        return to_ref.at[v_safe, pl.ds(seg_t(i), 2)]

    def on_seg(i, base, end):
        cnt = end - base
        pick = base + _uniform_index(cnt, u0_scr[i])
        idx_scr[i] = addr_scr[i] + pick
        deg_scr[i] = jnp.where(cnt > 0, deg_scr[i], 0)

    if cache is not None and cache.toff is not None:
        def hit_pair(i):
            s = jnp.clip(cache.cslot[i], 0, cache.num_hot - 1)
            t = seg_t(i)
            return cache.toff[s, t], cache.toff[s, t + 1]

        cached_gather2_loop(W, seg_src, pairbuf, pairsem, on_seg,
                            hit_fn=cache.hit_fn(), hit_pair_fn=hit_pair)
    else:
        gather2_loop(W, seg_src, pairbuf, pairsem, on_seg)

    def on_col(i, v):
        vnext_scr[i] = v

    _g1(W, lambda i: idx_scr[i], col_ref, colbuf, colsem,
        num_edges, on_col, cache,
        cache.col if cache is not None else None)


def _reservoir_sample(W, num_vertices, num_edges, CH, Lc, inv_p, inv_q,
                      max_degree, has_weights, k0, k1,
                      rp_ref, col_ref, wgt_ref,
                      colbuf, colsem, pairbuf, pairsem,
                      ckcol, ckwgt, cksem,
                      act, stop_scr, vcur, vprev, qid_o, hop_o, ep_o,
                      addr_scr, deg_scr, idx_scr, vnext_scr,
                      plo_scr, phi_scr, blo_scr, bhi_scr,
                      cand_scr, bkey_scr, ures_scr, fnd_scr, cache=None):
    """In-kernel ``chunked_loop`` schedule — the Efraimidis–Spirakis
    weighted reservoir scan (weighted Node2Vec) as a degree-adaptive
    chunk loop per lane.

    Per lane, the trip count is ``ceil(deg/CH)`` (the in-kernel form of
    the jnp path's ``adaptive_chunks`` bounding: chunks past a lane's
    own degree contribute only -inf reservoir keys, so truncating the
    loop there cannot change the scanned argmax — the kernel is
    degree-adaptive per lane regardless of the spec flag).  Chunk c of
    (column, edge weight) streams through ping-pong ``(2, Lc)`` DMA
    buffers with chunk c+1's fetch in flight while chunk c is scored;
    the per-chunk uniforms reproduce ``rng.task_uniforms(..., CH,
    SALT_CHUNK0 + c)``'s counter layout exactly; the (p, q) bias
    bisects all CH candidates in N(v_prev) breadth-wise (identical trip
    count and compares to `samplers.edge_exists`, probes double-
    buffered); and the running (E-S key, winning offset) carry is held
    in SMEM alongside the lane pool, folded with strict ``>`` so the
    earliest maximal key wins — the same tie-break as
    `samplers.es_chunk_score` (first within-chunk argmax) +
    `samplers.es_merge` (strict cross-chunk merge), making the fold
    bit-identical to `phase_program.reservoir_scan`.

    With ``cache``, a hit lane's whole chunk loop goes DMA-free: the
    candidate columns and fold weights read the packed cache row
    elementwise (same bytes as the staged chunk — verbatim CSR slices),
    both ping-pong copies are predicated off, and only the
    N(v_prev)-keyed bisection/membership fetches still touch HBM.  Miss
    lanes run the unchanged ping-pong pipeline.
    """
    iters = _bisect_iters(max_degree)
    pairs = (CH + 1) // 2

    # v_prev RP_entry pair per lane (bias bisection bounds), plus the
    # reservoir carry init.
    def vp_src(i):
        vp = jnp.clip(vprev[i], 0, num_vertices - 1)
        return rp_ref.at[pl.ds(vp, 2)]

    def on_vp(i, lo, hi):
        plo_scr[i] = lo
        phi_scr[i] = hi
        bkey_scr[i] = -jnp.inf
        cand_scr[i] = 0

    gather2_loop(W, vp_src, pairbuf, pairsem, on_vp)

    def lane_scan(i, _):
        deg = deg_scr[i]
        # Lanes whose sample is consumed this superstep: active, not
        # PPR-stopped, with a non-empty segment.  The jnp path computes
        # (masked, unused) results for the rest; skipping them here
        # changes nothing observable.
        run = (act[i] == 1) & (stop_scr[i] == 0) & (deg > 0)

        @pl.when(run)
        def _():
            addr = addr_scr[i]
            vp = vprev[i]
            plo = plo_scr[i]
            phi = phi_scr[i]
            n_tr = (deg + CH - 1) // CH
            hit = (cache.cslot[i] >= 0) if cache is not None else None

            def when_miss(fn):
                # Hit lanes read the cached row elementwise — every
                # chunk copy is predicated off for them.
                if cache is not None:
                    pl.when(jnp.logical_not(hit))(fn)
                else:
                    fn()

            def ck_copies(c, slot):
                # Chunk DMAs are fixed-length Lc; near the end of `col`
                # the base clamps down and valid positions shift by
                # `off` inside the buffer (invalid positions past the
                # lane's degree are masked out of the fold anyway).
                base = jnp.clip(addr + c * CH, 0, num_edges - Lc)
                cps = [pltpu.make_async_copy(
                    col_ref.at[pl.ds(base, Lc)], ckcol.at[slot],
                    cksem.at[slot, 0])]
                if has_weights:
                    cps.append(pltpu.make_async_copy(
                        wgt_ref.at[pl.ds(base, Lc)], ckwgt.at[slot],
                        cksem.at[slot, 1]))
                return cps

            def _start0():
                for cp in ck_copies(0, 0):
                    cp.start()

            when_miss(_start0)

            def chunk_body(c, _c):
                slot = jax.lax.rem(c, 2)

                def _prefetch():
                    @pl.when(c + 1 < n_tr)
                    def _():
                        for cp in ck_copies(c + 1, jax.lax.rem(c + 1, 2)):
                            cp.start()

                when_miss(_prefetch)

                def _drain():
                    for cp in ck_copies(c, slot):
                        cp.wait()

                when_miss(_drain)

                base = jnp.clip(addr + c * CH, 0, num_edges - Lc)
                off = addr + c * CH - base

                def cache_e(j):
                    # Cache-space index of chunk position j (addr is the
                    # packed-row base for hit lanes).
                    return jnp.clip(addr + c * CH + j, 0,
                                    cache.length - 1)

                def cand(j):
                    # chunk_gather's staging: invalid positions -> -1.
                    b = jnp.minimum(off + j, Lc - 1)
                    val = ckcol[slot, b]
                    if cache is not None:
                        val = jnp.where(hit, cache.col[cache_e(j)], val)
                    return jnp.where(c * CH + j < deg, val, -1)

                # Per-chunk uniforms: same counter split as
                # rng.key_bits(CH) (draw j and draw pairs+j share a
                # Threefry block; odd widths pad one zero counter).
                d0, d1 = rng.task_key_pair(
                    k0, k1, qid_o[i], hop_o[i], SALT_CHUNK0 + c, ep_o[i])

                def draw_block(b, _b):
                    bu = b.astype(jnp.uint32)
                    x1 = jnp.where(b + pairs < CH, bu + jnp.uint32(pairs),
                                   jnp.uint32(0))
                    y0, y1 = rng.threefry2x32(d0, d1, bu, x1)
                    ures_scr[b] = rng.bits_to_uniform(y0)

                    @pl.when(b + pairs < CH)
                    def _():
                        ures_scr[b + pairs] = rng.bits_to_uniform(y1)

                    return 0

                jax.lax.fori_loop(0, pairs, draw_block, 0)

                # Bias verify: bisect all CH candidates in N(v_prev)
                # breadth-wise, probe DMAs double-buffered.
                def binit(j, _j):
                    blo_scr[j] = plo
                    bhi_scr[j] = phi
                    return 0

                jax.lax.fori_loop(0, CH, binit, 0)

                for _ in range(iters):
                    def on_probe(j, cv):
                        lo = blo_scr[j]
                        hi = bhi_scr[j]
                        active = lo < hi
                        mid = (lo + hi) // 2
                        go_right = cv < cand(j)
                        blo_scr[j] = jnp.where(active & go_right, mid + 1,
                                               lo)
                        bhi_scr[j] = jnp.where(active & ~go_right, mid, hi)

                    gather1_loop(CH,
                                 lambda j: (blo_scr[j] + bhi_scr[j]) // 2,
                                 col_ref, colbuf, colsem, num_edges,
                                 on_probe)

                def on_member(j, cv):
                    fnd_scr[j] = ((blo_scr[j] < phi)
                                  & (cv == cand(j))).astype(jnp.int32)

                gather1_loop(CH, lambda j: blo_scr[j], col_ref, colbuf,
                             colsem, num_edges, on_member)

                # E-S fold into the SMEM reservoir carry: strict > is
                # exactly es_chunk_score's first-argmax + es_merge's
                # earliest-chunk tie-break, flattened.
                def fold(j, _f):
                    valid = c * CH + j < deg
                    y = cand(j)
                    b = jnp.minimum(off + j, Lc - 1)
                    if has_weights:
                        wv = ckwgt[slot, b]
                        if cache is not None and cache.wgt is not None:
                            wv = jnp.where(hit, cache.wgt[cache_e(j)], wv)
                        w_edge = jnp.where(valid, wv, 0.0)
                    else:
                        w_edge = jnp.where(valid, 1.0, 0.0)
                    common = (fnd_scr[j] == 1) & (vp >= 0)
                    bias = jnp.where(vp < 0, 1.0,
                                     jnp.where(y == vp, inv_p,
                                               jnp.where(common, 1.0,
                                                         inv_q)))
                    w = w_edge * bias
                    key = jnp.where(valid & (w > 0),
                                    jnp.log(ures_scr[j] + 1e-20) / w,
                                    -jnp.inf)
                    take = key > bkey_scr[i]
                    bkey_scr[i] = jnp.where(take, key, bkey_scr[i])
                    cand_scr[i] = jnp.where(take, c * CH + j, cand_scr[i])
                    return 0

                jax.lax.fori_loop(0, CH, fold, 0)
                return 0

            jax.lax.fori_loop(0, n_tr, chunk_body, 0)
            idx_scr[i] = addr + jnp.clip(cand_scr[i], 0,
                                         jnp.maximum(deg - 1, 0))

        @pl.when(~run)
        def _():
            idx_scr[i] = addr_scr[i]

        return 0

    jax.lax.fori_loop(0, W, lane_scan, 0)

    def on_col(i, v):
        vnext_scr[i] = v

    _g1(W, lambda i: idx_scr[i], col_ref, colbuf, colsem,
        num_edges, on_col, cache,
        cache.col if cache is not None else None)


def fused_superstep_kernel(
        # ---- static configuration (bound via functools.partial) ----
        num_vertices, num_edges, W, Q, max_hops, depth, delay,
        stop_prob, kind, mp_sched, rej_rounds, inv_p, inv_q, max_degree,
        res_chunk, res_len, has_weights, static_mode, record_paths,
        use_cache, num_hot, cache_probe_trips, cache_len,
        # ---- inputs ----
        key_ref, ctl_ref,
        vcur_in, vprev_in, qid_in, hop_in, act_in, ep_in,
        qctr_in, hist_in, stats_in, done_in, len_in,
        qstart_ref, qorder_ref, qepoch_ref,
        rp_ref, col_ref, wgt_ref, prob_ref, alias_ref, to_ref,
        chot_ref, cdeg_ref, coff_ref, ccol_ref, cwgt_ref, cprob_ref,
        cali_ref, ctoff_ref, paths_in,
        # ---- outputs ----
        vcur, vprev, qid_o, hop_o, act, ep_o,
        qctr, hist, stats, done, len_o, paths,
        # ---- scratch ----
        stop_scr, u0_scr, u1_scr, addr_scr, deg_scr, idx_scr, vnext_scr,
        term_scr,
        rpbuf, rpsem, colbuf, colsem, probbuf, probsem, aliasbuf, aliassem,
        wbuf, wsem, wmeta, wcnt, pairbuf, pairsem,
        plo_scr, phi_scr, blo_scr, bhi_scr, kq0_scr, kq1_scr, cand_scr,
        got_scr, bkey_scr, ures_scr, fnd_scr, ckcol, ckwgt, cksem,
        cslot_scr, lead_scr, tagv_scr, tagl_scr):
    del paths_in  # aliased with `paths` (input_output_aliases)
    alias = kind == "alias"
    k0 = key_ref[0]
    k1 = key_ref[1]
    wcnt[0] = 0
    # The gather-hierarchy context: None when cache_budget == 0, so the
    # cache-off kernel traces exactly the pre-cache pipeline.
    cache = None
    if use_cache:
        cache = _CacheCtx(
            num_hot, cache_probe_trips, cache_len,
            chot_ref, cdeg_ref, coff_ref, ccol_ref,
            cwgt_ref if has_weights else None,
            cprob_ref if alias else None,
            cali_ref if alias else None,
            ctoff_ref if kind == "metapath" else None,
            cslot_scr)

    def path_write(q, h, v):
        """Async double-buffered single-record path write-back: start the
        HBM store for this record and only wait when its staging slot is
        needed again two writes later — lane i+1's sampling overlaps lane
        i's store, like the row/column gathers."""
        c = wcnt[0]
        slot = jax.lax.rem(c, 2)

        @pl.when(c >= 2)
        def _():  # reclaim the slot: drain its in-flight store
            pltpu.make_async_copy(
                wbuf.at[slot],
                paths.at[wmeta[slot, 0], pl.ds(wmeta[slot, 1], 1)],
                wsem.at[slot]).wait()

        wbuf[slot, 0] = v
        wmeta[slot, 0] = q
        wmeta[slot, 1] = h
        pltpu.make_async_copy(wbuf.at[slot], paths.at[q, pl.ds(h, 1)],
                              wsem.at[slot]).start()
        wcnt[0] = c + 1

    # ---- bring the launch-resident state into the output refs ----------
    def cp_w(i, _):
        vcur[i] = vcur_in[i]
        vprev[i] = vprev_in[i]
        qid_o[i] = qid_in[i]
        hop_o[i] = hop_in[i]
        act[i] = act_in[i]
        ep_o[i] = ep_in[i]
        return 0

    jax.lax.fori_loop(0, W, cp_w, 0)

    def cp_q(i, _):
        done[i] = done_in[i]
        if record_paths:
            len_o[i] = len_in[i]
        return 0

    jax.lax.fori_loop(0, Q, cp_q, 0)
    if not record_paths:
        len_o[0] = len_in[0]
    for i in range(3):
        qctr[i] = qctr_in[i]
    for i in range(delay + 1):
        hist[i] = hist_in[i]
    for i in range(NUM_STATS):
        stats[i] = stats_in[i]
    stats[STAT["launches"]] = stats[STAT["launches"]] + 1

    # ---- one superstep (bit-identical to walk_engine._superstep) -------
    def superstep(_s, carry):
        head = qctr[0]
        tail = qctr[2]
        n_active = jax.lax.fori_loop(0, W, lambda i, a: a + act[i],
                                     jnp.int32(0))
        work = (head < tail) | (n_active > 0)

        @pl.when(work)
        def _():
            # -- per-lane stop draw + sampling uniforms (in-kernel RNG) --
            # The draw phase of the program: uniform/metapath consume one
            # uniform, alias two (counter layout exactly matches
            # rng.task_uniforms); rejection derives its 2K per-round
            # uniforms and the reservoir its CH per-chunk uniforms inside
            # the sampling loops below.
            def lane_rng(i, _):
                q = qid_o[i]
                h = hop_o[i]
                e = ep_o[i]
                if stop_prob > 0.0:
                    s0, s1 = rng.task_key_pair(k0, k1, q, h, SALT_STOP, e)
                    b0, _b1 = rng.threefry2x32(s0, s1, jnp.uint32(0),
                                               jnp.uint32(0))
                    u = rng.bits_to_uniform(b0)
                    stop_scr[i] = ((act[i] == 1)
                                   & (u < stop_prob)).astype(jnp.int32)
                else:
                    stop_scr[i] = 0
                if kind not in ("rejection_n2v", "reservoir_n2v"):
                    c0, c1 = rng.task_key_pair(k0, k1, q, h, SALT_COLUMN, e)
                    if alias:
                        y0, y1 = rng.threefry2x32(c0, c1, jnp.uint32(0),
                                                  jnp.uint32(1))
                        u0_scr[i] = rng.bits_to_uniform(y0)
                        u1_scr[i] = rng.bits_to_uniform(y1)
                    else:
                        y0, _y1 = rng.threefry2x32(c0, c1, jnp.uint32(0),
                                                   jnp.uint32(0))
                        u0_scr[i] = rng.bits_to_uniform(y0)
                return 0

            jax.lax.fori_loop(0, W, lane_rng, 0)

            # -- Row Access: packed (addr, deg) DMA per lane, or the
            # gather hierarchy (coalesce -> VMEM probe -> miss DMA) -----
            if use_cache:
                _cached_row_access(W, num_vertices, cache, rp_ref,
                                   rpbuf, rpsem, vcur, act,
                                   addr_scr, deg_scr, lead_scr,
                                   tagv_scr, tagl_scr, stats)
            else:
                def on_row(i, addr, deg):
                    v = vcur[i]
                    addr_scr[i] = addr
                    deg_scr[i] = jnp.where((v >= 0) & (v < num_vertices),
                                           deg, 0)

                row_access_loop(W, lambda i: vcur[i], rp_ref, rpbuf, rpsem,
                                num_vertices, on_row)

            # -- Sampling + Column Access (per phase program) ------------
            if kind == "rejection_n2v":
                _rejection_sample(
                    W, num_vertices, num_edges, rej_rounds, inv_p, inv_q,
                    max_degree, k0, k1, rp_ref, col_ref,
                    colbuf, colsem, pairbuf, pairsem,
                    vcur, vprev, qid_o, hop_o, ep_o,
                    addr_scr, deg_scr, idx_scr, vnext_scr, u1_scr,
                    plo_scr, phi_scr, blo_scr, bhi_scr,
                    kq0_scr, kq1_scr, cand_scr, got_scr, cache=cache)
            elif kind == "reservoir_n2v":
                _reservoir_sample(
                    W, num_vertices, num_edges, res_chunk, res_len,
                    inv_p, inv_q, max_degree, has_weights, k0, k1,
                    rp_ref, col_ref, wgt_ref,
                    colbuf, colsem, pairbuf, pairsem,
                    ckcol, ckwgt, cksem,
                    act, stop_scr, vcur, vprev, qid_o, hop_o, ep_o,
                    addr_scr, deg_scr, idx_scr, vnext_scr,
                    plo_scr, phi_scr, blo_scr, bhi_scr,
                    cand_scr, bkey_scr, ures_scr, fnd_scr, cache=cache)
            elif kind == "metapath":
                _metapath_sample(
                    W, num_vertices, num_edges, mp_sched, to_ref, col_ref,
                    colbuf, colsem, pairbuf, pairsem,
                    vcur, hop_o, u0_scr, addr_scr, deg_scr, idx_scr,
                    vnext_scr, cache=cache)
            else:
                def pick(i):
                    return jnp.clip(
                        addr_scr[i] + _uniform_index(deg_scr[i], u0_scr[i]),
                        0, num_edges - 1)

                if alias:
                    def on_prob(i, p):
                        # accept -> keep draw; reject -> alias probe below
                        idx_scr[i] = jnp.where(u1_scr[i] < p, 0, -1)

                    _g1(W, pick, prob_ref, probbuf, probsem,
                        num_edges, on_prob, cache,
                        cache.prob if cache is not None else None)

                    def on_alias(i, a):
                        deg = deg_scr[i]
                        kdraw = _uniform_index(deg, u0_scr[i])
                        j = jnp.where(idx_scr[i] < 0, a, kdraw)
                        j = jnp.clip(j, 0, jnp.maximum(deg - 1, 0))
                        idx_scr[i] = jnp.clip(addr_scr[i] + j, 0,
                                              num_edges - 1)

                    _g1(W, pick, alias_ref, aliasbuf, aliassem,
                        num_edges, on_alias, cache,
                        cache.alias if cache is not None else None)
                else:
                    def set_idx(i, _):
                        idx_scr[i] = pick(i)
                        return 0

                    jax.lax.fori_loop(0, W, set_idx, 0)

                def on_col(i, v):
                    vnext_scr[i] = v

                _g1(W, lambda i: idx_scr[i], col_ref, colbuf,
                    colsem, num_edges, on_col, cache,
                    cache.col if cache is not None else None)

            # -- terminate + advance + async path/done write-back --------
            def lane_update(i, acc):
                steps_acc, term_acc = acc
                A = act[i] == 1
                stop = stop_scr[i] == 1
                ok = deg_scr[i] > 0
                adv = A & (~stop) & ok
                dead = A & (~stop) & (~ok)
                nh = jnp.where(adv, hop_o[i] + 1, hop_o[i])
                term = stop | dead | (adv & (nh >= max_hops))
                term_scr[i] = term.astype(jnp.int32)
                q = qid_o[i]
                vprev[i] = jnp.where(adv, vcur[i], vprev[i])
                vcur[i] = jnp.where(adv, vnext_scr[i], vcur[i])
                hop_o[i] = nh

                if record_paths:
                    @pl.when(adv)
                    def _():
                        len_o[q] = nh + 1
                        path_write(q, nh, vnext_scr[i])

                @pl.when(term & A)
                def _():
                    done[q] = 1

                return (steps_acc + adv.astype(jnp.int32),
                        term_acc + (term & A).astype(jnp.int32))

            n_steps, n_term = jax.lax.fori_loop(
                0, W, lane_update, (jnp.int32(0), jnp.int32(0)))

            # -- stats (same accounting as the jnp superstep) ------------
            idle = W - n_active
            upstream = (head < tail).astype(jnp.int32)
            stats[STAT["steps"]] = stats[STAT["steps"]] + n_steps
            stats[STAT["slot_steps"]] = stats[STAT["slot_steps"]] + W
            stats[STAT["bubbles"]] = stats[STAT["bubbles"]] + idle
            stats[STAT["starved"]] = stats[STAT["starved"]] + idle * upstream
            stats[STAT["terminations"]] = (stats[STAT["terminations"]]
                                           + n_term)
            stats[STAT["supersteps"]] = stats[STAT["supersteps"]] + 1

            # -- staging controller (Theorem VI.1, delayed observation) --
            for j in range(delay):
                hist[j] = hist[j + 1]
            hist[delay] = head
            staged = jnp.maximum(qctr[1],
                                 jnp.minimum(hist[0] + depth, tail))
            qctr[1] = staged

            # -- zero-bubble prefix-sum refill from the order ring -------
            if static_mode:
                all_free = jax.lax.fori_loop(
                    0, W,
                    lambda i, a: a & ((act[i] == 0) | (term_scr[i] == 1)),
                    True)
            avail = jnp.maximum(staged - head, 0)

            def lane_refill(i, acc):
                rank, taken = acc
                free = (act[i] == 0) | (term_scr[i] == 1)
                if static_mode:
                    free = free & all_free
                take = free & (rank < avail)

                @pl.when(take)
                def _():
                    pos = jax.lax.rem(head + rank, Q)
                    nq = qorder_ref[pos]
                    start = qstart_ref[nq]
                    vcur[i] = start
                    vprev[i] = -1
                    qid_o[i] = nq
                    hop_o[i] = 0
                    act[i] = 1
                    ep_o[i] = qepoch_ref[nq]
                    if record_paths:
                        len_o[nq] = 1
                        path_write(nq, 0, start)

                @pl.when((~take) & (term_scr[i] == 1))
                def _():
                    qid_o[i] = -1
                    act[i] = 0

                return (rank + free.astype(jnp.int32),
                        taken + take.astype(jnp.int32))

            _, n_taken = jax.lax.fori_loop(
                0, W, lane_refill, (jnp.int32(0), jnp.int32(0)))
            qctr[0] = head + n_taken

        return carry

    jax.lax.fori_loop(0, ctl_ref[0], superstep, 0)

    if record_paths:
        # Drain the (at most two) in-flight path stores before the launch
        # returns its state.
        c = wcnt[0]
        for back in (2, 1):
            @pl.when(c >= back)
            def _(back=back):
                slot = jax.lax.rem(c - back, 2)
                pltpu.make_async_copy(
                    wbuf.at[slot],
                    paths.at[wmeta[slot, 0], pl.ds(wmeta[slot, 1], 1)],
                    wsem.at[slot]).wait()
