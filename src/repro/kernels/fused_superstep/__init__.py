from repro.kernels.fused_superstep.ops import build_fused_launch

__all__ = ["build_fused_launch"]
