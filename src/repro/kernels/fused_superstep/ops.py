"""Jitted public wrapper for the fused device-resident superstep kernel.

``build_fused_launch(spec, cfg, depth)`` returns a jitted
``launch(graph, state, base_key, k) -> StreamState`` that advances the
open-system :class:`~repro.core.walk_engine.StreamState` by at most ``k``
supersteps inside ONE Pallas launch (``k`` is traced — the host picks the
``hops_per_launch`` cadence without recompiling).  The engine-level
runners (`core/walk_engine.py`) drain a closed batch or chunk a stream by
looping launches; everything between launches is exactly the jnp engine's
host protocol (``inject_queries``, harvesting), so the two impls are
interchangeable mid-stream.

``interpret`` defaults to interpreting the kernel body off-TPU (CPU CI)
and compiling on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.phase_program import fused_kinds, lower
from repro.core.tasks import WalkStats
from repro.kernels.fused_superstep import fused_superstep as _k

# Sampler kinds the fused kernel covers — read off the phase programs.
# Every program lowers here (loop-free programs as one launch-resident
# pass, the chunked reservoir as the in-kernel chunk loop), so this is
# all of `samplers.KINDS`; there is no jnp fallback.
FUSED_KINDS = fused_kinds()


def build_fused_launch(spec, cfg, depth: int, interpret: bool | None = None,
                       cache=None):
    """Build the jitted single-launch runner for ``spec`` × ``cfg``.

    ``cache`` is the graph-specific
    :class:`~repro.graph.HotVertexCache` from
    `core.walk_engine.maybe_build_cache` (or ``None``): its packed
    payload block rides into the kernel as launch-resident operands and
    v_curr-keyed gathers on cached vertices skip their HBM DMAs —
    bit-identically, since the block packs verbatim CSR slices.
    """
    from repro.kernels.common import default_interpret
    assert lower(spec).fused, spec.kind
    kind = spec.kind
    alias = kind == "alias"
    metapath = kind == "metapath"
    rejection = kind == "rejection_n2v"
    reservoir = kind == "reservoir_n2v"
    second = rejection or reservoir
    interpret = default_interpret(interpret)
    W = cfg.num_slots
    H = cfg.max_hops
    C = cfg.injection_delay
    record_paths = cfg.record_paths
    stop_prob = float(spec.stop_prob)
    static_mode = cfg.mode == "static"
    mp_sched = tuple(int(t) for t in spec.metapath)
    rej_rounds = int(spec.rejection_rounds) if rejection else 0
    CH = int(spec.reservoir_chunk) if reservoir else 1
    inv_p = 1.0 / float(spec.p)
    inv_q = 1.0 / float(spec.q)
    if cache is not None:
        # A kind-required payload the graph could not provide (e.g. no
        # alias tables) disables the cache rather than half-serving it.
        needed = {"alias": ("alias_prob", "alias_idx"),
                  "metapath": ("type_offsets",)}.get(kind, ())
        if any(getattr(cache, p) is None for p in needed):
            cache = None
    use_cache = cache is not None
    num_hot = cache.num_hot if use_cache else 1
    cache_trips = cache.probe_trips if use_cache else 1
    cache_len = cache.num_entries if use_cache else 1

    @jax.jit
    def launch(graph, state, base_key, k):
        Q = state.done.shape[0]
        nv = graph.row_ptr.shape[0] - 1
        ne = graph.col.shape[0]
        QL = Q if record_paths else 1
        # Chunk DMAs are fixed-length; on a graph smaller than one chunk
        # the transfer shrinks to the edge count (valid positions always
        # fit — degrees are bounded by ne).
        Lc = max(1, min(CH, ne)) if reservoir else 1
        has_weights = reservoir and graph.weights is not None
        kernel = functools.partial(
            _k.fused_superstep_kernel, nv, ne, W, Q, H, depth, C,
            stop_prob, kind, mp_sched, rej_rounds, inv_p, inv_q,
            int(graph.max_degree), CH, Lc, has_weights, static_mode,
            record_paths, use_cache, num_hot, cache_trips, cache_len)
        smem = pl.BlockSpec(memory_space=pltpu.SMEM)
        hbm = pl.BlockSpec(memory_space=pl.ANY)
        s = state.slots
        q = state.queue
        stats_vec = jnp.stack(
            [jnp.asarray(v, jnp.int32) for v in state.stats])
        qctr = jnp.stack([q.head, q.staged, q.tail]).astype(jnp.int32)
        if alias:
            prob, ali = graph.alias_prob, graph.alias_idx
        else:  # inert placeholders so the operand list is shape-stable
            prob = jnp.zeros((1,), jnp.float32)
            ali = jnp.zeros((1,), jnp.int32)
        # Edge weights (the reservoir's chunk gather); inert placeholder
        # otherwise (unweighted graphs score every edge at weight 1).
        wgt = graph.weights if has_weights else jnp.zeros((1,), jnp.float32)
        # Typed sub-segment bounds (metapath's gather phase); inert
        # placeholder otherwise.
        to = graph.type_offsets if metapath else jnp.zeros((1, 2), jnp.int32)
        if use_cache:
            # The packed hot-vertex block: launch-resident operands (the
            # VMEM tier of the gather hierarchy).  jit folds the host
            # numpy arrays into on-device constants once per engine.
            chot = jnp.asarray(cache.hot_ids, jnp.int32)
            cdeg = jnp.asarray(cache.hot_deg, jnp.int32)
            coff = jnp.asarray(cache.hot_off, jnp.int32)
            ccol = jnp.asarray(cache.col, jnp.int32)
            cwgt = (jnp.asarray(cache.weights, jnp.float32)
                    if cache.weights is not None
                    else jnp.zeros((1,), jnp.float32))
            cprob = (jnp.asarray(cache.alias_prob, jnp.float32)
                     if cache.alias_prob is not None
                     else jnp.zeros((1,), jnp.float32))
            cali = (jnp.asarray(cache.alias_idx, jnp.int32)
                    if cache.alias_idx is not None
                    else jnp.zeros((1,), jnp.int32))
            ctoff = (jnp.asarray(cache.type_offsets, jnp.int32)
                     if cache.type_offsets is not None
                     else jnp.zeros((1, 2), jnp.int32))
        else:  # inert placeholders — the kernel never touches them
            chot = jnp.full((1,), -1, jnp.int32)
            cdeg = jnp.zeros((1,), jnp.int32)
            coff = jnp.zeros((2,), jnp.int32)
            ccol = jnp.zeros((1,), jnp.int32)
            cwgt = jnp.zeros((1,), jnp.float32)
            cprob = jnp.zeros((1,), jnp.float32)
            cali = jnp.zeros((1,), jnp.int32)
            ctoff = jnp.zeros((1, 2), jnp.int32)
        inputs = [
            jnp.asarray(base_key, jnp.uint32),
            jnp.asarray(k, jnp.int32).reshape(1),
            s.v_curr, s.v_prev, s.query_id, s.hop,
            s.active.astype(jnp.int32), s.epoch,
            qctr, state.head_hist.astype(jnp.int32), stats_vec,
            state.done.astype(jnp.int32), state.lengths,
            q.start_vertex, q.order, q.epoch,
            graph.row_ptr, graph.col, wgt, prob, ali, to,
            chot, cdeg, coff, ccol, cwgt, cprob, cali, ctoff,
            state.paths,
        ]
        # Second-order samplers (rejection / reservoir) bisect N(v_prev)
        # breadth-wise: rejection over the W lanes, the reservoir over
        # the CH positions of the staged chunk.
        BW = W if rejection else (CH if reservoir else 1)
        outs = pl.pallas_call(
            kernel,
            in_specs=[smem] * 16 + [hbm] * 6 + [smem] * 8 + [hbm],
            out_specs=[smem] * 11 + [hbm],
            out_shape=[jax.ShapeDtypeStruct((W,), jnp.int32)] * 6 + [
                jax.ShapeDtypeStruct((3,), jnp.int32),
                jax.ShapeDtypeStruct((C + 1,), jnp.int32),
                jax.ShapeDtypeStruct((_k.NUM_STATS,), jnp.int32),
                jax.ShapeDtypeStruct((Q,), jnp.int32),
                jax.ShapeDtypeStruct((QL,), jnp.int32),
                jax.ShapeDtypeStruct(state.paths.shape, jnp.int32),
            ],
            scratch_shapes=[
                pltpu.SMEM((W,), jnp.int32),    # stop flags
                pltpu.SMEM((W,), jnp.float32),  # u0 (column draw)
                pltpu.SMEM((W,), jnp.float32),  # u1 (alias accept)
                pltpu.SMEM((W,), jnp.int32),    # addr
                pltpu.SMEM((W,), jnp.int32),    # deg
                pltpu.SMEM((W,), jnp.int32),    # edge index
                pltpu.SMEM((W,), jnp.int32),    # v_next
                pltpu.SMEM((W,), jnp.int32),    # terminated
                pltpu.SMEM((2, 2), jnp.int32),   # row-access DMA buf
                pltpu.SemaphoreType.DMA((2,)),
                pltpu.SMEM((2, 1), jnp.int32),   # column DMA buf
                pltpu.SemaphoreType.DMA((2,)),
                pltpu.SMEM((2, 1), jnp.float32),  # alias-prob DMA buf
                pltpu.SemaphoreType.DMA((2,)),
                pltpu.SMEM((2, 1), jnp.int32),   # alias-idx DMA buf
                pltpu.SemaphoreType.DMA((2,)),
                pltpu.SMEM((2, 1), jnp.int32),   # path write staging (x2)
                pltpu.SemaphoreType.DMA((2,)),
                pltpu.SMEM((2, 2), jnp.int32),   # in-flight write (q, h)
                pltpu.SMEM((1,), jnp.int32),     # write counter
                pltpu.SMEM((2, 2), jnp.int32),   # pair-gather DMA buf
                pltpu.SemaphoreType.DMA((2,)),
                # Second-order scratch (inert (1,) when unused):
                # v_prev segment bounds per lane, bisection lo/hi per
                # breadth-wise probe, rejection's folded key pair /
                # candidate / first-accept flag, the reservoir's SMEM
                # carry (running E-S key + winning offset rides
                # cand_scr), per-chunk uniforms and membership flags,
                # and the ping-pong chunk column/weight DMA buffers.
                pltpu.SMEM((W if second else 1,), jnp.int32),    # plo
                pltpu.SMEM((W if second else 1,), jnp.int32),    # phi
                pltpu.SMEM((BW,), jnp.int32),                    # bisect lo
                pltpu.SMEM((BW,), jnp.int32),                    # bisect hi
                pltpu.SMEM((W if rejection else 1,), jnp.uint32),  # kq0
                pltpu.SMEM((W if rejection else 1,), jnp.uint32),  # kq1
                pltpu.SMEM((W if second else 1,), jnp.int32),    # cand/best
                pltpu.SMEM((W if rejection else 1,), jnp.int32),  # got
                pltpu.SMEM((W if reservoir else 1,), jnp.float32),  # E-S key
                pltpu.SMEM((CH,), jnp.float32),  # per-chunk uniforms
                pltpu.SMEM((CH,), jnp.int32),    # common-neighbor flags
                pltpu.SMEM((2, Lc), jnp.int32),    # chunk column DMA buf
                pltpu.SMEM((2, Lc), jnp.float32),  # chunk weight DMA buf
                pltpu.SemaphoreType.DMA((2, 2)),
                # Gather-hierarchy scratch (inert (1,) when cache off):
                # per-lane probe result (cache slot or -1), coalescing
                # leader, and the direct-mapped tag table (vertex, lane).
                pltpu.SMEM((W if use_cache else 1,), jnp.int32),  # cslot
                pltpu.SMEM((W if use_cache else 1,), jnp.int32),  # leader
                pltpu.SMEM((W if use_cache else 1,), jnp.int32),  # tag v
                pltpu.SMEM((W if use_cache else 1,), jnp.int32),  # tag lane
            ],
            input_output_aliases={len(inputs) - 1: 11},
            interpret=interpret,
        )(*inputs)
        (vcur, vprev, qid, hop, act, ep, qctr_o, hist_o, stats_o,
         done_o, len_o, paths_o) = outs
        return state._replace(
            slots=s._replace(v_curr=vcur, v_prev=vprev, query_id=qid,
                             hop=hop, active=act != 0, epoch=ep),
            queue=q._replace(head=qctr_o[0], staged=qctr_o[1],
                             tail=qctr_o[2]),
            paths=paths_o, lengths=len_o, done=done_o != 0,
            stats=WalkStats(*(stats_o[i] for i in range(_k.NUM_STATS))),
            head_hist=hist_o)

    return launch
