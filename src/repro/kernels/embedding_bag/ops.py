"""Jitted wrapper for the fused embedding-bag kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag import embedding_bag as _k
from repro.kernels.embedding_bag.ref import embedding_bag_ref


@partial(jax.jit, static_argnames=("tile_b", "interpret", "use_kernel"))
def embedding_bag(indices, table, weights=None, tile_b: int = 128,
                  interpret: bool | None = None, use_kernel: bool = True):
    """EmbeddingBag: (B, H) int32 indices (pad -1), (R, D) table ->
    (B, D) weighted bag sums.  ``interpret=None`` → interpret off-TPU."""
    from repro.kernels.common import default_interpret
    interpret = default_interpret(interpret)
    B, H = indices.shape
    if weights is None:
        weights = jnp.ones((B, H), table.dtype)
    if not use_kernel:
        return embedding_bag_ref(indices, weights, table)
    tb = min(tile_b, B)
    Bp = -(-B // tb) * tb
    if Bp != B:
        pad = Bp - B
        indices = jnp.concatenate(
            [indices, jnp.full((pad, H), -1, indices.dtype)])
        weights = jnp.concatenate([weights, jnp.zeros((pad, H), weights.dtype)])
    out = _k.embedding_bag(indices, weights, table, tile_b=tb,
                           interpret=interpret)
    return out[:B]
