"""Oracle: take + masked weighted sum (the jnp EmbeddingBag)."""
import jax.numpy as jnp


def embedding_bag_ref(indices, weights, table):
    safe = jnp.clip(indices, 0, table.shape[0] - 1)
    rows = table[safe]                                  # (B, H, D)
    w = jnp.where(indices >= 0, weights, 0.0)[..., None]
    return jnp.sum(rows * w, axis=1)
