"""Fused embedding-bag Pallas TPU kernel (recsys lookup hot path).

JAX has no native ``EmbeddingBag``; the framework-level fallback is
``take`` + ``segment_sum``.  This kernel fuses the two: for each bag it
streams the hot rows out of the HBM-resident table with double-buffered
async DMAs (the same outstanding-request discipline as the walk-step
kernel — embedding lookup *is* the random-access regime the paper
optimizes) and accumulates in VMEM, so gathered rows never round-trip
through HBM.

Layout: bags are fixed-width multi-hot (B, H) index matrices padded with
-1 (quotient-remainder-style preprocessed upstream); out (B, D) is the
weighted sum of table rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import ScheduleBuilder


def dma_schedule(tile_b: int = 2, hots: int = 2):
    """Declarative DMA schedule of one embedding-bag tile, for the static
    hazard analyzer (`repro.analysis.dma_hazards`).

    The kernel is a single double-buffered gather over the flattened
    ``tile_b * hots`` (bag, hot) pairs — the `gather_loop` shape with
    row k+1's table-row fetch in flight while row k is accumulated.
    Keep in sync with `_kernel`.
    """
    b = ScheduleBuilder()
    b.gather_loop("rowbuf", tile_b * hots)
    return b.ops


def _kernel(num_rows, hots,
            idx_ref, w_ref,      # SMEM (TILE_B, H)
            table_ref,           # ANY (HBM) (R, D)
            out_ref,             # VMEM (TILE_B, D)
            acc, rowbuf, sem):
    tile_b = idx_ref.shape[0]
    n = tile_b * hots

    def copy(k, slot):
        i, h = k // hots, k % hots
        r = jnp.clip(idx_ref[i, h], 0, num_rows - 1)
        return pltpu.make_async_copy(table_ref.at[pl.ds(r, 1), :],
                                     rowbuf.at[slot], sem.at[slot])

    def body(k, _):
        i, h = k // hots, k % hots
        slot = jax.lax.rem(k, 2)

        @pl.when(k + 1 < n)
        def _():
            copy(k + 1, jax.lax.rem(k + 1, 2)).start()

        copy(k, slot).wait()
        w = jnp.where(idx_ref[i, h] >= 0, w_ref[i, h], 0.0)

        @pl.when(h == 0)
        def _():
            acc[0, :] = rowbuf[slot, 0, :] * w

        @pl.when(h != 0)
        def _():
            acc[0, :] = acc[0, :] + rowbuf[slot, 0, :] * w

        @pl.when(h == hots - 1)
        def _():
            out_ref[i, :] = acc[0, :]

        return 0

    copy(0, 0).start()
    jax.lax.fori_loop(0, n, body, 0, unroll=False)


def embedding_bag(indices, weights, table, *, tile_b: int = 128,
                  interpret: bool = True):
    """out[b] = Σ_h weights[b,h] · table[indices[b,h]]  (indices<0 skipped)."""
    B, H = indices.shape
    R, D = table.shape
    tb = min(tile_b, B)
    assert B % tb == 0, (B, tb)
    kernel = functools.partial(_kernel, R, H)
    return pl.pallas_call(
        kernel,
        grid=(B // tb,),
        in_specs=[pl.BlockSpec((tb, H), lambda t: (t, 0),
                               memory_space=pltpu.SMEM),
                  pl.BlockSpec((tb, H), lambda t: (t, 0),
                               memory_space=pltpu.SMEM),
                  pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec((tb, D), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((B, D), table.dtype),
        scratch_shapes=[pltpu.VMEM((1, D), table.dtype),
                        pltpu.VMEM((2, 1, D), table.dtype),
                        pltpu.SemaphoreType.DMA((2,))],
        interpret=interpret,
    )(indices, weights, table)
