from repro.kernels.segment_sum.ops import SegmentSumOp, segment_sum
