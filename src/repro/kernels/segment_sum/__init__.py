from repro.kernels.segment_sum.ops import segment_sum, SegmentSumOp
