"""Public wrapper for the tiled segment-sum kernel.

For static graphs the tiling plan (host-side numpy over the sorted segment
ids) is computed once and reused every step; `SegmentSumOp` caches it.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.segment_sum import segment_sum as _k
from repro.kernels.segment_sum.ref import segment_sum_ref


class SegmentSumOp:
    """Pre-planned segment sum for a fixed (sorted) segment-id vector."""

    def __init__(self, segment_ids: np.ndarray, num_segments: int,
                 tile_e: int = 256, row_block: int = 128,
                 interpret: bool | None = None, use_kernel: bool = True):
        from repro.kernels.common import default_interpret
        seg = np.asarray(segment_ids)
        assert (np.diff(seg) >= 0).all(), "segment_ids must be sorted"
        self.num_segments = int(num_segments)
        self.tile_e = tile_e
        self.row_block = row_block
        self.interpret = default_interpret(interpret)
        self.use_kernel = use_kernel
        self.seg = jnp.asarray(seg, jnp.int32)
        self.plan = _k.plan_tiles(seg, self.num_segments, tile_e, row_block)

    def __call__(self, data: jnp.ndarray) -> jnp.ndarray:
        if not self.use_kernel:
            return segment_sum_ref(data, self.seg, self.num_segments)
        return _k.segment_sum_sorted(
            data, self.seg, self.num_segments, self.plan,
            tile_e=self.tile_e, row_block=self.row_block,
            interpret=self.interpret)


def segment_sum(data, segment_ids, num_segments: int, *, tile_e: int = 256,
                row_block: int = 128, interpret: bool | None = None):
    """One-shot convenience API (sorts edges if unsorted)."""
    seg = np.asarray(segment_ids)
    order = None
    if not (np.diff(seg) >= 0).all():
        order = np.argsort(seg, kind="stable")
        seg = seg[order]
        data = data[jnp.asarray(order)]
    op = SegmentSumOp(seg, num_segments, tile_e, row_block, interpret)
    return op(data)
