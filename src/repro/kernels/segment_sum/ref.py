"""Oracle: jax.ops.segment_sum."""
import jax


def segment_sum_ref(data, segment_ids, num_segments: int):
    return jax.ops.segment_sum(data, segment_ids, num_segments=num_segments)
