"""Tiled MXU-friendly segment-sum Pallas TPU kernel (GNN message scatter).

The GNN message-passing hot path is ``out[s] += data[e]`` over an
edge-index sorted by destination segment.  The TPU-native formulation
turns the scatter into a sequence of small one-hot matmuls (MXU work)
instead of per-row dynamic stores:

  * edges are tiled (``TILE_E``); destination rows are tiled (``ROW_BLOCK``);
  * per edge tile, only the row blocks its segment range touches are
    visited (host precomputes lo/hi block per tile → scalar prefetch, so
    the output BlockSpec ``index_map`` is data-dependent);
  * partial = one_hot(seg - r·RB) @ data_tile — an (RB × TILE_E)·(TILE_E × D)
    matmul per visited block;
  * because segments are sorted, the visited output-block sequence is
    monotone nondecreasing → revisits are always consecutive (the Pallas
    TPU requirement for output revisiting); ``first_visit`` flags select
    init-vs-accumulate.

Empty row blocks (no incident edges) are never visited; the wrapper masks
them to zero.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import ScheduleBuilder


def dma_schedule(seg_sorted=None, num_segments: int = 8, tile_e: int = 4,
                 row_block: int = 2):
    """Declarative output-visit schedule of one segment-sum launch, for
    the static hazard analyzer (`repro.analysis.dma_hazards`).

    This kernel issues no explicit async copies — its hazard surface is
    the Pallas TPU output-revisit contract: the grid-ordered sequence of
    output blocks chosen by the data-dependent ``index_map`` must revisit
    each block only consecutively (monotone, because segments are
    sorted), and ``first_visit`` must flag exactly the first visit of
    each block (init-vs-accumulate).  The schedule replays `plan_tiles`
    over a representative sorted segment vector (or a caller-supplied
    one) and emits one ``visit`` op per (edge-tile, block-slot) grid
    point, mirroring `_kernel`'s ``r`` / ``live`` / ``first`` logic.
    """
    if seg_sorted is None:
        # Representative fixture: skewed sorted segments spanning several
        # row blocks, with an empty segment (3) and a block-crossing tile.
        seg_sorted = np.array([0, 0, 0, 1, 2, 2, 4, 4, 5, 6, 6, 7],
                              np.int64)
    seg_sorted = np.asarray(seg_sorted)
    lo, hi, first, _covered, T, L, _Ep = plan_tiles(
        seg_sorted, num_segments, tile_e, row_block)
    b = ScheduleBuilder()
    for t in range(T):
        for l in range(L):
            r = min(int(lo[t]) + l, int(hi[t]))
            live = int(lo[t]) + l <= int(hi[t])
            b.visit("out", r, first=bool(first[t, l]), live=live)
    return b.ops


def _kernel(row_block, tile_e,
            lo_ref, hi_ref, first_ref,  # scalar-prefetch
            seg_ref, dat_ref, out_ref):
    t = pl.program_id(0)
    l = pl.program_id(1)
    r = jnp.minimum(lo_ref[t] + l, hi_ref[t])
    live = (lo_ref[t] + l) <= hi_ref[t]
    seg = seg_ref[...]
    oh = (seg[None, :] - r * row_block ==
          jax.lax.broadcasted_iota(jnp.int32, (row_block, tile_e), 0))
    partial = oh.astype(dat_ref.dtype) @ dat_ref[...]

    @pl.when(first_ref[t, l] == 1)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(live)
    def _():
        out_ref[...] += partial


def plan_tiles(seg_sorted: np.ndarray, num_segments: int, tile_e: int,
               row_block: int):
    """Host-side tiling plan: per-edge-tile touched row-block range,
    first-visit flags, and row coverage mask."""
    E = seg_sorted.shape[0]
    Ep = -(-E // tile_e) * tile_e
    segp = np.concatenate([seg_sorted,
                           np.full(Ep - E, num_segments, np.int64)])
    # Padding edges point at a sentinel segment; give them the last real
    # tile's block so they stay monotone and write nothing (mask below).
    T = Ep // tile_e
    tiles = segp.reshape(T, tile_e)
    lo = np.minimum(tiles[:, 0], num_segments - 1) // row_block
    hi = np.minimum(tiles[:, -1], num_segments - 1) // row_block
    hi = np.maximum(hi, lo)
    L = int((hi - lo).max()) + 1 if T else 1
    first = np.zeros((T, L), np.int32)
    seen = -1
    for t in range(T):
        for l in range(L):
            r = lo[t] + l
            if r <= hi[t] and r > seen:
                seen = r
                first[t, l] = 1
    n_blocks = -(-num_segments // row_block)
    covered = np.zeros(n_blocks, bool)
    for t in range(T):
        covered[lo[t]:hi[t] + 1] = True
    return (lo.astype(np.int32), hi.astype(np.int32), first,
            covered, T, L, Ep)


def segment_sum_sorted(data, seg_sorted, num_segments: int, plan,
                       *, tile_e: int = 256, row_block: int = 128,
                       interpret: bool = True):
    """Segment-sum of ``data`` (E, D) by sorted ``seg_sorted`` (E,).

    ``plan`` comes from `plan_tiles` (host-side, reusable across steps for
    a static graph)."""
    lo, hi, first, covered, T, L, Ep = plan
    E, D = data.shape
    if Ep != E:
        pad = Ep - E
        data = jnp.concatenate([data, jnp.zeros((pad, D), data.dtype)])
        seg_sorted = jnp.concatenate(
            [seg_sorted, jnp.full((pad,), num_segments, seg_sorted.dtype)])
    n_blocks = -(-num_segments // row_block)
    Vp = n_blocks * row_block

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(T, L),
        in_specs=[
            pl.BlockSpec((tile_e,), lambda t, l, lo, hi, fi: (t,)),
            pl.BlockSpec((tile_e, D), lambda t, l, lo, hi, fi: (t, 0)),
        ],
        out_specs=pl.BlockSpec(
            (row_block, D),
            lambda t, l, lo, hi, fi: (jnp.minimum(lo[t] + l, hi[t]), 0)),
    )
    kernel = functools.partial(_kernel, row_block, tile_e)
    out = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Vp, D), data.dtype),
        interpret=interpret,
    )(jnp.asarray(lo), jnp.asarray(hi), jnp.asarray(first),
      seg_sorted.astype(jnp.int32), data)
    mask = jnp.repeat(jnp.asarray(covered), row_block)[:Vp, None]
    out = jnp.where(mask, out, 0)
    return out[:num_segments]
