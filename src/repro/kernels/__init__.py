"""Pallas TPU kernels for the perf-critical random-access hot spots.

Validated in interpret mode on CPU; targeted at TPU (BlockSpec VMEM/SMEM
tiling + async-copy DMA pipelining).  Each kernel ships with ``ops.py``
(jitted wrapper) and ``ref.py`` (pure-jnp oracle).
"""
from repro.kernels.embedding_bag import embedding_bag
from repro.kernels.segment_sum import SegmentSumOp, segment_sum
from repro.kernels.walk_step import walk_step_alias, walk_step_uniform
