"""Pallas TPU kernels for the perf-critical random-access hot spots.

Validated in interpret mode on CPU; compiled on TPU (``interpret``
defaults to ``jax.default_backend() != "tpu"`` — see `common.py`).  Each
kernel ships with ``ops.py`` (jitted wrapper) and ``ref.py`` /
engine-level oracle.
"""
from repro.kernels.common import default_interpret
from repro.kernels.embedding_bag import embedding_bag
from repro.kernels.segment_sum import SegmentSumOp, segment_sum
from repro.kernels.walk_step import walk_step_alias, walk_step_uniform
