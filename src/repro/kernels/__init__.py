"""Pallas TPU kernels for the perf-critical random-access hot spots.

Validated in interpret mode on CPU; targeted at TPU (BlockSpec VMEM/SMEM
tiling + async-copy DMA pipelining).  Each kernel ships with ``ops.py``
(jitted wrapper) and ``ref.py`` (pure-jnp oracle).
"""
from repro.kernels.walk_step import walk_step_uniform, walk_step_alias
from repro.kernels.segment_sum import segment_sum, SegmentSumOp
from repro.kernels.embedding_bag import embedding_bag
