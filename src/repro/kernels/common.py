"""Shared kernel-wrapper plumbing + the declarative DMA-schedule IR.

Every Pallas kernel in this tree that issues asynchronous copies also
*emits* its DMA schedule as data (a ``dma_schedule()`` function next to
the kernel): a flat sequence of :class:`DmaOp` records — copy start,
copy wait, buffer-slot read/write — in the kernel's program order.  The
static analyzer (`repro.analysis.dma_hazards`) builds the dependence
relation over that sequence and proves the two async-pipeline safety
properties RidgeWalker's "perfect pipelining" rests on:

  * every **read** of a staging slot is dominated by the **wait** of the
    copy that filled it (no read-before-arrival), and
  * no slot is **re-issued or overwritten** while a prior copy on it is
    still un-waited (no overwrite-while-in-flight), and every copy is
    drained before the kernel returns.

Double-buffered loops are periodic with period 2 (the slot cycle), so a
schedule unrolled for n ≥ 3 iterations covers every steady-state slot
interaction plus the prologue and drain — the emitters below default to
small unroll counts on that argument.

The `ScheduleBuilder` emitters mirror the generic loop shapes
(`walk_step.row_access_loop`/`gather1_loop`/`gather2_loop`, the fused
kernel's ping-pong chunk loop and delayed-wait write-back); each kernel
composes them into its full schedule.  Keep an emitter and its loop in
the same module so a pipeline change and its declared schedule travel in
one diff.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence, Tuple

import jax


class DmaOp(NamedTuple):
    """One event of a kernel's declared DMA schedule, in program order.

    ``kind``:
      * ``start`` — an async copy (id ``copy``) begins on ``(buffer,
        slot)``; the slot is busy until the matching ``wait``.
      * ``wait``  — the copy ``copy`` on ``(buffer, slot)`` completes.
      * ``read``  — kernel arithmetic consumes ``(buffer, slot)``; legal
        only if the latest inbound copy on the slot has been waited.
      * ``write`` — kernel arithmetic overwrites ``(buffer, slot)`` (the
        write-back staging pattern); legal only with no copy in flight
        on the slot.
      * ``visit`` — an output-block visit (grid-scheduled kernels like
        `segment_sum`, which revisit output blocks instead of issuing
        explicit DMAs); ``slot`` is the block id, ``first`` flags the
        declared init-vs-accumulate bit, ``live`` whether the visit
        actually accumulates.

    ``tier`` names the memory tier the op touches: ``"hbm"`` (the
    default — every async-copy staging buffer) or ``"vmem"`` for the
    hot-vertex cache block, which is launch-resident and therefore never
    the target of a copy.  A ``read`` with ``tier="vmem"`` needs no
    dominating wait (the data is always resident); a ``start`` on a vmem
    buffer is by definition a *phantom copy* — a hit path issuing HBM
    traffic it was built to avoid — and the DMA pass flags it.
    """

    kind: str
    buffer: str
    slot: int
    copy: int = -1
    first: bool = False
    live: bool = True
    tier: str = "hbm"


class ScheduleBuilder:
    """Accumulates a kernel's :class:`DmaOp` sequence with globally
    unique copy ids (buffers are reused across loop instances — ids must
    not be)."""

    def __init__(self):
        self.ops: list[DmaOp] = []
        self._next_copy = 0

    # ---------------------------------------------------------- primitives

    def start(self, buffer: str, slot: int) -> int:
        cid = self._next_copy
        self._next_copy += 1
        self.ops.append(DmaOp("start", buffer, slot, cid))
        return cid

    def wait(self, buffer: str, slot: int, copy: int) -> None:
        self.ops.append(DmaOp("wait", buffer, slot, copy))

    def read(self, buffer: str, slot: int, tier: str = "hbm") -> None:
        self.ops.append(DmaOp("read", buffer, slot, tier=tier))

    def cache_read(self, buffer: str) -> None:
        """A hit-path read of the VMEM-resident hot-vertex cache: no
        copy, no wait — the declarative record of "this gather issued no
        HBM traffic" that the DMA pass verifies cached schedules by."""
        self.read(buffer, 0, tier="vmem")

    def write(self, buffer: str, slot: int) -> None:
        self.ops.append(DmaOp("write", buffer, slot))

    def visit(self, buffer: str, block: int, first: bool,
              live: bool = True) -> None:
        self.ops.append(DmaOp("visit", buffer, block, first=first,
                              live=live))

    # ------------------------------------------------------------ patterns

    def gather_loop(self, buffer: str, n: int = 3) -> None:
        """The double-buffered gather shape shared by `row_access_loop` /
        `gather1_loop` / `gather2_loop`: ``start(0)``; per item *i*,
        prefetch *i+1* into the other slot, then wait and consume *i*."""
        if n <= 0:
            return
        pend = {0: self.start(buffer, 0)}
        for i in range(n):
            if i + 1 < n:
                pend[i + 1] = self.start(buffer, (i + 1) % 2)
            self.wait(buffer, i % 2, pend.pop(i))
            self.read(buffer, i % 2)

    def pingpong_loop(self, buffers: Sequence[str], n: int = 3,
                      reads_per_chunk: int = 1) -> None:
        """The fused kernel's chunk-loop shape: several buffers (column +
        weight) advance through the same slot cycle together, chunk c+1's
        copies in flight while chunk c is consumed ``reads_per_chunk``
        times (the E-S fold reads the staged chunk once per position
        group)."""
        if n <= 0:
            return
        pend = {0: [(b, self.start(b, 0)) for b in buffers]}
        for c in range(n):
            if c + 1 < n:
                pend[c + 1] = [(b, self.start(b, (c + 1) % 2))
                               for b in buffers]
            for b, cid in pend.pop(c):
                self.wait(b, c % 2, cid)
            for _ in range(reads_per_chunk):
                for b in buffers:
                    self.read(b, c % 2)

    def writeback_loop(self, buffer: str, n: int = 4) -> None:
        """The fused kernel's async path write-back shape: per record,
        reclaim the staging slot by waiting its two-records-old store,
        overwrite it, start the outbound copy; drain both slots at the
        end of the launch."""
        pend: list[int] = []
        for c in range(n):
            if c >= 2:
                self.wait(buffer, (c - 2) % 2, pend[c - 2])
            self.write(buffer, c % 2)
            pend.append(self.start(buffer, c % 2))
        for back in (2, 1):
            if n >= back:
                self.wait(buffer, (n - back) % 2, pend[n - back])


def schedule_buffers(ops: Sequence[DmaOp]) -> Tuple[str, ...]:
    """Distinct buffer names referenced by a schedule, in first-use
    order (the docs table and diagnostics name buffers with this)."""
    seen: dict[str, None] = {}
    for op in ops:
        seen.setdefault(op.buffer)
    return tuple(seen)


def default_interpret(interpret: bool | None) -> bool:
    """Resolve a wrapper's per-call ``interpret`` override.

    ``None`` (the default everywhere) means: compile the Pallas kernel on
    a TPU backend, interpret its body elsewhere — so the same call sites
    exercise the real kernels on hardware while CPU CI keeps validating
    them in interpret mode.  Pass an explicit bool to force either.
    """
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret
