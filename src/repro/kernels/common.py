"""Shared kernel-wrapper plumbing."""
from __future__ import annotations

import jax


def default_interpret(interpret: bool | None) -> bool:
    """Resolve a wrapper's per-call ``interpret`` override.

    ``None`` (the default everywhere) means: compile the Pallas kernel on
    a TPU backend, interpret its body elsewhere — so the same call sites
    exercise the real kernels on hardware while CPU CI keeps validating
    them in interpret mode.  Pass an explicit bool to force either.
    """
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret
