"""Online chunk-size adaptation for the walk service (Theorem VI.1).

The service's ``chunk`` — supersteps run per ``stream.advance`` launch —
is the open-system injection delay C of paper §VI-A: while the device
runs a chunk, the host cannot admit arrivals or release finished slots,
so Theorem VI.1's required stage-ahead depth D = W + ceil(mu·C·W) grows
linearly with it.  Too large a chunk starves lanes (arrivals wait at
the host while lanes idle); too small a chunk drowns the run in
host<->device synchronizations.  The right value depends on load, so
:class:`HopsController` closes the loop online, reusing the same
queuing-theory discipline as the engine's stage-ahead watermark:

  * observe the engine's exported occupancy stats over the last window
    (starved-lane ratio = lanes idle *while work existed* — the direct
    Theorem VI.1 violation signal — plus the bubble ratio);
  * **shrink** (halve) the chunk when starvation exceeds the high
    watermark — smaller C restores D <= capacity;
  * **grow** (double) only after ``patience`` consecutive healthy
    windows below the low watermark — fewer host syncs per superstep;
  * clamp to ``[min_chunk, max_chunk]`` always.

The two watermarks plus the patience streak give bounded hysteresis:
a load level sitting between the watermarks never toggles the chunk,
and a single noisy window never triggers growth.  Every decision is
recorded as an :class:`AdaptationEvent`; `WalkService.analyze` exposes
the trace on ``ServiceAnalysis.adaptation``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class AdaptationEvent:
    """One controller decision (also recorded for unchanged windows
    where a decision was *considered*, i.e. a watermark was crossed)."""

    clock: int            # service superstep clock at the decision
    chunk_before: int
    chunk_after: int
    starved_ratio: float  # over the observation window
    bubble_ratio: float
    reason: str           # "shrink" | "grow" | "hold"


@dataclasses.dataclass
class HopsController:
    """Bounded-hysteresis supersteps-per-launch controller.

    Attributes:
      min_chunk / max_chunk: hard bounds on the adapted chunk.
      low_water:  starved ratio below which a window counts as healthy
                  (growth requires ``patience`` such windows in a row).
      high_water: starved ratio above which the chunk shrinks now.
      patience:   consecutive healthy windows required before growing.
    """

    min_chunk: int = 1
    max_chunk: int = 256
    low_water: float = 0.02
    high_water: float = 0.15
    patience: int = 2
    _healthy_streak: int = dataclasses.field(default=0, repr=False)

    def __post_init__(self):
        if not 0 < self.min_chunk <= self.max_chunk:
            raise ValueError(
                f"need 0 < min_chunk <= max_chunk, got "
                f"{self.min_chunk}/{self.max_chunk}")
        if not 0.0 <= self.low_water < self.high_water:
            raise ValueError(
                f"need 0 <= low_water < high_water, got "
                f"{self.low_water}/{self.high_water}")
        if self.patience <= 0:
            raise ValueError(f"patience must be positive, got "
                             f"{self.patience}")

    def clamp(self, chunk: int) -> int:
        """``chunk`` clipped into the controller's bounds."""
        return max(self.min_chunk, min(self.max_chunk, int(chunk)))

    def propose(self, chunk: int, starved_ratio: float,
                bubble_ratio: float, clock: int = 0,
                ) -> Tuple[int, Optional[AdaptationEvent]]:
        """Next chunk given the last window's occupancy stats.

        Returns ``(new_chunk, event)`` — ``event`` is None when neither
        watermark was crossed (pure steady state, nothing recorded).
        """
        chunk = self.clamp(chunk)
        if starved_ratio > self.high_water:
            self._healthy_streak = 0
            new = self.clamp(chunk // 2)
            return new, AdaptationEvent(
                clock, chunk, new, starved_ratio, bubble_ratio,
                "shrink" if new != chunk else "hold")
        if starved_ratio < self.low_water:
            self._healthy_streak += 1
            if self._healthy_streak >= self.patience:
                self._healthy_streak = 0
                new = self.clamp(chunk * 2)
                if new != chunk:
                    return new, AdaptationEvent(
                        clock, chunk, new, starved_ratio, bubble_ratio,
                        "grow")
            return chunk, None
        self._healthy_streak = 0
        return chunk, None
