"""Open-system walk service on the streaming engine (ROADMAP north star).

The closed-system engine drains a fixed query batch; a *service* faces
continuous arrivals from many tenants.  :class:`WalkService` wraps a
persistent walk stream (:class:`repro.walker.WalkStream` on one device or
:class:`repro.walker.ShardedWalkStream` on a device mesh — the service
only speaks the shared stream interface) and alternates two phases, never
recompiling:

  admit   — pop free slots from the stream's ring and inject pending
            requests' start vertices (each walk gets an ``(epoch, qid)``
            identity: the slot id it occupies and that slot's reuse epoch
            — the multi-tenancy bookkeeping),
  run     — advance the engine a *chunk* of ``k`` supersteps, then
            harvest: any request whose every ``(epoch, qid)`` flipped
            ``done`` gets its recorded paths sliced out, its sojourn
            (submit→complete) and admission wait (submit→inject) logged,
            and its slots *released* back to the free ring with
            ``epoch + 1``.

The chunk size is the host-injection granularity: smaller chunks admit
arrivals sooner (lower sojourn) at the cost of more host↔device syncs —
the open-system analogue of the paper's §VI-A injection delay C.

Ring-buffer reclamation means the device buffer holds ``capacity`` *live*
queries and completed slots go around again immediately — there is no
drain barrier anywhere, so lanes stay busy across request boundaries
exactly as Theorem VI.1 prescribes for the closed pool.  Query ids repeat
across occupancies but ``(epoch, qid)`` is unique, and the RNG derivation
is salted with the epoch (`core.rng.task_fold`), keeping samples
independent: epoch ``e`` of slot ``qid`` samples exactly the walk a
closed batch run would sample for query ``qid`` under
``rng.stream_key(seed, e)``, on either backend.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional

import numpy as np

from repro.core.samplers import SamplerSpec
from repro.core.scheduler import ServiceAnalysis, analyze_service
from repro.core.tasks import WalkStats
from repro.core.walk_engine import EngineConfig


@dataclasses.dataclass
class WalkRequest:
    """One tenant request: a batch of walk queries tracked as a unit."""

    request_id: int
    num_walks: int
    qids: Optional[np.ndarray] = None    # slot id per walk, once admitted
    epochs: Optional[np.ndarray] = None  # slot epoch per walk (RNG identity)
    submitted_at: int = -1     # service superstep clock at submit()
    admitted_at: int = -1      # ... at injection into the device slot ring
    completed_at: int = -1     # ... when the last walk terminated
    wall_submitted: float = 0.0
    wall_admitted: float = 0.0
    wall_completed: float = 0.0
    paths: Optional[np.ndarray] = None    # (num_walks, max_hops+1) once done
    lengths: Optional[np.ndarray] = None  # (num_walks,) once done

    @property
    def done(self) -> bool:
        return self.completed_at >= 0

    @property
    def sojourn(self) -> int:
        """Supersteps from submission to completion (open-system latency)."""
        return self.completed_at - self.submitted_at

    @property
    def admission_wait(self) -> int:
        """Supersteps from submission to slot-ring injection — the
        host-side queueing component of the sojourn (waiting for free
        slots); the rest is device time."""
        return self.admitted_at - self.submitted_at

    @property
    def wall_sojourn(self) -> float:
        return self.wall_completed - self.wall_submitted

    @property
    def wall_admission_wait(self) -> float:
        return self.wall_admitted - self.wall_submitted


class WalkService:
    """Multi-tenant streaming walk service over one graph + walk program.

    Typical use (the walker front-end; either backend)::

        svc = walker.compile(WalkProgram.urw(80)).serve(graph)
        rid = svc.submit(start_vertices)        # non-blocking
        svc.step()                              # admit + run one chunk
        req = svc.poll(rid)                     # WalkRequest or None
        reqs = svc.drain()                      # run until all complete

    Construction forms:

    * ``WalkService(stream=walker.stream(g, ...), chunk=16)`` — over a
      prebuilt stream (what ``Walker.serve`` does; works for single and
      sharded streams alike).
    * ``WalkService(graph, program_or_spec, cfg, capacity, chunk, seed)`` —
      legacy direct form; builds a single-device stream internally.
      ``program_or_spec`` may be a :class:`repro.walker.WalkProgram`
      (machine knobs from ``execution``) or a bare
      :class:`~repro.core.SamplerSpec` with an ``cfg``
      :class:`~repro.core.EngineConfig`.
    """

    def __init__(self, graph=None, program=None,
                 cfg: Optional[EngineConfig] = None,
                 capacity: int = 4096, chunk: int = 16, seed: int = 0,
                 execution=None, stream=None, adapt: bool = False,
                 controller=None):
        if stream is None:
            if graph is None or program is None:
                raise ValueError(
                    "WalkService needs either a prebuilt stream= or "
                    "(graph, program) to build one")
            from repro.walker.compile import WalkStream
            from repro.walker.execution import ExecutionConfig
            from repro.walker.program import WalkProgram
            if isinstance(program, SamplerSpec):
                execution = ExecutionConfig.from_engine_config(
                    cfg or EngineConfig())
                program = WalkProgram(spec=program,
                                      max_hops=(cfg or EngineConfig()).max_hops)
            elif execution is None:
                execution = (ExecutionConfig() if cfg is None
                             else ExecutionConfig.from_engine_config(cfg))
            stream = WalkStream(program, execution, graph, capacity, seed)
        self.stream = stream
        self.graph = stream.graph if graph is None else graph
        self.capacity = stream.capacity
        self.chunk = int(chunk)
        self.clock = 0            # total supersteps advanced by this service
        # Online supersteps-per-launch adaptation (Theorem VI.1 loop):
        # observe the last launch's starved/bubble ratios, shrink or grow
        # self.chunk within the controller's bounds (serve.scheduler).
        if controller is not None:
            adapt = True
        self._controller = None
        if adapt:
            from repro.serve.scheduler import HopsController
            self._controller = controller or HopsController()
            self.chunk = self._controller.clamp(self.chunk)
        self._adaptation: List = []
        self._last_window_stats = None

        self._pending: deque[WalkRequest] = deque()   # submitted, not admitted
        self._pending_starts: Dict[int, np.ndarray] = {}
        self._inflight: Dict[int, WalkRequest] = {}
        self._completed: Dict[int, WalkRequest] = {}
        self._next_rid = 0
        self._resets = 0

    # ------------------------------------------------------------ geometry

    @property
    def num_slots(self) -> int:
        """Total walker lanes across devices (service rate capacity)."""
        return self.stream.num_slots

    @property
    def max_hops(self) -> int:
        return self.stream.max_hops

    @property
    def cfg(self):
        """The stream's engine-layer config (EngineConfig or DistConfig)."""
        return self.stream.cfg

    # ------------------------------------------------------------- admission

    def submit(self, start_vertices) -> int:
        """Enqueue a request (a batch of walks); returns its request id."""
        sv = np.asarray(start_vertices, np.int32).reshape(-1)
        if sv.size == 0:
            raise ValueError("empty request")
        if sv.size > self.capacity:
            raise ValueError(
                f"request of {sv.size} walks exceeds slot-ring capacity "
                f"{self.capacity}; split it or raise capacity")
        rid = self._next_rid
        self._next_rid += 1
        req = WalkRequest(request_id=rid, num_walks=int(sv.size),
                          submitted_at=self.clock,
                          wall_submitted=time.perf_counter())
        self._pending.append(req)
        self._pending_starts[rid] = sv
        return rid

    def _admit(self) -> int:
        """FIFO-admit pending requests while free ring slots remain."""
        admitted = 0
        while self._pending:
            req = self._pending[0]
            if req.num_walks > self.stream.num_free:
                break  # head-of-line blocks until enough slots are released
            starts = self._pending_starts[req.request_id]
            req.qids, req.epochs = self.stream.inject(starts)
            req.admitted_at = self.clock
            req.wall_admitted = time.perf_counter()
            self._pending.popleft()
            del self._pending_starts[req.request_id]
            self._inflight[req.request_id] = req
            admitted += 1
        return admitted

    # ------------------------------------------------------------- execution

    def step(self, k: Optional[int] = None) -> int:
        """Admit pending requests, run one chunk of at most ``k``
        supersteps, harvest completions (releasing their slots back to the
        ring).  Returns the number of supersteps executed.

        With an adaptive controller attached (``adapt=True``), each
        launch's occupancy stats feed the Theorem VI.1 chunk controller,
        which may shrink/grow ``self.chunk`` for the *next* launch (an
        explicit ``k`` bypasses adaptation for this launch).
        """
        self._admit()
        if not self._inflight:
            return 0
        ran = self.stream.advance(self.chunk if k is None else int(k))
        self.clock += ran
        self._harvest()
        if self._controller is not None and k is None and ran > 0:
            self._adapt_chunk()
        return ran

    def _adapt_chunk(self) -> None:
        """Feed the last launch's occupancy window to the controller."""
        cur = self.stream.walk_stats()
        prev = self._last_window_stats
        self._last_window_stats = cur
        if prev is None:
            return
        slot_steps = cur.slot_steps - prev.slot_steps
        if slot_steps <= 0:
            return
        starved = (cur.starved - prev.starved) / slot_steps
        bubbles = (cur.bubbles - prev.bubbles) / slot_steps
        new_chunk, event = self._controller.propose(
            self.chunk, starved, bubbles, clock=self.clock)
        if event is not None:
            self._adaptation.append(event)
        self.chunk = new_chunk

    @property
    def adaptation(self) -> tuple:
        """The chunk-adaptation trace so far (AdaptationEvent tuple)."""
        return tuple(self._adaptation)

    def _harvest(self) -> None:
        done = self.stream.done_mask()
        finished: List[WalkRequest] = []
        for req in self._inflight.values():
            if done[req.qids].all():
                finished.append(req)
        for req in finished:
            req.paths, req.lengths = self.stream.harvest_ids(req.qids)
            req.completed_at = self.clock
            req.wall_completed = time.perf_counter()
            self.stream.release(req.qids)   # slots go around again (epoch+1)
            del self._inflight[req.request_id]
            self._completed[req.request_id] = req

    def drain(self) -> List[WalkRequest]:
        """Run until every submitted request has completed."""
        while self._pending or self._inflight:
            ran = self.step()
            if ran == 0 and not self._inflight and self._pending:
                # Admission made no progress with nothing in flight: the
                # ring is fully free, so the head request simply cannot fit.
                raise RuntimeError("service stalled: pending request cannot "
                                   "be admitted")
        return sorted(self._completed.values(),
                      key=lambda r: r.request_id)

    def reset_metrics(self) -> None:
        """Forget completed-request records and engine counters while
        keeping the compiled superstep runner warm (benchmark sweeps time
        several load points against one service without re-tracing XLA).
        The stream is re-seeded so successive sweeps draw fresh walks."""
        if self._pending or self._inflight:
            raise RuntimeError("reset_metrics with requests outstanding")
        self._resets += 1
        self.stream.reset(seed=self.stream.seed + 1)
        self.clock = 0
        self._completed.clear()
        self._adaptation.clear()
        self._last_window_stats = None

    # ------------------------------------------------------------ inspection

    def poll(self, request_id: int) -> Optional[WalkRequest]:
        """The completed WalkRequest, or None while still in flight."""
        return self._completed.get(request_id)

    def result(self, request_id: int) -> WalkRequest:
        """Block (stepping the engine) until ``request_id`` completes."""
        if (request_id not in self._completed
                and request_id not in self._inflight
                and all(r.request_id != request_id for r in self._pending)):
            raise KeyError(f"unknown request id {request_id}")
        while request_id not in self._completed:
            self.step()
        return self._completed[request_id]

    @property
    def num_pending(self) -> int:
        return len(self._pending)

    @property
    def num_inflight(self) -> int:
        return len(self._inflight)

    @property
    def num_free_slots(self) -> int:
        return self.stream.num_free

    def walk_stats(self) -> WalkStats:
        """Engine counters since construction / reset (host ints)."""
        return self.stream.walk_stats()

    def sojourns(self) -> List[int]:
        return [r.sojourn for r in self._completed.values()]

    def admission_waits(self) -> List[int]:
        return [r.admission_wait for r in self._completed.values()]

    def analyze(self, offered_load: float = float("nan"),
                wall_time_s: Optional[float] = None) -> ServiceAnalysis:
        reqs = list(self._completed.values())
        mean_len = (float(np.mean([r.lengths.mean() for r in reqs]))
                    if reqs else float("nan"))
        return analyze_service(
            self.sojourns(), self.walk_stats(), self.num_slots,
            offered_load=offered_load, mean_walk_len=mean_len,
            wall_time_s=wall_time_s,
            admission_waits=self.admission_waits(),
            adaptation=self.adaptation)
