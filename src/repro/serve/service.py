"""Open-system walk service on the streaming engine (ROADMAP north star).

The closed-system engine (`core.walk_engine.make_engine`) drains a fixed
query batch; a *service* faces continuous arrivals from many tenants.
:class:`WalkService` keeps a persistent :class:`~repro.core.StreamState` on
device and alternates two phases, never recompiling:

  admit   — append pending requests' start vertices at the queue tail
            (``inject_queries``; each request owns a contiguous query-id
            range, the multi-tenancy bookkeeping),
  run     — advance the engine a *chunk* of ``k`` supersteps
            (``run_supersteps``), then harvest: any request whose whole
            query-id range flipped ``done`` gets its recorded paths sliced
            out and its sojourn (submit→complete, in supersteps) logged.

The chunk size is the host-injection granularity: smaller chunks admit
arrivals sooner (lower sojourn) at the cost of more host↔device syncs —
the open-system analogue of the paper's §VI-A injection delay C.

The device buffer holds ``capacity`` queries per *generation*.  When the
buffer is exhausted and all in-flight walks have drained, the service
rotates to a fresh state (generation += 1) with a distinct RNG seed, so an
unbounded request stream is served with bounded device memory.  Query ids
repeat across generations but ``(generation, qid)`` is unique — and walks
in different generations use different seeds, keeping samples independent.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.samplers import SamplerSpec
from repro.core.scheduler import ServiceAnalysis, analyze_service
from repro.core.tasks import WalkStats
from repro.core.walk_engine import (EngineConfig, init_stream_state,
                                    inject_queries, make_superstep_runner)


@dataclasses.dataclass
class WalkRequest:
    """One tenant request: a batch of walk queries tracked as a unit."""

    request_id: int
    num_walks: int
    generation: int = -1
    qid_lo: int = -1           # query-id range [qid_lo, qid_hi) in its generation
    qid_hi: int = -1
    submitted_at: int = -1     # service superstep clock at submit()
    admitted_at: int = -1      # ... at injection into the device queue
    completed_at: int = -1     # ... when the last walk terminated
    wall_submitted: float = 0.0
    wall_completed: float = 0.0
    paths: Optional[np.ndarray] = None    # (num_walks, max_hops+1) once done
    lengths: Optional[np.ndarray] = None  # (num_walks,) once done

    @property
    def done(self) -> bool:
        return self.completed_at >= 0

    @property
    def sojourn(self) -> int:
        """Supersteps from submission to completion (open-system latency)."""
        return self.completed_at - self.submitted_at

    @property
    def wall_sojourn(self) -> float:
        return self.wall_completed - self.wall_submitted


def _pad_block(n: int, floor: int = 16) -> int:
    """Next power of two >= n (>= floor): bounds distinct inject shapes to
    O(log capacity) jit specializations."""
    b = floor
    while b < n:
        b <<= 1
    return b


class WalkService:
    """Multi-tenant streaming walk service over one graph + sampler spec.

    Typical use (the walker front-end)::

        svc = walker.compile(WalkProgram.urw(80)).serve(graph)
        rid = svc.submit(start_vertices)        # non-blocking
        svc.step()                              # admit + run one chunk
        req = svc.poll(rid)                     # WalkRequest or None
        reqs = svc.drain()                      # run until all complete

    ``program`` may be a :class:`repro.walker.WalkProgram` (preferred;
    machine knobs come from ``execution``) or a bare
    :class:`~repro.core.SamplerSpec` with a legacy ``cfg``
    :class:`~repro.core.EngineConfig`.
    """

    def __init__(self, graph, program, cfg: Optional[EngineConfig] = None,
                 capacity: int = 4096, chunk: int = 16, seed: int = 0,
                 execution=None):
        if isinstance(program, SamplerSpec):
            spec = program
            cfg = cfg or EngineConfig()
        else:  # WalkProgram
            spec = program.spec
            if cfg is None:
                from repro.walker.execution import ExecutionConfig
                cfg = (execution or ExecutionConfig()).engine_config(program)
        if not cfg.record_paths:
            # Harvesting slices recorded paths; recording is mandatory here.
            cfg = dataclasses.replace(cfg, record_paths=True)
        self.graph = graph
        self.spec = spec
        self.cfg = cfg
        self.capacity = int(capacity)
        self.chunk = int(chunk)
        self._base_seed = int(seed)
        self._run = make_superstep_runner(spec, cfg)

        self.generation = 0
        self._state = init_stream_state(cfg, self.capacity)
        self._tail = 0            # host mirror of queue.tail (admission check)
        self._gen_supersteps = 0  # supersteps inside the current generation
        self.clock = 0            # total supersteps across generations

        self._pending: deque[WalkRequest] = deque()   # submitted, not admitted
        self._pending_starts: Dict[int, np.ndarray] = {}
        self._inflight: Dict[int, WalkRequest] = {}
        self._completed: Dict[int, WalkRequest] = {}
        self._next_rid = 0
        # WalkStats accumulated from rotated-out generations (host ints).
        self._stats_base = {f: 0 for f in WalkStats._fields}

    # ------------------------------------------------------------- admission

    def submit(self, start_vertices) -> int:
        """Enqueue a request (a batch of walks); returns its request id."""
        sv = np.asarray(start_vertices, np.int32).reshape(-1)
        if sv.size == 0:
            raise ValueError("empty request")
        if sv.size > self.capacity:
            raise ValueError(
                f"request of {sv.size} walks exceeds buffer capacity "
                f"{self.capacity}; split it or raise capacity")
        rid = self._next_rid
        self._next_rid += 1
        req = WalkRequest(request_id=rid, num_walks=int(sv.size),
                          submitted_at=self.clock,
                          wall_submitted=time.perf_counter())
        self._pending.append(req)
        self._pending_starts[rid] = sv
        return rid

    def _seed(self) -> int:
        return self._base_seed + self.generation

    def _block_for(self, n: int) -> int:
        """Injection block size: power of two, capped at the full buffer, so
        `inject_queries` compiles O(log capacity) shapes — never the
        arbitrary residual room at the end of a generation."""
        return min(_pad_block(n), self.capacity)

    def _admit(self) -> int:
        """FIFO-admit pending requests while buffer room remains."""
        admitted = 0
        while self._pending:
            req = self._pending[0]
            n = req.num_walks
            block = self._block_for(n)
            if self._tail + block > self.capacity:  # no room this generation
                break
            starts = self._pending_starts[req.request_id]
            padded = np.zeros((block,), np.int32)
            padded[:n] = starts
            self._state = inject_queries(self._state, jnp.asarray(padded), n)
            req.generation = self.generation
            req.qid_lo, req.qid_hi = self._tail, self._tail + n
            req.admitted_at = self.clock
            self._tail += n
            self._pending.popleft()
            del self._pending_starts[req.request_id]
            self._inflight[req.request_id] = req
            admitted += 1
        return admitted

    def _maybe_rotate(self) -> None:
        """Start a fresh generation once the buffer is spent and drained."""
        if self._inflight or not self._pending:
            return
        n = self._pending[0].num_walks
        if self._tail + self._block_for(n) <= self.capacity:
            return  # head request still fits — no rotation needed
        for f in WalkStats._fields:
            self._stats_base[f] += int(getattr(self._state.stats, f))
        self.generation += 1
        self._state = init_stream_state(self.cfg, self.capacity)
        self._tail = 0
        self._gen_supersteps = 0

    # ------------------------------------------------------------- execution

    def step(self, k: Optional[int] = None) -> int:
        """Admit pending requests, run one chunk of at most ``k`` supersteps,
        harvest completions.  Returns the number of supersteps executed."""
        self._maybe_rotate()
        self._admit()
        if not self._inflight:
            return 0
        k = self.chunk if k is None else int(k)
        self._state = self._run(self.graph, self._state, self._seed(), k)
        now = int(self._state.stats.supersteps)       # device→host sync point
        ran = now - self._gen_supersteps
        self._gen_supersteps = now
        self.clock += ran
        self._harvest()
        return ran

    def _harvest(self) -> None:
        done = np.asarray(self._state.done)
        finished: List[WalkRequest] = []
        for req in self._inflight.values():
            if done[req.qid_lo:req.qid_hi].all():
                finished.append(req)
        for req in finished:
            sl = slice(req.qid_lo, req.qid_hi)
            req.paths = np.asarray(self._state.paths[sl])
            req.lengths = np.asarray(self._state.lengths[sl])
            req.completed_at = self.clock
            req.wall_completed = time.perf_counter()
            del self._inflight[req.request_id]
            self._completed[req.request_id] = req

    def drain(self) -> List[WalkRequest]:
        """Run until every submitted request has completed."""
        while self._pending or self._inflight:
            ran = self.step()
            if ran == 0 and not self._pending and not self._inflight:
                break
            if ran == 0 and not self._inflight and self._pending:
                # Only possible if rotation+admission made no progress.
                raise RuntimeError("service stalled: pending request cannot "
                                   "be admitted")
        return sorted(self._completed.values(),
                      key=lambda r: r.request_id)

    def reset_metrics(self) -> None:
        """Forget completed-request records and engine counters while keeping
        the compiled superstep runner warm (benchmark sweeps time several
        load points against one service without re-tracing XLA)."""
        if self._pending or self._inflight:
            raise RuntimeError("reset_metrics with requests outstanding")
        self.generation += 1          # keep per-generation RNG streams fresh
        self._state = init_stream_state(self.cfg, self.capacity)
        self._tail = 0
        self._gen_supersteps = 0
        self.clock = 0
        self._completed.clear()
        self._stats_base = {f: 0 for f in WalkStats._fields}

    # ------------------------------------------------------------ inspection

    def poll(self, request_id: int) -> Optional[WalkRequest]:
        """The completed WalkRequest, or None while still in flight."""
        return self._completed.get(request_id)

    def result(self, request_id: int) -> WalkRequest:
        """Block (stepping the engine) until ``request_id`` completes."""
        if (request_id not in self._completed
                and request_id not in self._inflight
                and all(r.request_id != request_id for r in self._pending)):
            raise KeyError(f"unknown request id {request_id}")
        while request_id not in self._completed:
            self.step()
        return self._completed[request_id]

    @property
    def num_pending(self) -> int:
        return len(self._pending)

    @property
    def num_inflight(self) -> int:
        return len(self._inflight)

    def walk_stats(self) -> WalkStats:
        """Engine counters accumulated across all generations (host ints)."""
        return WalkStats(**{
            f: self._stats_base[f] + int(getattr(self._state.stats, f))
            for f in WalkStats._fields})

    def sojourns(self) -> List[int]:
        return [r.sojourn for r in self._completed.values()]

    def analyze(self, offered_load: float = float("nan"),
                wall_time_s: Optional[float] = None) -> ServiceAnalysis:
        reqs = list(self._completed.values())
        mean_len = (float(np.mean([r.lengths.mean() for r in reqs]))
                    if reqs else float("nan"))
        return analyze_service(
            self.sojourns(), self.walk_stats(), self.cfg.num_slots,
            offered_load=offered_load, mean_walk_len=mean_len,
            wall_time_s=wall_time_s)
