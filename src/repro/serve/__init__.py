"""Open-system walk serving: continuous request arrival over a persistent
walk stream (`repro.walker.WalkStream` / `ShardedWalkStream` — ring-buffer
slot reclamation, either backend), with optional online chunk adaptation
(`repro.serve.scheduler.HopsController`)."""
from repro.serve.scheduler import AdaptationEvent, HopsController
from repro.serve.service import WalkRequest, WalkService
from repro.serve.workload import OpenLoad, run_open_load

__all__ = ["AdaptationEvent", "HopsController", "WalkRequest",
           "WalkService", "OpenLoad", "run_open_load"]
