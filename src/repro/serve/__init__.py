"""Open-system walk serving: continuous request arrival over the streaming
engine (`core.walk_engine.make_superstep_runner`)."""
from repro.serve.service import WalkRequest, WalkService
from repro.serve.workload import OpenLoad, run_open_load

__all__ = ["WalkRequest", "WalkService", "OpenLoad", "run_open_load"]
