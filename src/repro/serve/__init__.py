"""Open-system walk serving: continuous request arrival over a persistent
walk stream (`repro.walker.WalkStream` / `ShardedWalkStream` — ring-buffer
slot reclamation, either backend)."""
from repro.serve.service import WalkRequest, WalkService
from repro.serve.workload import OpenLoad, run_open_load

__all__ = ["WalkRequest", "WalkService", "OpenLoad", "run_open_load"]
