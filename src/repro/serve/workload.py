"""Synthetic open-system workloads for the walk service.

Poisson arrivals at a target *offered load* λ (walks/superstep), expressed
relative to the lane service capacity: with W lanes and mean walk length
E[L], the system completes ~W/E[L] walks per superstep, so utilization
ρ = λ·E[L]/W.  Sweeping ρ past 1.0 drives the service into overload —
the regime where sojourn time diverges (Theorem VI.1's queue keeps *lanes*
busy; it cannot create capacity).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from repro.core.scheduler import ServiceAnalysis
from repro.serve.service import WalkService


@dataclasses.dataclass(frozen=True)
class OpenLoad:
    """Poisson request arrivals against a WalkService."""

    num_requests: int = 64        # total requests to offer
    request_size: int = 16        # walks per request
    utilization: float = 0.5      # ρ — target fraction of lane capacity
    mean_walk_len: Optional[float] = None  # E[L]; default svc.max_hops

    def walks_per_superstep(self, svc) -> float:
        """λ for target ρ; ``svc`` is a WalkService (or anything exposing
        ``num_slots``/``max_hops`` — works across both backends)."""
        mean_len = self.mean_walk_len or float(svc.max_hops)
        return self.utilization * svc.num_slots / mean_len


def run_open_load(svc: WalkService, load: OpenLoad,
                  seed: int = 0) -> ServiceAnalysis:
    """Drive ``svc`` with Poisson arrivals and drain; returns the analysis.

    Arrivals are generated chunk-by-chunk on the *superstep* clock: each
    iteration submits ``Poisson(λ·t / request_size)`` requests, where ``t``
    is the number of supersteps the previous chunk actually executed (the
    engine stops early when work drains, and an idle chunk counts as a full
    ``chunk`` of elapsed time).  Chunk granularity is thus part of the
    measured sojourn — the honest cost of host-side injection.
    """
    rng = np.random.default_rng(seed)
    lam = load.walks_per_superstep(svc)
    nv = svc.graph.num_vertices

    t0 = time.perf_counter()
    submitted = 0
    elapsed = svc.chunk  # supersteps of arrival time covered this iteration
    while submitted < load.num_requests:
        n_req = int(rng.poisson(lam * elapsed / load.request_size))
        for _ in range(min(n_req, load.num_requests - submitted)):
            starts = rng.integers(0, nv, load.request_size).astype(np.int32)
            svc.submit(starts)
            submitted += 1
        ran = svc.step()
        elapsed = ran if ran > 0 else svc.chunk
    svc.drain()
    dt = time.perf_counter() - t0
    return svc.analyze(offered_load=lam, wall_time_s=dt)
