"""The paper's own workload configs: GRW algorithms x graph datasets
(Table II / §VIII-A4). Used by benchmarks and the bonus walk dry-run."""
from repro.core.samplers import SamplerSpec
from repro.core.walk_engine import EngineConfig

FAMILY = "walk"
ALGORITHMS = {
    "urw": SamplerSpec(kind="uniform"),
    "ppr": SamplerSpec(kind="uniform", stop_prob=0.15),
    "deepwalk": SamplerSpec(kind="alias"),
    "node2vec": SamplerSpec(kind="rejection_n2v", p=2.0, q=0.5),
    "node2vec_w": SamplerSpec(kind="reservoir_n2v", p=2.0, q=0.5),
}
QUERY_LENGTH = 80          # paper §VIII-A4
ENGINE = EngineConfig(num_slots=4096, max_hops=QUERY_LENGTH,
                      record_paths=False)
DATASETS = ("WG", "CP", "AS", "LJ", "AB", "UK")
