"""Architecture registry scaffolding.

Each ``configs/<id>.py`` exposes:
  FAMILY — "lm" | "gnn" | "recsys"
  FULL   — the exact published configuration (dry-run only; never allocated)
  SMOKE  — a reduced same-family configuration for CPU smoke tests
  SHAPES — the arch's own input-shape set (name -> shape dict)

Shape-cell semantics (assignment):
  LM:   train_* lowers train_step; prefill_* lowers serve_prefill;
        decode_* / long_* lower serve_step (1 new token vs a seq_len cache).
  GNN:  all shapes lower train_step on the given graph shape.
  recsys: train_batch lowers train_step; serve_* lower predict;
        retrieval_cand lowers retrieval scoring.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str              # train | prefill | decode | serve | retrieval
    dims: Dict[str, Any]


LM_SHAPES = {
    "train_4k": ShapeCell("train_4k", "train",
                          dict(seq_len=4096, global_batch=256)),
    "prefill_32k": ShapeCell("prefill_32k", "prefill",
                             dict(seq_len=32768, global_batch=32)),
    "decode_32k": ShapeCell("decode_32k", "decode",
                            dict(seq_len=32768, global_batch=128)),
    "long_500k": ShapeCell("long_500k", "decode",
                           dict(seq_len=524288, global_batch=1)),
}

GNN_SHAPES = {
    "full_graph_sm": ShapeCell("full_graph_sm", "train",
                               dict(n_nodes=2708, n_edges=10556, d_feat=1433)),
    "minibatch_lg": ShapeCell("minibatch_lg", "train",
                              dict(n_nodes=232_965, n_edges=114_615_892,
                                   batch_nodes=1024, fanout=(15, 10))),
    "ogb_products": ShapeCell("ogb_products", "train",
                              dict(n_nodes=2_449_029, n_edges=61_859_140,
                                   d_feat=100)),
    "molecule": ShapeCell("molecule", "train",
                          dict(n_nodes=30, n_edges=64, batch=128)),
}

RECSYS_SHAPES = {
    "train_batch": ShapeCell("train_batch", "train", dict(batch=65_536)),
    "serve_p99": ShapeCell("serve_p99", "serve", dict(batch=512)),
    "serve_bulk": ShapeCell("serve_bulk", "serve", dict(batch=262_144)),
    "retrieval_cand": ShapeCell("retrieval_cand", "retrieval",
                                dict(batch=1, n_candidates=1_000_000)),
}
