"""minitron-8b [arXiv:2407.14679]: 32L d_model=4096 32H (GQA kv=8)
d_ff=16384 vocab=256000 — pruned nemotron."""
from repro.configs.base import LM_SHAPES
from repro.models.transformer import TransformerConfig

FAMILY = "lm"
SHAPES = LM_SHAPES
FULL = TransformerConfig(
    name="minitron-8b", n_layers=32, d_model=4096, n_heads=32,
    n_kv_heads=8, d_ff=16384, vocab=256000,
)
SMOKE = TransformerConfig(
    name="minitron-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=256, vocab=200,
)
