"""phi3.5-moe-42b-a6.6b [hf:microsoft/Phi-3.5-MoE-instruct]:
32L d_model=4096 32H (GQA kv=8) d_ff=6400 vocab=32064, MoE 16 experts top-2."""
from repro.configs.base import LM_SHAPES
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig

FAMILY = "lm"
SHAPES = LM_SHAPES
FULL = TransformerConfig(
    name="phi3.5-moe-42b-a6.6b", n_layers=32, d_model=4096, n_heads=32,
    n_kv_heads=8, d_ff=6400, vocab=32064,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff=6400,
                  expert_sharding="expert"),
)
SMOKE = TransformerConfig(
    name="phi3.5-moe-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=96, vocab=128,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff=96, expert_sharding="expert"),
)
