"""meshgraphnet [arXiv:2010.03409]: 15L d_hidden=128 sum agg, 2-layer MLPs."""
from repro.configs.base import GNN_SHAPES
from repro.models.gnn.meshgraphnet import MeshGraphNetConfig

FAMILY = "gnn"
SHAPES = GNN_SHAPES
FULL = MeshGraphNetConfig(n_layers=15, d_hidden=128, mlp_layers=2)
SMOKE = MeshGraphNetConfig(n_layers=3, d_hidden=32, mlp_layers=2,
                           node_in=8, edge_in=4)
