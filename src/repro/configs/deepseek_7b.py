"""deepseek-7b [arXiv:2401.02954]: 30L d_model=4096 32H (MHA, kv=32)
d_ff=11008 vocab=102400 — llama-arch."""
from repro.configs.base import LM_SHAPES
from repro.models.transformer import TransformerConfig

FAMILY = "lm"
SHAPES = LM_SHAPES
FULL = TransformerConfig(
    name="deepseek-7b", n_layers=30, d_model=4096, n_heads=32,
    n_kv_heads=32, d_ff=11008, vocab=102400,
)
SMOKE = TransformerConfig(
    name="deepseek-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=172, vocab=160,
)
