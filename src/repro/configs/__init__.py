"""Architecture registry: --arch <id> resolves here."""
import importlib

ARCHS = (
    "phi35_moe", "granite_moe", "deepseek_7b", "minitron_8b", "stablelm_12b",
    "meshgraphnet", "schnet", "pna", "mace", "dcn_v2",
)

ALIASES = {
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "granite-moe-3b-a800m": "granite_moe",
    "deepseek-7b": "deepseek_7b",
    "minitron-8b": "minitron_8b",
    "stablelm-12b": "stablelm_12b",
    "dcn-v2": "dcn_v2",
}


def get_arch(name: str):
    name = ALIASES.get(name, name).replace("-", "_")
    assert name in ARCHS or name == "ridgewalker", f"unknown arch {name}"
    return importlib.import_module(f"repro.configs.{name}")
