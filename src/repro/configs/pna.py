"""pna [arXiv:2004.05718]: 4L d_hidden=75, mean/max/min/std aggregators,
identity/amplification/attenuation scalers."""
from repro.configs.base import GNN_SHAPES
from repro.models.gnn.pna import PNAConfig

FAMILY = "gnn"
SHAPES = GNN_SHAPES
FULL = PNAConfig(n_layers=4, d_hidden=75)
SMOKE = PNAConfig(n_layers=2, d_hidden=16, node_in=8, out_dim=5)
