"""dcn-v2 [arXiv:2008.13535]: n_dense=13 n_sparse=26 embed_dim=16
3 cross layers, MLP 1024-1024-512, cross interaction."""
from repro.configs.base import RECSYS_SHAPES
from repro.models.recsys.dcn import DCNConfig

FAMILY = "recsys"
SHAPES = RECSYS_SHAPES
FULL = DCNConfig()
SMOKE = DCNConfig(mlp_dims=(64, 32), vocab_sizes=tuple([500] * 26))
