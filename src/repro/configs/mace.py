"""mace [arXiv:2206.07697]: 2L d_hidden=128 l_max=2 correlation=3 n_rbf=8,
E(3)-equivariant (Cartesian irreps, see models/gnn/mace.py)."""
from repro.configs.base import GNN_SHAPES
from repro.models.gnn.mace import MACEConfig

FAMILY = "gnn"
SHAPES = GNN_SHAPES
FULL = MACEConfig(n_layers=2, d_hidden=128, l_max=2, correlation=3, n_rbf=8)
SMOKE = MACEConfig(n_layers=2, d_hidden=8, l_max=2, correlation=3, n_rbf=4)
