"""stablelm-12b [hf:stabilityai/stablelm family]: 40L d_model=5120 32H
(GQA kv=8) d_ff=13824 vocab=100352."""
from repro.configs.base import LM_SHAPES
from repro.models.transformer import TransformerConfig

FAMILY = "lm"
SHAPES = LM_SHAPES
FULL = TransformerConfig(
    name="stablelm-12b", n_layers=40, d_model=5120, n_heads=32,
    n_kv_heads=8, d_ff=13824, vocab=100352,
)
SMOKE = TransformerConfig(
    name="stablelm-smoke", n_layers=2, d_model=80, n_heads=4, n_kv_heads=2,
    d_ff=216, vocab=157,
)
