"""granite-moe-3b-a800m [hf:ibm-granite/granite-3.0 family]:
32L d_model=1536 24H (GQA kv=8) d_ff=512 vocab=49155, MoE 40 experts top-8.

Experts (40) don't divide the 16-way model axis -> expert-FFN hidden
sharding (TP over d_ff), see models/moe.py."""
from repro.configs.base import LM_SHAPES
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig

FAMILY = "lm"
SHAPES = LM_SHAPES
FULL = TransformerConfig(
    name="granite-moe-3b-a800m", n_layers=32, d_model=1536, n_heads=24,
    n_kv_heads=8, d_ff=512, vocab=49155,
    moe=MoEConfig(num_experts=40, top_k=8, d_ff=512, expert_sharding="ffn"),
)
SMOKE = TransformerConfig(
    name="granite-moe-smoke", n_layers=2, d_model=48, n_heads=4, n_kv_heads=2,
    d_ff=32, vocab=111,
    moe=MoEConfig(num_experts=5, top_k=3, d_ff=32, expert_sharding="ffn"),
)
