"""schnet [arXiv:1706.08566]: 3 interactions d_hidden=64 rbf=300 cutoff=10."""
from repro.configs.base import GNN_SHAPES
from repro.models.gnn.schnet import SchNetConfig

FAMILY = "gnn"
SHAPES = GNN_SHAPES
FULL = SchNetConfig(n_interactions=3, d_hidden=64, n_rbf=300, cutoff=10.0)
SMOKE = SchNetConfig(n_interactions=2, d_hidden=16, n_rbf=24, cutoff=10.0)
