"""Sparse embedding substrate for recsys (JAX has no native EmbeddingBag).

Tables are row-sharded over the `model` axis; lookup is ``jnp.take`` (+
``segment_sum`` for multi-hot bags), or the fused Pallas
`kernels/embedding_bag` on the serving hot path.  The random-row gather is
the same access regime the paper's asynchronous memory engine targets
(DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class EmbeddingConfig:
    vocab_sizes: tuple          # per-field vocabulary sizes
    embed_dim: int = 16
    combine: str = "concat"     # concat | sum


def init_tables(key, cfg: EmbeddingConfig, dtype=jnp.float32):
    keys = jax.random.split(key, len(cfg.vocab_sizes))
    return {
        f"table_{i}": jax.random.normal(k, (v, cfg.embed_dim), dtype) * 0.01
        for i, (k, v) in enumerate(zip(keys, cfg.vocab_sizes))
    }


def lookup(tables, sparse_ids, cfg: EmbeddingConfig):
    """sparse_ids: (B, F) single-hot per field -> (B, F·D) or (B, D)."""
    outs = []
    for i in range(sparse_ids.shape[1]):
        t = tables[f"table_{i}"]
        ids = jnp.clip(sparse_ids[:, i], 0, t.shape[0] - 1)
        outs.append(jnp.take(t, ids, axis=0))
    if cfg.combine == "sum":
        return sum(outs)
    return jnp.concatenate(outs, axis=-1)


def lookup_bags(table, indices, weights=None, use_kernel: bool = False):
    """Multi-hot EmbeddingBag over one table: indices (B, H), pad -1."""
    if use_kernel:
        from repro.kernels.embedding_bag import embedding_bag
        return embedding_bag(indices, table, weights)
    safe = jnp.clip(indices, 0, table.shape[0] - 1)
    rows = table[safe]
    if weights is None:
        weights = jnp.ones(indices.shape, table.dtype)
    w = jnp.where(indices >= 0, weights, 0.0)[..., None]
    return jnp.sum(rows * w, axis=1)
