from repro.models.recsys import dcn, embedding
