"""DCN-v2 [arXiv:2008.13535]: cross network v2 + deep MLP (parallel
structure), n_dense=13, n_sparse=26, embed_dim=16, 3 cross layers,
MLP 1024-1024-512; plus a two-tower retrieval head for candidate scoring.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.recsys.embedding import (EmbeddingConfig, init_tables,
                                           lookup)


@dataclasses.dataclass(frozen=True)
class DCNConfig:
    name: str = "dcn-v2"
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 16
    n_cross_layers: int = 3
    mlp_dims: Tuple[int, ...] = (1024, 1024, 512)
    vocab_sizes: Optional[tuple] = None   # default: Criteo-like 1e6 rows
    retrieval_dim: int = 64

    def vocabs(self):
        if self.vocab_sizes is not None:
            return self.vocab_sizes
        return tuple([1_000_000] * self.n_sparse)

    @property
    def d0(self) -> int:
        return self.n_dense + self.n_sparse * self.embed_dim


def init_params(key, cfg: DCNConfig, dtype=jnp.float32):
    ke, kc, km, kf, kr = jax.random.split(key, 5)
    emb_cfg = EmbeddingConfig(cfg.vocabs(), cfg.embed_dim)
    d0 = cfg.d0
    ckeys = jax.random.split(kc, cfg.n_cross_layers)
    cross = [{
        "w": jax.random.normal(k, (d0, d0), dtype) / math.sqrt(d0),
        "b": jnp.zeros((d0,), dtype),
    } for k in ckeys]
    mlp_p = L.mlp_init(km, [d0] + list(cfg.mlp_dims), dtype)
    final_in = d0 + cfg.mlp_dims[-1]
    return {
        "tables": init_tables(ke, emb_cfg, dtype),
        "cross": cross,
        "mlp": mlp_p,
        "final": L.dense_init(kf, final_in, 1, dtype),
        "user_proj": L.dense_init(kr, final_in, cfg.retrieval_dim, dtype),
    }


def _backbone(params, dense_feats, sparse_ids, cfg: DCNConfig):
    emb_cfg = EmbeddingConfig(cfg.vocabs(), cfg.embed_dim)
    emb = lookup(params["tables"], sparse_ids, emb_cfg)     # (B, 26·16)
    x0 = jnp.concatenate([dense_feats, emb], axis=-1)       # (B, d0)
    # Cross network v2: x_{l+1} = x0 ⊙ (W x_l + b) + x_l
    x = x0
    for cp in params["cross"]:
        x = x0 * (x @ cp["w"] + cp["b"]) + x
    deep = L.mlp(params["mlp"], x0, act=jax.nn.relu, final_act=True)
    return jnp.concatenate([x, deep], axis=-1)


def predict(params, dense_feats, sparse_ids, cfg: DCNConfig):
    """CTR logit: (B,)."""
    z = _backbone(params, dense_feats, sparse_ids, cfg)
    return L.dense(params["final"], z)[:, 0]


def train_loss(params, batch, cfg: DCNConfig):
    logits = predict(params, batch["dense"], batch["sparse"], cfg)
    y = batch["labels"].astype(jnp.float32)
    return jnp.mean(jnp.maximum(logits, 0) - logits * y
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def user_embedding(params, dense_feats, sparse_ids, cfg: DCNConfig):
    z = _backbone(params, dense_feats, sparse_ids, cfg)
    u = L.dense(params["user_proj"], z)
    return u / jnp.linalg.norm(u, axis=-1, keepdims=True).clip(1e-6)


def retrieval_scores(params, dense_feats, sparse_ids, cand_embs,
                     cfg: DCNConfig):
    """Score one (or few) queries against n_candidates item embeddings:
    batched dot product, (B, n_cand)."""
    u = user_embedding(params, dense_feats, sparse_ids, cfg)
    return u @ cand_embs.T
