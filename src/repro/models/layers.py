"""Minimal functional NN substrate (no flax in this environment).

Params are nested dicts of jnp arrays; every layer is an (init, apply)
pair of pure functions.  Sharding is expressed as a parallel pytree of
PartitionSpecs produced by the model's ``param_specs`` function.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def dense_init(key, d_in, d_out, dtype=jnp.float32, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return {"w": jax.random.normal(key, (d_in, d_out), dtype) * scale}


def dense(params, x):
    return x @ params["w"]


def mlp_init(key, dims, dtype=jnp.float32):
    ks = jax.random.split(key, len(dims) - 1)
    return {"layers": [dense_init(k, a, b, dtype)
                       for k, a, b in zip(ks, dims[:-1], dims[1:])]}


def mlp(params, x, act=jax.nn.gelu, final_act=False):
    n = len(params["layers"])
    for i, lp in enumerate(params["layers"]):
        x = dense(lp, x)
        if i < n - 1 or final_act:
            x = act(x)
    return x


def layernorm_init(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x, eps=1e-5):
    m = jnp.mean(x, axis=-1, keepdims=True)
    v = jnp.var(x, axis=-1, keepdims=True)
    return (x - m) * jax.lax.rsqrt(v + eps) * params["scale"] + params["bias"]


def rmsnorm_init(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps=1e-6, cast_scale=False):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps).astype(x.dtype)
    scale = params["scale"].astype(x.dtype) if cast_scale else params["scale"]
    return (out * scale).astype(x.dtype)


# ----------------------------- RoPE ---------------------------------------

def rope_freqs(d_head: int, theta: float = 10000.0):
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32)
                            / d_head))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    d_head = x.shape[-1]
    freqs = rope_freqs(d_head, theta)                      # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos = jnp.cos(ang)[..., None, :]                       # (..., S, 1, Dh/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ------------------------- GQA attention ----------------------------------

def attention_init(key, d_model, n_heads, n_kv_heads, d_head,
                   dtype=jnp.float32):
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d_model)
    return {
        "wq": jax.random.normal(kq, (d_model, n_heads, d_head), dtype) * s,
        "wk": jax.random.normal(kk, (d_model, n_kv_heads, d_head), dtype) * s,
        "wv": jax.random.normal(kv, (d_model, n_kv_heads, d_head), dtype) * s,
        "wo": jax.random.normal(ko, (n_heads, d_head, d_model), dtype) * s,
    }


def _gqa_scores(q, k, n_rep):
    """q: (B,S,Hq,D), k: (B,T,Hkv,D) -> scores (B,Hkv,n_rep,S,T)."""
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    q = q.reshape(B, S, Hkv, n_rep, D)
    return jnp.einsum("bsgrd,btgd->bgrst", q, k)


def attention(params, x, positions, *, n_rep, causal=True, theta=10000.0,
              kv_cache=None, cache_len=None, return_kv=False,
              chunked=False, q_block=1024, kv_block=1024,
              unroll_attn=False):
    """GQA attention. If kv_cache is given: decode mode — x is (B, 1, d),
    cache holds (k, v) of shape (B, T, Hkv, D), cache_len is the current
    valid length (the new token is written at index cache_len).

    Returns (out, new_cache).
    """
    B, S, d = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)

    if kv_cache is not None:
        ck, cv = kv_cache
        T = ck.shape[1]
        # Write the new token(s) at cache_len (dynamic slice update).
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                          (0, cache_len, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (0, cache_len, 0, 0))
        k_all, v_all = ck, cv
        t_idx = jnp.arange(T)
        kv_mask = t_idx[None, :] <= (cache_len + S - 1)     # (1, T)
        scores = _gqa_scores(q, k_all, n_rep) / math.sqrt(q.shape[-1])
        scores = jnp.where(kv_mask[None, None, None, :, :]
                           if kv_mask.ndim == 2 else kv_mask,
                           scores, -1e30)
        probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
        out = jnp.einsum("bgrst,btgd->bsgrd", probs.astype(x.dtype), v_all)
        out = out.reshape(B, S, -1, q.shape[-1])
        out = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
        return out, (ck, cv)

    if chunked:
        from repro.models.attention_chunked import chunked_attention
        out = chunked_attention(q, k, v, causal=causal,
                                q_block=q_block, kv_block=kv_block,
                                unroll=unroll_attn)
    else:
        scores = _gqa_scores(q, k, n_rep) / math.sqrt(q.shape[-1])
        if causal:
            mask = jnp.tril(jnp.ones((S, S), bool))
            scores = jnp.where(mask[None, None, None], scores, -1e30)
        probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
        out = jnp.einsum("bgrst,btgd->bsgrd", probs.astype(x.dtype), v)
    out = out.reshape(B, S, -1, q.shape[-1])
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return out, ((k, v) if return_kv else None)


# --------------------------- SwiGLU FFN -----------------------------------

def ffn_init(key, d_model, d_ff, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    s = 1.0 / math.sqrt(d_model)
    return {
        "w_gate": jax.random.normal(k1, (d_model, d_ff), dtype) * s,
        "w_up": jax.random.normal(k2, (d_model, d_ff), dtype) * s,
        "w_down": jax.random.normal(k3, (d_ff, d_model), dtype)
        / math.sqrt(d_ff),
    }


def ffn(params, x):
    g = jax.nn.silu(x @ params["w_gate"])
    u = x @ params["w_up"]
    return (g * u) @ params["w_down"]
