"""Flash-style chunked attention in pure JAX (scan over KV blocks with a
running (max, denom, acc) online softmax; optional outer scan over Q
blocks).  Keeps the working set at (q_block × kv_block) instead of S×S —
required for the 32k prefill / 4k train cells, and the object of several
§Perf iterations (block-size sweeps).

Equivalent to full softmax attention (LSE-combined); asserted in tests.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp


def _block_attn(q, k, v, qpos, kpos, causal, m, l, acc, scale):
    """One (q_block, kv_block) tile of the online softmax."""
    s = jnp.einsum("bsgrd,btgd->bgrst", q, k) * scale     # (B,g,r,qb,kb)
    if causal:
        mask = qpos[:, None] >= kpos[None, :]             # (qb, kb)
        s = jnp.where(mask[None, None, None], s, -1e30)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))           # (B,g,r,qb)
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + jnp.sum(p, axis=-1)
    pv = jnp.einsum("bgrst,btgd->bgrsd", p.astype(v.dtype), v)
    acc_new = acc * corr[..., None] + pv.astype(acc.dtype)
    return m_new, l_new, acc_new


def chunked_attention(q, k, v, *, causal: bool = True,
                      q_block: int = 1024, kv_block: int = 1024,
                      q_offset=0, unroll: bool = False):
    """q: (B, S, Hq, D); k/v: (B, T, Hkv, D); GQA via Hq = g·r.

    q_offset: position of q[0] within the kv sequence (prefill: 0; decode
    with history: cache_len).  Returns (B, S, Hq, D).
    """
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    r = Hq // Hkv
    scale = 1.0 / (D ** 0.5)
    qb = min(q_block, S)
    kb = min(kv_block, T)
    assert S % qb == 0 and T % kb == 0, (S, qb, T, kb)
    nq, nk = S // qb, T // kb

    qr = q.reshape(B, nq, qb, Hkv, r, D).transpose(1, 0, 2, 3, 4, 5)
    qr = qr.transpose(0, 1, 3, 4, 2, 5)        # (nq, B, g, r, qb, D)
    kr = k.reshape(B, nk, kb, Hkv, D).transpose(1, 0, 2, 3, 4)
    vr = v.reshape(B, nk, kb, Hkv, D).transpose(1, 0, 2, 3, 4)

    def q_step(_, qi_q):
        qi, q_blk = qi_q                        # q_blk: (B,g,r,qb,D)
        qpos = q_offset + qi * qb + jnp.arange(qb)

        def kv_step(carry, ki_kv):
            m, l, acc = carry
            ki, k_blk, v_blk = ki_kv
            kpos = ki * kb + jnp.arange(kb)
            # (B,qb,g,r,D) view for the einsum convention
            qv = q_blk.transpose(0, 3, 1, 2, 4)
            m, l, acc = _block_attn(qv, k_blk, v_blk, qpos, kpos, causal,
                                    m, l, acc, scale)
            return (m, l, acc), None

        m0 = jnp.full((B, Hkv, r, qb), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, Hkv, r, qb), jnp.float32)
        a0 = jnp.zeros((B, Hkv, r, qb, D), jnp.float32)
        if unroll:  # dry-run cost lowers: scan bodies are invisible to
            carry = (m0, l0, a0)  # the XLA cost model, so unroll
            for ki in range(nk):
                carry, _ = kv_step(carry, (jnp.asarray(ki), kr[ki], vr[ki]))
            m, l, acc = carry
        else:
            (m, l, acc), _ = jax.lax.scan(
                kv_step, (m0, l0, a0),
                (jnp.arange(nk), kr, vr))
        out = acc / jnp.maximum(l, 1e-30)[..., None]      # (B,g,r,qb,D)
        return None, out.astype(q.dtype)

    if unroll:
        outs = jnp.stack([q_step(None, (jnp.asarray(qi), qr[qi]))[1]
                          for qi in range(nq)])
    else:
        _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qr))
    # outs: (nq, B, g, r, qb, D) -> (B, S, Hq, D)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, S, Hq, D)
    return out


def full_attention_ref(q, k, v, *, causal=True, q_offset=0):
    """Oracle: materialized-scores softmax attention (small shapes only)."""
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    r = Hq // Hkv
    qr = q.reshape(B, S, Hkv, r, D)
    s = jnp.einsum("bsgrd,btgd->bgrst", qr, k) / (D ** 0.5)
    if causal:
        qpos = q_offset + jnp.arange(S)
        kpos = jnp.arange(T)
        mask = qpos[:, None] >= kpos[None, :]
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    o = jnp.einsum("bgrst,btgd->bsgrd", p.astype(v.dtype), v)
    return o.reshape(B, S, Hq, D)
