"""Mixture-of-Experts layer with capacity-bucketed dispatch.

The token→expert dispatch is the *same* fixed-capacity sort-and-bucket
machinery as the walk engine's task router (`core/router.py`): tokens are
stateless work items tagged with a destination (expert), ranked within
their destination by a stable sort, and bucketed with capacity
``C = top_k · T / E · capacity_factor``; overflow tokens fall through the
residual connection (dropless-style passthrough).  This is the
beyond-paper reuse of RidgeWalker's scheduling insight noted in
DESIGN.md §4.

Sharding: ``expert`` mode shards the expert dimension over the `model`
axis (EP — used when E % mesh_model == 0, e.g. phi-3.5-MoE's 16 experts);
``ffn`` mode shards each expert's hidden dim (TP — used for granite-MoE's
40 × d_ff=512 experts).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff: int
    capacity_factor: float = 1.25
    expert_sharding: str = "expert"  # expert (EP) | ffn (TP)
    router_aux_weight: float = 0.01
    # dispatch granularity: "global" sorts/buckets all T tokens at once
    # (paper-faithful single-queue semantics, but GSPMD cannot keep the
    # (E, C) buffers data-sharded); "row" dispatches independently per
    # batch row — per-device capacity semantics (Switch/GShard), keeps all
    # dispatch traffic inside the data shard (§Perf iteration 1).
    dispatch: str = "global"
    # pad num_experts up to a multiple of `pad_experts_to` with never-routed
    # dummies so EP sharding divides the mesh (§Perf iteration 2).
    pad_experts_to: int = 0

    @property
    def padded_experts(self) -> int:
        if self.pad_experts_to and self.num_experts % self.pad_experts_to:
            return -(-self.num_experts // self.pad_experts_to) \
                * self.pad_experts_to
        return self.num_experts


def moe_init(key, d_model: int, cfg: MoEConfig, dtype=jnp.float32):
    kr, k1, k2, k3 = jax.random.split(key, 4)
    E, F = cfg.padded_experts, cfg.d_ff
    s = 1.0 / math.sqrt(d_model)
    return {
        "router": jax.random.normal(kr, (d_model, cfg.num_experts),
                                    jnp.float32) * s,
        "w_gate": jax.random.normal(k1, (E, d_model, F), dtype) * s,
        "w_up": jax.random.normal(k2, (E, d_model, F), dtype) * s,
        "w_down": jax.random.normal(k3, (E, F, d_model), dtype)
        / math.sqrt(F),
    }


def moe_apply(params, x, cfg: MoEConfig):
    """x: (T, d) flattened tokens -> (T, d), aux_loss (scalar)."""
    T, d = x.shape
    E, K = cfg.padded_experts, cfg.top_k
    C = max(1, int(math.ceil(cfg.capacity_factor * K * T / E)))

    logits = (x.astype(jnp.float32) @ params["router"])      # (T, E_real)
    if E != cfg.num_experts:  # padded dummies are never routed to
        pad = jnp.full((T, E - cfg.num_experts), -1e30, jnp.float32)
        logits = jnp.concatenate([logits, pad], axis=-1)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, experts = jax.lax.top_k(probs, K)             # (T, K)
    gate_vals = gate_vals / jnp.clip(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # Load-balancing auxiliary loss (Switch/GShard style).
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jax.nn.one_hot(experts[:, 0], E, dtype=jnp.float32), axis=0)
    aux = cfg.router_aux_weight * E * jnp.sum(me * ce)

    # --- capacity-bucket dispatch (router.pack_buckets, token edition) ---
    flat_e = experts.reshape(-1)                             # (T*K,)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)   # token ids
    flat_g = gate_vals.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    e_sorted = flat_e[order]
    t_sorted = flat_t[order]
    g_sorted = flat_g[order]
    first = jnp.searchsorted(e_sorted, e_sorted, side="left")
    pos = jnp.arange(T * K, dtype=jnp.int32) - first.astype(jnp.int32)
    keep = pos < C
    slot = e_sorted * C + pos                                # (T*K,)
    slot_safe = jnp.where(keep, slot, E * C)

    # Gather tokens into (E, C, d) expert buffers (OOB -> dropped).
    buf = jnp.zeros((E * C + 1, d), x.dtype)
    buf = buf.at[slot_safe].set(x[t_sorted], mode="drop")
    buf = buf[: E * C].reshape(E, C, d)

    # Per-expert FFN (grouped einsum over the expert dim).
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"]))
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    y = jnp.einsum("ecf,efd->ecd", g * u, params["w_down"])  # (E, C, d)

    # Combine: scatter-add back to tokens with gate weights.
    y_flat = y.reshape(E * C, d)
    contrib = y_flat[jnp.clip(slot, 0, E * C - 1)] * g_sorted[:, None]
    contrib = jnp.where(keep[:, None], contrib, 0)
    out = jnp.zeros_like(x).at[t_sorted].add(contrib)
    return out, aux


def moe_apply_batched(params, x, cfg: MoEConfig):
    """x: (B, S, d) -> (B, S, d), aux. Row dispatch vmaps the bucketed
    dispatch over the (data-sharded) batch dim so the sort/scatter never
    crosses a data shard."""
    B, S, d = x.shape
    if cfg.dispatch == "row":
        y, aux = jax.vmap(lambda xr: moe_apply(params, xr, cfg))(x)
        return y, jnp.mean(aux)
    y, aux = moe_apply(params, x.reshape(B * S, d), cfg)
    return y.reshape(B, S, d), aux


def moe_param_specs(cfg: MoEConfig, model_axis: str = "model"):
    from jax.sharding import PartitionSpec as P
    if cfg.expert_sharding == "expert":
        w = P(model_axis, None, None)
        wd = P(model_axis, None, None)
    else:
        w = P(None, None, model_axis)
        wd = P(None, model_axis, None)
    return {"router": P(None, None), "w_gate": w, "w_up": w, "w_down": wd}
