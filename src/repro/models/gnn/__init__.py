from repro.models.gnn import meshgraphnet, schnet, pna, mace
