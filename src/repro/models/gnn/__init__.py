from repro.models.gnn import mace, meshgraphnet, pna, schnet
