"""SchNet [arXiv:1706.08566]: continuous-filter convolutions with RBF
edge filters; 3 interactions, d_hidden=64, 300 RBFs, cutoff 10 Å.
Kernel regime: triplet-free radial gather + scatter (taxonomy §GNN)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.gnn.common import scatter_sum


@dataclasses.dataclass(frozen=True)
class SchNetConfig:
    name: str = "schnet"
    n_interactions: int = 3
    d_hidden: int = 64
    n_rbf: int = 300
    cutoff: float = 10.0
    n_species: int = 100
    scan_layers: bool = True


def shifted_softplus(x):
    return jax.nn.softplus(x) - jnp.log(2.0)


def rbf_expand(dist, n_rbf: int, cutoff: float):
    centers = jnp.linspace(0.0, cutoff, n_rbf)
    gamma = 10.0 / cutoff
    return jnp.exp(-gamma * jnp.square(dist[:, None] - centers[None, :]))


def init_params(key, cfg: SchNetConfig):
    ke, kl, ko = jax.random.split(key, 3)
    lkeys = jax.random.split(kl, cfg.n_interactions)

    def init_inter(k):
        k1, k2, k3, k4 = jax.random.split(k, 4)
        d = cfg.d_hidden
        return {
            "filter": L.mlp_init(k1, [cfg.n_rbf, d, d]),
            "in_proj": L.dense_init(k2, d, d),
            "out1": L.dense_init(k3, d, d),
            "out2": L.dense_init(k4, d, d),
        }

    return {
        "embed": jax.random.normal(ke, (cfg.n_species, cfg.d_hidden)) * 0.1,
        "inters": jax.vmap(init_inter)(lkeys),
        "out": L.mlp_init(ko, [cfg.d_hidden, cfg.d_hidden // 2, 1]),
    }


def apply(params, species, positions, edge_index, cfg: SchNetConfig,
          mol_id=None, n_mols: int = 1):
    """species (N,) int; positions (N,3); edge_index (2,E).
    Returns per-molecule energies (n_mols,)."""
    N = species.shape[0]
    src, dst = edge_index[0], edge_index[1]
    h = params["embed"][jnp.clip(species, 0, cfg.n_species - 1)]
    rij = positions[dst] - positions[src]
    dist = jnp.sqrt(jnp.sum(jnp.square(rij), axis=-1) + 1e-12)
    rbf = rbf_expand(dist, cfg.n_rbf, cfg.cutoff)
    # smooth cosine cutoff envelope
    env = 0.5 * (jnp.cos(jnp.pi * jnp.clip(dist / cfg.cutoff, 0, 1)) + 1.0)

    def body(h, lp):
        w = L.mlp(lp["filter"], rbf, act=shifted_softplus,
                  final_act=True) * env[:, None]
        x = L.dense(lp["in_proj"], h)
        msg = x[src] * w
        agg = scatter_sum(msg, dst, N)
        y = shifted_softplus(L.dense(lp["out1"], agg))
        return h + L.dense(lp["out2"], y), None

    if cfg.scan_layers:
        h, _ = jax.lax.scan(body, h, params["inters"])
    else:
        for i in range(cfg.n_interactions):
            lp = jax.tree.map(lambda a: a[i], params["inters"])
            h, _ = body(h, lp)
    e_atom = L.mlp(params["out"], h, act=shifted_softplus)[:, 0]
    if mol_id is None:
        mol_id = jnp.zeros((N,), jnp.int32)
    return jax.ops.segment_sum(e_atom, mol_id, num_segments=n_mols)


def train_loss(params, batch, cfg: SchNetConfig):
    e = apply(params, batch["species"], batch["positions"],
              batch["edge_index"], cfg, batch.get("mol_id"),
              batch["energies"].shape[0])
    return jnp.mean(jnp.square(e - batch["energies"]))
