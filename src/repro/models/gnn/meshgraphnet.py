"""MeshGraphNet [arXiv:2010.03409]: encode-process-decode with 15 message
passing layers, d_hidden=128, sum aggregation, 2-layer MLPs + LayerNorm."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.gnn.common import mlp_ln, mlp_ln_init, scatter_sum


@dataclasses.dataclass(frozen=True)
class MeshGraphNetConfig:
    name: str = "meshgraphnet"
    n_layers: int = 15
    d_hidden: int = 128
    mlp_layers: int = 2
    node_in: int = 16
    edge_in: int = 8
    out_dim: int = 3
    remat: bool = True
    scan_layers: bool = True


def _mlp_dims(cfg, d_in):
    return [d_in] + [cfg.d_hidden] * cfg.mlp_layers


def init_params(key, cfg: MeshGraphNetConfig):
    kn, ke, kl, kd = jax.random.split(key, 4)
    lkeys = jax.random.split(kl, cfg.n_layers)

    def init_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "edge_mlp": mlp_ln_init(k1, _mlp_dims(cfg, 3 * cfg.d_hidden)),
            "node_mlp": mlp_ln_init(k2, _mlp_dims(cfg, 2 * cfg.d_hidden)),
        }

    return {
        "node_enc": mlp_ln_init(kn, _mlp_dims(cfg, cfg.node_in)),
        "edge_enc": mlp_ln_init(ke, _mlp_dims(cfg, cfg.edge_in)),
        "layers": jax.vmap(init_layer)(lkeys),
        "decoder": L.mlp_init(kd, [cfg.d_hidden, cfg.d_hidden, cfg.out_dim]),
    }


def apply(params, node_feats, edge_feats, edge_index, cfg: MeshGraphNetConfig):
    """edge_index: (2, E) [src, dst]. Returns per-node predictions (N, out)."""
    N = node_feats.shape[0]
    src, dst = edge_index[0], edge_index[1]
    h = mlp_ln(params["node_enc"], node_feats)
    e = mlp_ln(params["edge_enc"], edge_feats)

    def body(carry, lp):
        h, e = carry
        msg_in = jnp.concatenate([e, h[src], h[dst]], axis=-1)
        e = e + mlp_ln(lp["edge_mlp"], msg_in)
        agg = scatter_sum(e, dst, N)
        h = h + mlp_ln(lp["node_mlp"], jnp.concatenate([h, agg], axis=-1))
        return (h, e), None

    if cfg.remat:
        body = jax.checkpoint(body)
    if cfg.scan_layers:
        (h, e), _ = jax.lax.scan(body, (h, e), params["layers"])
    else:
        carry = (h, e)
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            carry, _ = body(carry, lp)
        h, e = carry
    return L.mlp(params["decoder"], h)


def train_loss(params, batch, cfg: MeshGraphNetConfig):
    pred = apply(params, batch["node_feats"], batch["edge_feats"],
                 batch["edge_index"], cfg)
    return jnp.mean(jnp.square(pred - batch["targets"]))
