"""MACE [arXiv:2206.07697]: higher-order equivariant message passing —
2 layers, d_hidden=128 channels, l_max=2, correlation order 3, 8 radial
Bessel functions, E(3)-equivariance.

Implementation note (DESIGN.md §7): irreps are carried in **Cartesian
form** — l=0 scalars (N, C), l=1 vectors (N, C, 3), l=2 traceless
symmetric tensors (N, C, 3, 3).  Clebsch-Gordan couplings become explicit
Cartesian contractions (dot, cross-free symmetric products, traceless
projections), which is exactly equivariant under O(3) and avoids
hand-rolled CG tables; at l_max=2 the O(L⁶)→O(L³) eSCN reduction is
unnecessary.  The correlation-order-3 "B-features" are the products of
the density "A-features" listed in ``_symmetric_contractions``.
Equivariance is property-tested (rotate inputs → outputs co-rotate).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class MACEConfig:
    name: str = "mace"
    n_layers: int = 2
    d_hidden: int = 128      # channels per irrep
    l_max: int = 2
    correlation: int = 3
    n_rbf: int = 8
    r_cut: float = 5.0
    n_species: int = 100
    # --- distributed-communication knobs (§Perf iterations) ---
    # propagate_lmax: highest-l node features carried ACROSS edges. 2 =
    # full (gathers (N,C,3)+(N,C,3,3) per layer — 15x the scalar bytes);
    # 0 = communicate invariants only, rebuild equivariants locally from
    # Y_l(r̂) (B-features keep correlation-3 / l<=2 equivariance).
    propagate_lmax: int = 2
    # cast gathered/scattered edge messages to bf16 (halves the all-gather
    # + scatter-reduce bytes; readout math stays f32)
    message_dtype: str = "f32"
    # static promise that edges arrive sorted by destination (the paper's
    # dst-partitioned neighbor layout); lets XLA use windowed scatters
    edges_sorted: bool = False


def bessel_basis(r, n: int, r_cut: float):
    """Radial Bessel basis (MACE eq. 7): sqrt(2/rc)·sin(nπr/rc)/r."""
    r = jnp.maximum(r, 1e-9)
    ns = jnp.arange(1, n + 1, dtype=jnp.float32)
    return (jnp.sqrt(2.0 / r_cut) * jnp.sin(ns[None, :] * jnp.pi
                                            * r[:, None] / r_cut)
            / r[:, None])


def cutoff_envelope(r, r_cut: float, p: int = 6):
    x = jnp.clip(r / r_cut, 0.0, 1.0)
    return (1.0 - 0.5 * (p + 1) * (p + 2) * x ** p
            + p * (p + 2) * x ** (p + 1)
            - 0.5 * p * (p + 1) * x ** (p + 2))


def _traceless(t):
    """Project (…,3,3) onto symmetric-traceless (the l=2 irrep)."""
    sym = 0.5 * (t + jnp.swapaxes(t, -1, -2))
    tr = jnp.trace(sym, axis1=-2, axis2=-1)[..., None, None]
    eye = jnp.eye(3, dtype=t.dtype)
    return sym - tr * eye / 3.0


def _symmetric_contractions(a0, a1, a2):
    """Correlation-order ≤ 3 invariant/equivariant products of the
    A-features (the Cartesian form of MACE's symmetrized tensor powers).

    Returns (scalars list, vectors list, tensors list), each element of
    per-channel shape (N, C[, 3[, 3]])."""
    dot11 = jnp.einsum("nci,nci->nc", a1, a1)
    dot22 = jnp.einsum("ncij,ncij->nc", a2, a2)
    v2v = jnp.einsum("ncij,ncj->nci", a2, a1)          # A2·A1 (vector)
    scalars = [
        a0,                                            # order 1
        a0 * a0, dot11, dot22,                         # order 2
        a0 * a0 * a0, a0 * dot11, a0 * dot22,          # order 3
        jnp.einsum("nci,nci->nc", a1, v2v),            # A1·A2·A1
        jnp.einsum("ncij,ncjk,ncki->nc", a2, a2, a2),  # tr(A2³)
    ]
    vectors = [
        a1,                                            # order 1
        a0[..., None] * a1, v2v,                       # order 2
        a0[..., None] * v2v, dot11[..., None] * a1,    # order 3
        jnp.einsum("ncij,ncjk,nck->nci", a2, a2, a1),
    ]
    outer11 = _traceless(jnp.einsum("nci,ncj->ncij", a1, a1))
    tensors = [
        a2,
        a0[..., None, None] * a2, outer11,
        _traceless(jnp.einsum("ncik,nckj->ncij", a2, a2)),
        a0[..., None, None] * outer11,
        _traceless(jnp.einsum("nci,ncj->ncij", a1, v2v)),
    ]
    return scalars, vectors, tensors


def init_params(key, cfg: MACEConfig):
    ke, kl, ko = jax.random.split(key, 3)
    lkeys = jax.random.split(kl, cfg.n_layers)
    C = cfg.d_hidden

    def init_layer(k):
        ks = jax.random.split(k, 8)
        n_s, n_v, n_t = 9, 6, 6  # product counts above
        return {
            "radial0": L.mlp_init(ks[0], [cfg.n_rbf, 32, C]),
            "radial1": L.mlp_init(ks[1], [cfg.n_rbf, 32, C]),
            "radial2": L.mlp_init(ks[2], [cfg.n_rbf, 32, C]),
            # couplings of the previous layer's l=1 / l=2 node features
            "radial1b": L.mlp_init(ks[0], [cfg.n_rbf, 32, C]),
            "radial2b": L.mlp_init(ks[1], [cfg.n_rbf, 32, C]),
            "mix_s": L.dense_init(ks[3], n_s * C, C),
            "mix_v": jax.random.normal(ks[4], (n_v, C, C)) * (1.0 / C),
            "mix_t": jax.random.normal(ks[5], (n_t, C, C)) * (1.0 / C),
            "update": L.dense_init(ks[6], 2 * C, C),
            "readout": L.mlp_init(ks[7], [C, 16, 1]),
        }

    return {
        "embed": jax.random.normal(ke, (cfg.n_species, C)) * 0.1,
        "layers": [init_layer(k) for k in lkeys],
    }


def apply(params, species, positions, edge_index, cfg: MACEConfig,
          mol_id=None, n_mols: int = 1):
    """Returns per-molecule energies (n_mols,). Equivariant internals."""
    N = species.shape[0]
    src, dst = edge_index[0], edge_index[1]
    C = cfg.d_hidden

    h = params["embed"][jnp.clip(species, 0, cfg.n_species - 1)]  # (N, C)
    rij = positions[src] - positions[dst]
    r = jnp.sqrt(jnp.sum(jnp.square(rij), -1) + 1e-12)
    rhat = rij / r[:, None]
    rbf = bessel_basis(r, cfg.n_rbf, cfg.r_cut) \
        * cutoff_envelope(r, cfg.r_cut)[:, None]
    y1 = rhat                                             # (E, 3)
    y2 = _traceless(jnp.einsum("ei,ej->eij", rhat, rhat))  # (E, 3, 3)

    mdt = jnp.bfloat16 if cfg.message_dtype == "bf16" else jnp.float32
    energy = jnp.zeros((N,), jnp.float32)
    h_v = jnp.zeros((N, C, 3), mdt)
    h_t = jnp.zeros((N, C, 3, 3), mdt)
    for lp in params["layers"]:
        r0 = L.mlp(lp["radial0"], rbf)                    # (E, C)
        r1 = L.mlp(lp["radial1"], rbf)
        r2 = L.mlp(lp["radial2"], rbf)
        hsrc = h[src].astype(mdt)                         # (E, C)
        # Density A-features: scalar channels spread onto Y_l(r̂), plus
        # (propagate_lmax >= 1) the previous layer's own l=1 / l=2 features
        # propagated along edges.
        import jax as _jax
        seg = lambda m: _jax.ops.segment_sum(
            m, dst, num_segments=N, indices_are_sorted=cfg.edges_sorted)
        a0 = seg(r0.astype(mdt) * hsrc).astype(jnp.float32)
        m1 = (r1.astype(mdt) * hsrc)[..., None] * y1[:, None, :].astype(mdt)
        if cfg.propagate_lmax >= 1:
            r1b = L.mlp(lp["radial1b"], rbf)
            m1 = m1 + r1b.astype(mdt)[..., None] * h_v[src]
        a1 = seg(m1).astype(jnp.float32)
        m2 = (r2.astype(mdt) * hsrc)[..., None, None] \
            * y2[:, None, :, :].astype(mdt)
        if cfg.propagate_lmax >= 2:
            r2b = L.mlp(lp["radial2b"], rbf)
            m2 = m2 + r2b.astype(mdt)[..., None, None] * h_t[src]
        a2 = seg(m2).astype(jnp.float32)

        s_list, v_list, t_list = _symmetric_contractions(a0, a1, a2)
        b_s = L.dense(lp["mix_s"], jnp.concatenate(s_list, axis=-1))
        # equivariant channel mixing (no nonlinearity on l>0 parts)
        h_v = jnp.einsum("pnci,pcd->ndi", jnp.stack(v_list),
                         lp["mix_v"]).astype(mdt)
        h_t = jnp.einsum("pncij,pcd->ndij", jnp.stack(t_list),
                         lp["mix_t"]).astype(mdt)
        h = jax.nn.silu(L.dense(lp["update"],
                                jnp.concatenate([h, b_s], axis=-1)))
        energy = energy + L.mlp(lp["readout"], h)[:, 0]

    if mol_id is None:
        mol_id = jnp.zeros((N,), jnp.int32)
    return jax.ops.segment_sum(energy, mol_id, num_segments=n_mols)


def train_loss(params, batch, cfg: MACEConfig):
    e = apply(params, batch["species"], batch["positions"],
              batch["edge_index"], cfg, batch.get("mol_id"),
              batch["energies"].shape[0])
    return jnp.mean(jnp.square(e - batch["energies"]))
