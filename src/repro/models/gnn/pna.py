"""PNA [arXiv:2004.05718]: Principal Neighbourhood Aggregation —
4 aggregators (mean/min/max/std) × 3 degree scalers (identity,
amplification, attenuation), n_layers=4, d_hidden=75."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.gnn.common import (degrees, mlp_ln, mlp_ln_init, scatter_max,
                                     scatter_mean, scatter_min)


@dataclasses.dataclass(frozen=True)
class PNAConfig:
    name: str = "pna"
    n_layers: int = 4
    d_hidden: int = 75
    node_in: int = 16
    out_dim: int = 7
    avg_log_degree: float = 2.0  # δ: dataset-level E[log(d+1)]
    scan_layers: bool = True


def init_params(key, cfg: PNAConfig):
    ke, kl, kd = jax.random.split(key, 3)
    lkeys = jax.random.split(kl, cfg.n_layers)

    def init_layer(k):
        k1, k2 = jax.random.split(k)
        d = cfg.d_hidden
        return {
            "msg": mlp_ln_init(k1, [2 * d, d, d]),
            "update": mlp_ln_init(k2, [13 * d, d, d]),  # h + 12 aggregates
        }

    return {
        "enc": mlp_ln_init(ke, [cfg.node_in, cfg.d_hidden, cfg.d_hidden]),
        "layers": jax.vmap(init_layer)(lkeys),
        "dec": L.mlp_init(kd, [cfg.d_hidden, cfg.d_hidden, cfg.out_dim]),
    }


def apply(params, node_feats, edge_index, cfg: PNAConfig):
    N = node_feats.shape[0]
    src, dst = edge_index[0], edge_index[1]
    h = mlp_ln(params["enc"], node_feats)
    deg = degrees(dst, N)
    logd = jnp.log(deg + 1.0)
    amp = (logd / cfg.avg_log_degree)[:, None]
    att = (cfg.avg_log_degree / jnp.maximum(logd, 1e-6))[:, None]

    def body(h, lp):
        msg = mlp_ln(lp["msg"], jnp.concatenate([h[src], h[dst]], -1))
        mean = scatter_mean(msg, dst, N)
        mx = scatter_max(msg, dst, N)
        mn = scatter_min(msg, dst, N)
        sq = scatter_mean(jnp.square(msg), dst, N)
        std = jnp.sqrt(jnp.maximum(sq - jnp.square(mean), 0.0) + 1e-6)
        # mask empty neighborhoods (segment_max returns -inf-ish fill)
        has = (deg > 0)[:, None]
        aggs = [jnp.where(has, a, 0.0) for a in (mean, mx, mn, std)]
        scaled = [a * s for a in aggs for s in
                  (jnp.ones_like(amp), amp, att)]           # 12 × (N, d)
        upd = jnp.concatenate([h] + scaled, axis=-1)
        return h + mlp_ln(lp["update"], upd), None

    if cfg.scan_layers:
        h, _ = jax.lax.scan(body, h, params["layers"])
    else:
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            h, _ = body(h, lp)
    return L.mlp(params["dec"], h)


def train_loss(params, batch, cfg: PNAConfig):
    logits = apply(params, batch["node_feats"], batch["edge_index"], cfg)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], 1))
