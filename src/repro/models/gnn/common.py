"""GNN message-passing substrate.

JAX sparse is BCOO-only, so message passing is implemented as
gather (edge src) → message → ``segment_sum``/``segment_max`` scatter over
the destination index — optionally through the tiled Pallas
`kernels/segment_sum` for the perf-critical scatter.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L


def scatter_sum(messages, dst, num_nodes: int):
    return jax.ops.segment_sum(messages, dst, num_segments=num_nodes)


def scatter_mean(messages, dst, num_nodes: int):
    s = scatter_sum(messages, dst, num_nodes)
    cnt = jax.ops.segment_sum(jnp.ones((messages.shape[0],), messages.dtype),
                              dst, num_segments=num_nodes)
    return s / jnp.maximum(cnt, 1.0)[:, None]


def scatter_max(messages, dst, num_nodes: int):
    return jax.ops.segment_max(messages, dst, num_segments=num_nodes,
                               indices_are_sorted=False)


def scatter_min(messages, dst, num_nodes: int):
    return -scatter_max(-messages, dst, num_nodes)


def degrees(dst, num_nodes: int, dtype=jnp.float32):
    return jax.ops.segment_sum(jnp.ones_like(dst, dtype), dst,
                               num_segments=num_nodes)


def mlp_ln_init(key, dims, dtype=jnp.float32):
    p = L.mlp_init(key, dims, dtype)
    p["ln"] = L.layernorm_init(dims[-1], jnp.float32)
    return p


def mlp_ln(params, x, act=jax.nn.relu):
    y = L.mlp(params, x, act=act)
    return L.layernorm(params["ln"], y)
