"""Skip-gram with negative sampling (DeepWalk/Node2Vec embedding trainer).

The end-to-end driver: RidgeWalker's walk engine generates the corpus, a
sliding window produces (center, context) pairs, and this model learns the
node embeddings — the full DeepWalk pipeline [5] on top of the paper's
system.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SkipGramConfig:
    num_vertices: int
    dim: int = 128
    num_negatives: int = 5
    window: int = 5


def init_params(key, cfg: SkipGramConfig):
    k1, k2 = jax.random.split(key)
    s = 1.0 / cfg.dim
    return {
        "in_embed": jax.random.uniform(k1, (cfg.num_vertices, cfg.dim),
                                       minval=-s, maxval=s),
        # small random (not zero) output init: breaks the in/out symmetry
        # so the SGNS gradients reach in_embed from step one
        "out_embed": jax.random.normal(k2, (cfg.num_vertices, cfg.dim)) * 0.1,
    }


def loss_fn(params, centers, contexts, negatives):
    """centers (B,), contexts (B,), negatives (B, K) — SGNS objective."""
    ci = params["in_embed"][centers]              # (B, D)
    co = params["out_embed"][contexts]            # (B, D)
    no = params["out_embed"][negatives]           # (B, K, D)
    pos = jnp.sum(ci * co, axis=-1)
    neg = jnp.einsum("bd,bkd->bk", ci, no)
    pos_l = jax.nn.log_sigmoid(pos)
    neg_l = jnp.sum(jax.nn.log_sigmoid(-neg), axis=-1)
    return -jnp.mean(pos_l + neg_l)


def pairs_from_walks(paths: np.ndarray, lengths: np.ndarray, window: int,
                     rng: np.random.Generator, max_pairs: int | None = None):
    """Sliding-window (center, context) pairs from walk paths (host-side)."""
    centers, contexts = [], []
    for q in range(paths.shape[0]):
        L = int(lengths[q])
        for i in range(L):
            lo, hi = max(0, i - window), min(L, i + window + 1)
            for j in range(lo, hi):
                if j != i and paths[q, j] >= 0 and paths[q, i] >= 0:
                    centers.append(paths[q, i])
                    contexts.append(paths[q, j])
    c = np.asarray(centers, np.int32)
    x = np.asarray(contexts, np.int32)
    if max_pairs is not None and c.size > max_pairs:
        sel = rng.choice(c.size, max_pairs, replace=False)
        c, x = c[sel], x[sel]
    return c, x
