"""Skip-gram with negative sampling (DeepWalk/Node2Vec embedding trainer).

The end-to-end driver: RidgeWalker's walk engine generates the corpus, a
sliding window produces (center, context) pairs, and this model learns the
node embeddings — the full DeepWalk pipeline [5] on top of the paper's
system.

Two consumption paths exist:

* the legacy host path (:func:`pairs_from_walks` + ad-hoc batching), kept
  for offline corpus processing;
* the device-resident path — `repro.core.corpus_ring` samples
  (center, context, negatives) windows straight from the HBM ring and
  :func:`make_sgns_step` (donated embedding-table buffers, hot-path
  gathers on the fused `kernels/embedding_bag` Pallas kernel) consumes
  them with zero per-step host traffic.  ``Walker.train_embeddings``
  composes the two ends.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw


@dataclasses.dataclass(frozen=True)
class SkipGramConfig:
    num_vertices: int
    dim: int = 128
    num_negatives: int = 5
    window: int = 5


def init_params(key, cfg: SkipGramConfig):
    k1, k2 = jax.random.split(key)
    s = 1.0 / cfg.dim
    return {
        "in_embed": jax.random.uniform(k1, (cfg.num_vertices, cfg.dim),
                                       minval=-s, maxval=s),
        # small random (not zero) output init: breaks the in/out symmetry
        # so the SGNS gradients reach in_embed from step one
        "out_embed": jax.random.normal(k2, (cfg.num_vertices, cfg.dim)) * 0.1,
    }


# ------------------------------------------------------------ row gathers
#
# The SGNS hot path is three random-row gathers per step — exactly the
# access regime the embedding_bag kernel double-buffers (each id is a
# one-row bag).  pallas_call has no VJP, so the kernel carries a
# custom_vjp whose backward is the standard scatter-add — identical to
# the jnp gather's gradient.


@jax.custom_vjp
def _kernel_gather(table, flat_ids):
    from repro.kernels.embedding_bag import embedding_bag
    return embedding_bag(flat_ids[:, None], table)


def _kernel_gather_fwd(table, flat_ids):
    return _kernel_gather(table, flat_ids), (flat_ids, table.shape[0])


def _kernel_gather_bwd(res, g):
    flat_ids, rows = res
    gt = jnp.zeros((rows, g.shape[-1]), g.dtype).at[flat_ids].add(g)
    return gt, np.zeros(flat_ids.shape, dtype=jax.dtypes.float0)


_kernel_gather.defvjp(_kernel_gather_fwd, _kernel_gather_bwd)


def gather_rows(table, ids, use_kernel: bool = False):
    """``table[ids]`` with the forward gather on the embedding_bag kernel.

    ``ids`` may carry any leading shape; the row axis is appended last.
    ``use_kernel=False`` is the jnp reference the parity tests pin the
    kernel against (bit-exact forward, scatter-add-identical backward).
    """
    if not use_kernel:
        return table[ids]
    flat = ids.reshape(-1).astype(jnp.int32)
    rows = _kernel_gather(table, flat)
    return rows.reshape(*ids.shape, table.shape[1])


def loss_fn(params, centers, contexts, negatives, mask=None,
            use_kernel: bool = False):
    """centers (B,), contexts (B,), negatives (B, K) — SGNS objective.

    ``mask`` (B,) bool skips invalid pairs (a corpus-ring window that
    fell off its walk) without changing the static batch shape; ``None``
    keeps the legacy all-pairs mean bit-exactly.  ``use_kernel`` routes
    the three row gathers through the embedding_bag Pallas kernel.
    """
    ci = gather_rows(params["in_embed"], centers, use_kernel)    # (B, D)
    co = gather_rows(params["out_embed"], contexts, use_kernel)  # (B, D)
    no = gather_rows(params["out_embed"], negatives, use_kernel)  # (B, K, D)
    pos = jnp.sum(ci * co, axis=-1)
    neg = jnp.einsum("bd,bkd->bk", ci, no)
    pos_l = jax.nn.log_sigmoid(pos)
    neg_l = jnp.sum(jax.nn.log_sigmoid(-neg), axis=-1)
    per_pair = pos_l + neg_l
    if mask is None:
        return -jnp.mean(per_pair)
    w = mask.astype(per_pair.dtype)
    return -jnp.sum(per_pair * w) / jnp.maximum(jnp.sum(w), 1.0)


def make_sgns_step(cfg: SkipGramConfig, opt_cfg: adamw.AdamWConfig,
                   use_kernel: bool = True):
    """Build the jitted SGNS grad step with donated table buffers.

    ``step(params, opt_state, batch) -> (params, opt_state, aux)`` where
    ``batch = (centers, contexts, negatives, mask)``.  Donating the
    embedding tables and optimizer moments lets XLA update the (2·|V|·D)
    buffers in place — the tables never leave the device and no step
    allocates a second copy.
    """

    @partial(jax.jit, donate_argnums=(0, 1))
    def step(params, opt_state, batch):
        centers, contexts, negatives, mask = batch

        def objective(p):
            return loss_fn(p, centers, contexts, negatives, mask=mask,
                           use_kernel=use_kernel)

        loss, grads = jax.value_and_grad(objective)(params)
        params2, opt2, stats = adamw.apply_updates(params, grads, opt_state,
                                                   opt_cfg)
        return params2, opt2, {"loss": loss, **stats}

    return step


def pairs_from_walks(paths: np.ndarray, lengths: np.ndarray, window: int,
                     rng: np.random.Generator, max_pairs: int | None = None):
    """Sliding-window (center, context) pairs from walk paths (host-side)."""
    centers, contexts = [], []
    for q in range(paths.shape[0]):
        L = int(lengths[q])
        for i in range(L):
            lo, hi = max(0, i - window), min(L, i + window + 1)
            for j in range(lo, hi):
                if j != i and paths[q, j] >= 0 and paths[q, i] >= 0:
                    centers.append(paths[q, i])
                    contexts.append(paths[q, j])
    c = np.asarray(centers, np.int32)
    x = np.asarray(contexts, np.int32)
    if max_pairs is not None and c.size > max_pairs:
        sel = rng.choice(c.size, max_pairs, replace=False)
        c, x = c[sel], x[sel]
    return c, x
