"""Decoder-only LM (dense + MoE) with GQA, RoPE, KV-cache serving paths.

Covers the five assigned LM architectures (phi3.5-moe, granite-moe,
deepseek-7b, minitron-8b, stablelm-12b).  Layers are stacked and executed
with ``jax.lax.scan`` (+ remat) so the HLO stays compact at 30-40 layers —
essential for the 512-device dry-run compiles on the CPU host.

Entry points:
  * ``train_loss(params, tokens, labels, cfg)``      — training objective
  * ``prefill(params, tokens, cfg)``                 — logits + KV cache
  * ``decode_step(params, token, cache, len, cfg)``  — one serving step

Sharding: ``param_specs(cfg)`` returns a PartitionSpec pytree. Attention
shards Q-heads over `model` when divisible, else the head dim; MoE shards
experts (EP) or expert-FFN hidden (TP) per ``MoEConfig.expert_sharding``;
vocab shards over `model` when divisible, else the embedding dim.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models.moe import (MoEConfig, moe_apply_batched, moe_init,
                              moe_param_specs)


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str = "lm"
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 2
    d_ff: int = 1024
    vocab: int = 1024
    moe: Optional[MoEConfig] = None
    rope_theta: float = 10000.0
    dtype: Any = jnp.bfloat16
    remat: bool = True
    tp_axis: str = "model"
    dp_axes: Tuple[str, ...] = ("data",)
    # flash-style chunked attention kicks in at seq >= chunk_threshold
    chunk_threshold: int = 2048
    q_block: int = 1024
    kv_block: int = 1024
    # scan_layers=False unrolls the layer loop (used by the dry-run cost
    # extrapolation: XLA's cost model counts a scan body once, so per-layer
    # costs are measured on small unrolled models and extrapolated)
    scan_layers: bool = True
    # Megatron-style vocab-parallel cross-entropy: gold logit via a local
    # one-hot masked sum (elementwise on the vocab-sharded logits) instead
    # of take_along_axis, which GSPMD implements by all-gathering the full
    # (B, S, V) logits (§Perf iteration: deepseek train_4k)
    vocab_parallel_ce: bool = False
    # KV projection sharding: "d_head" (baseline) contracts a sharded
    # d_head in the score einsum -> psum of every score tile; "heads"
    # (valid when n_kv_heads % 16 == 0, e.g. MHA) and "replicate"
    # (GQA: KV projections are small) avoid it (§Perf iteration 2)
    kv_sharding: str = "d_head"
    # cast the f32 norm scales to the activation dtype at use: keeps the
    # BACKWARD pass in bf16 — with f32 scales the cotangents of every
    # residual tensor promote to f32 and all TP activation-grad psums move
    # 2x the bytes (§Perf iteration 3; LLaMA runs bf16 norm scales)
    cast_norm_scale: bool = False
    # decode KV-cache sharding over `model`: "seq" (baseline) shards the
    # time axis — the in-place token write at a dynamic position then
    # crosses shards; "dhead" shards the head dim — writes stay local,
    # attention contracts a sharded d_head into small psum'd score stats
    # (the flash-decoding combine). §Perf decode iteration.
    decode_cache_shard: str = "seq"

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def n_rep(self) -> int:
        return self.n_heads // self.n_kv_heads

    def param_count(self) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab
        attn = d * self.n_heads * self.d_head * 2 \
            + d * self.n_kv_heads * self.d_head * 2
        if self.moe:
            ff = self.moe.num_experts * 3 * d * self.moe.d_ff \
                + d * self.moe.num_experts
        else:
            ff = 3 * d * f
        per_layer = attn + ff + 2 * d
        return self.n_layers * per_layer + 2 * v * d + d

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top_k experts only)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        attn = d * self.n_heads * self.d_head * 2 \
            + d * self.n_kv_heads * self.d_head * 2
        ff = self.moe.top_k * 3 * d * self.moe.d_ff
        per_layer = attn + ff + 2 * d
        return self.n_layers * per_layer + 2 * self.vocab * d + d


# ------------------------------ init --------------------------------------

def _init_layer(cfg: TransformerConfig, key):
    ka, kf = jax.random.split(key)
    p = {
        "ln1": L.rmsnorm_init(cfg.d_model, jnp.float32),
        "attn": L.attention_init(ka, cfg.d_model, cfg.n_heads,
                                 cfg.n_kv_heads, cfg.d_head, cfg.dtype),
        "ln2": L.rmsnorm_init(cfg.d_model, jnp.float32),
    }
    if cfg.moe:
        p["moe"] = moe_init(kf, cfg.d_model, cfg.moe, cfg.dtype)
    else:
        p["ffn"] = L.ffn_init(kf, cfg.d_model, cfg.d_ff, cfg.dtype)
    return p


def init_params(key, cfg: TransformerConfig):
    ke, kl, ko = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    layers_p = jax.vmap(partial(_init_layer, cfg))(layer_keys)
    s = 1.0 / math.sqrt(cfg.d_model)
    return {
        "embed": jax.random.normal(ke, (cfg.vocab, cfg.d_model), cfg.dtype) * s,
        "layers": layers_p,
        "final_norm": L.rmsnorm_init(cfg.d_model, jnp.float32),
        "lm_head": jax.random.normal(ko, (cfg.d_model, cfg.vocab), cfg.dtype) * s,
    }


def param_specs(cfg: TransformerConfig):
    tp = cfg.tp_axis
    heads_div = cfg.n_heads % 16 == 0  # conservative: divisible by max TP
    hq = P(None, None, tp, None) if heads_div else P(None, None, None, tp)
    if cfg.kv_sharding == "heads":
        hkv = P(None, None, tp, None)
    elif cfg.kv_sharding == "replicate":
        hkv = P(None, None, None, None)
    else:  # baseline: shard d_head
        hkv = P(None, None, None, tp)
    attn = {"wq": hq, "wk": hkv, "wv": hkv,
            "wo": P(None, tp, None, None) if heads_div
            else P(None, None, tp, None)}
    norm = {"scale": P(None, None)}
    layer = {"ln1": norm, "ln2": norm, "attn": attn}
    if cfg.moe:
        ms = moe_param_specs(cfg.moe, tp)
        layer["moe"] = {k: P(*((None,) + tuple(s)))
                        for k, s in ms.items()}
    else:
        layer["ffn"] = {"w_gate": P(None, None, tp),
                        "w_up": P(None, None, tp),
                        "w_down": P(None, tp, None)}
    vocab_div = cfg.vocab % 16 == 0
    embed = P(tp, None) if vocab_div else P(None, tp)
    lm_head = P(None, tp) if vocab_div else P(tp, None)
    return {
        "embed": embed,
        "layers": layer,
        "final_norm": {"scale": P(None)},
        "lm_head": lm_head,
    }


# ----------------------------- forward ------------------------------------

def _block(cfg: TransformerConfig, x, positions, lp, kv_cache=None,
           cache_len=None, return_kv=False, causal=True):
    S = x.shape[1]
    chunked = kv_cache is None and S >= cfg.chunk_threshold
    cs = cfg.cast_norm_scale
    h, kv = L.attention(lp["attn"], L.rmsnorm(lp["ln1"], x, cast_scale=cs),
                        positions,
                        n_rep=cfg.n_rep, causal=causal,
                        theta=cfg.rope_theta, kv_cache=kv_cache,
                        cache_len=cache_len, return_kv=return_kv,
                        chunked=chunked, q_block=cfg.q_block,
                        kv_block=cfg.kv_block,
                        unroll_attn=not cfg.scan_layers)
    x = x + h
    hn = L.rmsnorm(lp["ln2"], x, cast_scale=cfg.cast_norm_scale)
    if cfg.moe:
        y, aux = moe_apply_batched(lp["moe"], hn, cfg.moe)
    else:
        y, aux = L.ffn(lp["ffn"], hn), jnp.zeros((), jnp.float32)
    return (x + y.astype(x.dtype)).astype(x.dtype), kv, aux


def forward(params, tokens, cfg: TransformerConfig):
    """Training/prefill trunk: tokens (B, S) -> hidden (B, S, d), aux."""
    B, S = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.arange(S)[None, :]

    def body(carry, lp):
        x, aux = carry
        x, _, a = _block(cfg, x, positions, lp)
        return (x, aux + a), None

    if cfg.remat:
        body = jax.checkpoint(body)
    carry = (x, jnp.zeros((), jnp.float32))
    if cfg.scan_layers:
        (x, aux), _ = jax.lax.scan(body, carry, params["layers"])
    else:
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            carry, _ = body(carry, lp)
        x, aux = carry
    x = L.rmsnorm(params["final_norm"], x)
    return x, aux


def train_loss(params, tokens, labels, cfg: TransformerConfig):
    x, aux = forward(params, tokens, cfg)
    logits = (x @ params["lm_head"]).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    if cfg.vocab_parallel_ce:
        # shard-local masked sum; the only cross-shard reduction is the
        # small (B, S) sum GSPMD inserts for the sharded-V contraction
        onehot = jax.nn.one_hot(labels, cfg.vocab, dtype=logits.dtype)
        gold = jnp.sum(logits * onehot, axis=-1)
    else:
        gold = jnp.take_along_axis(logits, labels[..., None],
                                   axis=-1)[..., 0]
    nll = jnp.mean(logz - gold)
    zloss = 1e-4 * jnp.mean(jnp.square(logz))
    return nll + zloss + aux


def prefill(params, tokens, cfg: TransformerConfig):
    """Prefill: returns (logits_last, kv_caches stacked (L, 2, B, S, H, D))."""
    B, S = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.arange(S)[None, :]

    def body(x, lp):
        x, kv, _ = _block(cfg, x, positions, lp, return_kv=True)
        return x, jnp.stack(kv)  # (2, B, S, Hkv, Dh)

    if cfg.remat:
        body = jax.checkpoint(body)
    if cfg.scan_layers:
        x, caches = jax.lax.scan(body, x, params["layers"])
    else:
        outs = []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, kv = body(x, lp)
            outs.append(kv)
        caches = jnp.stack(outs)
    x = L.rmsnorm(params["final_norm"], x)
    logits = (x[:, -1:] @ params["lm_head"]).astype(jnp.float32)
    return logits, caches


def decode_step(params, token, caches, cache_len, cfg: TransformerConfig):
    """One token for every sequence: token (B, 1), caches (L, 2, B, T, H, D),
    cache_len scalar — the new KV is written at cache_len."""
    B = token.shape[0]
    x = params["embed"][token]
    positions = jnp.full((B, 1), cache_len, jnp.int32)

    def body(x, inputs):
        lp, cache = inputs
        x, kv, _ = _block(cfg, x, positions, lp,
                          kv_cache=(cache[0], cache[1]),
                          cache_len=cache_len, causal=False)
        return x, jnp.stack(kv)

    if cfg.scan_layers:
        x, new_caches = jax.lax.scan(body, x, (params["layers"], caches))
    else:
        outs = []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, kv = body(x, (lp, caches[i]))
            outs.append(kv)
        new_caches = jnp.stack(outs)
    x = L.rmsnorm(params["final_norm"], x)
    logits = (x @ params["lm_head"]).astype(jnp.float32)
    return logits, new_caches


def make_kv_cache(cfg: TransformerConfig, batch: int, max_len: int,
                  dtype=None):
    dtype = dtype or cfg.dtype
    return jnp.zeros((cfg.n_layers, 2, batch, max_len, cfg.n_kv_heads,
                      cfg.d_head), dtype)
