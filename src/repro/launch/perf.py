import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""§Perf hillclimb driver: lower a dry-run cell with config overrides and
compare its roofline terms against the frozen baseline artifact.

  PYTHONPATH=src python -m repro.launch.perf --arch deepseek_7b \
      --shape train_4k --tag vpce --set vocab_parallel_ce=true

Results land in experiments/perf/single/<arch>__<shape>__<tag>.json and a
delta line is printed for EXPERIMENTS.md §Perf.
"""
import argparse
import json


def parse_val(v: str):
    if v.lower() in ("true", "false"):
        return v.lower() == "true"
    try:
        return int(v)
    except ValueError:
        pass
    try:
        return float(v)
    except ValueError:
        return v


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--tag", required=True)
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (dotted paths ok)")
    ap.add_argument("--baseline-dir", default="experiments/dryrun")
    ap.add_argument("--out", default="experiments/perf")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    from repro.launch.dryrun import run_cell
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = parse_val(v)

    rec = run_cell(args.arch, args.shape, multi_pod=False, out_dir=args.out,
                   force=args.force, overrides=overrides, tag=args.tag)
    base_path = os.path.join(args.baseline_dir, "single",
                             f"{args.arch}__{args.shape}.json")
    base = json.load(open(base_path)) if os.path.exists(base_path) else None

    if rec["status"] != "ok":
        print(f"FAIL: {rec['error'][:300]}")
        return
    r = rec["roofline"]
    line = (f"{args.arch}/{args.shape} [{args.tag}] "
            f"compute={r['compute_s']:.3e} memory={r['memory_s']:.3e} "
            f"coll={r['collective_s']:.3e} bound={r['bound_s']:.3e} "
            f"dom={r['dominant']}")
    if base and base.get("status") == "ok":
        b = base["roofline"]
        line += (f"  | vs baseline bound={b['bound_s']:.3e}: "
                 f"{b['bound_s']/r['bound_s']:.2f}x better "
                 f"(coll {b['collective_s']/max(r['collective_s'],1e-12):.2f}x,"
                 f" mem {b['memory_s']/max(r['memory_s'],1e-12):.2f}x)")
    print(line)


if __name__ == "__main__":
    main()
