"""GRW service driver — the paper's workload as a runnable CLI.

  PYTHONPATH=src python -m repro.launch.walk --algo deepwalk --dataset WG \
      --queries 2000 --slots 1024
  PYTHONPATH=src python -m repro.launch.walk --algo urw --distributed \
      --devices 8 ...   (needs XLA_FLAGS=--xla_force_host_platform_device_count=8)
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

from repro.configs.ridgewalker import ALGORITHMS, ENGINE, QUERY_LENGTH
from repro.core.scheduler import analyze_run
from repro.core.walk_engine import run_walks
from repro.graph import make_dataset, partition_graph


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--algo", default="urw", choices=sorted(ALGORITHMS))
    ap.add_argument("--dataset", default="WG")
    ap.add_argument("--scale", type=int, default=None,
                    help="RMAT scale override (CPU-sized default)")
    ap.add_argument("--queries", type=int, default=2000)
    ap.add_argument("--slots", type=int, default=1024)
    ap.add_argument("--max-hops", type=int, default=QUERY_LENGTH)
    ap.add_argument("--mode", default="zero_bubble",
                    choices=["zero_bubble", "static"])
    ap.add_argument("--step-impl", default="jnp", choices=["jnp", "pallas"])
    ap.add_argument("--distributed", action="store_true")
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--record-paths", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    spec = ALGORITHMS[args.algo]
    weighted = spec.kind in ("alias", "reservoir_n2v")
    g = make_dataset(args.dataset, weighted=weighted,
                     with_alias=spec.kind == "alias",
                     scale_override=args.scale, seed=args.seed)
    print(f"{args.dataset}: |V|={g.num_vertices} |E|={g.num_edges} "
          f"max_deg={g.max_degree}")
    rng = np.random.default_rng(args.seed)
    starts = rng.integers(0, g.num_vertices, args.queries).astype(np.int32)

    if args.distributed:
        from repro.core.distributed import DistConfig, run_distributed
        pg = partition_graph(g, args.devices)
        cfg = DistConfig(slots_per_device=args.slots // args.devices,
                         max_hops=args.max_hops,
                         record_paths=args.record_paths)
        t0 = time.time()
        if spec.kind == "rejection_n2v":
            from repro.core.distributed_n2v import run_distributed_n2v
            logs, stats = run_distributed_n2v(pg, starts, spec, cfg,
                                              seed=args.seed)
        else:
            logs, stats = run_distributed(pg, starts, spec, cfg,
                                          seed=args.seed)
        import jax
        jax.block_until_ready(logs.cursor)
        dt = time.time() - t0
        import jax.numpy as jnp
        tot = type(stats)(*(v.sum() for v in stats))
        a = analyze_run(tot, dt)
    else:
        cfg = dataclasses.replace(
            ENGINE, num_slots=args.slots, max_hops=args.max_hops,
            mode=args.mode, record_paths=args.record_paths,
            step_impl=args.step_impl)
        t0 = time.time()
        res = run_walks(g, starts, spec, cfg, seed=args.seed)
        res.stats.steps.block_until_ready()
        dt = time.time() - t0
        a = analyze_run(res.stats, dt)
    print(f"steps={a.steps} supersteps={a.supersteps} "
          f"throughput={a.msteps_per_s:.3f} MStep/s "
          f"occupancy={a.occupancy:.3f} starved={a.starved} drops={a.drops}")


if __name__ == "__main__":
    main()
