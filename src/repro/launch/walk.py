"""GRW service driver — the paper's workload as a runnable CLI, on the
unified walker API (`repro.walker.compile`).

  PYTHONPATH=src python -m repro.launch.walk --algo deepwalk --dataset WG \
      --queries 2000 --slots 1024
  PYTHONPATH=src python -m repro.launch.walk --algo node2vec --backend sharded \
      --devices 8 ...   (needs XLA_FLAGS=--xla_force_host_platform_device_count=8)
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro import walker
from repro.configs.ridgewalker import ALGORITHMS, QUERY_LENGTH
from repro.core.scheduler import analyze_run
from repro.graph import make_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--algo", default="urw", choices=sorted(ALGORITHMS))
    ap.add_argument("--dataset", default="WG")
    ap.add_argument("--scale", type=int, default=None,
                    help="RMAT scale override (CPU-sized default)")
    ap.add_argument("--queries", type=int, default=2000)
    ap.add_argument("--slots", type=int, default=1024)
    ap.add_argument("--max-hops", type=int, default=QUERY_LENGTH)
    ap.add_argument("--mode", default="zero_bubble",
                    choices=["zero_bubble", "static"])
    ap.add_argument("--step-impl", default="jnp",
                    choices=["jnp", "pallas", "fused"])
    ap.add_argument("--hops-per-launch", type=int, default=16,
                    help="fused only: supersteps per kernel launch")
    ap.add_argument("--backend", default="single",
                    choices=list(walker.BACKENDS))
    ap.add_argument("--distributed", action="store_true",
                    help="alias for --backend sharded")
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--record-paths", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    spec = ALGORITHMS[args.algo]
    program = walker.WalkProgram(spec=spec, max_hops=args.max_hops,
                                 name=args.algo)
    weighted = spec.kind in ("alias", "reservoir_n2v")
    g = make_dataset(args.dataset, weighted=weighted,
                     with_alias=spec.kind == "alias",
                     scale_override=args.scale, seed=args.seed)
    print(f"{args.dataset}: |V|={g.num_vertices} |E|={g.num_edges} "
          f"max_deg={g.max_degree}")
    rng = np.random.default_rng(args.seed)
    starts = rng.integers(0, g.num_vertices, args.queries).astype(np.int32)

    backend = "sharded" if args.distributed else args.backend
    if backend == "sharded":
        if args.mode != "zero_bubble" or args.step_impl != "jnp":
            ap.error("--mode/--step-impl only apply to --backend single "
                     "(the sharded superstep is always zero-bubble jnp)")
        execution = walker.ExecutionConfig(
            num_slots=args.slots, record_paths=args.record_paths,
            num_devices=args.devices)
    else:
        execution = walker.ExecutionConfig(
            num_slots=args.slots, record_paths=args.record_paths,
            mode=args.mode, step_impl=args.step_impl,
            hops_per_launch=args.hops_per_launch)
    w = walker.compile(program, backend=backend, execution=execution)
    t0 = time.time()
    res = w.run(g, starts, seed=args.seed)
    res.stats.steps.block_until_ready()
    dt = time.time() - t0
    a = analyze_run(res.stats, dt)
    print(f"steps={a.steps} supersteps={a.supersteps} "
          f"throughput={a.msteps_per_s:.3f} MStep/s "
          f"occupancy={a.occupancy:.3f} starved={a.starved} drops={a.drops}")


if __name__ == "__main__":
    main()
