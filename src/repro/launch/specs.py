"""Per-(arch × shape × mesh) step functions + ShapeDtypeStruct inputs.

``build_cell`` returns (fn, args_structs, donate_argnums) where every
struct carries a NamedSharding — ``jax.jit(fn).lower(*args)`` then
compiles the full production-sharded program without allocating anything
(the shannon/kernels stand-in pattern).

Bulk dims that must divide the mesh are padded up (recorded in the cell
metadata) — the launcher does the same padding for real data.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_arch
from repro.configs.base import ShapeCell
from repro.launch.mesh import dp_axes, flat_axes
from repro.models import transformer as tfm
from repro.optim import adamw


def _pad_up(n: int, div: int) -> int:
    return -(-n // div) * div


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _with_shardings(struct_tree, spec_tree, mesh):
    return jax.tree.map(
        lambda s, p: _sds(s.shape, s.dtype, mesh, p), struct_tree, spec_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def zero_spec(spec: P, shape, axis: str = "data", div: int = 16) -> P:
    """ZeRO-style optimizer-state sharding: add the data axis on the first
    unsharded, divisible dim (optimizer state must never be replicated
    across data-parallel replicas at this scale)."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for i, (e, d) in enumerate(zip(entries, shape)):
        if e is None and d % div == 0 and d >= div:
            entries[i] = axis
            break
    return P(*entries)


def _opt_specs(pspecs, pstruct):
    mu = jax.tree.map(lambda sp, st: zero_spec(sp, st.shape), pspecs, pstruct)
    return adamw.AdamWState(step=P(), mu=mu, nu=mu)


# ------------------------------- LM ---------------------------------------

def _lm_cell(mod, cell: ShapeCell, mesh, multi_pod: bool):
    cfg: tfm.TransformerConfig = mod.FULL
    dp = dp_axes(multi_pod)
    dpP = dp if len(dp) > 1 else dp[0]
    seq, gb = cell.dims["seq_len"], cell.dims["global_batch"]
    pspecs = tfm.param_specs(cfg)
    pstruct = jax.eval_shape(partial(tfm.init_params, cfg=cfg),
                             jax.random.PRNGKey(0))
    params = _with_shardings(pstruct, pspecs, mesh)
    meta = {"params": int(sum(np.prod(l.shape) for l in
                              jax.tree.leaves(pstruct)))}

    if cell.kind == "train":
        opt_cfg = adamw.AdamWConfig()
        ostruct = jax.eval_shape(adamw.init_state, pstruct)
        ospecs = _opt_specs(pspecs, pstruct)
        opt = _with_shardings(ostruct, ospecs, mesh)
        tok_spec = P(dpP, None)
        toks = _sds((gb, seq), jnp.int32, mesh, tok_spec)

        def step(params, opt_state, tokens, labels):
            loss, grads = jax.value_and_grad(tfm.train_loss)(
                params, tokens, labels, cfg)
            params, opt_state, _ = adamw.apply_updates(
                params, grads, opt_state, opt_cfg)
            return params, opt_state, loss

        return step, (params, opt, toks, toks), (0, 1), meta

    if cell.kind == "prefill":
        toks = _sds((gb, seq), jnp.int32, mesh, P(dpP, None))

        def step(params, tokens):
            return tfm.prefill(params, tokens, cfg)

        return step, (params, toks), (), meta

    # decode: one new token against a seq_len KV cache
    bsz = gb
    cache_shape = (cfg.n_layers, 2, bsz, seq, cfg.n_kv_heads, cfg.d_head)
    dhead_mode = getattr(cfg, "decode_cache_shard", "seq") == "dhead"
    if bsz == 1:
        # long-context: sequence-shard the cache over every mesh axis
        cache_spec = P(None, None, None, flat_axes(multi_pod), None, None)
        tok_spec = P(None, None)
    elif dhead_mode:
        cache_spec = P(None, None, dpP, None, None, "model")
        tok_spec = P(dpP, None)
    else:
        cache_spec = P(None, None, dpP, "model", None, None)
        tok_spec = P(dpP, None)
    caches = _sds(cache_shape, cfg.dtype, mesh, cache_spec)
    token = _sds((bsz, 1), jnp.int32, mesh, tok_spec)
    clen = _sds((), jnp.int32, mesh, P())

    def step(params, token, caches, cache_len):
        return tfm.decode_step(params, token, caches, cache_len, cfg)

    return step, (params, token, caches, clen), (2,), meta


# ------------------------------- GNN --------------------------------------

def _gnn_batch_structs(arch: str, cell: ShapeCell, mesh, multi_pod: bool):
    fa = flat_axes(multi_pod)
    nchips = int(np.prod([mesh.shape[a] for a in fa]))
    d = dict(cell.dims)
    if cell.name == "minibatch_lg":
        seeds = d["batch_nodes"]
        f1, f2 = d["fanout"]
        n_nodes = seeds + seeds * f1 + seeds * f1 * f2
        n_edges = seeds * f1 + seeds * f1 * f2
        d_feat = 602  # Reddit-like
    elif cell.name == "molecule":
        n_nodes = d["n_nodes"] * d["batch"]
        n_edges = d["n_edges"] * d["batch"]
        d_feat = 16
    else:
        n_nodes, n_edges = d["n_nodes"], d["n_edges"]
        d_feat = d.get("d_feat", 16)
    N = _pad_up(n_nodes, nchips)
    E = _pad_up(n_edges, nchips)
    nmol = _pad_up(d.get("batch", 1), nchips) if cell.name == "molecule" else 1
    geo = arch in ("schnet", "mace")
    b = {}
    if geo:
        b["species"] = _sds((N,), jnp.int32, mesh, P(fa))
        b["positions"] = _sds((N, 3), jnp.float32, mesh, P(fa, None))
        b["energies"] = _sds((nmol,), jnp.float32, mesh,
                             P(fa) if nmol >= nchips else P(None))
        b["mol_id"] = _sds((N,), jnp.int32, mesh, P(fa))
    else:
        b["node_feats"] = _sds((N, d_feat), jnp.float32, mesh, P(fa, None))
        if arch == "meshgraphnet":
            b["edge_feats"] = _sds((E, 4), jnp.float32, mesh, P(fa, None))
            b["targets"] = _sds((N, 3), jnp.float32, mesh, P(fa, None))
        else:
            b["labels"] = _sds((N,), jnp.int32, mesh, P(fa))
    b["edge_index"] = _sds((2, E), jnp.int32, mesh, P(None, fa))
    meta = {"padded_nodes": N, "padded_edges": E, "d_feat": d_feat}
    return b, d_feat, meta


def _gnn_cell(arch, mod, cell: ShapeCell, mesh, multi_pod: bool):
    batch, d_feat, meta = _gnn_batch_structs(arch, cell, mesh, multi_pod)
    cfg = mod.FULL
    if arch == "meshgraphnet":
        cfg = dataclasses.replace(cfg, node_in=d_feat, edge_in=4)
        from repro.models.gnn import meshgraphnet as m
    elif arch == "pna":
        cfg = dataclasses.replace(cfg, node_in=d_feat, out_dim=47)
        from repro.models.gnn import pna as m
    elif arch == "schnet":
        from repro.models.gnn import schnet as m
    else:
        from repro.models.gnn import mace as m

    init = partial(m.init_params, cfg=cfg)
    pstruct = jax.eval_shape(init, jax.random.PRNGKey(0))
    # GNN params are small: replicate (graph data dominates).
    params = jax.tree.map(
        lambda s: _sds(s.shape, s.dtype, mesh, P()), pstruct,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    meta["params"] = int(sum(np.prod(l.shape)
                             for l in jax.tree.leaves(pstruct)))
    opt_cfg = adamw.AdamWConfig()
    ostruct = jax.eval_shape(adamw.init_state, pstruct)
    opt = jax.tree.map(
        lambda s: _sds(s.shape, s.dtype, mesh, P()), ostruct,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))

    loss_fn = m.train_loss

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg)
        params, opt_state, _ = adamw.apply_updates(params, grads, opt_state,
                                                   opt_cfg)
        return params, opt_state, loss

    return step, (params, opt, batch), (0, 1), meta


# ------------------------------ recsys ------------------------------------

def _recsys_cell(mod, cell: ShapeCell, mesh, multi_pod: bool):
    from repro.models.recsys import dcn
    cfg = mod.FULL
    fa = flat_axes(multi_pod)
    nchips = int(np.prod([mesh.shape[a] for a in fa]))
    dp = dp_axes(multi_pod)
    dpP = dp if len(dp) > 1 else dp[0]

    pstruct = jax.eval_shape(partial(dcn.init_params, cfg=cfg),
                             jax.random.PRNGKey(0))
    pspecs = jax.tree.map(lambda s: P(), pstruct,
                          is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    # embedding tables row-sharded over `model`
    pspecs["tables"] = {k: P("model", None) for k in pstruct["tables"]}
    params = _with_shardings(pstruct, pspecs, mesh)
    meta = {"params": int(sum(np.prod(l.shape)
                              for l in jax.tree.leaves(pstruct)))}

    B = _pad_up(cell.dims["batch"], nchips)
    bspec = fa if B >= nchips else None
    batch = {
        "dense": _sds((B, cfg.n_dense), jnp.float32, mesh, P(bspec, None)),
        "sparse": _sds((B, cfg.n_sparse), jnp.int32, mesh, P(bspec, None)),
        "labels": _sds((B,), jnp.int32, mesh, P(bspec)),
    }

    if cell.kind == "train":
        opt_cfg = adamw.AdamWConfig()
        ostruct = jax.eval_shape(adamw.init_state, pstruct)
        ospecs = _opt_specs(pspecs, pstruct)
        opt = _with_shardings(ostruct, ospecs, mesh)

        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(dcn.train_loss)(
                params, batch, cfg)
            params, opt_state, _ = adamw.apply_updates(
                params, grads, opt_state, opt_cfg)
            return params, opt_state, loss

        return step, (params, opt, batch), (0, 1), meta

    if cell.kind == "serve":
        def step(params, batch):
            return dcn.predict(params, batch["dense"], batch["sparse"], cfg)

        return step, (params, batch), (), meta

    # retrieval: 1 query vs n_candidates item embeddings
    nc = _pad_up(cell.dims["n_candidates"], nchips)
    cands = _sds((nc, cfg.retrieval_dim), jnp.float32, mesh, P(fa, None))
    q = {
        "dense": _sds((1, cfg.n_dense), jnp.float32, mesh, P(None, None)),
        "sparse": _sds((1, cfg.n_sparse), jnp.int32, mesh, P(None, None)),
    }
    meta["padded_candidates"] = nc

    def step(params, q, cands):
        return dcn.retrieval_scores(params, q["dense"], q["sparse"], cands,
                                    cfg)

    return step, (params, q, cands), (), meta


# ------------------------------ walk (bonus) -------------------------------

class _ModProxy:
    """Arch module stand-in with an overridden FULL config (used for the
    L=1/L=2 cost-extrapolation lowers)."""

    def __init__(self, mod, full):
        self.FAMILY = mod.FAMILY
        self.SHAPES = mod.SHAPES
        self.SMOKE = mod.SMOKE
        self.FULL = full


LAYER_FIELD = {"lm": "n_layers", "meshgraphnet": "n_layers", "pna": "n_layers",
               "schnet": "n_interactions"}


def scan_layer_count(arch: str):
    """(field, L) if the arch's layers run under lax.scan (whose body the
    XLA cost model counts ONCE — see dryrun cost extrapolation)."""
    mod = get_arch(arch)
    if mod.FAMILY == "lm":
        return "n_layers", mod.FULL.n_layers
    if arch in ("meshgraphnet", "pna"):
        return "n_layers", mod.FULL.n_layers
    if arch == "schnet":
        return "n_interactions", mod.FULL.n_interactions
    return None, None  # mace/dcn: python loop, fully counted


def apply_overrides(cfg, overrides: dict):
    """dataclasses.replace with dotted-path keys ('moe.dispatch')."""
    nested: dict = {}
    flat = {}
    for k, v in overrides.items():
        if "." in k:
            head, rest = k.split(".", 1)
            nested.setdefault(head, {})[rest] = v
        else:
            flat[k] = v
    for head, sub in nested.items():
        flat[head] = apply_overrides(getattr(cfg, head), sub)
    return dataclasses.replace(cfg, **flat)


def build_cell(arch: str, shape: str, mesh, multi_pod: bool,
               layers_override: int | None = None,
               overrides: dict | None = None):
    """Returns (fn, args, donate, meta) for one dry-run cell."""
    mod = get_arch(arch)
    if overrides:
        mod = _ModProxy(mod, apply_overrides(mod.FULL, overrides))
    if layers_override is not None:
        field, _ = scan_layer_count(arch)
        assert field is not None
        # unrolled so the XLA cost model sees every layer (trip counts are
        # invisible to cost_analysis — dryrun extrapolates from L=1/L=2)
        mod = _ModProxy(mod, dataclasses.replace(
            mod.FULL, scan_layers=False, **{field: layers_override}))
    cell = mod.SHAPES[shape]
    if mod.FAMILY == "lm":
        return _lm_cell(mod, cell, mesh, multi_pod)
    if mod.FAMILY == "gnn":
        return _gnn_cell(arch.replace("-", "_"), mod, cell, mesh, multi_pod)
    if mod.FAMILY == "recsys":
        return _recsys_cell(mod, cell, mesh, multi_pod)
    raise ValueError(mod.FAMILY)
