import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run driver (deliverable e).

For every (architecture × input shape × mesh) cell:
  jax.jit(step_fn).lower(*ShapeDtypeStructs).compile()
and record memory_analysis / cost_analysis / collective bytes parsed from
the post-SPMD HLO — the inputs to the §Roofline analysis.  No arrays are
ever allocated (ShapeDtypeStruct stand-ins only).

Results land incrementally in experiments/dryrun/<mesh>/<arch>__<shape>.json
so the sweep is resumable.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch phi35_moe --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]
"""
import argparse
import json
import re
import time
import traceback

import jax
import numpy as np

from repro.configs import ARCHS, get_arch
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z]+[0-9]+|pred)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in the SPMD module."""
    out = {c: 0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(?[^=]*?\)?)\s*"
                     r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                     r"collective-permute)", ls)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        if "-start" in ls.split("=")[1][:64] and op + "-start" not in ls:
            pass
        out[op] += _shape_bytes(shape_str)
        counts[op] += 1
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    out["counts"] = counts
    return out


def roofline_terms(flops: float, bytes_accessed: float, coll_bytes: float,
                   chips: int) -> dict:
    """Terms in seconds. The compiled SPMD module is the PER-DEVICE
    program, so cost_analysis flops/bytes and the parsed collective shard
    bytes are already per-chip: divide by per-chip peaks only.  (The spec
    formula `total / (chips × peak)` is identical — our inputs are
    `total / chips` already.)"""
    ct = flops / PEAK_FLOPS_BF16
    mt = bytes_accessed / HBM_BW
    lt = coll_bytes / ICI_BW
    terms = {"compute_s": ct, "memory_s": mt, "collective_s": lt}
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom
    terms["bound_s"] = max(ct, mt, lt)
    return terms


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str,
             force: bool = False, overrides: dict | None = None,
             tag: str = "") -> dict:
    mesh_name = "multi" if multi_pod else "single"
    d = os.path.join(out_dir, mesh_name)
    os.makedirs(d, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    path = os.path.join(d, f"{arch}__{shape}{suffix}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    from repro.launch.specs import build_cell, scan_layer_count
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    rec = {"arch": arch, "shape": shape, "mesh": list(mesh.shape.values()),
           "chips": chips, "status": "error", "overrides": overrides or {},
           "tag": tag}
    t0 = time.time()
    try:
        fn, args, donate, meta = build_cell(arch, shape, mesh, multi_pod,
                                            overrides=overrides)
        rec["meta"] = meta
        with mesh:
            lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
            compiled = lowered.compile()
        rec["lower_compile_s"] = time.time() - t0

        def _cost(c):
            ca = c.cost_analysis() or {}
            cb = collective_bytes(c.as_text())
            return {"flops": float(ca.get("flops", 0.0)),
                    "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
                    "collective_bytes": cb["total"],
                    "collectives": cb}

        cost = _cost(compiled)
        rec["cost_analysis_raw"] = dict(cost)

        # XLA's cost model counts a lax.scan body ONCE regardless of trip
        # count.  For scanned-layer models, lower L=1 and L=2 variants and
        # extrapolate: cost(L) = cost(1) + (L-1)·(cost(2)-cost(1)).
        field, L = scan_layer_count(arch)
        if field is not None and L and L > 1:
            with mesh:
                f1, a1, _, _ = build_cell(arch, shape, mesh, multi_pod,
                                          layers_override=1,
                                          overrides=overrides)
                c1 = _cost(jax.jit(f1).lower(*a1).compile())
                f2, a2, _, _ = build_cell(arch, shape, mesh, multi_pod,
                                          layers_override=2,
                                          overrides=overrides)
                c2 = _cost(jax.jit(f2).lower(*a2).compile())
            for k in ("flops", "bytes_accessed", "collective_bytes"):
                per_layer = max(c2[k] - c1[k], 0.0)
                cost[k] = c1[k] + (L - 1) * per_layer
            rec["cost_extrapolation"] = {
                "layers": L, "L1": {k: c1[k] for k in
                                    ("flops", "bytes_accessed",
                                     "collective_bytes")},
                "L2": {k: c2[k] for k in ("flops", "bytes_accessed",
                                          "collective_bytes")}}
        rec["cost_analysis"] = {"flops": cost["flops"],
                                "bytes_accessed": cost["bytes_accessed"]}
        rec["collectives"] = cost["collectives"]
        rec["collectives"]["total"] = cost["collective_bytes"]
        try:
            ma = compiled.memory_analysis()
            rec["memory_analysis"] = {
                k: int(getattr(ma, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(ma, k)}
        except Exception as e:  # CPU backend may not expose this
            rec["memory_analysis"] = {"error": str(e)}
        rec["roofline"] = roofline_terms(cost["flops"],
                                         cost["bytes_accessed"],
                                         cost["collective_bytes"], chips)
        rec["status"] = "ok"
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-3000:]
        rec["lower_compile_s"] = time.time() - t0
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    cells = []
    archs = ARCHS if (args.all or args.arch is None) else [args.arch]
    for a in archs:
        mod = get_arch(a)
        shapes = list(mod.SHAPES) if args.shape is None else [args.shape]
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    n_ok = 0
    for a, s, mp in cells:
        rec = run_cell(a, s, mp, args.out, force=args.force)
        tag = "multi " if mp else "single"
        if rec["status"] == "ok":
            n_ok += 1
            r = rec["roofline"]
            print(f"[{tag}] {a:14s} {s:14s} OK   "
                  f"compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s "
                  f"coll={r['collective_s']:.3e}s dom={r['dominant']}",
                  flush=True)
        else:
            print(f"[{tag}] {a:14s} {s:14s} FAIL {rec['error'][:120]}",
                  flush=True)
    print(f"{n_ok}/{len(cells)} cells OK")


if __name__ == "__main__":
    main()
