"""Production mesh construction (MULTI-POD DRY-RUN spec).

Defined as functions so importing this module never touches jax device
state.  The single-pod mesh is 16×16 = 256 chips (paper analogue: the
32-HBM-channel U55C scaled to a pod); multi-pod adds a leading ``pod``
axis (2 pods = 512 chips)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)


def flat_axes(multi_pod: bool):
    """All mesh axes — used to shard graph/recsys bulk dims over every chip."""
    return ("pod", "data", "model") if multi_pod else ("data", "model")


# TPU v5e-class hardware constants for the roofline (§ROOFLINE ANALYSIS).
PEAK_FLOPS_BF16 = 197e12     # per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
