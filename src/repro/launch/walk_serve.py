"""Open-system GRW serving driver — continuous Poisson arrivals against the
streaming walk engine (the queuing setting Theorem VI.1 models).

  PYTHONPATH=src python -m repro.launch.walk_serve --algo urw --dataset WG \
      --rho 0.8 --requests 64 --request-size 16 --slots 512 --chunk 8

Sharded serving runs the same service over the distributed superstep
(requires >1 visible device; on CPU force them with
``XLA_FLAGS=--xla_force_host_platform_device_count=N``):

  PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=2 \
      python -m repro.launch.walk_serve --backend sharded --slots 64

Compare with `repro.launch.walk`, which drains a fixed (closed) batch.
"""
from __future__ import annotations

import argparse

from repro import walker
from repro.configs.ridgewalker import ALGORITHMS, QUERY_LENGTH
from repro.graph import make_dataset
from repro.serve import OpenLoad, run_open_load


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--algo", default="urw", choices=sorted(ALGORITHMS))
    ap.add_argument("--backend", default="single",
                    choices=sorted(walker.BACKENDS),
                    help="single device or sharded across the device mesh")
    ap.add_argument("--dataset", default="WG")
    ap.add_argument("--scale", type=int, default=None,
                    help="RMAT scale override (CPU-sized default)")
    ap.add_argument("--rho", type=float, default=0.8,
                    help="offered utilization λ·E[L]/W (>=1 overloads)")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--request-size", type=int, default=16,
                    help="walks per request")
    ap.add_argument("--slots", type=int, default=512)
    ap.add_argument("--max-hops", type=int, default=QUERY_LENGTH)
    ap.add_argument("--chunk", type=int, default=8,
                    help="supersteps per host-injection chunk")
    ap.add_argument("--capacity", type=int, default=8192,
                    help="live-query slot-ring capacity (slots recycle "
                    "continuously; this bounds concurrency, not volume)")
    ap.add_argument("--injection-delay", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    spec = ALGORITHMS[args.algo]
    weighted = spec.kind in ("alias", "reservoir_n2v")
    g = make_dataset(args.dataset, weighted=weighted,
                     with_alias=spec.kind == "alias",
                     scale_override=args.scale, seed=args.seed)
    print(f"{args.dataset}: |V|={g.num_vertices} |E|={g.num_edges} "
          f"max_deg={g.max_degree}")

    program = walker.WalkProgram(spec=spec, max_hops=args.max_hops,
                                 name=args.algo)
    execution = walker.ExecutionConfig(num_slots=args.slots,
                                       injection_delay=args.injection_delay)
    svc = walker.compile(program, backend=args.backend,
                         execution=execution).serve(
        g, capacity=args.capacity, chunk=args.chunk, seed=args.seed)
    load = OpenLoad(num_requests=args.requests,
                    request_size=args.request_size,
                    utilization=args.rho)
    a = run_open_load(svc, load, seed=args.seed)
    stats = svc.walk_stats()
    print(f"backend={args.backend} offered_load={a.offered_load:.2f} "
          f"walks/superstep (rho={a.utilization:.2f})")
    print(f"requests={a.requests} walks={a.walks} supersteps={a.supersteps} "
          f"drops={int(stats.drops)}")
    print(f"sojourn supersteps: p50={a.p50_sojourn:.1f} "
          f"p99={a.p99_sojourn:.1f} mean={a.mean_sojourn:.1f} "
          f"(admission wait p50={a.p50_admission_wait:.1f} "
          f"p99={a.p99_admission_wait:.1f})")
    print(f"throughput={a.throughput:.1f} hops/superstep "
          f"({a.msteps_per_s:.3f} MStep/s) bubble_ratio={a.bubble_ratio:.3f} "
          f"starved_ratio={a.starved_ratio:.3f}")


if __name__ == "__main__":
    main()
