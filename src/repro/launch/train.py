"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch deepseek_7b --smoke \
      --steps 50 --batch 8 --seq 128

``--smoke`` uses the reduced config on the host device count; the full
configs are exercised via the dry-run only (this container has 1 CPU
device).  The loop runs through `runtime/train_loop.py` — checkpointing,
straggler watchdog, resume — so the fault-tolerance path is the same one
a real cluster job uses.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.data import pipeline as datapipe
from repro.optim import adamw
from repro.runtime import train_loop


def make_lm_step(cfg, opt_cfg):
    from repro.models import transformer as tfm

    @jax.jit
    def step(state, batch):
        params, opt_state = state
        tokens, labels = batch
        loss, grads = jax.value_and_grad(tfm.train_loss)(
            params, tokens, labels, cfg)
        params, opt_state, stats = adamw.apply_updates(
            params, grads, opt_state, opt_cfg)
        return (params, opt_state), {"loss": loss, **stats}

    return step


def make_gnn_step(arch, cfg, opt_cfg):
    import repro.models.gnn as gnnmod
    m = getattr(gnnmod, arch)

    @jax.jit
    def step(state, batch):
        params, opt_state = state
        loss, grads = jax.value_and_grad(m.train_loss)(params, batch, cfg)
        params, opt_state, stats = adamw.apply_updates(
            params, grads, opt_state, opt_cfg)
        return (params, opt_state), {"loss": loss, **stats}

    return step


def make_recsys_step(cfg, opt_cfg):
    from repro.models.recsys import dcn

    @jax.jit
    def step(state, batch):
        params, opt_state = state
        loss, grads = jax.value_and_grad(dcn.train_loss)(params, batch, cfg)
        params, opt_state, stats = adamw.apply_updates(
            params, grads, opt_state, opt_cfg)
        return (params, opt_state), {"loss": loss, **stats}

    return step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    mod = get_arch(args.arch)
    cfg = mod.SMOKE if args.smoke else mod.FULL
    opt_cfg = adamw.AdamWConfig(lr=args.lr, total_steps=args.steps,
                                warmup_steps=max(1, args.steps // 10))
    key = jax.random.PRNGKey(0)

    if mod.FAMILY == "lm":
        from repro.models import transformer as tfm
        cfg = dataclasses.replace(cfg, dtype=jnp.float32)
        params = tfm.init_params(key, cfg)
        dcfg = datapipe.TokenPipelineConfig(cfg.vocab, args.seq, args.batch)
        batch_fn = lambda step: jax.tree.map(
            jnp.asarray, datapipe.lm_batch(dcfg, step))
        step_fn = make_lm_step(cfg, opt_cfg)
    elif mod.FAMILY == "gnn":
        arch = args.arch.replace("-", "_")
        if arch in ("schnet", "mace"):
            b = datapipe.molecule_batch(16, 48, args.batch)
        else:
            b = datapipe.gnn_batch(256, 1024, getattr(cfg, "node_in", 8),
                                   d_edge=4 if arch == "meshgraphnet" else 0,
                                   n_classes=getattr(cfg, "out_dim", 5))
        b = jax.tree.map(jnp.asarray, b)
        batch_fn = lambda step: b
        m = getattr(__import__("repro.models.gnn", fromlist=[arch]), arch)
        params = m.init_params(key, cfg)
        step_fn = make_gnn_step(arch, cfg, opt_cfg)
    else:
        from repro.models.recsys import dcn
        params = dcn.init_params(key, cfg)
        batch_fn = lambda step: jax.tree.map(jnp.asarray, datapipe.recsys_batch(
            args.batch, cfg.n_dense, cfg.n_sparse, cfg.vocabs(), seed=step))
        step_fn = make_recsys_step(cfg, opt_cfg)

    opt_state = adamw.init_state(params)
    state = (params, opt_state)
    loop_cfg = train_loop.TrainLoopConfig(
        total_steps=args.steps, ckpt_dir=args.ckpt_dir,
        ckpt_every=max(10, args.steps // 3), log_every=5)
    start = 0
    if args.resume:
        state, start = train_loop.resume_or_init(args.ckpt_dir, state)
        print(f"resumed at step {start}")
    state, step, history, watchdog = train_loop.run(
        step_fn, state, batch_fn, loop_cfg, start_step=start)
    if history:
        print("first:", history[0])
        print("last: ", history[-1])
    print(f"done at step {step}; stragglers={watchdog.straggler_steps}")


if __name__ == "__main__":
    main()
