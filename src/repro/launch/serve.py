"""LM serving driver with zero-bubble continuous batching.

The paper's scheduler, applied beyond-paper (DESIGN.md §4): decode slots
are lanes; a finished sequence frees its lane, which is refilled from the
pending-request queue by the same prefix-sum compaction the walk engine
uses.  Bubble ratio (idle-lane-steps / lane-steps) is reported — the
serving analogue of Fig. 11.

  PYTHONPATH=src python -m repro.launch.serve --arch deepseek_7b \
      --requests 64 --slots 8 --max-new 32
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import transformer as tfm


@dataclasses.dataclass
class ServeStats:
    lane_steps: int = 0
    busy_steps: int = 0
    completed: int = 0
    decode_steps: int = 0

    @property
    def bubble_ratio(self):
        return 1.0 - self.busy_steps / max(self.lane_steps, 1)


def continuous_batching_loop(params, cfg, requests, num_slots: int,
                             max_new: int, cache_cap: int, seed: int = 0):
    """requests: list of (prompt array). Greedy decode, slot refill."""
    stats = ServeStats()
    key = jax.random.PRNGKey(seed)

    decode = jax.jit(lambda p, t, c, l: tfm.decode_step(p, t, c, l, cfg))

    # Lane state (host-managed; device state is the batched KV cache).
    caches = tfm.make_kv_cache(cfg, num_slots, cache_cap, jnp.float32)
    cur_tok = jnp.zeros((num_slots, 1), jnp.int32)
    lens = np.zeros(num_slots, np.int32)          # per-lane position
    remaining = np.zeros(num_slots, np.int32)     # tokens left to emit
    active = np.zeros(num_slots, bool)
    outputs = [[] for _ in range(num_slots)]
    results = []
    queue = list(enumerate(requests))
    qhead = 0

    def refill():
        nonlocal qhead, cur_tok, caches
        for lane in range(num_slots):
            if not active[lane] and qhead < len(queue):
                rid, prompt = queue[qhead]
                qhead += 1
                # prefill this lane (single-request prefill)
                logits, kv = tfm.prefill(params, prompt[None, :], cfg)
                S = prompt.shape[0]
                # kv: (L, 2, 1, S, H, D) -> write into lane cache
                caches = caches.at[:, :, lane:lane + 1, :S].set(
                    kv.astype(caches.dtype))
                nxt = jnp.argmax(logits[0, -1]).astype(jnp.int32)
                cur_tok = cur_tok.at[lane, 0].set(nxt)
                lens[lane] = S
                remaining[lane] = max_new
                active[lane] = True
                outputs[lane] = [int(nxt)]

    refill()
    while active.any():
        stats.lane_steps += num_slots
        stats.busy_steps += int(active.sum())
        stats.decode_steps += 1
        # NOTE: single cache_len per call requires equal lane positions in
        # this simplified host loop; we step lanes at their own position by
        # taking the max and masking — for the demo all prompts share length.
        pos = int(lens[active].max())
        logits, caches = decode(params, cur_tok, caches, jnp.asarray(pos))
        nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
        cur_tok = nxt[:, None]
        for lane in range(num_slots):
            if not active[lane]:
                continue
            outputs[lane].append(int(nxt[lane]))
            lens[lane] += 1
            remaining[lane] -= 1
            if remaining[lane] <= 0 or lens[lane] >= cache_cap - 1:
                results.append(outputs[lane])
                stats.completed += 1
                active[lane] = False
        refill()
    return results, stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek_7b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=8)
    args = ap.parse_args()

    mod = get_arch(args.arch)
    assert mod.FAMILY == "lm", "serving is for LM archs"
    cfg = dataclasses.replace(mod.SMOKE, dtype=jnp.float32)
    key = jax.random.PRNGKey(0)
    params = tfm.init_params(key, cfg)
    rng = np.random.default_rng(0)
    reqs = [jnp.asarray(rng.integers(0, cfg.vocab, args.prompt_len),
                        jnp.int32) for _ in range(args.requests)]
    t0 = time.time()
    results, stats = continuous_batching_loop(
        params, cfg, reqs, args.slots, args.max_new,
        cache_cap=args.prompt_len + args.max_new + 2)
    dt = time.time() - t0
    print(f"completed={stats.completed} decode_steps={stats.decode_steps} "
          f"bubble_ratio={stats.bubble_ratio:.3f} time={dt:.1f}s")
    print("sample output:", results[0][:8])


if __name__ == "__main__":
    main()
