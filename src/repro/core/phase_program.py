"""Sampler phase-program IR: one declarative sampler definition, every
backend a lowering of it.

RidgeWalker's Markov decomposition (paper §IV–§VI) makes each hop of a
walk a stateless task that factors into fine-grained *phases* any
substrate can execute out of order — the paper's Row Access / Sampling /
Column Access pipeline stages, generalized (LightRW makes the same
observation for second-order dynamic walks: every sampler reduces to a
small set of gather/score/commit primitives).  This module is that
factorization made explicit:

  * a :class:`SamplerSpec` **lowers once** (:func:`lower`) into a
    :class:`PhaseProgram` — a short sequence of typed :class:`Phase`
    records (``draw`` / ``gather`` / ``score`` / ``commit``) with
    explicit *operand residency* (owner-of-``v_curr`` vs
    owner-of-``v_prev``), and
  * every backend is a generic interpreter/lowerer of that IR:

      - the single-device jnp superstep executes the phases vectorized
        in one pass (:func:`make_sampler` — the replacement for the old
        per-sampler ``sample_*`` dispatch table);
      - the sharded engine (`core/distributed.py`) reads the residency
        schedule to build the task word and per-superstep routing plan
        (replacing the hand-written ``_FirstOrderCap`` /
        ``_TwoPhaseN2VCap`` / ``_ChunkedReservoirCap`` trio);
      - the fused device-resident Pallas kernel
        (`kernels/fused_superstep`) stages the same phases' operands
        through its double-buffered DMA machinery for every program:
        loop-free phase lists run as one launch-resident pass, and the
        chunked reservoir scan runs as an in-kernel degree-adaptive
        chunk loop with its carry held in SMEM (``fused`` is True for
        all programs — there is no jnp fallback).

Because each phase's arithmetic lives in exactly one executor here and
each backend drives the *same* executors (or, for the kernel, a pinned
scalar transliteration of them), all lowerings sample bit-identical
walks — the property `tests/test_fused_step.py` / `test_walker_api.py`
pin across impls and backends.

Phase vocabulary
----------------
``draw(width, salt)``
    Consume ``width`` U[0,1) draws from the task's stateless stream
    (`rng.task_uniforms` at the given salt channel).
``gather(segment, width)``
    Materialize candidate operands from the graph at the phase's
    residency: ``csr`` (``width`` proposal columns from N(v_curr)),
    ``typed`` (the MetaPath sub-segment bounds from ``type_offsets``),
    ``alias`` (Walker alias-table probes), ``chunk`` (one reservoir
    chunk of (candidate, edge weight)).
``score(reduction)``
    Reduce candidates to a decision: ``pick_uniform``, ``alias_accept``,
    ``first_accept`` (bounded-round rejection), ``es_reservoir``
    (Efraimidis–Spirakis weighted reservoir fold).
``commit``
    Column access on the chosen offset + hop advance (engine-owned).

Residency is what the sharded engine routes on: a program whose phases
all live at ``v_curr`` is a one-superstep hop at owner(v_curr)
(``first_order``); a ``score`` at ``v_prev`` splits the hop into a
propose/verify superstep pair (``two_phase``); a looping chunk program
ping-pongs gather@owner(v_curr) / score@owner(v_prev) until the scan
covers deg(v_curr) (``chunked_reservoir``).

Run ``python -m repro.core.phase_program`` to regenerate the
sampler × step_impl × backend support matrix embedded in
``docs/api.md`` and ``python -m repro.core.phase_program --schedule``
for the phase-program → schedule → backend table in
``docs/architecture.md`` — both docs tables are generated from these
declarations, not hand-maintained (pinned by tests;
``python -m repro.core.phase_program --check`` fails on drift and is
run by CI).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import rng as task_rng
from repro.core.rng import SALT_CHUNK0, SALT_COLUMN, SALT_STOP
from repro.core.samplers import (KINDS, SamplerSpec, _uniform_index,
                                 es_chunk_score, es_merge, es_num_chunks,
                                 n2v_bias, rejection_choose, vertex_row)

__all__ = ["KINDS", "Phase", "PhaseProgram", "DrawStream", "lower",
           "make_sampler", "reservoir_scan", "chunk_gather", "chunk_score",
           "fused_kinds", "support_rows", "render_support_matrix",
           "render_schedule_table"]


@dataclasses.dataclass(frozen=True)
class Phase:
    """One typed phase of a hop.

    ``op``        — draw | gather | score | commit.
    ``variant``   — gather segment (csr/typed/alias/chunk) or score
                    reduction (pick_uniform/alias_accept/first_accept/
                    es_reservoir); "" for draw/commit.
    ``residency`` — which vertex's owner holds this phase's operands:
                    "v_curr" or "v_prev".
    ``width``     — per-lane operand fan-out (rng draws for ``draw``,
                    candidates for ``gather``).
    ``salt``      — rng salt channel for ``draw``.
    """

    op: str
    variant: str = ""
    residency: str = "v_curr"
    width: int = 1
    salt: int = SALT_COLUMN

    @property
    def cacheable(self) -> bool:
        """May this phase's graph operands be served from the hot-vertex
        cache?  True exactly for ``v_curr``-resident ``gather``/``commit``
        phases: their operands are slices of the current vertex's
        adjacency payload, which is what `graph.hot_cache` packs into
        VMEM.  ``v_prev``-resident phases (the rejection verify and the
        reservoir bias/membership probes) address N(v_prev) and always
        take the HBM DMA path."""
        return self.op in ("gather", "commit") and self.residency == "v_curr"


@dataclasses.dataclass(frozen=True)
class PhaseProgram:
    """A lowered sampler: the phase list plus the derived facts every
    backend dispatches on.

    ``loop``    — the gather/score pair repeats per reservoir chunk
                  (trip count ceil(deg/chunk)).
    ``carry``   — cross-residency task-word payload the phases thread
                  between owners: "none" (single-word WalkerSlots),
                  "candidates" (N2VSlots: K proposal columns + a phase
                  bit), "reservoir" (ReservoirSlots: chunk buffer +
                  running E-S maximum + phase counter).
    ``requires``— graph payloads the program samples from
                  ("alias" | "typed" | "weights"), used for validation
                  and the docs matrix.
    """

    kind: str
    phases: Tuple[Phase, ...]
    loop: bool = False
    carry: str = "none"
    requires: Tuple[str, ...] = ()

    # ------------------------------------------------------------ derived

    @property
    def schedule(self) -> str:
        """Sharded execution schedule implied by the residencies:
        ``single_phase`` (whole hop at owner(v_curr)), ``two_phase``
        (propose at owner(v_curr), verify at owner(v_prev)), or
        ``chunked_loop`` (per-chunk gather/score ping-pong)."""
        if self.loop:
            return "chunked_loop"
        if any(p.residency == "v_prev" for p in self.phases):
            return "two_phase"
        return "single_phase"

    @property
    def capability(self) -> Optional[str]:
        """Distributed capability the program declares — the dispatch
        key `core.distributed` allocates the task word and routing
        schedule from.  ``None`` would mean "not distributable"; every
        current program declares one (MetaPath's typed sub-segments are
        partitioned alongside the CSR shards)."""
        return {"single_phase": "first_order",
                "two_phase": "two_phase",
                "chunked_loop": "chunked_reservoir"}[self.schedule]

    @property
    def fused(self) -> bool:
        """Lowerable to the device-resident fused superstep kernel.

        True for every program: loop-free phase lists run as one
        launch-resident pass, and the looping chunk program
        (``chunked_loop``) runs as an in-kernel degree-adaptive chunk
        loop whose reservoir carry stays SMEM-resident — so the fused
        kernel covers the whole sampler matrix and the engine never
        falls back to jnp.
        """
        return True

    @property
    def pallas(self) -> bool:
        """Covered by the one-hop `kernels/walk_step` Pallas kernel
        (single-residency programs over the plain/alias CSR segments)."""
        return all(p.residency == "v_curr" for p in self.phases) and not (
            self.loop or "typed" in self.requires)

    @property
    def cache_payloads(self) -> Tuple[str, ...]:
        """Adjacency payload arrays the hot-vertex cache must pack for
        this program — read off the cacheable (``v_curr``-resident)
        gather/commit phases, so `graph.hot_cache.build_hot_cache` sizes
        the VMEM block from the program, not a hand-kept list.

        Every program needs ``col`` (the commit column access); the
        alias probe adds ``alias_prob``/``alias_idx``, the typed gather
        adds ``type_offsets``, and the reservoir chunk gather adds
        ``weights``.  ``v_prev``-resident phases contribute nothing —
        their operands stay on the HBM DMA path.
        """
        payloads = ["col"]
        for ph in self.phases:
            if not ph.cacheable or ph.op != "gather":
                continue
            payloads += {"alias": ["alias_prob", "alias_idx"],
                         "typed": ["type_offsets"],
                         "chunk": ["weights"],
                         "csr": []}[ph.variant]
        return tuple(payloads)

    # ------------------------------------------- static-analysis exports

    def draw_streams(self) -> Tuple["DrawStream", ...]:
        """Declarative RNG draw streams this program consumes per task —
        the schedule-export hook the `repro.analysis` RNG-collision pass
        reads.

        Each ``draw`` phase contributes one stream at its salt channel;
        in a looping program the draw repeats per chunk at
        ``salt + chunk``, an open-ended *family* (chunk counts are
        degree-dependent and statically unbounded).  Engine-issued draws
        (the PPR stop draw) are declared separately
        (`repro.core.walk_engine.ENGINE_DRAW_STREAMS`) — they share the
        same (seed, epoch, qid, hop) tuple, so the analyzer checks them
        against these streams too.
        """
        streams = []
        for n, ph in enumerate(self.phases):
            if ph.op != "draw":
                continue
            streams.append(DrawStream(
                site=f"{self.kind}.phases[{n}].draw",
                salt=ph.salt, width=ph.width, family=self.loop))
        return tuple(streams)


class DrawStream(NamedTuple):
    """One per-task RNG draw stream: ``width`` uniforms at salt channel
    ``salt`` (or, for a chunk *family*, at every salt in ``[salt, ∞)`` —
    one chunk per salt, degree-dependent count).  Two streams with
    distinct salts are disjoint by the Threefry key fold; two streams
    sharing any salt value both consume counters ``[0, width)`` there and
    therefore collide — the RNG-collision pass's whole check."""

    site: str
    salt: int
    width: int
    family: bool = False

    def salt_span(self) -> Tuple[int, Optional[int]]:
        """Half-open salt interval this stream draws from (``None`` hi =
        unbounded chunk family)."""
        return (self.salt, None if self.family else self.salt + 1)


@functools.lru_cache(maxsize=None)
def lower(spec: SamplerSpec) -> PhaseProgram:
    """Lower a sampler definition to its phase program (cached — specs
    are frozen/hashable, and backends re-lower freely)."""
    k = spec.kind
    if k == "uniform":
        return PhaseProgram(k, (
            Phase("draw", width=1),
            Phase("score", "pick_uniform"),
            Phase("commit"),
        ))
    if k == "alias":
        return PhaseProgram(k, (
            Phase("draw", width=2),
            Phase("gather", "alias"),
            Phase("score", "alias_accept"),
            Phase("commit"),
        ), requires=("alias",))
    if k == "metapath":
        return PhaseProgram(k, (
            Phase("draw", width=1),
            Phase("gather", "typed"),
            Phase("score", "pick_uniform"),
            Phase("commit"),
        ), requires=("typed",))
    if k == "rejection_n2v":
        K = spec.rejection_rounds
        return PhaseProgram(k, (
            Phase("draw", width=2 * K),
            Phase("gather", "csr", width=K),
            Phase("score", "first_accept", residency="v_prev", width=K),
            Phase("commit"),
        ), carry="candidates")
    if k == "reservoir_n2v":
        CH = spec.reservoir_chunk
        return PhaseProgram(k, (
            Phase("draw", width=CH, salt=SALT_CHUNK0),
            Phase("gather", "chunk", width=CH),
            Phase("score", "es_reservoir", residency="v_prev", width=CH),
            Phase("commit"),
        ), loop=True, carry="reservoir", requires=("weights",))
    raise ValueError(f"unknown sampler kind: {k!r}")


# ==========================================================================
# jnp lowering: execute the phase list vectorized, one pass per hop.
# Each (op, variant) pair has exactly one executor; the sharded engine's
# propose/verify/chunk supersteps call the same executors on its local
# graph views (they are residency-aware via `samplers.vertex_row` /
# `edge_exists`), which is what keeps every backend bit-identical.
# ==========================================================================


class _Ctx:
    """Mutable interpretation state threaded through one hop's phases."""

    __slots__ = ("spec", "g", "addr", "deg", "slots", "base_key", "u",
                 "cand_idx", "cand", "seg_base", "seg_cnt", "index", "ok")

    def __init__(self, spec, g, addr, deg, slots, base_key):
        self.spec, self.g = spec, g
        self.addr, self.deg = addr, deg
        self.slots, self.base_key = slots, base_key
        self.u = None
        self.cand_idx = None     # (W, K) neighbor offsets
        self.cand = None         # (W, K) candidate vertices
        self.seg_base = None     # typed sub-segment base offset
        self.seg_cnt = None      # typed sub-segment length
        self.index = None        # chosen neighbor offset
        self.ok = None           # lane has a valid continuation


def _exec_draw(ph: Phase, ctx: _Ctx):
    s = ctx.slots
    ctx.u = task_rng.task_uniforms(ctx.base_key, s.query_id, s.hop, ph.width,
                                   ph.salt, epoch=s.epoch)


def _exec_gather_alias(ph: Phase, ctx: _Ctx):
    # The alias tables live beside the CSR segment at owner(v_curr); the
    # jnp pass probes them directly in the score phase (the fused kernel
    # lowers this phase to its two one-element DMA probes).
    pass


def _exec_gather_typed(ph: Phase, ctx: _Ctx):
    """MetaPath sub-segment bounds for hop t's scheduled edge type."""
    g, s, spec = ctx.g, ctx.slots, ctx.spec
    sched = jnp.asarray(spec.metapath, jnp.int32)
    t = sched[s.hop % len(spec.metapath)]
    row = vertex_row(g, s.v_curr)
    base = g.type_offsets[row, t]
    ctx.seg_base = base
    ctx.seg_cnt = g.type_offsets[row, t + 1] - base


def _exec_gather_csr(ph: Phase, ctx: _Ctx):
    """K proposal columns from N(v_curr) (rejection sampling phase A)."""
    K = ph.width
    u_col = ctx.u[:, :K]
    ctx.cand_idx = _uniform_index(ctx.deg[:, None], u_col)
    e = jnp.clip(ctx.addr[:, None] + ctx.cand_idx, 0,
                 ctx.g.col.shape[-1] - 1)
    ctx.cand = ctx.g.col[e]


def _exec_score_pick_uniform(ph: Phase, ctx: _Ctx):
    """index = min(floor(u·n), n-1) over the CSR segment or, when a typed
    gather ran, over the scheduled sub-segment (no match → dead lane)."""
    if ctx.seg_base is not None:
        ctx.index = ctx.seg_base + _uniform_index(ctx.seg_cnt, ctx.u[:, 0])
        ctx.ok = (ctx.seg_cnt > 0) & (ctx.deg > 0)
    else:
        ctx.index = _uniform_index(ctx.deg, ctx.u[:, 0])
        ctx.ok = ctx.deg > 0


def _exec_score_alias_accept(ph: Phase, ctx: _Ctx):
    """Walker alias method: accept the column draw with prob[e], else
    take the alias index — O(1) per draw, two uniforms, two probes."""
    g = ctx.g
    k = _uniform_index(ctx.deg, ctx.u[:, 0])
    e = jnp.clip(ctx.addr + k, 0, g.col.shape[-1] - 1)
    accept = ctx.u[:, 1] < g.alias_prob[e]
    idx = jnp.where(accept, k, g.alias_idx[e])
    ctx.index = jnp.clip(idx, 0, jnp.maximum(ctx.deg - 1, 0))
    ctx.ok = ctx.deg > 0


def _exec_score_first_accept(ph: Phase, ctx: _Ctx):
    """Bounded-round rejection (gSampler/KnightKing style): first
    proposal whose (p, q) bias survives the accept test wins; the last
    round is forced (geometric tail bias < (1-a_min)^K, measured in
    tests)."""
    K = ph.width
    w = n2v_bias(ctx.spec, ctx.g, ctx.slots.v_prev, ctx.cand)
    first = rejection_choose(ctx.spec, ctx.u[:, K:], w)
    ctx.index = jnp.take_along_axis(ctx.cand_idx, first[:, None], 1)[:, 0]
    ctx.ok = ctx.deg > 0


def _exec_commit(ph: Phase, ctx: _Ctx):
    pass  # column access + hop advance are engine-owned


_JNP_EXEC = {
    ("draw", ""): _exec_draw,
    ("gather", "alias"): _exec_gather_alias,
    ("gather", "typed"): _exec_gather_typed,
    ("gather", "csr"): _exec_gather_csr,
    ("score", "pick_uniform"): _exec_score_pick_uniform,
    ("score", "alias_accept"): _exec_score_alias_accept,
    ("score", "first_accept"): _exec_score_first_accept,
    ("commit", ""): _exec_commit,
}


def reservoir_scan(spec: SamplerSpec, g, addr, deg, slots, base_key):
    """Chunked-loop lowering executed locally: the whole E-S reservoir
    scan of N(v_curr) in one vectorized pass (weighted Node2Vec,
    LightRW's method) — key = u^(1/w'), keep the max; O(deg) work per
    hop, chunked so the working set stays in VMEM.

    This is the jnp lowering of the looping (draw, gather-chunk,
    score-chunk) program; the sharded engine lowers the *same* program
    to a per-chunk gather@owner(v_curr) / score@owner(v_prev) superstep
    ping-pong (`distributed.ProgramCapability`), staging
    :func:`chunk_gather`'s output through the task word and folding with
    the shared `es_chunk_score`/`es_merge` — same uniforms, same float
    ops, bit-identical scanned argmax.

    Degree-adaptive scan (``spec.adaptive_chunks``): the chunk loop runs
    a dynamic ``ceil(max(live deg)/chunk)`` trip count instead of the
    static ``ceil(max_degree/chunk)``.  Every chunk past a lane's own
    degree contributes only -inf reservoir keys, so truncating the loop
    at the live lanes' max degree cannot change any lane's scanned
    argmax — paths are bit-identical, only the wasted supersteps of the
    power-law tail disappear."""
    CH = spec.reservoir_chunk
    n_chunks = es_num_chunks(g.max_degree, CH)
    W = addr.shape[0]

    def chunk_body(c, carry):
        """One gather+score trip of the chunked E-S scan (fori body)."""
        best_key, best_idx = carry
        u = task_rng.task_uniforms(base_key, slots.query_id, slots.hop, CH,
                                   SALT_CHUNK0 + c, epoch=slots.epoch)
        # Same staging as the sharded gather phase (chunk_gather pads
        # invalid lanes to (-1, 0.0), which es_chunk_score keys to -inf
        # exactly like an explicit position mask — bit-identical fold).
        chunk = jnp.full((W,), c, jnp.int32)
        y, w_edge = chunk_gather(g, addr, deg, chunk, CH)
        w = w_edge * n2v_bias(spec, g, slots.v_prev, y)
        c_best, c_key = es_chunk_score(u, y >= 0, w)
        return es_merge(best_key, best_idx, c, CH, c_best, c_key)

    init = (jnp.full((W,), -jnp.inf), jnp.zeros((W,), jnp.int32))
    if spec.adaptive_chunks:
        live_deg = jnp.max(jnp.where(slots.active, deg, 0))
        hi = jnp.clip((live_deg + CH - 1) // CH, 1, n_chunks)
    else:
        hi = n_chunks
    _, best_idx = jax.lax.fori_loop(0, hi, chunk_body, init)
    return jnp.clip(best_idx, 0, jnp.maximum(deg - 1, 0)), deg > 0


def chunk_gather(g, addr, deg, chunk, width):
    """Stage chunk ``chunk`` of (candidate vertex, edge weight) from the
    CSR segment at ``addr`` — the gather phase of the chunked-loop
    program, shared by the sharded lowering.  Padding lanes carry
    ``(-1, 0.0)`` so the score phase's validity mask and E-S keys match
    the local scan exactly."""
    pos = chunk[:, None] * width + jnp.arange(width, dtype=jnp.int32)[None, :]
    valid = pos < deg[:, None]
    e = jnp.clip(addr[:, None] + pos, 0, g.col.shape[-1] - 1)
    y = jnp.where(valid, g.col[e], -1)
    if g.weights is not None:
        w_edge = jnp.where(valid, g.weights[e], 0.0)
    else:
        w_edge = jnp.where(valid, 1.0, 0.0)
    return y, w_edge


def chunk_score(spec: SamplerSpec, g, slots, chunk, width, base_key):
    """Score one staged chunk at owner(v_prev): E-S keys under the local
    adjacency bias, folded into the carried reservoir maximum — the
    score phase of the chunked-loop program (sharded lowering)."""
    u = task_rng.task_uniforms(base_key, slots.query_id, slots.hop, width,
                               SALT_CHUNK0 + chunk, epoch=slots.epoch)
    svalid = slots.cand >= 0
    w = slots.cand_w * n2v_bias(spec, g, slots.v_prev, slots.cand)
    c_best, c_key = es_chunk_score(u, svalid, w)
    return es_merge(slots.best_key, slots.best_idx, chunk, width, c_best,
                    c_key)


def make_sampler(spec: SamplerSpec):
    """Lower ``spec`` for the vectorized single-superstep backend:
    returns ``sample(g, addr, deg, slots, base_key) -> (index, ok)``.

    ``g`` may be the full `CSRGraph` or a sharded `LocalView` — the
    executors are residency-aware (`samplers.vertex_row` maps vertex ids
    to local rows), so the same lowering serves the single-device engine
    and the sharded engine's single-phase hops."""
    prog = lower(spec)
    if prog.loop:
        return functools.partial(reservoir_scan, spec)
    execs = [( _JNP_EXEC[(p.op, p.variant)], p) for p in prog.phases]

    def sample(g, addr, deg, slots, base_key):
        """Execute the lowered phases over one superstep's lane pool."""
        ctx = _Ctx(spec, g, addr, deg, slots, base_key)
        for fn, ph in execs:
            fn(ph, ctx)
        return ctx.index, ctx.ok

    return sample


# ==========================================================================
# Support matrix: the docs table is generated from the programs, not
# hand-maintained (docs/api.md embeds render_support_matrix()'s output;
# a test pins the embedding).
# ==========================================================================

_KIND_LABEL = {
    "uniform": "uniform (urw/ppr)",
    "alias": "alias (deepwalk)",
    "rejection_n2v": "rejection_n2v (node2vec)",
    "reservoir_n2v": "reservoir_n2v (weighted node2vec)",
    "metapath": "metapath",
}


def _default_spec(kind: str) -> SamplerSpec:
    return SamplerSpec(kind=kind,
                       metapath=(0,) if kind == "metapath" else ())


def support_rows():
    """One row per sampler kind: which step_impl lowers it natively,
    which sharded capability it declares, and the schedule / carry /
    residency facts the architecture table documents — all read off the
    phase programs."""
    rows = []
    for kind in KINDS:
        prog = lower(_default_spec(kind))
        residency = ("v_curr + v_prev"
                     if any(p.residency == "v_prev" for p in prog.phases)
                     else "v_curr")
        rows.append({
            "kind": kind,
            "label": _KIND_LABEL[kind],
            "jnp": True,
            "pallas": prog.pallas,
            "fused": prog.fused,
            "capability": prog.capability,
            "schedule": prog.schedule,
            "carry": prog.carry,
            "residency": residency,
            "requires": prog.requires,
            "phases": prog.phases,
            "cache_payloads": prog.cache_payloads,
        })
    return rows


def render_support_matrix() -> str:
    """Markdown sampler × step_impl × backend matrix (embedded verbatim
    in docs/api.md — regenerate with
    ``python -m repro.core.phase_program``)."""
    lines = [
        "| sampler | `jnp` | `pallas` (one-hop kernel) "
        "| `fused` (k-superstep kernel) | `sharded` capability |",
        "|---|---|---|---|---|",
    ]
    for r in support_rows():
        pallas = "✓" if r["pallas"] else "falls back to jnp"
        fused = "✓" if r["fused"] else "falls back to jnp"
        lines.append(f"| {r['label']} | ✓ | {pallas} | {fused} "
                     f"| `{r['capability']}` |")
    return "\n".join(lines)


def _phase_sig(ph: Phase) -> str:
    """Compact one-token rendering of a phase for the schedule table."""
    tag = ph.op if not ph.variant else f"{ph.op}:{ph.variant}"
    if ph.op in ("draw", "gather") and ph.width > 1:
        tag += f"×{ph.width}"
    if ph.residency == "v_prev":
        tag += "@v_prev"
    return tag


def render_schedule_table() -> str:
    """Markdown phase-program → schedule → backend table (embedded
    verbatim in docs/architecture.md — regenerate with
    ``python -m repro.core.phase_program --schedule``).

    Widths are those of the default spec (K = rejection_rounds = 12,
    CH = reservoir_chunk = 64); they scale with the spec fields but the
    schedule / carry / residency columns are spec-invariant.
    """
    lines = [
        "| sampler | phases | schedule | carry | residency "
        "| graph payloads | hot-cache payloads |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in support_rows():
        phases = " → ".join(_phase_sig(p) for p in r["phases"])
        loop = " (looped per chunk)" if r["schedule"] == "chunked_loop" \
            else ""
        req = ", ".join(f"`{x}`" for x in r["requires"]) or "—"
        hot = ", ".join(f"`{x}`" for x in r["cache_payloads"])
        lines.append(f"| {r['label']} | `{phases}`{loop} "
                     f"| `{r['schedule']}` | `{r['carry']}` "
                     f"| {r['residency']} | {req} | {hot} |")
    return "\n".join(lines)


def fused_kinds() -> Tuple[str, ...]:
    """Sampler kinds the fused device-resident kernel covers (derived
    from the phase programs, not a hand-kept list — all of them since
    the chunked reservoir scan moved in-kernel)."""
    return tuple(r["kind"] for r in support_rows() if r["fused"])


def _check_docs_embeddings() -> int:
    """Verify the committed docs embed the generated tables verbatim.

    Returns a process exit code: 0 when every generated line appears in
    its doc, 1 (with a diff-style report) on drift — the CI docs-drift
    job runs ``python -m repro.core.phase_program --check``.
    """
    import pathlib
    root = pathlib.Path(__file__).resolve().parents[3]
    targets = [
        (root / "docs" / "api.md", render_support_matrix(),
         "support matrix"),
        (root / "docs" / "architecture.md", render_support_matrix(),
         "support matrix"),
        (root / "docs" / "architecture.md", render_schedule_table(),
         "schedule table"),
    ]
    failures = []
    for path, table, name in targets:
        text = path.read_text() if path.exists() else ""
        missing = [ln for ln in table.splitlines() if ln not in text]
        if missing:
            failures.append((path, name, missing))
    for path, name, missing in failures:
        print(f"DRIFT: {path} is missing {len(missing)} generated "
              f"{name} line(s):")
        for ln in missing:
            print(f"  {ln}")
    if failures:
        print("regenerate with `python -m repro.core.phase_program` / "
              "`--schedule` and paste the output into the docs")
        return 1
    print("docs embeddings up to date")
    return 0


def _main(argv=None) -> int:
    """CLI: print the generated docs tables or check them for drift."""
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.core.phase_program",
        description="Generate (or drift-check) the docs tables derived "
                    "from the sampler phase programs.")
    ap.add_argument("--schedule", action="store_true",
                    help="print the phase-program → schedule → backend "
                         "table (docs/architecture.md) instead of the "
                         "support matrix (docs/api.md)")
    ap.add_argument("--check", action="store_true",
                    help="verify docs/*.md embed the generated tables "
                         "verbatim; exit 1 on drift")
    args = ap.parse_args(argv)
    if args.check:
        return _check_docs_embeddings()
    print(render_schedule_table() if args.schedule
          else render_support_matrix())
    return 0


if __name__ == "__main__":
    raise SystemExit(_main())
