"""Data-aware task routing (paper §V-C: Task Router, butterfly interconnect).

On TPU the butterfly *is* the ICI network and its native bulk operation is
``all_to_all``.  Each superstep, every live task must reach the device that
owns its current vertex's adjacency list.  We realize the paper's routing +
backpressure with fixed-shape, provably-lossless machinery:

  * the per-device slot pool is ``[receive region (N·K) | retention (R)]``;
  * tasks are packed into per-destination buckets of capacity ``K``
    (receive region of the destination) via one lexsort — the O(1)-per-task
    pairwise Dispatcher/Merger cascade of §VI-C collapses into a single
    vectorized rank computation on a SIMD machine;
  * bucket overflow (short-lived load skew, §IV-A) goes to the *retention*
    region and re-routes next superstep with **priority over fresh tasks**
    — exactly the paper's Task Merger policy of prioritizing in-flight
    queries (§VI-C module 2);
  * retention overflow is dropped only if R is exhausted, and counted
    (``drops`` must be 0 — asserted in tests; capacity is provisioned by
    `scheduler.routing_capacity`, the Theorem VI.1 margin).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.tasks import WalkerSlots


class RouteResult(NamedTuple):
    send: object            # (N*K,) bucketed tasks, qid=-1 where empty
    retention: object       # (R,) overflow tasks retained locally
    waits: jnp.ndarray      # scalar — tasks that must wait a superstep
    drops: jnp.ndarray      # scalar — tasks lost (must be 0)


def _empty_like(slots, n: int):
    """Generic empty task tuple: int fields -1 (qid=-1 ≙ free lane), bools
    False, floats 0 — works for WalkerSlots and extended task words
    (e.g. the two-phase Node2Vec tuple with its candidate matrix)."""
    def empty_field(f):
        shape = (n,) + f.shape[1:]
        if f.dtype == bool:
            return jnp.zeros(shape, bool)
        if jnp.issubdtype(f.dtype, jnp.integer):
            return jnp.full(shape, -1, f.dtype)
        return jnp.zeros(shape, f.dtype)
    return type(slots)(*(empty_field(f) for f in slots))


def _scatter_slots(dst, idx: jnp.ndarray, src, keep: jnp.ndarray):
    """Scatter src lanes into dst at idx where keep (OOB index = drop)."""
    oob = dst[0].shape[0]
    idx = jnp.where(keep, idx, oob)
    return type(dst)(*(d.at[idx].set(s, mode="drop")
                       for d, s in zip(dst, src)))


def _gather_slots(slots, order: jnp.ndarray):
    return type(slots)(*(f[order] for f in slots))


def pack_buckets(slots: WalkerSlots, dest: jnp.ndarray, priority: jnp.ndarray,
                 num_devices: int, bucket_cap: int,
                 retention_cap: int) -> RouteResult:
    """Pack live tasks into per-destination buckets + retention overflow.

    dest:     (S,) int32 destination device of each lane (ignored if idle).
    priority: (S,) int32 — lower routes first (retained tasks use 0).
    """
    N, K, R = num_devices, bucket_cap, retention_cap
    valid = slots.active
    dest_s = jnp.where(valid, dest, N)  # sentinel so idle lanes sort last
    order = jnp.lexsort((priority, dest_s))
    d_sorted = dest_s[order]
    v_sorted = valid[order]
    sorted_slots = _gather_slots(slots, order)

    # Rank within each destination group (first occurrence via searchsorted).
    S = dest.shape[0]
    first = jnp.searchsorted(d_sorted, d_sorted, side="left")
    pos = jnp.arange(S, dtype=jnp.int32) - first.astype(jnp.int32)

    in_bucket = v_sorted & (pos < K) & (d_sorted < N)
    bucket_slot = d_sorted.astype(jnp.int32) * K + pos
    send = _scatter_slots(_empty_like(slots, N * K), bucket_slot,
                          sorted_slots, in_bucket)

    overflow = v_sorted & ~in_bucket & (d_sorted < N)
    ret_rank = jnp.cumsum(overflow.astype(jnp.int32)) - 1
    ret_ok = overflow & (ret_rank < R)
    retention = _scatter_slots(_empty_like(slots, R), ret_rank,
                               sorted_slots, ret_ok)

    waits = jnp.sum(overflow.astype(jnp.int32))
    drops = jnp.sum((overflow & ~ret_ok).astype(jnp.int32))
    return RouteResult(send=send, retention=retention, waits=waits, drops=drops)


def exchange(send, axis_name: str):
    """The butterfly hop: all_to_all the (N·K,) send buffer so bucket d
    lands on device d. Fixed shapes; one collective per superstep."""
    def a2a(x):
        return jax.lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0,
                                  tiled=True)
    return type(send)(*(a2a(f) for f in send))
