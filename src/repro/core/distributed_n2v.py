"""Deprecated shim — distributed second-order walks now live in the
generic engine (`repro.core.distributed`) via sampler-capability dispatch:
`SamplerSpec.capability` selects the task word (`N2VSlots`,
`ReservoirSlots`) and the per-phase routing schedule, so first- and
second-order walks share one routing path.  Prefer
``repro.walker.compile(program, backend="sharded")``.
"""
from __future__ import annotations

import warnings
from typing import Optional

import jax

from repro.core.distributed import DistConfig, _run_distributed
from repro.core.samplers import SamplerSpec
from repro.core.tasks import N2VSlots  # noqa: F401 — legacy re-export
from repro.graph.partition import PartitionedGraph


def run_distributed_n2v(pg: PartitionedGraph, starts, spec: SamplerSpec,
                        cfg: Optional[DistConfig] = None,
                        mesh: Optional[jax.sharding.Mesh] = None,
                        seed: int = 0):
    """Deprecated: the generic distributed engine handles second-order
    samplers.  Returns (DistLogs, stats), as before."""
    warnings.warn(
        "run_distributed_n2v is deprecated; second-order walks route "
        "through the generic distributed engine — use repro.walker."
        "compile(program, backend='sharded').run(...) or "
        "repro.core.distributed.run_distributed",
        DeprecationWarning, stacklevel=2)
    assert spec.second_order, spec.kind
    return _run_distributed(pg, starts, spec, cfg, mesh, seed)
