"""Distributed SECOND-ORDER walks (Node2Vec) — two-phase routing.

Beyond-paper extension of §V-C: a second-order hop needs data from TWO
vertices — the proposal draw reads N(v_curr), the rejection bias reads
N(v_prev) (is the candidate adjacent to the previous vertex?).  The paper
carries "two vertices for higher-order walks" in the task word; we extend
that to a *two-phase* task that routes twice per hop:

  phase A  @ owner(v_curr): draw K uniform proposals from N(v_curr),
           store them in the task word (K·32 bits — still ≤ 512-bit word
           for K ≤ 12, matching the paper's single-word constraint),
           route to owner(v_prev);
  phase B  @ owner(v_prev): bisect each candidate in N(v_prev), compute
           the (p, q) bias, accept the first winner (same bounded-round
           semantics AND the same (seed, qid, hop)-derived uniforms as the
           single-device sampler ⇒ bit-identical walks, asserted in
           tests), advance, terminate/refill, route to owner(v_curr').

Both phases coexist in the same slot pool every superstep (a lane's phase
bit selects its work), so the pipeline stays full — the zero-bubble
property is phase-agnostic.
"""
from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rng as task_rng, router
from repro.core.distributed import DistConfig, DistLogs, LocalView
from repro.core.samplers import SALT_COLUMN, SamplerSpec
from repro.core.tasks import zero_stats
from repro.distributed.compat import shard_map
from repro.graph.partition import PartitionedGraph, owner_of


class N2VSlots(NamedTuple):
    """Two-phase Node2Vec task word (SoA)."""
    v_curr: jnp.ndarray    # (S,) int32
    v_prev: jnp.ndarray    # (S,) int32
    query_id: jnp.ndarray  # (S,) int32 (-1 = free)
    hop: jnp.ndarray       # (S,) int32
    active: jnp.ndarray    # (S,) bool
    phase: jnp.ndarray     # (S,) int32: 0 = propose (A), 1 = verify (B)
    cand: jnp.ndarray      # (S, K) int32 — proposals carried A -> B


def _local_deg_addr(view: LocalView, v, N, v_per_dev):
    lid = jnp.clip(jnp.where(v >= 0, v // N, 0), 0, v_per_dev - 1)
    addr = view.row_ptr[lid]
    return addr, view.row_ptr[lid + 1] - addr


def _local_edge_exists(view: LocalView, src, dst_mat, N, v_per_dev):
    """Bisect dst_mat (S, K) in src's LOCAL neighbor list (sorted)."""
    addr, deg = _local_deg_addr(view, src, N, v_per_dev)
    lo = jnp.broadcast_to(addr[:, None], dst_mat.shape).astype(jnp.int32)
    hi0 = jnp.broadcast_to((addr + deg)[:, None], dst_mat.shape).astype(jnp.int32)
    hi = hi0
    iters = max(1, int(math.ceil(math.log2(max(int(view.max_degree), 2) + 1))))
    ne = view.col.shape[-1]
    for _ in range(iters):
        active = lo < hi
        mid = (lo + hi) // 2
        v = view.col[jnp.clip(mid, 0, ne - 1)]
        go_right = v < dst_mat
        lo = jnp.where(active & go_right, mid + 1, lo)
        hi = jnp.where(active & ~go_right, mid, hi)
    found = (lo < hi0) & (view.col[jnp.clip(lo, 0, ne - 1)] == dst_mat)
    return found & (src >= 0)[:, None]


def _superstep_n2v(spec: SamplerSpec, cfg: DistConfig, N, v_per_dev,
                   base_key, view, starts_loc, qcount, rank, carry):
    (slots, head, log_q, log_h, log_v, cursor, stats, done, t) = carry
    K = spec.rejection_rounds
    W_loc = cfg.slots_per_device
    Kb = cfg.bucket_cap(N)
    R = cfg.retention_cap()
    S = cfg.pool_size(N)

    here = owner_of(jnp.where(slots.phase == 0, slots.v_curr,
                              jnp.maximum(slots.v_prev, 0)), N) == rank
    mine = slots.active & here

    # ---- phase A: propose K candidates from N(v_curr) -------------------
    do_a = mine & (slots.phase == 0)
    addr, deg = _local_deg_addr(view, slots.v_curr, N, v_per_dev)
    u = task_rng.task_uniforms(base_key, slots.query_id, slots.hop, 2 * K,
                               SALT_COLUMN)
    u_col, u_acc = u[:, :K], u[:, K:]
    idx = jnp.minimum((u_col * deg[:, None]).astype(jnp.int32),
                      jnp.maximum(deg - 1, 0)[:, None])
    e = jnp.clip(addr[:, None] + idx, 0, view.col.shape[-1] - 1)
    proposals = view.col[e]                                   # (S, K)
    dead = do_a & (deg == 0)
    # hop 0 has no v_prev: bias ≡ 1 -> verify locally in phase A (also
    # avoids the owner(-1) thundering-herd hotspot on device 0)
    w_max = max(1.0 / spec.p, 1.0, 1.0 / spec.q)
    hop0 = do_a & (slots.v_prev < 0) & (deg > 0)
    acc0 = (u_acc * w_max <= 1.0).at[:, K - 1].set(True)
    first0 = jnp.argmax(acc0, axis=1)
    v0 = jnp.take_along_axis(proposals, first0[:, None], 1)[:, 0]

    # ---- phase B: verify candidates against N(v_prev) -------------------
    do_b = mine & (slots.phase == 1)
    is_ret = slots.cand == slots.v_prev[:, None]
    common = _local_edge_exists(view, slots.v_prev, slots.cand, N, v_per_dev)
    w = jnp.where(is_ret, 1.0 / spec.p,
                  jnp.where(common, 1.0, 1.0 / spec.q))
    accept = (u_acc * w_max <= w).at[:, K - 1].set(True)
    first = jnp.argmax(accept, axis=1)
    v_next = jnp.take_along_axis(slots.cand, first[:, None], 1)[:, 0]

    adv = do_b | hop0
    v_next = jnp.where(hop0, v0, v_next)
    new_hop = jnp.where(adv, slots.hop + 1, slots.hop)
    reached_max = adv & (new_hop >= cfg.max_hops)
    terminated = dead | reached_max

    # ---- emission log ----------------------------------------------------
    log_drop = jnp.zeros((), jnp.int32)
    if cfg.record_paths:
        cap = cfg.log_capacity
        pos = cursor + jnp.cumsum(adv.astype(jnp.int32)) - 1
        keep = adv & (pos < cap)
        p_safe = jnp.where(keep, pos, cap)
        log_q = log_q.at[p_safe].set(jnp.where(adv, slots.query_id, -1),
                                     mode="drop")
        log_h = log_h.at[p_safe].set(new_hop, mode="drop")
        log_v = log_v.at[p_safe].set(v_next, mode="drop")
        log_drop = jnp.sum((adv & ~keep).astype(jnp.int32))
        cursor = jnp.minimum(cursor + jnp.sum(adv.astype(jnp.int32)), cap)

    slots = N2VSlots(
        v_curr=jnp.where(adv, v_next, slots.v_curr),
        v_prev=jnp.where(adv, slots.v_curr, slots.v_prev),
        query_id=jnp.where(terminated, -1, slots.query_id),
        hop=new_hop,
        active=slots.active & ~terminated,
        phase=jnp.where(do_a & ~hop0, 1, jnp.where(adv, 0, slots.phase)),
        cand=jnp.where((do_a & ~hop0)[:, None], proposals, slots.cand),
    )

    # ---- zero-bubble refill ----------------------------------------------
    n_active = jnp.sum(slots.active.astype(jnp.int32))
    free = ~slots.active
    budget = jnp.maximum(W_loc - n_active, 0)
    avail = jnp.minimum(jnp.maximum(qcount - head, 0), budget)
    rank_free = jnp.cumsum(free.astype(jnp.int32)) - 1
    take = free & (rank_free < avail)
    k_local = head + rank_free
    k_safe = jnp.clip(k_local, 0, starts_loc.shape[0] - 1)
    slots = N2VSlots(
        v_curr=jnp.where(take, starts_loc[k_safe], slots.v_curr),
        v_prev=jnp.where(take, -1, slots.v_prev),
        query_id=jnp.where(take, k_local * N + rank, slots.query_id),
        hop=jnp.where(take, 0, slots.hop),
        active=slots.active | take,
        phase=jnp.where(take, 0, slots.phase),
        cand=slots.cand,
    )
    head = head + jnp.sum(take.astype(jnp.int32))

    # ---- route: phase A tasks go to owner(v_prev); phase B -> owner(v_curr)
    dest = jnp.where(slots.phase == 1,
                     owner_of(jnp.maximum(slots.v_prev, 0), N),
                     owner_of(slots.v_curr, N))
    lane = jnp.arange(S, dtype=jnp.int32)
    priority = jnp.where(lane >= N * Kb, 0, 1)
    rr = router.pack_buckets(slots, dest, priority, N, Kb, R)
    incoming = router.exchange(rr.send, cfg.axis_name)
    slots = N2VSlots(*(jnp.concatenate([a, b])
                       for a, b in zip(incoming, rr.retention)))

    busy = jnp.sum(mine.astype(jnp.int32))
    upstream = (head < qcount).astype(jnp.int32)
    stats = stats._replace(
        steps=stats.steps + jnp.sum(adv.astype(jnp.int32)),
        slot_steps=stats.slot_steps + W_loc,
        bubbles=stats.bubbles + jnp.maximum(W_loc - busy, 0),
        starved=stats.starved + jnp.maximum(W_loc - busy, 0) * upstream,
        terminations=stats.terminations + jnp.sum(terminated.astype(jnp.int32)),
        supersteps=stats.supersteps + 1,
        route_waits=stats.route_waits + rr.waits,
        drops=stats.drops + rr.drops + log_drop,
    )
    n_live = jnp.sum(slots.active.astype(jnp.int32))
    remaining = jnp.maximum(qcount - head, 0)
    done = jax.lax.psum(n_live + remaining, cfg.axis_name) == 0
    return (slots, head, log_q, log_h, log_v, cursor, stats, done, t + 1)


def _empty_pool_n2v(S: int, K: int) -> N2VSlots:
    return N2VSlots(
        v_curr=jnp.full((S,), -1, jnp.int32),
        v_prev=jnp.full((S,), -1, jnp.int32),
        query_id=jnp.full((S,), -1, jnp.int32),
        hop=jnp.zeros((S,), jnp.int32),
        active=jnp.zeros((S,), bool),
        phase=jnp.zeros((S,), jnp.int32),
        cand=jnp.full((S, K), -1, jnp.int32),
    )


def run_distributed_n2v(pg: PartitionedGraph, starts, spec: SamplerSpec,
                        cfg: Optional[DistConfig] = None,
                        mesh: Optional[jax.sharding.Mesh] = None,
                        seed: int = 0):
    """Distributed rejection-sampling Node2Vec. Returns (DistLogs, stats)."""
    assert spec.kind == "rejection_n2v"
    cfg = cfg or DistConfig()
    N = pg.num_devices
    if mesh is None:
        devs = np.array(jax.devices()[:N])
        mesh = jax.sharding.Mesh(devs, (cfg.axis_name,))
    P = jax.sharding.PartitionSpec
    starts = np.asarray(starts, dtype=np.int32)
    Q = starts.shape[0]
    q_loc = (Q + N - 1) // N
    starts_sh = np.zeros((N, q_loc), dtype=np.int32)
    qcount = np.zeros((N, 1), dtype=np.int32)
    for r in range(N):
        part = starts[r::N]
        starts_sh[r, : part.size] = part
        qcount[r, 0] = part.size
    v_per_dev = pg.vertices_per_device

    def body(rowp, colp, starts_loc, qc, base_key):
        rank = jax.lax.axis_index(cfg.axis_name)
        view = LocalView(row_ptr=rowp[0], col=colp[0], weights=None,
                         alias_prob=None, alias_idx=None,
                         max_degree=pg.max_degree)
        S = cfg.pool_size(N)
        cap = cfg.log_capacity if cfg.record_paths else 1
        carry = (_empty_pool_n2v(S, spec.rejection_rounds),
                 jnp.zeros((), jnp.int32),
                 jnp.full((cap,), -1, jnp.int32),
                 jnp.full((cap,), -1, jnp.int32),
                 jnp.full((cap,), -1, jnp.int32),
                 jnp.zeros((), jnp.int32),
                 zero_stats(), jnp.asarray(False), jnp.zeros((), jnp.int32))

        def cond(c):
            return (~c[7]) & (c[8] < cfg.max_supersteps)

        step = partial(_superstep_n2v, spec, cfg, N, v_per_dev, base_key,
                       view, starts_loc[0], qc[0, 0], rank)
        carry = jax.lax.while_loop(cond, step, carry)
        _, head, log_q, log_h, log_v, cursor, stats, _, _ = carry
        return (log_q[None], log_h[None], log_v[None], cursor[None],
                jax.tree.map(lambda x: x[None], stats))

    smapped = shard_map(
        body, mesh=mesh,
        in_specs=(P(cfg.axis_name),) * 4 + (P(),),
        out_specs=(P(cfg.axis_name),) * 4 + (P(cfg.axis_name),),
        check_vma=False)
    log_q, log_h, log_v, cursor, stats = jax.jit(smapped)(
        pg.row_ptr, pg.col, jnp.asarray(starts_sh), jnp.asarray(qcount),
        jax.random.PRNGKey(seed))
    return DistLogs(qid=log_q, hop=log_h, vertex=log_v, cursor=cursor), stats
