"""GRW algorithm front-ends (paper Table I + §VIII-A4).

Thin wrappers that pick the right SamplerSpec for each published GRW and
run the engine.  Defaults follow the paper's evaluation setup: query
length 80; Node2Vec p=2, q=0.5; ThunderRW-style edge weights.
"""
from __future__ import annotations

from typing import Optional, Sequence

from repro.core.samplers import SamplerSpec
from repro.core.tasks import WalkResult
from repro.core.walk_engine import EngineConfig, run_walks
from repro.graph.csr import CSRGraph


def urw(graph: CSRGraph, starts, max_hops: int = 80,
        cfg: Optional[EngineConfig] = None, seed: int = 0) -> WalkResult:
    """Unbiased random walk [49]: uniform neighbor sampling."""
    spec = SamplerSpec(kind="uniform")
    cfg = (cfg or EngineConfig())
    cfg = _with(cfg, max_hops=max_hops)
    return run_walks(graph, starts, spec, cfg, seed)


def ppr(graph: CSRGraph, starts, alpha: float = 0.15, max_hops: int = 80,
        cfg: Optional[EngineConfig] = None, seed: int = 0) -> WalkResult:
    """Personalized PageRank walks [50]: uniform sampling, geometric
    termination with teleport probability α (walk endpoints estimate PPR
    mass)."""
    spec = SamplerSpec(kind="uniform", stop_prob=alpha)
    cfg = _with(cfg or EngineConfig(), max_hops=max_hops)
    return run_walks(graph, starts, spec, cfg, seed)


def deepwalk(graph: CSRGraph, starts, max_hops: int = 80,
             cfg: Optional[EngineConfig] = None, seed: int = 0) -> WalkResult:
    """DeepWalk [5]: alias sampling over (weighted) neighbor lists.
    Graph must carry alias tables (graph.alias.build_alias_tables)."""
    assert graph.has_alias, "DeepWalk requires alias tables on the graph"
    spec = SamplerSpec(kind="alias")
    cfg = _with(cfg or EngineConfig(), max_hops=max_hops)
    return run_walks(graph, starts, spec, cfg, seed)


def node2vec(graph: CSRGraph, starts, p: float = 2.0, q: float = 0.5,
             max_hops: int = 80, weighted: Optional[bool] = None,
             cfg: Optional[EngineConfig] = None, seed: int = 0) -> WalkResult:
    """Node2Vec [9]: rejection sampling (unweighted) or Efraimidis–Spirakis
    reservoir sampling (weighted) — paper Table I."""
    if weighted is None:
        weighted = graph.weighted
    kind = "reservoir_n2v" if weighted else "rejection_n2v"
    spec = SamplerSpec(kind=kind, p=p, q=q)
    cfg = _with(cfg or EngineConfig(), max_hops=max_hops)
    return run_walks(graph, starts, spec, cfg, seed)


def metapath(graph: CSRGraph, starts, schedule: Sequence[int],
             max_hops: int = 80, cfg: Optional[EngineConfig] = None,
             seed: int = 0) -> WalkResult:
    """MetaPath walks [16]: each hop samples uniformly among neighbors of
    the scheduled edge type; no match → early termination (the workload
    that most stresses the zero-bubble scheduler, §VIII-B)."""
    assert graph.typed, "MetaPath requires a typed graph"
    spec = SamplerSpec(kind="metapath", metapath=tuple(int(t) for t in schedule))
    cfg = _with(cfg or EngineConfig(), max_hops=max_hops)
    return run_walks(graph, starts, spec, cfg, seed)


def _with(cfg: EngineConfig, **kw) -> EngineConfig:
    import dataclasses
    return dataclasses.replace(cfg, **kw)


ALGORITHMS = {
    "urw": urw,
    "ppr": ppr,
    "deepwalk": deepwalk,
    "node2vec": node2vec,
    "metapath": metapath,
}
