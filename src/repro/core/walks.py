"""Deprecated GRW algorithm front-ends (paper Table I + §VIII-A4).

Thin shims over the unified walker API — each call builds the equivalent
:class:`repro.walker.WalkProgram` and runs it on the single-device
backend, emitting a ``DeprecationWarning``.  Prefer::

    from repro import walker
    w = walker.compile(walker.WalkProgram.deepwalk(max_hops=80))
    result = w.run(graph, starts, seed=0)

Defaults follow the paper's evaluation setup: query length 80; Node2Vec
p=2, q=0.5; ThunderRW-style edge weights.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Sequence

from repro.core.tasks import WalkResult
from repro.core.walk_engine import EngineConfig

_MIGRATE = {
    "urw": "WalkProgram.urw(max_hops)",
    "ppr": "WalkProgram.ppr(alpha, max_hops)",
    "deepwalk": "WalkProgram.deepwalk(max_hops)",
    "node2vec": "WalkProgram.node2vec(p, q, max_hops, weighted=...)",
    "metapath": "WalkProgram.metapath(schedule, max_hops)",
}


def _deprecated_run(name: str, program, graph, starts,
                    cfg: Optional[EngineConfig], seed: int) -> WalkResult:
    warnings.warn(
        f"walks.{name} is deprecated; use repro.walker.compile("
        f"{_MIGRATE[name]}).run(graph, starts, seed=seed)",
        DeprecationWarning, stacklevel=3)
    from repro import walker
    execution = (walker.ExecutionConfig.from_engine_config(cfg)
                 if cfg is not None else walker.ExecutionConfig())
    return walker.compile(program, execution=execution).run(
        graph, starts, seed=seed)


def urw(graph, starts, max_hops: int = 80,
        cfg: Optional[EngineConfig] = None, seed: int = 0) -> WalkResult:
    """Unbiased random walk [49]: uniform neighbor sampling."""
    from repro.walker import WalkProgram
    return _deprecated_run("urw", WalkProgram.urw(max_hops), graph, starts,
                           cfg, seed)


def ppr(graph, starts, alpha: float = 0.15, max_hops: int = 80,
        cfg: Optional[EngineConfig] = None, seed: int = 0) -> WalkResult:
    """Personalized PageRank walks [50]: uniform sampling, geometric
    termination with teleport probability α (walk endpoints estimate PPR
    mass)."""
    from repro.walker import WalkProgram
    return _deprecated_run("ppr", WalkProgram.ppr(alpha, max_hops), graph,
                           starts, cfg, seed)


def deepwalk(graph, starts, max_hops: int = 80,
             cfg: Optional[EngineConfig] = None, seed: int = 0) -> WalkResult:
    """DeepWalk [5]: alias sampling over (weighted) neighbor lists.
    Graph must carry alias tables (graph.alias.build_alias_tables)."""
    from repro.walker import WalkProgram
    assert graph.has_alias, "DeepWalk requires alias tables on the graph"
    return _deprecated_run("deepwalk", WalkProgram.deepwalk(max_hops), graph,
                           starts, cfg, seed)


def node2vec(graph, starts, p: float = 2.0, q: float = 0.5,
             max_hops: int = 80, weighted: Optional[bool] = None,
             cfg: Optional[EngineConfig] = None, seed: int = 0) -> WalkResult:
    """Node2Vec [9]: rejection sampling (unweighted) or Efraimidis–Spirakis
    reservoir sampling (weighted) — paper Table I."""
    from repro.walker import WalkProgram
    if weighted is None:
        weighted = graph.weighted
    program = WalkProgram.node2vec(p, q, max_hops, weighted=weighted)
    return _deprecated_run("node2vec", program, graph, starts, cfg, seed)


def metapath(graph, starts, schedule: Sequence[int],
             max_hops: int = 80, cfg: Optional[EngineConfig] = None,
             seed: int = 0) -> WalkResult:
    """MetaPath walks [16]: each hop samples uniformly among neighbors of
    the scheduled edge type; no match → early termination (the workload
    that most stresses the zero-bubble scheduler, §VIII-B)."""
    from repro.walker import WalkProgram
    assert graph.typed, "MetaPath requires a typed graph"
    return _deprecated_run("metapath", WalkProgram.metapath(schedule, max_hops),
                           graph, starts, cfg, seed)


def _with(cfg: EngineConfig, **kw) -> EngineConfig:
    return dataclasses.replace(cfg, **kw)


ALGORITHMS = {
    "urw": urw,
    "ppr": ppr,
    "deepwalk": deepwalk,
    "node2vec": node2vec,
    "metapath": metapath,
}
