"""Device-resident corpus ring: walks land in HBM, training reads HBM.

The walks→embeddings hand-off is the first *consumer* of the walk engine,
and the naive wiring collapses the pipeline to host-bandwidth speed:
every completed path is pulled through ``np.asarray`` and re-uploaded
before the SGNS step can touch it.  This module keeps the hand-off on
device (the LightRW precedent): completed paths are scattered into a
ring of ``capacity`` rows that lives in HBM for its whole life, and the
jitted batch sampler draws (center, context, negatives) windows straight
out of it.

Ring economy
------------
The ring mirrors the ``QueryQueue`` slot economy: a monotone ``tail``
counter is the only state besides the row buffers.  ``append`` scatters
``n`` completed paths at slots ``(tail + i) % capacity`` (oldest rows
are overwritten once the ring wraps) and advances ``tail``; the sampler
reads ``filled = min(tail, capacity)`` rows.  There is no head/consume
pointer — training *samples* the ring (with replacement) rather than
draining it, so one walk is reused by many windows, exactly like an
on-host DeepWalk corpus.

Determinism
-----------
Every batch is a pure function of ``(base_key, step, ring contents)``:
batch element ``i`` at grad step ``t`` folds the task tuple
``(seed, qid=i, hop=t)`` — the *same* fold space a walk task of stream
epoch 0 uses — so the corpus draws get their own registered salt
channels (``SALT_CORPUS`` for the row/center/offset window draw,
``SALT_NEGATIVE`` for the negative ids) and the `repro.analysis` rng
pass proves them disjoint from every sampler and engine channel.  Ring
contents are themselves pure functions of ``(seed, round)`` (round
``r``'s walks are a closed batch under ``rng.stream_key(seed, r)``), so
the whole batch stream is restartable from ``(seed, ring state)``.

Host-copy accounting
--------------------
The zero-copy claim is pinned by a counter, not prose: every code path
that pulls walk paths to the host (``harvest_ids``, the serial-mode
round-trip) calls :func:`record_host_copy`, and tests wrap the training
loop in :func:`no_host_copies` — which raises on the first recorded copy
and additionally arms ``jax.transfer_guard_device_to_host`` (inert on
CPU, enforcing on real accelerators).
"""
from __future__ import annotations

import contextlib
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import rng as task_rng
from repro.core.rng import SALT_CORPUS, SALT_NEGATIVE

# Draw streams the corpus consumer adds to every sampler's task draws —
# the `repro.analysis` rng pass appends these to each kind's stream set
# (consumer qid/hop tuples overlap walk tasks under the round-0 key, so
# salt disjointness is the only separator).  Widths: the window draw is
# always 3 uniforms (row, center, offset); negatives default to 5/batch
# element (`SkipGramConfig.num_negatives`).
CORPUS_DRAW_STREAMS = (("corpus.window_draw", SALT_CORPUS, 3),
                       ("corpus.negatives", SALT_NEGATIVE, 5))


class CorpusRing(NamedTuple):
    """Device-resident walk corpus: a ring of completed path rows.

    ``paths`` is ``(capacity, path_width)`` int32 with ``-1`` padding
    (the engine's recording layout, ``path_width = max_hops + 1``);
    ``lengths`` is the recorded hop count per row; ``tail`` is the
    monotone append counter (a device scalar so the ring checkpoints as
    a plain pytree and `append` stays jittable).
    """

    paths: jnp.ndarray    # (R, P) int32, -1 pad
    lengths: jnp.ndarray  # (R,) int32
    tail: jnp.ndarray     # () int32 — monotone rows-ever-appended

    @property
    def capacity(self) -> int:
        """R — ring rows (old walks are overwritten past this)."""
        return int(self.paths.shape[0])

    @property
    def path_width(self) -> int:
        """P — path buffer width (``max_hops + 1``)."""
        return int(self.paths.shape[1])


def init_ring(capacity: int, path_width: int) -> CorpusRing:
    """An empty ring able to hold ``capacity`` walks of ``path_width``."""
    if capacity <= 0:
        raise ValueError(f"corpus ring capacity must be positive, got "
                         f"{capacity}")
    if path_width <= 0:
        raise ValueError(f"path_width must be positive, got {path_width}")
    return CorpusRing(
        paths=jnp.full((capacity, path_width), -1, jnp.int32),
        lengths=jnp.zeros((capacity,), jnp.int32),
        tail=jnp.zeros((), jnp.int32),
    )


@jax.jit
def append(ring: CorpusRing, paths: jnp.ndarray,
           lengths: jnp.ndarray) -> CorpusRing:
    """Scatter ``n`` completed walks into the ring (device→device).

    Rows land at slots ``(tail + i) % capacity`` — the monotone-counter
    ring economy of ``QueryQueue``, so appending never needs a host
    round-trip and wrapping transparently retires the oldest walks.
    ``paths`` may be narrower than the ring rows (shorter hop budget);
    it is right-padded with ``-1``.
    """
    n, p = paths.shape
    R, P = ring.paths.shape
    if n > R:
        raise ValueError(
            f"appending {n} walks to a {R}-row ring would overwrite rows "
            "within one append; raise ring_capacity")
    if p > P:
        raise ValueError(
            f"walk paths are {p} wide but the ring holds {P}-wide rows")
    if p < P:
        paths = jnp.concatenate(
            [paths, jnp.full((n, P - p), -1, jnp.int32)], axis=1)
    slots = (ring.tail + jnp.arange(n, dtype=jnp.int32)) % R
    return CorpusRing(
        paths=ring.paths.at[slots].set(jnp.asarray(paths, jnp.int32)),
        lengths=ring.lengths.at[slots].set(jnp.asarray(lengths, jnp.int32)),
        tail=ring.tail + n,
    )


def filled(ring: CorpusRing) -> jnp.ndarray:
    """Rows currently holding a walk (``min(tail, capacity)``)."""
    return jnp.minimum(ring.tail, ring.paths.shape[0])


def make_batch_sampler(num_vertices: int, batch_size: int, window: int,
                       num_negatives: int):
    """Build the jitted corpus consumer: ring → (center, context, negs).

    The returned ``sample(ring, base_key, step)`` draws one SGNS batch
    deterministically: element ``i`` folds ``(qid=i, hop=step)`` and
    draws 3 uniforms on ``SALT_CORPUS`` (ring row, center position,
    window offset) plus ``num_negatives`` on ``SALT_NEGATIVE``.  Returns
    ``(centers, contexts, negatives, mask)`` — ``mask`` is False where
    the window fell off the walk (or the ring is empty), so the loss
    skips the pair without breaking batch-shape staticness.
    """
    if window <= 0:
        raise ValueError(f"window must be positive, got {window}")
    if num_negatives <= 0:
        raise ValueError(f"num_negatives must be positive, got "
                         f"{num_negatives}")

    @jax.jit
    def sample(ring: CorpusRing, base_key, step):
        B = batch_size
        qid = jnp.arange(B, dtype=jnp.int32)
        hop = jnp.asarray(step, jnp.int32)
        u = task_rng.task_uniforms(base_key, qid, hop, 3, SALT_CORPUS)
        avail = filled(ring)
        # Ring row + center position (clamped draws: floor(u·n) < n).
        row = jnp.minimum((u[:, 0] * avail).astype(jnp.int32),
                          jnp.maximum(avail - 1, 0))
        ln = jnp.maximum(ring.lengths[row], 1)
        center = jnp.minimum((u[:, 1] * ln).astype(jnp.int32), ln - 1)
        # Window offset in {-window..-1, 1..window} (never 0).
        j = jnp.minimum((u[:, 2] * (2 * window)).astype(jnp.int32),
                        2 * window - 1)
        off = j - window
        off = jnp.where(off >= 0, off + 1, off)
        ctx_pos = center + off
        valid = (ctx_pos >= 0) & (ctx_pos < ln) & (avail > 0)
        ctx_pos = jnp.clip(ctx_pos, 0, ln - 1)
        centers = ring.paths[row, center]
        contexts = ring.paths[row, ctx_pos]
        mask = valid & (centers >= 0) & (contexts >= 0)
        un = task_rng.task_uniforms(base_key, qid, hop, num_negatives,
                                    SALT_NEGATIVE)
        negatives = jnp.minimum((un * num_vertices).astype(jnp.int32),
                                num_vertices - 1)
        return (jnp.maximum(centers, 0), jnp.maximum(contexts, 0),
                negatives, mask)

    return sample


# ---------------------------------------------------- host-copy accounting

_copies = 0
_guard_depth = 0


def record_host_copy(site: str = "") -> None:
    """Note one host round-trip of walk paths (harvest / serial mode).

    Raises when inside :func:`no_host_copies` — that is how the
    zero-per-step-host-transfer property is pinned by a test instead of
    trusted to prose.
    """
    global _copies
    _copies += 1
    if _guard_depth > 0:
        raise RuntimeError(
            f"walk paths copied to the host under a no_host_copies guard "
            f"(site: {site or 'unknown'}) — the device-resident pipeline "
            "must hand paths to the corpus ring without a host round-trip")


def host_copies() -> int:
    """Total path host round-trips recorded since import."""
    return _copies


@contextlib.contextmanager
def no_host_copies():
    """Assert no walk-path host round-trip happens in this scope.

    Arms both the module counter (raises at the offending call site) and
    ``jax.transfer_guard_device_to_host("disallow")`` — the JAX guard is
    inert on CPU (host and device memory coincide) but enforces the same
    property at the runtime level on real accelerators.
    """
    global _guard_depth
    _guard_depth += 1
    try:
        with jax.transfer_guard_device_to_host("disallow"):
            yield
    finally:
        _guard_depth -= 1
