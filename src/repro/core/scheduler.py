"""Zero-bubble scheduling theory + feedback controller (paper §VI).

Theorem VI.1 (Lu et al., bulk-service M/M/1[N] with delayed feedback):
with N servers of service rate μ tasks/cycle and availability feedback
delayed by at most C cycles, a dispatch queue of depth

    D = N + ceil(μ · C · N)

suffices to keep every server busy whenever the system is backlogged.

On TPU the "servers" are the W lanes of a slot pool (service rate μ = 1
hop/superstep) and C is the host→device query-injection latency in
supersteps; `min_queue_depth` sizes the stage-ahead watermark used by the
engine's feedback controller.  For the *distributed* engine, the same bound
sizes the per-destination routing capacity: the butterfly's 2·log N
dispatcher/merger latency becomes the all_to_all round trip (1 superstep),
and the per-pipeline FIFO depth 1 + 4·log N becomes the capacity margin of
the receive buckets (`router.py`).

`analyze_run` turns WalkStats into the paper's utilization metrics
(bubble ratio, §III-B; effective bandwidth utilization, Eq. (1)).
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.tasks import WalkStats


def min_queue_depth(num_servers: int, mu: float = 1.0, delay: int = 0) -> int:
    """Theorem VI.1: D = N + O(μ·C·N). We use the explicit constant 1."""
    return int(num_servers + math.ceil(mu * delay * num_servers))


def butterfly_feedback_delay(num_pipelines: int) -> int:
    """Paper §VI-D: tasks traverse log N Dispatchers + log N Mergers, each
    ≤ 2 cycles, plus the scheduler↔pipeline round trip: C ≤ 4·log2 N."""
    n = max(2, num_pipelines)
    return int(4 * math.ceil(math.log2(n)))


def per_pipeline_fifo_depth(num_pipelines: int) -> int:
    """Paper §VI-D: D = N + 4·N·log N total → 1 + 4·log N per pipeline."""
    n = max(2, num_pipelines)
    return int(1 + 4 * math.ceil(math.log2(n)))


def routing_capacity(local_slots: int, num_devices: int,
                     margin: float = 2.0) -> int:
    """Per-destination all_to_all bucket capacity for the distributed
    engine: expected load is ``local_slots / num_devices`` (uniform mixing,
    paper §IV-A); ``margin`` absorbs the short-lived fluctuations the
    paper's FIFOs absorb. Capacity overflow is retained, never dropped."""
    expected = max(1, local_slots // max(num_devices, 1))
    return int(math.ceil(margin * expected))


@dataclasses.dataclass
class RunAnalysis:
    steps: int
    supersteps: int
    slot_steps: int
    bubbles: int
    starved: int
    bubble_ratio: float
    starved_ratio: float
    occupancy: float
    terminations: int
    route_waits: int
    drops: int
    msteps_per_s: float = float("nan")
    launches: int = 0
    supersteps_per_launch: float = float("nan")

    @property
    def zero_bubble(self) -> bool:
        """True iff no lane ever starved while work existed (Thm VI.1)."""
        return self.starved == 0


def analyze_run(stats: WalkStats, wall_time_s: float | None = None) -> RunAnalysis:
    import numpy as np
    s = {k: int(np.asarray(v)) for k, v in stats._asdict().items()}
    ratio = s["bubbles"] / max(s["slot_steps"], 1)
    sratio = s["starved"] / max(s["slot_steps"], 1)
    msteps = float("nan")
    if wall_time_s and wall_time_s > 0:
        msteps = s["steps"] / wall_time_s / 1e6
    return RunAnalysis(
        steps=s["steps"], supersteps=s["supersteps"],
        slot_steps=s["slot_steps"], bubbles=s["bubbles"], starved=s["starved"],
        bubble_ratio=ratio, starved_ratio=sratio, occupancy=1.0 - ratio,
        terminations=s["terminations"], route_waits=s["route_waits"],
        drops=s["drops"], msteps_per_s=msteps,
        launches=s.get("launches", 0),
        supersteps_per_launch=s["supersteps"] / max(s.get("launches", 0), 1),
    )


@dataclasses.dataclass
class ServiceAnalysis:
    """Open-system (streaming service) metrics: the queuing-theoretic view
    of Theorem VI.1 — requests arrive continuously at offered load λ and
    each observes a *sojourn time* (submit → last walk completed).

    ``offered_load`` is λ in walks/superstep; ``utilization`` is the
    fraction of lane service capacity demanded, ρ = λ·E[L] / W (ρ ≥ 1 means
    the system is overloaded and sojourn grows with the backlog).

    ``*_admission_wait`` isolates the *host-side* queueing component of the
    sojourn: supersteps from submit to injection into the device slot ring.
    Under the ring-buffer economy a request waits only while fewer free
    slots exist than it needs, so admission wait is the backlog signal and
    ``sojourn - admission_wait`` is pure device time."""

    offered_load: float
    utilization: float
    requests: int
    walks: int
    supersteps: int
    throughput: float        # hops per superstep (lane-work actually done)
    p50_sojourn: float       # supersteps, per-request
    p99_sojourn: float
    mean_sojourn: float
    bubble_ratio: float
    starved_ratio: float
    msteps_per_s: float = float("nan")
    p50_admission_wait: float = float("nan")  # supersteps, submit -> inject
    p99_admission_wait: float = float("nan")
    mean_admission_wait: float = float("nan")
    # Online chunk-adaptation trace (serve.scheduler.AdaptationEvent
    # tuples) when the service runs with an adaptive supersteps-per-
    # launch controller; empty for fixed-chunk services.
    adaptation: tuple = ()


def sojourn_percentiles(sojourns, qs=(50.0, 99.0)):
    """Percentiles of per-request sojourn times (supersteps)."""
    import numpy as np
    s = np.asarray(list(sojourns), float)
    if s.size == 0:
        return tuple(float("nan") for _ in qs)
    return tuple(float(np.percentile(s, q)) for q in qs)


def analyze_service(sojourns, stats: WalkStats, num_slots: int,
                    offered_load: float = float("nan"),
                    mean_walk_len: float = float("nan"),
                    wall_time_s: float | None = None,
                    admission_waits=None,
                    adaptation=()) -> ServiceAnalysis:
    """Fold per-request sojourns (+ optional admission waits) and engine
    WalkStats into service metrics.  ``adaptation`` is the service's
    online chunk-adaptation trace, passed through verbatim."""
    import numpy as np
    base = analyze_run(stats, wall_time_s)
    s = np.asarray(list(sojourns), float)
    p50, p99 = sojourn_percentiles(s)
    mean = float(s.mean()) if s.size else float("nan")
    util = offered_load * mean_walk_len / max(num_slots, 1)
    aw50 = aw99 = aw_mean = float("nan")
    if admission_waits is not None:
        aw = np.asarray(list(admission_waits), float)
        aw50, aw99 = sojourn_percentiles(aw)
        aw_mean = float(aw.mean()) if aw.size else float("nan")
    return ServiceAnalysis(
        offered_load=offered_load,
        utilization=util,
        requests=int(s.size),
        walks=base.terminations,
        supersteps=base.supersteps,
        throughput=base.steps / max(base.supersteps, 1),
        p50_sojourn=p50,
        p99_sojourn=p99,
        mean_sojourn=mean,
        bubble_ratio=base.bubble_ratio,
        starved_ratio=base.starved_ratio,
        msteps_per_s=base.msteps_per_s,
        p50_admission_wait=aw50,
        p99_admission_wait=aw99,
        mean_admission_wait=aw_mean,
        adaptation=tuple(adaptation),
    )


def peak_random_access_bandwidth(f_mem_hz: float, t_rrd_cycles: float,
                                 num_channels: int, bits: int = 64) -> float:
    """Paper Eq. (1): B_peak = f_mem / t_RRD × N_chn × bits/8  [bytes/s],
    with t_RRD the row-to-row delay in memory-clock cycles (each GRW step
    is assumed to be a DRAM row-buffer miss).

    Kept for parity with the paper's FPGA analysis; the TPU roofline in
    benchmarks/ uses HBM bandwidth with a measured random-access derate
    instead (no public t_RRD for TPU HBM stacks)."""
    return f_mem_hz / t_rrd_cycles * num_channels * (bits / 8)
