"""Stateless task / slot-pool structures (paper §V-A).

The paper decomposes each GRW query into minimal stateless tasks
``Q_s^y = <v_last, ID_y, x, ...>`` that fit in a single pipeline word.  The
TPU-native layout is a structure-of-arrays *slot pool*: ``W`` lanes, each
holding one task word.  A lane is either live (carrying a task) or free;
the zero-bubble scheduler's job is to keep every lane live whenever work
exists (paper §VI).

``v_prev`` carries the one extra vertex of history needed by second-order
walks (Node2Vec) — exactly the paper's "or two vertices for higher-order
walks" extension of the task tuple.

``epoch`` extends the task identity for the open system's ring-buffer slot
economy: query ids are *reused* once a query completes and is harvested,
and the occupant's epoch salts its RNG derivation
(``rng.task_fold(..., epoch=...)``) so successive occupants of one slot
sample independent walks.  Closed-batch runs carry epoch 0 everywhere,
which derives bit-identically to the classic ``(seed, query_id, hop)``
tuple.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp
import numpy as np


class WalkerSlots(NamedTuple):
    """Slot pool of stateless walk tasks (SoA; all arrays shape (W,))."""

    v_curr: jnp.ndarray   # int32 — the task's v_last (current vertex)
    v_prev: jnp.ndarray   # int32 — previous vertex (2nd-order walks); -1 if none
    query_id: jnp.ndarray  # int32 — unique query id (result tracking); -1 = free
    hop: jnp.ndarray      # int32 — hop count x
    active: jnp.ndarray   # bool  — lane holds a live task
    epoch: Optional[jnp.ndarray] = None  # int32 — slot-reuse epoch (RNG salt)

    @property
    def width(self) -> int:
        return self.v_curr.shape[-1]


def empty_slots(width: int) -> WalkerSlots:
    return WalkerSlots(
        v_curr=jnp.full((width,), -1, jnp.int32),
        v_prev=jnp.full((width,), -1, jnp.int32),
        query_id=jnp.full((width,), -1, jnp.int32),
        hop=jnp.zeros((width,), jnp.int32),
        active=jnp.zeros((width,), bool),
        epoch=jnp.zeros((width,), jnp.int32),
    )


class N2VSlots(NamedTuple):
    """Two-phase second-order task word (SoA) for distributed Node2Vec
    rejection sampling: phase A draws K proposals at owner(v_curr), phase B
    verifies them against N(v_prev) — the paper's "two vertices for
    higher-order walks" extension of the task tuple, plus the K·32-bit
    candidate payload carried between phases."""

    v_curr: jnp.ndarray    # (S,) int32
    v_prev: jnp.ndarray    # (S,) int32
    query_id: jnp.ndarray  # (S,) int32 (-1 = free)
    hop: jnp.ndarray       # (S,) int32
    active: jnp.ndarray    # (S,) bool
    phase: jnp.ndarray     # (S,) int32: 0 = propose (A), 1 = verify (B)
    cand: jnp.ndarray      # (S, K) int32 — proposals carried A -> B
    epoch: Optional[jnp.ndarray] = None  # (S,) int32 — slot-reuse epoch


def empty_n2v_slots(width: int, k: int) -> N2VSlots:
    return N2VSlots(
        v_curr=jnp.full((width,), -1, jnp.int32),
        v_prev=jnp.full((width,), -1, jnp.int32),
        query_id=jnp.full((width,), -1, jnp.int32),
        hop=jnp.zeros((width,), jnp.int32),
        active=jnp.zeros((width,), bool),
        phase=jnp.zeros((width,), jnp.int32),
        cand=jnp.full((width, k), -1, jnp.int32),
        epoch=jnp.zeros((width,), jnp.int32),
    )


class ReservoirSlots(NamedTuple):
    """Chunked-scan second-order task word for distributed *weighted*
    Node2Vec (Efraimidis–Spirakis reservoir).  The scan over N(v_curr)
    ping-pongs between owner(v_curr) (gather a chunk of candidates and
    their edge weights) and owner(v_prev) (score the chunk against the
    local adjacency bias), carrying the running reservoir maximum."""

    v_curr: jnp.ndarray    # (S,) int32
    v_prev: jnp.ndarray    # (S,) int32
    query_id: jnp.ndarray  # (S,) int32 (-1 = free)
    hop: jnp.ndarray       # (S,) int32
    active: jnp.ndarray    # (S,) bool
    phase: jnp.ndarray     # (S,) int32: 2c = gather chunk c @owner(v_curr),
                           #             2c+1 = score chunk c @owner(v_prev),
                           #             2·n_chunks = finalize @owner(v_curr)
    cand: jnp.ndarray      # (S, CH) int32 — chunk candidates (-1 = padding)
    cand_w: jnp.ndarray    # (S, CH) float32 — candidate edge weights
    best_key: jnp.ndarray  # (S,) float32 — running E-S reservoir key
    best_idx: jnp.ndarray  # (S,) int32 — running argmax neighbor offset
    last_chunk: Optional[jnp.ndarray] = None  # (S,) bool — scored chunk was
                           # the final one deg(v_curr) needs (early finalize)
    epoch: Optional[jnp.ndarray] = None       # (S,) int32 — slot-reuse epoch


def empty_reservoir_slots(width: int, chunk: int) -> ReservoirSlots:
    return ReservoirSlots(
        v_curr=jnp.full((width,), -1, jnp.int32),
        v_prev=jnp.full((width,), -1, jnp.int32),
        query_id=jnp.full((width,), -1, jnp.int32),
        hop=jnp.zeros((width,), jnp.int32),
        active=jnp.zeros((width,), bool),
        phase=jnp.zeros((width,), jnp.int32),
        cand=jnp.full((width, chunk), -1, jnp.int32),
        cand_w=jnp.zeros((width, chunk), jnp.float32),
        best_key=jnp.full((width,), -jnp.inf, jnp.float32),
        best_idx=jnp.zeros((width,), jnp.int32),
        last_chunk=jnp.zeros((width,), bool),
        epoch=jnp.zeros((width,), jnp.int32),
    )


class QueryQueue(NamedTuple):
    """Device-resident pending-query ring (the Theorem VI.1 queue).

    ``head`` is the next arrival to issue; ``staged`` is the injection
    watermark — arrivals with sequence >= staged have not yet "arrived" from
    the host (models the C-cycle observation/injection delay of §VI-A).  The
    feedback controller advances ``staged``; refill may only consume
    ``head < staged``.

    ``head``/``staged``/``tail`` are *monotone arrival counters* (they never
    wrap); the buffers they index are rings of ``capacity`` slots addressed
    mod capacity.  ``order[i % capacity]`` is the query id assigned to the
    i-th arrival — in the closed system it is the identity permutation (query
    i occupies slot i), while the open system's ring-buffer slot economy
    re-issues reclaimed slots to later arrivals, so arrival order and slot
    id decouple.  ``start_vertex[qid]`` / ``epoch[qid]`` are indexed by slot
    id and describe the slot's *current occupant*; ``epoch`` salts the
    occupant's RNG derivation so successive occupants sample independently.

    Invariants: ``head <= staged <= tail`` and ``tail - head <= capacity``
    (an arrival only exists while its slot is live, and at most ``capacity``
    slots are live).
    """

    start_vertex: jnp.ndarray  # (Q,) int32 — start vertex by slot id
    head: jnp.ndarray          # scalar int32 — monotone issue counter
    staged: jnp.ndarray        # scalar int32 — monotone staging watermark
    tail: jnp.ndarray          # scalar int32 — monotone arrival counter
    order: jnp.ndarray         # (Q,) int32 — slot id by arrival seq (mod Q)
    epoch: jnp.ndarray         # (Q,) int32 — occupant epoch by slot id

    @property
    def capacity(self) -> int:
        return self.start_vertex.shape[-1]


def make_queue(start_vertices, staged: int | None = None,
               tail: int | None = None) -> QueryQueue:
    sv = jnp.asarray(start_vertices, jnp.int32)
    q = sv.shape[-1]
    tail = q if tail is None else tail
    staged = tail if staged is None else staged
    if tail > q:
        raise ValueError(
            f"tail={tail} exceeds the queue buffer capacity {q}; only "
            f"queries that fit in the buffer can have arrived")
    if staged > tail:
        raise ValueError(
            f"staged={staged} exceeds tail={tail}: the injection watermark "
            f"cannot run ahead of the queries that actually arrived "
            f"(invariant head <= staged <= tail <= capacity)")
    return QueryQueue(
        start_vertex=sv,
        head=jnp.zeros((), jnp.int32),
        staged=jnp.asarray(staged, jnp.int32),
        tail=jnp.asarray(tail, jnp.int32),
        order=jnp.arange(q, dtype=jnp.int32),
        epoch=jnp.zeros((q,), jnp.int32),
    )


def empty_queue(capacity: int) -> QueryQueue:
    """Open-system ring: room for ``capacity`` live queries, none arrived
    yet; slot ids are handed out by the host's free ring at injection."""
    return QueryQueue(
        start_vertex=jnp.zeros((capacity,), jnp.int32),
        head=jnp.zeros((), jnp.int32),
        staged=jnp.zeros((), jnp.int32),
        tail=jnp.zeros((), jnp.int32),
        order=jnp.arange(capacity, dtype=jnp.int32),
        epoch=jnp.zeros((capacity,), jnp.int32),
    )


class WalkStats(NamedTuple):
    """Cycle-accurate-style utilization counters (paper Fig. 3 / Fig. 11)."""

    steps: jnp.ndarray        # total hops executed (visited vertices)
    slot_steps: jnp.ndarray   # total lane-supersteps elapsed
    bubbles: jnp.ndarray      # lane-supersteps with no live task (idle lanes)
    starved: jnp.ndarray      # idle lane-supersteps WHILE upstream work existed
                              # (the quantity Theorem VI.1 drives to zero;
                              # bubbles - starved = unavoidable tail drain)
    terminations: jnp.ndarray  # completed queries
    supersteps: jnp.ndarray   # wall supersteps executed
    route_waits: jnp.ndarray  # tasks that waited a superstep for routing capacity
    drops: jnp.ndarray        # tasks lost to capacity overflow (must be 0)
    launches: jnp.ndarray     # kernel/superstep dispatches: the per-hop jnp
                              # and pallas impls pay one launch per superstep
                              # (launches == supersteps); the fused
                              # device-resident kernel amortizes many
                              # supersteps per launch, so
                              # supersteps / launches is the fusion factor
    cache_hits: jnp.ndarray   # lane-gathers served from the VMEM hot-vertex
                              # cache (fused kernel with cache_budget > 0;
                              # 0 everywhere else)
    cache_misses: jnp.ndarray  # lane-gathers that fell through to the HBM
                              # DMA loops despite the cache being on
    cache_coalesced: jnp.ndarray  # lane-gathers that shared another lane's
                              # issue because their v_curr coincided within
                              # the superstep (same-vertex coalescing)

    def bubble_ratio(self):
        return self.bubbles / jnp.maximum(self.slot_steps, 1)

    def occupancy(self):
        return 1.0 - self.bubble_ratio()

    def supersteps_per_launch(self):
        return self.supersteps / jnp.maximum(self.launches, 1)

    def cache_hit_rate(self):
        """Fraction of cache probes (leader gathers) served from VMEM."""
        return self.cache_hits / jnp.maximum(
            self.cache_hits + self.cache_misses, 1)


def zero_stats() -> WalkStats:
    return WalkStats(*(jnp.zeros((), jnp.int32)
                       for _ in range(len(WalkStats._fields))))


class WalkResult(NamedTuple):
    """Collected walk paths: paths[q, t] = t-th vertex of query q, -1 padded."""

    paths: jnp.ndarray    # (Q, max_len) int32
    lengths: jnp.ndarray  # (Q,) int32 — number of vertices recorded
    stats: WalkStats

    def as_numpy(self):
        return np.asarray(self.paths), np.asarray(self.lengths)
