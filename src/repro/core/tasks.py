"""Stateless task / slot-pool structures (paper §V-A).

The paper decomposes each GRW query into minimal stateless tasks
``Q_s^y = <v_last, ID_y, x, ...>`` that fit in a single pipeline word.  The
TPU-native layout is a structure-of-arrays *slot pool*: ``W`` lanes, each
holding one task word.  A lane is either live (carrying a task) or free;
the zero-bubble scheduler's job is to keep every lane live whenever work
exists (paper §VI).

``v_prev`` carries the one extra vertex of history needed by second-order
walks (Node2Vec) — exactly the paper's "or two vertices for higher-order
walks" extension of the task tuple.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np


class WalkerSlots(NamedTuple):
    """Slot pool of stateless walk tasks (SoA; all arrays shape (W,))."""

    v_curr: jnp.ndarray   # int32 — the task's v_last (current vertex)
    v_prev: jnp.ndarray   # int32 — previous vertex (2nd-order walks); -1 if none
    query_id: jnp.ndarray  # int32 — unique query id (result tracking); -1 = free
    hop: jnp.ndarray      # int32 — hop count x
    active: jnp.ndarray   # bool  — lane holds a live task

    @property
    def width(self) -> int:
        return self.v_curr.shape[-1]


def empty_slots(width: int) -> WalkerSlots:
    return WalkerSlots(
        v_curr=jnp.full((width,), -1, jnp.int32),
        v_prev=jnp.full((width,), -1, jnp.int32),
        query_id=jnp.full((width,), -1, jnp.int32),
        hop=jnp.zeros((width,), jnp.int32),
        active=jnp.zeros((width,), bool),
    )


class QueryQueue(NamedTuple):
    """Device-resident pending-query buffer (the Theorem VI.1 queue).

    ``head`` is the next query to issue; ``staged`` is the injection
    watermark — queries with index >= staged have not yet "arrived" from the
    host (models the C-cycle observation/injection delay of §VI-A).  The
    feedback controller advances ``staged``; refill may only consume
    ``head < staged``.

    ``tail`` decouples the *buffer size* (``capacity``, a static shape) from
    the *queries that actually exist* (a traced scalar): in the closed system
    the two coincide, while the open-system streaming engine appends arrivals
    at ``tail`` between superstep chunks.  Invariant:
    ``head <= staged <= tail <= capacity``.
    """

    start_vertex: jnp.ndarray  # (Q,) int32
    head: jnp.ndarray          # scalar int32
    staged: jnp.ndarray        # scalar int32
    tail: jnp.ndarray          # scalar int32 — arrivals so far

    @property
    def capacity(self) -> int:
        return self.start_vertex.shape[-1]


def make_queue(start_vertices, staged: int | None = None,
               tail: int | None = None) -> QueryQueue:
    sv = jnp.asarray(start_vertices, jnp.int32)
    q = sv.shape[-1]
    return QueryQueue(
        start_vertex=sv,
        head=jnp.zeros((), jnp.int32),
        staged=jnp.asarray(q if staged is None else min(staged, q), jnp.int32),
        tail=jnp.asarray(q if tail is None else min(tail, q), jnp.int32),
    )


def empty_queue(capacity: int) -> QueryQueue:
    """Open-system buffer: room for ``capacity`` queries, none arrived yet."""
    return QueryQueue(
        start_vertex=jnp.zeros((capacity,), jnp.int32),
        head=jnp.zeros((), jnp.int32),
        staged=jnp.zeros((), jnp.int32),
        tail=jnp.zeros((), jnp.int32),
    )


class WalkStats(NamedTuple):
    """Cycle-accurate-style utilization counters (paper Fig. 3 / Fig. 11)."""

    steps: jnp.ndarray        # total hops executed (visited vertices)
    slot_steps: jnp.ndarray   # total lane-supersteps elapsed
    bubbles: jnp.ndarray      # lane-supersteps with no live task (idle lanes)
    starved: jnp.ndarray      # idle lane-supersteps WHILE upstream work existed
                              # (the quantity Theorem VI.1 drives to zero;
                              # bubbles - starved = unavoidable tail drain)
    terminations: jnp.ndarray  # completed queries
    supersteps: jnp.ndarray   # wall supersteps executed
    route_waits: jnp.ndarray  # tasks that waited a superstep for routing capacity
    drops: jnp.ndarray        # tasks lost to capacity overflow (must be 0)

    def bubble_ratio(self):
        return self.bubbles / jnp.maximum(self.slot_steps, 1)

    def occupancy(self):
        return 1.0 - self.bubble_ratio()


def zero_stats() -> WalkStats:
    return WalkStats(*(jnp.zeros((), jnp.int32) for _ in range(8)))


class WalkResult(NamedTuple):
    """Collected walk paths: paths[q, t] = t-th vertex of query q, -1 padded."""

    paths: jnp.ndarray    # (Q, max_len) int32
    lengths: jnp.ndarray  # (Q,) int32 — number of vertices recorded
    stats: WalkStats

    def as_numpy(self):
        return np.asarray(self.paths), np.asarray(self.lengths)
