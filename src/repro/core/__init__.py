"""RidgeWalker core: stateless task decomposition, samplers, zero-bubble
slot-pool engine, queuing-theoretic scheduler, distributed routing."""
from repro.core.samplers import SamplerSpec, get_sampler, edge_exists
from repro.core.tasks import (WalkerSlots, QueryQueue, WalkStats, WalkResult,
                              empty_slots, make_queue)
from repro.core.walk_engine import EngineConfig, make_engine, run_walks
from repro.core import scheduler
from repro.core import walks

__all__ = [
    "SamplerSpec", "get_sampler", "edge_exists",
    "WalkerSlots", "QueryQueue", "WalkStats", "WalkResult",
    "empty_slots", "make_queue",
    "EngineConfig", "make_engine", "run_walks",
    "scheduler", "walks",
]
