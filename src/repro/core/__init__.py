"""RidgeWalker core: stateless task decomposition, sampler phase-program
IR, zero-bubble slot-pool engine, queuing-theoretic scheduler,
distributed routing."""
from repro.core import corpus_ring, phase_program, scheduler
from repro.core.corpus_ring import CorpusRing
from repro.core.samplers import SamplerSpec, edge_exists
from repro.core.tasks import (N2VSlots, QueryQueue, ReservoirSlots,
                              WalkerSlots, WalkResult, WalkStats,
                              empty_queue, empty_slots, make_queue)
from repro.core.walk_engine import (EngineConfig, StreamState, build_engine,
                                    init_stream_state, inject_queries,
                                    make_engine, make_superstep_runner,
                                    run_walks)

__all__ = [
    "SamplerSpec", "edge_exists",
    "WalkerSlots", "N2VSlots", "ReservoirSlots", "QueryQueue",
    "WalkStats", "WalkResult",
    "empty_slots", "empty_queue", "make_queue",
    "EngineConfig", "StreamState", "init_stream_state", "inject_queries",
    "build_engine", "make_engine", "make_superstep_runner", "run_walks",
    "phase_program", "scheduler",
    "corpus_ring", "CorpusRing",
]
