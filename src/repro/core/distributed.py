"""Distributed walk engine: N asynchronous "pipelines" = N devices
(paper §IV: 16 pipelines over 32 HBM channels → here, the device mesh).

The full run loop lives inside a single ``shard_map`` over the ``ch``
(channel) axis: per superstep each device (a) executes one hop for every
live task whose current vertex it owns, (b) terminates finished walks and
refills freed lanes from its local query shard (zero-bubble scheduling),
(c) routes every live task to the owner of its new vertex with one
``all_to_all`` (the butterfly, `router.py`).

Because tasks are stateless and their randomness derives from
(seed, query_id, hop), the distributed engine produces *bit-identical
walks* to the single-device engine — the strongest possible correctness
check of the paper's claim that out-of-order, cross-pipeline execution
does not alter the sampled distribution (§V-A).  Tests assert this.

Path write-back uses the paper's streaming-window scheme (§IV-B): each
device appends (query_id, hop, vertex) records to a device-resident
emission log, flushed to host memory after the run and scattered into
per-query paths.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rng as task_rng, router
from repro.core.samplers import SALT_STOP, SamplerSpec, get_sampler
from repro.core.scheduler import routing_capacity
from repro.core.tasks import WalkerSlots, zero_stats
from repro.distributed.compat import shard_map
from repro.graph.partition import PartitionedGraph, owner_of


@dataclasses.dataclass(frozen=True)
class DistConfig:
    slots_per_device: int = 256    # W_loc — target live tasks per device
    max_hops: int = 80
    capacity_margin: float = 2.0   # Theorem VI.1 margin on bucket capacity
    retention_factor: float = 2.0  # retention region = factor × W_loc
    log_capacity: int = 1 << 16    # emission-log entries per device
    record_paths: bool = True
    max_supersteps: int = 1 << 16
    axis_name: str = "ch"

    def bucket_cap(self, num_devices: int) -> int:
        return routing_capacity(self.slots_per_device, num_devices,
                                self.capacity_margin)

    def retention_cap(self) -> int:
        return int(math.ceil(self.retention_factor * self.slots_per_device))

    def pool_size(self, num_devices: int) -> int:
        return num_devices * self.bucket_cap(num_devices) + self.retention_cap()


class LocalView(NamedTuple):
    """Per-device graph shard presented with the sampler interface."""
    row_ptr: jnp.ndarray
    col: jnp.ndarray
    weights: Optional[jnp.ndarray]
    alias_prob: Optional[jnp.ndarray]
    alias_idx: Optional[jnp.ndarray]
    max_degree: int
    type_offsets: Optional[jnp.ndarray] = None


class DistLogs(NamedTuple):
    qid: jnp.ndarray     # (N, cap) int32
    hop: jnp.ndarray     # (N, cap) int32
    vertex: jnp.ndarray  # (N, cap) int32
    cursor: jnp.ndarray  # (N,) int32


def _local_row_access(view: LocalView, v: jnp.ndarray, rank, num_devices: int,
                      v_per_dev: int):
    lid = jnp.clip(jnp.where(v >= 0, v // num_devices, 0), 0, v_per_dev - 1)
    addr = view.row_ptr[lid]
    deg = view.row_ptr[lid + 1] - addr
    return addr, deg


def _superstep_dist(spec, cfg, N, v_per_dev, nq_total, base_key, view,
                    starts_loc, qcount, rank, carry):
    (slots, head, log_q, log_h, log_v, cursor, stats, done, t) = carry
    W_loc = cfg.slots_per_device
    K = cfg.bucket_cap(N)
    R = cfg.retention_cap()
    S = cfg.pool_size(N)

    # ---- process: one hop for locally-owned live tasks ------------------
    mine = slots.active & (owner_of(slots.v_curr, N) == rank)
    if spec.stop_prob > 0.0:
        u_stop = task_rng.task_uniforms(base_key, slots.query_id, slots.hop,
                                        1, SALT_STOP)[:, 0]
        stop = mine & (u_stop < spec.stop_prob)
    else:
        stop = jnp.zeros_like(mine)

    addr, deg = _local_row_access(view, slots.v_curr, rank, N, v_per_dev)
    sampler = get_sampler(spec)
    idx, ok = sampler(view, addr, deg, slots, base_key)
    e = jnp.clip(addr + idx, 0, view.col.shape[-1] - 1)
    v_next = view.col[e]

    adv = mine & ~stop & ok
    dead = mine & ~stop & ~ok
    new_hop = jnp.where(adv, slots.hop + 1, slots.hop)
    reached_max = adv & (new_hop >= cfg.max_hops)
    terminated = stop | dead | reached_max

    # ---- emission log (streaming write-back, paper §IV-B) ---------------
    # Must run before the slot update clears query_id of terminated lanes
    # (the final hop of a walk is still a recorded visit).
    log_drop = jnp.zeros((), jnp.int32)
    if cfg.record_paths:
        cap = cfg.log_capacity
        pos = cursor + jnp.cumsum(adv.astype(jnp.int32)) - 1
        keep = adv & (pos < cap)
        p_safe = jnp.where(keep, pos, cap)
        qid_rec = jnp.where(adv, slots.query_id, -1)
        log_q = log_q.at[p_safe].set(qid_rec, mode="drop")
        log_h = log_h.at[p_safe].set(new_hop, mode="drop")
        log_v = log_v.at[p_safe].set(v_next, mode="drop")
        n_adv = jnp.sum(adv.astype(jnp.int32))
        log_drop = jnp.sum((adv & ~keep).astype(jnp.int32))
        cursor = jnp.minimum(cursor + n_adv, cap)

    slots = WalkerSlots(
        v_curr=jnp.where(adv, v_next, slots.v_curr),
        v_prev=jnp.where(adv, slots.v_curr, slots.v_prev),
        query_id=jnp.where(terminated, -1, slots.query_id),
        hop=new_hop,
        active=slots.active & ~terminated,
    )

    # ---- zero-bubble refill from the local query shard ------------------
    n_active = jnp.sum(slots.active.astype(jnp.int32))
    free = ~slots.active
    budget = jnp.maximum(W_loc - n_active, 0)
    avail = jnp.minimum(jnp.maximum(qcount - head, 0), budget)
    rank_free = jnp.cumsum(free.astype(jnp.int32)) - 1
    take = free & (rank_free < avail)
    k_local = head + rank_free
    k_safe = jnp.clip(k_local, 0, starts_loc.shape[0] - 1)
    start_v = starts_loc[k_safe]
    qid_new = k_local * N + rank  # global query id of local index k
    slots = WalkerSlots(
        v_curr=jnp.where(take, start_v, slots.v_curr),
        v_prev=jnp.where(take, -1, slots.v_prev),
        query_id=jnp.where(take, qid_new, slots.query_id),
        hop=jnp.where(take, 0, slots.hop),
        active=slots.active | take,
    )
    head = head + jnp.sum(take.astype(jnp.int32))

    # ---- route: butterfly all_to_all to the owning device ---------------
    dest = owner_of(slots.v_curr, N)
    lane = jnp.arange(S, dtype=jnp.int32)
    priority = jnp.where(lane >= N * K, 0, 1)  # retained tasks go first
    rr = router.pack_buckets(slots, dest, priority, N, K, R)
    incoming = router.exchange(rr.send, cfg.axis_name)
    slots = WalkerSlots(*(jnp.concatenate([a, b])
                          for a, b in zip(incoming, rr.retention)))

    # ---- stats + global termination --------------------------------------
    busy = jnp.sum(mine.astype(jnp.int32))
    upstream = (head < qcount).astype(jnp.int32)
    stats = stats._replace(
        steps=stats.steps + jnp.sum(adv.astype(jnp.int32)),
        slot_steps=stats.slot_steps + W_loc,
        bubbles=stats.bubbles + jnp.maximum(W_loc - busy, 0),
        starved=stats.starved + jnp.maximum(W_loc - busy, 0) * upstream,
        terminations=stats.terminations + jnp.sum(terminated.astype(jnp.int32)),
        supersteps=stats.supersteps + 1,
        route_waits=stats.route_waits + rr.waits,
        drops=stats.drops + rr.drops + log_drop,
    )
    n_live = jnp.sum(slots.active.astype(jnp.int32))
    remaining = jnp.maximum(qcount - head, 0)
    done = jax.lax.psum(n_live + remaining, cfg.axis_name) == 0
    return (slots, head, log_q, log_h, log_v, cursor, stats, done, t + 1)


def _empty_pool(S: int) -> WalkerSlots:
    return WalkerSlots(
        v_curr=jnp.full((S,), -1, jnp.int32),
        v_prev=jnp.full((S,), -1, jnp.int32),
        query_id=jnp.full((S,), -1, jnp.int32),
        hop=jnp.zeros((S,), jnp.int32),
        active=jnp.zeros((S,), bool),
    )


def make_distributed_engine(pg: PartitionedGraph, spec: SamplerSpec,
                            cfg: DistConfig, mesh: jax.sharding.Mesh):
    """Build a jitted distributed runner over the given 1-D mesh."""
    N = pg.num_devices
    assert mesh.devices.size == N, (mesh.devices.size, N)
    v_per_dev = pg.vertices_per_device
    P = jax.sharding.PartitionSpec

    has_w = pg.weights is not None
    has_alias = pg.alias_prob is not None

    def body(rowp, colp, wp, app, aip, starts_loc, qcount, base_key):
        rank = jax.lax.axis_index(cfg.axis_name)
        view = LocalView(
            row_ptr=rowp[0], col=colp[0],
            weights=wp[0] if has_w else None,
            alias_prob=app[0] if has_alias else None,
            alias_idx=aip[0] if has_alias else None,
            max_degree=pg.max_degree,
        )
        starts_l = starts_loc[0]
        qcount_l = qcount[0, 0]
        S = cfg.pool_size(N)
        cap = cfg.log_capacity if cfg.record_paths else 1
        carry = (
            _empty_pool(S),
            jnp.zeros((), jnp.int32),
            jnp.full((cap,), -1, jnp.int32),
            jnp.full((cap,), -1, jnp.int32),
            jnp.full((cap,), -1, jnp.int32),
            jnp.zeros((), jnp.int32),
            zero_stats(),
            jnp.asarray(False),
            jnp.zeros((), jnp.int32),
        )
        nq_total = starts_l.shape[0] * N

        def cond(c):
            return (~c[7]) & (c[8] < cfg.max_supersteps)

        step = partial(_superstep_dist, spec, cfg, N, v_per_dev, nq_total,
                       base_key, view, starts_l, qcount_l, rank)
        carry = jax.lax.while_loop(cond, step, carry)
        _, head, log_q, log_h, log_v, cursor, stats, _, _ = carry
        return (log_q[None], log_h[None], log_v[None], cursor[None],
                jax.tree.map(lambda x: x[None], stats))

    smapped = shard_map(
        body, mesh=mesh,
        in_specs=(P(cfg.axis_name), P(cfg.axis_name), P(cfg.axis_name),
                  P(cfg.axis_name), P(cfg.axis_name), P(cfg.axis_name),
                  P(cfg.axis_name), P()),
        out_specs=(P(cfg.axis_name),) * 4 + (P(cfg.axis_name),),
        check_vma=False,
    )

    @jax.jit
    def run(graph: PartitionedGraph, starts_sharded, qcount, base_key):
        dummy = jnp.zeros((N, 1), jnp.float32)
        dummy_i = jnp.zeros((N, 1), jnp.int32)
        return smapped(graph.row_ptr, graph.col,
                       graph.weights if has_w else dummy,
                       graph.alias_prob if has_alias else dummy,
                       graph.alias_idx if has_alias else dummy_i,
                       starts_sharded, qcount, base_key)

    return run


def run_distributed(pg: PartitionedGraph, starts, spec: SamplerSpec,
                    cfg: Optional[DistConfig] = None,
                    mesh: Optional[jax.sharding.Mesh] = None, seed: int = 0):
    """One-shot distributed run. Returns (DistLogs, WalkStats-per-device)."""
    cfg = cfg or DistConfig()
    N = pg.num_devices
    if mesh is None:
        devs = np.array(jax.devices()[:N])
        mesh = jax.sharding.Mesh(devs, (cfg.axis_name,))
    starts = np.asarray(starts, dtype=np.int32)
    Q = starts.shape[0]
    q_loc = (Q + N - 1) // N
    starts_sh = np.full((N, q_loc), 0, dtype=np.int32)
    qcount = np.zeros((N, 1), dtype=np.int32)
    for r in range(N):
        part = starts[r::N]
        starts_sh[r, : part.size] = part
        qcount[r, 0] = part.size
    run = make_distributed_engine(pg, spec, cfg, mesh)
    base_key = jax.random.PRNGKey(seed)
    log_q, log_h, log_v, cursor, stats = run(
        pg, jnp.asarray(starts_sh), jnp.asarray(qcount), base_key)
    logs = DistLogs(qid=log_q, hop=log_h, vertex=log_v, cursor=cursor)
    return logs, stats


def assemble_paths(logs: DistLogs, starts, max_hops: int):
    """Host-side scatter of the emission logs into per-query paths."""
    starts = np.asarray(starts)
    Q = starts.shape[0]
    paths = np.full((Q, max_hops + 1), -1, dtype=np.int32)
    lengths = np.ones((Q,), dtype=np.int32)
    paths[:, 0] = starts
    q = np.asarray(logs.qid).reshape(-1)
    h = np.asarray(logs.hop).reshape(-1)
    v = np.asarray(logs.vertex).reshape(-1)
    valid = q >= 0
    q, h, v = q[valid], h[valid], v[valid]
    paths[q, h] = v
    np.maximum.at(lengths, q, h + 1)
    return paths, lengths
