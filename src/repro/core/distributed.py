"""Distributed walk engine: N asynchronous "pipelines" = N devices
(paper §IV: 16 pipelines over 32 HBM channels → here, the device mesh).

The full run loop lives inside a single ``shard_map`` over the ``ch``
(channel) axis: per superstep each device (a) executes one *phase* of work
for every live task homed on it, (b) terminates finished walks and refills
freed lanes from its local query shard (zero-bubble scheduling), (c)
routes every live task to the device that owns the data its next phase
reads, with one ``all_to_all`` (the butterfly, `router.py`).

One generic superstep serves every sampler through the **phase-program
IR** (`repro.core.phase_program`): a sampler lowers once into typed
gather/score/draw/commit phases with explicit operand residency, and
:class:`ProgramCapability` interprets the lowered program's residency
schedule — all-local programs (uniform/alias/metapath over partitioned
``type_offsets``) execute a whole hop at owner(v_curr); a score phase
resident at owner(v_prev) splits the hop into a propose/verify superstep
pair (rejection Node2Vec); the looping chunk program ping-pongs reservoir
chunks between the two owners (weighted Node2Vec).  The engine allocates
the task word the program's ``carry`` declares (`WalkerSlots` /
`N2VSlots` / `ReservoirSlots`) and drives the same routing path for all.

Because tasks are stateless and their randomness derives from
(seed, query_id, hop), the distributed engine produces *bit-identical
walks* to the single-device engine — the strongest possible correctness
check of the paper's claim that out-of-order, cross-pipeline execution
does not alter the sampled distribution (§V-A).  Tests assert this for
first- AND second-order walks.

Losslessness.  Refill is flow-controlled: a device admits new queries
only up to its fair share of the *global* live-task headroom
(``psum``-coordinated), which bounds live tasks system-wide by N·W_loc;
the router retention region is provisioned to that bound
(`DistConfig.retention_cap`), so bucket overflow can always be retained
and ``drops == 0`` is a guarantee, not a hope.  (The previous
heuristically-sized retention dropped tasks under hub skew — the root
cause of the 8-device bit-identity failure; see ROADMAP.)

Path write-back uses the paper's streaming-window scheme (§IV-B): each
device appends (query_id, hop, vertex) records to a device-resident
emission log, flushed to host memory after the run and scattered into
per-query paths.
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from functools import partial
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rng as task_rng, router
from repro.core.phase_program import (PhaseProgram, chunk_gather,
                                      chunk_score, lower, make_sampler,
                                      reservoir_scan)
from repro.core.rng import SALT_COLUMN, SALT_STOP
from repro.core.samplers import (SamplerSpec, _uniform_index, es_num_chunks,
                                 n2v_bias, rejection_choose)
from repro.core.scheduler import routing_capacity
from repro.core.tasks import (WalkerSlots, empty_n2v_slots,
                              empty_reservoir_slots, empty_slots, zero_stats)
from repro.distributed.compat import shard_map
from repro.graph.partition import PartitionedGraph, owner_of


@dataclasses.dataclass(frozen=True)
class DistConfig:
    slots_per_device: int = 256    # W_loc — target live tasks per device
    max_hops: int = 80
    capacity_margin: float = 2.0   # Theorem VI.1 margin on bucket capacity
    retention_factor: float = 1.0  # × N·W_loc (global live bound); >= 1.0
                                   # guarantees drops == 0 (see module doc)
    log_capacity: int = 1 << 16    # emission-log entries per device
    record_paths: bool = True
    max_supersteps: int = 1 << 16
    axis_name: str = "ch"

    def __post_init__(self):
        if self.slots_per_device <= 0:
            raise ValueError(
                f"slots_per_device must be a positive lane count, got "
                f"{self.slots_per_device}")
        if self.max_hops <= 0:
            raise ValueError(f"max_hops must be positive, got "
                             f"{self.max_hops}")
        if self.capacity_margin <= 0:
            raise ValueError(f"capacity_margin must be positive, got "
                             f"{self.capacity_margin}")
        if self.retention_factor <= 0:
            raise ValueError(f"retention_factor must be positive, got "
                             f"{self.retention_factor}")
        if self.log_capacity <= 0 or self.max_supersteps <= 0:
            raise ValueError(
                f"log_capacity / max_supersteps must be positive, got "
                f"{self.log_capacity} / {self.max_supersteps}")

    def bucket_cap(self, num_devices: int) -> int:
        return routing_capacity(self.slots_per_device, num_devices,
                                self.capacity_margin)

    def retention_cap(self, num_devices: int) -> int:
        """Retention region sized to the global live-task bound N·W_loc:
        every live task in the system could, worst case, pile onto one
        device (hub skew) and must be retainable there."""
        return int(math.ceil(self.retention_factor
                             * num_devices * self.slots_per_device))

    def pool_size(self, num_devices: int) -> int:
        return (num_devices * self.bucket_cap(num_devices)
                + self.retention_cap(num_devices))


class LocalView(NamedTuple):
    """Per-device graph shard presented with the sampler interface.

    ``num_shards`` is what makes the shared sampler arithmetic
    residency-aware: `samplers.vertex_row` maps a global vertex id to
    ``v // num_shards``, its row in this shard's per-vertex arrays
    (`edge_exists` and the typed-segment gather run unchanged on either
    the full graph or a view)."""
    row_ptr: jnp.ndarray
    col: jnp.ndarray
    weights: Optional[jnp.ndarray]
    alias_prob: Optional[jnp.ndarray]
    alias_idx: Optional[jnp.ndarray]
    max_degree: int
    type_offsets: Optional[jnp.ndarray] = None
    num_shards: int = 1


class DistLogs(NamedTuple):
    qid: jnp.ndarray     # (N, cap) int32
    hop: jnp.ndarray     # (N, cap) int32
    vertex: jnp.ndarray  # (N, cap) int32
    cursor: jnp.ndarray  # (N,) int32


class StepOut(NamedTuple):
    """What a capability's per-phase step hands back to the generic
    superstep: the updated pool plus the hop-advance/termination masks the
    emission log and refill need.  ``query_id``/``active`` must be left
    untouched by the step — the generic code owns their lifecycle."""
    slots: Any
    adv: jnp.ndarray         # lanes that advanced one hop this superstep
    terminated: jnp.ndarray  # lanes whose walk ended this superstep
    v_next: jnp.ndarray      # vertex to record for advanced lanes
    new_hop: jnp.ndarray     # hop index of that record


def _local_row_access(view: LocalView, v: jnp.ndarray, num_devices: int,
                      v_per_dev: int):
    lid = jnp.clip(jnp.where(v >= 0, v // num_devices, 0), 0, v_per_dev - 1)
    addr = view.row_ptr[lid]
    deg = view.row_ptr[lid + 1] - addr
    return addr, deg


# --------------------------------------------------------------------------
# Generic capability: ONE engine adapter interpreting the lowered phase
# program — residency schedule → routing plan, `carry` → task word, phase
# bodies → the shared executors in `phase_program` / `samplers`.
# --------------------------------------------------------------------------


class ProgramCapability:
    """Sharded lowering of a :class:`~repro.core.phase_program.PhaseProgram`.

    The program's residency schedule picks one of three execution plans
    (this is derived structure, not per-sampler code):

    ``single_phase`` — every phase resident at owner(v_curr): the whole
    hop (Row Access → phase list → Column Access) executes in one
    superstep on the owner, via the same vectorized phase interpreter
    the single-device engine uses (`phase_program.make_sampler`, which
    is residency-aware through `LocalView.num_shards`).  Covers uniform,
    alias, and — with ``type_offsets`` partitioned alongside the CSR
    shards — metapath.

    ``two_phase`` — a score phase resident at owner(v_prev): phase A
    executes the program's csr-gather at owner(v_curr) and stages the
    candidate fan-out in the task word (`N2VSlots`); phase B executes
    the first-accept score at owner(v_prev) with the same
    (seed, qid, hop)-derived uniforms and the shared
    `rejection_choose`/`n2v_bias` arithmetic ⇒ bit-identical walks.
    Hop 0 has no v_prev (bias ≡ 1) and scores locally in phase A, which
    also avoids an owner(-1) thundering-herd hotspot on device 0.

    ``chunked_loop`` — the looping gather/score chunk pair: the O(deg)
    E-S reservoir scan ping-pongs `phase_program.chunk_gather` output
    (staged in `ReservoirSlots`) between owner(v_curr) and
    owner(v_prev)'s `phase_program.chunk_score` fold; phase 2·n_chunks
    finalizes at owner(v_curr) with a column access on the winning
    offset.  Per-lane early finalize: the gather phase flags the chunk
    covering deg(v_curr), and its score phase jumps straight to finalize
    instead of stepping through empty chunks (skipped chunks contribute
    only -inf reservoir keys, so the scanned argmax — and bit-identity
    with the local scan, which folds those same -inf chunks — is
    unchanged).

    Hop-0 prescan (``hop0_inline=False``, closed engine, chunked loop
    only): hop 0 is the one hop whose whole scan is local (bias ≡ 1
    without v_prev), so the closed engine batches it *once* before the
    superstep loop (:meth:`prescan_hop0`) instead of tracing the full
    chunked scan into every superstep — refilled tasks enter the pool
    already at hop 1.  Draws still derive from ``(seed, qid, hop=0,
    chunk)``, so paths are bit-identical.  The streaming engine keeps
    the inline hop-0 path (arrivals land mid-run)."""

    def __init__(self, prog: PhaseProgram, spec: SamplerSpec,
                 cfg: DistConfig, num_devices: int, v_per_dev: int,
                 max_degree: int, hop0_inline: bool = True):
        self.prog, self.spec, self.cfg = prog, spec, cfg
        self.N, self.v_per_dev = num_devices, v_per_dev
        self.schedule = prog.schedule
        if self.schedule == "chunked_loop":
            self.CH = spec.reservoir_chunk
            self.n_chunks = es_num_chunks(max_degree, self.CH)
            self.hop0_inline = hop0_inline
            self.prescan = not hop0_inline
        else:
            self.hop0_inline = True
            self.prescan = False
        if self.schedule == "single_phase":
            self._sampler = make_sampler(spec)

    # ------------------------------------------------- task word / routing

    def empty_pool(self, size: int):
        carry = self.prog.carry
        if carry == "candidates":
            return empty_n2v_slots(size, self.spec.rejection_rounds)
        if carry == "reservoir":
            return empty_reservoir_slots(size, self.CH)
        return empty_slots(size)

    def home(self, slots) -> jnp.ndarray:
        if self.schedule == "single_phase":
            return owner_of(slots.v_curr, self.N)
        if self.schedule == "two_phase":
            return owner_of(jnp.where(slots.phase == 0, slots.v_curr,
                                      jnp.maximum(slots.v_prev, 0)), self.N)
        # chunked loop: even phases (gather / finalize) live at
        # owner(v_curr); odd (score) at owner(v_prev).
        return owner_of(jnp.where(slots.phase % 2 == 0, slots.v_curr,
                                  jnp.maximum(slots.v_prev, 0)), self.N)

    def route_dest(self, slots) -> jnp.ndarray:
        if self.schedule == "two_phase":
            return owner_of(jnp.where(slots.phase == 1,
                                      jnp.maximum(slots.v_prev, 0),
                                      slots.v_curr), self.N)
        return self.home(slots)

    def reset_extras(self, slots, take):
        carry = self.prog.carry
        if carry == "candidates":
            return slots._replace(phase=jnp.where(take, 0, slots.phase))
        if carry == "reservoir":
            return slots._replace(
                phase=jnp.where(take, 0, slots.phase),
                best_key=jnp.where(take, -jnp.inf, slots.best_key),
                best_idx=jnp.where(take, 0, slots.best_idx),
                last_chunk=jnp.where(take, False, slots.last_chunk),
            )
        return slots

    # ------------------------------------------------------------- stepping

    def step(self, view: LocalView, slots, mine, base_key) -> StepOut:
        return {"single_phase": self._step_single,
                "two_phase": self._step_two_phase,
                "chunked_loop": self._step_chunked}[self.schedule](
                    view, slots, mine, base_key)

    def _step_single(self, view: LocalView, slots, mine,
                     base_key) -> StepOut:
        """Whole hop at owner(v_curr): Row Access → phase interpreter →
        Column Access (the sharded twin of `walk_engine._process`)."""
        spec, cfg = self.spec, self.cfg
        if spec.stop_prob > 0.0:
            u_stop = task_rng.task_uniforms(base_key, slots.query_id,
                                            slots.hop, 1, SALT_STOP,
                                            epoch=slots.epoch)[:, 0]
            stop = mine & (u_stop < spec.stop_prob)
        else:
            stop = jnp.zeros_like(mine)

        addr, deg = _local_row_access(view, slots.v_curr, self.N,
                                      self.v_per_dev)
        idx, ok = self._sampler(view, addr, deg, slots, base_key)
        e = jnp.clip(addr + idx, 0, view.col.shape[-1] - 1)
        v_next = view.col[e]

        adv = mine & ~stop & ok
        dead = mine & ~stop & ~ok
        new_hop = jnp.where(adv, slots.hop + 1, slots.hop)
        reached_max = adv & (new_hop >= cfg.max_hops)
        terminated = stop | dead | reached_max

        slots = slots._replace(
            v_curr=jnp.where(adv, v_next, slots.v_curr),
            v_prev=jnp.where(adv, slots.v_curr, slots.v_prev),
            hop=new_hop,
        )
        return StepOut(slots, adv, terminated, v_next, new_hop)

    def _step_two_phase(self, view: LocalView, slots, mine,
                        base_key) -> StepOut:
        """Propose @ owner(v_curr) (csr-gather phase), verify @
        owner(v_prev) (first-accept score phase)."""
        spec, cfg = self.spec, self.cfg
        K = spec.rejection_rounds

        do_a = mine & (slots.phase == 0)
        if spec.stop_prob > 0.0:   # termination draw at the top of a hop
            u_stop = task_rng.task_uniforms(base_key, slots.query_id,
                                            slots.hop, 1, SALT_STOP,
                                            epoch=slots.epoch)[:, 0]
            stop = do_a & (u_stop < spec.stop_prob)
        else:
            stop = jnp.zeros_like(do_a)

        # ---- phase A: the gather(csr, K) phase at owner(v_curr) ---------
        addr, deg = _local_row_access(view, slots.v_curr, self.N,
                                      self.v_per_dev)
        u = task_rng.task_uniforms(base_key, slots.query_id, slots.hop,
                                   2 * K, SALT_COLUMN, epoch=slots.epoch)
        u_col, u_acc = u[:, :K], u[:, K:]
        idx = _uniform_index(deg[:, None], u_col)
        e = jnp.clip(addr[:, None] + idx, 0, view.col.shape[-1] - 1)
        proposals = view.col[e]                                   # (S, K)
        dead = do_a & ~stop & (deg == 0)
        hop0 = do_a & ~stop & (slots.v_prev < 0) & (deg > 0)
        # Hop 0 scores locally: no v_prev ⇒ bias ≡ 1.
        first0 = rejection_choose(spec, u_acc, jnp.ones_like(u_acc))
        v0 = jnp.take_along_axis(proposals, first0[:, None], 1)[:, 0]
        go_b = do_a & ~stop & ~dead & ~hop0

        # ---- phase B: the score(first_accept) phase at owner(v_prev) ----
        do_b = mine & (slots.phase == 1)
        w = n2v_bias(spec, view, slots.v_prev, slots.cand)
        first = rejection_choose(spec, u_acc, w)
        vb = jnp.take_along_axis(slots.cand, first[:, None], 1)[:, 0]

        adv = do_b | hop0
        v_next = jnp.where(hop0, v0, vb)
        new_hop = jnp.where(adv, slots.hop + 1, slots.hop)
        reached_max = adv & (new_hop >= cfg.max_hops)
        terminated = stop | dead | reached_max

        slots = slots._replace(
            v_curr=jnp.where(adv, v_next, slots.v_curr),
            v_prev=jnp.where(adv, slots.v_curr, slots.v_prev),
            hop=new_hop,
            phase=jnp.where(go_b, 1, jnp.where(adv, 0, slots.phase)),
            cand=jnp.where(go_b[:, None], proposals, slots.cand),
        )
        return StepOut(slots, adv, terminated, v_next, new_hop)

    def prescan_hop0(self, view: LocalView, starts, qids, own, base_key):
        """Batched hop-0 scan for the queries this device owns data for
        (chunked loop, closed engine).

        One vectorized E-S reservoir scan over all owned start vertices
        (bias ≡ 1: no v_prev yet), with the exact (seed, qid, hop=0,
        chunk) uniforms and the hop-0 stop draw the inline path uses —
        bit-identical outcomes, evaluated once instead of inside every
        superstep.  Returns ``(v1, adv0, term0, enter)``: the sampled
        hop-1 vertex, whether the query advanced (a path record exists),
        whether it terminated at the prescan, and whether it should enter
        the slot pool (advanced and hop budget left).
        """
        spec, cfg = self.spec, self.cfg
        zeros = jnp.zeros_like(qids)
        addr, deg = _local_row_access(view, starts, self.N, self.v_per_dev)
        if spec.stop_prob > 0.0:
            u = task_rng.task_uniforms(base_key, qids, zeros, 1, SALT_STOP,
                                       epoch=zeros)[:, 0]
            stop = own & (u < spec.stop_prob)
        else:
            stop = jnp.zeros_like(own)
        dead = own & ~stop & (deg == 0)
        adv0 = own & ~stop & ~dead
        scan_slots = WalkerSlots(
            v_curr=starts, v_prev=jnp.full_like(starts, -1), query_id=qids,
            hop=zeros, active=adv0, epoch=zeros)
        idx0, _ = reservoir_scan(spec, view, addr, deg, scan_slots, base_key)
        v1 = view.col[jnp.clip(addr + idx0, 0, view.col.shape[-1] - 1)]
        reached = adv0 & (1 >= cfg.max_hops)
        term0 = stop | dead | reached
        return v1, adv0, term0, adv0 & ~reached

    def _step_chunked(self, view: LocalView, slots, mine,
                      base_key) -> StepOut:
        """One chunk phase of the looping gather/score program."""
        spec, cfg = self.spec, self.cfg
        CH, NC = self.CH, self.n_chunks
        phase = slots.phase
        chunk = phase // 2

        is_gather = mine & (phase % 2 == 0) & (phase < 2 * NC)
        is_score = mine & (phase % 2 == 1)
        is_final = mine & (phase == 2 * NC)
        at_hop_start = is_gather & (chunk == 0)

        if spec.stop_prob > 0.0:
            u_stop = task_rng.task_uniforms(base_key, slots.query_id,
                                            slots.hop, 1, SALT_STOP,
                                            epoch=slots.epoch)[:, 0]
            stop = at_hop_start & (u_stop < spec.stop_prob)
        else:
            stop = jnp.zeros_like(mine)

        addr, deg = _local_row_access(view, slots.v_curr, self.N,
                                      self.v_per_dev)
        dead = at_hop_start & ~stop & (deg == 0)

        # ---- hop 0: all-local scan (bias ≡ 1 without v_prev) ------------
        if self.hop0_inline:
            hop0 = at_hop_start & ~stop & (slots.v_prev < 0) & (deg > 0)
            idx0, _ = reservoir_scan(spec, view, addr, deg, slots, base_key)
            v0 = view.col[jnp.clip(addr + idx0, 0, view.col.shape[-1] - 1)]
        else:  # closed engine: hop 0 was batched by prescan_hop0
            hop0 = jnp.zeros_like(mine)
            v0 = slots.v_curr

        # ---- gather phase: stage chunk c of (candidate, edge weight) ----
        do_gather = is_gather & ~stop & ~dead & ~hop0
        y, w_edge = chunk_gather(view, addr, deg, chunk, CH)
        cand = jnp.where(do_gather[:, None], y, slots.cand)
        cand_w = jnp.where(do_gather[:, None], w_edge, slots.cand_w)

        # ---- score phase: E-S fold under the local N(v_prev) bias -------
        m_key, m_idx = chunk_score(spec, view, slots, chunk, CH, base_key)

        # ---- finalize: column access on the scanned argmax --------------
        idx_f = jnp.clip(slots.best_idx, 0, jnp.maximum(deg - 1, 0))
        v_f = view.col[jnp.clip(addr + idx_f, 0, view.col.shape[-1] - 1)]

        adv = is_final | hop0
        v_next = jnp.where(hop0, v0, v_f)
        new_hop = jnp.where(adv, slots.hop + 1, slots.hop)
        reached_max = adv & (new_hop >= cfg.max_hops)
        terminated = stop | dead | reached_max

        # Early finalize: the gather phase sees deg(v_curr) and flags the
        # chunk covering the last neighbor; its score phase then jumps to
        # the finalize phase rather than stepping through empty chunks.
        covers_deg = (chunk + 1) * CH >= deg
        next_phase = jnp.where(is_score & slots.last_chunk,
                               2 * NC, phase + 1)
        slots = slots._replace(
            v_curr=jnp.where(adv, v_next, slots.v_curr),
            v_prev=jnp.where(adv, slots.v_curr, slots.v_prev),
            hop=new_hop,
            phase=jnp.where(do_gather | is_score, next_phase,
                            jnp.where(adv, 0, phase)),
            cand=cand,
            cand_w=cand_w,
            best_key=jnp.where(is_score, m_key,
                               jnp.where(adv, -jnp.inf, slots.best_key)),
            best_idx=jnp.where(is_score, m_idx,
                               jnp.where(adv, 0, slots.best_idx)),
            last_chunk=jnp.where(do_gather, covers_deg,
                                 jnp.where(adv, False, slots.last_chunk)),
        )
        return StepOut(slots, adv, terminated, v_next, new_hop)


def get_capability(spec: SamplerSpec, cfg: DistConfig, num_devices: int,
                   v_per_dev: int, max_degree: int,
                   hop0_inline: bool = True) -> ProgramCapability:
    """Lower the sampler's phase program to the generic engine adapter.

    ``hop0_inline=False`` (closed engine) lets the chunked-loop schedule
    batch its hop-0 work into a one-time prescan instead of the
    per-superstep critical path.
    """
    prog = lower(spec)
    if prog.capability is None:  # no current program declares None
        raise NotImplementedError(
            f"sampler kind {spec.kind!r} declares no distributed "
            "capability; run it on the single-device backend")
    return ProgramCapability(prog, spec, cfg, num_devices, v_per_dev,
                             max_degree, hop0_inline=hop0_inline)


# --------------------------------------------------------------------------
# Generic superstep: phase-step → emission log → terminate → flow-controlled
# refill → butterfly route.  Identical for every capability.
# --------------------------------------------------------------------------


def _superstep_dist(cap, cfg: DistConfig, N: int, base_key, view,
                    starts_loc, qcount, rank, seeds, carry):
    (slots, head, log_q, log_h, log_v, cursor, stats, done, t) = carry
    W_loc = cfg.slots_per_device
    K = cfg.bucket_cap(N)
    R = cfg.retention_cap(N)
    S = cfg.pool_size(N)

    # ---- process: one phase for locally-homed live tasks ----------------
    mine = slots.active & (cap.home(slots) == rank)
    out = cap.step(view, slots, mine, base_key)
    slots, adv, terminated = out.slots, out.adv, out.terminated

    # ---- emission log (streaming write-back, paper §IV-B) ---------------
    # Runs before the terminated lanes' query_id is cleared (the final hop
    # of a walk is still a recorded visit).
    log_drop = jnp.zeros((), jnp.int32)
    if cfg.record_paths:
        cap_log = cfg.log_capacity
        pos = cursor + jnp.cumsum(adv.astype(jnp.int32)) - 1
        keep = adv & (pos < cap_log)
        p_safe = jnp.where(keep, pos, cap_log)
        log_q = log_q.at[p_safe].set(jnp.where(adv, slots.query_id, -1),
                                     mode="drop")
        log_h = log_h.at[p_safe].set(out.new_hop, mode="drop")
        log_v = log_v.at[p_safe].set(out.v_next, mode="drop")
        log_drop = jnp.sum((adv & ~keep).astype(jnp.int32))
        cursor = jnp.minimum(cursor + jnp.sum(adv.astype(jnp.int32)), cap_log)

    slots = slots._replace(
        query_id=jnp.where(terminated, -1, slots.query_id),
        active=slots.active & ~terminated,
    )

    # ---- zero-bubble refill, flow-controlled to the global live bound ---
    # Each device admits at most its fair share of the global headroom
    # N·W_loc - live, so system-wide live tasks never exceed N·W_loc — the
    # bound the retention region is provisioned for (drops == 0 by
    # construction, not by margin).
    n_active = jnp.sum(slots.active.astype(jnp.int32))
    global_live = jax.lax.psum(n_active, cfg.axis_name)
    slack = jnp.maximum(N * W_loc - global_live, 0)
    free = ~slots.active
    budget = jnp.minimum(jnp.maximum(W_loc - n_active, 0), slack // N)
    avail = jnp.minimum(jnp.maximum(qcount - head, 0), budget)
    rank_free = jnp.cumsum(free.astype(jnp.int32)) - 1
    take = free & (rank_free < avail)
    k_local = head + rank_free
    k_safe = jnp.clip(k_local, 0, starts_loc.shape[0] - 1)
    # Refill seeds: the plain engine admits hop-0 tasks at the start
    # vertex; a hop-0 prescan capability seeds hop-1 tasks (v_prev = the
    # start) and skips queries the prescan already terminated (`enter`).
    seed_vc, seed_vp, seed_hop, seed_enter = seeds
    adm = take & seed_enter[k_safe]  # admitted to the pool
    slots = slots._replace(
        v_curr=jnp.where(adm, seed_vc[k_safe], slots.v_curr),
        v_prev=jnp.where(adm, seed_vp[k_safe], slots.v_prev),
        query_id=jnp.where(adm, k_local * N + rank, slots.query_id),
        hop=jnp.where(adm, seed_hop[k_safe], slots.hop),
        active=slots.active | adm,
        epoch=jnp.where(adm, 0, slots.epoch),  # closed batch == epoch 0
    )
    slots = cap.reset_extras(slots, adm)
    head = head + jnp.sum(take.astype(jnp.int32))

    # ---- route: butterfly all_to_all to each task's next home -----------
    dest = cap.route_dest(slots)
    lane = jnp.arange(S, dtype=jnp.int32)
    priority = jnp.where(lane >= N * K, 0, 1)  # retained tasks go first
    rr = router.pack_buckets(slots, dest, priority, N, K, R)
    incoming = router.exchange(rr.send, cfg.axis_name)
    slots = type(slots)(*(jnp.concatenate([a, b])
                          for a, b in zip(incoming, rr.retention)))

    # ---- stats + global termination -------------------------------------
    busy = jnp.sum(mine.astype(jnp.int32))
    upstream = (head < qcount).astype(jnp.int32)
    stats = stats._replace(
        steps=stats.steps + jnp.sum(adv.astype(jnp.int32)),
        slot_steps=stats.slot_steps + W_loc,
        bubbles=stats.bubbles + jnp.maximum(W_loc - busy, 0),
        starved=stats.starved + jnp.maximum(W_loc - busy, 0) * upstream,
        terminations=stats.terminations
        + jnp.sum(terminated.astype(jnp.int32)),
        supersteps=stats.supersteps + 1,
        route_waits=stats.route_waits + rr.waits,
        drops=stats.drops + rr.drops + log_drop,
        launches=stats.launches + 1,
    )
    n_live = jnp.sum(slots.active.astype(jnp.int32))
    remaining = jnp.maximum(qcount - head, 0)
    done = jax.lax.psum(n_live + remaining, cfg.axis_name) == 0
    return (slots, head, log_q, log_h, log_v, cursor, stats, done, t + 1)


def _run_hop0_prescan(cap, cfg: DistConfig, N: int, rank, view: LocalView,
                      starts_l, qcount_l, base_key, log_q, log_h, log_v):
    """One-time batched hop-0 pass for prescan capabilities (closed engine).

    All devices gather the global query list once; each device runs the
    capability's vectorized hop-0 scan for the start vertices *it* owns,
    logs the resulting hop-1 records locally, and a psum distributes the
    hop-1 refill seeds back to the device staging each query.  Runs before
    the superstep loop — O(Q) work once instead of a full reservoir scan
    traced into every superstep.
    """
    q_loc = starts_l.shape[0]
    starts_all = jax.lax.all_gather(starts_l, cfg.axis_name)   # (N, q_loc)
    qcount_all = jax.lax.all_gather(qcount_l, cfg.axis_name)   # (N,)
    ks = jnp.arange(q_loc, dtype=jnp.int32)
    ranks = jnp.arange(N, dtype=jnp.int32)
    qid_all = (ks[None, :] * N + ranks[:, None]).reshape(-1)
    valid = (ks[None, :] < qcount_all[:, None]).reshape(-1)
    sflat = starts_all.reshape(-1)
    own = valid & (owner_of(sflat, N) == rank)
    v1, adv0, term0, enter = cap.prescan_hop0(view, sflat, qid_all, own,
                                              base_key)

    # The owner that computed each hop-1 vertex logs its (qid, 1, v1)
    # record — same emission-log discipline as the superstep.
    log_drop = jnp.zeros((), jnp.int32)
    cursor = jnp.zeros((), jnp.int32)
    if cfg.record_paths:
        cap_log = log_q.shape[0]
        pos = jnp.cumsum(adv0.astype(jnp.int32)) - 1
        keep = adv0 & (pos < cap_log)
        p_safe = jnp.where(keep, pos, cap_log)
        log_q = log_q.at[p_safe].set(jnp.where(adv0, qid_all, -1),
                                     mode="drop")
        log_h = log_h.at[p_safe].set(1, mode="drop")
        log_v = log_v.at[p_safe].set(v1, mode="drop")
        log_drop = jnp.sum((adv0 & ~keep).astype(jnp.int32))
        cursor = jnp.minimum(jnp.sum(adv0.astype(jnp.int32)), cap_log)

    stats0 = zero_stats()._replace(
        steps=jnp.sum(adv0.astype(jnp.int32)),
        terminations=jnp.sum(term0.astype(jnp.int32)),
        drops=log_drop,
    )

    # Hand every device the hop-1 seeds for the queries IT stages: each
    # query has exactly one owner, so a psum of owner-masked values is a
    # broadcast of that owner's result.
    v1_all = jax.lax.psum(jnp.where(enter, v1, 0), cfg.axis_name)
    enter_all = jax.lax.psum(enter.astype(jnp.int32), cfg.axis_name) > 0
    seeds = (v1_all.reshape(N, q_loc)[rank], starts_l,
             jnp.ones_like(starts_l), enter_all.reshape(N, q_loc)[rank])
    return seeds, log_q, log_h, log_v, cursor, stats0


def make_distributed_engine(pg: PartitionedGraph, spec: SamplerSpec,
                            cfg: DistConfig, mesh: jax.sharding.Mesh):
    """Build a jitted distributed runner over the given 1-D mesh.

    Works for every sampler kind that declares a capability — first- and
    second-order walks share this one routing path.
    """
    N = pg.num_devices
    assert mesh.devices.size == N, (mesh.devices.size, N)
    v_per_dev = pg.vertices_per_device
    prog = lower(spec)
    if "typed" in prog.requires and pg.type_offsets is None:
        raise ValueError(
            "metapath programs need type_offsets partitioned with the "
            "graph — build the CSRGraph with num_edge_types > 0 before "
            "partition_graph")
    cap = get_capability(spec, cfg, N, v_per_dev, pg.max_degree,
                         hop0_inline=False)
    P = jax.sharding.PartitionSpec

    has_w = pg.weights is not None
    has_alias = pg.alias_prob is not None
    has_to = pg.type_offsets is not None

    def body(rowp, colp, wp, app, aip, top, starts_loc, qcount, base_key):
        rank = jax.lax.axis_index(cfg.axis_name)
        view = LocalView(
            row_ptr=rowp[0], col=colp[0],
            weights=wp[0] if has_w else None,
            alias_prob=app[0] if has_alias else None,
            alias_idx=aip[0] if has_alias else None,
            max_degree=pg.max_degree,
            type_offsets=top[0] if has_to else None,
            num_shards=N,
        )
        starts_l = starts_loc[0]
        qcount_l = qcount[0, 0]
        S = cfg.pool_size(N)
        cap_log = cfg.log_capacity if cfg.record_paths else 1
        log_q = jnp.full((cap_log,), -1, jnp.int32)
        log_h = jnp.full((cap_log,), -1, jnp.int32)
        log_v = jnp.full((cap_log,), -1, jnp.int32)
        cursor = jnp.zeros((), jnp.int32)
        stats0 = zero_stats()
        # Default refill seeds: hop-0 tasks at the start vertex.
        seeds = (starts_l, jnp.full_like(starts_l, -1),
                 jnp.zeros_like(starts_l),
                 jnp.ones(starts_l.shape, bool))
        if getattr(cap, "prescan", False):
            # ---- one-time batched hop-0 local scan (out of the
            # per-superstep critical path; see ProgramCapability) ------
            seeds, log_q, log_h, log_v, cursor, stats0 = _run_hop0_prescan(
                cap, cfg, N, rank, view, starts_l, qcount_l, base_key,
                log_q, log_h, log_v)
        carry = (
            cap.empty_pool(S),
            jnp.zeros((), jnp.int32),
            log_q, log_h, log_v, cursor,
            stats0,
            jnp.asarray(False),
            jnp.zeros((), jnp.int32),
        )

        def cond(c):
            return (~c[7]) & (c[8] < cfg.max_supersteps)

        step = partial(_superstep_dist, cap, cfg, N, base_key, view,
                       starts_l, qcount_l, rank, seeds)
        carry = jax.lax.while_loop(cond, step, carry)
        _, head, log_q, log_h, log_v, cursor, stats, _, _ = carry
        return (log_q[None], log_h[None], log_v[None], cursor[None],
                jax.tree.map(lambda x: x[None], stats))

    smapped = shard_map(
        body, mesh=mesh,
        in_specs=(P(cfg.axis_name), P(cfg.axis_name), P(cfg.axis_name),
                  P(cfg.axis_name), P(cfg.axis_name), P(cfg.axis_name),
                  P(cfg.axis_name), P(cfg.axis_name), P()),
        out_specs=(P(cfg.axis_name),) * 4 + (P(cfg.axis_name),),
        check_vma=False,
    )

    @jax.jit
    def run(graph: PartitionedGraph, starts_sharded, qcount, base_key):
        dummy = jnp.zeros((N, 1), jnp.float32)
        dummy_i = jnp.zeros((N, 1), jnp.int32)
        dummy_to = jnp.zeros((N, 1, 2), jnp.int32)
        return smapped(graph.row_ptr, graph.col,
                       graph.weights if has_w else dummy,
                       graph.alias_prob if has_alias else dummy,
                       graph.alias_idx if has_alias else dummy_i,
                       graph.type_offsets if has_to else dummy_to,
                       starts_sharded, qcount, base_key)

    return run


# --------------------------------------------------------------------------
# Open-system (streaming) distributed engine: persistent sharded state,
# chunked supersteps, host injection between chunks — the multi-device
# realization of the ring-buffer slot economy (core/walk_engine.py).  The
# same capability dispatch, flow-controlled refill, and butterfly routing
# as the closed engine; only arrival/injection and harvest differ.
# --------------------------------------------------------------------------


class DistStreamState(NamedTuple):
    """Persistent sharded stream state; every leaf's leading axis is the
    device (channel) axis.

    Arrivals are staged by the host into per-device *arrival rings* —
    (start, qid, epoch) triplets appended at monotone ``tail`` counters on
    whichever device the host round-robins them to.  Refill turns a staged
    arrival into a hop-0 task on the staging device, and the very next
    routing phase (the same butterfly ``all_to_all`` every live task rides)
    carries it to owner(start_vertex) — injected queries reuse the existing
    distributed routing rather than a second injection network.

    ``paths``/``lengths``/``done`` are streaming write-back windows indexed
    by global slot id: each device scatters only the hops *it* executed and
    the host folds the shards with an elementwise max at harvest.  Every
    (qid, hop) cell is written by exactly one device (the one that advanced
    that hop) while all others keep the -1/0 fill, so the fold is exact and
    — unlike the closed engine's bounded emission log — structurally
    lossless: streaming harvests can never drop path records.

    Rings are provisioned to the full stream ``capacity`` per device, so
    even if every live query is staged on one device the ring cannot
    overflow (live queries are bounded by ``capacity`` host-side).
    """

    slots: Any               # capability task word, leaves (N, S, ...)
    ring_start: jnp.ndarray  # (N, cap) int32 — start vertex by arrival seq
    ring_qid: jnp.ndarray    # (N, cap) int32 — slot id by arrival seq
    ring_epoch: jnp.ndarray  # (N, cap) int32 — occupant epoch by arrival seq
    head: jnp.ndarray        # (N,) int32 — monotone per-device issue counter
    tail: jnp.ndarray        # (N,) int32 — monotone per-device arrival counter
    paths: jnp.ndarray       # (N, cap, max_hops+1) int32 — per-device hops
    lengths: jnp.ndarray     # (N, cap) int32
    done: jnp.ndarray        # (N, cap) bool — terminated, by slot id
    stats: WalkStats         # leaves (N,)


def init_dist_stream_state(pg: PartitionedGraph, spec: SamplerSpec,
                           cfg: DistConfig, capacity: int) -> DistStreamState:
    """Empty sharded open-system state with room for ``capacity`` live
    queries (global slot ids 0..capacity-1, shared across devices)."""
    N = pg.num_devices
    cap_ = get_capability(spec, cfg, N, pg.vertices_per_device,
                          pg.max_degree)
    pool = cap_.empty_pool(cfg.pool_size(N))

    def rep(x):
        return jnp.broadcast_to(x[None], (N,) + x.shape)

    return DistStreamState(
        slots=jax.tree.map(rep, pool),
        ring_start=jnp.zeros((N, capacity), jnp.int32),
        ring_qid=jnp.zeros((N, capacity), jnp.int32),
        ring_epoch=jnp.zeros((N, capacity), jnp.int32),
        head=jnp.zeros((N,), jnp.int32),
        tail=jnp.zeros((N,), jnp.int32),
        paths=jnp.full((N, capacity, cfg.max_hops + 1), -1, jnp.int32),
        lengths=jnp.zeros((N, capacity), jnp.int32),
        done=jnp.zeros((N, capacity), bool),
        stats=jax.tree.map(rep, zero_stats()),
    )


@jax.jit
def inject_stream_queries(state: DistStreamState, starts_blk, qid_blk,
                          epoch_blk, counts) -> DistStreamState:
    """Stage arrival blocks into the per-device rings (host→device).

    ``starts_blk``/``qid_blk``/``epoch_blk`` are (N, B) blocks (padded to a
    fixed B so injection compiles O(log capacity) shapes); row r's first
    ``counts[r]`` entries are real arrivals for device r.  Recycled slots'
    ``done`` bits and path rows are cleared on *every* device shard — an
    old occupant's hops may have been recorded anywhere.
    """
    N, cap = state.ring_qid.shape
    B = starts_blk.shape[1]
    idx = jnp.arange(B, dtype=jnp.int32)[None, :]
    counts = jnp.asarray(counts, jnp.int32)
    valid = idx < counts[:, None]
    row = jnp.broadcast_to(jnp.arange(N, dtype=jnp.int32)[:, None], (N, B))
    pos = jnp.where(valid, (state.tail[:, None] + idx) % cap, cap)
    ring_start = state.ring_start.at[row, pos].set(
        jnp.asarray(starts_blk, jnp.int32), mode="drop")
    ring_qid = state.ring_qid.at[row, pos].set(
        jnp.asarray(qid_blk, jnp.int32), mode="drop")
    ring_epoch = state.ring_epoch.at[row, pos].set(
        jnp.asarray(epoch_blk, jnp.int32), mode="drop")

    cols = jnp.where(valid, jnp.asarray(qid_blk, jnp.int32), cap).reshape(-1)
    done = state.done.at[:, cols].set(False, mode="drop")
    paths = state.paths.at[:, cols, :].set(-1, mode="drop")
    lengths = state.lengths.at[:, cols].set(0, mode="drop")
    return state._replace(
        ring_start=ring_start, ring_qid=ring_qid, ring_epoch=ring_epoch,
        tail=state.tail + counts, done=done, paths=paths, lengths=lengths)


def _superstep_dist_stream(cap, cfg: DistConfig, N: int, capacity: int,
                           base_key, view, rank, carry):
    """One streaming superstep: phase-step → path/done scatter → terminate
    → flow-controlled ring refill → butterfly route (mirrors
    `_superstep_dist`, with the arrival ring in place of the start shard
    and scatter windows in place of the emission log)."""
    i, _, st = carry
    slots = st.slots
    W_loc = cfg.slots_per_device
    K = cfg.bucket_cap(N)
    R = cfg.retention_cap(N)
    S = cfg.pool_size(N)

    # ---- process: one phase for locally-homed live tasks ----------------
    mine = slots.active & (cap.home(slots) == rank)
    out = cap.step(view, slots, mine, base_key)
    slots, adv, terminated = out.slots, out.adv, out.terminated

    # ---- streaming write-back: scatter executed hops locally ------------
    scatter_q = jnp.where(adv, slots.query_id, capacity)   # capacity = drop
    paths = st.paths.at[scatter_q, out.new_hop].set(out.v_next, mode="drop")
    lengths = st.lengths.at[scatter_q].set(out.new_hop + 1, mode="drop")
    done = st.done.at[jnp.where(terminated, slots.query_id, capacity)].set(
        True, mode="drop")

    slots = slots._replace(
        query_id=jnp.where(terminated, -1, slots.query_id),
        active=slots.active & ~terminated,
    )

    # ---- zero-bubble refill from the local arrival ring, flow-controlled
    # to the global live bound N·W_loc (identical psum coordination to the
    # closed engine, so losslessness carries over to the open system) ----
    n_active = jnp.sum(slots.active.astype(jnp.int32))
    global_live = jax.lax.psum(n_active, cfg.axis_name)
    slack = jnp.maximum(N * W_loc - global_live, 0)
    free = ~slots.active
    budget = jnp.minimum(jnp.maximum(W_loc - n_active, 0), slack // N)
    avail = jnp.minimum(jnp.maximum(st.tail - st.head, 0), budget)
    rank_free = jnp.cumsum(free.astype(jnp.int32)) - 1
    take = free & (rank_free < avail)
    pos = (st.head + jnp.maximum(rank_free, 0)) % capacity
    qid = st.ring_qid[pos]
    start = st.ring_start[pos]
    ep = st.ring_epoch[pos]
    slots = slots._replace(
        v_curr=jnp.where(take, start, slots.v_curr),
        v_prev=jnp.where(take, -1, slots.v_prev),
        query_id=jnp.where(take, qid, slots.query_id),
        hop=jnp.where(take, 0, slots.hop),
        active=slots.active | take,
        epoch=jnp.where(take, ep, slots.epoch),
    )
    slots = cap.reset_extras(slots, take)
    head = st.head + jnp.sum(take.astype(jnp.int32))
    # Record hop 0 on the staging device; the route below hands the task
    # to owner(start_vertex) for its first hop.
    sq = jnp.where(take, qid, capacity)
    paths = paths.at[sq, 0].set(start, mode="drop")
    lengths = lengths.at[sq].set(1, mode="drop")

    # ---- route: butterfly all_to_all to each task's next home -----------
    dest = cap.route_dest(slots)
    lane = jnp.arange(S, dtype=jnp.int32)
    priority = jnp.where(lane >= N * K, 0, 1)  # retained tasks go first
    rr = router.pack_buckets(slots, dest, priority, N, K, R)
    incoming = router.exchange(rr.send, cfg.axis_name)
    slots = type(slots)(*(jnp.concatenate([a, b])
                          for a, b in zip(incoming, rr.retention)))

    # ---- stats + global work flag ---------------------------------------
    busy = jnp.sum(mine.astype(jnp.int32))
    upstream = (head < st.tail).astype(jnp.int32)
    stats = st.stats._replace(
        steps=st.stats.steps + jnp.sum(adv.astype(jnp.int32)),
        slot_steps=st.stats.slot_steps + W_loc,
        bubbles=st.stats.bubbles + jnp.maximum(W_loc - busy, 0),
        starved=st.stats.starved + jnp.maximum(W_loc - busy, 0) * upstream,
        terminations=st.stats.terminations
        + jnp.sum(terminated.astype(jnp.int32)),
        supersteps=st.stats.supersteps + 1,
        route_waits=st.stats.route_waits + rr.waits,
        drops=st.stats.drops + rr.drops,
        launches=st.stats.launches + 1,
    )
    n_live = jnp.sum(slots.active.astype(jnp.int32))
    pending = jnp.maximum(st.tail - head, 0)
    work = jax.lax.psum(n_live + pending, cfg.axis_name) > 0
    st = DistStreamState(
        slots=slots, ring_start=st.ring_start, ring_qid=st.ring_qid,
        ring_epoch=st.ring_epoch, head=head, tail=st.tail, paths=paths,
        lengths=lengths, done=done, stats=stats)
    return (i + 1, work, st)


def make_sharded_stream_engine(pg: PartitionedGraph, spec: SamplerSpec,
                               cfg: DistConfig, mesh: jax.sharding.Mesh,
                               capacity: int):
    """Build a jitted ``run(graph, state, base_key, k) -> DistStreamState``
    advancing the sharded stream by at most ``k`` supersteps (stopping
    early when no work remains anywhere).  ``k`` is traced; the host
    injects with :func:`inject_stream_queries` between chunks and harvests
    by max-folding the per-device path windows.
    """
    N = pg.num_devices
    assert mesh.devices.size == N, (mesh.devices.size, N)
    v_per_dev = pg.vertices_per_device
    prog = lower(spec)
    if "typed" in prog.requires and pg.type_offsets is None:
        raise ValueError(
            "metapath programs need type_offsets partitioned with the "
            "graph — build the CSRGraph with num_edge_types > 0 before "
            "partition_graph")
    cap_ = get_capability(spec, cfg, N, v_per_dev, pg.max_degree)
    P = jax.sharding.PartitionSpec

    has_w = pg.weights is not None
    has_alias = pg.alias_prob is not None
    has_to = pg.type_offsets is not None

    def body(rowp, colp, wp, app, aip, top, state, base_key, k):
        rank = jax.lax.axis_index(cfg.axis_name)
        view = LocalView(
            row_ptr=rowp[0], col=colp[0],
            weights=wp[0] if has_w else None,
            alias_prob=app[0] if has_alias else None,
            alias_idx=aip[0] if has_alias else None,
            max_degree=pg.max_degree,
            type_offsets=top[0] if has_to else None,
            num_shards=N,
        )
        st = jax.tree.map(lambda x: x[0], state)
        live0 = jnp.sum(st.slots.active.astype(jnp.int32))
        pending0 = jnp.maximum(st.tail - st.head, 0)
        work0 = jax.lax.psum(live0 + pending0, cfg.axis_name) > 0

        step = partial(_superstep_dist_stream, cap_, cfg, N, capacity,
                       base_key, view, rank)

        def cond(c):
            return c[1] & (c[0] < k)

        _, _, st = jax.lax.while_loop(
            cond, step, (jnp.zeros((), jnp.int32), work0, st))
        return jax.tree.map(lambda x: x[None], st)

    smapped = shard_map(
        body, mesh=mesh,
        in_specs=(P(cfg.axis_name), P(cfg.axis_name), P(cfg.axis_name),
                  P(cfg.axis_name), P(cfg.axis_name), P(cfg.axis_name),
                  P(cfg.axis_name), P(), P()),
        out_specs=P(cfg.axis_name),
        check_vma=False,
    )

    @jax.jit
    def run(graph: PartitionedGraph, state: DistStreamState, base_key,
            k) -> DistStreamState:
        dummy = jnp.zeros((N, 1), jnp.float32)
        dummy_i = jnp.zeros((N, 1), jnp.int32)
        dummy_to = jnp.zeros((N, 1, 2), jnp.int32)
        return smapped(graph.row_ptr, graph.col,
                       graph.weights if has_w else dummy,
                       graph.alias_prob if has_alias else dummy,
                       graph.alias_idx if has_alias else dummy_i,
                       graph.type_offsets if has_to else dummy_to,
                       state, base_key, jnp.asarray(k, jnp.int32))

    return run


def shard_starts(starts, num_devices: int):
    """Round-robin shard start vertices across devices; returns the
    (N, q_loc) padded shard matrix and the (N, 1) per-device counts.
    Query ``k`` of device ``r`` is global query id ``k·N + r``."""
    starts = np.asarray(starts, dtype=np.int32)
    N = num_devices
    q_loc = max((starts.shape[0] + N - 1) // N, 1)
    starts_sh = np.zeros((N, q_loc), dtype=np.int32)
    qcount = np.zeros((N, 1), dtype=np.int32)
    for r in range(N):
        part = starts[r::N]
        starts_sh[r, : part.size] = part
        qcount[r, 0] = part.size
    return starts_sh, qcount


def _run_distributed(pg: PartitionedGraph, starts, spec: SamplerSpec,
                     cfg: Optional[DistConfig] = None,
                     mesh: Optional[jax.sharding.Mesh] = None, seed: int = 0):
    """One-shot distributed run. Returns (DistLogs, WalkStats-per-device)."""
    cfg = cfg or DistConfig()
    N = pg.num_devices
    if mesh is None:
        devs = np.array(jax.devices()[:N])
        mesh = jax.sharding.Mesh(devs, (cfg.axis_name,))
    starts_sh, qcount = shard_starts(starts, N)
    run = make_distributed_engine(pg, spec, cfg, mesh)
    base_key = task_rng.stream_key(seed)
    log_q, log_h, log_v, cursor, stats = run(
        pg, jnp.asarray(starts_sh), jnp.asarray(qcount), base_key)
    logs = DistLogs(qid=log_q, hop=log_h, vertex=log_v, cursor=cursor)
    return logs, stats


def run_distributed(pg: PartitionedGraph, starts, spec: SamplerSpec,
                    cfg: Optional[DistConfig] = None,
                    mesh: Optional[jax.sharding.Mesh] = None, seed: int = 0):
    """Deprecated one-shot entry — use
    ``repro.walker.compile(program, backend="sharded").run(...)``."""
    warnings.warn(
        "run_distributed is deprecated; use repro.walker.compile(program, "
        "backend='sharded').run(graph, starts) instead",
        DeprecationWarning, stacklevel=2)
    return _run_distributed(pg, starts, spec, cfg, mesh, seed)


def assemble_paths(logs: DistLogs, starts, max_hops: int):
    """Host-side scatter of the emission logs into per-query paths."""
    starts = np.asarray(starts)
    Q = starts.shape[0]
    paths = np.full((Q, max_hops + 1), -1, dtype=np.int32)
    lengths = np.ones((Q,), dtype=np.int32)
    paths[:, 0] = starts
    q = np.asarray(logs.qid).reshape(-1)
    h = np.asarray(logs.hop).reshape(-1)
    v = np.asarray(logs.vertex).reshape(-1)
    valid = q >= 0
    q, h, v = q[valid], h[valid], v[valid]
    paths[q, h] = v
    np.maximum.at(lengths, q, h + 1)
    return paths, lengths
