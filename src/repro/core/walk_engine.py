"""Single-device walk engine: out-of-order slot-pool execution with
zero-bubble refill (paper §V + §VI, adapted to a SIMD superstep machine).

One *superstep* advances every live lane by one hop through the paper's
three stages — Row Access → Sampling → Column Access — then terminates
finished walks and immediately refills freed lanes from the pending-query
queue (the zero-bubble scheduler).  Because each task is stateless
(`tasks.py`) and its randomness derives from (seed, query_id, hop)
(`rng.py`), lanes are interchangeable: a query may be served by different
lanes on different hops without changing its sampled path — the Markov
decomposition of §V-A.

Two scheduling modes reproduce the paper's Fig. 11 ablation axis:
  * ``zero_bubble`` — per-superstep compaction + refill (RidgeWalker).
  * ``static``      — bulk-synchronous batches: a batch of W queries is
    bound to lanes and the engine waits for the *slowest* walk before
    loading the next batch (FastRW/LightRW-style static scheduling).
    Early-terminating walks leave idle lanes that are counted as bubbles.

The host→device injection latency is modeled by the queue's ``staged``
watermark, advanced by a feedback controller with C-superstep-delayed
observations of ``head`` (paper §VI-A "Back-pressure and Observation
Delay"); `scheduler.py` provisions the stage-ahead depth per Theorem VI.1.

Closed vs. open system
----------------------
The engine exposes two execution styles over one superstep function:

  * ``build_engine`` — the closed system of the paper's evaluation: a fixed
    query batch is drained to completion inside a single
    ``jax.lax.while_loop``.
  * ``make_superstep_runner`` — the open system of the queuing-theoretic
    setting Theorem VI.1 actually models: a jitted
    ``run_supersteps(graph, state, seed, k)`` advances *at most* ``k``
    supersteps and returns the persistent :class:`StreamState`, so the host
    can append newly arrived queries (``inject_queries``) between chunks
    without recompiling.  ``k`` and the arrival count are traced scalars;
    only the buffer shapes are static.

Ring-buffer slot economy (continuous operation)
-----------------------------------------------
The open system never drains: query-id slots are a *ring*.  When a query
completes and its paths are harvested, the host returns its slot to a free
ring and re-issues it to the next arrival with ``epoch + 1``; the RNG
derivation is salted with ``(epoch, qid, hop)`` (`rng.task_fold`), so
successive occupants of one slot sample independent walks and an unbounded
request stream is served with a bounded device buffer — no generation
rotation, no drain barrier.  ``inject_queries`` scatters arrivals into
host-assigned slots and appends them to the arrival-order ring
(``QueryQueue.order``) that refill consumes; epoch 0 derives bit-identically
to the classic ``(seed, query_id, hop)`` tuple, so a closed batch is simply
epoch 0 of a stream.

`repro.walker` is the front-end over both (``compile(program).run()`` /
``.stream()`` / ``.serve()``); the deprecated ``make_engine`` /
``run_walks`` names survive as warning shims.

Because path content depends only on ``(seed, epoch, query_id, hop)``,
chunked execution is bit-identical to one-shot execution for the same seed,
and epoch ``e`` of a stream is bit-identical to a closed batch run under
``rng.stream_key(seed, e)`` — the properties `tests/test_streaming.py`
pins down.
"""
from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import rng as task_rng, scheduler as sched
from repro.core.phase_program import lower as lower_program, make_sampler
from repro.core.rng import SALT_COLUMN, SALT_STOP
from repro.core.samplers import SamplerSpec
from repro.core.tasks import (QueryQueue, WalkerSlots, WalkResult, WalkStats,
                              empty_queue, empty_slots, make_queue, zero_stats)
from repro.graph.csr import CSRGraph, column_access, row_access


# Allowed scheduling modes / step implementations — shared with
# ExecutionConfig so the two validation layers cannot drift.
MODES = ("zero_bubble", "static")
STEP_IMPLS = ("jnp", "pallas", "fused")

# Schedule-export hook for the static analyzer (`repro.analysis`): draw
# streams the engine itself issues per task, outside any sampler phase
# program.  The PPR stop draw shares the task's (seed, epoch, qid, hop)
# tuple with the sampler's draws, so its salt channel must stay disjoint
# from every phase-program stream — the RNG-collision pass checks these
# against `PhaseProgram.draw_streams()`.  (All three backends — this jnp
# superstep, the sharded engine, and the fused kernel — issue the same
# logical stop draw at SALT_STOP.)
ENGINE_DRAW_STREAMS = (("engine.stop_draw", SALT_STOP, 1),)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    num_slots: int = 1024          # W — lane count (outstanding tasks/core)
    max_hops: int = 80             # paper §VIII-A4: query length 80
    record_paths: bool = True
    mode: str = "zero_bubble"      # zero_bubble | static
    injection_delay: int = 0       # C supersteps of host->device latency
    queue_depth_factor: float = 1.0  # × Theorem VI.1 depth D
    max_supersteps: int = 1 << 20  # safety bound for the while loop
    step_impl: str = "jnp"         # jnp | pallas (one-hop kernel) | fused
                                   # (device-resident multi-hop kernel)
    hops_per_launch: int = 16      # fused only: supersteps per kernel launch
                                   # (the k of the O(k·state) -> O(state)
                                   # host-traffic reduction)
    cache_budget: int = 0          # fused only: byte budget of the VMEM
                                   # hot-vertex adjacency cache (0 = off);
                                   # gathers on cached hubs skip the HBM
                                   # DMA loops, bit-identically

    def __post_init__(self):
        if self.num_slots <= 0:
            raise ValueError(
                f"num_slots must be a positive lane count (W), got "
                f"{self.num_slots}; a zero-width slot pool can do no work")
        if self.max_hops <= 0:
            raise ValueError(
                f"max_hops must be positive, got {self.max_hops}; a walk "
                "needs at least one hop of budget")
        if self.mode not in MODES:
            raise ValueError(
                f"mode must be one of {MODES}, got {self.mode!r}")
        if self.step_impl not in STEP_IMPLS:
            raise ValueError(
                f"step_impl must be one of {STEP_IMPLS}, got "
                f"{self.step_impl!r}")
        if self.injection_delay < 0:
            raise ValueError(
                f"injection_delay is a latency in supersteps and cannot be "
                f"negative, got {self.injection_delay}")
        if self.queue_depth_factor <= 0:
            raise ValueError(
                f"queue_depth_factor must be positive (it scales the "
                f"Theorem VI.1 stage-ahead depth), got "
                f"{self.queue_depth_factor}")
        if self.max_supersteps <= 0:
            raise ValueError(
                f"max_supersteps must be positive, got {self.max_supersteps}")
        if self.hops_per_launch <= 0:
            raise ValueError(
                f"hops_per_launch must be a positive superstep count per "
                f"fused-kernel launch, got {self.hops_per_launch}")
        if self.cache_budget < 0:
            raise ValueError(
                f"cache_budget is a byte budget (0 disables the hot-vertex "
                f"cache) and cannot be negative, got {self.cache_budget}")


class StreamState(NamedTuple):
    """Persistent engine state threaded through chunked superstep runs.

    All leaves are device arrays with static shapes, so the same jitted
    ``run_supersteps`` serves every chunk of a stream.  ``done[q]`` flips to
    True when query ``q`` terminates — the harvesting signal for the service
    layer (a lane-independent property: it does not matter which lane served
    the final hop).
    """

    slots: WalkerSlots
    queue: QueryQueue
    paths: jnp.ndarray      # (Q, max_hops+1) int32; (1, 1) when not recording
    lengths: jnp.ndarray    # (Q,) int32; (1,) when not recording
    done: jnp.ndarray       # (Q,) bool — query fully terminated
    stats: WalkStats
    head_hist: jnp.ndarray  # (C+1,) int32 — delayed head observations


def _stage_depth(cfg: EngineConfig) -> int:
    d = sched.min_queue_depth(cfg.num_slots, mu=1.0, delay=cfg.injection_delay)
    return max(1, int(round(cfg.queue_depth_factor * d)))


def maybe_build_cache(spec: SamplerSpec, cfg: EngineConfig, graph: CSRGraph):
    """Hot-vertex cache for this (spec, cfg, graph), or ``None``.

    The cache only exists for the fused kernel with a positive byte
    budget; its payload set comes from the phase program's declared
    ``cache_payloads`` (columns always, plus weights / alias tables /
    typed offsets as the sampler's gather phases require).  Building is
    host-side numpy work — callers that rebind graphs should memoize on
    graph identity (`repro.walker.compile` does).
    """
    if cfg.step_impl != "fused" or cfg.cache_budget <= 0:
        return None
    from repro.graph.hot_cache import build_hot_cache
    payloads = lower_program(spec).cache_payloads
    return build_hot_cache(graph, payloads, cfg.cache_budget)


def _fresh_buffers(cfg: EngineConfig, num_queries: int):
    if cfg.record_paths:
        paths = jnp.full((num_queries, cfg.max_hops + 1), -1, jnp.int32)
        lengths = jnp.zeros((num_queries,), jnp.int32)
    else:
        paths = jnp.full((1, 1), -1, jnp.int32)
        lengths = jnp.zeros((1,), jnp.int32)
    return paths, lengths


def init_stream_state(cfg: EngineConfig, capacity: int) -> StreamState:
    """Empty open-system state: a buffer with room for ``capacity`` queries,
    none of which have arrived yet (``tail == 0``)."""
    paths, lengths = _fresh_buffers(cfg, capacity)
    return StreamState(
        slots=empty_slots(cfg.num_slots),
        queue=empty_queue(capacity),
        paths=paths,
        lengths=lengths,
        done=jnp.zeros((capacity,), bool),
        stats=zero_stats(),
        head_hist=jnp.zeros((cfg.injection_delay + 1,), jnp.int32),
    )


def inject_queries(state: StreamState, qids, new_starts=None, epochs=None,
                   n_valid=None) -> StreamState:
    """Admit arrivals into ring slots (host→device injection).

    ``qids`` are the slot ids the host popped from its free ring (a slot is
    free initially or once its previous occupant was harvested and
    released); ``epochs`` are the occupant epochs salting each slot's RNG
    stream.  All three arrays may be padded to a fixed block size to bound
    the number of compiled shapes; only the first ``n_valid`` entries
    become real queries.  The arrival sequence ``tail`` advances by
    ``n_valid`` and the new occupants are appended to the arrival-order
    ring that refill consumes.  Recycled slots' ``done`` bits and recorded
    path rows are cleared here, so stale epochs can never leak into a
    harvest.  The host must only hand out free slots — `WalkStream` /
    `serve.WalkService` own that free-ring bookkeeping.

    The pre-ring form ``inject_queries(state, new_starts, n_valid)``
    (append fresh queries at sequential slots from the tail) survives as a
    deprecated shim.
    """
    if epochs is None:  # legacy 3-arg form: (state, new_starts, n_valid)
        warnings.warn(
            "inject_queries(state, starts, n_valid) is deprecated; the ring "
            "engine takes (state, qids, starts, epochs, n_valid) — or use "
            "repro.walker.compile(program).stream(graph), which owns the "
            "slot-ring bookkeeping", DeprecationWarning, stacklevel=2)
        starts = jnp.asarray(qids, jnp.int32)
        n_valid = jnp.asarray(new_starts, jnp.int32)
        # Sequential fresh slots at the tail, epoch 0 — exactly the old
        # append semantics (pad entries beyond n_valid stay inert).
        qids = state.queue.tail + jnp.arange(starts.shape[0], dtype=jnp.int32)
        new_starts = starts
        epochs = jnp.zeros((starts.shape[0],), jnp.int32)
    return _inject_queries(state, qids, new_starts, epochs, n_valid)


@jax.jit
def _inject_queries(state: StreamState, qids: jnp.ndarray,
                    new_starts: jnp.ndarray, epochs: jnp.ndarray,
                    n_valid) -> StreamState:
    q = state.queue
    cap = q.capacity
    n = jnp.asarray(n_valid, jnp.int32)
    qids = jnp.asarray(qids, jnp.int32)
    idx = jnp.arange(qids.shape[0], dtype=jnp.int32)
    valid = idx < n
    slot = jnp.where(valid, qids, cap)                       # cap = OOB drop
    sv = q.start_vertex.at[slot].set(
        jnp.asarray(new_starts, jnp.int32), mode="drop")
    ep = q.epoch.at[slot].set(jnp.asarray(epochs, jnp.int32), mode="drop")
    pos = jnp.where(valid, (q.tail + idx) % cap, cap)
    order = q.order.at[pos].set(qids, mode="drop")
    done = state.done.at[slot].set(False, mode="drop")
    paths, lengths = state.paths, state.lengths
    if paths.shape[0] == state.done.shape[0]:  # recording paths
        paths = paths.at[slot].set(-1, mode="drop")
        lengths = lengths.at[slot].set(0, mode="drop")
    return state._replace(
        queue=q._replace(start_vertex=sv, epoch=ep, order=order,
                         tail=q.tail + n),
        done=done, paths=paths, lengths=lengths)


def _refill(slots: WalkerSlots, queue: QueryQueue, paths, lengths,
            cfg: EngineConfig, terminated: jnp.ndarray):
    """Zero-bubble compaction + refill: freed lanes pull the next staged
    arrivals via a prefix-sum ranking (the butterfly balancer's O(1)-per-task
    dispatch, §VI-C, realized as a vectorized scan).  Arrivals are consumed
    from the order ring — the slot id, start vertex, and epoch of occupant
    ``head + rank`` all come from the ring, so reclaimed slots are re-issued
    transparently."""
    free = (~slots.active) | terminated
    if cfg.mode == "static":
        # Bulk-synchronous: only reload when the whole batch drained.
        all_free = jnp.all(free)
        free = free & all_free
    avail = jnp.maximum(queue.staged - queue.head, 0)
    rank = jnp.cumsum(free.astype(jnp.int32)) - 1           # rank among free lanes
    take = free & (rank < avail)
    nq = queue.capacity
    pos = (queue.head + jnp.maximum(rank, 0)) % nq          # arrival seq -> ring
    qid = queue.order[pos]
    start = queue.start_vertex[qid]
    ep = queue.epoch[qid]

    new_slots = WalkerSlots(
        v_curr=jnp.where(take, start, slots.v_curr),
        v_prev=jnp.where(take, -1, slots.v_prev),
        query_id=jnp.where(take, qid, jnp.where(terminated, -1, slots.query_id)),
        hop=jnp.where(take, 0, slots.hop),
        active=jnp.where(take, True, slots.active & ~terminated),
        epoch=jnp.where(take, ep, slots.epoch),
    )
    n_taken = jnp.sum(take.astype(jnp.int32))
    new_queue = queue._replace(head=queue.head + n_taken)
    if cfg.record_paths:
        scatter_q = jnp.where(take, qid, nq)  # nq = OOB -> dropped
        paths = paths.at[scatter_q, 0].set(start, mode="drop")
        lengths = lengths.at[scatter_q].set(1, mode="drop")
    return new_slots, new_queue, paths, lengths


def _advance_controller(queue: QueryQueue, head_hist: jnp.ndarray,
                        cfg: EngineConfig, depth: int):
    """Feedback-driven staging: observe head with C-superstep delay, keep
    the staged watermark >= delayed_head + D (Theorem VI.1), clipped to the
    queries that have actually *arrived* (``tail``) — in the open system the
    controller reacts to live arrivals, not a fixed batch size.

    ``head_hist`` holds the last C+1 head observations; pushing the current
    head first and reading index 0 yields the head from exactly C
    supersteps ago (the freshest observation available under the delay)."""
    head_hist = jnp.concatenate([head_hist[1:], queue.head[None]])
    delayed_head = head_hist[0]
    target = jnp.minimum(delayed_head + depth, queue.tail)
    staged = jnp.maximum(queue.staged, target)
    return queue._replace(staged=staged), head_hist


def _process(graph: CSRGraph, spec: SamplerSpec, cfg: EngineConfig, base_key,
             slots: WalkerSlots, paths, lengths, done):
    """One hop for every live lane: Row Access → Sampling → Column Access →
    terminate (paper Alg. II.1 lines 5-9, vectorized over lanes)."""
    A = slots.active

    # PPR teleport/termination draw (before the hop; geometric walk length).
    if spec.stop_prob > 0.0:
        u_stop = task_rng.task_uniforms(base_key, slots.query_id, slots.hop,
                                        1, SALT_STOP, epoch=slots.epoch)[:, 0]
        stop = A & (u_stop < spec.stop_prob)
    else:
        stop = jnp.zeros_like(A)

    if cfg.step_impl == "pallas" and lower_program(spec).pallas:
        # Fused Pallas walk-step kernel (async DMA pipeline, kernels/walk_step).
        from repro.kernels.walk_step import ops as walk_ops
        if spec.kind == "uniform":
            u = task_rng.task_uniforms(base_key, slots.query_id, slots.hop,
                                       1, SALT_COLUMN, epoch=slots.epoch)
            v_next, deg = walk_ops.walk_step_uniform(
                slots.v_curr, u[:, 0], graph.row_ptr, graph.col)
        else:
            u = task_rng.task_uniforms(base_key, slots.query_id, slots.hop,
                                       2, SALT_COLUMN, epoch=slots.epoch)
            v_next, deg = walk_ops.walk_step_alias(
                slots.v_curr, u[:, 0], u[:, 1], graph.row_ptr, graph.col,
                graph.alias_prob, graph.alias_idx)
        ok = deg > 0
    else:
        addr, deg = row_access(graph, slots.v_curr)           # stage 1
        sampler = make_sampler(spec)                          # phase program
        idx, ok = sampler(graph, addr, deg, slots, base_key)  # stage 2
        v_next = column_access(graph, addr, idx)              # stage 3

    adv = A & ~stop & ok
    dead = A & ~stop & ~ok
    new_hop = jnp.where(adv, slots.hop + 1, slots.hop)
    reached_max = adv & (new_hop >= cfg.max_hops)
    terminated = stop | dead | reached_max

    new_slots = WalkerSlots(
        v_curr=jnp.where(adv, v_next, slots.v_curr),
        v_prev=jnp.where(adv, slots.v_curr, slots.v_prev),
        query_id=slots.query_id,
        hop=new_hop,
        active=slots.active,
        epoch=slots.epoch,
    )
    if cfg.record_paths:
        nq = paths.shape[0]
        scatter_q = jnp.where(adv, slots.query_id, nq)
        paths = paths.at[scatter_q, new_hop].set(v_next, mode="drop")
        lengths = lengths.at[scatter_q].set(new_hop + 1, mode="drop")
    nd = done.shape[0]
    scatter_d = jnp.where(terminated & A, slots.query_id, nd)
    done = done.at[scatter_d].set(True, mode="drop")
    return new_slots, terminated, adv, paths, lengths, done


def _superstep(graph, spec, cfg, base_key, depth,
               state: StreamState) -> StreamState:
    slots, queue, paths, lengths, done, stats, head_hist = state
    W = cfg.num_slots

    slots, terminated, adv, paths, lengths, done = _process(
        graph, spec, cfg, base_key, slots, paths, lengths, done)

    n_active = jnp.sum(slots.active.astype(jnp.int32))
    idle = W - n_active
    # Idle lanes while unserved queries exist upstream = scheduler
    # starvation (what Theorem VI.1 eliminates); idle lanes after the last
    # arrived query was issued = unavoidable tail drain.
    upstream = (queue.head < queue.tail).astype(jnp.int32)
    stats = stats._replace(
        steps=stats.steps + jnp.sum(adv.astype(jnp.int32)),
        slot_steps=stats.slot_steps + W,
        bubbles=stats.bubbles + idle,
        starved=stats.starved + idle * upstream,
        terminations=stats.terminations
        + jnp.sum((terminated & slots.active).astype(jnp.int32)),
        supersteps=stats.supersteps + 1,
        # The per-hop impls dispatch one device program per superstep; the
        # fused kernel instead counts one launch per k supersteps.
        launches=stats.launches + 1,
    )

    queue, head_hist = _advance_controller(queue, head_hist, cfg, depth)
    slots, queue, paths, lengths = _refill(slots, queue, paths, lengths, cfg,
                                           terminated)
    return StreamState(slots, queue, paths, lengths, done, stats, head_hist)


def _work_left(state: StreamState):
    return (state.queue.head < state.queue.tail) | jnp.any(state.slots.active)


def make_superstep_runner(spec: SamplerSpec, cfg: EngineConfig, cache=None):
    """Build a jitted ``run_supersteps(graph, state, seed, k) -> StreamState``.

    Advances the stream by at most ``k`` supersteps, stopping early when no
    work remains (no staged queries and no live lanes).  ``k`` is a traced
    scalar, so chunk sizes can vary call-to-call without recompilation; the
    host injects arrivals between chunks with :func:`inject_queries`.

    With ``cfg.step_impl == "fused"`` the chunk is executed as
    ``ceil(k / hops_per_launch)`` launches of the device-resident fused
    kernel instead of ``k`` superstep bounces — same state protocol, same
    bit-exact paths, O(state) host traffic per launch instead of per hop.
    ``cache`` is the graph-specific :class:`~repro.graph.HotVertexCache`
    from :func:`maybe_build_cache` (fused + ``cache_budget > 0`` only).
    """
    depth = _stage_depth(cfg)
    # Every phase program lowers to the fused kernel (the chunked
    # reservoir runs as an in-kernel chunk loop) — cfg.step_impl is
    # taken at face value, no fallback resolution.
    assert lower_program(spec).fused, spec.kind

    if cfg.step_impl == "fused":
        from repro.kernels.fused_superstep import build_fused_launch
        launch = build_fused_launch(spec, cfg, depth, cache=cache)

        @jax.jit
        def run_supersteps(graph: CSRGraph, state: StreamState, seed,
                           k) -> StreamState:
            base_key = task_rng.stream_key(seed)
            k = jnp.asarray(k, jnp.int32)

            def cond(carry):
                i, st = carry
                return (i < k) & _work_left(st)

            def body(carry):
                i, st = carry
                kc = jnp.minimum(cfg.hops_per_launch, k - i)
                return i + kc, launch(graph, st, base_key, kc)

            _, state = jax.lax.while_loop(
                cond, body, (jnp.zeros((), jnp.int32), state))
            return state

        return run_supersteps

    @jax.jit
    def run_supersteps(graph: CSRGraph, state: StreamState, seed,
                       k) -> StreamState:
        base_key = task_rng.stream_key(seed)
        step = partial(_superstep, graph, spec, cfg, base_key, depth)

        def cond(carry):
            i, st = carry
            return (i < k) & _work_left(st)

        def body(carry):
            i, st = carry
            return i + 1, step(st)

        _, state = jax.lax.while_loop(
            cond, body, (jnp.zeros((), jnp.int32), state))
        return state

    return run_supersteps


def build_engine(spec: SamplerSpec, cfg: EngineConfig, cache=None):
    """Build a jitted ``run(graph, start_vertices, seed) -> WalkResult``
    (the closed system: drain a fixed query batch to completion).

    Engine-layer builder used by `repro.walker.compile`; prefer the
    `Walker` front-end unless you are extending the engine itself.

    ``step_impl="fused"`` drains the batch as a ``while_loop`` over
    device-resident fused-kernel launches (``hops_per_launch`` supersteps
    each) instead of per-hop superstep bounces — bit-identical paths,
    O(state) host traffic per launch.  ``cache`` is the graph-specific
    hot-vertex cache from :func:`maybe_build_cache`, closure-captured by
    the fused launch (ignored by the per-hop impls).
    """
    assert lower_program(spec).fused, spec.kind
    fused_launch = None
    if cfg.step_impl == "fused":
        from repro.kernels.fused_superstep import build_fused_launch
        fused_launch = build_fused_launch(spec, cfg, _stage_depth(cfg),
                                          cache=cache)

    @partial(jax.jit, static_argnames=("num_queries",))
    def run(graph: CSRGraph, start_vertices: jnp.ndarray, seed,
            num_queries: int) -> WalkResult:
        base_key = task_rng.stream_key(seed)
        depth = _stage_depth(cfg)
        queue = make_queue(start_vertices, staged=min(depth, num_queries))
        paths, lengths = _fresh_buffers(cfg, num_queries)
        state = StreamState(
            slots=empty_slots(cfg.num_slots),
            queue=queue,
            paths=paths,
            lengths=lengths,
            done=jnp.zeros((num_queries,), bool),
            stats=zero_stats(),
            head_hist=jnp.zeros((cfg.injection_delay + 1,), jnp.int32),
        )
        # Initial injection so lanes processed in superstep 1 are live.
        queue, head_hist = _advance_controller(state.queue, state.head_hist,
                                               cfg, depth)
        slots, queue, paths, lengths = _refill(
            state.slots, queue, state.paths, state.lengths, cfg,
            jnp.zeros((cfg.num_slots,), bool))
        state = state._replace(slots=slots, queue=queue, paths=paths,
                               lengths=lengths, head_hist=head_hist)

        def cond(st):
            return _work_left(st) & (st.stats.supersteps < cfg.max_supersteps)

        if cfg.step_impl == "fused":
            def body(st):
                kc = jnp.minimum(cfg.hops_per_launch,
                                 cfg.max_supersteps - st.stats.supersteps)
                return fused_launch(graph, st, base_key, kc)

            state = jax.lax.while_loop(cond, body, state)
        else:
            step = partial(_superstep, graph, spec, cfg, base_key, depth)
            state = jax.lax.while_loop(cond, step, state)
        return WalkResult(paths=state.paths, lengths=state.lengths,
                          stats=state.stats)

    return run


def make_engine(spec: SamplerSpec, cfg: EngineConfig):
    """Deprecated alias for :func:`build_engine` — prefer
    ``repro.walker.compile(program).run(...)``."""
    warnings.warn(
        "make_engine is deprecated; use repro.walker.compile(program)"
        ".run(graph, starts) (or build_engine when extending the engine)",
        DeprecationWarning, stacklevel=2)
    return build_engine(spec, cfg)


def _run_walks(graph: CSRGraph, start_vertices, spec: SamplerSpec,
               cfg: Optional[EngineConfig] = None, seed: int = 0) -> WalkResult:
    """One-shot closed-system run (engine-internal reference path)."""
    cfg = cfg or EngineConfig()
    sv = jnp.asarray(start_vertices, jnp.int32)
    run = build_engine(spec, cfg, cache=maybe_build_cache(spec, cfg, graph))
    return run(graph, sv, seed, num_queries=int(sv.shape[0]))


def run_walks(graph: CSRGraph, start_vertices, spec: SamplerSpec,
              cfg: Optional[EngineConfig] = None, seed: int = 0) -> WalkResult:
    """Deprecated convenience one-shot API — prefer
    ``repro.walker.compile(program).run(graph, starts)``."""
    warnings.warn(
        "run_walks is deprecated; use repro.walker.compile(program)"
        ".run(graph, starts)",
        DeprecationWarning, stacklevel=2)
    return _run_walks(graph, start_vertices, spec, cfg, seed)
