"""Stateless per-task random number generation (ThundeRiNG analogue, §VII).

The paper pairs each sampling module with ThundeRiNG, an on-chip RNG that
produces decorrelated streams with zero HBM traffic (unlike FastRW, which
pre-generates randoms on the host and burns HBM bandwidth loading them).

On TPU the exact analogue is JAX's counter-based Threefry: the random draw
for a task is a *pure function of the task tuple* ``(seed, query_id, hop)``
— which makes the draw itself stateless, so a task can be executed on any
device, at any time, in any order, and still produce the identical sample.
This is the RNG-side half of the paper's Markov-based stateless
decomposition (§V-A): reordering and re-routing tasks provably cannot
change the sampled walk distribution because the randomness travels with
the task identity, not with the execution site.

Open-system slot reuse extends the task identity with an *epoch*: when the
streaming engine reclaims a finished query's buffer slot (ring-buffer
economy), the next occupant of slot ``qid`` carries ``epoch + 1`` and its
draws derive from ``(seed, epoch, qid, hop)``.  Epoch 0 folds nothing
extra, so it is bit-for-bit the classic ``(seed, query_id, hop)``
derivation — a closed-batch run *is* epoch 0 of a stream, and epoch ``e``
of any stream equals a closed-batch run under :func:`stream_key`'s
epoch-salted base key.

Shared Threefry core
--------------------
:func:`threefry2x32` is an explicit, shape-agnostic implementation of the
Threefry-2x32 block cipher that is bit-equal to ``jax.random``'s
(``tests/test_fused_step.py`` pins the equality).  It exists so the *same*
derivation runs in two places:

  * the vectorized jnp superstep (``task_uniforms`` below — now direct
    uint32 vector math instead of a ``vmap`` of ``jax.random.fold_in``),
  * inside the fused Pallas superstep kernel
    (`repro.kernels.fused_superstep`), where per-lane draws are computed
    on SMEM scalars with zero HBM traffic — the literal ThundeRiNG
    analogue.

Both paths therefore sample identical walks for identical
``(seed, epoch, query_id, hop, salt)`` tuples, which is what makes
``step_impl="fused"`` bit-identical to ``step_impl="jnp"``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# --------------------------------------------------------------------------
# Salt registry: the single source of truth for every salt channel.
#
# A task's draw stream is keyed by (seed, epoch, query_id, hop, salt) — two
# streams with distinct salts are disjoint (the salt folds into the Threefry
# key), so the whole RNG-collision argument reduces to: no two independent
# uses share a salt.  Every SALT_* constant in the codebase is registered
# here, uniqueness is asserted at import, and the static analyzer
# (`repro.analysis`) reads this registry as ground truth when it proves the
# per-sampler draw streams pairwise disjoint.
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SaltChannel:
    """One registered salt channel.

    A scalar channel owns exactly the value ``value``.  A *family*
    (``family=True``) owns the open-ended range ``[value, ∞)`` — the
    reservoir chunk draws use ``SALT_CHUNK0 + c`` for chunk ``c`` with a
    data-dependent (degree-bounded, statically unbounded) chunk count, so
    the family must sit above every scalar channel.
    """

    name: str
    value: int
    family: bool = False

    def covers(self, salt: int) -> bool:
        """Does this channel own the concrete salt value ``salt``?"""
        return salt >= self.value if self.family else salt == self.value


class SaltRegistry:
    """Name → :class:`SaltChannel` registry with import-time disjointness.

    ``register`` raises immediately when a new channel overlaps an
    existing one (duplicate scalar value, scalar inside a family's range,
    or a second open-ended family — two unbounded families always
    overlap), so a bad salt constant can never make it past import.
    """

    def __init__(self):
        self._channels: Dict[str, SaltChannel] = {}

    def register(self, name: str, value: int, family: bool = False) -> int:
        ch = SaltChannel(name, int(value), family)
        if name in self._channels:
            raise ValueError(f"salt channel {name!r} registered twice")
        for other in self._channels.values():
            span = self._overlap(ch, other)
            if span is not None:
                lo, hi = span
                rng_s = f"[{lo}, ∞)" if hi is None else f"[{lo}, {hi})"
                raise ValueError(
                    f"salt channel {name}={value!r} overlaps "
                    f"{other.name}={other.value!r} on {rng_s} — every "
                    f"salt channel must own a disjoint value range")
        self._channels[name] = ch
        return ch.value

    @staticmethod
    def _overlap(a: SaltChannel,
                 b: SaltChannel) -> Optional[Tuple[int, Optional[int]]]:
        """Overlap interval of two channels' owned ranges, or None."""
        if a.family and b.family:
            return (max(a.value, b.value), None)
        if a.family or b.family:
            fam, sc = (a, b) if a.family else (b, a)
            return (sc.value, sc.value + 1) if sc.value >= fam.value else None
        return (a.value, a.value + 1) if a.value == b.value else None

    def channels(self) -> Tuple[SaltChannel, ...]:
        return tuple(self._channels.values())

    def lookup(self, salt: int) -> Optional[SaltChannel]:
        """The channel owning concrete salt value ``salt``, if any."""
        for ch in self._channels.values():
            if ch.covers(int(salt)):
                return ch
        return None

    def names(self) -> Tuple[str, ...]:
        return tuple(self._channels)

    def __getitem__(self, name: str) -> SaltChannel:
        return self._channels[name]

    def __contains__(self, name: str) -> bool:
        return name in self._channels


#: The registry instance — all salt channels in the system, in one place.
SALTS = SaltRegistry()

# Salt channels for decorrelated draws within one hop.  `samplers.py` and
# the kernels import these (never redefine them); the `repro.analysis` RNG
# pass cross-checks every `task_*` call site against this registry.
SALT_COLUMN = SALTS.register("SALT_COLUMN", 0)   # which neighbor column
SALT_ACCEPT = SALTS.register("SALT_ACCEPT", 1)   # alias/rejection accept
SALT_STOP = SALTS.register("SALT_STOP", 2)       # PPR termination draw
# Corpus-consumer channels (`core/corpus_ring.py`): the SGNS batch sampler
# draws (ring row, center position, window offset) and the negative ids
# from the same (seed, qid, hop) fold space a walk task of round 0 uses
# (batch element i at grad step t folds qid=i, hop=t), so its channels
# must be registry-disjoint from every sampler/engine channel — the
# `repro.analysis` rng pass proves it.
SALT_CORPUS = SALTS.register("SALT_CORPUS", 3)       # window draw (row/c/off)
SALT_NEGATIVE = SALTS.register("SALT_NEGATIVE", 4)   # SGNS negative ids
# Reservoir chunk draws: chunk c draws at SALT_CHUNK0 + c, an open-ended
# family (chunk counts are degree-dependent), so it must sit above every
# scalar channel — the registry enforces that at import.
SALT_CHUNK0 = SALTS.register("SALT_CHUNK0", 8, family=True)


# Threefry-2x32 key-schedule parity constant (Salmon et al., SC'11).
_THREEFRY_PARITY = np.uint32(0x1BD11BDA)
_ROTATIONS = ((13, 15, 26, 6), (17, 29, 16, 24))


def threefry2x32(k0, k1, x0, x1):
    """One Threefry-2x32 block: encrypt counter ``(x0, x1)`` under key
    ``(k0, k1)``; returns the two output words.

    Shape-agnostic uint32 math (scalars inside a Pallas kernel, (W,) or
    (W, P) arrays in the jnp path) — bit-equal to the ``threefry2x32``
    primitive ``jax.random`` lowers to, pinned by tests.
    """
    k0 = jnp.asarray(k0, jnp.uint32)
    k1 = jnp.asarray(k1, jnp.uint32)
    x0 = jnp.asarray(x0, jnp.uint32)
    x1 = jnp.asarray(x1, jnp.uint32)
    ks = (k0, k1, k0 ^ k1 ^ _THREEFRY_PARITY)
    x0 = x0 + ks[0]
    x1 = x1 + ks[1]
    for i in range(5):
        for r in _ROTATIONS[i % 2]:
            x0 = x0 + x1
            x1 = (x1 << r) | (x1 >> (32 - r))
            x1 = x0 ^ x1
        x0 = x0 + ks[(i + 1) % 3]
        x1 = x1 + ks[(i + 2) % 3] + np.uint32(i + 1)
    return x0, x1


def fold_in_pair(k0, k1, data):
    """``jax.random.fold_in`` on an explicit (k0, k1) key pair: the data
    word is encrypted as the counter ``(0, data)`` (a 32-bit datum's high
    word is zero), yielding the folded key pair."""
    data = jnp.asarray(data, jnp.uint32)
    return threefry2x32(k0, k1, jnp.zeros_like(data), data)


def task_key_pair(k0, k1, query_id, hop, salt, epoch=None):
    """Per-task key pair from (seed[, epoch], query_id, hop, salt) — the
    fold chain of :func:`task_fold` on explicit uint32 words (usable on
    SMEM scalars inside a kernel).  ``epoch`` 0 / None reproduces the
    legacy 3-tuple derivation bit-exactly."""
    qid = jnp.asarray(query_id, jnp.uint32)
    if epoch is not None:
        e = jnp.asarray(epoch, jnp.int32)
        s0, s1 = fold_in_pair(k0, k1, e.astype(jnp.uint32))
        use_salted = e > 0
        k0 = jnp.where(use_salted, s0, jnp.broadcast_to(
            jnp.asarray(k0, jnp.uint32), s0.shape))
        k1 = jnp.where(use_salted, s1, jnp.broadcast_to(
            jnp.asarray(k1, jnp.uint32), s1.shape))
    k0, k1 = fold_in_pair(k0, k1, qid)
    k0, k1 = fold_in_pair(k0, k1, jnp.asarray(hop, jnp.uint32))
    return fold_in_pair(k0, k1, jnp.asarray(salt, jnp.uint32))


def bits_to_uniform(bits):
    """uint32 random bits -> U[0, 1) float32, exactly as
    ``jax.random.uniform``: keep the top 23 bits as the mantissa of a
    float in [1, 2), subtract 1."""
    f = jax.lax.bitcast_convert_type(
        (jnp.asarray(bits, jnp.uint32) >> np.uint32(9))
        | np.uint32(0x3F800000), jnp.float32)
    return jnp.maximum(f - 1.0, 0.0)


def _counter_pairs(num: int):
    """Counter words for ``num`` 32-bit draws, split exactly as
    ``jax.random``'s ``threefry_2x32`` does (odd sizes pad one zero)."""
    pairs = (num + 1) // 2
    x0 = np.arange(pairs, dtype=np.uint32)
    x1 = np.where(np.arange(pairs) + pairs < num,
                  np.arange(pairs) + pairs, 0).astype(np.uint32)
    return x0, x1


def key_bits(k0, k1, num: int):
    """``num`` uint32 words from a key pair — bit-equal to
    ``jax.random.bits(key, (num,), jnp.uint32)``.  ``k0``/``k1`` may carry
    leading batch dims; the draw axis is appended last."""
    x0, x1 = _counter_pairs(num)
    k0 = jnp.asarray(k0, jnp.uint32)[..., None]
    k1 = jnp.asarray(k1, jnp.uint32)[..., None]
    y0, y1 = threefry2x32(k0, k1, x0[None, :], x1[None, :])
    return jnp.concatenate([y0, y1], axis=-1)[..., :num]


def task_fold(base_key: jax.Array, query_id: jnp.ndarray, hop: jnp.ndarray,
              salt=0, epoch=None) -> jax.Array:
    """Derive one PRNG key per task from (seed[, epoch], query_id, hop, salt).

    ``salt`` decorrelates independent uses within the same hop (sampler
    column draw vs. accept test vs. PPR stop draw vs. reservoir chunk).
    ``epoch`` (per-task, optional) decorrelates successive occupants of a
    reused query slot; epoch 0 (or None) reproduces the legacy 3-tuple
    derivation exactly, so closed-batch walks are unchanged.

    Returns a (W, 2) uint32 key array, bit-equal to the historical
    ``vmap(fold_in∘fold_in∘fold_in)`` derivation.
    """
    base = jnp.asarray(base_key, jnp.uint32)
    salt_b = jnp.broadcast_to(jnp.asarray(salt, jnp.uint32),
                              query_id.shape).astype(jnp.uint32)
    k0, k1 = task_key_pair(base[..., 0], base[..., 1], query_id, hop, salt_b,
                           epoch)
    return jnp.stack([k0, k1], axis=-1)


def stream_key(seed, epoch: int = 0) -> jax.Array:
    """Base key reproducing epoch ``epoch`` of a stream rooted at ``seed``.

    A closed-batch run (``Walker.run``) seeded with ``stream_key(seed, e)``
    samples bit-identical paths to the ``(e, qid)`` occupants of a stream
    rooted at ``seed`` — the reference the streaming soak tests pin.
    Epoch 0 is the root key itself (closed batch == epoch 0).
    """
    base = jax.random.PRNGKey(seed) if jnp.ndim(seed) == 0 else seed
    return base if epoch == 0 else jax.random.fold_in(base, epoch)


def task_uniforms(base_key: jax.Array, query_id: jnp.ndarray, hop: jnp.ndarray,
                  num: int, salt=0, epoch=None) -> jnp.ndarray:
    """(W, num) iid U[0,1) draws, one row per task, derived statelessly."""
    keys = task_fold(base_key, query_id, hop, salt, epoch)
    return bits_to_uniform(key_bits(keys[..., 0], keys[..., 1], num))


def task_bits(base_key: jax.Array, query_id: jnp.ndarray, hop: jnp.ndarray,
              num: int, salt=0, epoch=None) -> jnp.ndarray:
    """(W, num) uint32 random bits per task (for kernels that do their own
    fixed-point arithmetic, mirroring the paper's 64-bit pipeline words)."""
    keys = task_fold(base_key, query_id, hop, salt, epoch)
    return key_bits(keys[..., 0], keys[..., 1], num)
