"""Stateless per-task random number generation (ThundeRiNG analogue, §VII).

The paper pairs each sampling module with ThundeRiNG, an on-chip RNG that
produces decorrelated streams with zero HBM traffic (unlike FastRW, which
pre-generates randoms on the host and burns HBM bandwidth loading them).

On TPU the exact analogue is JAX's counter-based Threefry: the random draw
for a task is a *pure function of the task tuple* ``(seed, query_id, hop)``
— which makes the draw itself stateless, so a task can be executed on any
device, at any time, in any order, and still produce the identical sample.
This is the RNG-side half of the paper's Markov-based stateless
decomposition (§V-A): reordering and re-routing tasks provably cannot
change the sampled walk distribution because the randomness travels with
the task identity, not with the execution site.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def task_fold(base_key: jax.Array, query_id: jnp.ndarray, hop: jnp.ndarray,
              salt=0) -> jax.Array:
    """Derive one PRNG key per task from (seed, query_id, hop, salt).

    ``salt`` decorrelates independent uses within the same hop (sampler
    column draw vs. accept test vs. PPR stop draw vs. reservoir chunk).
    """
    salt = jnp.asarray(salt, jnp.uint32)
    def one(qid, h, s):
        k = jax.random.fold_in(base_key, qid)
        k = jax.random.fold_in(k, h)
        return jax.random.fold_in(k, s)
    salt_b = jnp.broadcast_to(salt, query_id.shape).astype(jnp.uint32)
    return jax.vmap(one)(query_id.astype(jnp.uint32), hop.astype(jnp.uint32), salt_b)


def task_uniforms(base_key: jax.Array, query_id: jnp.ndarray, hop: jnp.ndarray,
                  num: int, salt=0) -> jnp.ndarray:
    """(W, num) iid U[0,1) draws, one row per task, derived statelessly."""
    keys = task_fold(base_key, query_id, hop, salt)
    return jax.vmap(lambda k: jax.random.uniform(k, (num,)))(keys)


def task_bits(base_key: jax.Array, query_id: jnp.ndarray, hop: jnp.ndarray,
              num: int, salt=0) -> jnp.ndarray:
    """(W, num) uint32 random bits per task (for kernels that do their own
    fixed-point arithmetic, mirroring the paper's 64-bit pipeline words)."""
    keys = task_fold(base_key, query_id, hop, salt)
    return jax.vmap(lambda k: jax.random.bits(k, (num,), jnp.uint32))(keys)
