"""Stateless per-task random number generation (ThundeRiNG analogue, §VII).

The paper pairs each sampling module with ThundeRiNG, an on-chip RNG that
produces decorrelated streams with zero HBM traffic (unlike FastRW, which
pre-generates randoms on the host and burns HBM bandwidth loading them).

On TPU the exact analogue is JAX's counter-based Threefry: the random draw
for a task is a *pure function of the task tuple* ``(seed, query_id, hop)``
— which makes the draw itself stateless, so a task can be executed on any
device, at any time, in any order, and still produce the identical sample.
This is the RNG-side half of the paper's Markov-based stateless
decomposition (§V-A): reordering and re-routing tasks provably cannot
change the sampled walk distribution because the randomness travels with
the task identity, not with the execution site.

Open-system slot reuse extends the task identity with an *epoch*: when the
streaming engine reclaims a finished query's buffer slot (ring-buffer
economy), the next occupant of slot ``qid`` carries ``epoch + 1`` and its
draws derive from ``(seed, epoch, qid, hop)``.  Epoch 0 folds nothing
extra, so it is bit-for-bit the classic ``(seed, query_id, hop)``
derivation — a closed-batch run *is* epoch 0 of a stream, and epoch ``e``
of any stream equals a closed-batch run under :func:`stream_key`'s
epoch-salted base key.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def task_fold(base_key: jax.Array, query_id: jnp.ndarray, hop: jnp.ndarray,
              salt=0, epoch=None) -> jax.Array:
    """Derive one PRNG key per task from (seed[, epoch], query_id, hop, salt).

    ``salt`` decorrelates independent uses within the same hop (sampler
    column draw vs. accept test vs. PPR stop draw vs. reservoir chunk).
    ``epoch`` (per-task, optional) decorrelates successive occupants of a
    reused query slot; epoch 0 (or None) reproduces the legacy 3-tuple
    derivation exactly, so closed-batch walks are unchanged.
    """
    salt = jnp.asarray(salt, jnp.uint32)
    salt_b = jnp.broadcast_to(salt, query_id.shape).astype(jnp.uint32)
    if epoch is None:
        def one(qid, h, s):
            k = jax.random.fold_in(base_key, qid)
            k = jax.random.fold_in(k, h)
            return jax.random.fold_in(k, s)
        return jax.vmap(one)(query_id.astype(jnp.uint32),
                             hop.astype(jnp.uint32), salt_b)

    ep = jnp.broadcast_to(jnp.asarray(epoch, jnp.int32), query_id.shape)

    def one(qid, h, s, e):
        # Both branches are computed under vmap; fold_in is cheap and the
        # select keeps epoch 0 identical to the no-epoch derivation.
        salted = jax.random.fold_in(base_key, e.astype(jnp.uint32))
        kb = jnp.where(e > 0, salted, base_key)
        k = jax.random.fold_in(kb, qid)
        k = jax.random.fold_in(k, h)
        return jax.random.fold_in(k, s)

    return jax.vmap(one)(query_id.astype(jnp.uint32), hop.astype(jnp.uint32),
                         salt_b, ep)


def stream_key(seed, epoch: int = 0) -> jax.Array:
    """Base key reproducing epoch ``epoch`` of a stream rooted at ``seed``.

    A closed-batch run (``Walker.run``) seeded with ``stream_key(seed, e)``
    samples bit-identical paths to the ``(e, qid)`` occupants of a stream
    rooted at ``seed`` — the reference the streaming soak tests pin.
    Epoch 0 is the root key itself (closed batch == epoch 0).
    """
    base = jax.random.PRNGKey(seed) if jnp.ndim(seed) == 0 else seed
    return base if epoch == 0 else jax.random.fold_in(base, epoch)


def task_uniforms(base_key: jax.Array, query_id: jnp.ndarray, hop: jnp.ndarray,
                  num: int, salt=0, epoch=None) -> jnp.ndarray:
    """(W, num) iid U[0,1) draws, one row per task, derived statelessly."""
    keys = task_fold(base_key, query_id, hop, salt, epoch)
    return jax.vmap(lambda k: jax.random.uniform(k, (num,)))(keys)


def task_bits(base_key: jax.Array, query_id: jnp.ndarray, hop: jnp.ndarray,
              num: int, salt=0, epoch=None) -> jnp.ndarray:
    """(W, num) uint32 random bits per task (for kernels that do their own
    fixed-point arithmetic, mirroring the paper's 64-bit pipeline words)."""
    keys = task_fold(base_key, query_id, hop, salt, epoch)
    return jax.vmap(lambda k: jax.random.bits(k, (num,), jnp.uint32))(keys)
