"""Sampler definitions and their shared arithmetic (paper §VII, Table I).

:class:`SamplerSpec` is the host-programmable configuration of the
paper's pluggable AXI-Stream sampling module (p, q, α, mode bits).  It no
longer carries per-sampler execution code: a spec *lowers* into a
declarative phase program (`repro.core.phase_program`) — a short
sequence of typed gather/score/draw/commit phases with explicit operand
residency — and every backend (vectorized jnp superstep, fused Pallas
kernel, sharded engine) interprets that one program.

| GRW            | weighted | sampler            |
|----------------|----------|--------------------|
| URW, PPR       | no       | uniform            |
| DeepWalk       | yes      | alias (Walker)     |
| Node2Vec       | no       | rejection          |
| Node2Vec       | yes      | reservoir (E-S)    |
| MetaPath       | either   | typed uniform      |

What remains here is the arithmetic every lowering shares — index
picking, adjacency bisection, the Node2Vec (p, q) bias, the
Efraimidis–Spirakis chunk fold — written once so the backends cannot
drift apart numerically (bit-identity across backends is pinned by
tests).  The helpers are residency-aware: they accept the full
`CSRGraph` *or* a sharded `LocalView` (``num_shards`` maps global vertex
ids to local rows), so the single-device and distributed engines run the
same expressions.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import jax.numpy as jnp

# Salt channels re-exported from the registry in `core/rng.py` (the single
# source of truth — uniqueness is asserted there at import, and the
# `repro.analysis` RNG-collision pass reads the registry as ground truth).
from repro.core.rng import (SALT_ACCEPT, SALT_CHUNK0,  # noqa: F401
                            SALT_COLUMN, SALT_STOP)

# Sampler kinds with a phase-program lowering (`phase_program.lower`).
KINDS = ("uniform", "alias", "rejection_n2v", "reservoir_n2v", "metapath")


@dataclasses.dataclass(frozen=True)
class SamplerSpec:
    """Static configuration of the sampling module (host-programmable
    AXI4-Lite registers in the paper: p, q, α, mode bits).

    Validation happens at construction — a malformed spec (unknown kind,
    empty MetaPath schedule, non-positive Node2Vec parameters) fails
    here with an actionable message instead of deep inside tracing."""

    kind: str = "uniform"   # uniform|alias|rejection_n2v|reservoir_n2v|metapath
    p: float = 1.0          # Node2Vec return parameter
    q: float = 1.0          # Node2Vec in-out parameter
    stop_prob: float = 0.0  # PPR teleport/termination probability α
    rejection_rounds: int = 12
    reservoir_chunk: int = 64
    # Degree-adaptive reservoir scan: bound the E-S chunk loop by the live
    # lanes' actual max degree instead of the graph's max_degree (a pure
    # machine knob — skipped chunks contribute only -inf reservoir keys, so
    # sampled paths are bit-identical either way; the dominant win for
    # weighted Node2Vec on power-law graphs, see fig10 bench).  The default
    # "auto" lets the Walker gate it on measured degree skew at graph-bind
    # time (repro.tune.adaptive_chunk_gate: on balanced graphs the dynamic
    # loop bound buys nothing, so the gate keeps the fixed scan); engines
    # consuming an unresolved "auto" treat it as truthy (the legacy
    # always-adaptive behavior).
    adaptive_chunks: "bool | str" = "auto"
    metapath: Tuple[int, ...] = ()

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(
                f"unknown sampler kind: {self.kind!r} (one of {KINDS})")
        if not isinstance(self.metapath, tuple):
            # Specs must stay hashable (phase-program lowering is cached
            # on the frozen spec) — coerce list-like schedules to tuples.
            object.__setattr__(self, "metapath",
                               tuple(int(t) for t in self.metapath))
        if self.kind == "metapath":
            if not self.metapath:
                raise ValueError(
                    "metapath samplers need a non-empty edge-type schedule "
                    "(pass metapath=(t0, t1, ...) / "
                    "WalkProgram.metapath(schedule=[...]))")
            if any(int(t) < 0 for t in self.metapath):
                raise ValueError(
                    f"metapath schedule entries are edge-type ids and must "
                    f"be non-negative, got {self.metapath}")
        if not 0.0 <= self.stop_prob <= 1.0:
            raise ValueError(
                f"stop_prob must be a probability in [0, 1], got "
                f"{self.stop_prob}")
        if self.second_order and (self.p <= 0 or self.q <= 0):
            raise ValueError(
                f"Node2Vec parameters must be positive, got p={self.p} "
                f"q={self.q}")
        if self.rejection_rounds <= 0:
            raise ValueError(
                f"rejection_rounds must be positive, got "
                f"{self.rejection_rounds}")
        if self.reservoir_chunk <= 0:
            raise ValueError(
                f"reservoir_chunk must be positive, got "
                f"{self.reservoir_chunk}")
        if self.adaptive_chunks not in (True, False, "auto"):
            raise ValueError(
                f"adaptive_chunks must be True, False, or 'auto', got "
                f"{self.adaptive_chunks!r}")

    @property
    def second_order(self) -> bool:
        return self.kind in ("rejection_n2v", "reservoir_n2v")

    @property
    def capability(self) -> str | None:
        """Distributed-execution capability this sampler declares — read
        off the lowered phase program's residency schedule (all-local →
        ``first_order``; a score at owner(v_prev) → ``two_phase``; the
        chunked reservoir loop → ``chunked_reservoir``).  The sharded
        engine dispatches on this to allocate the task word and routing
        schedule."""
        from repro.core.phase_program import lower
        return lower(self).capability


# --------------------------------------------------------------------------
# Shared arithmetic: written once, interpreted by every lowering.
# --------------------------------------------------------------------------


def _col_at(g, e):
    return g.col[jnp.clip(e, 0, g.col.shape[-1] - 1)]


def _uniform_index(deg: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """index = min(floor(u * deg), deg-1); safe for deg == 0."""
    idx = jnp.floor(u * deg.astype(u.dtype)).astype(jnp.int32)
    return jnp.clip(idx, 0, jnp.maximum(deg - 1, 0))


def vertex_row(g, v: jnp.ndarray) -> jnp.ndarray:
    """Map a (global) vertex id to its row in ``g``'s per-vertex arrays
    (``row_ptr`` / ``type_offsets``).  Identity for the full CSRGraph;
    ``v // num_shards`` for a sharded LocalView (vertex v is owned by
    device ``v % N`` and stored at local row ``v // N``).  Negative /
    out-of-range ids clamp to a valid row — callers mask validity."""
    shards = getattr(g, "num_shards", 1)
    rows = g.row_ptr.shape[-1] - 1
    local = v // shards if shards > 1 else v
    return jnp.clip(jnp.where(v >= 0, local, 0), 0, rows - 1)


def edge_exists(g, src: jnp.ndarray, dst: jnp.ndarray) -> jnp.ndarray:
    """Vectorized adjacency test: is dst in src's (sorted) neighbor list?

    Lower-bound bisection with a static iteration count (log2 of max
    segment length).  ``src`` broadcasts against ``dst``'s leading dims.
    Works on the full CSRGraph and on a sharded LocalView (the bisection
    runs over the local copy of src's segment — same values, same
    result), which is what lets the sharded verify/score phases reuse
    the exact single-device bias expression."""
    while src.ndim < dst.ndim:
        src = src[..., None]
    row = vertex_row(g, src)
    lo = jnp.broadcast_to(g.row_ptr[row], dst.shape).astype(jnp.int32)
    hi0 = jnp.broadcast_to(g.row_ptr[row + 1], dst.shape).astype(jnp.int32)
    hi = hi0
    iters = max(1, int(math.ceil(math.log2(max(int(g.max_degree), 2) + 1))))
    for _ in range(iters):
        active = lo < hi
        mid = (lo + hi) // 2
        v = _col_at(g, mid)
        go_right = v < dst
        lo = jnp.where(active & go_right, mid + 1, lo)
        hi = jnp.where(active & ~go_right, mid, hi)
    found = (lo < hi0) & (_col_at(g, lo) == dst)
    valid_src = jnp.broadcast_to(src >= 0, dst.shape)
    return found & valid_src


def n2v_bias(spec, g, v_prev, y):
    """Node2Vec bias: 1/p if returning, 1 if y ∈ N(v_prev), 1/q otherwise.
    Hop 0 (v_prev < 0) → unbiased (weight 1)."""
    inv_p = 1.0 / spec.p
    inv_q = 1.0 / spec.q
    vp = v_prev if y.ndim == v_prev.ndim else v_prev[..., None]
    is_ret = y == vp
    common = edge_exists(g, v_prev, y)
    w = jnp.where(is_ret, inv_p, jnp.where(common, 1.0, inv_q))
    no_hist = jnp.broadcast_to(vp < 0, y.shape)
    return jnp.where(no_hist, 1.0, w)


def rejection_choose(spec, u_acc: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Bounded-round rejection reduction: accept round j iff
    ``u_acc[j] · w_max <= w[j]``; the last round is forced (bounded
    fallback) and the first accepted round wins.  Returns the winning
    round index per lane — shared by the jnp lowering and the sharded
    propose/verify phases so accepts cannot drift."""
    w_max = max(1.0 / spec.p, 1.0, 1.0 / spec.q)
    accept = (u_acc * w_max <= w).at[:, -1].set(True)
    return jnp.argmax(accept, axis=1)


def es_chunk_score(u, valid, w):
    """Efraimidis–Spirakis chunk scoring: key = u^(1/w), monotone in
    log(u)/w (stabler) — returns the within-chunk (argmax, max).

    Shared verbatim by the local reservoir scan and the sharded
    engine's chunk-score phase so the two are bit-identical: both feed the
    same (u, valid, w) and the same float ops produce the same key.
    """
    key = jnp.where(valid & (w > 0), jnp.log(u + 1e-20) / w, -jnp.inf)
    c_best = jnp.argmax(key, axis=1)
    c_key = jnp.take_along_axis(key, c_best[:, None], 1)[:, 0]
    return c_best, c_key


def es_merge(best_key, best_idx, chunk_index, chunk_size, c_best, c_key):
    """Fold one chunk's (argmax, max) into the running reservoir maximum.
    Strict > keeps the earliest chunk on ties — shared by both engines."""
    take = c_key > best_key
    best_idx = jnp.where(take,
                         chunk_index * chunk_size + c_best.astype(jnp.int32),
                         best_idx)
    best_key = jnp.maximum(best_key, c_key)
    return best_key, best_idx


def es_num_chunks(max_degree: int, chunk: int) -> int:
    return max(1, -(-int(max_degree) // chunk))
