"""Application-specific sampling modules (paper §VII, Table I).

Each sampler is a pure function of the stateless task tuple and the graph —
the TPU analogue of the paper's pluggable AXI-Stream sampling module.  All
samplers return ``(index, ok)`` where ``index`` is the chosen offset into
the current vertex's neighbor list and ``ok`` marks lanes whose vertex has a
valid continuation (``ok=False`` → early termination, e.g. MetaPath with no
type-matching neighbor).

| GRW            | weighted | sampler            |
|----------------|----------|--------------------|
| URW, PPR       | no       | uniform            |
| DeepWalk       | yes      | alias (Walker)     |
| Node2Vec       | no       | rejection          |
| Node2Vec       | yes      | reservoir (E-S)    |
| MetaPath       | either   | typed uniform      |
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import rng as task_rng

# Salt channels for decorrelated draws within one hop.
SALT_COLUMN = 0      # which neighbor column
SALT_ACCEPT = 1      # alias / rejection accept test
SALT_STOP = 2        # PPR termination draw (used by the engine)
SALT_CHUNK0 = 8      # reservoir chunk draws start here


@dataclasses.dataclass(frozen=True)
class SamplerSpec:
    """Static configuration of the sampling module (host-programmable
    AXI4-Lite registers in the paper: p, q, α, mode bits)."""

    kind: str = "uniform"   # uniform|alias|rejection_n2v|reservoir_n2v|metapath
    p: float = 1.0          # Node2Vec return parameter
    q: float = 1.0          # Node2Vec in-out parameter
    stop_prob: float = 0.0  # PPR teleport/termination probability α
    rejection_rounds: int = 12
    reservoir_chunk: int = 64
    # Degree-adaptive reservoir scan: bound the E-S chunk loop by the live
    # lanes' actual max degree instead of the graph's max_degree (a pure
    # machine knob — skipped chunks contribute only -inf reservoir keys, so
    # sampled paths are bit-identical either way; the dominant win for
    # weighted Node2Vec on power-law graphs, see fig10 bench).
    adaptive_chunks: bool = True
    metapath: Tuple[int, ...] = ()

    @property
    def second_order(self) -> bool:
        return self.kind in ("rejection_n2v", "reservoir_n2v")

    @property
    def capability(self) -> str | None:
        """Distributed-execution capability this sampler declares — the
        dispatch key the sharded engine uses to allocate the task word and
        routing schedule (first- and second-order walks share one routing
        path; second-order kinds declare the extra slot state they carry).

        ``first_order``: the whole hop reads one vertex's data — route to
        owner(v_curr), WalkerSlots task word.
        ``two_phase_n2v``: propose at owner(v_curr), verify at
        owner(v_prev) — N2VSlots with a phase bit + candidate payload.
        ``chunked_reservoir_n2v``: O(deg) weighted scan ping-pongs chunks
        between owner(v_curr) and owner(v_prev) — ReservoirSlots.
        ``None``: not distributable yet (metapath: typed sub-segments are
        not partitioned).
        """
        return _DIST_CAPABILITIES[self.kind]


def _col_at(g, e):
    return g.col[jnp.clip(e, 0, g.col.shape[-1] - 1)]


def _uniform_index(deg: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """index = min(floor(u * deg), deg-1); safe for deg == 0."""
    idx = jnp.floor(u * deg.astype(u.dtype)).astype(jnp.int32)
    return jnp.clip(idx, 0, jnp.maximum(deg - 1, 0))


def edge_exists(g, src: jnp.ndarray, dst: jnp.ndarray) -> jnp.ndarray:
    """Vectorized adjacency test: is dst in src's (sorted) neighbor list?

    Lower-bound bisection with a static iteration count (log2 of max
    segment length).  ``src`` broadcasts against ``dst``'s leading dims.
    """
    nv = g.row_ptr.shape[-1] - 1
    while src.ndim < dst.ndim:
        src = src[..., None]
    src_safe = jnp.clip(src, 0, nv - 1)
    lo = jnp.broadcast_to(g.row_ptr[src_safe], dst.shape).astype(jnp.int32)
    hi0 = jnp.broadcast_to(g.row_ptr[src_safe + 1], dst.shape).astype(jnp.int32)
    hi = hi0
    iters = max(1, int(math.ceil(math.log2(max(int(g.max_degree), 2) + 1))))
    for _ in range(iters):
        active = lo < hi
        mid = (lo + hi) // 2
        v = _col_at(g, mid)
        go_right = v < dst
        lo = jnp.where(active & go_right, mid + 1, lo)
        hi = jnp.where(active & ~go_right, mid, hi)
    found = (lo < hi0) & (_col_at(g, lo) == dst)
    valid_src = jnp.broadcast_to(src >= 0, dst.shape)
    return found & valid_src


def sample_uniform(spec, g, addr, deg, slots, base_key):
    u = task_rng.task_uniforms(base_key, slots.query_id, slots.hop, 1,
                               SALT_COLUMN, epoch=slots.epoch)[:, 0]
    return _uniform_index(deg, u), deg > 0


def sample_alias(spec, g, addr, deg, slots, base_key):
    """Walker alias sampling: O(1) per draw, two uniforms, two gathers."""
    u = task_rng.task_uniforms(base_key, slots.query_id, slots.hop, 2,
                               SALT_COLUMN, epoch=slots.epoch)
    k = _uniform_index(deg, u[:, 0])
    e = jnp.clip(addr + k, 0, g.col.shape[-1] - 1)
    accept = u[:, 1] < g.alias_prob[e]
    idx = jnp.where(accept, k, g.alias_idx[e])
    return jnp.clip(idx, 0, jnp.maximum(deg - 1, 0)), deg > 0


def _n2v_bias(spec, g, v_prev, y):
    """Node2Vec bias: 1/p if returning, 1 if y ∈ N(v_prev), 1/q otherwise.
    Hop 0 (v_prev < 0) → unbiased (weight 1)."""
    inv_p = 1.0 / spec.p
    inv_q = 1.0 / spec.q
    vp = v_prev if y.ndim == v_prev.ndim else v_prev[..., None]
    is_ret = y == vp
    common = edge_exists(g, v_prev, y)
    w = jnp.where(is_ret, inv_p, jnp.where(common, 1.0, inv_q))
    no_hist = jnp.broadcast_to(vp < 0, y.shape)
    return jnp.where(no_hist, 1.0, w)


def sample_rejection_n2v(spec, g, addr, deg, slots, base_key):
    """Bounded-round rejection sampling for unweighted Node2Vec (gSampler /
    KnightKing style).  K proposal rounds; first accept wins; if all rounds
    reject, the last proposal is taken (geometric tail bias < (1-a_min)^K,
    measured in tests).  Each round = 2 uniforms + 1 column gather + one
    O(log d) adjacency bisection."""
    K = spec.rejection_rounds
    w_max = max(1.0 / spec.p, 1.0, 1.0 / spec.q)
    u = task_rng.task_uniforms(base_key, slots.query_id, slots.hop, 2 * K,
                               SALT_COLUMN, epoch=slots.epoch)
    u_col = u[:, :K]
    u_acc = u[:, K:]
    props = _uniform_index(deg[:, None], u_col)              # (W, K)
    y = _col_at(g, addr[:, None] + props)                    # (W, K)
    w = _n2v_bias(spec, g, slots.v_prev, y)                  # (W, K)
    accept = u_acc * w_max <= w                              # (W, K)
    accept = accept.at[:, K - 1].set(True)                   # bounded fallback
    first = jnp.argmax(accept, axis=1)
    idx = jnp.take_along_axis(props, first[:, None], axis=1)[:, 0]
    return idx, deg > 0


def es_chunk_score(u, valid, w):
    """Efraimidis–Spirakis chunk scoring: key = u^(1/w), monotone in
    log(u)/w (stabler) — returns the within-chunk (argmax, max).

    Shared verbatim by the single-device reservoir sampler and the sharded
    engine's chunk-score phase so the two are bit-identical: both feed the
    same (u, valid, w) and the same float ops produce the same key.
    """
    key = jnp.where(valid & (w > 0), jnp.log(u + 1e-20) / w, -jnp.inf)
    c_best = jnp.argmax(key, axis=1)
    c_key = jnp.take_along_axis(key, c_best[:, None], 1)[:, 0]
    return c_best, c_key


def es_merge(best_key, best_idx, chunk_index, chunk_size, c_best, c_key):
    """Fold one chunk's (argmax, max) into the running reservoir maximum.
    Strict > keeps the earliest chunk on ties — shared by both engines."""
    take = c_key > best_key
    best_idx = jnp.where(take,
                         chunk_index * chunk_size + c_best.astype(jnp.int32),
                         best_idx)
    best_key = jnp.maximum(best_key, c_key)
    return best_key, best_idx


def es_num_chunks(max_degree: int, chunk: int) -> int:
    return max(1, -(-int(max_degree) // chunk))


def sample_reservoir_n2v(spec, g, addr, deg, slots, base_key):
    """Weighted Node2Vec via Efraimidis–Spirakis weighted reservoir
    (LightRW's method): scan the full neighbor list in chunks, key =
    u^(1/w'), keep the max.  O(deg) work per hop — inherent to exact
    weighted 2nd-order sampling; chunked so the working set stays in VMEM.

    Degree-adaptive scan (``spec.adaptive_chunks``): the chunk loop runs a
    dynamic ``ceil(max(live deg)/chunk)`` trip count instead of the static
    ``ceil(max_degree/chunk)``.  Every chunk past a lane's own degree
    contributes only -inf reservoir keys (all candidates masked invalid),
    so truncating the loop at the live lanes' max degree cannot change any
    lane's scanned argmax — paths are bit-identical, only the wasted
    supersteps of the power-law tail disappear."""
    CH = spec.reservoir_chunk
    n_chunks = es_num_chunks(g.max_degree, CH)
    W = addr.shape[0]
    weights = g.weights if g.weights is not None else None

    def chunk_body(c, carry):
        best_key, best_idx = carry
        u = task_rng.task_uniforms(base_key, slots.query_id, slots.hop, CH,
                                   SALT_CHUNK0 + c, epoch=slots.epoch)
        pos = c * CH + jnp.arange(CH, dtype=jnp.int32)[None, :]  # (1, CH)
        valid = pos < deg[:, None]
        e = jnp.clip(addr[:, None] + pos, 0, g.col.shape[-1] - 1)
        y = g.col[e]
        w = weights[e] if weights is not None else jnp.ones_like(u)
        w = w * _n2v_bias(spec, g, slots.v_prev, y)
        c_best, c_key = es_chunk_score(u, valid, w)
        return es_merge(best_key, best_idx, c, CH, c_best, c_key)

    init = (jnp.full((W,), -jnp.inf), jnp.zeros((W,), jnp.int32))
    if spec.adaptive_chunks:
        live_deg = jnp.max(jnp.where(slots.active, deg, 0))
        hi = jnp.clip((live_deg + CH - 1) // CH, 1, n_chunks)
    else:
        hi = n_chunks
    _, best_idx = jax.lax.fori_loop(0, hi, chunk_body, init)
    return jnp.clip(best_idx, 0, jnp.maximum(deg - 1, 0)), deg > 0


def sample_metapath(spec, g, addr, deg, slots, base_key):
    """Typed uniform sampling: hop t draws uniformly from the sub-segment of
    neighbors with edge type schedule[t mod |schedule|]; no such neighbor →
    early termination (paper §VIII-B, MetaPath's higher early-termination
    rate is what stresses the zero-bubble scheduler)."""
    assert g.type_offsets is not None, "MetaPath needs a typed graph"
    sched = jnp.asarray(spec.metapath, jnp.int32)
    t = sched[slots.hop % len(spec.metapath)]
    nv = g.type_offsets.shape[0]
    v_safe = jnp.clip(slots.v_curr, 0, nv - 1)
    base = g.type_offsets[v_safe, t]
    cnt = g.type_offsets[v_safe, t + 1] - base
    u = task_rng.task_uniforms(base_key, slots.query_id, slots.hop, 1,
                               SALT_COLUMN, epoch=slots.epoch)[:, 0]
    idx = base + _uniform_index(cnt, u)
    return idx, (cnt > 0) & (deg > 0)


_SAMPLERS = {
    "uniform": sample_uniform,
    "alias": sample_alias,
    "rejection_n2v": sample_rejection_n2v,
    "reservoir_n2v": sample_reservoir_n2v,
    "metapath": sample_metapath,
}

# Distributed capability each sampler kind declares (see
# SamplerSpec.capability).  The sharded engine dispatches on this to pick
# the task word + per-phase routing schedule — one routing path for all.
_DIST_CAPABILITIES = {
    "uniform": "first_order",
    "alias": "first_order",
    "rejection_n2v": "two_phase_n2v",
    "reservoir_n2v": "chunked_reservoir_n2v",
    "metapath": None,
}


def get_sampler(spec: SamplerSpec):
    try:
        fn = _SAMPLERS[spec.kind]
    except KeyError:
        raise ValueError(f"unknown sampler kind: {spec.kind!r}") from None
    return partial(fn, spec)
