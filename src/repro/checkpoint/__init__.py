from repro.checkpoint import checkpointer
