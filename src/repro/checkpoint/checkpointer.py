"""Sharded checkpointing with atomic commit, async write, and elastic
restore (re-shard to a different device count / mesh on load).

Format: one directory per step —
  step_000123.tmp/ -> (atomic rename) -> step_000123/
    manifest.json   — pytree structure, shapes, dtypes
    arr_<k>.npy     — one file per leaf (host-gathered)

Restore never requires the original mesh: leaves are loaded host-side and
``jax.device_put`` re-shards to whatever sharding the caller provides —
this is the elastic-scaling path (pod loss -> restart at fewer devices).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save(ckpt_dir: str, step: int, tree: Any, blocking: bool = True):
    """Write a checkpoint. Atomic: readers never see partial state."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    paths, leaves, _ = _flatten_with_paths(tree)

    def _write():
        manifest = {"step": step, "leaves": []}
        for i, (p, leaf) in enumerate(zip(paths, leaves)):
            arr = np.asarray(jax.device_get(leaf))
            dtype = str(arr.dtype)
            if arr.dtype.kind not in "biufc":
                # ml_dtypes (bf16/fp8) aren't numpy-native: store as f32
                # (exact for bf16/fp8) and cast back on restore.
                arr = arr.astype(np.float32)
            np.save(os.path.join(tmp, f"arr_{i}.npy"), arr)
            manifest["leaves"].append(
                {"path": p, "file": f"arr_{i}.npy",
                 "shape": list(arr.shape), "dtype": dtype})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit

    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Any, shardings: Any = None):
    """Load into the structure of ``like``; re-shard with ``shardings``
    (a matching pytree of Sharding or None for host arrays)."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    _, leaves, treedef = _flatten_with_paths(like)
    assert len(leaves) == len(manifest["leaves"]), "structure mismatch"
    loaded = []
    for m, ref in zip(manifest["leaves"], leaves):
        arr = np.load(os.path.join(d, m["file"]))
        if str(arr.dtype) != m["dtype"]:
            arr = arr.astype(np.asarray(jax.device_get(ref)).dtype)
        loaded.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, loaded)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s is not None else x,
            tree, shardings)
    return tree
