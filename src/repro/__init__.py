"""repro: RidgeWalker (perfectly pipelined graph random walks) as a
multi-pod JAX framework — walk engine, model zoo, kernels, launchers."""
__version__ = "0.1.0"
