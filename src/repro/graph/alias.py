"""Walker alias-table construction (paper §VII, Table I: DeepWalk on weighted
graphs uses alias sampling; ``RP_entry`` is extended to point at the table).

Built host-side (numpy) as a preprocessing step, exactly as the paper builds
tables before loading the graph to HBM.  Sampling itself (O(1): one uniform
draw for the column, one for the accept test) lives in ``core/samplers.py``.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.graph.csr import CSRGraph


def _vose(prob_seg: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Vose's alias construction for one neighbor list. O(d)."""
    d = prob_seg.size
    scaled = prob_seg * d / prob_seg.sum()
    prob = np.ones(d, dtype=np.float32)
    alias = np.arange(d, dtype=np.int32)
    small = [i for i in range(d) if scaled[i] < 1.0]
    large = [i for i in range(d) if scaled[i] >= 1.0]
    scaled = scaled.astype(np.float64)
    while small and large:
        s = small.pop()
        l = large.pop()
        prob[s] = scaled[s]
        alias[s] = l
        scaled[l] = scaled[l] + scaled[s] - 1.0
        (small if scaled[l] < 1.0 else large).append(l)
    for i in large:
        prob[i] = 1.0
    for i in small:  # numerical leftovers
        prob[i] = 1.0
    return prob, alias


def build_alias_tables(g: CSRGraph) -> CSRGraph:
    """Attach per-neighbor-list alias tables to a weighted CSR graph.

    For unweighted graphs alias sampling degenerates to uniform; we still
    build (prob=1, alias=i) tables so DeepWalk code paths are uniform.
    """
    rp = np.asarray(g.row_ptr)
    E = g.num_edges
    prob = np.ones(E, dtype=np.float32)
    alias = np.zeros(E, dtype=np.int32)
    if g.weights is not None:
        w = np.asarray(g.weights, dtype=np.float64)
        for v in range(g.num_vertices):
            s, e = int(rp[v]), int(rp[v + 1])
            if e - s <= 1:
                if e - s == 1:
                    prob[s], alias[s] = 1.0, 0
                continue
            p, a = _vose(w[s:e])
            prob[s:e] = p
            alias[s:e] = a
    else:
        # Uniform: identity alias table, vectorized.
        deg = np.diff(rp)
        alias = (np.arange(E, dtype=np.int64) - np.repeat(rp[:-1], deg)).astype(np.int32)
    import dataclasses
    return dataclasses.replace(g, alias_prob=jnp.asarray(prob),
                               alias_idx=jnp.asarray(alias))


def alias_sample_reference(prob: np.ndarray, alias: np.ndarray,
                           u1: np.ndarray, u2: np.ndarray, deg: int) -> np.ndarray:
    """Numpy oracle for alias sampling used in tests."""
    k = np.minimum((u1 * deg).astype(np.int64), deg - 1)
    return np.where(u2 < prob[k], k, alias[k])
