"""Fanout neighbor sampler for GNN minibatch training (``minibatch_lg``).

GraphSAGE-style k-hop sampling with replacement, built on the same
stateless-sampling substrate as the walk engine: the sample for (node,
hop, slot) is a pure function of (seed, node, hop, slot), so sampling is
deterministic, restartable, and shardable — one-hop fanout sampling *is*
a width-``fanout`` bundle of one-step random walks (DESIGN.md §4).

Produces fixed-shape padded blocks: per layer an edge list
(2, n_src·fanout) where sampled duplicates are real (with-replacement
semantics, standard GraphSAGE) and zero-degree sources self-loop.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import rng as task_rng
from repro.graph.csr import CSRGraph, row_access


class SampledBlock(NamedTuple):
    """One message-passing layer's sampled bipartite block."""
    edge_index: jnp.ndarray   # (2, E) [src_global, dst_global]
    num_src: int
    num_dst: int


def sample_neighbors(graph: CSRGraph, nodes: jnp.ndarray, fanout: int,
                     base_key, hop: int) -> jnp.ndarray:
    """(n,) nodes -> (n, fanout) sampled neighbor ids (self-loop if deg=0)."""
    addr, deg = row_access(graph, nodes)
    u = task_rng.task_uniforms(base_key, nodes, jnp.full_like(nodes, hop),
                               fanout, salt=3)
    idx = jnp.minimum((u * deg[:, None]).astype(jnp.int32),
                      jnp.maximum(deg - 1, 0)[:, None])
    e = jnp.clip(addr[:, None] + idx, 0, max(graph.num_edges - 1, 0))
    nbrs = graph.col[e]
    return jnp.where(deg[:, None] > 0, nbrs, nodes[:, None])


def sample_blocks(graph: CSRGraph, seeds: jnp.ndarray,
                  fanouts: Sequence[int], seed: int = 0
                  ) -> Tuple[list, jnp.ndarray]:
    """k-hop fanout sampling. Returns (blocks outer-to-inner, all_nodes).

    blocks[i].edge_index holds (neighbor -> frontier) edges for hop i;
    message passing runs inner-to-outer (reverse order).
    """
    base_key = jax.random.PRNGKey(seed)
    frontier = jnp.asarray(seeds, jnp.int32)
    blocks = []
    all_nodes = [frontier]
    for h, f in enumerate(fanouts):
        nbrs = sample_neighbors(graph, frontier, f, base_key, h)  # (n, f)
        src = nbrs.reshape(-1)
        dst = jnp.repeat(frontier, f)
        blocks.append(SampledBlock(
            edge_index=jnp.stack([src, dst]),
            num_src=int(src.shape[0]),
            num_dst=int(frontier.shape[0])))
        frontier = src
        all_nodes.append(frontier)
    return blocks, jnp.concatenate(all_nodes)


def block_union_graph(blocks) -> jnp.ndarray:
    """Concatenate all block edges into one (2, ΣE) edge list (the padded
    union graph the dry-run cells lower)."""
    return jnp.concatenate([b.edge_index for b in blocks], axis=1)
