"""Dataset registry: synthetic stand-ins for the paper's graphs (Table II).

The container is offline, so the six real-world graphs (WG/CP/AS/LJ/AB/UK)
are replaced by *statistically matched* synthetic graphs: same category of
degree skew (RMAT Graph500 initiator for web/social skew), matched average
degree, and a configurable ``scale`` knob so CPU benchmarks stay tractable
while the full-size specs remain available for dry-run shape analysis.

``δ``-like early-termination structure (dangling vertices) is preserved:
directed RMAT graphs naturally have zero-out-degree vertices, which drive
the imbalanced-termination behavior the paper's scheduler targets (§III-B).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.graph.alias import build_alias_tables
from repro.graph.csr import CSRGraph, build_csr
from repro.graph.generators import GRAPH500, rmat_edges


@dataclass(frozen=True)
class GraphSpec:
    name: str
    num_vertices: int          # full-size |V| (paper Table II)
    num_edges: int             # full-size |E|
    category: str
    # Synthetic stand-in parameters (scaled):
    rmat_scale: int
    rmat_edge_factor: int
    initiator: tuple = GRAPH500
    undirected: bool = False


# Full-size numbers follow paper Table II; rmat_scale/edge_factor give the
# CPU-sized stand-in used by tests/benchmarks (2^scale vertices).
DATASET_SPECS = {
    "WG": GraphSpec("web-Google", 916_428, 5_105_039, "web", 14, 6),
    "CP": GraphSpec("cit-Patents", 3_774_768, 16_518_948, "citation", 15, 4),
    "AS": GraphSpec("as-Skitter", 1_696_415, 22_190_596, "network", 14, 13,
                    undirected=True),
    "LJ": GraphSpec("soc-LiveJournal", 4_847_571, 68_993_773, "social", 15, 14,
                    undirected=True),
    "AB": GraphSpec("arabic-2005", 22_744_080, 639_999_458, "web", 16, 28),
    "UK": GraphSpec("uk-2005", 39_459_925, 936_364_282, "web", 16, 24),
}


def make_dataset(
    name: str,
    weighted: bool = False,
    with_alias: bool = False,
    num_edge_types: int = 0,
    seed: int = 0,
    scale_override: Optional[int] = None,
) -> CSRGraph:
    """Build the synthetic stand-in CSR graph for a paper dataset key."""
    spec = DATASET_SPECS[name]
    scale = spec.rmat_scale if scale_override is None else scale_override
    edges, n = rmat_edges(scale, spec.rmat_edge_factor, spec.initiator,
                          seed=seed, undirected=spec.undirected)
    rng = np.random.default_rng(seed + 1)
    weights = None
    if weighted:
        # ThunderRW-style weights (paper §VIII-A4): uniform (0, 1].
        weights = rng.random(edges.shape[0]).astype(np.float32) + 1e-3
    edge_types = None
    if num_edge_types > 0:
        edge_types = rng.integers(0, num_edge_types, size=edges.shape[0]).astype(np.int32)
    g = build_csr(edges, n, weights=weights, edge_types=edge_types,
                  num_edge_types=num_edge_types)
    if with_alias:
        g = build_alias_tables(g)
    return g


def make_cora_like(seed: int = 0) -> tuple[CSRGraph, np.ndarray, np.ndarray]:
    """Cora-shaped citation graph for GNN ``full_graph_sm``: 2708 nodes,
    10556 directed edges, 1433-dim features, 7 classes."""
    n, e, d, c = 2708, 10556, 1433, 7
    rng = np.random.default_rng(seed)
    edges = np.stack([rng.integers(0, n, e), rng.integers(0, n, e)], axis=1)
    g = build_csr(edges, n)
    feats = (rng.random((n, d)) < 0.01).astype(np.float32)  # sparse bag-of-words
    labels = rng.integers(0, c, n).astype(np.int32)
    return g, feats, labels
