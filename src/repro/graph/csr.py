"""Compressed Sparse Row graph representation (paper §II-A).

The CSR graph is the memory layout the paper's Row Access / Column Access
stages read: ``row_ptr[v]`` gives the offset of v's neighbor list in ``col``
and ``row_ptr[v+1]-row_ptr[v]`` its degree (an O(1) "RP_entry" lookup).

All arrays are JAX arrays so the graph is a pytree and can be donated /
sharded.  Optional per-edge payloads (weights, alias tables, edge types)
extend the layout exactly the way the paper extends ``RP_entry``/``CL`` for
weighted walks (§VII, Table I).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@partial(jax.tree_util.register_dataclass,
         data_fields=["row_ptr", "col", "weights", "alias_prob", "alias_idx",
                      "edge_type", "type_offsets"],
         meta_fields=["num_vertices", "num_edges", "max_degree",
                      "num_edge_types"])
@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """Padded CSR graph.

    Attributes:
      row_ptr:  (V+1,) int32 — neighbor-list offsets into ``col``.
      col:      (E,)   int32 — neighbor vertex ids (global).
      weights:  (E,)   float32 or None — edge weights (weighted walks).
      alias_prob: (E,) float32 or None — Walker alias-table accept prob.
      alias_idx:  (E,) int32  or None — Walker alias-table alias index.
      edge_type:  (E,) int32  or None — edge type id (MetaPath walks).
      type_offsets: (V, T+1) int32 or None — per-vertex sub-segment offsets
        into the (type-sorted) neighbor list; MetaPath samples uniformly
        within ``[row_ptr[v]+type_offsets[v,t], row_ptr[v]+type_offsets[v,t+1])``.
      num_vertices / num_edges / max_degree: static ints (aux data).
    """

    row_ptr: jnp.ndarray
    col: jnp.ndarray
    weights: Optional[jnp.ndarray] = None
    alias_prob: Optional[jnp.ndarray] = None
    alias_idx: Optional[jnp.ndarray] = None
    edge_type: Optional[jnp.ndarray] = None
    type_offsets: Optional[jnp.ndarray] = None
    num_vertices: int = 0
    num_edges: int = 0
    max_degree: int = 0
    num_edge_types: int = 0

    @property
    def weighted(self) -> bool:
        return self.weights is not None

    @property
    def has_alias(self) -> bool:
        return self.alias_prob is not None

    @property
    def typed(self) -> bool:
        return self.edge_type is not None


def build_csr(
    edges: np.ndarray,
    num_vertices: int,
    weights: Optional[np.ndarray] = None,
    edge_types: Optional[np.ndarray] = None,
    num_edge_types: int = 0,
    dedup: bool = True,
    sort_neighbors: bool = True,
) -> CSRGraph:
    """Build a CSRGraph from an (E, 2) int edge array (src, dst).

    Neighbor lists are sorted by (edge_type, dst) so that (a) MetaPath
    sub-segments are contiguous and (b) rejection sampling for Node2Vec can
    binary-search adjacency.
    """
    edges = np.asarray(edges, dtype=np.int64)
    if edges.size == 0:
        edges = edges.reshape(0, 2)
    src, dst = edges[:, 0], edges[:, 1]
    if weights is None:
        w = None
    else:
        w = np.asarray(weights, dtype=np.float32)
    et = None if edge_types is None else np.asarray(edge_types, dtype=np.int32)

    if dedup and edges.shape[0] > 0:
        key = src * num_vertices + dst
        if et is not None:
            key = key * max(num_edge_types, 1) + et
        _, keep = np.unique(key, return_index=True)
        src, dst = src[keep], dst[keep]
        if w is not None:
            w = w[keep]
        if et is not None:
            et = et[keep]

    # Sort edges by (src, type, dst) for contiguous, ordered neighbor lists.
    if sort_neighbors and src.size:
        t = et if et is not None else np.zeros_like(src)
        order = np.lexsort((dst, t, src))
        src, dst = src[order], dst[order]
        if w is not None:
            w = w[order]
        if et is not None:
            et = et[order]

    deg = np.bincount(src, minlength=num_vertices).astype(np.int64)
    row_ptr = np.zeros(num_vertices + 1, dtype=np.int64)
    np.cumsum(deg, out=row_ptr[1:])

    type_offsets = None
    if et is not None and num_edge_types > 0:
        # Per-vertex, per-type counts -> prefix offsets within each segment.
        counts = np.zeros((num_vertices, num_edge_types), dtype=np.int64)
        np.add.at(counts, (src, et), 1)
        type_offsets = np.zeros((num_vertices, num_edge_types + 1), dtype=np.int32)
        np.cumsum(counts, axis=1, out=type_offsets[:, 1:])

    max_degree = int(deg.max()) if deg.size else 0
    g = CSRGraph(  # noqa: call matches registered dataclass fields
        alias_prob=None,
        alias_idx=None,
        row_ptr=jnp.asarray(row_ptr, dtype=jnp.int32),
        col=jnp.asarray(dst, dtype=jnp.int32),
        weights=None if w is None else jnp.asarray(w),
        edge_type=None if et is None else jnp.asarray(et),
        type_offsets=None if type_offsets is None else jnp.asarray(type_offsets),
        num_vertices=int(num_vertices),
        num_edges=int(src.size),
        max_degree=max_degree,
        num_edge_types=int(num_edge_types),
    )
    return g


def degrees(g: CSRGraph) -> jnp.ndarray:
    return g.row_ptr[1:] - g.row_ptr[:-1]


def row_access(g: CSRGraph, v: jnp.ndarray):
    """Paper Alg II.1 line 5: {addr, deg} = row_access(v).

    Out-of-range v (inactive slot sentinel) maps to degree 0.
    """
    v_safe = jnp.clip(v, 0, g.num_vertices - 1)
    addr = g.row_ptr[v_safe]
    deg = g.row_ptr[v_safe + 1] - addr
    deg = jnp.where((v >= 0) & (v < g.num_vertices), deg, 0)
    return addr, deg


def column_access(g: CSRGraph, addr: jnp.ndarray, index: jnp.ndarray) -> jnp.ndarray:
    """Paper Alg II.1 line 7: v_next = col[addr + index] (clipped gather)."""
    e = jnp.clip(addr + index, 0, max(g.num_edges - 1, 0))
    return g.col[e]


def validate_csr(g: CSRGraph) -> None:
    rp = np.asarray(g.row_ptr)
    col = np.asarray(g.col)
    assert rp.shape == (g.num_vertices + 1,)
    assert rp[0] == 0 and rp[-1] == g.num_edges
    assert np.all(np.diff(rp) >= 0), "row_ptr must be monotone"
    if g.num_edges:
        assert col.min() >= 0 and col.max() < g.num_vertices
    if g.typed and g.type_offsets is not None:
        to = np.asarray(g.type_offsets)
        deg = np.diff(rp)
        assert np.all(to[:, -1] == deg), "type offsets must cover each segment"
