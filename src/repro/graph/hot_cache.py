"""Hot-vertex adjacency cache: the VMEM tier of the gather hierarchy.

Power-law graphs concentrate most gather traffic on a handful of hub
vertices (a walking lane occupies a vertex with probability proportional
to its degree, so hubs are over-represented *quadratically*: once in the
stationary distribution and once in payload size).  LightRW and the
memory-access-pattern studies of graph accelerators (see PAPERS.md) both
exploit this with a small on-chip adjacency cache; this module is the
host-side builder for ours.

:func:`build_hot_cache` packs the top-``H`` highest-degree vertices'
adjacency payloads — columns, plus whatever per-kind payloads the phase
program declares via ``PhaseProgram.cache_payloads`` (edge weights,
alias tables, typed sub-segment offsets) — into one contiguous block
with an id → slot lookup (binary search over the sorted hot-id list).
``H`` is sized from a byte budget, greedily admitting vertices in
descending-degree order (ties broken toward the smaller vertex id, so
the cache contents are a deterministic function of (graph, payloads,
budget)).

The packed arrays are *verbatim copies* of the graph's own CSR slices:
``col[hot_off[slot] + j] == graph.col[row_ptr[v] + j]`` for every hot
vertex ``v`` and offset ``j < deg(v)``.  That is the whole bit-identity
argument of the cached fused superstep — a hit reads the same bytes from
a different memory tier, so no sampled walk can change.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

import numpy as np

__all__ = ["HotVertexCache", "build_hot_cache", "edge_payload_bytes",
           "vertex_overhead_bytes"]

# Per-edge payload arrays the cache can pack (4 bytes per entry each).
_EDGE_PAYLOADS = ("col", "weights", "alias_prob", "alias_idx")


def edge_payload_bytes(payloads: Sequence[str]) -> int:
    """Bytes per cached *edge* for this payload set (4 per array)."""
    return 4 * sum(1 for p in payloads if p in _EDGE_PAYLOADS)


def vertex_overhead_bytes(payloads: Sequence[str],
                          num_edge_types: int = 0) -> int:
    """Bytes per cached *vertex*: id + degree + prefix offset, plus the
    per-vertex typed sub-segment row when ``type_offsets`` is packed."""
    fixed = 12  # hot_ids + hot_deg + hot_off, 4 bytes each
    if "type_offsets" in payloads:
        fixed += 4 * (max(int(num_edge_types), 0) + 1)
    return fixed


@dataclasses.dataclass(frozen=True)
class HotVertexCache:
    """The packed VMEM-resident block plus its id → slot directory.

    ``hot_ids`` is sorted ascending so the kernel's probe is a static
    ``ceil(log2(H+1))``-trip binary search; ``hot_off`` is the exclusive
    prefix sum of ``hot_deg`` — slot ``s``'s payload occupies
    ``[hot_off[s], hot_off[s+1])`` of every packed edge array.
    ``type_offsets`` rows are packed verbatim — the graph stores them
    *row-relative* (sub-segment ``t`` of vertex ``v`` spans
    ``[type_offsets[v, t], type_offsets[v, t + 1])`` within the row), so
    the same offsets index the cached row relative to ``hot_off[s]``
    exactly as they index the HBM row relative to ``row_ptr[v]``.
    """

    hot_ids: np.ndarray                 # (H,) int32, sorted ascending
    hot_deg: np.ndarray                 # (H,) int32
    hot_off: np.ndarray                 # (H + 1,) int32 exclusive prefix
    col: np.ndarray                     # (P,) int32 packed columns
    weights: Optional[np.ndarray]       # (P,) float32 or None
    alias_prob: Optional[np.ndarray]    # (P,) float32 or None
    alias_idx: Optional[np.ndarray]     # (P,) int32 or None
    type_offsets: Optional[np.ndarray]  # (H, T + 1) int32 (row-relative)
    payloads: Tuple[str, ...]           # payload set the block packs
    budget_bytes: int                   # the budget it was sized under

    @property
    def num_hot(self) -> int:
        return int(self.hot_ids.shape[0])

    @property
    def num_entries(self) -> int:
        """Packed edge-payload length P (>= 1; padded when all-zero)."""
        return int(self.col.shape[0])

    @property
    def probe_trips(self) -> int:
        """Static trip count of the kernel's binary-search probe."""
        return max(1, int(math.ceil(math.log2(self.num_hot + 1))))

    def nbytes(self) -> int:
        """Actual bytes of the packed block (directory + payloads)."""
        total = self.hot_ids.nbytes + self.hot_deg.nbytes + self.hot_off.nbytes
        for arr in (self.col, self.weights, self.alias_prob, self.alias_idx,
                    self.type_offsets):
            if arr is not None:
                total += arr.nbytes
        return int(total)

    def slot_of(self, v: int) -> int:
        """Cache slot of vertex ``v``, or -1 on a miss (host-side mirror
        of the kernel probe — same binary search over the same array)."""
        s = int(np.searchsorted(self.hot_ids, v))
        if s < self.num_hot and int(self.hot_ids[s]) == int(v):
            return s
        return -1


def _pack_indices(row_ptr: np.ndarray, chosen: np.ndarray,
                  lens: np.ndarray, total: int) -> np.ndarray:
    """HBM edge indices of every cached entry, in slot-major order."""
    if total == 0:
        return np.zeros((0,), np.int64)
    starts = row_ptr[chosen].astype(np.int64)
    base = np.repeat(starts - np.concatenate(
        ([0], np.cumsum(lens)[:-1])).astype(np.int64), lens)
    return base + np.arange(total, dtype=np.int64)


def build_hot_cache(graph, payloads: Sequence[str],
                    budget_bytes: int) -> Optional[HotVertexCache]:
    """Pack the largest degree-descending vertex prefix that fits.

    Vertices are admitted in descending-degree order (smaller id wins a
    degree tie); each costs its per-vertex directory overhead plus
    ``deg(v)`` entries of every packed edge payload.  Returns ``None``
    when the budget does not admit even the top vertex — the caller
    treats that as "cache off".
    """
    budget = int(budget_bytes)
    if budget <= 0:
        return None
    payloads = tuple(payloads)
    row_ptr = np.asarray(graph.row_ptr)
    deg = (row_ptr[1:] - row_ptr[:-1]).astype(np.int64)
    nv = deg.shape[0]
    if nv == 0:
        return None
    # Descending degree, ascending id on ties (lexsort: last key primary).
    order = np.lexsort((np.arange(nv), -deg))
    per_edge = edge_payload_bytes(payloads)
    per_vert = vertex_overhead_bytes(payloads, graph.num_edge_types or 0)
    cost = per_vert + per_edge * deg[order]
    h = int(np.searchsorted(np.cumsum(cost), budget, side="right"))
    if h == 0:
        return None
    chosen = np.sort(order[:h]).astype(np.int64)
    hot_deg = deg[chosen]
    hot_off = np.concatenate(([0], np.cumsum(hot_deg))).astype(np.int32)
    total = int(hot_off[-1])
    idx = _pack_indices(row_ptr, chosen, hot_deg, total)

    def pack(src, fill, dtype):
        out = np.full((max(total, 1),), fill, dtype)
        out[:total] = np.asarray(src)[idx].astype(dtype)
        return out

    col = pack(graph.col, 0, np.int32)
    # A payload is only packable when the graph actually carries the
    # source array (e.g. the reservoir program declares `weights` but an
    # unweighted graph scores every edge at 1 — nothing to cache).
    weights = (pack(graph.weights, 0.0, np.float32)
               if "weights" in payloads and graph.weights is not None
               else None)
    alias_prob = (pack(graph.alias_prob, 0.0, np.float32)
                  if "alias_prob" in payloads and graph.alias_prob is not None
                  else None)
    alias_idx = (pack(graph.alias_idx, 0, np.int32)
                 if "alias_idx" in payloads and graph.alias_idx is not None
                 else None)
    type_offsets = None
    if "type_offsets" in payloads and graph.type_offsets is not None:
        # Row-relative in the graph, row-relative in the cache: verbatim.
        type_offsets = np.asarray(graph.type_offsets)[chosen].astype(np.int32)
    return HotVertexCache(
        hot_ids=chosen.astype(np.int32), hot_deg=hot_deg.astype(np.int32),
        hot_off=hot_off, col=col, weights=weights, alias_prob=alias_prob,
        alias_idx=alias_idx, type_offsets=type_offsets, payloads=payloads,
        budget_bytes=budget)
