"""Synthetic graph generators (paper §VIII-C2 uses RMAT balanced + Graph500).

All generators are deterministic given a seed and produce numpy edge arrays
for ``build_csr``.  The RMAT generator is fully vectorized: each of the
``scale`` address bits of (src, dst) is drawn for all edges at once.
"""
from __future__ import annotations

import numpy as np

# RMAT initiator matrices from the paper: balanced and Graph500 (§VIII-C2).
BALANCED = (0.25, 0.25, 0.25, 0.25)
GRAPH500 = (0.57, 0.19, 0.19, 0.05)


def rmat_edges(
    scale: int,
    edge_factor: int,
    initiator=GRAPH500,
    seed: int = 0,
    undirected: bool = False,
) -> tuple[np.ndarray, int]:
    """Generate RMAT edges. Returns (edges (E,2) int64, num_vertices)."""
    a, b, c, d = initiator
    assert abs(a + b + c + d - 1.0) < 1e-6
    n = 1 << scale
    m = n * edge_factor
    rng = np.random.default_rng(seed)
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    for bit in range(scale):
        r = rng.random(m)
        # Quadrant choice: P(src_bit=0,dst_bit=0)=a, (0,1)=b, (1,0)=c, (1,1)=d
        src_bit = (r >= a + b).astype(np.int64)
        dst_bit = ((r >= a) & (r < a + b) | (r >= a + b + c)).astype(np.int64)
        src = (src << 1) | src_bit
        dst = (dst << 1) | dst_bit
    edges = np.stack([src, dst], axis=1)
    if undirected:
        edges = np.concatenate([edges, edges[:, ::-1]], axis=0)
    return edges, n


def erdos_renyi_edges(num_vertices: int, num_edges: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    src = rng.integers(0, num_vertices, size=num_edges)
    dst = rng.integers(0, num_vertices, size=num_edges)
    return np.stack([src, dst], axis=1).astype(np.int64)


def power_law_edges(num_vertices: int, num_edges: int, alpha: float = 1.5,
                    seed: int = 0) -> np.ndarray:
    """Directed power-law out-degree graph (Zipf-distributed destinations)."""
    rng = np.random.default_rng(seed)
    # Zipf ranks for dst create hubs; src uniform.
    ranks = rng.zipf(alpha, size=num_edges)
    dst = (ranks - 1) % num_vertices
    src = rng.integers(0, num_vertices, size=num_edges)
    return np.stack([src, dst], axis=1).astype(np.int64)


def dangling_fraction(edges: np.ndarray, num_vertices: int) -> float:
    """Fraction of vertices with no outgoing edge (early-termination drivers)."""
    deg = np.bincount(edges[:, 0], minlength=num_vertices)
    return float((deg == 0).mean())
