"""Vertex partitioning across memory channels / devices (paper §IV-A/B).

The paper randomly partitions the CSR across HBM channels and encodes the
owning channel in each ``RP_entry``.  On TPU the "channels" are devices on
the mesh: vertex v is owned by device ``v % N`` (random-ish for RMAT ids —
matches the paper's random partitioning, whose load is near-uniform after
the walk mixes, §IV-A), and each device stores the row pointers *and*
neighbor lists of its owned vertices.

Adaptation note (DESIGN.md §2): the paper splits Row-Access and Column-Access
across distinct channels to avoid intra-channel arbitration. TPU devices have
no per-channel arbiter, so splitting RA/CA across devices would only add a
second all_to_all per hop; we co-locate a vertex's row entry and neighbor
list on its owner and route once per hop.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@partial(jax.tree_util.register_dataclass,
         data_fields=["row_ptr", "col", "weights", "alias_prob", "alias_idx",
                      "type_offsets"],
         meta_fields=["num_vertices", "num_devices", "vertices_per_device",
                      "max_degree"])
@dataclasses.dataclass(frozen=True)
class PartitionedGraph:
    """Stacked per-device CSR shards; leading axis = device (channel).

    row_ptr: (N, V_loc+1) int32  — per-device local row pointers.
    col:     (N, E_loc)   int32  — neighbor lists (global vertex ids), padded.
    weights/alias_prob/alias_idx: optional per-edge payloads, same layout.
    type_offsets: (N, V_loc, T+1) int32 or None — per-owned-vertex MetaPath
        sub-segment offsets (segment-relative, so they shard with the
        vertex: the values are copied verbatim from the global table).
    """

    row_ptr: jnp.ndarray
    col: jnp.ndarray
    weights: Optional[jnp.ndarray] = None
    alias_prob: Optional[jnp.ndarray] = None
    alias_idx: Optional[jnp.ndarray] = None
    type_offsets: Optional[jnp.ndarray] = None
    num_vertices: int = 0
    num_devices: int = 1
    vertices_per_device: int = 0
    max_degree: int = 0


def owner_of(v: jnp.ndarray, num_devices: int) -> jnp.ndarray:
    return jnp.where(v >= 0, v % num_devices, 0)


def local_id(v: jnp.ndarray, num_devices: int) -> jnp.ndarray:
    return jnp.where(v >= 0, v // num_devices, 0)


def partition_graph(g, num_devices: int) -> PartitionedGraph:
    """Shard a CSRGraph into N per-device sub-CSRs (host-side numpy)."""
    rp = np.asarray(g.row_ptr)
    col = np.asarray(g.col)
    w = None if g.weights is None else np.asarray(g.weights)
    ap = None if g.alias_prob is None else np.asarray(g.alias_prob)
    ai = None if g.alias_idx is None else np.asarray(g.alias_idx)
    to = None if getattr(g, "type_offsets", None) is None else \
        np.asarray(g.type_offsets)

    V = g.num_vertices
    v_per_dev = (V + num_devices - 1) // num_devices
    deg = np.diff(rp)

    # Per-device local degree table, padded to v_per_dev vertices.
    local_deg = np.zeros((num_devices, v_per_dev), dtype=np.int64)
    for r in range(num_devices):
        owned = np.arange(r, V, num_devices)
        local_deg[r, : owned.size] = deg[owned]
    local_rp = np.zeros((num_devices, v_per_dev + 1), dtype=np.int64)
    np.cumsum(local_deg, axis=1, out=local_rp[:, 1:])

    e_max = int(local_rp[:, -1].max()) if V else 0
    e_max = max(e_max, 1)
    local_col = np.zeros((num_devices, e_max), dtype=np.int32)
    local_w = np.ones((num_devices, e_max), dtype=np.float32) if w is not None else None
    local_ap = np.ones((num_devices, e_max), dtype=np.float32) if ap is not None else None
    local_ai = np.zeros((num_devices, e_max), dtype=np.int32) if ai is not None else None
    # Type offsets are segment-relative, so the owned rows shard verbatim
    # (this is what lets MetaPath declare a first-order capability).
    local_to = (np.zeros((num_devices, v_per_dev, to.shape[1]), dtype=np.int32)
                if to is not None else None)

    for r in range(num_devices):
        owned = np.arange(r, V, num_devices)
        if local_to is not None:
            local_to[r, : owned.size] = to[owned]
        # Gather each owned vertex's neighbor segment into the local layout.
        for k, v in enumerate(owned):
            s, e = rp[v], rp[v + 1]
            ls, le = local_rp[r, k], local_rp[r, k + 1]
            local_col[r, ls:le] = col[s:e]
            if local_w is not None:
                local_w[r, ls:le] = w[s:e]
            if local_ap is not None:
                local_ap[r, ls:le] = ap[s:e]
            if local_ai is not None:
                local_ai[r, ls:le] = ai[s:e]

    return PartitionedGraph(
        row_ptr=jnp.asarray(local_rp, dtype=jnp.int32),
        col=jnp.asarray(local_col),
        weights=None if local_w is None else jnp.asarray(local_w),
        alias_prob=None if local_ap is None else jnp.asarray(local_ap),
        alias_idx=None if local_ai is None else jnp.asarray(local_ai),
        type_offsets=None if local_to is None else jnp.asarray(local_to),
        num_vertices=V,
        num_devices=num_devices,
        vertices_per_device=v_per_dev,
        max_degree=g.max_degree,
    )
