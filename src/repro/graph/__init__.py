"""Graph substrate: CSR representation, generators, alias tables, partitioning."""
from repro.graph.csr import CSRGraph, build_csr, degrees, validate_csr
from repro.graph.generators import rmat_edges, erdos_renyi_edges, GRAPH500, BALANCED
from repro.graph.alias import build_alias_tables
from repro.graph.datasets import make_dataset, DATASET_SPECS
from repro.graph.partition import partition_graph, PartitionedGraph, owner_of

__all__ = [
    "CSRGraph", "build_csr", "degrees", "validate_csr",
    "rmat_edges", "erdos_renyi_edges", "GRAPH500", "BALANCED",
    "build_alias_tables", "make_dataset", "DATASET_SPECS",
    "partition_graph", "PartitionedGraph", "owner_of",
]
