"""Graph substrate: CSR representation, generators, alias tables, partitioning."""
from repro.graph.alias import build_alias_tables
from repro.graph.csr import CSRGraph, build_csr, degrees, validate_csr
from repro.graph.datasets import DATASET_SPECS, make_dataset
from repro.graph.generators import (BALANCED, GRAPH500, erdos_renyi_edges,
                                    rmat_edges)
from repro.graph.hot_cache import HotVertexCache, build_hot_cache
from repro.graph.partition import PartitionedGraph, owner_of, partition_graph

__all__ = [
    "CSRGraph", "build_csr", "degrees", "validate_csr",
    "rmat_edges", "erdos_renyi_edges", "GRAPH500", "BALANCED",
    "build_alias_tables", "make_dataset", "DATASET_SPECS",
    "partition_graph", "PartitionedGraph", "owner_of",
    "HotVertexCache", "build_hot_cache",
]
