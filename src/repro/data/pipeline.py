"""Deterministic synthetic data pipelines (offline container — no corpora).

Every pipeline is a stateless function of (seed, step) so any host in a
multi-host job can materialize exactly its shard of the global batch
without coordination, and restarts resume bit-identically (fault
tolerance: data state is just an integer).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0


def lm_batch(cfg: TokenPipelineConfig, step: int):
    """Synthetic Zipf-ish token batch: (tokens, labels) (B, S) int32."""
    rng = np.random.default_rng((cfg.seed, step))
    z = rng.zipf(1.3, size=(cfg.global_batch, cfg.seq_len + 1))
    toks = ((z - 1) % cfg.vocab).astype(np.int32)
    return toks[:, :-1], toks[:, 1:]


def lm_batches(cfg: TokenPipelineConfig, start_step: int = 0) -> Iterator:
    step = start_step
    while True:
        yield lm_batch(cfg, step)
        step += 1


def shard_batch(batch, sharding):
    """Place a host-global numpy batch onto the mesh with the given sharding."""
    return jax.tree.map(lambda x: jax.device_put(jnp.asarray(x), sharding),
                        batch)


def gnn_batch(n_nodes: int, n_edges: int, d_feat: int, seed: int = 0,
              d_edge: int = 0, n_classes: int = 7, out_dim: int = 3):
    rng = np.random.default_rng(seed)
    b = dict(
        node_feats=rng.random((n_nodes, d_feat), np.float32),
        edge_index=np.stack([rng.integers(0, n_nodes, n_edges),
                             rng.integers(0, n_nodes, n_edges)]).astype(np.int32),
        labels=rng.integers(0, n_classes, n_nodes).astype(np.int32),
        targets=rng.random((n_nodes, out_dim), np.float32),
    )
    if d_edge:
        b["edge_feats"] = rng.random((n_edges, d_edge), np.float32)
    return b


def molecule_batch(n_atoms: int, n_edges: int, n_mols: int, seed: int = 0):
    """Batched small molecules: one padded disjoint-union graph."""
    rng = np.random.default_rng(seed)
    N = n_atoms * n_mols
    src = np.concatenate([rng.integers(0, n_atoms, n_edges) + m * n_atoms
                          for m in range(n_mols)])
    dst = np.concatenate([rng.integers(0, n_atoms, n_edges) + m * n_atoms
                          for m in range(n_mols)])
    return dict(
        species=rng.integers(0, 20, N).astype(np.int32),
        positions=(rng.random((N, 3), np.float32) * 4.0),
        edge_index=np.stack([src, dst]).astype(np.int32),
        mol_id=np.repeat(np.arange(n_mols), n_atoms).astype(np.int32),
        energies=rng.random(n_mols).astype(np.float32),
    )


def recsys_batch(batch: int, n_dense: int, n_sparse: int, vocab_sizes,
                 seed: int = 0):
    rng = np.random.default_rng(seed)
    sparse = np.stack([rng.integers(0, v, batch) for v in vocab_sizes],
                      axis=1).astype(np.int32)
    return dict(
        dense=rng.random((batch, n_dense), np.float32),
        sparse=sparse,
        labels=rng.integers(0, 2, batch).astype(np.int32),
    )
