from repro.optim import adamw, grad_compression
