"""Error-feedback int8 gradient compression for the cross-pod hop.

At 2+ pods the inter-pod links are the slow hop; gradients are reduced
hierarchically: full-precision reduce within a pod (fast ICI), then an
int8-quantized all-reduce across pods with per-tensor scale and local
error feedback (the quantization residual is added back into the next
step's gradient), preserving convergence (1-bit Adam / EF-SGD lineage).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x):
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_with_feedback(grad, error):
    """EF step: g' = g + e; q = Q(g'); e' = g' - deQ(q)."""
    g = grad.astype(jnp.float32) + error
    q, scale = quantize_int8(g)
    deq = dequantize_int8(q, scale)
    new_error = g - deq
    return (q, scale), deq, new_error


def crosspod_psum_compressed(grads, errors, axis_name: str):
    """Per-leaf: error-feedback int8 quantize -> psum over pods -> dequant.

    Inside shard_map with a 'pod' axis. Returns (reduced_grads, new_errors).
    The int8 payload cuts cross-pod bytes 4x vs f32 (2x vs bf16)."""
    def one(g, e):
        (q, scale), _, new_e = compress_with_feedback(g, e)
        # Sum int8 payloads in int32 (exact), share scales via max.
        s = jax.lax.pmax(scale, axis_name)
        q32 = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return (q32.astype(jnp.float32) * s), new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(errors)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
