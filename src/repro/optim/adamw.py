"""AdamW + schedules (hand-rolled; optax is not available offline)."""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: any
    nu: any


def init_state(params) -> AdamWState:
    z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(z, params),
                      nu=jax.tree.map(z, params))


def cosine_lr(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply_updates(params, grads, state: AdamWState, cfg: AdamWConfig):
    """One AdamW step with global-norm clipping. Returns (params, state, stats)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = cosine_lr(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return (p - lr * delta.astype(p.dtype)).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    stats = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v), stats
