"""Fault-tolerant training runtime.

* checkpoint every N steps + on SIGTERM (preemption-safe), atomic commits;
* resume from the latest manifest (data pipeline state is just the step
  counter — bit-identical restart);
* straggler watchdog: EWMA of step wall time; steps slower than
  ``k × EWMA`` are logged and counted (on a real multi-host job this
  triggers the elastic controller in `runtime/elastic.py`);
* metrics ring written as JSON-lines for external scraping.
"""
from __future__ import annotations

import dataclasses
import json
import os
import signal
import time
from typing import Any, Callable, Optional

import jax

from repro.checkpoint import checkpointer


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    log_every: int = 10
    straggler_factor: float = 3.0
    async_checkpoint: bool = True
    metrics_path: Optional[str] = None


class StragglerWatchdog:
    def __init__(self, factor: float = 3.0, alpha: float = 0.1):
        self.factor = factor
        self.alpha = alpha
        self.ewma = None
        self.straggler_steps = 0

    def observe(self, dt: float) -> bool:
        is_straggler = False
        if self.ewma is not None and dt > self.factor * self.ewma:
            self.straggler_steps += 1
            is_straggler = True
        self.ewma = dt if self.ewma is None else \
            (1 - self.alpha) * self.ewma + self.alpha * dt
        return is_straggler


def run(step_fn: Callable, state: Any, batch_fn: Callable,
        cfg: TrainLoopConfig, start_step: int = 0):
    """Generic loop: state = step_fn(state, batch). state must be a pytree
    (params, opt_state, ...). batch_fn(step) -> device batch."""
    os.makedirs(cfg.ckpt_dir, exist_ok=True)
    stop = {"flag": False}

    def _on_sigterm(signum, frame):
        stop["flag"] = True

    old = signal.signal(signal.SIGTERM, _on_sigterm)
    watchdog = StragglerWatchdog(cfg.straggler_factor)
    metrics_f = open(cfg.metrics_path, "a") if cfg.metrics_path else None
    pending = None
    step = start_step
    history = []
    try:
        while step < cfg.total_steps and not stop["flag"]:
            t0 = time.perf_counter()
            batch = batch_fn(step)
            state, aux = step_fn(state, batch)
            jax.block_until_ready(jax.tree.leaves(state)[0])
            dt = time.perf_counter() - t0
            straggler = watchdog.observe(dt)
            step += 1
            if step % cfg.log_every == 0 or straggler:
                rec = {"step": step, "dt_s": dt,
                       "straggler": straggler,
                       **{k: float(v) for k, v in (aux or {}).items()}}
                history.append(rec)
                if metrics_f:
                    metrics_f.write(json.dumps(rec) + "\n")
                    metrics_f.flush()
            if step % cfg.ckpt_every == 0:
                if pending is not None:
                    pending.join()
                pending = checkpointer.save(
                    cfg.ckpt_dir, step, state,
                    blocking=not cfg.async_checkpoint)
    finally:
        if pending is not None:
            pending.join()
        # Preemption / completion checkpoint.
        checkpointer.save(cfg.ckpt_dir, step, state, blocking=True)
        if metrics_f:
            metrics_f.close()
        signal.signal(signal.SIGTERM, old)
    return state, step, history, watchdog


@dataclasses.dataclass
class PipelineConfig:
    """Knobs for the producer/consumer pipelined loop (`run_pipelined`).

    ``rounds`` walk-production rounds × ``steps_per_round`` grad steps;
    ``overlap=True`` dispatches round ``r+1``'s walk launch *before*
    round ``r``'s grad steps are issued, so the device queue interleaves
    walk supersteps with training (async dispatch — the host never
    blocks between the two).  ``overlap=False`` is the serial baseline:
    block on the walks, round-trip them through the host, then train.
    """

    rounds: int = 4
    steps_per_round: int = 16
    overlap: bool = True
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 0          # 0 = no mid-training checkpoints
    log_every: int = 0           # 0 = no loss history (zero host syncs)
    straggler_factor: float = 3.0


def run_pipelined(produce_fn: Callable, append_fn: Callable,
                  sample_fn: Callable, step_fn: Callable,
                  state: Any, ring: Any, cfg: PipelineConfig,
                  start_step: int = 0, rounds_done: int = 0,
                  batch_hook: Optional[Callable] = None):
    """Overlapped producer/consumer training loop.

    * ``produce_fn(round) -> walks`` — dispatch one walk round (device
      arrays; must be a pure function of the round index, so a resumed
      run regenerates exactly the rounds it needs).
    * ``append_fn(ring, walks) -> ring`` — land the walks in the corpus
      ring (device→device in overlapped mode; the serial baseline's
      append is where the host round-trip lives).
    * ``sample_fn(ring, step) -> batch`` — the jitted corpus consumer.
    * ``step_fn(state, batch) -> (state, aux)`` — the grad step.

    With ``cfg.overlap`` the loop issues round ``r+1``'s production
    immediately after appending round ``r`` — before any of round ``r``'s
    grad steps — so walk launches and grad steps coexist in the device
    queue (launch ``k+1`` in flight while step ``k`` executes).  Steps
    are checkpointed (``{"state", "ring"}`` payload) every
    ``ckpt_every`` steps; resume via :func:`resume_pipeline`, passing
    the restored ``rounds_done`` so already-ingested rounds are not
    re-appended.  Returns ``(state, ring, step, history, watchdog)``.
    """
    if cfg.rounds <= 0 or cfg.steps_per_round <= 0:
        raise ValueError(
            f"rounds ({cfg.rounds}) and steps_per_round "
            f"({cfg.steps_per_round}) must be positive")
    total = cfg.rounds * cfg.steps_per_round
    spr = cfg.steps_per_round
    watchdog = StragglerWatchdog(cfg.straggler_factor)
    history = []
    pending = None
    pending_round = -1
    step = start_step
    while step < total:
        r = step // spr
        # Ingest every round up to and including r (a fresh run appends
        # exactly round r here; a resumed run may need to catch up).
        while rounds_done <= r:
            if pending_round != rounds_done:
                pending = produce_fn(rounds_done)
                pending_round = rounds_done
            ring = append_fn(ring, pending)
            pending = None
            rounds_done += 1
        # Overlap: round r+1's walk launch enters the device queue ahead
        # of round r's grad steps (the producer side of the pipeline).
        nxt = rounds_done
        if cfg.overlap and nxt == r + 1 and nxt < cfg.rounds:
            pending = produce_fn(nxt)
            pending_round = nxt
        end = min(total, (r + 1) * spr)
        while step < end:
            t0 = time.perf_counter()
            batch = sample_fn(ring, step)
            if batch_hook is not None:
                batch_hook(step, batch)
            state, aux = step_fn(state, batch)
            step += 1
            if cfg.log_every and step % cfg.log_every == 0:
                jax.block_until_ready(jax.tree.leaves(state)[0])
                dt = time.perf_counter() - t0
                history.append({"step": step, "dt_s": dt,
                                "straggler": watchdog.observe(dt),
                                **{k: float(v)
                                   for k, v in (aux or {}).items()}})
            if (cfg.ckpt_dir and cfg.ckpt_every
                    and step % cfg.ckpt_every == 0 and step < total):
                checkpointer.save(cfg.ckpt_dir, step,
                                  {"state": state, "ring": ring},
                                  blocking=True)
        if cfg.overlap:
            # Bounded pipeline: fence on the consumer state at the round
            # boundary (round r+1's walk launch is already in flight, so
            # it keeps executing behind this wait).  Without the fence
            # the async dispatch queue grows without bound and dispatch
            # overhead eats the overlap win.
            jax.block_until_ready(jax.tree.leaves(state)[0])
    if cfg.ckpt_dir:
        checkpointer.save(cfg.ckpt_dir, step,
                          {"state": state, "ring": ring}, blocking=True)
    return state, ring, step, history, watchdog


def resume_pipeline(ckpt_dir: Optional[str], init_state: Any, init_ring: Any):
    """Latest pipelined checkpoint (state, ring, step) or the fresh pair."""
    if not ckpt_dir:
        return init_state, init_ring, 0
    last = checkpointer.latest_step(ckpt_dir)
    if last is None:
        return init_state, init_ring, 0
    payload = checkpointer.restore(ckpt_dir, last,
                                   {"state": init_state, "ring": init_ring})
    return payload["state"], payload["ring"], last


def resume_or_init(ckpt_dir: str, init_state: Any, shardings=None):
    """Elastic restart: load the latest checkpoint (re-sharded to the
    current mesh) or return the fresh state."""
    last = checkpointer.latest_step(ckpt_dir)
    if last is None:
        return init_state, 0
    state = checkpointer.restore(ckpt_dir, last, init_state, shardings)
    return state, last
