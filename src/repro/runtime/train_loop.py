"""Fault-tolerant training runtime.

* checkpoint every N steps + on SIGTERM (preemption-safe), atomic commits;
* resume from the latest manifest (data pipeline state is just the step
  counter — bit-identical restart);
* straggler watchdog: EWMA of step wall time; steps slower than
  ``k × EWMA`` are logged and counted (on a real multi-host job this
  triggers the elastic controller in `runtime/elastic.py`);
* metrics ring written as JSON-lines for external scraping.
"""
from __future__ import annotations

import dataclasses
import json
import os
import signal
import time
from typing import Any, Callable, Optional

import jax

from repro.checkpoint import checkpointer


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    log_every: int = 10
    straggler_factor: float = 3.0
    async_checkpoint: bool = True
    metrics_path: Optional[str] = None


class StragglerWatchdog:
    def __init__(self, factor: float = 3.0, alpha: float = 0.1):
        self.factor = factor
        self.alpha = alpha
        self.ewma = None
        self.straggler_steps = 0

    def observe(self, dt: float) -> bool:
        is_straggler = False
        if self.ewma is not None and dt > self.factor * self.ewma:
            self.straggler_steps += 1
            is_straggler = True
        self.ewma = dt if self.ewma is None else \
            (1 - self.alpha) * self.ewma + self.alpha * dt
        return is_straggler


def run(step_fn: Callable, state: Any, batch_fn: Callable,
        cfg: TrainLoopConfig, start_step: int = 0):
    """Generic loop: state = step_fn(state, batch). state must be a pytree
    (params, opt_state, ...). batch_fn(step) -> device batch."""
    os.makedirs(cfg.ckpt_dir, exist_ok=True)
    stop = {"flag": False}

    def _on_sigterm(signum, frame):
        stop["flag"] = True

    old = signal.signal(signal.SIGTERM, _on_sigterm)
    watchdog = StragglerWatchdog(cfg.straggler_factor)
    metrics_f = open(cfg.metrics_path, "a") if cfg.metrics_path else None
    pending = None
    step = start_step
    history = []
    try:
        while step < cfg.total_steps and not stop["flag"]:
            t0 = time.perf_counter()
            batch = batch_fn(step)
            state, aux = step_fn(state, batch)
            jax.block_until_ready(jax.tree.leaves(state)[0])
            dt = time.perf_counter() - t0
            straggler = watchdog.observe(dt)
            step += 1
            if step % cfg.log_every == 0 or straggler:
                rec = {"step": step, "dt_s": dt,
                       "straggler": straggler,
                       **{k: float(v) for k, v in (aux or {}).items()}}
                history.append(rec)
                if metrics_f:
                    metrics_f.write(json.dumps(rec) + "\n")
                    metrics_f.flush()
            if step % cfg.ckpt_every == 0:
                if pending is not None:
                    pending.join()
                pending = checkpointer.save(
                    cfg.ckpt_dir, step, state,
                    blocking=not cfg.async_checkpoint)
    finally:
        if pending is not None:
            pending.join()
        # Preemption / completion checkpoint.
        checkpointer.save(cfg.ckpt_dir, step, state, blocking=True)
        if metrics_f:
            metrics_f.close()
        signal.signal(signal.SIGTERM, old)
    return state, step, history, watchdog


def resume_or_init(ckpt_dir: str, init_state: Any, shardings=None):
    """Elastic restart: load the latest checkpoint (re-sharded to the
    current mesh) or return the fresh state."""
    last = checkpointer.latest_step(ckpt_dir)
    if last is None:
        return init_state, 0
    state = checkpointer.restore(ckpt_dir, last, init_state, shardings)
    return state, last
