"""Elastic scaling: rebuild the mesh when the healthy-device set changes
and re-shard training state from the latest checkpoint.

A pod loss at 2×16×16 degrades to 1×16×16: ``plan_remesh`` picks the
largest supported mesh ≤ the healthy device count, and `restart` reloads
the checkpoint with the new shardings (checkpoints are mesh-agnostic —
see `checkpoint/checkpointer.py`).  Straggler-driven demotion uses the
watchdog counts from `runtime/train_loop.py`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax


SUPPORTED_MESHES: Tuple[Tuple[int, ...], ...] = (
    (2, 16, 16), (1, 16, 16), (16, 16), (8, 16), (4, 16), (2, 16), (16,),
    (8,), (4,), (2,), (1,),
)


def plan_remesh(healthy_devices: int,
                prefer_axes=("pod", "data", "model")) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Largest supported mesh that fits the healthy device count."""
    for shape in SUPPORTED_MESHES:
        n = 1
        for s in shape:
            n *= s
        if n <= healthy_devices:
            axes = prefer_axes[-len(shape):]
            return shape, tuple(axes)
    raise RuntimeError("no devices left")


def build_mesh(shape: Sequence[int], axes: Sequence[str],
               devices=None) -> jax.sharding.Mesh:
    import numpy as np
    devices = devices if devices is not None else jax.devices()
    n = int(np.prod(shape))
    arr = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(arr, tuple(axes))


@dataclasses.dataclass
class ElasticController:
    """Decides restart actions from health signals."""
    min_devices: int = 1
    max_straggler_ratio: float = 0.05

    def decide(self, healthy: int, total_steps: int,
               straggler_steps: int) -> Optional[str]:
        if healthy < self.min_devices:
            return "abort"
        if straggler_steps > self.max_straggler_ratio * max(total_steps, 1):
            return "remesh"       # persistent straggler: demote and rebalance
        return None
