from repro.runtime import elastic, train_loop
