from repro.runtime import train_loop, elastic
