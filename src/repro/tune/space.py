"""The tunable knob space and its validity constraints.

A :class:`Knob` names one tunable axis; a :class:`Candidate` is one
assignment of values to a subset of knobs.  Candidates apply to a
``(WalkProgram, ExecutionConfig)`` pair through
``dataclasses.replace`` — so every validity constraint already encoded
in ``ExecutionConfig.__post_init__`` / ``SamplerSpec.__post_init__``
is enforced for free: enumeration simply drops assignments whose
``apply`` raises.

Knobs are split by what they may change:

  * **path-preserving** knobs (``num_slots``, ``hops_per_launch``,
    ``queue_depth_factor``, ``adaptive_chunks``) are pure machine knobs
    — sampled walks are bit-identical for any value (paper §V-A);
  * **resampling** knobs (``reservoir_chunk``) change which walks are
    drawn, because the E-S reservoir partitions its uniforms per chunk
    (``SALT_CHUNK0 + c``).  They are excluded from enumeration unless
    the caller explicitly opts in (``include_resampling=True``), which
    is what lets the tuned-vs-default benchmark pin
    ``paths_identical=True``.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Optional, Sequence, Tuple

# Execution-level knobs that accept the "auto" sentinel.
EXEC_KNOBS = ("num_slots", "hops_per_launch", "queue_depth_factor",
              "cache_budget")
# Sampler-spec-level knobs.
SPEC_KNOBS = ("reservoir_chunk", "adaptive_chunks")


@dataclasses.dataclass(frozen=True)
class Knob:
    """One tunable axis: its value grid and what it is allowed to change."""

    name: str
    values: Tuple
    target: str                 # "execution" | "spec"
    path_preserving: bool = True


def knobs_for(program, execution, backend: str = "single") -> Tuple[Knob, ...]:
    """The knob set applicable to this (program, execution, backend).

    Grids are clipped to sensible ranges; validity beyond that is
    delegated to the config dataclasses' own ``__post_init__``.
    """
    knobs = [
        Knob("num_slots", (32, 64, 128, 256, 512, 1024, 2048), "execution"),
        Knob("queue_depth_factor", (0.5, 1.0, 2.0, 4.0), "execution"),
    ]
    step_impl = getattr(execution, "step_impl", "jnp")
    if step_impl == "fused":
        # Only the fused superstep kernel consumes hops_per_launch.
        knobs.append(Knob("hops_per_launch", (2, 4, 8, 16, 32, 64),
                          "execution"))
        # Hot-vertex cache byte budget (0 = off).  Path-preserving by
        # construction: hits read the same bytes from VMEM instead of
        # HBM, so the sampled walks cannot change.
        knobs.append(Knob("cache_budget", (0, 1 << 14, 1 << 16, 1 << 18),
                          "execution"))
    if program.spec.kind == "reservoir_n2v":
        knobs.append(Knob("adaptive_chunks", (True, False), "spec"))
        knobs.append(Knob("reservoir_chunk", (16, 32, 64, 128, 256), "spec",
                          path_preserving=False))
    return tuple(knobs)


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One assignment of values to knobs (hashable: sorted item tuple)."""

    items: Tuple[Tuple[str, object], ...]

    @classmethod
    def of(cls, **knobs) -> "Candidate":
        """Build a candidate from keyword knob assignments."""
        return cls(items=tuple(sorted(knobs.items())))

    def to_dict(self) -> Dict[str, object]:
        """Plain-dict view (JSON-serializable for the tuning cache)."""
        return dict(self.items)

    def get(self, name: str, default=None):
        """The assigned value of ``name`` (or ``default``)."""
        return self.to_dict().get(name, default)

    def apply(self, program, execution):
        """Concrete ``(program, execution)`` under this assignment.

        Raises ``ValueError`` when the assignment violates any config
        invariant — enumeration uses that as the validity filter.
        """
        d = self.to_dict()
        exec_kw = {k: v for k, v in d.items() if k in EXEC_KNOBS}
        spec_kw = {k: v for k, v in d.items() if k in SPEC_KNOBS}
        unknown = set(d) - set(EXEC_KNOBS) - set(SPEC_KNOBS)
        if unknown:
            raise ValueError(f"unknown tuning knob(s): {sorted(unknown)}")
        new_exec = execution.resolved(**exec_kw)
        new_prog = program
        if spec_kw:
            spec = dataclasses.replace(program.spec, **spec_kw)
            new_prog = dataclasses.replace(program, spec=spec)
        return new_prog, new_exec

    def __str__(self) -> str:
        return ",".join(f"{k}={v}" for k, v in self.items)


def default_candidate(program, execution,
                      knobs: Sequence[Knob]) -> Candidate:
    """The assignment reproducing the *current* (auto-resolved) config —
    the do-nothing point every tuning run must keep in its grid so a
    tuned config can never lose to the default by construction."""
    resolved = execution.resolved()
    vals = {}
    for k in knobs:
        if k.target == "execution":
            vals[k.name] = getattr(resolved, k.name)
        else:
            v = getattr(program.spec, k.name)
            if k.name == "adaptive_chunks" and v == "auto":
                v = True  # legacy default before gate resolution
            vals[k.name] = v
    return Candidate.of(**vals)


def enumerate_candidates(program, execution, backend: str = "single",
                         include_resampling: bool = False,
                         only: Optional[Sequence[str]] = None,
                         exclude: Sequence[str] = ()) -> Tuple[Candidate, ...]:
    """Every valid knob assignment for this (program, execution, backend).

    Knobs not enumerated (filtered by ``only``/``exclude``/
    ``include_resampling``) are pinned to their default-candidate value,
    so every returned candidate is a *complete* assignment over the
    applicable knob set.  Assignments rejected by the config dataclasses'
    validation are dropped.  The default candidate is always included.
    """
    knobs = knobs_for(program, execution, backend)
    base = default_candidate(program, execution, knobs).to_dict()
    active = []
    for k in knobs:
        if not include_resampling and not k.path_preserving:
            continue
        if only is not None and k.name not in only:
            continue
        if k.name in exclude:
            continue
        active.append(k)
    out = []
    seen = set()
    grids = [k.values for k in active]
    for combo in itertools.product(*grids) if active else [()]:
        vals = dict(base)
        vals.update({k.name: v for k, v in zip(active, combo)})
        cand = Candidate.of(**vals)
        if cand.items in seen:
            continue
        try:
            cand.apply(program, execution)
        except (ValueError, TypeError):
            continue
        seen.add(cand.items)
        out.append(cand)
    default = Candidate.of(**base)
    if default.items not in seen:
        out.insert(0, default)
    return tuple(out)
