"""Measurement-driven autotuner with roofline-model search-space pruning.

The engine's realized throughput hangs on machine knobs —
``num_slots``, ``hops_per_launch``, ``queue_depth_factor``, the E-S
reservoir chunking — whose right values are a function of
*(graph, sampler, machine, workload)*, not constants.  This package
closes that loop:

* `repro.tune.space` — the tunable knob grid + validity constraints
  (delegated to the config dataclasses' own validation);
* `repro.tune.model` — the analytical cost model (bytes/hop counted
  off the phase program's DMA schedule) used to prune the grid and to
  answer ``"auto"`` sentinels without timing;
* `repro.tune.measure` — the **only** module allowed to read a clock
  (interleaved min-of-k timing; tests inject deterministic costs);
* `repro.tune.cache` — the persistent JSON cache keyed by graph
  signature x sampler x machine x workload;
* `repro.tune.tuner` — orchestration: `autotune` (measured) and
  `resolve` (cache/model-only; what ``Walker`` compilation calls).

CLI: ``python -m repro.tune [--no-measure] --cache tune_cache.json``.
"""
from repro.tune.cache import (GraphSignature, TuningCache, cache_key,
                              default_cache_path, graph_signature,
                              workload_bucket)
from repro.tune.measure import InjectedMeasurer, Measurer, WalkMeasurer
from repro.tune.model import (DEFAULT_COEFFS, CostCoeffs,
                              adaptive_chunk_gate, bytes_per_hop,
                              expected_walk_len, fit, live_max_degree,
                              predict_us, prune)
from repro.tune.space import (Candidate, Knob, default_candidate,
                              enumerate_candidates, knobs_for)
from repro.tune.tuner import TuneResult, autotune, needs_resolution, resolve

__all__ = [
    "GraphSignature", "TuningCache", "cache_key", "default_cache_path",
    "graph_signature", "workload_bucket",
    "Measurer", "InjectedMeasurer", "WalkMeasurer",
    "CostCoeffs", "DEFAULT_COEFFS", "adaptive_chunk_gate", "bytes_per_hop",
    "expected_walk_len", "fit", "live_max_degree", "predict_us", "prune",
    "Candidate", "Knob", "default_candidate", "enumerate_candidates",
    "knobs_for",
    "TuneResult", "autotune", "needs_resolution", "resolve",
]
