"""Library-grade analytical cost model for walk-engine runs.

This is ``benchmarks/roofline.py``'s walk-engine half lifted into the
library: a closed-batch drain is priced as

    cost = S·a  +  S·W·b  +  S·W·B·c  +  launches·d

where ``S`` is the superstep count the drain needs, ``W`` the lane-pool
width, ``B`` the per-lane **bytes gathered per hop** — counted off the
sampler kind's declarative DMA schedule
(`repro.kernels.fused_superstep.dma_schedule`), not guessed — and
``launches`` the host dispatch count (``ceil(S / hops_per_launch)``
under the fused superstep, 1 for the fully jitted drains).  The four
coefficients ``(a, b, c, d)`` form a :class:`CostCoeffs`; they can be
*fit* from measured samples per sampler kind (:func:`fit`) and are used
to rank and prune the candidate grid before any timing
(:func:`prune`) — the roofline-model pruning of the tuner.

The model also owns the **degree-adaptive reservoir gate**: the live
max degree of a W-lane pool on a skewed graph concentrates around the
degree-weighted quantile at ``q = 0.5**(1/W)`` (each of W roughly
independent lanes sits below d with probability F_w(d)), so the
expected chunk-loop trip count of the adaptive scan is predictable from
the graph signature alone — no timing needed to decide the
``adaptive_chunks="auto"`` sentinel.

No wall-clock here: everything is arithmetic over the
:class:`~repro.tune.cache.GraphSignature` and the phase program's
static schedule (`repro.tune.measure` is the only module allowed to
time anything).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.samplers import es_num_chunks
from repro.tune.cache import PLAIN_QS, WEIGHTED_QS, GraphSignature
from repro.tune.space import Candidate

# Bytes moved per `start` op of the declarative DMA schedule, by buffer.
# Scalar probes are 4-byte words; RP_entry / (lo, hi) pair probes are two
# words; the reservoir chunk stages copy a whole CH-wide chunk of columns
# or weights per start.
_BUF_WORD_BYTES = {
    "rpbuf": 8,     # RP_entry: (row_ptr[v], row_ptr[v+1])
    "pairbuf": 8,   # v_prev RP_entry / typed sub-segment bounds
    "colbuf": 4,    # one column probe
    "probbuf": 4,   # alias probability probe
    "aliasbuf": 4,  # alias index probe
    "wbuf": 8,      # path write-back record (qid, vertex)
}


@dataclasses.dataclass(frozen=True)
class CostCoeffs:
    """Fitted roofline coefficients, all in microseconds per unit."""

    superstep_us: float = 30.0   # fixed dispatch/bookkeeping per superstep
    lane_us: float = 0.02        # per lane-hop of compute
    byte_us: float = 0.002       # per lane-byte gathered
    launch_us: float = 150.0     # per host->device kernel dispatch

    def as_array(self) -> np.ndarray:
        """(4,) coefficient vector matching :func:`features` columns."""
        return np.array([self.superstep_us, self.lane_us, self.byte_us,
                         self.launch_us], dtype=np.float64)


DEFAULT_COEFFS = CostCoeffs()


def expected_walk_len(program) -> float:
    """E[L] under the program's stop rule (geometric, capped)."""
    stop = float(getattr(program.spec, "stop_prob", 0.0))
    max_hops = float(program.max_hops)
    if stop <= 0.0:
        return max_hops
    return min(max_hops, 1.0 / stop)


@functools.lru_cache(maxsize=256)
def _schedule_bytes(kind: str, rounds: int, bisect_iters: int, chunks: int,
                    reservoir_chunk: int, record_paths: bool,
                    cached: bool = False) -> float:
    """Per-lane bytes of one hop, summed over the kind's DMA schedule.

    ``cached=True`` prices the fully-hit representative superstep of the
    gather hierarchy: only the HBM copies the cache cannot absorb remain
    (v_prev-keyed probes, path write-back) — VMEM-tier reads move no HBM
    bytes and are skipped with the rest of the non-``start`` ops.
    """
    from repro.kernels.fused_superstep.fused_superstep import dma_schedule
    ops = dma_schedule(kind, lanes=1, rounds=rounds,
                       bisect_iters=bisect_iters, chunks=chunks,
                       records=1, record_paths=record_paths, cached=cached)
    total = 0.0
    for op in ops:
        if op.kind != "start":
            continue
        if op.buffer in ("ckcol", "ckwgt"):
            total += 4.0 * reservoir_chunk   # a whole staged chunk
        else:
            total += _BUF_WORD_BYTES.get(op.buffer, 4)
    return total


def bytes_per_hop(spec, sig: GraphSignature,
                  chunk_trips: Optional[int] = None,
                  record_paths: bool = False,
                  cached: bool = False) -> float:
    """Per-lane bytes gathered per hop for ``spec`` on a ``sig`` graph.

    ``chunk_trips`` overrides the reservoir chunk-loop trip count (the
    adaptive scan runs fewer trips than the static
    ``es_num_chunks(max_degree, CH)`` bound).  ``cached=True`` prices a
    cache-hit hop (residual HBM traffic only); blend the two with
    :func:`predicted_hit_rate` for the effective per-hop bytes.
    """
    bisect = max(1, int(math.ceil(
        math.log2(max(int(sig.max_degree), 2) + 1))))
    trips = 1
    if spec.kind == "reservoir_n2v":
        trips = (int(chunk_trips) if chunk_trips is not None
                 else es_num_chunks(sig.max_degree, spec.reservoir_chunk))
    return _schedule_bytes(spec.kind, int(spec.rejection_rounds), bisect,
                           max(1, trips), int(spec.reservoir_chunk),
                           bool(record_paths), bool(cached))


@functools.lru_cache(maxsize=64)
def _spec_payloads(spec) -> Tuple[str, ...]:
    from repro.core.phase_program import lower
    return lower(spec).cache_payloads


def predicted_hit_rate(sig: GraphSignature, budget_bytes: int,
                       payloads: Sequence[str]) -> float:
    """Modeled hit rate of a hot-vertex cache sized to ``budget_bytes``.

    The builder admits vertices in descending-degree order, and a
    walking lane occupies a vertex with probability proportional to its
    degree (stationary distribution), so the hit rate of a cache that
    covers every vertex of degree > d is the *edge-mass* fraction above
    d — read off the signature's degree-weighted quantile ladder, while
    the plain ladder prices the directory overhead (vertex count above
    d).  We scan the candidate thresholds both ladders store and keep
    the largest mass fraction whose modeled footprint fits the budget.
    Arithmetic over the signature only — no adjacency access, no clock.
    """
    budget = int(budget_bytes)
    if budget <= 0:
        return 0.0
    from repro.graph.hot_cache import (edge_payload_bytes,
                                       vertex_overhead_bytes)
    payloads = tuple(payloads)
    per_edge = max(edge_payload_bytes(payloads), 4)
    # The signature does not store the edge-type count; 2 is the floor
    # for a typed graph and only perturbs the per-vertex directory term.
    per_vert = vertex_overhead_bytes(
        payloads, 2 if "type_offsets" in payloads else 0)
    # Anchor both ladders at degree 0 (zero mass / zero vertices below).
    dq = np.concatenate(([0.0], np.asarray(sig.deg_q, np.float64)))
    pq = np.concatenate(([0.0], np.asarray(PLAIN_QS, np.float64)))
    dwq = np.concatenate(([0.0], np.asarray(sig.deg_wq, np.float64)))
    wq = np.concatenate(([0.0], np.asarray(WEIGHTED_QS, np.float64)))
    thresholds = np.unique(np.concatenate((dq, dwq)))
    best = 0.0
    for d in thresholds:
        vert_frac = 1.0 - float(np.interp(d, dq, pq))
        mass_frac = 1.0 - float(np.interp(d, dwq, wq))
        need = (vert_frac * sig.num_vertices * per_vert
                + mass_frac * sig.num_edges * per_edge)
        if need <= budget:
            best = max(best, mass_frac)
    return float(min(max(best, 0.0), 1.0))


# ------------------------------------------------------------------ gate


def live_max_degree(sig: GraphSignature, num_slots: int) -> int:
    """Predicted max degree among ``num_slots`` live lanes.

    A walking lane occupies a vertex with probability proportional to
    its degree (stationary distribution of an undirected random walk),
    so the max over W lanes concentrates at the degree-weighted quantile
    ``q = 0.5**(1/W)`` — interpolated over the signature's stored
    weighted-quantile ladder.
    """
    w = max(int(num_slots), 1)
    q = 0.5 ** (1.0 / w)
    qs = np.asarray(WEIGHTED_QS)
    vals = np.asarray(sig.deg_wq, dtype=np.float64)
    return int(round(float(np.interp(q, qs, vals))))


def adaptive_chunk_gate(sig: GraphSignature, num_slots: int, chunk: int,
                        margin: float = 0.75) -> bool:
    """Should the degree-adaptive reservoir scan be on for this graph?

    The adaptive scan bounds the E-S chunk loop by the live lanes' max
    degree instead of the graph's ``max_degree``; its win is the trip
    ratio, its cost a dynamic loop bound.  Gate it on only when the
    predicted trips fall below ``margin`` of the static bound — on
    balanced graphs the ratio is ~1 and the gate keeps the fixed scan,
    so the adaptive path can no longer lose to it.
    """
    ch = max(int(chunk), 1)
    t_live = -(-live_max_degree(sig, num_slots) // ch)
    t_fixed = es_num_chunks(sig.max_degree, ch)
    return max(1, t_live) <= margin * t_fixed


# ----------------------------------------------------------- prediction


def _reservoir_trips(spec, sig: GraphSignature, num_slots: int,
                     adaptive) -> Optional[int]:
    if spec.kind != "reservoir_n2v":
        return None
    if adaptive:
        live = live_max_degree(sig, num_slots)
        return max(1, -(-live // max(int(spec.reservoir_chunk), 1)))
    return es_num_chunks(sig.max_degree, spec.reservoir_chunk)


def features(program, execution, sig: GraphSignature,
             num_queries: int) -> np.ndarray:
    """(4,) feature vector [S, S·W, S·W·B, launches] of a closed run."""
    ex = execution.resolved()
    spec = program.spec
    w = int(ex.num_slots)
    length = expected_walk_len(program)
    q = max(int(num_queries), 1)
    supersteps = max(length, math.ceil(q * length / max(w, 1)))
    adaptive = spec.adaptive_chunks
    if adaptive == "auto":
        adaptive = adaptive_chunk_gate(sig, w, spec.reservoir_chunk)
    trips = _reservoir_trips(spec, sig, w, adaptive)
    b = bytes_per_hop(spec, sig, chunk_trips=trips,
                      record_paths=ex.record_paths)
    cb = getattr(ex, "cache_budget", 0)
    if ex.step_impl == "fused" and isinstance(cb, int) and cb > 0:
        # Gather hierarchy: a hit hop moves only the residual HBM bytes
        # the cache cannot absorb, so the effective per-hop traffic is
        # the hit-rate blend of the two schedules.
        h = predicted_hit_rate(sig, cb, _spec_payloads(spec))
        b_hit = bytes_per_hop(spec, sig, chunk_trips=trips,
                              record_paths=ex.record_paths, cached=True)
        b = (1.0 - h) * b + h * b_hit
    if ex.step_impl == "fused":
        launches = math.ceil(supersteps / max(int(ex.hops_per_launch), 1))
    else:
        launches = 1.0   # fully jitted drain: one dispatch
    return np.array([supersteps, supersteps * w, supersteps * w * b,
                     launches], dtype=np.float64)


def predict_us(program, execution, sig: GraphSignature, num_queries: int,
               coeffs: CostCoeffs = DEFAULT_COEFFS) -> float:
    """Modeled wall-time (microseconds) of one closed-batch run."""
    return float(features(program, execution, sig, num_queries)
                 @ coeffs.as_array())


def fit(feature_rows: Sequence[np.ndarray],
        measured_us: Sequence[float],
        base: CostCoeffs = DEFAULT_COEFFS) -> CostCoeffs:
    """Fit :class:`CostCoeffs` from measured samples (least squares,
    clipped non-negative).  With fewer samples than coefficients the
    system is underdetermined — fall back to uniformly rescaling
    ``base`` so total predicted time matches total measured time (the
    ranking the pruner needs survives a global rescale)."""
    X = np.asarray(list(feature_rows), dtype=np.float64)
    y = np.asarray(list(measured_us), dtype=np.float64)
    if X.ndim != 2 or X.shape[0] == 0 or X.shape[0] != y.shape[0]:
        raise ValueError(
            f"fit needs matching non-empty samples, got X{X.shape} "
            f"y{y.shape}")
    if X.shape[0] >= X.shape[1]:
        sol, *_ = np.linalg.lstsq(X, y, rcond=None)
        sol = np.clip(sol, 0.0, None)
        if sol.any():
            return CostCoeffs(*sol.tolist())
    pred = X @ base.as_array()
    scale = float(y.sum() / pred.sum()) if pred.sum() > 0 else 1.0
    c = base.as_array() * max(scale, 1e-9)
    return CostCoeffs(*c.tolist())


def prune(program, execution, sig: GraphSignature, num_queries: int,
          candidates: Sequence[Candidate], keep: int = 6,
          coeffs: CostCoeffs = DEFAULT_COEFFS,
          always_keep: Sequence[Candidate] = ()) -> Tuple[Candidate, ...]:
    """Model-ranked top-``keep`` candidates (plus ``always_keep``).

    Ranking is by :func:`predict_us` of the candidate applied to
    ``(program, execution)``; ties break toward the earlier candidate so
    pruning is deterministic.  ``always_keep`` (typically the default
    candidate) survives regardless of rank — the guarantee that tuning
    can never select something worse than what it was allowed to keep.
    """
    scored = []
    for i, cand in enumerate(candidates):
        prog_c, ex_c = cand.apply(program, execution)
        scored.append((predict_us(prog_c, ex_c, sig, num_queries, coeffs),
                       i, cand))
    scored.sort(key=lambda t: (t[0], t[1]))
    kept = [c for _, _, c in scored[:max(int(keep), 1)]]
    for cand in always_keep:
        if cand not in kept:
            kept.append(cand)
    return tuple(kept)


def predictions(program, execution, sig: GraphSignature, num_queries: int,
                candidates: Sequence[Candidate],
                coeffs: CostCoeffs = DEFAULT_COEFFS) -> Dict[Candidate, float]:
    """Modeled cost of every candidate (the ``--no-measure`` ranking)."""
    out = {}
    for cand in candidates:
        prog_c, ex_c = cand.apply(program, execution)
        out[cand] = predict_us(prog_c, ex_c, sig, num_queries, coeffs)
    return out
