"""Persistent tuning cache keyed by (graph signature, sampler, machine).

Tuned knob choices are a function of *(graph, sampler, machine,
workload)* — not constants — so the cache key folds in:

  * the **graph signature**: n, m, max degree, payload flags, and two
    degree-quantile ladders (plain and degree-weighted; the weighted
    ladder is what predicts the live-lane max degree of a W-lane pool,
    see `repro.tune.model.live_max_degree`);
  * the **sampler kind** (each kind has its own DMA schedule and
    bytes/hop profile);
  * the **machine axes**: backend, ``step_impl``, device kind, and the
    Pallas interpret flag (interpreted kernels have a completely
    different cost profile than compiled ones);
  * the **workload bucket**: a power-of-two bucket of the closed-batch
    query count (the optimal lane-pool width depends on how much work
    is offered; bucketing bounds distinct entries).

The store is a flat JSON file so tuned configs can be committed to the
repo and reused across sessions/CI (`python -m repro.tune` writes one;
``RIDGEWALKER_TUNE_CACHE`` points the compile-time resolver at it).
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Optional, Tuple

import numpy as np

# Quantile ladders stored in the signature.  The weighted ladder is
# denser near 1.0 because live-lane-max prediction interpolates at
# q = 0.5**(1/W), which approaches 1.0 as the lane pool widens.
PLAIN_QS: Tuple[float, ...] = (0.05, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0)
WEIGHTED_QS: Tuple[float, ...] = (0.5, 0.75, 0.9, 0.95, 0.975, 0.99,
                                  0.999, 1.0)

_ENV_CACHE = "RIDGEWALKER_TUNE_CACHE"
_SCHEMA_VERSION = 1


@dataclasses.dataclass(frozen=True)
class GraphSignature:
    """Degree-skew fingerprint of a graph (the tuning-relevant shape).

    Two graphs with the same signature get the same tuned knobs: the
    cost model only reads sizes and the degree distribution, never the
    adjacency itself.
    """

    num_vertices: int
    num_edges: int
    max_degree: int
    weighted: bool
    typed: bool
    deg_q: Tuple[int, ...]    # plain degree quantiles at PLAIN_QS
    deg_wq: Tuple[int, ...]   # degree-weighted quantiles at WEIGHTED_QS

    def token(self) -> str:
        """Stable string form used inside cache keys."""
        q = ".".join(str(v) for v in self.deg_q)
        wq = ".".join(str(v) for v in self.deg_wq)
        return (f"n{self.num_vertices}-m{self.num_edges}"
                f"-dmax{self.max_degree}"
                f"-w{int(self.weighted)}-t{int(self.typed)}"
                f"-q{q}-wq{wq}")


def _degree_quantile(sorted_deg: np.ndarray, q: float) -> int:
    """Plain quantile of the (sorted ascending) degree array."""
    i = min(int(q * (sorted_deg.size - 1) + 0.5), sorted_deg.size - 1)
    return int(sorted_deg[i])


def _weighted_quantile(sorted_deg: np.ndarray, cum: np.ndarray,
                       q: float) -> int:
    """Degree-weighted quantile: the degree d such that a fraction ``q``
    of *edge endpoints* live at vertices of degree <= d.  This is the
    distribution a uniformly random walk actually visits (walks land on
    vertices proportionally to degree), hence the predictor for the max
    degree among W live lanes."""
    i = int(np.searchsorted(cum, q * cum[-1]))
    return int(sorted_deg[min(i, sorted_deg.size - 1)])


def graph_signature(graph) -> GraphSignature:
    """Fingerprint a `CSRGraph` or `PartitionedGraph` for the cache."""
    row_ptr = np.asarray(graph.row_ptr)
    if row_ptr.ndim == 2:       # PartitionedGraph: per-device row pointers
        deg = np.diff(row_ptr, axis=1).reshape(-1)
    else:
        deg = np.diff(row_ptr)
    deg = deg.astype(np.int64)
    if deg.size == 0:
        deg = np.zeros((1,), np.int64)
    sd = np.sort(deg)
    cum = np.cumsum(sd)
    if cum[-1] == 0:
        cum = cum + 1  # degenerate edgeless graph: keep searchsorted sane
    return GraphSignature(
        num_vertices=int(getattr(graph, "num_vertices", deg.size)),
        num_edges=int(getattr(graph, "num_edges", int(deg.sum()))),
        max_degree=int(getattr(graph, "max_degree", int(sd[-1]))),
        weighted=getattr(graph, "weights", None) is not None,
        typed=getattr(graph, "edge_type", None) is not None,
        deg_q=tuple(_degree_quantile(sd, q) for q in PLAIN_QS),
        deg_wq=tuple(_weighted_quantile(sd, cum, q) for q in WEIGHTED_QS),
    )


def workload_bucket(num_queries: Optional[int]) -> int:
    """Power-of-two bucket (>= 64) of a closed-batch query count; 0 when
    the workload size is unknown (stream/serve resolution)."""
    if not num_queries or num_queries <= 0:
        return 0
    b = 64
    while b < num_queries:
        b <<= 1
    return b


def cache_key(sig: GraphSignature, kind: str, backend: str, step_impl: str,
              device_kind: str, interpret: bool,
              num_queries: Optional[int] = None) -> str:
    """The full lookup key: sampler x machine x workload x graph."""
    return (f"{kind}|{backend}|{step_impl}|{device_kind}"
            f"|interp{int(bool(interpret))}"
            f"|q{workload_bucket(num_queries)}|{sig.token()}")


def default_cache_path() -> Optional[str]:
    """Cache file named by ``RIDGEWALKER_TUNE_CACHE`` (None: in-memory)."""
    p = os.environ.get(_ENV_CACHE, "").strip()
    return p or None


class TuningCache:
    """JSON-backed map: cache key -> {"knobs": {...}, "meta": {...}}.

    ``path=None`` gives a process-local in-memory cache (resolution
    still dedupes work within one process, nothing is persisted).
    A missing or unreadable file is treated as empty — a stale or
    corrupt committed cache must never break compilation.
    """

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._entries: Dict[str, dict] = {}
        if path and os.path.exists(path):
            try:
                with open(path) as f:
                    data = json.load(f)
                if (isinstance(data, dict)
                        and data.get("version") == _SCHEMA_VERSION
                        and isinstance(data.get("entries"), dict)):
                    self._entries = dict(data["entries"])
            except (OSError, ValueError):
                self._entries = {}

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, key: str) -> Optional[dict]:
        """The stored ``{"knobs": ..., "meta": ...}`` record, or None."""
        rec = self._entries.get(key)
        if not isinstance(rec, dict) or "knobs" not in rec:
            return None
        return rec

    def put(self, key: str, knobs: dict, meta: Optional[dict] = None) -> None:
        """Store a tuned knob assignment (JSON-serializable values only)."""
        self._entries[key] = {"knobs": dict(knobs), "meta": dict(meta or {})}

    def save(self, path: Optional[str] = None) -> Optional[str]:
        """Write the cache to ``path`` (or the construction path)."""
        p = path or self.path
        if not p:
            return None
        with open(p, "w") as f:
            json.dump({"version": _SCHEMA_VERSION, "entries": self._entries},
                      f, indent=2, sort_keys=True)
            f.write("\n")
        return p
