"""Tuning orchestration: cache -> model -> (optional) measurement.

Two entry points:

* :func:`resolve` — what ``walker/compile.py`` calls when an
  ``ExecutionConfig`` carries ``"auto"`` sentinels (or a reservoir spec
  carries ``adaptive_chunks="auto"``).  **Never times anything**: it
  answers from the tuning cache, falling back to the analytical model
  (`repro.tune.model`) on a miss — so compiling a Walker stays
  deterministic and lint-clean.  Populate the cache with measured
  entries via ``python -m repro.tune``.

* :func:`autotune` — the full measurement-driven loop: enumerate the
  valid knob grid, measure a small *anchor* set, fit the roofline
  coefficients from those samples, model-prune the grid to ``keep``
  candidates, measure the survivors interleaved, and pick the winner.
  The default configuration is always kept in the measured set and the
  winner must beat it by ``min_gain`` — so a tuned config can never
  lose to the default it replaced (the tuned-vs-default benchmark
  invariant).  Pass an :class:`~repro.tune.measure.InjectedMeasurer`
  to run the whole loop deterministically (tests), or
  ``measurer=None`` for model-only mode (``--no-measure``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.tune import model as _model
from repro.tune.cache import (TuningCache, cache_key, default_cache_path,
                              graph_signature)
from repro.tune.space import (EXEC_KNOBS, Candidate, default_candidate,
                              enumerate_candidates, knobs_for)


def _device_kind() -> str:
    import jax
    return jax.devices()[0].platform


def _interpret_mode() -> bool:
    from repro.kernels.common import default_interpret
    return bool(default_interpret(None))


def needs_resolution(program, execution) -> bool:
    """Does this (program, execution) carry any unresolved sentinel?"""
    if getattr(execution, "has_auto", False):
        return True
    return (program.spec.kind == "reservoir_n2v"
            and program.spec.adaptive_chunks == "auto")


@dataclasses.dataclass
class TuneResult:
    """Outcome of one tuning run (see :func:`autotune`)."""

    candidate: Candidate
    program: object
    execution: object
    key: str
    signature: object
    source: str                        # "cache" | "model" | "measured"
    measured: Dict[Candidate, float]
    predicted: Dict[Candidate, float]
    coeffs: Optional[_model.CostCoeffs] = None


def _filter_to_known(knobs: dict, program, execution, backend: str,
                     include_resampling: bool) -> dict:
    """Keep only cached knob values that are valid axes here and now."""
    valid = {k.name: k for k in knobs_for(program, execution, backend)}
    out = {}
    for name, val in knobs.items():
        k = valid.get(name)
        if k is None:
            continue
        if not include_resampling and not k.path_preserving:
            continue
        out[name] = val
    return out


def _complete(partial: dict, program, execution, backend: str) -> Candidate:
    """Fill unassigned knobs with the default-candidate values."""
    knobs = knobs_for(program, execution, backend)
    vals = default_candidate(program, execution, knobs).to_dict()
    vals.update(partial)
    return Candidate.of(**vals)


def _build_runners(graph, program, execution, backend, candidates,
                   num_queries, seed, runners=None):
    """Zero-arg blocking closed-run callables, one per candidate."""
    import jax
    import numpy as np

    from repro.walker.compile import compile as compile_walker
    n = int(graph.num_vertices)
    starts = (np.arange(int(num_queries), dtype=np.int64) % n).astype(
        np.int32)
    runners = dict(runners or {})
    for cand in candidates:
        if cand in runners:
            continue
        prog_c, ex_c = cand.apply(program, execution)
        walker = compile_walker(prog_c, backend=backend, execution=ex_c)

        def run(walker=walker):
            out = walker.run(graph, starts, seed=seed)
            jax.block_until_ready(out.stats.steps)
            return out

        runners[cand] = run
    return runners


def _anchors(candidates, default: Candidate) -> Tuple[Candidate, ...]:
    """Small fit set: the default plus one-knob-at-an-extreme variants.

    Varying one knob at a time to its grid extremes spreads the feature
    matrix enough for the least-squares fit without measuring the grid.
    """
    cand_set = {c.items for c in candidates}
    out = [default]
    base = default.to_dict()
    by_knob: Dict[str, list] = {}
    for c in candidates:
        d = c.to_dict()
        diff = [k for k, v in d.items() if base.get(k) != v]
        if len(diff) == 1:
            by_knob.setdefault(diff[0], []).append((d[diff[0]], c))
    for _name, vals in sorted(by_knob.items()):
        vals.sort(key=lambda t: (str(type(t[0])), t[0]))
        for pick in (vals[0][1], vals[-1][1]):
            if pick.items in cand_set and pick not in out:
                out.append(pick)
    return tuple(out)


def autotune(graph, program, execution=None, backend: str = "single", *,
             num_queries: int = 256, seed: int = 0, measurer=None,
             cache: Optional[TuningCache] = None, keep: int = 6,
             include_resampling: bool = False, min_gain: float = 0.02,
             coeffs: Optional[_model.CostCoeffs] = None,
             use_cache: bool = True) -> TuneResult:
    """Tune the knob grid for (graph, program, execution, backend).

    ``measurer=None`` ranks purely by the analytical model (the
    ``--no-measure`` mode); otherwise ``measurer`` is any
    `repro.tune.measure.Measurer`.  Returns a :class:`TuneResult` whose
    ``program``/``execution`` are the chosen concrete configs.
    """
    from repro.walker.execution import ExecutionConfig
    execution = execution or ExecutionConfig()
    sig = graph_signature(graph)
    base_coeffs = coeffs or _model.DEFAULT_COEFFS
    key = cache_key(sig, program.spec.kind, backend, execution.step_impl,
                    _device_kind(), _interpret_mode(), num_queries)
    cache = cache if cache is not None else TuningCache(default_cache_path())

    if use_cache:
        rec = cache.get(key)
        if rec is not None:
            known = _filter_to_known(rec["knobs"], program, execution,
                                     backend, include_resampling)
            cand = _complete(known, program, execution, backend)
            prog_c, ex_c = cand.apply(program, execution)
            return TuneResult(cand, prog_c, ex_c, key, sig, "cache", {}, {})

    default = _complete({}, program, execution, backend)
    if measurer is None:
        # Model-only: the adaptive-reservoir axis is decided by the skew
        # gate, not the byte model (the model cannot see the dynamic
        # loop-bound overhead, so it would always prefer adaptive).
        cands = enumerate_candidates(program, execution, backend,
                                     include_resampling=include_resampling,
                                     exclude=("adaptive_chunks",))
        preds = _model.predictions(program, execution, sig, num_queries,
                                   cands, base_coeffs)
        chosen = min(cands, key=lambda c: (preds[c], c != default))
        gate = {}
        if any(k.name == "adaptive_chunks"
               for k in knobs_for(program, execution, backend)):
            gate["adaptive_chunks"] = _model.adaptive_chunk_gate(
                sig, int(chosen.get("num_slots")),
                int(chosen.get("reservoir_chunk",
                               program.spec.reservoir_chunk)))
        chosen = _complete({**chosen.to_dict(), **gate}, program, execution,
                           backend)
        measured: Dict[Candidate, float] = {}
        fitted = None
        source = "model"
    else:
        cands = enumerate_candidates(program, execution, backend,
                                     include_resampling=include_resampling)
        anchors = _anchors(cands, default)
        runners = _build_runners(graph, program, execution, backend,
                                 anchors, num_queries, seed)
        anchor_cost = measurer(anchors, runners)
        rows, ys = [], []
        for c in anchors:
            prog_c, ex_c = c.apply(program, execution)
            rows.append(_model.features(prog_c, ex_c, sig, num_queries))
            ys.append(anchor_cost[c])
        fitted = _model.fit(rows, ys, base=base_coeffs)
        pruned = _model.prune(program, execution, sig, num_queries, cands,
                              keep=keep, coeffs=fitted,
                              always_keep=(default,))
        runners = _build_runners(graph, program, execution, backend, pruned,
                                 num_queries, seed, runners=runners)
        measured = dict(anchor_cost)
        measured.update(measurer(pruned, runners))
        best = min(measured, key=lambda c: (measured[c], c != default))
        # Hysteresis: deviate from the default only for a real win.
        if measured[best] > (1.0 - min_gain) * measured[default]:
            best = default
        chosen = best
        preds = _model.predictions(program, execution, sig, num_queries,
                                   [chosen, default], fitted)
        source = "measured"

    meta = {"source": source, "kind": program.spec.kind,
            "backend": backend, "step_impl": execution.step_impl,
            "num_queries": int(num_queries)}
    if measured:
        meta["measured_s"] = float(measured[chosen])
        meta["default_s"] = float(measured[default])
    cache.put(key, chosen.to_dict(), meta=meta)
    if use_cache:
        cache.save()
    prog_c, ex_c = chosen.apply(program, execution)
    return TuneResult(chosen, prog_c, ex_c, key, sig, source, measured,
                      dict(preds), fitted)


def resolve(program, execution, graph, backend: str = "single",
            num_queries: Optional[int] = None,
            cache: Optional[TuningCache] = None):
    """Resolve every ``"auto"`` sentinel to a concrete value.

    Cache hit -> the committed tuned value; miss -> analytical-model
    argmin (and the skew gate for ``adaptive_chunks``).  No wall-clock
    on any path, so Walker compilation stays deterministic; run
    ``python -m repro.tune`` to fill the cache with measured entries.
    Returns the concrete ``(program, execution)`` pair.
    """
    if not needs_resolution(program, execution):
        return program, execution
    sig = graph_signature(graph)
    if cache is None:
        path = getattr(execution, "tune_cache", None) or default_cache_path()
        cache = TuningCache(path)
    key = cache_key(sig, program.spec.kind, backend, execution.step_impl,
                    _device_kind(), _interpret_mode(), num_queries)
    rec = cache.get(key)
    cached = dict(rec["knobs"]) if rec else {}

    auto_names = tuple(execution.auto_knobs)
    chosen = {k: v for k, v in cached.items()
              if k in auto_names and k in EXEC_KNOBS}
    missing = [n for n in auto_names if n not in chosen]
    if missing:
        cands = enumerate_candidates(program, execution, backend,
                                     only=missing,
                                     exclude=("adaptive_chunks",))
        nq = num_queries or max(int(sig.num_vertices), 1)
        preds = _model.predictions(program, execution, sig, nq, cands)
        best = min(cands, key=lambda c: preds[c])
        chosen.update({k: v for k, v in best.to_dict().items()
                       if k in missing})
    ex2 = execution.resolved(**{k: v for k, v in chosen.items()
                                if k in EXEC_KNOBS})

    prog2 = program
    spec = program.spec
    if spec.kind == "reservoir_n2v" and spec.adaptive_chunks == "auto":
        if "adaptive_chunks" in cached:
            adaptive = bool(cached["adaptive_chunks"])
        else:
            adaptive = _model.adaptive_chunk_gate(sig, int(ex2.num_slots),
                                                  int(spec.reservoir_chunk))
        prog2 = dataclasses.replace(
            program, spec=dataclasses.replace(spec,
                                              adaptive_chunks=adaptive))
    return prog2, ex2
