"""Measurement backends for the autotuner.

**This is the only module in the deterministic tree allowed to touch
wall-clock** (the `repro.analysis` determinism lint allowlists exactly
this file).  Everything else in ``repro.tune`` works over injected
costs, the analytical model, or the cache — so tests exercise the full
tuning pipeline with a deterministic :class:`InjectedMeasurer` and the
library never times anything unless explicitly asked to.

A *measurer* is any callable

    measurer(candidates, runners) -> {candidate: cost}

where ``runners[c]`` is a zero-argument callable executing (and
blocking on) one full run under candidate ``c``.  The tuner builds the
runners; the measurer decides how to time them.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Mapping, Protocol, Sequence

from repro.tune.space import Candidate


class Measurer(Protocol):
    """Pluggable timing strategy (see module docstring for the shape)."""

    def __call__(self, candidates: Sequence[Candidate],
                 runners: Mapping[Candidate, Callable[[], object]],
                 ) -> Dict[Candidate, float]:
        """Cost (lower is better) per candidate."""
        ...


class InjectedMeasurer:
    """Deterministic measurer for tests: cost = ``cost_fn(candidate)``.

    Never calls the runners and never reads a clock, so a tuning run
    under an InjectedMeasurer is a pure function of its inputs.
    """

    def __init__(self, cost_fn: Callable[[Candidate], float]):
        self.cost_fn = cost_fn
        self.calls = 0

    def __call__(self, candidates, runners=None):
        """Evaluate ``cost_fn`` on every candidate."""
        self.calls += 1
        return {c: float(self.cost_fn(c)) for c in candidates}


class WalkMeasurer:
    """Interleaved min-of-k wall-clock timing of candidate runs.

    Each candidate's runner is executed once un-timed (compile + warm
    the jit cache), then the candidates are timed **interleaved** —
    round r times every candidate once before round r+1 starts — so
    slow machine-wide drift (thermal, background load) hits all
    candidates equally instead of biasing whichever ran last.  The
    min over rounds estimates the noise floor.
    """

    def __init__(self, repeats: int = 3, warmup: int = 1):
        if repeats <= 0:
            raise ValueError(f"repeats must be positive, got {repeats}")
        self.repeats = int(repeats)
        self.warmup = max(int(warmup), 0)

    def __call__(self, candidates, runners):
        """Time every candidate; returns best-of-``repeats`` seconds."""
        cands = list(candidates)
        for c in cands:
            for _ in range(self.warmup):
                runners[c]()
        best = {c: float("inf") for c in cands}
        for _ in range(self.repeats):
            for c in cands:
                t0 = time.perf_counter()
                runners[c]()
                dt = time.perf_counter() - t0
                if dt < best[c]:
                    best[c] = dt
        return best
