"""Autotune CLI: populate the persistent tuning cache.

    PYTHONPATH=src python -m repro.tune --cache tune_cache.json
    PYTHONPATH=src python -m repro.tune --no-measure      # model-only

Tunes one (sampler kind x step_impl) grid per requested combination on
a synthetic dataset matching the benchmark suites, writing each chosen
config into the JSON cache.  Point ``RIDGEWALKER_TUNE_CACHE`` at the
written file (or set ``ExecutionConfig.tune_cache``) and any
``ExecutionConfig`` with ``"auto"`` sentinels resolves through it.
"""
from __future__ import annotations

import argparse
import sys


def _program_for(kind: str, max_hops: int):
    from repro.walker.program import WalkProgram
    if kind == "uniform":
        return WalkProgram.urw(max_hops)
    if kind == "alias":
        return WalkProgram.deepwalk(max_hops)
    if kind == "rejection_n2v":
        return WalkProgram.node2vec(2.0, 0.5, max_hops)
    if kind == "reservoir_n2v":
        return WalkProgram.node2vec(2.0, 0.5, max_hops, weighted=True)
    if kind == "metapath":
        return WalkProgram.metapath([0, 1, 2], max_hops)
    raise SystemExit(f"unknown sampler kind {kind!r}")


def main(argv=None) -> int:
    from repro.graph import make_dataset
    from repro.tune import TuningCache, WalkMeasurer, autotune
    from repro.walker.execution import ExecutionConfig

    ap = argparse.ArgumentParser(
        prog="python -m repro.tune",
        description="populate the walk-engine tuning cache")
    ap.add_argument("--no-measure", action="store_true",
                    help="model-only ranking (no wall-clock)")
    ap.add_argument("--cache", default="tune_cache.json",
                    help="JSON cache path to read/extend (default: "
                         "tune_cache.json)")
    ap.add_argument("--dataset", default="WG")
    ap.add_argument("--scale", type=int, default=10)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--max-hops", type=int, default=16)
    ap.add_argument("--kinds", default="uniform,reservoir_n2v",
                    help="comma list of sampler kinds to tune")
    ap.add_argument("--step-impls", default="jnp",
                    help="comma list of step_impl values to tune")
    ap.add_argument("--keep", type=int, default=6,
                    help="model-pruned candidates to measure")
    ap.add_argument("--repeats", type=int, default=3,
                    help="min-of-k timing repeats")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--force", action="store_true",
                    help="retune even on a cache hit")
    args = ap.parse_args(argv)

    g = make_dataset(args.dataset, scale_override=args.scale, weighted=True,
                     with_alias=True, num_edge_types=3)
    cache = TuningCache(args.cache)
    measurer = None if args.no_measure else WalkMeasurer(
        repeats=args.repeats)
    mode = "model-only" if args.no_measure else "measured"
    for kind in [k for k in args.kinds.split(",") if k]:
        program = _program_for(kind, args.max_hops)
        for impl in [s for s in args.step_impls.split(",") if s]:
            execution = ExecutionConfig(record_paths=False, step_impl=impl)
            res = autotune(g, program, execution,
                           num_queries=args.queries, seed=args.seed,
                           measurer=measurer, cache=cache, keep=args.keep,
                           use_cache=not args.force)
            if args.force:
                cache.save()
            print(f"{kind}/{impl} [{res.source}] -> {res.candidate}")
    path = cache.save()
    print(f"# {mode} tuning cache: {len(cache)} entr"
          f"{'y' if len(cache) == 1 else 'ies'} -> {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
