"""Deliberately broken inputs proving each pass actually catches its
hazard class.

Each fixture builds a *mutated* copy of a real declaration (a valid
phase program with one phase moved, a valid schedule with one wait
dropped, …) and runs the single pass that owns the invariant.  The CLI
(``python -m repro.analysis --fixture NAME``) exits non-zero when
findings are produced — CI asserts every fixture trips, so a checker
regression that silently stops detecting a hazard class fails the
build, not a code review.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List

from repro.analysis import determinism, dma_hazards, residency, \
    rng_collisions
from repro.analysis.report import Finding
from repro.core.phase_program import DrawStream, _default_spec, lower
from repro.core.rng import SALT_CHUNK0, SALT_COLUMN
from repro.kernels.common import DmaOp
from repro.kernels.walk_step.walk_step import dma_schedule as ws_schedule


def _replace_phase(prog, i, **changes):
    phases = list(prog.phases)
    phases[i] = dataclasses.replace(phases[i], **changes)
    return dataclasses.replace(prog, phases=tuple(phases))


# ----------------------------------------------------------- rng fixtures


def rng_duplicate_salt() -> List[Finding]:
    """Two scalar streams of one task on the same salt channel — e.g. a
    second draw phase added without registering a new salt."""
    streams = (DrawStream("fixture.draw_a", SALT_COLUMN, 2),
               DrawStream("fixture.draw_b", SALT_COLUMN, 1))
    return rng_collisions.check_streams(streams, context="fixture")


def rng_chunk_overlap() -> List[Finding]:
    """A scalar stream salted inside the open-ended chunk family — the
    chunk-c draw with c = salt - SALT_CHUNK0 collides with it."""
    streams = (DrawStream("fixture.reservoir", SALT_CHUNK0, 64,
                          family=True),
               DrawStream("fixture.extra", SALT_CHUNK0 + 3, 4))
    return rng_collisions.check_streams(streams, context="fixture")


def rng_corpus_salt_reuse() -> List[Finding]:
    """The corpus-ring negatives draw put back on a walk channel — the
    defect the SALT_NEGATIVE registration exists to prevent.  Consumer
    batches fold (qid=batch element, hop=grad step) under the round-0
    stream key, the very tuples walk tasks fold, so a consumer stream on
    SALT_COLUMN collides with the uniform sampler's column draw."""
    streams = rng_collisions.spec_streams(_default_spec("uniform"))
    streams += (DrawStream("fixture.corpus_negatives", SALT_COLUMN, 5),)
    return rng_collisions.check_streams(streams, context="fixture")


def rng_literal_salt() -> List[Finding]:
    """A call site passing a raw integer salt the registry never saw."""
    src = ("from repro.core import rng as task_rng\n"
           "def f(base_key, qid, hop):\n"
           "    return task_rng.task_uniforms(base_key, qid, hop, 2, 5)\n")
    return rng_collisions.check_source(src, "fixture/literal_salt.py")


# ----------------------------------------------------------- dma fixtures


def dma_missing_wait() -> List[Finding]:
    """A gather loop with one copy-wait dropped: the read consumes the
    slot while its copy is still in flight (read-before-arrival), and
    the copy is never drained."""
    ops = [op for op in ws_schedule("uniform")
           if not (op.kind == "wait" and op.buffer == "rpbuf"
                   and op.copy == 1)]
    return dma_hazards.check_schedule(ops, "fixture.missing_wait")


def dma_overwrite_in_flight() -> List[Finding]:
    """Ping-pong slots swapped to a single slot: copy i+1 re-issues the
    slot copy i still occupies (overwrite-while-in-flight)."""
    ops = [op._replace(slot=0) if op.buffer == "colbuf" else op
           for op in ws_schedule("uniform")]
    return dma_hazards.check_schedule(ops, "fixture.overwrite")


def dma_undrained() -> List[Finding]:
    """A trailing prefetch with no drain before the kernel returns."""
    ops = list(ws_schedule("uniform"))
    ops.append(DmaOp("start", "colbuf", 0, copy=999))
    return dma_hazards.check_schedule(ops, "fixture.undrained")


def dma_cached_phantom_copy() -> List[Finding]:
    """A cached gather op that still issues an HBM copy on the hit path:
    the cache probe resolved the vertex on-chip, yet the emitter started
    a DMA into the cache-tier column buffer anyway.  Bit-identical in
    result (the same bytes arrive) but the latency win is gone — exactly
    the silent regression the phantom-copy rule exists to trip."""
    from repro.kernels.fused_superstep.fused_superstep import \
        dma_schedule as fused_schedule
    ops = list(fused_schedule("uniform", cached=True))
    hit = next(i for i, op in enumerate(ops)
               if op.kind == "read" and op.tier == "vmem"
               and op.buffer == "cache.col")
    ops.insert(hit, DmaOp("start", "cache.col", 0, copy=990))
    return dma_hazards.check_schedule(ops, "fixture.cached_phantom")


def visit_nonconsecutive() -> List[Finding]:
    """segment-sum visiting a block, leaving it, then returning — the
    revisit contract an unsorted segment vector would break."""
    ops = [DmaOp("visit", "out", 0, first=True),
           DmaOp("visit", "out", 1, first=True),
           DmaOp("visit", "out", 0, first=False)]
    return dma_hazards.check_schedule(ops, "fixture.nonconsecutive")


def visit_bad_first() -> List[Finding]:
    """first_visit set on a revisit — would zero a partial accumulation."""
    ops = [DmaOp("visit", "out", 0, first=True),
           DmaOp("visit", "out", 0, first=True)]
    return dma_hazards.check_schedule(ops, "fixture.bad_first")


# ----------------------------------------------------- residency fixtures


def residency_vprev_draw() -> List[Finding]:
    """A single_phase program with its draw moved to owner(v_prev) —
    the interpreter has no superstep to run it in."""
    prog = _replace_phase(lower(_default_spec("uniform")), 0,
                          residency="v_prev")
    return residency.check_program(prog)


def residency_missing_carry() -> List[Finding]:
    """A two_phase program whose carry was dropped: the verify superstep
    at owner(v_prev) would receive no candidate payload."""
    prog = dataclasses.replace(lower(_default_spec("rejection_n2v")),
                               carry="none")
    return residency.check_program(prog)


# --------------------------------------------------- determinism fixtures


def determinism_jax_random() -> List[Finding]:
    """An ambient jax.random draw inside the deterministic tree."""
    src = ("import jax\n"
           "def sample(key, n):\n"
           "    return jax.random.uniform(key, (n,))\n")
    return determinism.check_source(src, "fixture/ambient_random.py")


def determinism_no_interpret() -> List[Finding]:
    """A pallas_call wrapper with no interpret plumbing."""
    src = ("from jax.experimental import pallas as pl\n"
           "def launch(x):\n"
           "    return pl.pallas_call(lambda r, o: None)(x)\n")
    return determinism.check_source(src, "fixture/no_interpret.py")


def determinism_tune_clock() -> List[Finding]:
    """A wall-clock read leaking out of tune/measure.py into the rest of
    the autotuner — e.g. the candidate space or cost model timing itself.
    Only measure.py may touch the clock; everything the compile path
    imports (space, model, cache, tuner) must stay replayable."""
    src = ("import time\n"
           "def knob_grid():\n"
           "    t0 = time.perf_counter()\n"
           "    return [2 ** k for k in range(5)], t0\n")
    return determinism.check_source(src, "fixture/tune/space.py")


FIXTURES: Dict[str, Callable[[], List[Finding]]] = {
    "rng-duplicate-salt": rng_duplicate_salt,
    "rng-chunk-overlap": rng_chunk_overlap,
    "rng-corpus-salt-reuse": rng_corpus_salt_reuse,
    "rng-literal-salt": rng_literal_salt,
    "dma-missing-wait": dma_missing_wait,
    "dma-overwrite-in-flight": dma_overwrite_in_flight,
    "dma-undrained": dma_undrained,
    "dma-cached-phantom-copy": dma_cached_phantom_copy,
    "visit-nonconsecutive": visit_nonconsecutive,
    "visit-bad-first": visit_bad_first,
    "residency-vprev-draw": residency_vprev_draw,
    "residency-missing-carry": residency_missing_carry,
    "determinism-jax-random": determinism_jax_random,
    "determinism-no-interpret": determinism_no_interpret,
    "determinism-tune-clock": determinism_tune_clock,
}


def run_fixture(name: str) -> List[Finding]:
    return FIXTURES[name]()
