"""Generated docs tables: the statically-verified-invariants summary
embedded in ``docs/architecture.md`` (regenerate with
``python -m repro.analysis --table``; drift fails ``--check`` and CI)."""
from __future__ import annotations

from repro.analysis.rng_collisions import spec_streams
from repro.core.phase_program import _default_spec
from repro.core.rng import SALTS
from repro.core.samplers import KINDS
from repro.kernels.common import schedule_buffers


def _span(stream) -> str:
    lo, hi = stream.salt_span()
    if hi is None:
        return f"[{lo}, ∞)"
    if hi == lo + 1:
        return f"{lo}"
    return f"[{lo}, {hi})"


def render_salt_table() -> str:
    lines = ["| channel | salt | shape |", "|---|---|---|"]
    for ch in SALTS.channels():
        shape = f"family `[{ch.value}, ∞)` (one salt per chunk)" \
            if ch.family else "scalar"
        lines.append(f"| `{ch.name}` | {ch.value} | {shape} |")
    return "\n".join(lines)


def render_stream_table() -> str:
    lines = ["| sampler | draw stream | salt span | uniforms/task |",
             "|---|---|---|---|"]
    for kind in KINDS:
        for s in spec_streams(_default_spec(kind)):
            lines.append(f"| {kind} | `{s.site}` | {_span(s)} "
                         f"| {s.width} |")
    return "\n".join(lines)


def render_schedule_table() -> str:
    from repro.analysis.dma_hazards import kernel_schedules
    lines = ["| kernel schedule | buffers | ops | async copies |",
             "|---|---|---|---|"]
    for name, ops in kernel_schedules().items():
        bufs = ", ".join(f"`{b}`" for b in schedule_buffers(ops))
        copies = sum(1 for op in ops if op.kind == "start")
        lines.append(f"| `{name}` | {bufs} | {len(ops)} | {copies} |")
    return "\n".join(lines)


def render_table() -> str:
    """The full --table output (every line embedded in the docs)."""
    return "\n\n".join([
        "Salt channels (uniqueness asserted at import, "
        "`rng.SaltRegistry`):",
        render_salt_table(),
        "Per-task draw streams (pairwise salt-disjoint, proven by the "
        "`rng` pass):",
        render_stream_table(),
        "Declared kernel DMA schedules (hazard-free, proven by the "
        "`dma` pass):",
        render_schedule_table(),
    ])
