"""CLI for the static verifier.

``--check``          run all four passes over the repo (and verify the
                     docs embed the generated --table output); exit 1
                     with per-finding diagnostics on any violation.
``--table``          print the statically-verified-invariants summary
                     (embedded in docs/architecture.md).
``--fixture NAME``   run one deliberately-broken fixture; exits 1 when
                     the defect is (correctly) caught — CI asserts this
                     for every fixture so the checkers can't silently
                     rot.
``--list-fixtures``  print the fixture names.
"""
from __future__ import annotations

import argparse
import pathlib

from repro.analysis import run_all
from repro.analysis.fixtures import FIXTURES, run_fixture
from repro.analysis.report import render_findings
from repro.analysis.tables import render_table


def _check_docs_embedding() -> int:
    """The --table output must appear verbatim in docs/architecture.md
    (same discipline as `repro.core.phase_program --check`)."""
    root = pathlib.Path(__file__).resolve().parents[3]
    doc = root / "docs" / "architecture.md"
    text = doc.read_text() if doc.exists() else ""
    missing = [ln for ln in render_table().splitlines()
               if ln and ln not in text]
    if missing:
        print(f"DRIFT: {doc} is missing {len(missing)} generated "
              f"invariant-table line(s):")
        for ln in missing:
            print(f"  {ln}")
        print("regenerate with `python -m repro.analysis --table` and "
              "paste the output into the docs")
        return 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static pipeline-hazard / RNG-collision / residency "
                    "/ determinism verifier.")
    ap.add_argument("--check", action="store_true",
                    help="run all passes over the repo; exit 1 on any "
                         "finding or docs drift")
    ap.add_argument("--table", action="store_true",
                    help="print the statically-verified-invariants "
                         "summary tables")
    ap.add_argument("--fixture", metavar="NAME",
                    help="run one injected-defect fixture; exit 1 when "
                         "its defect is detected")
    ap.add_argument("--list-fixtures", action="store_true",
                    help="list fixture names")
    args = ap.parse_args(argv)

    if args.list_fixtures:
        for name in FIXTURES:
            print(name)
        return 0
    if args.fixture:
        if args.fixture not in FIXTURES:
            known = ", ".join(FIXTURES)
            print(f"unknown fixture {args.fixture!r} (known: {known})")
            return 2
        findings = run_fixture(args.fixture)
        print(render_findings(findings))
        return 1 if findings else 0
    if args.table:
        print(render_table())
        return 0
    # default: --check
    findings = run_all()
    print(render_findings(findings))
    code = 1 if findings else 0
    code = max(code, _check_docs_embedding())
    if code == 0:
        print("docs embedding up to date")
    return code


if __name__ == "__main__":
    raise SystemExit(main())
