"""Finding record + rendering shared by every analysis pass."""
from __future__ import annotations

from typing import NamedTuple, Sequence


class Finding(NamedTuple):
    """One verified-invariant violation.

    ``pass_name`` — rng | dma | residency | determinism.
    ``site``      — where (stream site, schedule op index, file:line).
    ``message``   — what is wrong and what would fix it (diagnostics are
                    actionable: they name the offending salts / copy ids
                    / phases, not just "check failed").
    """

    pass_name: str
    site: str
    message: str

    def __str__(self) -> str:
        return f"[{self.pass_name}] {self.site}: {self.message}"


def render_findings(findings: Sequence[Finding]) -> str:
    """Stable plain-text report (sorted; one finding per line)."""
    if not findings:
        return "all invariants hold"
    lines = [str(f) for f in sorted(findings)]
    lines.append(f"{len(findings)} finding(s)")
    return "\n".join(lines)
