"""RNG-collision pass: prove every per-task draw stream disjoint.

The RNG contract (`core/rng.py`): a draw stream is identified by the
Threefry key fold ``(seed[, epoch], query_id, hop, salt)`` plus a
counter range ``[0, width)``.  Epoch / query / hop are folded into the
key, so two streams of the *same* task can only be separated by their
salt channel — distinct salts → disjoint streams (injective key fold),
a shared salt value → both streams consume counters ``[0, width)``
there and collide on ``[0, min(widths))``.

The model is built from the declarative exports, once per logical
stream (the jnp path, the sharded supersteps, and the fused kernel all
issue the *same* logical draws — bit-identity across backends is the
repo's pinned property, so modelling each call site separately would
triple-count the streams, not find more collisions):

  * `PhaseProgram.draw_streams()` — one stream per ``draw`` phase; a
    looping program's stream is an open-ended *family* at
    ``[salt, ∞)`` (one chunk per salt, degree-dependent count);
  * `walk_engine.ENGINE_DRAW_STREAMS` — engine-issued draws (the PPR
    stop draw) outside the phase programs;
  * `corpus_ring.CORPUS_DRAW_STREAMS` — the corpus-ring batch sampler's
    window/negative draws.  The consumer folds ``(qid=batch element,
    hop=grad step)`` under the round-0 stream key — the *same* fold
    tuples walk tasks use — so its channels must be disjoint from every
    sampler and engine channel, and they join each kind's stream set.

The AST side then keeps the model honest: every
``task_uniforms`` / ``task_key_pair`` / ``task_bits`` / ``task_fold``
call site in ``src/repro/{core,kernels,walker}`` must pass a salt that
is a registered `SaltRegistry` channel (a ``SALT_*`` name, a
``SALT_CHUNK0 + c`` family member, or an IR-supplied ``.salt``
attribute) — so no code path can draw from a channel the stream model
doesn't know about.
"""
from __future__ import annotations

import ast
import pathlib
from typing import List, Sequence, Tuple

from repro.analysis.report import Finding
from repro.core.corpus_ring import CORPUS_DRAW_STREAMS
from repro.core.phase_program import DrawStream, _default_spec, lower
from repro.core.rng import SALTS
from repro.core.samplers import KINDS
from repro.core.walk_engine import ENGINE_DRAW_STREAMS

_RNG_FNS = {"task_uniforms": 4, "task_bits": 4, "task_key_pair": 4,
            "task_fold": 3}  # fn -> positional index of the salt arg
_SCOPE = ("core", "kernels", "walker")


# ------------------------------------------------------------ stream model


def spec_streams(spec) -> Tuple[DrawStream, ...]:
    """All draw streams one sampler spec's tasks consume: the lowered
    program's streams, the engine-issued ones, and the corpus-ring
    consumer's (its (qid, hop) tuples overlap walk tasks under the
    round-0 key, so it shares the task fold space)."""
    streams = list(lower(spec).draw_streams())
    for site, salt, width in ENGINE_DRAW_STREAMS:
        streams.append(DrawStream(site=site, salt=salt, width=width))
    for site, salt, width in CORPUS_DRAW_STREAMS:
        streams.append(DrawStream(site=site, salt=salt, width=width))
    return tuple(streams)


def _span_overlap(a: DrawStream, b: DrawStream):
    """Intersection of two salt spans, or None (``hi=None`` = ∞)."""
    lo_a, hi_a = a.salt_span()
    lo_b, hi_b = b.salt_span()
    lo = max(lo_a, lo_b)
    if hi_a is None:
        hi = hi_b
    elif hi_b is None:
        hi = hi_a
    else:
        hi = min(hi_a, hi_b)
    if hi is not None and lo >= hi:
        return None
    return (lo, hi)


def check_streams(streams: Sequence[DrawStream],
                  context: str = "") -> List[Finding]:
    """Pairwise salt-disjointness over one task's streams."""
    findings = []
    tag = f"{context}: " if context else ""
    for i, a in enumerate(streams):
        for b in streams[i + 1:]:
            span = _span_overlap(a, b)
            if span is None:
                continue
            lo, hi = span
            salts = f"salt {lo}" if hi == lo + 1 else (
                f"salts [{lo}, {'∞' if hi is None else hi})")
            w = min(a.width, b.width)
            findings.append(Finding(
                "rng", f"{a.site} × {b.site}",
                f"{tag}streams share {salts}: both consume counters "
                f"[0, {w}) there (same (seed, epoch, qid, hop) fold) — "
                f"give one a distinct SaltRegistry channel"))
    return findings


def check_kinds() -> List[Finding]:
    """Disjointness for every sampler kind's default spec."""
    findings = []
    for kind in KINDS:
        findings += check_streams(spec_streams(_default_spec(kind)),
                                  context=f"kind={kind}")
    return findings


# --------------------------------------------------------- call-site audit


def _classify_salt(node: ast.expr):
    """Classify a salt argument expression.

    Returns (status, detail): ``ok`` (registered channel name or chunk
    family), ``ir`` (attribute access — the salt rides the phase IR,
    already covered by the stream model), or ``bad``.
    """
    if isinstance(node, ast.Name):
        if node.id in SALTS.names():
            return "ok", node.id
        return "bad", f"unregistered salt name {node.id!r}"
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Add):
        base = node.left
        if (isinstance(base, ast.Name) and base.id in SALTS.names()
                and SALTS[base.id].family):
            return "ok", f"{base.id} + <chunk>"
        return "bad", "salt arithmetic must be <family channel> + offset"
    if isinstance(node, ast.Attribute):
        return "ir", f".{node.attr}"
    if isinstance(node, ast.Constant):
        return "bad", (f"literal salt {node.value!r} — use a named "
                       f"SaltRegistry channel (SALT_*)")
    return "bad", f"unrecognized salt expression {ast.dump(node)[:60]}"


def check_call_sites(root=None) -> List[Finding]:
    """AST audit: every rng call site's salt is a registered channel."""
    root = pathlib.Path(root) if root else _src_root()
    findings = []
    for sub in _SCOPE:
        for py in sorted((root / sub).rglob("*.py")):
            findings += check_source(py.read_text(),
                                     str(py.relative_to(root.parent)))
    return findings


def check_source(source: str, filename: str) -> List[Finding]:
    """Audit one module's rng call sites (exposed for fixtures/tests)."""
    findings = []
    if filename.endswith("core/rng.py"):
        return findings  # the registry itself defines the channels
    tree = ast.parse(source, filename=filename)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        name = fn.attr if isinstance(fn, ast.Attribute) else (
            fn.id if isinstance(fn, ast.Name) else None)
        if name not in _RNG_FNS:
            continue
        pos = _RNG_FNS[name]
        salt_node = None
        if len(node.args) > pos:
            salt_node = node.args[pos]
        else:
            for kw in node.keywords:
                if kw.arg == "salt":
                    salt_node = kw.value
        if salt_node is None:
            continue  # salt defaulted (SALT_COLUMN)
        status, detail = _classify_salt(salt_node)
        if status == "bad":
            findings.append(Finding(
                "rng", f"{filename}:{node.lineno}",
                f"{name}(...) salt: {detail}"))
    return findings


def _src_root() -> pathlib.Path:
    return pathlib.Path(__file__).resolve().parents[1]


def check_repo() -> List[Finding]:
    return check_kinds() + check_call_sites()
