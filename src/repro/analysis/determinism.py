"""Determinism lint: AST pass over the deterministic core.

The repo's bit-identity guarantees (same walks across jnp / sharded /
fused backends, same draws across supersteps) hold only because every
random bit flows through the stateless counter RNG in `core/rng.py` and
every Pallas kernel can be forced into interpret mode off-TPU.  This
pass bans the ways that discipline erodes:

  * ``jax.random.*`` anywhere in ``src/repro/{core,kernels,walker,tune}``
    except `core/rng.py` itself (ambient PRNG keys fork the stream
    model; `rng.stream_key` / `rng.task_uniforms` are the blessed
    entries);
  * ``numpy.random`` / ``np.random`` and ``time.time`` / wall-clock
    calls in the same tree (host-side randomness or timing leaking into
    sampler/kernel paths breaks replay; benchmarks and dataset builders
    live outside the linted tree on purpose).  The autotuner is linted
    too: `tune/measure.py` is the *only* module allowed to read the
    clock, so cache/model-driven resolution on the compile path is
    provably wall-clock-free;
  * Pallas plumbing: every function that calls ``pl.pallas_call`` must
    take an ``interpret`` parameter, and every ``kernels/*/ops.py``
    wrapper module must route it through
    `kernels.common.default_interpret` (otherwise CPU CI silently stops
    exercising the kernel bodies).
"""
from __future__ import annotations

import ast
import pathlib
from typing import List

from repro.analysis.report import Finding

_SCOPE = ("core", "kernels", "walker", "tune")
_ALLOWED = ("core/rng.py", "tune/measure.py")


def _dotted(node: ast.expr) -> str:
    """Best-effort dotted name of an attribute/name expression."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


def check_source(source: str, filename: str) -> List[Finding]:
    findings = []
    if any(filename.endswith(a) for a in _ALLOWED):
        return findings
    tree = ast.parse(source, filename=filename)

    def flag(node, msg):
        findings.append(Finding("determinism",
                                f"{filename}:{node.lineno}", msg))

    for node in ast.walk(tree):
        if isinstance(node, (ast.Import, ast.ImportFrom)):
            mods = []
            if isinstance(node, ast.Import):
                mods = [a.name for a in node.names]
            elif node.module:
                mods = [f"{node.module}.{a.name}" for a in node.names]
            for m in mods:
                if m.startswith("jax.random") or m == "jax.random":
                    flag(node, "imports jax.random — all draws must go "
                               "through core/rng.py's counter RNG")
        if isinstance(node, ast.Attribute):
            dotted = _dotted(node)
            if dotted.startswith("jax.random."):
                flag(node, f"{dotted} — ambient PRNG outside core/rng.py"
                           f"; use rng.stream_key / rng.task_uniforms")
            elif dotted.startswith(("np.random.", "numpy.random.")):
                flag(node, f"{dotted} — host randomness in the "
                           f"deterministic tree; thread an explicit "
                           f"seed through core/rng.py")
            elif dotted in ("time.time", "time.time_ns",
                            "time.perf_counter"):
                flag(node, f"{dotted} — wall-clock in the deterministic "
                           f"tree breaks replay; timing belongs in "
                           f"benchmarks/")
    _PallasVisitor(flag).visit(tree)
    return findings


class _PallasVisitor(ast.NodeVisitor):
    """Flags ``pallas_call`` sites with no ``interpret`` parameter on
    any enclosing function (a jitted closure may capture the resolved
    flag from its builder — that counts)."""

    def __init__(self, flag):
        self._flag = flag
        self._stack: list = []

    def visit_FunctionDef(self, node):
        self._stack.append(node)
        self.generic_visit(node)
        self._stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Call(self, node):
        if _dotted(node.func).endswith("pallas_call"):
            plumbed = any(
                "interpret" in [a.arg for a in (f.args.args
                                                + f.args.kwonlyargs)]
                for f in self._stack)
            if not plumbed:
                name = self._stack[-1].name if self._stack else "<module>"
                self._flag(node, f"{name} calls pl.pallas_call without "
                                 f"an 'interpret' parameter in scope — "
                                 f"plumb it through kernels.common."
                                 f"default_interpret so CPU CI "
                                 f"interprets the kernel body")
        self.generic_visit(node)


def _check_ops_module(source: str, filename: str) -> List[Finding]:
    """kernels/*/ops.py must resolve interpret via default_interpret."""
    if "default_interpret" in source:
        return []
    return [Finding(
        "determinism", filename,
        "kernel wrapper module never calls default_interpret — "
        "per-call interpret overrides must default to 'interpret "
        "off-TPU' (kernels/common.default_interpret)")]


def check_repo(root=None) -> List[Finding]:
    root = pathlib.Path(root) if root else \
        pathlib.Path(__file__).resolve().parents[1]
    findings = []
    for sub in _SCOPE:
        for py in sorted((root / sub).rglob("*.py")):
            rel = str(py.relative_to(root.parent))
            src = py.read_text()
            findings += check_source(src, rel)
            if py.name == "ops.py" and sub == "kernels":
                findings += _check_ops_module(src, rel)
    return findings
