"""Residency / schedule-legality pass over lowered phase programs.

The sharded interpreter (`core/distributed.ProgramCapability`) executes
whatever the phase program declares — so the program must actually be
executable under its contract.  This pass recomputes every derived fact
from the raw phase list (never trusting the ``schedule`` / ``capability``
/ ``fused`` / ``pallas`` properties it is checking) and verifies:

  * **phase grammar** — known (op, variant) pairs, exactly one trailing
    ``commit``, at least one ``draw`` before the first ``score``;
  * **residency legality** — ``v_prev`` operands exist only on ``score``
    phases (the interpreter only routes the verify/score superstep to
    owner(v_prev); a draw or gather at v_prev has no executor), and only
    under the ``two_phase`` / ``chunked_loop`` schedules;
  * **carry discipline** — a cross-residency split needs a task-word
    payload produced at owner(v_curr) before owner(v_prev) consumes it:
    ``candidates`` ⇒ a ``gather`` precedes the v_prev ``score``;
    ``reservoir`` ⇒ the looping chunk ``gather`` precedes the v_prev
    fold; single-residency programs must carry ``none`` (task words are
    sized from the carry — an oversized carry wastes the wire format, a
    missing one drops the payload);
  * **width plumbing** — a multi-candidate ``score`` consumes a
    ``gather`` of the same width, and the ``draw`` provides at least as
    many uniforms as the widest consumer;
  * **derived-flag honesty** — the ``schedule`` / ``capability`` /
    ``pallas`` properties equal their recomputation, and ``fused``
    stays total (the engine has no jnp fallback path to fall back to);
  * **requires completeness** — each gather segment declares its graph
    payload (``alias`` / ``typed`` / ``chunk``→``weights``).
"""
from __future__ import annotations

from typing import List

from repro.analysis.report import Finding
from repro.core.phase_program import PhaseProgram, _default_spec, lower
from repro.core.samplers import KINDS

_OPS = {("draw", ""), ("gather", "alias"), ("gather", "typed"),
        ("gather", "csr"), ("gather", "chunk"),
        ("score", "pick_uniform"), ("score", "alias_accept"),
        ("score", "first_accept"), ("score", "es_reservoir"),
        ("commit", "")}
_GATHER_REQUIRES = {"alias": "alias", "typed": "typed", "chunk": "weights"}


def check_program(prog: PhaseProgram) -> List[Finding]:
    findings = []
    kind = prog.kind

    def flag(site, msg):
        findings.append(Finding("residency", f"{kind}.{site}", msg))

    phases = prog.phases
    # ---- phase grammar --------------------------------------------------
    for n, ph in enumerate(phases):
        if (ph.op, ph.variant) not in _OPS:
            flag(f"phases[{n}]", f"unknown phase ({ph.op!r}, "
                 f"{ph.variant!r}) — no executor in any backend")
        if ph.residency not in ("v_curr", "v_prev"):
            flag(f"phases[{n}]", f"unknown residency {ph.residency!r}")
    commits = [n for n, ph in enumerate(phases) if ph.op == "commit"]
    if commits != [len(phases) - 1]:
        flag("phases", f"program must end with exactly one commit "
             f"(found commit at {commits or 'nowhere'}) — column access "
             f"and hop advance are engine-owned and run last")
    scores = [n for n, ph in enumerate(phases) if ph.op == "score"]
    draws = [n for n, ph in enumerate(phases) if ph.op == "draw"]
    if scores and (not draws or draws[0] > scores[0]):
        flag(f"phases[{scores[0]}]", "score precedes any draw — its "
             "uniforms are never produced")

    # ---- residency legality --------------------------------------------
    vprev = [n for n, ph in enumerate(phases) if ph.residency == "v_prev"]
    for n in vprev:
        if phases[n].op != "score":
            flag(f"phases[{n}]", f"{phases[n].op} phase at v_prev — the "
                 f"sharded interpreter only routes score phases to "
                 f"owner(v_prev); move the operand materialization to "
                 f"v_curr and thread it through the carry")

    # ---- recomputed schedule / capability / pallas ----------------------
    expect_schedule = ("chunked_loop" if prog.loop else
                       "two_phase" if vprev else "single_phase")
    if prog.schedule != expect_schedule:
        flag("schedule", f"declares {prog.schedule!r} but the phase "
             f"facts imply {expect_schedule!r}")
    expect_cap = {"single_phase": "first_order", "two_phase": "two_phase",
                  "chunked_loop": "chunked_reservoir"}[expect_schedule]
    if prog.capability != expect_cap:
        flag("capability", f"declares {prog.capability!r} but schedule "
             f"{expect_schedule!r} implies {expect_cap!r} — the "
             f"dispatch key must be recomputed, not trusted")
    if not prog.fused:
        flag("fused", "program opts out of the fused kernel — the "
             "engine has no jnp fallback path; every program must "
             "lower to the device-resident superstep")
    expect_pallas = not vprev and not prog.loop and (
        "typed" not in prog.requires)
    if prog.pallas != expect_pallas:
        flag("pallas", f"declares pallas={prog.pallas} but the one-hop "
             f"kernel covers exactly single-residency loop-free "
             f"non-typed programs (⇒ {expect_pallas})")

    # ---- carry discipline ----------------------------------------------
    if vprev or prog.loop:
        if prog.carry == "none":
            flag("carry", f"schedule {expect_schedule!r} splits the hop "
                 f"across owners but carry='none' — the verify/fold "
                 f"superstep would receive no payload; declare "
                 f"'candidates' or 'reservoir'")
        else:
            gathers = [n for n, ph in enumerate(phases)
                       if ph.op == "gather"]
            consumer = vprev[0] if vprev else (scores[0] if scores
                                               else len(phases))
            if not gathers or gathers[0] > consumer:
                flag("carry", f"carry {prog.carry!r} consumed at "
                     f"phases[{consumer}] but no gather produces it "
                     f"earlier — payloads must be produced at "
                     f"owner(v_curr) before owner(v_prev) consumes them")
        if prog.loop and prog.carry != "reservoir":
            flag("carry", f"chunked_loop requires the 'reservoir' carry "
                 f"(running E-S maximum + chunk counter), got "
                 f"{prog.carry!r}")
    elif prog.carry != "none":
        flag("carry", f"single-residency program declares carry "
             f"{prog.carry!r} — task words are sized from the carry; "
             f"drop it")

    # ---- width plumbing -------------------------------------------------
    draw_width = max((phases[n].width for n in draws), default=0)
    for n in scores:
        ph = phases[n]
        if ph.width <= 1:
            continue
        feeding = [phases[m] for m in range(n) if phases[m].op == "gather"
                   and phases[m].width == ph.width]
        if not feeding:
            flag(f"phases[{n}]", f"score width {ph.width} but no "
                 f"preceding gather stages {ph.width} candidates")
        if draw_width < ph.width:
            flag(f"phases[{n}]", f"score consumes {ph.width} candidates "
                 f"but the draw provides only {draw_width} uniforms")

    # ---- requires completeness -----------------------------------------
    for n, ph in enumerate(phases):
        need = _GATHER_REQUIRES.get(ph.variant) if ph.op == "gather" \
            else None
        if need and need not in prog.requires:
            flag(f"phases[{n}]", f"gather:{ph.variant} needs the "
                 f"{need!r} graph payload but requires={prog.requires}")
    return findings


def check_repo() -> List[Finding]:
    findings = []
    for kind in KINDS:
        findings += check_program(lower(_default_spec(kind)))
    return findings
