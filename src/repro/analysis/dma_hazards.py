"""DMA hazard pass: prove every declared kernel schedule pipeline-safe.

Input is the ``dma_schedule()`` declaration each Pallas kernel exports
(`kernels/common.DmaOp` sequences in program order — the double-buffered
gather loops, the fused kernel's ping-pong chunk loop, the delayed-wait
path write-back, and segment-sum's output-block visit sequence).  The
checker is a single forward scan holding per-``(buffer, slot)`` state:

  * **read-before-arrival** — a ``read`` is legal only when the latest
    copy issued on its slot has been waited (and some copy ever filled
    the slot);
  * **overwrite-while-in-flight** — a ``start`` or ``write`` on a slot
    with an un-waited copy clobbers data the DMA engine is still moving
    (inbound: partially-arrived gather; outbound: a store still being
    streamed home);
  * **malformed wait** — a ``wait`` must name the copy currently in
    flight on its slot (waiting a never-started / already-waited /
    wrong-slot copy means the semaphore accounting is off by one);
  * **un-drained copy** — every copy started must be waited before the
    kernel returns (Pallas semaphores must balance per launch);
  * **phantom copy** — a ``start`` targeting a VMEM-resident buffer (one
    the schedule reads with ``tier="vmem"``, or tagged so itself).  The
    cached gather hierarchy's whole point is that hit paths issue *no*
    DMA — a copy into cache-tier storage means a hit path still went to
    HBM, silently erasing the latency win while staying bit-identical.

``read`` ops with ``tier="vmem"`` are cache-hit probes/payload reads:
they touch on-chip memory only, so no dominating wait is required and
they participate in no slot state.

For the grid-scheduled `segment_sum` (no explicit DMAs) the same scan
checks the Pallas TPU output-revisit contract over ``visit`` ops:
revisits of an output block must be **consecutive** (the data-dependent
``index_map`` may not return to a block it left), and the declared
``first_visit`` flag must be set on exactly the first visit of each
block (it selects zero-init vs accumulate).

Because every loop in the kernels is slot-periodic with period 2, the
small unrolls the emitters use (n ≥ 3) exhaust the reachable state
space — the scan is a proof, not a sampling.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

from repro.analysis.report import Finding
from repro.kernels.common import DmaOp

Slot = Tuple[str, int]


def check_schedule(ops: Sequence[DmaOp], name: str = "kernel"
                   ) -> List[Finding]:
    """Forward-scan hazard check of one declared DMA schedule."""
    findings = []
    in_flight: Dict[Slot, int] = {}   # slot -> un-waited copy id
    copy_slot: Dict[int, Slot] = {}   # copy id -> slot it was issued on
    filled: Dict[Slot, bool] = {}     # slot has waited-arrived contents
    visits: List[DmaOp] = []

    def flag(i, op, msg):
        findings.append(Finding("dma", f"{name}[{i}]", f"{op.kind} "
                                f"{op.buffer}/slot{op.slot}: {msg}"))

    # Buffers the schedule declares VMEM-resident (cache-tier): any read
    # at tier="vmem" marks its buffer as on-chip for the whole schedule.
    vmem_bufs = {op.buffer for op in ops
                 if getattr(op, "tier", "hbm") == "vmem"}

    for i, op in enumerate(ops):
        slot = (op.buffer, op.slot)
        if op.kind == "read" and getattr(op, "tier", "hbm") == "vmem":
            continue  # on-chip read: no DMA, no slot state
        if op.kind == "start":
            if op.tier == "vmem" or op.buffer in vmem_bufs:
                flag(i, op, "DMA start into a VMEM-resident cache buffer "
                            "(phantom copy) — cached hit paths must serve "
                            "from on-chip memory without issuing copies")
                continue
            if slot in in_flight:
                flag(i, op, f"re-issued while copy {in_flight[slot]} is "
                            f"still un-waited (overwrite-while-in-flight)"
                            f" — wait the prior copy before reusing the "
                            f"slot")
            in_flight[slot] = op.copy
            copy_slot[op.copy] = slot
            filled[slot] = False
        elif op.kind == "wait":
            if op.copy not in copy_slot:
                flag(i, op, f"waits copy {op.copy} that was never "
                            f"started")
            elif copy_slot[op.copy] != slot:
                b, s = copy_slot[op.copy]
                flag(i, op, f"waits copy {op.copy} on the wrong slot "
                            f"(started on {b}/slot{s})")
            elif in_flight.get(slot) != op.copy:
                flag(i, op, f"waits copy {op.copy} which is not in "
                            f"flight there (already waited, or a newer "
                            f"copy {in_flight.get(slot)} superseded it)")
            else:
                del in_flight[slot]
                filled[slot] = True
        elif op.kind == "read":
            if slot in in_flight:
                flag(i, op, f"read while copy {in_flight[slot]} is "
                            f"un-waited (read-before-arrival) — insert "
                            f"the copy-wait before consuming the slot")
            elif not filled.get(slot, False):
                flag(i, op, "read of a slot no waited copy ever filled "
                            "(read-before-arrival)")
        elif op.kind == "write":
            if slot in in_flight:
                flag(i, op, f"overwritten while copy {in_flight[slot]} "
                            f"is un-waited (overwrite-while-in-flight) — "
                            f"reclaim the staging slot with its delayed "
                            f"wait first")
            filled[slot] = True
        elif op.kind == "visit":
            visits.append(op)
        else:
            flag(i, op, f"unknown op kind {op.kind!r}")

    for slot, cid in sorted(in_flight.items()):
        findings.append(Finding(
            "dma", f"{name}[end]",
            f"copy {cid} on {slot[0]}/slot{slot[1]} never waited — "
            f"drain all outstanding copies before the kernel returns"))
    findings += _check_visits(visits, name)
    return findings


def _check_visits(visits: Sequence[DmaOp], name: str) -> List[Finding]:
    """Output-revisit contract over ``visit`` ops (grid-order block
    sequence with declared first/live flags)."""
    findings = []
    closed = set()    # blocks already left
    initialized = set()
    current = None
    for i, op in enumerate(visits):
        block = op.slot
        site = f"{name}.visit[{i}]"
        if block != current:
            if current is not None:
                closed.add(current)
            if block in closed:
                findings.append(Finding(
                    "dma", site,
                    f"output block {block} revisited non-consecutively "
                    f"(left after an earlier visit) — Pallas revisits "
                    f"must be consecutive; sort segments / fix the "
                    f"index_map clamp"))
            current = block
        if op.first:
            if block in initialized:
                findings.append(Finding(
                    "dma", site,
                    f"first_visit set on a revisit of block {block} — "
                    f"would zero a partially accumulated output block"))
            initialized.add(block)
        elif op.live and block not in initialized:
            findings.append(Finding(
                "dma", site,
                f"live accumulation into block {block} before any "
                f"first_visit zero-init — reads uninitialized output"))
    return findings


def kernel_schedules():
    """Name → declared-op-list for every kernel in the tree (imported
    lazily so the pass stays usable without the full kernel deps)."""
    from repro.kernels.embedding_bag.embedding_bag import \
        dma_schedule as eb_schedule
    from repro.kernels.fused_superstep.fused_superstep import \
        dma_schedule as fused_schedule
    from repro.kernels.segment_sum.segment_sum import \
        dma_schedule as ss_schedule
    from repro.kernels.walk_step.walk_step import \
        dma_schedule as ws_schedule

    schedules = {}
    for kind in ("uniform", "alias"):
        schedules[f"walk_step.{kind}"] = ws_schedule(kind)
    for kind in ("uniform", "alias", "metapath", "rejection_n2v",
                 "reservoir_n2v"):
        schedules[f"fused_superstep.{kind}"] = fused_schedule(kind)
        # Cached variant: the fully-hit representative superstep — cache
        # probes and payload reads at tier="vmem", HBM loops only where
        # the hierarchy cannot serve (v_prev-keyed state, write-back).
        schedules[f"fused_superstep.{kind}.cached"] = fused_schedule(
            kind, cached=True)
    schedules["embedding_bag"] = eb_schedule()
    schedules["segment_sum"] = ss_schedule()
    return schedules


def check_repo() -> List[Finding]:
    findings = []
    for name, ops in kernel_schedules().items():
        findings += check_schedule(ops, name)
    return findings
