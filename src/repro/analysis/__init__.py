"""Static verifier for the pipeline invariants the repro rests on.

Four passes, each reading a *declarative export* the runtime code
already maintains (nothing here re-implements a backend — the passes
check the declarations the backends execute):

  * `rng_collisions` — every per-task draw stream (phase-program
    ``draw_streams()``, engine stop draws, AST-extracted call-site
    salts) is pairwise disjoint across phases / chunks / rounds /
    epochs for every sampler kind.
  * `dma_hazards` — every kernel's declared DMA schedule
    (``dma_schedule()`` next to each kernel) is hazard-free: reads
    dominated by copy-waits, no slot re-issued while in flight, all
    copies drained; plus the segment-sum output-revisit contract.
  * `residency` — every lowered `PhaseProgram` satisfies the sharded
    interpreter's contract (v_prev phases only under two_phase /
    chunked_loop, carries produced before consumed, derived flags
    recomputed from the phase facts).
  * `determinism` — AST lint over ``src/repro/{core,kernels,walker}``:
    no ambient RNG or wall-clock in the deterministic paths, every
    Pallas wrapper plumbed through `default_interpret`.

``python -m repro.analysis --check`` runs all four (CI job
``analysis``); ``--table`` regenerates the docs summary;
``--fixture NAME`` runs a pass over a deliberately broken input and
exits non-zero when (as it must) the defect is caught.
"""
from repro.analysis.report import Finding, render_findings

__all__ = ["Finding", "render_findings", "run_all"]


def run_all():
    """Run every pass over the repo; returns the combined findings."""
    from repro.analysis import (determinism, dma_hazards, residency,
                                rng_collisions)
    findings = []
    findings += rng_collisions.check_repo()
    findings += dma_hazards.check_repo()
    findings += residency.check_repo()
    findings += determinism.check_repo()
    return findings
