"""Execution configuration (the machine half of the unified API).

Everything here is a *machine* knob — lane counts, scheduling mode,
host-injection latency, device placement, routing capacities.  None of it
changes which walks are sampled: paths depend only on
``(seed, query_id, hop)`` (paper §V-A), so one :class:`WalkProgram` runs
bit-identically under any :class:`ExecutionConfig` and any backend.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

from repro.core.distributed import DistConfig
from repro.core.walk_engine import (EngineConfig, MODES as _MODES,
                                    STEP_IMPLS as _STEP_IMPLS)

#: Sentinel accepted by the tunable knobs below: "resolve me from the
#: tuning cache / analytical model at graph-bind time" (repro.tune).
AUTO = "auto"

#: Knobs that accept the AUTO sentinel.  All are *path-preserving*
#: machine knobs — resolution never changes which walks are sampled.
TUNABLE_KNOBS = ("num_slots", "hops_per_launch", "queue_depth_factor",
                 "cache_budget")


@dataclasses.dataclass(frozen=True)
class ExecutionConfig:
    """Machine knobs for compiled walkers, across every backend.

    Single-device knobs map onto :class:`repro.core.EngineConfig`;
    sharded knobs onto :class:`repro.core.distributed.DistConfig`.

    The ``num_slots`` / ``hops_per_launch`` / ``queue_depth_factor``
    knobs also accept the string ``"auto"``: the Walker resolves them
    per graph at bind time through the tuning cache / analytical model
    (`repro.tune.resolve`) — see ``tune_cache`` below.  A config with
    unresolved sentinels cannot be lowered (``engine_config`` /
    ``dist_config`` raise); use :meth:`resolved` to pin values manually.

    Attributes:
      num_slots:        W — total walker lanes (divided across devices on
                        the sharded backend unless ``slots_per_device`` is
                        given).
      record_paths:     keep per-query path buffers (required for
                        harvesting / serving).
      mode:             ``zero_bubble`` (per-superstep compaction+refill)
                        or ``static`` (bulk-synchronous batches).
      injection_delay:  C — host→device staging latency in supersteps.
      queue_depth_factor: × the Theorem VI.1 stage-ahead depth D.
      max_supersteps:   safety bound for the drain loop.
      step_impl:        ``jnp`` (vectorized superstep), ``pallas`` (one-hop
                        fused walk-step kernel), or ``fused`` (device-
                        resident multi-hop superstep kernel; covers every
                        sampler kind, including the chunked E-S
                        reservoir).
      hops_per_launch:  ``fused`` only — supersteps executed per kernel
                        launch (the k of the O(k·state) → O(state) host-
                        traffic reduction; ``stats.launches`` exposes the
                        realized fusion factor).
      cache_budget:     ``fused`` only — byte budget of the VMEM
                        hot-vertex adjacency cache (0 disables it).  The
                        top-H highest-degree vertices' payloads are
                        packed on-chip and gathers on them skip the HBM
                        DMA loops; paths are bit-identical either way
                        (same bytes, different tier), so this is a
                        tunable machine knob like the others.
      num_devices:      sharded backend only — mesh size (default: all
                        visible devices).
      slots_per_device: sharded backend only — W_loc override (default
                        ``num_slots // num_devices``).
      capacity_margin:  × Theorem VI.1 margin on routing bucket capacity.
      retention_factor: × the global live-task bound N·W_loc sizing the
                        router retention region; >= 1.0 is provably
                        lossless under the flow-controlled refill.
      log_capacity:     per-device emission-log entries (path write-back).
      axis_name:        mesh axis name for the sharded backend.
      tune_cache:       optional path of a tuning-cache JSON consulted
                        when resolving ``"auto"`` knobs (default: the
                        ``RIDGEWALKER_TUNE_CACHE`` environment variable,
                        else model-only resolution).
    """

    num_slots: "int | str" = 1024
    record_paths: bool = True
    mode: str = "zero_bubble"
    injection_delay: int = 0
    queue_depth_factor: "float | str" = 1.0
    max_supersteps: int = 1 << 20
    step_impl: str = "jnp"
    hops_per_launch: "int | str" = 16
    cache_budget: "int | str" = 0
    # ---- sharded backend ----
    num_devices: Optional[int] = None
    slots_per_device: Optional[int] = None
    capacity_margin: float = 2.0
    retention_factor: float = 1.0
    log_capacity: int = 1 << 16
    axis_name: str = "ch"
    tune_cache: Optional[str] = None

    def __post_init__(self):
        for knob in TUNABLE_KNOBS:
            v = getattr(self, knob)
            if isinstance(v, str) and v != AUTO:
                raise ValueError(
                    f"{knob} must be a number or the sentinel "
                    f"{AUTO!r}, got {v!r}")
        if self.num_slots != AUTO and self.num_slots <= 0:
            raise ValueError(
                f"num_slots must be a positive lane count, got "
                f"{self.num_slots}")
        if self.mode not in _MODES:
            raise ValueError(f"mode must be one of {_MODES}, got "
                             f"{self.mode!r}")
        if self.step_impl not in _STEP_IMPLS:
            raise ValueError(f"step_impl must be one of {_STEP_IMPLS}, got "
                             f"{self.step_impl!r}")
        if self.injection_delay < 0:
            raise ValueError(
                f"injection_delay is a latency in supersteps and cannot be "
                f"negative, got {self.injection_delay}")
        if self.queue_depth_factor != AUTO and self.queue_depth_factor <= 0:
            raise ValueError(
                f"queue_depth_factor must be positive (it scales the "
                f"Theorem VI.1 depth), got {self.queue_depth_factor}")
        if self.max_supersteps <= 0:
            raise ValueError(f"max_supersteps must be positive, got "
                             f"{self.max_supersteps}")
        if self.hops_per_launch != AUTO and self.hops_per_launch <= 0:
            raise ValueError(f"hops_per_launch must be positive, got "
                             f"{self.hops_per_launch}")
        if self.cache_budget != AUTO and self.cache_budget < 0:
            raise ValueError(
                f"cache_budget is a byte budget (0 disables the hot-vertex "
                f"cache) and cannot be negative, got {self.cache_budget}")
        if self.num_devices is not None and self.num_devices <= 0:
            raise ValueError(f"num_devices must be positive, got "
                             f"{self.num_devices}")
        if self.slots_per_device is not None and self.slots_per_device <= 0:
            raise ValueError(f"slots_per_device must be positive, got "
                             f"{self.slots_per_device}")
        if self.capacity_margin <= 0 or self.retention_factor <= 0:
            raise ValueError(
                f"capacity_margin / retention_factor must be positive, got "
                f"{self.capacity_margin} / {self.retention_factor}")
        if self.log_capacity <= 0:
            raise ValueError(f"log_capacity must be positive, got "
                             f"{self.log_capacity}")

    # ------------------------------------------------------ auto sentinels

    @property
    def auto_knobs(self) -> tuple:
        """Names of knobs currently carrying the ``"auto"`` sentinel."""
        return tuple(k for k in TUNABLE_KNOBS if getattr(self, k) == AUTO)

    @property
    def has_auto(self) -> bool:
        """True while any tunable knob is still an unresolved sentinel."""
        return bool(self.auto_knobs)

    def resolved(self, **knobs) -> "ExecutionConfig":
        """Concrete copy: ``knobs`` override, remaining sentinels take
        the class defaults.

        This is the manual escape hatch and the primitive the tuner's
        candidate application uses; ``Walker`` resolves through
        `repro.tune.resolve` instead (cache / model aware).
        """
        bad = set(knobs) - set(TUNABLE_KNOBS)
        if bad:
            raise ValueError(
                f"resolved() only accepts the tunable knobs "
                f"{TUNABLE_KNOBS}, got {sorted(bad)}")
        vals = dict(knobs)
        for k in TUNABLE_KNOBS:
            if k not in vals and getattr(self, k) == AUTO:
                vals[k] = getattr(type(self), "__dataclass_fields__")[
                    k].default
        return dataclasses.replace(self, **vals) if vals else self

    def _require_concrete(self, what: str) -> None:
        if self.has_auto:
            raise ValueError(
                f"cannot build a {what} while {self.auto_knobs} are "
                f"'auto' — bind through Walker (which resolves them per "
                f"graph via repro.tune) or call .resolved(...) first")

    # ---------------------------------------------------------- conversions

    def engine_config(self, program) -> EngineConfig:
        """Single-device engine view of these knobs for ``program``."""
        self._require_concrete("single-device EngineConfig")
        return EngineConfig(
            num_slots=self.num_slots,
            max_hops=program.max_hops,
            record_paths=self.record_paths,
            mode=self.mode,
            injection_delay=self.injection_delay,
            queue_depth_factor=self.queue_depth_factor,
            max_supersteps=self.max_supersteps,
            step_impl=self.step_impl,
            hops_per_launch=self.hops_per_launch,
            cache_budget=self.cache_budget,
        )

    def dist_config(self, program, num_devices: int) -> DistConfig:
        """Sharded engine view of these knobs for ``program``."""
        self._require_concrete("sharded DistConfig")
        if self.mode != "zero_bubble" or self.step_impl != "jnp":
            warnings.warn(
                f"mode={self.mode!r} / step_impl={self.step_impl!r} do not "
                "apply to the sharded backend (it always runs the "
                "zero-bubble jnp superstep) and are ignored",
                RuntimeWarning, stacklevel=3)
        w_loc = self.slots_per_device or max(self.num_slots // num_devices, 1)
        return DistConfig(
            slots_per_device=w_loc,
            max_hops=program.max_hops,
            capacity_margin=self.capacity_margin,
            retention_factor=self.retention_factor,
            log_capacity=self.log_capacity,
            record_paths=self.record_paths,
            max_supersteps=self.max_supersteps,
            axis_name=self.axis_name,
        )

    @classmethod
    def from_engine_config(cls, cfg: EngineConfig, **kw) -> "ExecutionConfig":
        """Lift a legacy :class:`EngineConfig` (minus the program-level
        ``max_hops``) into an ExecutionConfig — the shim path."""
        return cls(
            num_slots=cfg.num_slots,
            record_paths=cfg.record_paths,
            mode=cfg.mode,
            injection_delay=cfg.injection_delay,
            queue_depth_factor=cfg.queue_depth_factor,
            max_supersteps=cfg.max_supersteps,
            step_impl=cfg.step_impl,
            hops_per_launch=cfg.hops_per_launch,
            cache_budget=cfg.cache_budget,
            **kw,
        )
