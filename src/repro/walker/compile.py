"""compile(program, backend=...) — one entry point for every regime.

The Markov property makes every hop a stateless task (paper §V-A), so a
single superstep definition serves the closed batch system, the open
streaming system, the multi-tenant service, and the ``shard_map``-
partitioned multi-device system.  :func:`compile` binds a
:class:`~repro.walker.WalkProgram` to a backend and returns a
:class:`Walker` exposing all three execution styles:

    walker = compile(WalkProgram.node2vec(p=2.0, q=0.5), backend="single")
    result = walker.run(graph, starts, seed=0)        # closed batch
    stream = walker.stream(graph, capacity=4096)      # open system
    service = walker.serve(graph)                     # multi-tenant

Paths are bit-identical across backends for the same (seed, query_id,
hop) — pinned by ``tests/test_walker_api.py``.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distributed import (DistLogs, assemble_paths,
                                    make_distributed_engine, shard_starts)
from repro.core.tasks import WalkResult, WalkStats
from repro.core.walk_engine import (StreamState, build_engine,
                                    init_stream_state, inject_queries,
                                    make_superstep_runner)
from repro.graph.partition import PartitionedGraph, partition_graph
from repro.walker.execution import ExecutionConfig
from repro.walker.program import WalkProgram

BACKENDS = ("single", "sharded")


def compile(program: WalkProgram, backend: str = "single",
            execution: Optional[ExecutionConfig] = None,
            mesh: Optional[jax.sharding.Mesh] = None) -> "Walker":
    """Bind ``program`` to an execution backend.

    backend:
      ``single``  — one device: slot-pool engine with zero-bubble refill.
      ``sharded`` — ``shard_map`` over a 1-D device mesh: vertex-
                    partitioned graph, per-phase butterfly routing,
                    flow-controlled lossless refill.
    """
    if not isinstance(program, WalkProgram):
        raise TypeError(
            f"compile expects a WalkProgram, got {type(program).__name__}; "
            "build one with WalkProgram.urw()/ppr()/deepwalk()/node2vec()/"
            "metapath() or WalkProgram(spec=...)")
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, got "
                         f"{backend!r}")
    return Walker(program, backend, execution or ExecutionConfig(), mesh)


class Walker:
    """A compiled walk program: one algorithm, three execution styles."""

    def __init__(self, program: WalkProgram, backend: str,
                 execution: ExecutionConfig,
                 mesh: Optional[jax.sharding.Mesh] = None):
        self.program = program
        self.backend = backend
        self.execution = execution
        self._mesh = mesh
        self._engine = None         # single-device closed-system runner
        self._dist_cache = {}       # sharded runners keyed by graph shape

    # ----------------------------------------------------------- internals

    def _engine_cfg(self):
        return self.execution.engine_config(self.program)

    def _single_engine(self):
        if self._engine is None:
            self._engine = build_engine(self.program.spec, self._engine_cfg())
        return self._engine

    def _partition(self, graph) -> PartitionedGraph:
        if isinstance(graph, PartitionedGraph):
            return graph
        n = self.execution.num_devices or len(jax.devices())
        return partition_graph(graph, n)

    def _dist_engine(self, pg: PartitionedGraph):
        # max_degree is baked into the compiled engine (bisect iteration
        # count, reservoir chunk count), so it must key the cache.
        key = (pg.num_devices, pg.vertices_per_device, pg.col.shape,
               pg.max_degree,
               pg.weights is not None, pg.alias_prob is not None)
        if key not in self._dist_cache:
            cfg = self.execution.dist_config(self.program, pg.num_devices)
            mesh = self._mesh
            if mesh is None:
                devs = np.array(jax.devices()[: pg.num_devices])
                mesh = jax.sharding.Mesh(devs, (cfg.axis_name,))
            self._dist_cache[key] = (
                make_distributed_engine(pg, self.program.spec, cfg, mesh), cfg)
        return self._dist_cache[key]

    # ---------------------------------------------------------- closed run

    def run(self, graph, starts, seed: int = 0) -> WalkResult:
        """Closed system: drain the batch of ``starts`` to completion.

        On the sharded backend ``graph`` may be a ``CSRGraph`` (partitioned
        on the fly over the configured device count) or a pre-built
        ``PartitionedGraph``; the emission logs are assembled into the same
        ``WalkResult`` layout as the single-device engine, with per-device
        stats summed.
        """
        if self.backend == "single":
            self.program.requires(graph)
            sv = jnp.asarray(starts, jnp.int32)
            return self._single_engine()(graph, sv, seed,
                                         num_queries=int(sv.shape[0]))

        if not isinstance(graph, PartitionedGraph):
            self.program.requires(graph)
        elif self.program.spec.kind == "alias" and graph.alias_prob is None:
            raise ValueError(
                "alias (DeepWalk) programs need alias tables on the "
                "partitioned graph — build the CSRGraph with alias tables "
                "before partition_graph")
        pg = self._partition(graph)
        run, cfg = self._dist_engine(pg)
        starts_np = np.asarray(starts, dtype=np.int32)
        starts_sh, qcount = shard_starts(starts_np, pg.num_devices)
        log_q, log_h, log_v, cursor, stats = run(
            pg, jnp.asarray(starts_sh), jnp.asarray(qcount),
            jax.random.PRNGKey(seed))
        # Devices run the lockstep superstep loop the same number of times:
        # supersteps is a global clock (max), everything else is additive.
        total = WalkStats(*(
            jnp.max(v) if name == "supersteps" else jnp.sum(v)
            for name, v in zip(WalkStats._fields, stats)))
        if int(total.supersteps) >= cfg.max_supersteps:
            warnings.warn(
                f"sharded run hit max_supersteps={cfg.max_supersteps} before "
                "draining — walks may be truncated; raise "
                "ExecutionConfig.max_supersteps", RuntimeWarning,
                stacklevel=2)
        if int(total.drops) > 0:
            # Routing drops are structurally impossible (flow-controlled
            # refill), so any drop is an emission-log overflow: recorded
            # paths have holes.
            warnings.warn(
                f"{int(total.drops)} path records dropped (emission log "
                "overflow) — assembled paths are incomplete; raise "
                "ExecutionConfig.log_capacity", RuntimeWarning, stacklevel=2)
        if cfg.record_paths:
            logs = DistLogs(qid=log_q, hop=log_h, vertex=log_v, cursor=cursor)
            paths, lengths = assemble_paths(logs, starts_np,
                                            self.program.max_hops)
            return WalkResult(paths=jnp.asarray(paths),
                              lengths=jnp.asarray(lengths), stats=total)
        dummy = jnp.full((1, 1), -1, jnp.int32)
        return WalkResult(paths=dummy, lengths=jnp.zeros((1,), jnp.int32),
                          stats=total)

    # --------------------------------------------------------- open stream

    def stream(self, graph, capacity: int = 4096, seed: int = 0) -> "WalkStream":
        """Open system: a persistent stream accepting injections between
        superstep chunks (single-device backend; sharded streaming is a
        ROADMAP item gated on this API)."""
        if self.backend != "single":
            raise NotImplementedError(
                "streaming on the sharded backend is not implemented yet "
                "(ROADMAP: shard serve.WalkService across devices); compile "
                "with backend='single'")
        self.program.requires(graph)
        return WalkStream(self.program, self.execution, graph, capacity, seed)

    # ------------------------------------------------------------- service

    def serve(self, graph, capacity: int = 4096, chunk: int = 16,
              seed: int = 0):
        """Multi-tenant request service over the streaming engine."""
        if self.backend != "single":
            raise NotImplementedError(
                "serving on the sharded backend is not implemented yet "
                "(ROADMAP: shard serve.WalkService across devices); compile "
                "with backend='single'")
        self.program.requires(graph)
        from repro.serve.service import WalkService
        return WalkService(graph, self.program, execution=self.execution,
                           capacity=capacity, chunk=chunk, seed=seed)


class WalkStream:
    """Persistent open-system stream: inject → advance → harvest.

    Thin stateful handle over the jitted superstep runner; all device
    state lives in a :class:`~repro.core.StreamState` whose shapes are
    static, so any injection/advance cadence reuses one compilation.
    """

    def __init__(self, program: WalkProgram, execution: ExecutionConfig,
                 graph, capacity: int, seed: int):
        if capacity <= 0:
            raise ValueError(f"stream capacity must be positive, got "
                             f"{capacity}")
        self.program = program
        self.graph = graph
        self.seed = seed
        self.capacity = int(capacity)
        # Harvesting slices recorded paths; recording is mandatory here
        # (same guard as WalkService).
        self._cfg = dataclasses.replace(
            execution.engine_config(program), record_paths=True)
        self._runner = make_superstep_runner(program.spec, self._cfg)
        self.state: StreamState = init_stream_state(self._cfg, self.capacity)
        self._tail = 0  # host mirror of queue.tail (admission bookkeeping)

    def inject(self, starts, n_valid: Optional[int] = None) -> None:
        """Append arrivals at the queue tail.  ``starts`` may be padded;
        only the first ``n_valid`` entries become real queries."""
        sv = np.asarray(starts, np.int32).reshape(-1)
        n = int(sv.size if n_valid is None else n_valid)
        if not 0 <= n <= sv.size:
            raise ValueError(
                f"n_valid={n} must be within [0, {sv.size}] (the injected "
                "block); a negative/oversized count would corrupt the "
                "queue tail")
        # The WHOLE padded block must fit: inject_queries writes all of
        # ``starts`` at the tail, and dynamic_update_slice clamps
        # out-of-bounds starts — a too-large pad would silently overwrite
        # already-admitted queries.
        if self._tail + max(n, sv.size) > self.capacity:
            raise ValueError(
                f"injecting {n} queries (padded to {sv.size}) overflows the "
                f"stream buffer ({self._tail}/{self.capacity} used); "
                "harvest + rebuild the stream, or raise capacity "
                "(WalkService rotates generations for you)")
        self.state = inject_queries(self.state, jnp.asarray(sv), n)
        self._tail += n

    def advance(self, k: int = 16) -> int:
        """Run at most ``k`` supersteps; returns how many executed."""
        before = int(self.state.stats.supersteps)
        self.state = self._runner(self.graph, self.state, self.seed, k)
        return int(self.state.stats.supersteps) - before

    @property
    def num_injected(self) -> int:
        return self._tail

    def done_mask(self) -> np.ndarray:
        """(capacity,) bool — True where that query id has terminated."""
        return np.asarray(self.state.done)

    def harvest(self, lo: int = 0, hi: Optional[int] = None):
        """Recorded (paths, lengths) for query ids [lo, hi) as numpy."""
        hi = self._tail if hi is None else hi
        return (np.asarray(self.state.paths[lo:hi]),
                np.asarray(self.state.lengths[lo:hi]))

    def drain(self, chunk: int = 64, max_chunks: int = 100_000) -> None:
        """Advance until every injected query is done."""
        for _ in range(max_chunks):
            if bool(self.done_mask()[: self._tail].all()):
                return
            self.advance(chunk)
        raise RuntimeError("stream did not drain (engine stalled?)")
