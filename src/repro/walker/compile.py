"""compile(program, backend=...) — one entry point for every regime.

The Markov property makes every hop a stateless task (paper §V-A), so a
single superstep definition serves the closed batch system, the open
streaming system, the multi-tenant service, and the ``shard_map``-
partitioned multi-device system.  :func:`compile` binds a
:class:`~repro.walker.WalkProgram` to a backend and returns a
:class:`Walker` exposing all three execution styles on either backend:

    walker = compile(WalkProgram.node2vec(p=2.0, q=0.5), backend="sharded")
    result = walker.run(graph, starts, seed=0)        # closed batch
    stream = walker.stream(graph, capacity=4096)      # open system
    service = walker.serve(graph)                     # multi-tenant

Streams are *continuous*: query-id slots form a ring (a host-side free
ring hands slots to arrivals; ``release`` reclaims them after harvest with
``epoch + 1``), so an unbounded arrival stream runs in a bounded device
buffer with no drain barrier.  Paths are bit-identical across backends
for the same (seed, epoch, query_id, hop) — epoch ``e`` of any stream
equals ``Walker.run`` under ``rng.stream_key(seed, e)`` — pinned by
``tests/test_walker_api.py`` and ``tests/test_streaming.py``.
"""
from __future__ import annotations

import dataclasses
import warnings
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import corpus_ring
from repro.core import rng as task_rng
from repro.core.distributed import (DistLogs, assemble_paths,
                                    init_dist_stream_state,
                                    inject_stream_queries,
                                    make_distributed_engine,
                                    make_sharded_stream_engine, shard_starts)
from repro.core.tasks import WalkResult, WalkStats
from repro.core.walk_engine import (StreamState, build_engine,
                                    init_stream_state, inject_queries,
                                    make_superstep_runner, maybe_build_cache)
from repro.graph.partition import PartitionedGraph, partition_graph
from repro.walker.execution import ExecutionConfig
from repro.walker.program import WalkProgram

BACKENDS = ("single", "sharded")


def _pad_block(n: int, floor: int = 16) -> int:
    """Next power of two >= n (>= floor): bounds distinct injection shapes
    to O(log capacity) jit specializations."""
    b = floor
    while b < n:
        b <<= 1
    return b


def compile(program: WalkProgram, backend: str = "single",
            execution: Optional[ExecutionConfig] = None,
            mesh: Optional[jax.sharding.Mesh] = None) -> "Walker":
    """Bind ``program`` to an execution backend.

    backend:
      ``single``  — one device: slot-pool engine with zero-bubble refill.
      ``sharded`` — ``shard_map`` over a 1-D device mesh: vertex-
                    partitioned graph, per-phase butterfly routing,
                    flow-controlled lossless refill.
    """
    if not isinstance(program, WalkProgram):
        raise TypeError(
            f"compile expects a WalkProgram, got {type(program).__name__}; "
            "build one with WalkProgram.urw()/ppr()/deepwalk()/node2vec()/"
            "metapath() or WalkProgram(spec=...)")
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, got "
                         f"{backend!r}")
    return Walker(program, backend, execution or ExecutionConfig(), mesh)


class Walker:
    """A compiled walk program: one algorithm, three execution styles."""

    def __init__(self, program: WalkProgram, backend: str,
                 execution: ExecutionConfig,
                 mesh: Optional[jax.sharding.Mesh] = None):
        self.program = program
        self.backend = backend
        self.execution = execution
        self._mesh = mesh
        self._engines = {}          # closed-system runners keyed by config
        self._resolved = {}         # (sig, workload) -> (program, execution)
        self._dist_cache = {}       # sharded runners keyed by graph shape
        self._emb_cache = {}        # train_embeddings jitted pieces

    # ----------------------------------------------------------- internals

    def _bind(self, graph, num_queries: Optional[int] = None):
        """Concrete ``(program, execution)`` for this graph + workload.

        Resolves any ``"auto"`` knob sentinels through the tuning cache /
        analytical model (`repro.tune.resolve`) — memoized per (graph
        signature, workload bucket), so repeat runs on a same-shaped
        graph reuse both the resolution and the compiled engine.  With
        no sentinels present this is the identity.
        """
        from repro import tune
        if not tune.needs_resolution(self.program, self.execution):
            return self.program, self.execution
        sig = tune.graph_signature(graph)
        key = (sig.token(), tune.workload_bucket(num_queries))
        if key not in self._resolved:
            self._resolved[key] = tune.resolve(
                self.program, self.execution, graph, backend=self.backend,
                num_queries=num_queries)
        return self._resolved[key]

    def _engine_cfg(self):
        return self.execution.engine_config(self.program)

    def _single_engine(self, program=None, execution=None, graph=None):
        program = program or self.program
        execution = execution or self.execution
        cfg = execution.engine_config(program)
        # The hot-vertex cache is a function of the graph, so graph
        # identity must key the memo whenever a cache would be built; the
        # memo pins the graph object, keeping its id() stable for the
        # cache entry's lifetime.
        wants_cache = (graph is not None and cfg.step_impl == "fused"
                       and cfg.cache_budget > 0)
        key = (program.spec, cfg, id(graph) if wants_cache else None)
        if key not in self._engines:
            cache = (maybe_build_cache(program.spec, cfg, graph)
                     if wants_cache else None)
            self._engines[key] = (build_engine(program.spec, cfg,
                                               cache=cache), graph)
        return self._engines[key][0]

    def _partition(self, graph) -> PartitionedGraph:
        if isinstance(graph, PartitionedGraph):
            return graph
        n = self.execution.num_devices or len(jax.devices())
        return partition_graph(graph, n)

    def _dist_engine(self, pg: PartitionedGraph, program=None,
                     execution=None):
        program = program or self.program
        execution = execution or self.execution
        # max_degree is baked into the compiled engine (bisect iteration
        # count, reservoir chunk count), so it must key the cache — as
        # must the resolved (spec, execution) when knobs were auto-tuned.
        key = (pg.num_devices, pg.vertices_per_device, pg.col.shape,
               pg.max_degree,
               pg.weights is not None, pg.alias_prob is not None,
               program.spec, execution)
        if key not in self._dist_cache:
            cfg = execution.dist_config(program, pg.num_devices)
            mesh = self._mesh
            if mesh is None:
                devs = np.array(jax.devices()[: pg.num_devices])
                mesh = jax.sharding.Mesh(devs, (cfg.axis_name,))
            self._dist_cache[key] = (
                make_distributed_engine(pg, program.spec, cfg, mesh), cfg)
        return self._dist_cache[key]

    # ---------------------------------------------------------- closed run

    def run(self, graph, starts, seed: int = 0) -> WalkResult:
        """Closed system: drain the batch of ``starts`` to completion.

        ``seed`` may be an int or a PRNG key (e.g. ``rng.stream_key(s, e)``
        to reproduce epoch ``e`` of a stream as a closed batch).  On the
        sharded backend ``graph`` may be a ``CSRGraph`` (partitioned on the
        fly over the configured device count) or a pre-built
        ``PartitionedGraph``; the emission logs are assembled into the same
        ``WalkResult`` layout as the single-device engine, with per-device
        stats summed.
        """
        if self.backend == "single":
            self.program.requires(graph)
            sv = jnp.asarray(starts, jnp.int32)
            program, execution = self._bind(graph, int(sv.shape[0]))
            return self._single_engine(program, execution, graph)(
                graph, sv, seed, num_queries=int(sv.shape[0]))

        if not isinstance(graph, PartitionedGraph):
            self.program.requires(graph)
        elif self.program.spec.kind == "alias" and graph.alias_prob is None:
            raise ValueError(
                "alias (DeepWalk) programs need alias tables on the "
                "partitioned graph — build the CSRGraph with alias tables "
                "before partition_graph")
        pg = self._partition(graph)
        program, execution = self._bind(pg, np.asarray(starts).size)
        run, cfg = self._dist_engine(pg, program, execution)
        starts_np = np.asarray(starts, dtype=np.int32)
        starts_sh, qcount = shard_starts(starts_np, pg.num_devices)
        base_key = task_rng.stream_key(seed)
        log_q, log_h, log_v, cursor, stats = run(
            pg, jnp.asarray(starts_sh), jnp.asarray(qcount), base_key)
        # Devices run the lockstep superstep loop the same number of times:
        # supersteps/launches are global clocks (max), the rest is additive.
        total = WalkStats(*(
            jnp.max(v) if name in ("supersteps", "launches") else jnp.sum(v)
            for name, v in zip(WalkStats._fields, stats)))
        if int(total.supersteps) >= cfg.max_supersteps:
            warnings.warn(
                f"sharded run hit max_supersteps={cfg.max_supersteps} before "
                "draining — walks may be truncated; raise "
                "ExecutionConfig.max_supersteps", RuntimeWarning,
                stacklevel=2)
        if int(total.drops) > 0:
            # Routing drops are structurally impossible (flow-controlled
            # refill), so any drop is an emission-log overflow: recorded
            # paths have holes.
            warnings.warn(
                f"{int(total.drops)} path records dropped (emission log "
                "overflow) — assembled paths are incomplete; raise "
                "ExecutionConfig.log_capacity", RuntimeWarning, stacklevel=2)
        if cfg.record_paths:
            logs = DistLogs(qid=log_q, hop=log_h, vertex=log_v, cursor=cursor)
            paths, lengths = assemble_paths(logs, starts_np,
                                            self.program.max_hops)
            return WalkResult(paths=jnp.asarray(paths),
                              lengths=jnp.asarray(lengths), stats=total)
        dummy = jnp.full((1, 1), -1, jnp.int32)
        return WalkResult(paths=dummy, lengths=jnp.zeros((1,), jnp.int32),
                          stats=total)

    # --------------------------------------------------------- open stream

    def stream(self, graph, capacity: int = 4096, seed: int = 0):
        """Open system: a persistent stream accepting injections between
        superstep chunks, with ring-buffer slot reclamation (``release``)
        for continuous operation.

        On ``backend="single"`` returns a :class:`WalkStream`; on
        ``backend="sharded"`` a :class:`ShardedWalkStream` over the
        capability-dispatched distributed superstep.  Both expose the same
        inject / advance / harvest_ids / release surface, so
        `serve.WalkService` runs unchanged over either.
        """
        if self.backend == "single":
            self.program.requires(graph)
            program, execution = self._bind(graph, capacity)
            return WalkStream(program, execution, graph, capacity, seed)
        if not isinstance(graph, PartitionedGraph):
            self.program.requires(graph)
        pg = self._partition(graph)
        program, execution = self._bind(pg, capacity)
        cfg = execution.dist_config(program, pg.num_devices)
        mesh = self._mesh
        if mesh is None:
            devs = np.array(jax.devices()[: pg.num_devices])
            mesh = jax.sharding.Mesh(devs, (cfg.axis_name,))
        return ShardedWalkStream(program, cfg, pg, mesh, capacity, seed)

    # ------------------------------------------------------------- service

    def serve(self, graph, capacity: int = 4096, chunk: int = 16,
              seed: int = 0, adapt: bool = False, controller=None):
        """Multi-tenant request service over the streaming engine (either
        backend — the service only speaks the stream interface).

        ``adapt=True`` attaches the Theorem VI.1 chunk controller
        (`repro.serve.scheduler.HopsController`, overridable via
        ``controller``): the service adapts its supersteps-per-launch
        online from the engine's occupancy stats, trace exposed on
        ``ServiceAnalysis.adaptation``.
        """
        from repro.serve.service import WalkService
        return WalkService(stream=self.stream(graph, capacity=capacity,
                                              seed=seed),
                           chunk=chunk, adapt=adapt, controller=controller)

    # ------------------------------------------------- walks → embeddings

    def train_embeddings(self, graph, *, seed: int = 0,
                         rounds: int = 4, walks_per_round: int = 64,
                         steps_per_round: int = 32, batch_size: int = 256,
                         dim: int = 32, window: int = 5,
                         num_negatives: int = 5,
                         ring_capacity: Optional[int] = None,
                         opt_cfg=None, overlap: bool = True,
                         use_kernel: bool = True,
                         ckpt_dir: Optional[str] = None,
                         ckpt_every: int = 0, log_every: int = 0,
                         batch_hook=None) -> dict:
        """Device-resident walks→embeddings pipeline (DeepWalk/node2vec).

        Runs ``rounds`` walk-production rounds of ``walks_per_round``
        walks each; completed paths land directly in an HBM corpus ring
        (`repro.core.corpus_ring`) and ``steps_per_round`` SGNS grad
        steps per round consume (center, context, negatives) windows
        sampled straight from the ring — the paths never visit the host.
        With ``overlap=True`` round ``r+1``'s walk launch is dispatched
        before round ``r``'s grad steps, so walking and training share
        the device queue; ``overlap=False`` is the serial baseline
        (host round-trip + blocking), bit-identical in result.

        Round ``r``'s corpus is the closed batch of starts
        ``(r·walks_per_round + i) % |V|`` under ``rng.stream_key(seed,
        r)`` — a pure function of ``(seed, r)`` on either backend, so a
        run checkpointed via ``ckpt_dir`` resumes bit-identically
        (pending rounds are re-produced, ingested rounds are not).

        Returns ``{"params", "opt_state", "ring", "step", "history",
        "config"}`` — ``params`` are the trained (device-resident)
        embedding tables.
        """
        from repro.models import embeddings as emb
        from repro.optim import adamw
        from repro.runtime import train_loop

        if walks_per_round <= 0 or rounds <= 0:
            raise ValueError(
                f"rounds ({rounds}) and walks_per_round ({walks_per_round}) "
                "must be positive")
        path_width = self.program.max_hops + 1

        # ------------------------------------------------------- producer
        if self.backend == "single":
            self.program.requires(graph)
            nv = int(graph.num_vertices)
            if "engine" not in self._emb_cache:
                program, execution = self._bind(graph, walks_per_round)
                cfg = dataclasses.replace(
                    execution.engine_config(program), record_paths=True)
                self._emb_cache["engine"] = build_engine(
                    program.spec, cfg,
                    cache=maybe_build_cache(program.spec, cfg, graph))
            engine = self._emb_cache["engine"]
            stream = None

            def produce(r: int):
                sv = jnp.asarray(
                    (r * walks_per_round + np.arange(walks_per_round)) % nv,
                    jnp.int32)
                res = engine(graph, sv, task_rng.stream_key(seed, r),
                             num_queries=walks_per_round)
                return res.paths, res.lengths
        else:
            stream = self.stream(graph, capacity=walks_per_round, seed=seed)
            nv = int(stream.graph.num_vertices)

            def produce(r: int):
                starts = (r * walks_per_round
                          + np.arange(walks_per_round)) % nv
                qids, epochs = stream.inject(starts)
                if int(epochs[0]) != r:
                    raise RuntimeError(
                        f"producer stream is at epoch {int(epochs[0])} but "
                        f"round {r} was requested (rounds must be produced "
                        "in order; use seek_epochs after a resume)")
                stream.drain()
                paths, lengths = stream.harvest_device(qids)
                stream.release(qids)
                return paths, lengths

        # ------------------------------------------------------- consumer
        sg_cfg = emb.SkipGramConfig(num_vertices=nv, dim=dim,
                                    num_negatives=num_negatives,
                                    window=window)
        opt_cfg = opt_cfg or adamw.AdamWConfig(
            lr=1e-2, warmup_steps=max(1, rounds * steps_per_round // 10),
            total_steps=rounds * steps_per_round)
        params0 = emb.init_params(task_rng.stream_key(seed), sg_cfg)
        state0 = (params0, adamw.init_state(params0))
        # Reuse jitted pieces across calls (repeat training runs on one
        # Walker hit the jit cache instead of recompiling).
        skey = ("sampler", nv, batch_size, window, num_negatives)
        if skey not in self._emb_cache:
            self._emb_cache[skey] = corpus_ring.make_batch_sampler(
                nv, batch_size, window, num_negatives)
        sampler = self._emb_cache[skey]
        base_key = task_rng.stream_key(seed)

        def sample(ring, step):
            return sampler(ring, base_key, step)

        gkey = ("sgns", dataclasses.astuple(sg_cfg),
                dataclasses.astuple(opt_cfg), use_kernel)
        if gkey not in self._emb_cache:
            self._emb_cache[gkey] = emb.make_sgns_step(
                sg_cfg, opt_cfg, use_kernel=use_kernel)
        sgns = self._emb_cache[gkey]

        def step_fn(state, batch):
            params, opt = state
            if not overlap:
                # Serial baseline: the naive wiring stages every batch
                # through the host (the per-step transfer the corpus
                # ring exists to delete) and blocks on every grad step.
                corpus_ring.record_host_copy("train_embeddings.serial_batch")
                batch = tuple(jnp.asarray(np.asarray(x)) for x in batch)
            params, opt, aux = sgns(params, opt, batch)
            if not overlap:
                jax.block_until_ready(params["in_embed"])
            return (params, opt), aux

        # ----------------------------------------------------------- ring
        cap = ring_capacity or max(2 * walks_per_round, walks_per_round)
        if self.backend == "sharded":
            ndev = stream.graph.num_devices
            cap = -(-cap // ndev) * ndev  # row-shardable across the mesh
        ring0 = corpus_ring.init_ring(cap, path_width)

        state, ring, start_step = train_loop.resume_pipeline(
            ckpt_dir, state0, ring0)
        rounds_done = int(ring.tail) // walks_per_round
        if stream is not None:
            stream.seek_epochs(rounds_done)
            mesh, ax = stream._mesh, stream.cfg.axis_name
            P = jax.sharding.PartitionSpec
            ring = jax.device_put(ring, corpus_ring.CorpusRing(
                paths=jax.sharding.NamedSharding(mesh, P(ax, None)),
                lengths=jax.sharding.NamedSharding(mesh, P(ax)),
                tail=jax.sharding.NamedSharding(mesh, P())))
            if nv % ndev == 0:
                # Vocab-sharded tables: each device owns |V|/N rows of
                # both tables (and their optimizer moments).
                vocab = jax.sharding.NamedSharding(mesh, P(ax, None))
                state = jax.tree.map(
                    lambda x: jax.device_put(x, vocab)
                    if getattr(x, "ndim", 0) == 2 and x.shape[0] == nv
                    else x, state)

        if overlap:
            def append(ring, walks):
                return corpus_ring.append(ring, *walks)
        else:
            def append(ring, walks):
                # The naive hand-off this module exists to delete: pull
                # every path to the host, re-upload, and fence.
                corpus_ring.record_host_copy("train_embeddings.serial")
                paths = np.asarray(walks[0])
                lengths = np.asarray(walks[1])
                ring = corpus_ring.append(ring, jnp.asarray(paths),
                                          jnp.asarray(lengths))
                jax.block_until_ready(ring.paths)
                return ring

        pcfg = train_loop.PipelineConfig(
            rounds=rounds, steps_per_round=steps_per_round, overlap=overlap,
            ckpt_dir=ckpt_dir, ckpt_every=ckpt_every, log_every=log_every)
        state, ring, step, history, _ = train_loop.run_pipelined(
            produce, append, sample, step_fn, state, ring, pcfg,
            start_step=start_step, rounds_done=rounds_done,
            batch_hook=batch_hook)
        params, opt_state = state
        return {"params": params, "opt_state": opt_state, "ring": ring,
                "step": step, "history": history, "config": sg_cfg}


class _StreamBase:
    """Host-side ring economy shared by both stream backends.

    The host owns the free ring: slot ids 0..capacity-1 start free, an
    injection pops slots FIFO and assigns each arrival ``(epoch, qid)``,
    and :meth:`release` returns harvested slots with ``epoch + 1`` so the
    next occupant samples an independent walk (`rng.task_fold` salts the
    derivation with the epoch).  The stream therefore never drains as a
    whole — slots individually complete, are harvested, and go around
    again.
    """

    capacity: int

    def _init_ring(self) -> None:
        self._free = deque(range(self.capacity))
        self._epochs = np.zeros((self.capacity,), np.int32)
        self._live = np.zeros((self.capacity,), bool)
        self._injected = 0

    # -- subclass hooks ----------------------------------------------------

    def _device_inject(self, qids: np.ndarray, starts: np.ndarray,
                       epochs: np.ndarray) -> None:
        raise NotImplementedError

    def advance(self, k: int = 16) -> int:
        """Run at most ``k`` supersteps on the persistent device state."""
        raise NotImplementedError

    def done_mask(self) -> np.ndarray:
        """Per-slot completion flags (capacity-sized, includes free slots)."""
        raise NotImplementedError

    def harvest_device(self, qids):
        """Fetch ``(paths, lengths)`` for the given live query-id slots as
        *device-resident* arrays (no host copy) — the corpus-ring feed."""
        raise NotImplementedError

    # -- ring economy ------------------------------------------------------

    @property
    def num_free(self) -> int:
        """Slots available for injection right now."""
        return len(self._free)

    @property
    def num_live(self) -> int:
        """Slots occupied by injected-but-not-released queries."""
        return self.capacity - len(self._free)

    @property
    def num_injected(self) -> int:
        """Total arrivals ever injected (monotone; exceeds capacity once
        slots recycle)."""
        return self._injected

    def epoch_of(self, qids) -> np.ndarray:
        """Current occupant epoch of each slot id."""
        return self._epochs[np.asarray(qids, np.int64)]

    def inject(self, starts, n_valid: Optional[int] = None):
        """Admit arrivals into free ring slots.

        Returns ``(qids, epochs)`` — the slot id and epoch assigned to each
        arrival, the identity under which its walk is sampled and
        harvested.  Raises if fewer than ``n_valid`` slots are free
        (``release`` harvested queries to make room).
        """
        sv = np.asarray(starts, np.int32).reshape(-1)
        n = int(sv.size if n_valid is None else n_valid)
        if not 0 < n <= sv.size:
            raise ValueError(
                f"n_valid={n} must be within [1, {sv.size}] (the injected "
                "block)")
        if n > len(self._free):
            raise ValueError(
                f"injecting {n} queries overflows the slot ring "
                f"({self.num_live}/{self.capacity} live, {len(self._free)} "
                "free); release harvested queries or raise capacity "
                "(WalkService does this bookkeeping for you)")
        qids = np.asarray([self._free.popleft() for _ in range(n)], np.int32)
        epochs = self._epochs[qids]
        self._live[qids] = True
        self._injected += n
        self._device_inject(qids, sv[:n], epochs)
        return qids, epochs

    def release(self, qids) -> None:
        """Return harvested slots to the free ring with ``epoch + 1``."""
        qids = np.asarray(qids, np.int64).reshape(-1)
        if np.unique(qids).size != qids.size:
            # A duplicate would enter the free ring twice and hand the same
            # (epoch, qid) identity to two future arrivals.
            raise ValueError("release with duplicate slot ids")
        if not self._live[qids].all():
            raise ValueError("release of a slot that is not live")
        done = self.done_mask()
        if not done[qids].all():
            raise ValueError(
                "release of an unfinished query: harvest only completed "
                "slots (done_mask) before recycling them")
        self._live[qids] = False
        self._epochs[qids] += 1
        self._free.extend(int(q) for q in qids)

    def seek_epochs(self, epoch: int) -> None:
        """Fast-forward every free slot's epoch (resume support).

        A resumed pipelined training run re-creates the stream with all
        epochs at 0 but needs production to continue at walk round
        ``rounds_done``; seeking makes the next occupant of every slot
        sample round ``epoch`` — bit-identical to a fresh run that walked
        through the earlier rounds, because epoch ``e`` of a slot is a
        pure function of ``(seed, e, qid)``.
        """
        if self._live.any():
            raise RuntimeError("seek_epochs with live queries outstanding")
        if epoch < int(self._epochs.max(initial=0)):
            raise ValueError(
                f"seek_epochs({epoch}) would rewind a slot already past it "
                f"(max epoch {int(self._epochs.max(initial=0))}) and replay "
                "a used (epoch, qid) identity")
        self._epochs[:] = epoch

    def harvest_ids(self, qids):
        """Fetch ``(paths, lengths)`` for the given live query-id slots as
        numpy (one recorded host round-trip over :meth:`harvest_device`)."""
        paths, lengths = self.harvest_device(qids)
        corpus_ring.record_host_copy("harvest_ids")
        return np.asarray(paths), np.asarray(lengths)

    def done_live_mask(self) -> np.ndarray:
        """(capacity,) bool — live slots whose query has terminated (the
        harvestable set; released slots read False)."""
        return self.done_mask() & self._live

    def harvest(self, lo: int = 0, hi: Optional[int] = None):
        """Recorded (paths, lengths) for the contiguous slot range
        [lo, hi) as numpy.  Before any slot recycles, slots are handed out
        FIFO, so this matches injection order; under reuse prefer
        :meth:`harvest_ids` with the ids :meth:`inject` returned."""
        hi = min(self._injected, self.capacity) if hi is None else hi
        return self.harvest_ids(np.arange(lo, hi))

    def drain(self, chunk: int = 64, max_chunks: int = 100_000) -> None:
        """Advance until every live (injected, unreleased) query is done."""
        for _ in range(max_chunks):
            live = self._live
            if not live.any() or bool(self.done_mask()[live].all()):
                return
            self.advance(chunk)
        raise RuntimeError("stream did not drain (engine stalled?)")


class WalkStream(_StreamBase):
    """Persistent single-device open-system stream: inject → advance →
    harvest → release.

    Thin stateful handle over the jitted superstep runner; all device
    state lives in a :class:`~repro.core.StreamState` whose shapes are
    static, so any injection/advance cadence reuses one compilation.
    """

    def __init__(self, program: WalkProgram, execution: ExecutionConfig,
                 graph, capacity: int, seed: int):
        if capacity <= 0:
            raise ValueError(f"stream capacity must be positive, got "
                             f"{capacity}")
        self.program = program
        self.graph = graph
        self.seed = seed
        self.capacity = int(capacity)
        # Harvesting slices recorded paths; recording is mandatory here
        # (same guard as WalkService).
        self._cfg = dataclasses.replace(
            execution.engine_config(program), record_paths=True)
        self._runner = make_superstep_runner(
            program.spec, self._cfg,
            cache=maybe_build_cache(program.spec, self._cfg, graph))
        self.state: StreamState = init_stream_state(self._cfg, self.capacity)
        self._init_ring()

    @property
    def num_slots(self) -> int:
        """W — walker lanes of the underlying engine."""
        return self._cfg.num_slots

    @property
    def max_hops(self) -> int:
        """The program's hop budget (path buffers are ``max_hops + 1``)."""
        return self.program.max_hops

    @property
    def cfg(self):
        """The lowered engine-layer config (:class:`EngineConfig`)."""
        return self._cfg

    def _device_inject(self, qids, starts, epochs) -> None:
        n = qids.shape[0]
        b = min(_pad_block(n), self.capacity)
        qb = np.full((b,), self.capacity, np.int32)  # capacity = inert pad
        sb = np.zeros((b,), np.int32)
        eb = np.zeros((b,), np.int32)
        qb[:n], sb[:n], eb[:n] = qids, starts, epochs
        self.state = inject_queries(self.state, jnp.asarray(qb),
                                    jnp.asarray(sb), jnp.asarray(eb), n)

    def advance(self, k: int = 16) -> int:
        """Run at most ``k`` supersteps; returns how many executed."""
        before = int(self.state.stats.supersteps)
        self.state = self._runner(self.graph, self.state, self.seed, k)
        return int(self.state.stats.supersteps) - before

    def done_mask(self) -> np.ndarray:
        """(capacity,) bool — True where that slot's query terminated."""
        return np.asarray(self.state.done)

    def harvest_device(self, qids):
        """Recorded (paths, lengths) rows for the given slot ids (device)."""
        idx = jnp.asarray(np.asarray(qids, np.int32))
        return self.state.paths[idx], self.state.lengths[idx]

    def walk_stats(self) -> WalkStats:
        """Engine counters since construction/reset (host ints)."""
        return WalkStats(*(int(getattr(self.state.stats, f))
                           for f in WalkStats._fields))

    def reset(self, seed: Optional[int] = None) -> None:
        """Fresh state and ring (keeps the compiled runner warm); pass a
        new ``seed`` to decorrelate from previous runs."""
        if self._live.any():
            raise RuntimeError("reset with live queries outstanding")
        if seed is not None:
            self.seed = seed
        self.state = init_stream_state(self._cfg, self.capacity)
        self._init_ring()


class ShardedWalkStream(_StreamBase):
    """Persistent sharded open-system stream (``backend="sharded"``).

    Same interface and same ring economy as :class:`WalkStream`, running
    over the capability-dispatched distributed superstep: arrivals are
    staged round-robin onto per-device arrival rings and the butterfly
    router carries each new task to owner(start_vertex); the psum
    flow-control admits injections only while global live tasks stay
    ≤ N·W_loc, so the closed engine's losslessness (drops == 0) carries
    over to the open system.  Harvest max-folds the per-device path
    windows (each hop is recorded by exactly the device that executed it).

    Bit-identity: the ``(epoch, qid)`` occupant samples exactly the walk
    ``Walker.run`` samples for query ``qid`` under
    ``rng.stream_key(seed, epoch)`` — identical across backends.
    """

    def __init__(self, program: WalkProgram, cfg, pg: PartitionedGraph,
                 mesh, capacity: int, seed: int):
        if capacity <= 0:
            raise ValueError(f"stream capacity must be positive, got "
                             f"{capacity}")
        self.program = program
        self.graph = pg
        self.seed = seed
        self.capacity = int(capacity)
        self._cfg = cfg
        self._mesh = mesh
        self._runner = make_sharded_stream_engine(pg, program.spec, cfg,
                                                  mesh, self.capacity)
        self.state = init_dist_stream_state(pg, program.spec, cfg,
                                            self.capacity)
        self._base_key = task_rng.stream_key(seed)
        self._next_dev = 0  # round-robin staging cursor
        self._init_ring()

    @property
    def num_slots(self) -> int:
        """W — total lanes across the mesh (devices × W_loc)."""
        return self.graph.num_devices * self._cfg.slots_per_device

    @property
    def max_hops(self) -> int:
        """The program's hop budget (path buffers are ``max_hops + 1``)."""
        return self.program.max_hops

    @property
    def cfg(self):
        """The lowered engine-layer config (:class:`DistConfig`)."""
        return self._cfg

    def _device_inject(self, qids, starts, epochs) -> None:
        n = qids.shape[0]
        N = self.graph.num_devices
        per_dev = -(-n // N)
        b = min(_pad_block(per_dev), self.capacity)
        qb = np.zeros((N, b), np.int32)
        sb = np.zeros((N, b), np.int32)
        eb = np.zeros((N, b), np.int32)
        cnt = np.zeros((N,), np.int32)
        for i in range(n):
            r = (self._next_dev + i) % N
            qb[r, cnt[r]] = qids[i]
            sb[r, cnt[r]] = starts[i]
            eb[r, cnt[r]] = epochs[i]
            cnt[r] += 1
        self._next_dev = (self._next_dev + n) % N
        self.state = inject_stream_queries(
            self.state, jnp.asarray(sb), jnp.asarray(qb), jnp.asarray(eb),
            jnp.asarray(cnt))

    def advance(self, k: int = 16) -> int:
        """Run at most ``k`` supersteps; returns how many executed."""
        before = int(jnp.max(self.state.stats.supersteps))
        self.state = self._runner(self.graph, self.state, self._base_key, k)
        return int(jnp.max(self.state.stats.supersteps)) - before

    def done_mask(self) -> np.ndarray:
        """(capacity,) bool — a slot is done once any device terminated
        its occupant's walk."""
        return np.asarray(jnp.any(self.state.done, axis=0))

    def harvest_device(self, qids):
        """Max-fold the per-device path windows for the given slot ids —
        a cross-device reduction, but the result stays on device."""
        idx = jnp.asarray(np.asarray(qids, np.int32))
        return (jnp.max(self.state.paths[:, idx, :], axis=0),
                jnp.max(self.state.lengths[:, idx], axis=0))

    def walk_stats(self) -> WalkStats:
        """Engine counters summed across devices (supersteps/launches are
        the global lockstep clock: max)."""
        return WalkStats(*(
            int(jnp.max(v)) if name in ("supersteps", "launches")
            else int(jnp.sum(v))
            for name, v in zip(WalkStats._fields, self.state.stats)))

    def reset(self, seed: Optional[int] = None) -> None:
        """Fresh state and ring (keeps the compiled runner warm)."""
        if self._live.any():
            raise RuntimeError("reset with live queries outstanding")
        if seed is not None:
            self.seed = seed
            self._base_key = task_rng.stream_key(seed)
        self.state = init_dist_stream_state(self.graph, self.program.spec,
                                            self._cfg, self.capacity)
        self._next_dev = 0
        self._init_ring()
