"""Declarative walk programs (the algorithm half of the unified API).

RidgeWalker's Markov decomposition (paper §V-A) makes every hop a
stateless task, so *one* program description — sampler + termination +
hop budget — serves every execution regime: closed batch, open stream,
multi-tenant service, and multi-device sharding.  :class:`WalkProgram`
is that description.  It deliberately carries **no machine knobs**
(lane counts, staging depths, device placement live in
:class:`repro.walker.ExecutionConfig`); the same program compiles to any
backend via :func:`repro.walker.compile`.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.samplers import SamplerSpec


@dataclasses.dataclass(frozen=True)
class WalkProgram:
    """One graph-random-walk algorithm, decoupled from the machine.

    Attributes:
      spec:      the sampling module configuration (paper Table I).
      max_hops:  hop budget per query (paper §VIII-A4: 80).
      name:      optional label for logs / benchmark rows.
    """

    spec: SamplerSpec = SamplerSpec(kind="uniform")
    max_hops: int = 80
    name: str = ""

    def __post_init__(self):
        # Sampler-level constraints (kind, schedule, p/q, stop_prob) are
        # validated by SamplerSpec itself at construction, so a malformed
        # spec fails before it can reach tracing; only the program-level
        # hop budget is checked here.
        if self.max_hops <= 0:
            raise ValueError(
                f"WalkProgram.max_hops must be positive, got {self.max_hops}; "
                "a walk needs at least one hop of budget")

    # ------------------------------------------------------------ factories

    @staticmethod
    def urw(max_hops: int = 80) -> "WalkProgram":
        """Unbiased random walk [49]: uniform neighbor sampling."""
        return WalkProgram(SamplerSpec(kind="uniform"), max_hops, "urw")

    @staticmethod
    def ppr(alpha: float = 0.15, max_hops: int = 80) -> "WalkProgram":
        """Personalized PageRank walks [50]: geometric termination with
        teleport probability α; endpoints estimate PPR mass."""
        return WalkProgram(SamplerSpec(kind="uniform", stop_prob=alpha),
                           max_hops, "ppr")

    @staticmethod
    def deepwalk(max_hops: int = 80) -> "WalkProgram":
        """DeepWalk [5]: Walker alias sampling over weighted neighbor
        lists.  The graph must carry alias tables."""
        return WalkProgram(SamplerSpec(kind="alias"), max_hops, "deepwalk")

    @staticmethod
    def node2vec(p: float = 2.0, q: float = 0.5, max_hops: int = 80,
                 weighted: bool = False,
                 rejection_rounds: int = 12) -> "WalkProgram":
        """Node2Vec [9]: bounded-round rejection sampling (unweighted) or
        Efraimidis–Spirakis reservoir sampling (weighted) — paper Table I."""
        kind = "reservoir_n2v" if weighted else "rejection_n2v"
        return WalkProgram(
            SamplerSpec(kind=kind, p=p, q=q,
                        rejection_rounds=rejection_rounds),
            max_hops, "node2vec_w" if weighted else "node2vec")

    @staticmethod
    def metapath(schedule: Sequence[int], max_hops: int = 80) -> "WalkProgram":
        """MetaPath walks [16]: hop t samples uniformly among neighbors of
        edge type schedule[t mod len]; no match → early termination."""
        return WalkProgram(
            SamplerSpec(kind="metapath",
                        metapath=tuple(int(t) for t in schedule)),
            max_hops, "metapath")

    # ------------------------------------------------------------ helpers

    @property
    def second_order(self) -> bool:
        """Whether sampling conditions on ``v_prev`` (Node2Vec family)."""
        return self.spec.second_order

    def requires(self, graph) -> None:
        """Validate that ``graph`` carries the payloads this program samples
        from; raises ValueError with an actionable message otherwise."""
        if self.spec.kind == "alias" and not graph.has_alias:
            raise ValueError(
                "alias (DeepWalk) programs need alias tables on the graph — "
                "build it with with_alias=True / graph.alias.build_alias_tables")
        if self.spec.kind == "metapath" and getattr(graph, "typed", False) is False:
            raise ValueError(
                "metapath programs need a typed graph (num_edge_types > 0)")
