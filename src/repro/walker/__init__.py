"""Unified walker API: one declarative program, every backend.

``WalkProgram`` (algorithm: sampler + termination + hop budget) ×
``ExecutionConfig`` (machine: slots, staging, placement) →
``compile(program, backend=...)`` → a ``Walker`` exposing

  * ``.run(graph, starts)``  — closed batch, drained to completion;
  * ``.stream(graph, ...)``  — continuous open system: ring-buffer slot
    reclamation (inject / advance / harvest / release), no drain barrier;
  * ``.serve(graph, ...)``   — multi-tenant ``WalkService``;

each on ``backend="single"`` or ``"sharded"`` (vertex-partitioned
``shard_map`` execution, bit-identical to single-device; ``.stream`` is a
``WalkStream`` or ``ShardedWalkStream`` with one shared interface).

The legacy surfaces (`run_walks`, `make_engine`, `run_distributed`)
remain as deprecated shims; the `core.walks` and `core.distributed_n2v`
modules (two PRs past deprecation) are gone — see the migration table in
``docs/api.md``.
"""
from repro.walker.compile import (BACKENDS, ShardedWalkStream, Walker,
                                  WalkStream, compile)
from repro.walker.execution import ExecutionConfig
from repro.walker.program import WalkProgram

__all__ = [
    "WalkProgram",
    "ExecutionConfig",
    "compile",
    "Walker",
    "WalkStream",
    "ShardedWalkStream",
    "BACKENDS",
]
