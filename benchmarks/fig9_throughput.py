"""Fig. 9 analogue: per-algorithm throughput (MStep/s) across the six
dataset stand-ins — URW / PPR / DeepWalk / Node2Vec (paper §VIII-C).

The H100 gSampler baseline is not runnable here; we report our engine's
absolute throughput per algorithm and the paper's published relative
positions for context in EXPERIMENTS.md.
"""
import numpy as np

from benchmarks.common import bench_walk, emit
from repro.core.samplers import SamplerSpec
from repro.core.walk_engine import EngineConfig
from repro.graph import make_dataset

ALGOS = {
    "urw": (SamplerSpec(kind="uniform"), {}),
    "ppr": (SamplerSpec(kind="uniform", stop_prob=0.15), {}),
    "deepwalk": (SamplerSpec(kind="alias"),
                 dict(weighted=True, with_alias=True)),
    "node2vec": (SamplerSpec(kind="rejection_n2v", p=2.0, q=0.5), {}),
}
CFG = EngineConfig(num_slots=1024, max_hops=80, record_paths=False)


def run(quick: bool = False):
    import dataclasses
    datasets = ["WG", "CP"] if quick else ["WG", "CP", "AS", "LJ", "AB", "UK"]
    queries = 2000 if quick else 6000
    cfg = dataclasses.replace(CFG, num_slots=256 if quick else 1024)
    out = {}
    for ds in datasets:
        for algo, (spec, kwargs) in ALGOS.items():
            if quick and algo == "node2vec" and ds != "WG":
                continue
            g = make_dataset(ds, **kwargs)
            starts = np.random.default_rng(1).integers(
                0, g.num_vertices, queries)
            dt, a = bench_walk(g, starts, spec, cfg)
            emit(f"fig9_{algo}_{ds}", dt * 1e6,
                 f"msteps={a.msteps_per_s:.3f};steps={a.steps};"
                 f"occ={a.occupancy:.2f}")
            out[(algo, ds)] = a.msteps_per_s
    return out


if __name__ == "__main__":
    run()
