"""Fig. 9 analogue: per-algorithm throughput (MStep/s) across the six
dataset stand-ins — URW / PPR / DeepWalk / Node2Vec (paper §VIII-C).

The H100 gSampler baseline is not runnable here; we report our engine's
absolute throughput per algorithm and the paper's published relative
positions for context in EXPERIMENTS.md.
"""
import numpy as np

from benchmarks.common import bench_walk, emit
from repro.graph import make_dataset
from repro.walker import ExecutionConfig, WalkProgram

ALGOS = {
    "urw": (WalkProgram.urw(80), {}),
    "ppr": (WalkProgram.ppr(0.15, 80), {}),
    "deepwalk": (WalkProgram.deepwalk(80),
                 dict(weighted=True, with_alias=True)),
    "node2vec": (WalkProgram.node2vec(2.0, 0.5, 80), {}),
}


def run(quick: bool = False):
    datasets = ["WG", "CP"] if quick else ["WG", "CP", "AS", "LJ", "AB", "UK"]
    queries = 2000 if quick else 6000
    ex = ExecutionConfig(num_slots=256 if quick else 1024,
                         record_paths=False)
    out = {}
    for ds in datasets:
        for algo, (program, kwargs) in ALGOS.items():
            if quick and algo == "node2vec" and ds != "WG":
                continue
            g = make_dataset(ds, **kwargs)
            starts = np.random.default_rng(1).integers(
                0, g.num_vertices, queries)
            dt, a = bench_walk(g, starts, program, ex)
            emit(f"fig9_{algo}_{ds}", dt * 1e6,
                 f"msteps={a.msteps_per_s:.3f};steps={a.steps};"
                 f"occ={a.occupancy:.2f}")
            out[(algo, ds)] = a.msteps_per_s
    return out


if __name__ == "__main__":
    run()
