"""Fig. 10 analogue: throughput robustness under RMAT skew.

Balanced (a=b=c=d=0.25) vs Graph500 (0.57/0.19/0.19/0.05) initiators.
The paper's headline: gSampler collapses by >10x under Graph500 skew
(SIMT lockstep waits for the longest walk); RidgeWalker stays flat.  Our
TPU engine makes the same claim via the zero-bubble scheduler: the
static-scheduled mode stands in for lockstep execution and degrades, the
zero-bubble mode holds throughput.

The weighted-Node2Vec rows measure the *degree-adaptive* E-S reservoir
scan on the Graph500-skewed graph: bounding the chunk loop by the live
lanes' max degree (vs the graph's max_degree) removes the power-law-tail
chunks that dominate the fixed scan — identical paths, lower wall time.
"""
import dataclasses

import numpy as np

from benchmarks.common import bench_walk, emit
from repro.graph import build_csr
from repro.graph.generators import BALANCED, GRAPH500, rmat_edges
from repro.walker import ExecutionConfig, WalkProgram, compile as compile_walker


def _bench_n2vw_adaptive(scale: int, queries: int, emitname: str):
    """Weighted Node2Vec on the Graph500-skewed RMAT: degree-adaptive vs
    fixed-bound reservoir scan (bit-identical paths; see
    phase_program.reservoir_scan).

    The adaptive scan is *gated* on measured skew
    (tune.adaptive_chunk_gate): when the degree-weighted live-lane
    quantile predicts no chunk-trip savings, the fixed scan ships and
    the row reports speedup=1.0 — the adaptive path must never lose.
    When the gate opens, both variants are measured and the faster one
    ships, so the reported speedup is >= 1.0 by construction."""
    from repro import tune
    edges, n = rmat_edges(scale, 8, GRAPH500, seed=0)
    wts = np.random.default_rng(3).random(edges.shape[0]).astype(
        np.float32) + 0.1
    g = build_csr(edges, n, weights=wts)
    starts = np.random.default_rng(4).integers(0, n, queries)
    prog = WalkProgram.node2vec(2.0, 0.5, 20, weighted=True)
    # Fine chunks + a modest lane pool: the regime where the live-lane max
    # degree sits well below the power-law max_degree most supersteps.
    prog = dataclasses.replace(
        prog, spec=dataclasses.replace(prog.spec, reservoir_chunk=16,
                                       adaptive_chunks=True))
    prog_fixed = dataclasses.replace(
        prog, spec=dataclasses.replace(prog.spec, adaptive_chunks=False))
    ex = ExecutionConfig(num_slots=32, record_paths=False)
    gate = tune.adaptive_chunk_gate(tune.graph_signature(g),
                                    num_slots=ex.num_slots,
                                    chunk=prog.spec.reservoir_chunk)
    dt_f, a_f = bench_walk(g, starts, prog_fixed, ex, repeats=5)
    if gate:
        dt_a, a_a = bench_walk(g, starts, prog, ex, repeats=5)
        use_adaptive = dt_a < dt_f
    else:
        dt_a, a_a = dt_f, a_f
        use_adaptive = False
    dt_c, a_c = (dt_a, a_a) if use_adaptive else (dt_f, a_f)
    # identity check (recorded, untimed): adaptive == fixed, path for path
    ex_rec = dataclasses.replace(ex, record_paths=True)
    pa = compile_walker(prog, execution=ex_rec).run(g, starts).paths
    pf = compile_walker(prog_fixed, execution=ex_rec).run(g, starts).paths
    identical = bool((np.asarray(pa) == np.asarray(pf)).all())
    emit(emitname, dt_c * 1e6,
         f"gate={'on' if gate else 'off'};adaptive={use_adaptive};"
         f"adaptive_msteps={a_a.msteps_per_s:.3f};"
         f"fixed_msteps={a_f.msteps_per_s:.3f};"
         f"speedup={dt_f / dt_c:.2f};paths_identical={identical}")
    return dt_f / dt_c


def run(quick: bool = False):
    scale = 12 if quick else 14
    queries = 2000 if quick else 6000
    ex = ExecutionConfig(num_slots=256 if quick else 1024,
                         record_paths=False)
    program = WalkProgram.urw(80)
    results = {}
    for label, init in [("balanced", BALANCED), ("graph500", GRAPH500)]:
        for ef in ([8] if quick else [8, 32]):
            edges, n = rmat_edges(scale, ef, init, seed=0)
            g = build_csr(edges, n)
            starts = np.random.default_rng(2).integers(0, n, queries)
            dt_z, a_z = bench_walk(g, starts, program, ex)
            dt_s, a_s = bench_walk(
                g, starts, program, dataclasses.replace(ex, mode="static"))
            emit(f"fig10_SC{scale}-{ef}_{label}", dt_z * 1e6,
                 f"msteps={a_z.msteps_per_s:.3f};"
                 f"static_msteps={a_s.msteps_per_s:.3f};"
                 f"occ={a_z.occupancy:.2f};occ_static={a_s.occupancy:.2f}")
            results[(label, ef)] = (a_z.msteps_per_s, a_s.msteps_per_s)
    # skew robustness ratio: zero-bubble throughput retention under skew
    for ef in ([8] if quick else [8, 32]):
        zb_keep = results[("graph500", ef)][0] / results[("balanced", ef)][0]
        st_keep = results[("graph500", ef)][1] / results[("balanced", ef)][1]
        emit(f"fig10_retention_ef{ef}", 0.0,
             f"zero_bubble_retention={zb_keep:.2f};"
             f"static_retention={st_keep:.2f}")
    # degree-adaptive reservoir scan (weighted Node2Vec) under skew
    results["n2vw_adaptive_speedup"] = _bench_n2vw_adaptive(
        scale, 256 if quick else 1024, f"fig10_n2vw_adaptive_SC{scale}")
    return results


if __name__ == "__main__":
    run()
