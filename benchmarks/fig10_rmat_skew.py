"""Fig. 10 analogue: throughput robustness under RMAT skew.

Balanced (a=b=c=d=0.25) vs Graph500 (0.57/0.19/0.19/0.05) initiators.
The paper's headline: gSampler collapses by >10x under Graph500 skew
(SIMT lockstep waits for the longest walk); RidgeWalker stays flat.  Our
TPU engine makes the same claim via the zero-bubble scheduler: the
static-scheduled mode stands in for lockstep execution and degrades, the
zero-bubble mode holds throughput."""
import dataclasses

import numpy as np

from benchmarks.common import bench_walk, emit
from repro.graph import build_csr
from repro.graph.generators import BALANCED, GRAPH500, rmat_edges
from repro.walker import ExecutionConfig, WalkProgram


def run(quick: bool = False):
    scale = 12 if quick else 14
    queries = 2000 if quick else 6000
    ex = ExecutionConfig(num_slots=256 if quick else 1024,
                         record_paths=False)
    program = WalkProgram.urw(80)
    results = {}
    for label, init in [("balanced", BALANCED), ("graph500", GRAPH500)]:
        for ef in ([8] if quick else [8, 32]):
            edges, n = rmat_edges(scale, ef, init, seed=0)
            g = build_csr(edges, n)
            starts = np.random.default_rng(2).integers(0, n, queries)
            dt_z, a_z = bench_walk(g, starts, program, ex)
            dt_s, a_s = bench_walk(
                g, starts, program, dataclasses.replace(ex, mode="static"))
            emit(f"fig10_SC{scale}-{ef}_{label}", dt_z * 1e6,
                 f"msteps={a_z.msteps_per_s:.3f};"
                 f"static_msteps={a_s.msteps_per_s:.3f};"
                 f"occ={a_z.occupancy:.2f};occ_static={a_s.occupancy:.2f}")
            results[(label, ef)] = (a_z.msteps_per_s, a_s.msteps_per_s)
    # skew robustness ratio: zero-bubble throughput retention under skew
    for ef in ([8] if quick else [8, 32]):
        zb_keep = results[("graph500", ef)][0] / results[("balanced", ef)][0]
        st_keep = results[("graph500", ef)][1] / results[("balanced", ef)][1]
        emit(f"fig10_retention_ef{ef}", 0.0,
             f"zero_bubble_retention={zb_keep:.2f};"
             f"static_retention={st_keep:.2f}")
    return results


if __name__ == "__main__":
    run()
