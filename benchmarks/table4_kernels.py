"""Table IV analogue. The paper reports FPGA LUT/BRAM/DSP budgets; the
TPU equivalents are per-kernel on-chip (VMEM/SMEM) budgets and DMA
depths, derived from the BlockSpec tiling — plus interpret-mode
correctness timing for scale."""
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed


def vmem_budget():
    rows = []
    # walk_step uniform: SMEM task words + 2-deep DMA buffers
    tile = 256
    smem = tile * 4 * 4 + 2 * 2 * 4 + 2 * 1 * 4   # v,u,out*2 scratch + bufs
    rows.append(("walk_step_uniform", smem, 2))
    tile_e, rb, D = 256, 128, 128
    vmem = tile_e * D * 4 + rb * D * 4 + rb * tile_e * 4
    rows.append(("segment_sum", vmem, 1))
    tb, H, D = 128, 8, 16
    vmem = tb * H * 4 * 2 + D * 4 + 2 * D * 4 + tb * D * 4
    rows.append(("embedding_bag", vmem, 2))
    return rows


def run(quick: bool = False):
    for name, bytes_, dma_depth in vmem_budget():
        emit(f"table4_{name}", 0.0,
             f"onchip_bytes={bytes_};dma_depth={dma_depth};"
             f"vmem_frac={bytes_/128e6:.5f}")
    # interpret-mode validation timing (not TPU perf — correctness gate)
    from repro.graph import make_dataset
    from repro.kernels.walk_step import ops as ws
    g = make_dataset("WG", scale_override=10)
    rng = np.random.default_rng(0)
    W = 512
    v = jnp.asarray(rng.integers(0, g.num_vertices, W), jnp.int32)
    u = jnp.asarray(rng.random(W), jnp.float32)
    dt, _ = timed(lambda: ws.walk_step_uniform(v, u, g.row_ptr, g.col,
                                               tile=256))
    emit("table4_walk_step_interpret", dt * 1e6, f"lanes={W}")
    return True


if __name__ == "__main__":
    run()
