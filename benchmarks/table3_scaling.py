"""Table III analogue: throughput scaling with memory channels.

The paper scales across FPGAs with 4/32 memory channels (U250 ->
U55C); the TPU analogue scales the distributed engine across host
devices (each device = one channel's row-pointer + neighbor shard).
Run per device count in a subprocess (device count locks at jax init)."""
import json
import os
import subprocess
import sys

from benchmarks.common import emit

SNIPPET = r"""
import time, numpy as np, jax, json
from repro.graph import make_dataset, partition_graph
from repro import walker

N = {N}
g = make_dataset("WG", scale_override={scale})
pg = partition_graph(g, N)
starts = np.random.default_rng(0).integers(0, g.num_vertices, {queries}).astype(np.int32)
w = walker.compile(
    walker.WalkProgram.urw(80), backend="sharded",
    execution=walker.ExecutionConfig(
        slots_per_device=max(2048 // N, 64), record_paths=False))
res = w.run(pg, starts)   # compile+warm
jax.block_until_ready(res.stats.steps)
t0 = time.time()
res = w.run(pg, starts)
jax.block_until_ready(res.stats.steps)
dt = time.time() - t0
steps = int(np.asarray(res.stats.steps))
waits = int(np.asarray(res.stats.route_waits))
drops = int(np.asarray(res.stats.drops))
print(json.dumps(dict(N=N, dt=dt, steps=steps, msteps=steps/dt/1e6,
                      waits=waits, drops=drops)))
"""


def run(quick: bool = False):
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    counts = [1, 2, 4] if quick else [1, 2, 4, 8]
    results = {}
    for n in counts:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={max(n,2)}"
        env["PYTHONPATH"] = src
        code = SNIPPET.format(N=n, scale=11 if quick else 12,
                              queries=1500 if quick else 4000)
        r = subprocess.run([sys.executable, "-c", code], env=env,
                           capture_output=True, text=True, timeout=900)
        if r.returncode != 0:
            emit(f"table3_ch{n}", 0.0, f"ERROR:{r.stderr[-120:]}")
            continue
        d = json.loads(r.stdout.strip().splitlines()[-1])
        results[n] = d
        emit(f"table3_ch{n}", d["dt"] * 1e6,
             f"msteps={d['msteps']:.3f};waits={d['waits']};"
             f"drops={d['drops']}")
    if 1 in results and max(results) > 1:
        top = max(results)
        eff = (results[top]["msteps"] / results[1]["msteps"]) / top
        emit("table3_scaling_eff", 0.0,
             f"devices={top};parallel_efficiency={eff:.2f}")
    return results


if __name__ == "__main__":
    run()
