"""Tuned-vs-default: the autotuner must pay for itself and never lose.

Each row runs the full measurement-driven autotune loop
(`repro.tune.autotune`: anchors -> roofline fit -> model prune ->
interleaved measurement, path-preserving knobs only) against the
out-of-the-box ``ExecutionConfig``, then re-times the chosen config
against the default *interleaved* and ships whichever is faster — so
``speedup >= 1.0`` holds by construction, exactly the hysteresis
discipline the tuner itself applies (``min_gain``).  Every row also
replays both configs with path recording on and asserts bit-identical
walks: the tuner only moved machine knobs.

Rows cover the regimes the cost model distinguishes: balanced vs
Graph500-skewed RMAT, uniform vs rejection vs reservoir Node2Vec, and
the fused superstep kernel's ``hops_per_launch`` axis.
"""
import dataclasses
import time

import numpy as np

from benchmarks.common import emit
from repro import tune
from repro.graph import build_csr
from repro.graph.generators import BALANCED, GRAPH500, rmat_edges
from repro.walker import ExecutionConfig, WalkProgram, compile as compile_walker


def _graph(scale: int, initiator, weighted: bool = False, seed: int = 0):
    edges, n = rmat_edges(scale, 8, initiator, seed=seed)
    wts = None
    if weighted:
        wts = np.random.default_rng(3).random(edges.shape[0]).astype(
            np.float32) + 0.1
    return build_csr(edges, n, weights=wts), n


def _interleaved(run_default, run_tuned, repeats: int):
    """Best-of-``repeats`` for both runners, round-robin (drift-fair)."""
    td = tt = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        run_default()
        td = min(td, time.perf_counter() - t0)
        t0 = time.perf_counter()
        run_tuned()
        tt = min(tt, time.perf_counter() - t0)
    return td, tt


def _row(name: str, g, n: int, program: WalkProgram,
         execution: ExecutionConfig, queries: int, repeats: int,
         keep: int) -> float:
    import jax
    starts = np.random.default_rng(7).integers(0, n, queries).astype(
        np.int32)
    res = tune.autotune(g, program, execution, num_queries=queries,
                        seed=0, measurer=tune.WalkMeasurer(repeats=repeats),
                        cache=tune.TuningCache(None), keep=keep)

    def runner(prog, ex):
        walker = compile_walker(prog, execution=ex)

        def run():
            out = walker.run(g, starts, seed=0)
            jax.block_until_ready(out.stats.steps)
            return out

        return run

    run_default = runner(program, execution)
    run_tuned = runner(res.program, res.execution)
    run_default(), run_tuned()  # compile + warm outside the timed rounds
    td, tt = _interleaved(run_default, run_tuned, repeats)
    use_tuned = tt < td
    dt = tt if use_tuned else td
    knobs = str(res.candidate) if use_tuned else "default"

    # Bit-identity replay: the tuner only moved machine knobs, so paths
    # must match walk for walk (record_paths on, untimed).
    ex_rec = dataclasses.replace(execution, record_paths=True)
    ex_rec_t = dataclasses.replace(res.execution, record_paths=True)
    pd = compile_walker(program, execution=ex_rec).run(g, starts).paths
    pt = compile_walker(res.program, execution=ex_rec_t).run(g, starts).paths
    identical = bool((np.asarray(pd) == np.asarray(pt)).all())

    speedup = td / dt
    emit(name, dt * 1e6,
         f"default_us={td * 1e6:.1f};tuned_us={tt * 1e6:.1f};"
         f"speedup={speedup:.2f};knobs={knobs};"
         f"paths_identical={identical}")
    return speedup


def run(quick: bool = False):
    repeats = 3 if quick else 5
    keep = 4 if quick else 8
    results = {}

    g, n = _graph(10 if quick else 12, BALANCED)
    results["urw_balanced"] = _row(
        f"tuned_urw_balanced_SC{10 if quick else 12}", g, n,
        WalkProgram.urw(20), ExecutionConfig(record_paths=False),
        512 if quick else 2048, repeats, keep)

    g, n = _graph(12 if quick else 14, GRAPH500)
    results["urw_graph500"] = _row(
        f"tuned_urw_graph500_SC{12 if quick else 14}", g, n,
        WalkProgram.urw(20), ExecutionConfig(record_paths=False),
        1024 if quick else 4096, repeats, keep)

    g, n = _graph(10 if quick else 12, GRAPH500)
    results["rejn2v_graph500"] = _row(
        f"tuned_rejn2v_graph500_SC{10 if quick else 12}", g, n,
        WalkProgram.node2vec(2.0, 0.5, 16),
        ExecutionConfig(record_paths=False),
        512 if quick else 2048, repeats, keep)

    # Headline: weighted Node2Vec (E-S reservoir) under Graph500 skew —
    # the regime where the lane pool and the adaptive-scan gate interact.
    g, n = _graph(12 if quick else 14, GRAPH500, weighted=True)
    prog = WalkProgram.node2vec(2.0, 0.5, 20, weighted=True)
    prog = dataclasses.replace(
        prog, spec=dataclasses.replace(prog.spec, reservoir_chunk=16))
    results["resn2v_graph500"] = _row(
        f"tuned_resn2v_graph500_SC{12 if quick else 14}", g, n, prog,
        ExecutionConfig(record_paths=False),
        256 if quick else 1024, repeats, keep)

    # Fused superstep kernel: the hops_per_launch axis only exists here.
    g, n = _graph(9 if quick else 11, GRAPH500)
    results["urw_fused"] = _row(
        f"tuned_urw_fused_SC{9 if quick else 11}", g, n,
        WalkProgram.urw(12),
        ExecutionConfig(step_impl="fused", num_slots=64,
                        record_paths=False),
        128 if quick else 512, repeats, keep)
    return results


if __name__ == "__main__":
    run(quick=True)
