"""Shared benchmark utilities. Every benchmark prints
``name,us_per_call,derived`` CSV rows (scaffold contract)."""
from __future__ import annotations

import time

import numpy as np

from repro.core.scheduler import analyze_run
from repro.walker import ExecutionConfig, WalkProgram, compile as compile_walker


def timed(fn, *args, repeats: int = 3, warmup: int = 1, **kw):
    """Median wall time of fn(*args) with block_until_ready."""
    import jax
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), out


def bench_walk(g, starts, program: WalkProgram,
               execution: ExecutionConfig, seed=0, repeats=3):
    """Compile ``program`` on the single-device backend and time the
    closed-batch run.  Returns (median_time_s, RunAnalysis)."""
    import jax
    walker = compile_walker(program, execution=execution)
    sv = np.asarray(starts, np.int32)
    out = walker.run(g, sv, seed=seed)
    jax.block_until_ready(out.stats.steps)   # compile + warm
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = walker.run(g, sv, seed=seed)
        jax.block_until_ready(out.stats.steps)
        ts.append(time.perf_counter() - t0)
    dt = float(np.median(ts))
    return dt, analyze_run(out.stats, dt)


# Rows emitted by every suite, in order — `run.py --json` slices this per
# suite into the machine-readable {suite: {name: {us_per_call, derived}}}.
RECORDS: list[tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
    RECORDS.append((name, float(us_per_call), str(derived)))
