"""Fig. 11 analogue: breakdown of the two optimizations.

Paper configuration axes -> TPU engine axes:
  baseline       = static scheduling + staged (unfused) step
  +scheduler     = zero-bubble refill, staged step
  +async         = static scheduling, fused Pallas walk-step kernel
  full           = zero-bubble + fused kernel

On CPU the fused-kernel axis measures fusion, not DMA overlap (interpret
mode runs the kernel body in Python), so the wall-clock column for the
kernel axis is not meaningful here — the *scheduling* axis and the
occupancy/superstep columns are the CPU-measurable reproduction; the
kernel's TPU value shows up in the §Roofline bytes analysis instead."""
import dataclasses

import numpy as np

from benchmarks.common import bench_walk, emit
from repro.graph import make_dataset
from repro.walker import ExecutionConfig, WalkProgram

MODES = {
    "baseline": dict(mode="static", step_impl="jnp"),
    "+scheduler": dict(mode="zero_bubble", step_impl="jnp"),
    "+async": dict(mode="static", step_impl="pallas"),
    "full": dict(mode="zero_bubble", step_impl="pallas"),
    "+fused": dict(mode="zero_bubble", step_impl="fused"),
}


def run(quick: bool = False):
    datasets = ["WG"] if quick else ["WG", "CP", "AS", "LJ"]
    queries = 2000 if quick else 8000
    slots = 256 if quick else 1024
    program = WalkProgram.urw(80)
    results = {}
    for ds in datasets:
        g = make_dataset(ds)
        starts = np.random.default_rng(3).integers(0, g.num_vertices, queries)
        base_ss = None
        for label, kw in MODES.items():
            if quick and kw["step_impl"] != "jnp":
                # kernel impls run interpreted off-TPU — full mode only
                continue
            ex = dataclasses.replace(
                ExecutionConfig(num_slots=slots, record_paths=False), **kw)
            dt, a = bench_walk(g, starts, program, ex, repeats=2)
            if label == "baseline":
                base_ss = a.supersteps
            sched_speedup = base_ss / a.supersteps if base_ss else 1.0
            emit(f"fig11_{ds}_{label.replace('+','plus_')}", dt * 1e6,
                 f"msteps={a.msteps_per_s:.3f};supersteps={a.supersteps};"
                 f"occ={a.occupancy:.3f};superstep_speedup="
                 f"{sched_speedup:.2f}x")
            results[(ds, label)] = a
    return results


if __name__ == "__main__":
    run()
