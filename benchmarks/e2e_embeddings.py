"""e2e_embeddings — walks → embeddings pipeline (corpus ring + SGNS).

Times `Walker.train_embeddings` end to end on the quick graph: the
walk producer alone (walks/sec), then the full pipeline in serial mode
(host round-trip + blocking grad steps — the naive wiring) vs
overlapped mode (device-resident corpus ring, round r+1's walk launch
dispatched before round r's grad steps, so the two executables run
concurrently).  Both modes compute bit-identical embeddings (pinned by
tests/test_corpus_pipeline.py), so the samples/sec delta is pure
pipelining — the row the BENCH_pr*.json trajectory tracks.

Sizes are chosen so one round's walk time ≈ one round's grad-step time
(the regime the overlap is for — either side much cheaper and there is
nothing to hide).  The timed rows run the jnp gather path
(``use_kernel=False``): off-TPU the Pallas embedding_bag kernel is
interpret-mode emulation, which would measure the emulator, not the
pipeline; the kernel path's parity is pinned by the test suite instead.
"""
import time

import numpy as np

from benchmarks.common import emit
from repro.graph import make_dataset
from repro.walker import WalkProgram, compile as compile_walker


def _time_modes(walker, g, repeats, **kw):
    """Best wall time of serial vs overlapped train_embeddings.

    The two modes are timed interleaved (serial, overlap, serial, ...)
    so slow machine drift lands on both sides equally instead of biasing
    whichever mode happens to run second, and the minimum over repeats
    is reported — the low-noise estimator, applied identically to both.
    """
    import jax

    def one(overlap):
        t0 = time.perf_counter()
        out = walker.train_embeddings(g, **kw, overlap=overlap)
        jax.block_until_ready(out["params"]["in_embed"])
        return time.perf_counter() - t0

    serial, over = [], []
    for _ in range(repeats):
        serial.append(one(False))
        over.append(one(True))
    return float(min(serial)), float(min(over))


def run(quick: bool = True):
    scale = 9 if quick else 12
    g = make_dataset("WG", scale_override=scale)
    rounds = 4 if quick else 8
    walks_per_round = 8192 if quick else 16384
    steps_per_round = 24 if quick else 48
    batch = 1024 if quick else 4096
    dim = 64 if quick else 128
    hops = 256
    w = compile_walker(WalkProgram.urw(max_hops=hops))
    kw = dict(seed=0, rounds=rounds, walks_per_round=walks_per_round,
              steps_per_round=steps_per_round, batch_size=batch,
              dim=dim, window=5, num_negatives=5, use_kernel=False)
    repeats = 5 if quick else 7

    # Producer alone: the closed-batch walk rounds the pipeline issues.
    import jax
    sv = np.arange(walks_per_round, dtype=np.int32) % g.num_vertices
    res = w.run(g, sv, seed=0)
    jax.block_until_ready(res.paths)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        for r in range(rounds):
            res = w.run(g, sv, seed=r)
        jax.block_until_ready(res.paths)
        ts.append(time.perf_counter() - t0)
    t_walk = float(np.median(ts))
    walks = rounds * walks_per_round
    emit("embeddings_walk_producer", t_walk / rounds * 1e6,
         f"walks_per_sec={walks / t_walk:.0f}")

    samples = rounds * steps_per_round * batch
    # Warm both modes (jit compiles are cached on the Walker).
    w.train_embeddings(g, **kw, overlap=False)
    w.train_embeddings(g, **kw, overlap=True)
    t_serial, t_overlap = _time_modes(w, g, repeats, **kw)
    emit("embeddings_serial", t_serial * 1e6,
         f"samples_per_sec={samples / t_serial:.0f}")
    emit("embeddings_overlap", t_overlap * 1e6,
         f"samples_per_sec={samples / t_overlap:.0f}")
    emit("embeddings_overlap_efficiency", t_overlap * 1e6,
         f"speedup={t_serial / t_overlap:.3f}x_vs_serial")
    return {"serial_s": t_serial, "overlap_s": t_overlap,
            "speedup": t_serial / t_overlap}
