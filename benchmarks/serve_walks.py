"""Open-system serving benchmark: offered-load sweep over the streaming
walk service (`repro.serve`).

For each utilization point ρ = λ·E[L]/W we drive Poisson request arrivals
into a WalkService and report the queuing-theoretic service metrics —
p50/p99 request sojourn (submit to last-walk-done, in supersteps), the
host-side admission wait (submit to slot-ring injection; the backlog
signal under the ring-buffer economy), and the engine bubble ratio.
Below saturation (ρ < 1) sojourn should be flat ≈ E[L] + chunk slack and
admission wait ≈ 0; past saturation both grow with the backlog while
bubble ratio falls toward 0 (lanes never idle under overload).

  PYTHONPATH=src python -m benchmarks.serve_walks
  PYTHONPATH=src python -m benchmarks.serve_walks --full

The same sweep runs over the sharded backend (one service over the
distributed superstep; on CPU force devices first):

  PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=2 \
      python -m benchmarks.serve_walks --backend sharded
"""
import argparse
import time

from benchmarks.common import emit
from repro.graph import make_dataset
from repro.serve import OpenLoad, run_open_load
from repro.walker import ExecutionConfig, WalkProgram, compile as compile_walker

# Target utilizations; computed against E[L] = max_hops, so the *measured*
# rho in the output is lower when walks dead-end early. The top points are
# chosen to land past measured saturation (sojourn divergence regime).
RHOS = (0.25, 0.5, 0.9, 1.5, 2.5)


def run(quick: bool = True, backend: str = "single"):
    slots = 128 if quick else 1024
    max_hops = 16 if quick else 80
    requests = 48 if quick else 256
    request_size = 16 if quick else 64
    chunk = 4 if quick else 8
    g = make_dataset("WG", scale_override=10 if quick else None)
    program = WalkProgram.urw(max_hops)
    walker = compile_walker(program, backend=backend,
                            execution=ExecutionConfig(num_slots=slots))

    # One service for the whole sweep: the superstep runner and injection
    # shapes are traced/compiled once (warm-up below), then reset_metrics
    # clears counters + re-seeds the stream between load points so XLA
    # compile never pollutes a timed run.  The slot ring recycles
    # continuously, so capacity only needs to cover peak *concurrency*,
    # not the total request volume.
    svc = walker.serve(g, capacity=2048, chunk=chunk, seed=7)
    run_open_load(svc, OpenLoad(num_requests=4, request_size=request_size,
                                utilization=0.5), seed=99)

    out = {}
    for rho in RHOS:
        svc.reset_metrics()
        load = OpenLoad(num_requests=requests, request_size=request_size,
                        utilization=rho)
        t0 = time.perf_counter()
        a = run_open_load(svc, load, seed=17)
        wall = time.perf_counter() - t0
        emit(f"serve_walks_{backend}_rho{rho:g}",
             wall * 1e6 / max(a.supersteps, 1),  # µs per superstep
             f"offered={a.offered_load:.2f};rho={a.utilization:.2f};"
             f"p50_sojourn={a.p50_sojourn:.1f};p99_sojourn={a.p99_sojourn:.1f};"
             f"p50_wait={a.p50_admission_wait:.1f};"
             f"bubble_ratio={a.bubble_ratio:.3f};"
             f"throughput={a.throughput:.1f}hops/ss;"
             f"msteps={a.msteps_per_s:.3f}")
        out[rho] = a
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--backend", default="single",
                    choices=("single", "sharded"))
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(quick=not args.full, backend=args.backend)
