"""§Roofline: read the dry-run artifacts and print the per-cell
compute/memory/collective terms + dominant bottleneck (deliverable g).

Also derives MODEL_FLOPS = 6·N·D (dense LM) / 6·N_active·D (MoE) and the
useful-compute ratio MODEL_FLOPS / HLO_FLOPS."""
import glob
import json
import os

from benchmarks.common import emit
from repro.configs import get_arch


def model_flops_per_step(arch: str, shape: str) -> float:
    mod = get_arch(arch)
    if mod.FAMILY != "lm":
        return 0.0
    cfg = mod.FULL
    dims = mod.SHAPES[shape].dims
    kind = mod.SHAPES[shape].kind
    n_active = cfg.active_param_count()
    if kind == "train":
        tokens = dims["seq_len"] * dims["global_batch"]
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = dims["seq_len"] * dims["global_batch"]
        return 2.0 * n_active * tokens
    return 2.0 * n_active * dims["global_batch"]  # decode: 1 token/seq


def load_cells(out_dir="experiments/dryrun", mesh="single"):
    cells = []
    for f in sorted(glob.glob(os.path.join(out_dir, mesh, "*.json"))):
        d = json.load(open(f))
        if d.get("status") == "ok":
            cells.append(d)
    return cells


def run(quick: bool = False, mesh: str = "single"):
    cells = load_cells(mesh=mesh)
    if not cells:
        emit("roofline", 0.0, "NO_DRYRUN_ARTIFACTS(run repro.launch.dryrun)")
        return []
    rows = []
    for d in cells:
        r = d["roofline"]
        mf = model_flops_per_step(d["arch"], d["shape"])
        hlo_f = d["cost_analysis"]["flops"] * d.get("chips", 256)
        useful = mf / hlo_f if (mf and hlo_f) else float("nan")
        bound = r["bound_s"]
        frac = {k: r[k] / bound if bound else 0.0
                for k in ("compute_s", "memory_s", "collective_s")}
        emit(f"roofline_{mesh}_{d['arch']}__{d['shape']}",
             bound * 1e6,
             f"dom={r['dominant']};compute={r['compute_s']:.3e};"
             f"memory={r['memory_s']:.3e};coll={r['collective_s']:.3e};"
             f"useful_ratio={useful:.3f}")
        rows.append(dict(arch=d["arch"], shape=d["shape"], **r,
                         useful_ratio=useful))
    return rows


if __name__ == "__main__":
    run()
