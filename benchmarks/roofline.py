"""§Roofline: read the dry-run artifacts and print the per-cell
compute/memory/collective terms + dominant bottleneck (deliverable g).

Also derives MODEL_FLOPS = 6·N·D (dense LM) / 6·N_active·D (MoE) and the
useful-compute ratio MODEL_FLOPS / HLO_FLOPS.

The ``roofline_walk_*`` rows are the walk-engine side: per-sampler
analytic bytes/hop counted off the fused kernel's DMA schedule
(`repro.tune.model.bytes_per_hop` — the same model the autotuner prunes
with), plus the model's predicted closed-batch time on a reference
Graph500-skewed workload.  They need no dry-run artifacts."""
import glob
import json
import os

from benchmarks.common import emit
from repro.configs import get_arch


def model_flops_per_step(arch: str, shape: str) -> float:
    mod = get_arch(arch)
    if mod.FAMILY != "lm":
        return 0.0
    cfg = mod.FULL
    dims = mod.SHAPES[shape].dims
    kind = mod.SHAPES[shape].kind
    n_active = cfg.active_param_count()
    if kind == "train":
        tokens = dims["seq_len"] * dims["global_batch"]
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = dims["seq_len"] * dims["global_batch"]
        return 2.0 * n_active * tokens
    return 2.0 * n_active * dims["global_batch"]  # decode: 1 token/seq


def load_cells(out_dir="experiments/dryrun", mesh="single"):
    cells = []
    for f in sorted(glob.glob(os.path.join(out_dir, mesh, "*.json"))):
        d = json.load(open(f))
        if d.get("status") == "ok":
            cells.append(d)
    return cells


def _walk_rows(quick: bool = True):
    """Analytic walk-engine roofline: bytes/hop + predicted batch time
    per sampler kind on a reference Graph500 RMAT workload."""
    import numpy as np

    from repro import tune
    from repro.graph import build_csr
    from repro.graph.generators import GRAPH500, rmat_edges
    from repro.walker import ExecutionConfig, WalkProgram

    scale = 10 if quick else 12
    queries = 512 if quick else 2048
    edges, n = rmat_edges(scale, 8, GRAPH500, seed=0)
    wts = np.abs(np.sin(np.arange(edges.shape[0]))).astype(np.float32) + 0.1
    g = build_csr(edges, n, weights=wts)
    sig = tune.graph_signature(g)
    ex = ExecutionConfig(record_paths=False)
    programs = {
        "uniform": WalkProgram.urw(20),
        "rejection_n2v": WalkProgram.node2vec(2.0, 0.5, 20),
        "reservoir_n2v": WalkProgram.node2vec(2.0, 0.5, 20, weighted=True),
        "metapath": WalkProgram.metapath([0, 1, 2], 20),
    }
    rows = []
    for kind, prog in programs.items():
        bph = tune.bytes_per_hop(prog.spec, sig)
        pred = tune.predict_us(prog, ex, sig, queries)
        emit(f"roofline_walk_{kind}", pred,
             f"bytes_per_hop={bph:.1f};"
             f"expected_len={tune.expected_walk_len(prog):.1f};"
             f"SC{scale};queries={queries}")
        rows.append(dict(kind=kind, bytes_per_hop=bph, predicted_us=pred))
    return rows


def run(quick: bool = False, mesh: str = "single"):
    walk_rows = _walk_rows(quick=quick)
    cells = load_cells(mesh=mesh)
    if not cells:
        emit("roofline", 0.0, "NO_DRYRUN_ARTIFACTS(run repro.launch.dryrun)")
        return walk_rows
    rows = list(walk_rows)
    for d in cells:
        r = d["roofline"]
        mf = model_flops_per_step(d["arch"], d["shape"])
        hlo_f = d["cost_analysis"]["flops"] * d.get("chips", 256)
        useful = mf / hlo_f if (mf and hlo_f) else float("nan")
        bound = r["bound_s"]
        frac = {k: r[k] / bound if bound else 0.0
                for k in ("compute_s", "memory_s", "collective_s")}
        emit(f"roofline_{mesh}_{d['arch']}__{d['shape']}",
             bound * 1e6,
             f"dom={r['dominant']};compute={r['compute_s']:.3e};"
             f"memory={r['memory_s']:.3e};coll={r['collective_s']:.3e};"
             f"useful_ratio={useful:.3f}")
        rows.append(dict(arch=d["arch"], shape=d["shape"], **r,
                         useful_ratio=useful))
    return rows


if __name__ == "__main__":
    run()
