"""Per-algorithm walks/sec across ``step_impl`` ∈ {jnp, pallas, fused}.

The three implementations sample bit-identical walks (pinned by
``tests/test_fused_step.py``); this suite tracks what each one *costs*:

  * ``jnp``    — vectorized XLA superstep, one dispatch per hop.
  * ``pallas`` — one-hop fused walk-step kernel inside the jnp superstep.
  * ``fused``  — device-resident multi-hop superstep kernel
                 (``hops_per_launch`` supersteps per launch).

Off-TPU the Pallas kernels run in interpret mode, so the pallas/fused
rows measure the interpreter, not the hardware — the suite pins the
harness and the BENCH.json schema either way, and becomes the fused-
pipeline headline number on a real TPU.  ``walks_per_s`` is completed
queries per wall-second of the closed-batch drain.
"""
import time

import numpy as np

from benchmarks.common import bench_walk, emit
from repro.graph import build_csr, make_dataset
from repro.graph.generators import GRAPH500, rmat_edges
from repro.walker import (ExecutionConfig, WalkProgram,
                          compile as compile_walker)

IMPLS = ("jnp", "pallas", "fused")

# Sampler kinds the cached-vs-uncached rows track: the pure-column
# gather, the typed metapath gather (type_offsets payload), and the
# chunked E-S reservoir (weights payload) — one row per cache payload
# shape.
CACHED_ALGOS = ("urw", "metapath", "reservoir_n2v")


def _algos(hops):
    return {
        "urw": WalkProgram.urw(hops),
        "ppr": WalkProgram.ppr(0.15, hops),
        "deepwalk": WalkProgram.deepwalk(hops),
        # PR-5 fused coverage: the rejection verify phase and the typed
        # metapath gather now run inside the device-resident kernel.
        "rejection_n2v": WalkProgram.node2vec(2.0, 0.5, hops,
                                              rejection_rounds=8),
        "metapath": WalkProgram.metapath([0, 1, 2], hops),
        # PR-6 fused coverage: weighted Node2Vec's chunked E-S reservoir
        # runs the in-kernel chunk loop — the last matrix row.
        "reservoir_n2v": WalkProgram.node2vec(2.0, 0.5, hops,
                                              weighted=True),
    }


def run(quick: bool = False):
    scale = 9 if quick else 11
    queries = 192 if quick else 1024
    hops = 12 if quick else 40
    slots = 64 if quick else 256
    g = make_dataset("WG", scale_override=scale, weighted=True,
                     with_alias=True, num_edge_types=3)
    starts = np.random.default_rng(1).integers(0, g.num_vertices, queries)
    out = {}
    for algo, program in _algos(hops).items():
        for impl in IMPLS:
            ex = ExecutionConfig(num_slots=slots, record_paths=False,
                                 step_impl=impl, hops_per_launch=8)
            dt, a = bench_walk(g, starts, program, ex, repeats=2)
            wps = queries / dt
            emit(f"impl_{algo}_{impl}", dt * 1e6,
                 f"walks_per_s={wps:.1f};msteps={a.msteps_per_s:.3f};"
                 f"supersteps_per_launch={a.supersteps_per_launch:.1f}")
            out.setdefault(algo, {})[impl] = wps
    # Fused kernel with hops_per_launch="auto": the compile-time resolver
    # (cache -> cost model, no wall clock) picks the launch granularity.
    ex = ExecutionConfig(num_slots=slots, record_paths=False,
                         step_impl="fused", hops_per_launch="auto")
    dt, a = bench_walk(g, starts, _algos(hops)["urw"], ex, repeats=2)
    wps = queries / dt
    emit("impl_urw_fused_auto", dt * 1e6,
         f"walks_per_s={wps:.1f};msteps={a.msteps_per_s:.3f};"
         f"supersteps_per_launch={a.supersteps_per_launch:.1f}")
    out.setdefault("urw", {})["fused_auto"] = wps
    _cached_rows(out, quick)
    return out


def _cached_rows(out, quick: bool):
    """Cached vs uncached fused superstep on a Graph500-skewed RMAT.

    The hot-vertex cache targets exactly this degree distribution: a few
    hubs carry most of the stationary gather traffic, so a small VMEM
    budget absorbs a large hit fraction.  Both variants are timed
    interleaved (min-of-k, drift-fair) and the *shipped* row is whichever
    is faster — fallback-to-default, so the reported speedup is >= 1.0 by
    construction and turning the cache on can never regress a
    deployment.  Hit rate and both raw timings ride in ``derived``.
    """
    import jax

    scale = 8 if quick else 10
    queries = 128 if quick else 512
    hops = 10 if quick else 24
    slots = 64 if quick else 256
    budget = (1 << 14) if quick else (1 << 17)
    repeats = 3
    edges, n = rmat_edges(scale, 8, GRAPH500, seed=2)
    r = np.random.default_rng(5)
    g = build_csr(edges, n,
                  weights=r.random(edges.shape[0]).astype(np.float32) + 1e-3,
                  edge_types=r.integers(0, 3, edges.shape[0]).astype(
                      np.int32),
                  num_edge_types=3)
    starts = np.random.default_rng(11).integers(0, n, queries).astype(
        np.int32)
    algos = {
        "urw": WalkProgram.urw(hops),
        "metapath": WalkProgram.metapath([0, 1, 2], hops),
        "reservoir_n2v": WalkProgram.node2vec(2.0, 0.5, hops, weighted=True),
    }
    for algo in CACHED_ALGOS:
        program = algos[algo]

        def runner(cb):
            ex = ExecutionConfig(num_slots=slots, record_paths=False,
                                 step_impl="fused", hops_per_launch=8,
                                 cache_budget=cb)
            w = compile_walker(program, execution=ex)

            def run():
                res = w.run(g, starts, seed=0)
                jax.block_until_ready(res.stats.steps)
                return res

            return run

        run_off, run_on = runner(0), runner(budget)
        run_off()                      # compile + warm
        hit = float(run_on().stats.cache_hit_rate())
        t_off = t_on = float("inf")
        for _ in range(repeats):       # interleaved min-of-k
            t0 = time.perf_counter()
            run_off()
            t_off = min(t_off, time.perf_counter() - t0)
            t0 = time.perf_counter()
            run_on()
            t_on = min(t_on, time.perf_counter() - t0)
        ship_cached = t_on < t_off
        dt = t_on if ship_cached else t_off
        speedup = max(t_off / t_on, 1.0)
        wps = queries / dt
        emit(f"impl_{algo}_fused_cached", dt * 1e6,
             f"walks_per_s={wps:.1f};uncached_us={t_off * 1e6:.1f};"
             f"cached_us={t_on * 1e6:.1f};speedup={speedup:.2f};"
             f"hit_rate={hit:.3f};"
             f"ship={'cached' if ship_cached else 'default'}")
        out.setdefault(algo, {})["fused_cached"] = wps


if __name__ == "__main__":
    run()
