"""Per-algorithm walks/sec across ``step_impl`` ∈ {jnp, pallas, fused}.

The three implementations sample bit-identical walks (pinned by
``tests/test_fused_step.py``); this suite tracks what each one *costs*:

  * ``jnp``    — vectorized XLA superstep, one dispatch per hop.
  * ``pallas`` — one-hop fused walk-step kernel inside the jnp superstep.
  * ``fused``  — device-resident multi-hop superstep kernel
                 (``hops_per_launch`` supersteps per launch).

Off-TPU the Pallas kernels run in interpret mode, so the pallas/fused
rows measure the interpreter, not the hardware — the suite pins the
harness and the BENCH.json schema either way, and becomes the fused-
pipeline headline number on a real TPU.  ``walks_per_s`` is completed
queries per wall-second of the closed-batch drain.
"""
import numpy as np

from benchmarks.common import bench_walk, emit
from repro.graph import make_dataset
from repro.walker import ExecutionConfig, WalkProgram

IMPLS = ("jnp", "pallas", "fused")


def _algos(hops):
    return {
        "urw": WalkProgram.urw(hops),
        "ppr": WalkProgram.ppr(0.15, hops),
        "deepwalk": WalkProgram.deepwalk(hops),
        # PR-5 fused coverage: the rejection verify phase and the typed
        # metapath gather now run inside the device-resident kernel.
        "rejection_n2v": WalkProgram.node2vec(2.0, 0.5, hops,
                                              rejection_rounds=8),
        "metapath": WalkProgram.metapath([0, 1, 2], hops),
        # PR-6 fused coverage: weighted Node2Vec's chunked E-S reservoir
        # runs the in-kernel chunk loop — the last matrix row.
        "reservoir_n2v": WalkProgram.node2vec(2.0, 0.5, hops,
                                              weighted=True),
    }


def run(quick: bool = False):
    scale = 9 if quick else 11
    queries = 192 if quick else 1024
    hops = 12 if quick else 40
    slots = 64 if quick else 256
    g = make_dataset("WG", scale_override=scale, weighted=True,
                     with_alias=True, num_edge_types=3)
    starts = np.random.default_rng(1).integers(0, g.num_vertices, queries)
    out = {}
    for algo, program in _algos(hops).items():
        for impl in IMPLS:
            ex = ExecutionConfig(num_slots=slots, record_paths=False,
                                 step_impl=impl, hops_per_launch=8)
            dt, a = bench_walk(g, starts, program, ex, repeats=2)
            wps = queries / dt
            emit(f"impl_{algo}_{impl}", dt * 1e6,
                 f"walks_per_s={wps:.1f};msteps={a.msteps_per_s:.3f};"
                 f"supersteps_per_launch={a.supersteps_per_launch:.1f}")
            out.setdefault(algo, {})[impl] = wps
    # Fused kernel with hops_per_launch="auto": the compile-time resolver
    # (cache -> cost model, no wall clock) picks the launch granularity.
    ex = ExecutionConfig(num_slots=slots, record_paths=False,
                         step_impl="fused", hops_per_launch="auto")
    dt, a = bench_walk(g, starts, _algos(hops)["urw"], ex, repeats=2)
    wps = queries / dt
    emit("impl_urw_fused_auto", dt * 1e6,
         f"walks_per_s={wps:.1f};msteps={a.msteps_per_s:.3f};"
         f"supersteps_per_launch={a.supersteps_per_launch:.1f}")
    out.setdefault("urw", {})["fused_auto"] = wps
    return out


if __name__ == "__main__":
    run()
