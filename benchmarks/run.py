# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness (deliverable d): one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run                     # quick suite
  PYTHONPATH=src python -m benchmarks.run --full              # full sizes
  PYTHONPATH=src python -m benchmarks.run --json BENCH.json   # + machine-
      readable {suite: {name: {us_per_call, derived}}} with a per-
      algorithm walks/sec summary across step_impl ∈ {jnp, pallas, fused}

Fig. 8  — vs statically-scheduled FPGA-baseline analogue
Fig. 9  — per-algorithm throughput across datasets
Fig. 10 — RMAT balanced vs Graph500 skew robustness (+ degree-adaptive
          reservoir scan for weighted Node2Vec)
Fig. 11 — scheduler/async ablation breakdown
Table III — channel (device) scaling of the distributed engine
Table IV  — per-kernel on-chip budgets (TPU analogue of LUT/BRAM)
Roofline  — dry-run derived compute/memory/collective terms (§Roofline)
step_impl — walks/sec across the jnp / pallas / fused superstep impls
"""
import argparse
import json
import numbers
import sys
import time


#: Keys every ``environment`` block must carry — numbers vary by host,
#: but the *shape* is part of the BENCH schema so dashboards can always
#: tell CPU-interpret runs from real-TPU runs before comparing timings.
ENVIRONMENT_KEYS = ("jax_version", "backend", "device_kind",
                    "device_count", "interpret")


def environment_metadata() -> dict:
    """Execution-environment block recorded in every BENCH payload.

    Timings from an interpret-mode CPU run and a compiled TPU run are
    not comparable; stamping the backend/device/interpret flags into the
    payload makes every BENCH_*.json self-describing.
    """
    import jax

    from repro.kernels.common import default_interpret
    devs = jax.devices()
    return {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_kind": devs[0].device_kind if devs else "unknown",
        "device_count": len(devs),
        "interpret": bool(default_interpret(None)),
    }


def validate_payload(payload) -> list:
    """Validate the BENCH JSON schema before it is written.

    Shape: ``{suite: {row: {"us_per_call": number, "derived": str}}}``
    plus the optional ``walks_per_sec`` summary
    (``{algo: {impl: number}}``) and the ``environment`` block
    (``{jax_version, backend, device_kind, device_count, interpret}``).
    Returns a list of problem strings — a malformed suite result (a
    typo'd key, a non-numeric timing, a stray nesting level) must fail
    the run instead of silently producing a BENCH.json downstream
    dashboards mis-parse.
    """
    problems = []
    if not isinstance(payload, dict):
        return [f"payload is {type(payload).__name__}, expected dict"]
    for suite, rows in payload.items():
        if suite == "environment":
            if not isinstance(rows, dict):
                problems.append(f"environment: expected dict, got "
                                f"{type(rows).__name__}")
                continue
            missing = set(ENVIRONMENT_KEYS) - set(rows)
            extra = set(rows) - set(ENVIRONMENT_KEYS)
            if missing:
                problems.append(f"environment: missing key(s) "
                                f"{sorted(missing)}")
            if extra:
                problems.append(f"environment: unknown key(s) "
                                f"{sorted(extra)}")
            if "device_count" in rows and not isinstance(
                    rows["device_count"], numbers.Real):
                problems.append("environment: device_count is "
                                f"{type(rows['device_count']).__name__}, "
                                f"expected number")
            if "interpret" in rows and not isinstance(
                    rows["interpret"], bool):
                problems.append("environment: interpret is "
                                f"{type(rows['interpret']).__name__}, "
                                f"expected bool")
            for k in ("jax_version", "backend", "device_kind"):
                if k in rows and not isinstance(rows[k], str):
                    problems.append(f"environment: {k} is "
                                    f"{type(rows[k]).__name__}, "
                                    f"expected str")
            continue
        if suite == "walks_per_sec":
            if not isinstance(rows, dict):
                problems.append(f"walks_per_sec: expected dict, got "
                                f"{type(rows).__name__}")
                continue
            for algo, impls in rows.items():
                if not isinstance(impls, dict):
                    problems.append(f"walks_per_sec[{algo!r}]: expected "
                                    f"dict of impl→rate")
                    continue
                for impl, rate in impls.items():
                    if not isinstance(rate, numbers.Real):
                        problems.append(
                            f"walks_per_sec[{algo!r}][{impl!r}]: rate is "
                            f"{type(rate).__name__}, expected number")
            continue
        if not isinstance(rows, dict):
            problems.append(f"suite {suite!r}: expected dict of rows, "
                            f"got {type(rows).__name__}")
            continue
        for row, rec in rows.items():
            if not isinstance(rec, dict):
                problems.append(f"{suite}.{row}: expected record dict, "
                                f"got {type(rec).__name__}")
                continue
            extra = set(rec) - {"us_per_call", "derived"}
            missing = {"us_per_call", "derived"} - set(rec)
            if extra:
                problems.append(f"{suite}.{row}: unknown key(s) "
                                f"{sorted(extra)}")
            if missing:
                problems.append(f"{suite}.{row}: missing key(s) "
                                f"{sorted(missing)}")
            us = rec.get("us_per_call")
            if "us_per_call" in rec and not isinstance(us, numbers.Real):
                problems.append(f"{suite}.{row}: us_per_call is "
                                f"{type(us).__name__}, expected number")
            der = rec.get("derived")
            if "derived" in rec and not isinstance(der, str):
                problems.append(f"{suite}.{row}: derived is "
                                f"{type(der).__name__}, expected str")
    return problems


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write results as JSON to PATH")
    args, _ = ap.parse_known_args()
    quick = not args.full

    from benchmarks import (common, e2e_embeddings, fig8_fpga_baselines,
                            fig9_throughput, fig10_rmat_skew, fig11_ablation,
                            roofline, serve_walks, step_impl_matrix,
                            table3_scaling, table4_kernels, tuned_vs_default)
    suites = {
        "fig8": fig8_fpga_baselines.run,
        "fig9": fig9_throughput.run,
        "fig10": fig10_rmat_skew.run,
        "fig11": fig11_ablation.run,
        "table3": table3_scaling.run,
        "table4": table4_kernels.run,
        "roofline": roofline.run,
        "serve": serve_walks.run,
        "step_impl": step_impl_matrix.run,
        "e2e_embeddings": e2e_embeddings.run,
        "tuned_vs_default": tuned_vs_default.run,
    }
    print("name,us_per_call,derived")
    payload = {"environment": environment_metadata()}
    failed = []
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        t0 = time.time()
        start = len(common.RECORDS)
        try:
            ret = fn(quick=quick)
        except Exception as e:  # a failing suite must not hide the others
            ret = None
            failed.append(name)
            common.emit(f"{name}_SUITE_ERROR", 0.0,
                        f"{type(e).__name__}:{e}")
        payload[name] = {
            row_name: {"us_per_call": us, "derived": derived}
            for row_name, us, derived in common.RECORDS[start:]
        }
        if name == "step_impl" and isinstance(ret, dict):
            # per-algorithm walks/sec summary across the three impls
            payload["walks_per_sec"] = ret
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)

    if args.json:
        problems = validate_payload(payload)
        if problems:
            # never write a malformed BENCH.json — fail loudly instead
            print(f"# BENCH schema invalid ({len(problems)} problem(s)); "
                  f"not writing {args.json}:", file=sys.stderr)
            for p in problems:
                print(f"#   {p}", file=sys.stderr)
            sys.exit(1)
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {args.json}", file=sys.stderr)
    if failed:
        # every suite ran (errors never hide the others), but the harness
        # itself must fail CI when any suite crashed
        print(f"# FAILED suites: {', '.join(failed)}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
