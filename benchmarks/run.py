# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness (deliverable d): one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run                     # quick suite
  PYTHONPATH=src python -m benchmarks.run --full              # full sizes
  PYTHONPATH=src python -m benchmarks.run --json BENCH.json   # + machine-
      readable {suite: {name: {us_per_call, derived}}} with a per-
      algorithm walks/sec summary across step_impl ∈ {jnp, pallas, fused}

Fig. 8  — vs statically-scheduled FPGA-baseline analogue
Fig. 9  — per-algorithm throughput across datasets
Fig. 10 — RMAT balanced vs Graph500 skew robustness (+ degree-adaptive
          reservoir scan for weighted Node2Vec)
Fig. 11 — scheduler/async ablation breakdown
Table III — channel (device) scaling of the distributed engine
Table IV  — per-kernel on-chip budgets (TPU analogue of LUT/BRAM)
Roofline  — dry-run derived compute/memory/collective terms (§Roofline)
step_impl — walks/sec across the jnp / pallas / fused superstep impls
"""
import argparse
import json
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write results as JSON to PATH")
    args, _ = ap.parse_known_args()
    quick = not args.full

    from benchmarks import (common, fig8_fpga_baselines, fig9_throughput,
                            fig10_rmat_skew, fig11_ablation, roofline,
                            serve_walks, step_impl_matrix, table3_scaling,
                            table4_kernels)
    suites = {
        "fig8": fig8_fpga_baselines.run,
        "fig9": fig9_throughput.run,
        "fig10": fig10_rmat_skew.run,
        "fig11": fig11_ablation.run,
        "table3": table3_scaling.run,
        "table4": table4_kernels.run,
        "roofline": roofline.run,
        "serve": serve_walks.run,
        "step_impl": step_impl_matrix.run,
    }
    print("name,us_per_call,derived")
    payload = {}
    failed = []
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        t0 = time.time()
        start = len(common.RECORDS)
        try:
            ret = fn(quick=quick)
        except Exception as e:  # a failing suite must not hide the others
            ret = None
            failed.append(name)
            common.emit(f"{name}_SUITE_ERROR", 0.0,
                        f"{type(e).__name__}:{e}")
        payload[name] = {
            row_name: {"us_per_call": us, "derived": derived}
            for row_name, us, derived in common.RECORDS[start:]
        }
        if name == "step_impl" and isinstance(ret, dict):
            # per-algorithm walks/sec summary across the three impls
            payload["walks_per_sec"] = ret
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)

    if args.json:
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {args.json}", file=sys.stderr)
    if failed:
        # every suite ran (errors never hide the others), but the harness
        # itself must fail CI when any suite crashed
        print(f"# FAILED suites: {', '.join(failed)}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
