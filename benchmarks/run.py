# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness (deliverable d): one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # quick suite
  PYTHONPATH=src python -m benchmarks.run --full     # full sizes

Fig. 8  — vs statically-scheduled FPGA-baseline analogue
Fig. 9  — per-algorithm throughput across datasets
Fig. 10 — RMAT balanced vs Graph500 skew robustness
Fig. 11 — scheduler/async ablation breakdown
Table III — channel (device) scaling of the distributed engine
Table IV  — per-kernel on-chip budgets (TPU analogue of LUT/BRAM)
Roofline  — dry-run derived compute/memory/collective terms (§Roofline)
"""
import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args, _ = ap.parse_known_args()
    quick = not args.full

    from benchmarks import (fig8_fpga_baselines, fig9_throughput,
                            fig10_rmat_skew, fig11_ablation, roofline,
                            serve_walks, table3_scaling, table4_kernels)
    suites = {
        "fig8": fig8_fpga_baselines.run,
        "fig9": fig9_throughput.run,
        "fig10": fig10_rmat_skew.run,
        "fig11": fig11_ablation.run,
        "table3": table3_scaling.run,
        "table4": table4_kernels.run,
        "roofline": roofline.run,
        "serve": serve_walks.run,
    }
    print("name,us_per_call,derived")
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        t0 = time.time()
        try:
            fn(quick=quick)
        except Exception as e:  # a failing suite must not hide the others
            print(f"{name}_SUITE_ERROR,0.0,{type(e).__name__}:{e}",
                  flush=True)
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
