"""Fig. 8 analogue: RidgeWalker vs the statically-scheduled baseline
(FastRW/LightRW-style bulk-synchronous execution) per GRW algorithm.

The paper compares against FPGA accelerators we cannot run; the
*algorithmic* baseline they embody — static lane binding + bulk batches —
is implemented in our engine (mode="static"), so the speedup column is
the scheduling contribution measured under identical compute.
"""
import dataclasses

import numpy as np

from benchmarks.common import bench_walk, emit
from repro.graph import make_dataset
from repro.walker import ExecutionConfig, WalkProgram

DATASETS = ["WG", "CP", "AS", "LJ"]


def run(quick: bool = False):
    datasets = DATASETS[:2] if quick else DATASETS
    queries = 2000 if quick else 8000
    ex = ExecutionConfig(num_slots=256 if quick else 1024,
                         record_paths=False)
    rows = []
    for name in datasets:
        for program, kwargs in [
            (WalkProgram.deepwalk(80), dict(weighted=True, with_alias=True)),
            (WalkProgram.ppr(0.15, 80), {}),
            (WalkProgram.urw(80), {}),
        ]:
            algo = program.name
            g = make_dataset(name, **kwargs)
            starts = np.random.default_rng(0).integers(
                0, g.num_vertices, queries)
            dt_s, a_s = bench_walk(g, starts, program,
                                   dataclasses.replace(ex, mode="static"))
            dt_z, a_z = bench_walk(g, starts, program, ex)
            speedup = dt_s / dt_z
            emit(f"fig8_{algo}_{name}", dt_z * 1e6,
                 f"msteps={a_z.msteps_per_s:.3f};static_msteps="
                 f"{a_s.msteps_per_s:.3f};sched_speedup={speedup:.2f}x;"
                 f"occ={a_z.occupancy:.2f};occ_static={a_s.occupancy:.2f}")
            rows.append((name, algo, speedup))
    return rows


if __name__ == "__main__":
    run()
